//! Vector kernels for the Kaczmarz hot path.
//!
//! Every Kaczmarz iteration is one `dot` (the residual of the sampled row)
//! plus one `axpy` (the projection update), both over a contiguous row of
//! length `n`. These two functions dominate the runtime of every solver in
//! this crate. Each has two implementations: the portable 8-lane scalar
//! kernels (`*_scalar` — the bitwise reference path, LLVM-autovectorized)
//! and explicit AVX2+FMA kernels in [`super::simd`]. The undecorated names
//! (`dot`, `axpy`, `axpy_dot`) dispatch between them once per call based
//! on the process-wide [`super::simd::active_flavor`] probe.

#[cfg(target_arch = "x86_64")]
use super::simd;

/// Dot product `<a, b>`.
///
/// Dispatches to the AVX2+FMA kernel when active (see
/// [`simd::active_flavor`]), otherwise runs [`dot_scalar`].
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if simd::use_avx2() {
        // SAFETY: `use_avx2` is true only when the host probe confirmed
        // AVX2 and FMA support.
        return unsafe { simd::avx::dot(a, b) };
    }
    dot_scalar(a, b)
}

/// Scalar reference dot product — eight-lane blocked accumulation over
/// `chunks_exact(8)`: the fixed-size chunk pattern eliminates bounds
/// checks and reliably auto-vectorizes (measured 6.4x over indexed 4-way
/// unrolling in the §Perf pass — see EXPERIMENTS.md §Perf). This exact
/// accumulator layout and reduction order is the crate's bitwise
/// reproducibility contract.
#[inline]
pub fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for i in 0..8 {
            acc[i] += xa[i] * xb[i];
        }
    }
    let mut tail = 0.0;
    for (x, y) in ra.iter().zip(rb) {
        tail += x * y;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
}

/// `y += alpha * x` (the Kaczmarz projection update).
///
/// Dispatches to the AVX2+FMA kernel when active (see
/// [`simd::active_flavor`]), otherwise runs [`axpy_scalar`].
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if simd::use_avx2() {
        // SAFETY: `use_avx2` is true only when the host probe confirmed
        // AVX2 and FMA support.
        unsafe { simd::avx::axpy(alpha, x, y) };
        return;
    }
    axpy_scalar(alpha, x, y)
}

/// Scalar reference `y += alpha * x` — the bitwise reference path.
#[inline]
pub fn axpy_scalar(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    // chunks_exact pairs: no bounds checks, clean vectorization.
    let cx = x.chunks_exact(8);
    let rx = cx.remainder();
    let mut cy = y.chunks_exact_mut(8);
    for (xa, ya) in cx.zip(&mut cy) {
        for i in 0..8 {
            ya[i] += alpha * xa[i];
        }
    }
    let ry = cy.into_remainder();
    for (xv, yv) in rx.iter().zip(ry) {
        *yv += alpha * xv;
    }
}

/// Fused projection kernel: `y += alpha * x`, returning `<z, y>` over the
/// *updated* `y` — one pass over memory instead of an `axpy` pass followed
/// by a `dot` pass.
///
/// This is the RKAB block-sweep workhorse: projection `j` updates `v` along
/// row `j` while simultaneously computing row `j+1`'s residual dot product
/// against the new `v`, halving the traffic on `v` (the whole block touches
/// each `v` cache line once per projection instead of twice). The lane
/// structure mirrors [`dot`]/[`axpy`] exactly (same 8-wide accumulators,
/// same tail, same final reduction order), so the result is bit-identical
/// to `axpy(alpha, x, y); dot(z, y)` — a contract both kernel flavors
/// keep (each fused kernel mirrors its own flavor's `dot` accumulators),
/// so the identity holds under either dispatch.
#[inline]
pub fn axpy_dot(alpha: f64, x: &[f64], z: &[f64], y: &mut [f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if simd::use_avx2() {
        // SAFETY: `use_avx2` is true only when the host probe confirmed
        // AVX2 and FMA support.
        return unsafe { simd::avx::axpy_dot(alpha, x, z, y) };
    }
    axpy_dot_scalar(alpha, x, z, y)
}

/// Scalar reference fused kernel — the bitwise reference path; lane
/// structure mirrors [`dot_scalar`]/[`axpy_scalar`] exactly.
#[inline]
pub fn axpy_dot_scalar(alpha: f64, x: &[f64], z: &[f64], y: &mut [f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(z.len(), y.len());
    let mut acc = [0.0f64; 8];
    let cx = x.chunks_exact(8);
    let cz = z.chunks_exact(8);
    let (rx, rz) = (cx.remainder(), cz.remainder());
    let mut cy = y.chunks_exact_mut(8);
    for ((xa, za), ya) in cx.zip(cz).zip(&mut cy) {
        for i in 0..8 {
            ya[i] += alpha * xa[i];
            acc[i] += za[i] * ya[i];
        }
    }
    let ry = cy.into_remainder();
    let mut tail = 0.0;
    for ((xv, zv), yv) in rx.iter().zip(rz).zip(ry) {
        *yv += alpha * xv;
        tail += zv * *yv;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
}

/// Squared Euclidean norm `‖v‖²`.
#[inline]
pub fn norm2_sq(v: &[f64]) -> f64 {
    dot(v, v)
}

/// Euclidean norm `‖v‖`.
#[inline]
pub fn norm2(v: &[f64]) -> f64 {
    norm2_sq(v).sqrt()
}

/// `out = a - b`.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Squared distance `‖a - b‖²` without allocating.
///
/// The stopping criterion `‖x^(k) - x*‖² < eps` runs this every iteration
/// when histories are tracked — same 8-lane pattern as [`dot`].
#[inline]
pub fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for i in 0..8 {
            let d = xa[i] - xb[i];
            acc[i] += d * d;
        }
    }
    let mut tail = 0.0;
    for (x, y) in ra.iter().zip(rb) {
        let d = x - y;
        tail += d * d;
    }
    acc.iter().sum::<f64>() + tail
}

/// In-place scalar multiply `v *= alpha`.
#[inline]
pub fn scale_in_place(v: &mut [f64], alpha: f64) {
    for x in v.iter_mut() {
        *x *= alpha;
    }
}

/// `y = x` copy helper (semantic alias used by the solvers for clarity).
#[inline]
pub fn assign(y: &mut [f64], x: &[f64]) {
    y.copy_from_slice(x);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        // Length 11 exercises both the unrolled body and the tail.
        let a: Vec<f64> = (0..11).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..11).map(|i| (i * i) as f64).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn dot_empty_is_zero() {
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn axpy_dot_matches_separate_kernels_bitwise() {
        // Lengths crossing the 8-lane boundary (tail of 0..7 elements).
        for n in [1usize, 7, 8, 9, 16, 63, 64, 65, 200] {
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
            let z: Vec<f64> = (0..n).map(|i| (i as f64 * 0.11).cos()).collect();
            let y0: Vec<f64> = (0..n).map(|i| 1.0 / (i as f64 + 1.0)).collect();
            let alpha = 0.731;

            let mut y_fused = y0.clone();
            let d_fused = axpy_dot(alpha, &x, &z, &mut y_fused);

            let mut y_ref = y0.clone();
            axpy(alpha, &x, &mut y_ref);
            let d_ref = dot(&z, &y_ref);

            assert_eq!(y_fused, y_ref, "n={n}: updated vectors differ");
            assert_eq!(d_fused.to_bits(), d_ref.to_bits(), "n={n}: dots differ");
        }
    }

    #[test]
    fn axpy_updates() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn norms() {
        let v = [3.0, 4.0];
        assert_eq!(norm2_sq(&v), 25.0);
        assert_eq!(norm2(&v), 5.0);
    }

    #[test]
    fn sub_and_dist() {
        let a = [5.0, 7.0];
        let b = [2.0, 3.0];
        assert_eq!(sub(&a, &b), vec![3.0, 4.0]);
        assert_eq!(dist_sq(&a, &b), 25.0);
    }

    #[test]
    fn scale_in_place_works() {
        let mut v = [1.0, -2.0, 0.5];
        scale_in_place(&mut v, -2.0);
        assert_eq!(v, [-2.0, 4.0, -1.0]);
    }

    #[test]
    fn assign_copies() {
        let mut y = [0.0; 3];
        assign(&mut y, &[1.0, 2.0, 3.0]);
        assert_eq!(y, [1.0, 2.0, 3.0]);
    }
}
