//! Storage-generic row access: one trait, two backends.
//!
//! Every Kaczmarz variant in this crate touches the matrix the same way —
//! read a row, dot it against the iterate, axpy it back — so the whole
//! solver stack can be made storage-agnostic with one small trait.
//! [`RowStorage`] captures exactly the operations the 11 solve loops, the
//! stopping/telemetry GEMVs, and the batch-serving layer perform:
//!
//! - row-scoped `dot` / `axpy` and the fused [`RowStorage::row_axpy_dot`]
//!   (the RKAB block-sweep workhorse),
//! - column-ranged flavors for the block-parallel column partitioning
//!   (`block_seq`),
//! - `(column, value)` iteration for scatter-style updates (`asyrk`),
//! - the row-norm precomputation behind eq.-4 sampling, and the
//!   matrix-vector products behind residual stopping and CGLS,
//! - column access (`col_norms_sq` / `col_dot` / `col_axpy`) for the
//!   Randomized Extended Kaczmarz column projections (`rek`).
//!
//! Two backends implement it: the paper's Arc-backed dense [`Matrix`]
//! (reference implementation — every dense trait method delegates to the
//! exact kernels the solvers called before this abstraction existed, so
//! dense results are *bitwise identical* to the pre-trait code) and the
//! sparse [`CsrMatrix`], whose row operations touch only stored entries.
//!
//! [`Storage`] is the two-variant enum the crate's [`LinearSystem`] holds.
//! Enum dispatch was chosen over generics deliberately: the solvers, the
//! batch layer, and the distributed engines stay non-generic (no type
//! parameter explosion through `Solver`/`BatchSolver`/`SimCluster`), the
//! branch is per-*operation* on rows of length `n` (noise next to the
//! `O(n)` kernel behind it), and heterogeneous queues of dense and sparse
//! jobs need no trait objects.
//!
//! [`LinearSystem`]: crate::data::LinearSystem

use super::csr::CsrMatrix;
use super::gemv::{gemv_block_into_with_panel, gemv_panel};
use super::matrix::Matrix;
use super::vector::{axpy, axpy_dot, dot, norm2_sq};
use crate::error::{Error, Result};

/// Iterator over one row's `(column, value)` entries, concrete so the trait
/// stays object-safe-free of generics and builds on older toolchains.
///
/// The dense flavor yields **every** position — zeros included — which is
/// what keeps scatter-style consumers (the asynchronous solver's per-entry
/// atomic adds) bitwise identical to the pre-trait row loops. The sparse
/// flavor yields stored entries only, column-sorted.
pub enum RowEntries<'a> {
    /// Dense row: every `(j, a_ij)` for `j in 0..cols`, zeros included.
    Dense(std::iter::Enumerate<std::slice::Iter<'a, f64>>),
    /// Sparse row: stored entries only, column-sorted.
    Sparse(std::iter::Zip<std::slice::Iter<'a, usize>, std::slice::Iter<'a, f64>>),
}

impl Iterator for RowEntries<'_> {
    type Item = (usize, f64);

    #[inline]
    fn next(&mut self) -> Option<(usize, f64)> {
        match self {
            RowEntries::Dense(it) => it.next().map(|(j, &v)| (j, v)),
            RowEntries::Sparse(it) => it.next().map(|(&j, &v)| (j, v)),
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        match self {
            RowEntries::Dense(it) => it.size_hint(),
            RowEntries::Sparse(it) => it.size_hint(),
        }
    }
}

/// Row-access contract every Kaczmarz solve loop runs against.
///
/// Implementations must treat `i`/`next` as in-range row indices (callers
/// sample them from the system's row distribution) and slices as full-length
/// (`x`/`y` of length `cols`, GEMV outputs of length `rows`).
pub trait RowStorage {
    /// Number of rows (`m` in the paper).
    fn rows(&self) -> usize;

    /// Number of columns (`n` in the paper).
    fn cols(&self) -> usize;

    /// Squared Euclidean norm of every row: `‖A^(i)‖²` (the eq.-4 sampling
    /// weights; precomputed once per system).
    fn row_norms_sq(&self) -> Vec<f64>;

    /// Residual dot product `<A^(i), x>` of row `i` against `x`.
    fn row_dot(&self, i: usize, x: &[f64]) -> f64;

    /// Projection update `y += scale * A^(i)` along row `i`.
    fn row_axpy(&self, i: usize, scale: f64, y: &mut [f64]);

    /// Fused projection: `y += scale * A^(i)`, returning `<A^(next), y>`
    /// over the *updated* `y` — the RKAB block-sweep workhorse. Dense
    /// storage fuses the two passes over `y` into one; sparse storage
    /// updates only row `i`'s stored coordinates of `y` before reading row
    /// `next`'s.
    fn row_axpy_dot(&self, i: usize, scale: f64, next: usize, y: &mut [f64]) -> f64;

    /// Column-ranged residual dot `<A^(i)[lo..hi], x[lo..hi]>` (`x` is the
    /// full-length vector; the block-parallel engine hands each worker one
    /// column chunk).
    fn row_dot_range(&self, i: usize, lo: usize, hi: usize, x: &[f64]) -> f64;

    /// Column-ranged projection update `y[j] += scale * a_ij` for
    /// `j in lo..hi` (`y` is the full-length vector).
    fn row_axpy_range(&self, i: usize, scale: f64, lo: usize, hi: usize, y: &mut [f64]);

    /// Iterate row `i`'s `(column, value)` entries — all positions for
    /// dense storage, stored entries for sparse (see [`RowEntries`]).
    fn row_entries(&self, i: usize) -> RowEntries<'_>;

    /// Squared Euclidean norm of every column: `‖A_(j)‖²` (REK's column
    /// sampling weights; the column dual of [`RowStorage::row_norms_sq`],
    /// precomputed once per solve).
    fn col_norms_sq(&self) -> Vec<f64>;

    /// Column dot product `<A_(j), y>` of column `j` against a
    /// length-`rows` vector `y` (REK's column-projection residual).
    fn col_dot(&self, j: usize, y: &[f64]) -> f64;

    /// Column update `y += scale * A_(j)` (`y` of length `rows`).
    fn col_axpy(&self, j: usize, scale: f64, y: &mut [f64]);

    /// `y = A x` (no allocation; hot path behind residual stopping).
    fn gemv_into(&self, x: &[f64], y: &mut [f64]);

    /// Cache-blocked `y = A x` for wide dense matrices; sparse storage has
    /// no panel to block (rows already touch only their stored columns), so
    /// it coincides with [`RowStorage::gemv_into`].
    fn gemv_block_into(&self, x: &[f64], y: &mut [f64]);

    /// `y = Aᵀ x` without materializing `Aᵀ` (row-scaled accumulation).
    fn gemv_transpose_into(&self, x: &[f64], y: &mut [f64]);
}

impl RowStorage for Matrix {
    #[inline]
    fn rows(&self) -> usize {
        Matrix::rows(self)
    }

    #[inline]
    fn cols(&self) -> usize {
        Matrix::cols(self)
    }

    fn row_norms_sq(&self) -> Vec<f64> {
        self.rows_iter().map(norm2_sq).collect()
    }

    #[inline]
    fn row_dot(&self, i: usize, x: &[f64]) -> f64 {
        dot(self.row(i), x)
    }

    #[inline]
    fn row_axpy(&self, i: usize, scale: f64, y: &mut [f64]) {
        axpy(scale, self.row(i), y);
    }

    #[inline]
    fn row_axpy_dot(&self, i: usize, scale: f64, next: usize, y: &mut [f64]) -> f64 {
        axpy_dot(scale, self.row(i), self.row(next), y)
    }

    #[inline]
    fn row_dot_range(&self, i: usize, lo: usize, hi: usize, x: &[f64]) -> f64 {
        dot(&self.row(i)[lo..hi], &x[lo..hi])
    }

    #[inline]
    fn row_axpy_range(&self, i: usize, scale: f64, lo: usize, hi: usize, y: &mut [f64]) {
        let row = self.row(i);
        for j in lo..hi {
            y[j] += scale * row[j];
        }
    }

    #[inline]
    fn row_entries(&self, i: usize) -> RowEntries<'_> {
        RowEntries::Dense(self.row(i).iter().enumerate())
    }

    fn col_norms_sq(&self) -> Vec<f64> {
        Matrix::col_norms_sq(self)
    }

    #[inline]
    fn col_dot(&self, j: usize, y: &[f64]) -> f64 {
        Matrix::col_dot(self, j, y)
    }

    #[inline]
    fn col_axpy(&self, j: usize, scale: f64, y: &mut [f64]) {
        Matrix::col_axpy(self, j, scale, y);
    }

    fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), Matrix::cols(self));
        debug_assert_eq!(y.len(), Matrix::rows(self));
        let panel = gemv_panel();
        if Matrix::cols(self) > panel {
            gemv_block_into_with_panel(self, x, y, panel);
            return;
        }
        for (yi, row) in y.iter_mut().zip(self.rows_iter()) {
            *yi = dot(row, x);
        }
    }

    fn gemv_block_into(&self, x: &[f64], y: &mut [f64]) {
        gemv_block_into_with_panel(self, x, y, gemv_panel());
    }

    fn gemv_transpose_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), Matrix::rows(self));
        debug_assert_eq!(y.len(), Matrix::cols(self));
        y.fill(0.0);
        for (xi, row) in x.iter().zip(self.rows_iter()) {
            if *xi != 0.0 {
                axpy(*xi, row, y);
            }
        }
    }
}

impl RowStorage for CsrMatrix {
    #[inline]
    fn rows(&self) -> usize {
        CsrMatrix::rows(self)
    }

    #[inline]
    fn cols(&self) -> usize {
        CsrMatrix::cols(self)
    }

    fn row_norms_sq(&self) -> Vec<f64> {
        CsrMatrix::row_norms_sq(self)
    }

    #[inline]
    fn row_dot(&self, i: usize, x: &[f64]) -> f64 {
        let mut acc = 0.0;
        for (j, v) in self.row_cols(i).iter().zip(self.row_values(i)) {
            acc += v * x[*j];
        }
        acc
    }

    #[inline]
    fn row_axpy(&self, i: usize, scale: f64, y: &mut [f64]) {
        for (j, v) in self.row_cols(i).iter().zip(self.row_values(i)) {
            y[*j] += scale * v;
        }
    }

    #[inline]
    fn row_axpy_dot(&self, i: usize, scale: f64, next: usize, y: &mut [f64]) -> f64 {
        // Sparse fused flavor: the update touches only row `i`'s stored
        // coordinates of `y`; the dot then reads only row `next`'s.
        self.row_axpy(i, scale, y);
        self.row_dot(next, y)
    }

    #[inline]
    fn row_dot_range(&self, i: usize, lo: usize, hi: usize, x: &[f64]) -> f64 {
        let mut acc = 0.0;
        for (j, v) in self.row_cols(i).iter().zip(self.row_values(i)) {
            if lo <= *j && *j < hi {
                acc += v * x[*j];
            }
        }
        acc
    }

    #[inline]
    fn row_axpy_range(&self, i: usize, scale: f64, lo: usize, hi: usize, y: &mut [f64]) {
        for (j, v) in self.row_cols(i).iter().zip(self.row_values(i)) {
            if lo <= *j && *j < hi {
                y[*j] += scale * v;
            }
        }
    }

    #[inline]
    fn row_entries(&self, i: usize) -> RowEntries<'_> {
        RowEntries::Sparse(self.row_cols(i).iter().zip(self.row_values(i).iter()))
    }

    fn col_norms_sq(&self) -> Vec<f64> {
        CsrMatrix::col_norms_sq(self)
    }

    #[inline]
    fn col_dot(&self, j: usize, y: &[f64]) -> f64 {
        CsrMatrix::col_dot(self, j, y)
    }

    #[inline]
    fn col_axpy(&self, j: usize, scale: f64, y: &mut [f64]) {
        CsrMatrix::col_axpy(self, j, scale, y);
    }

    fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), CsrMatrix::cols(self));
        debug_assert_eq!(y.len(), CsrMatrix::rows(self));
        for (i, yi) in y.iter_mut().enumerate() {
            *yi = self.row_dot(i, x);
        }
    }

    fn gemv_block_into(&self, x: &[f64], y: &mut [f64]) {
        // No column panel to block: each sparse row already touches only its
        // stored columns of `x`.
        self.gemv_into(x, y);
    }

    fn gemv_transpose_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), CsrMatrix::rows(self));
        debug_assert_eq!(y.len(), CsrMatrix::cols(self));
        y.fill(0.0);
        for (i, xi) in x.iter().enumerate() {
            if *xi != 0.0 {
                self.row_axpy(i, *xi, y);
            }
        }
    }
}

/// The storage a [`LinearSystem`](crate::data::LinearSystem) holds: dense or
/// CSR, behind one enum so every solver, the batch layer, and the simulated
/// cluster accept either backend without growing a type parameter.
///
/// Constructors take `impl Into<Storage>`, so call sites keep passing a bare
/// [`Matrix`] (or now a [`CsrMatrix`]) and conversion is implicit.
#[derive(Clone, Debug, PartialEq)]
pub enum Storage {
    /// Dense row-major backend (the paper's native layout).
    Dense(Matrix),
    /// Compressed sparse row backend.
    Csr(CsrMatrix),
}

impl From<Matrix> for Storage {
    fn from(m: Matrix) -> Storage {
        Storage::Dense(m)
    }
}

impl From<CsrMatrix> for Storage {
    fn from(m: CsrMatrix) -> Storage {
        Storage::Csr(m)
    }
}

impl Storage {
    /// Number of rows (`m` in the paper).
    #[inline]
    pub fn rows(&self) -> usize {
        match self {
            Storage::Dense(m) => m.rows(),
            Storage::Csr(m) => m.rows(),
        }
    }

    /// Number of columns (`n` in the paper).
    #[inline]
    pub fn cols(&self) -> usize {
        match self {
            Storage::Dense(m) => m.cols(),
            Storage::Csr(m) => m.cols(),
        }
    }

    /// The dense backend, if that is what this storage holds.
    #[inline]
    pub fn as_dense(&self) -> Option<&Matrix> {
        match self {
            Storage::Dense(m) => Some(m),
            Storage::Csr(_) => None,
        }
    }

    /// The CSR backend, if that is what this storage holds.
    #[inline]
    pub fn as_csr(&self) -> Option<&CsrMatrix> {
        match self {
            Storage::Dense(_) => None,
            Storage::Csr(m) => Some(m),
        }
    }

    /// Contiguous view of row `i` — **dense backend only**.
    ///
    /// # Panics
    ///
    /// Panics on CSR storage, which has no contiguous row slice; iterate
    /// [`Storage::row_entries`] instead (dense-only callers — tests,
    /// oracles — use this knowingly).
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        match self {
            Storage::Dense(m) => m.row(i),
            Storage::Csr(_) => {
                panic!("Storage::row is dense-only; iterate row_entries for CSR")
            }
        }
    }

    /// Mutable view of row `i` — **dense backend only** (copy-on-write).
    ///
    /// # Panics
    ///
    /// Panics on CSR storage (sparse rows cannot be rewritten in place).
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        match self {
            Storage::Dense(m) => m.row_mut(i),
            Storage::Csr(_) => {
                panic!("Storage::row_mut is dense-only; rebuild the CsrMatrix instead")
            }
        }
    }

    /// Do `self` and `other` share one storage buffer?
    ///
    /// Delegates to the backend's `Arc::ptr_eq` check; storages of different
    /// kinds never share. The batch layer's "one resident `A` across all
    /// lanes" guarantee is asserted through this.
    pub fn shares_storage(&self, other: &Storage) -> bool {
        match (self, other) {
            (Storage::Dense(a), Storage::Dense(b)) => a.shares_storage(b),
            (Storage::Csr(a), Storage::Csr(b)) => a.shares_storage(b),
            _ => false,
        }
    }

    /// Squared Euclidean norm of every row (eq.-4 sampling weights).
    pub fn row_norms_sq(&self) -> Vec<f64> {
        match self {
            Storage::Dense(m) => RowStorage::row_norms_sq(m),
            Storage::Csr(m) => RowStorage::row_norms_sq(m),
        }
    }

    /// Squared Frobenius norm `‖A‖²_F`.
    pub fn frobenius_sq(&self) -> f64 {
        match self {
            Storage::Dense(m) => m.frobenius_sq(),
            Storage::Csr(m) => m.frobenius_sq(),
        }
    }

    /// Residual dot product `<A^(i), x>` (see [`RowStorage::row_dot`]).
    #[inline]
    pub fn row_dot(&self, i: usize, x: &[f64]) -> f64 {
        match self {
            Storage::Dense(m) => RowStorage::row_dot(m, i, x),
            Storage::Csr(m) => RowStorage::row_dot(m, i, x),
        }
    }

    /// Projection update `y += scale * A^(i)` (see [`RowStorage::row_axpy`]).
    #[inline]
    pub fn row_axpy(&self, i: usize, scale: f64, y: &mut [f64]) {
        match self {
            Storage::Dense(m) => RowStorage::row_axpy(m, i, scale, y),
            Storage::Csr(m) => RowStorage::row_axpy(m, i, scale, y),
        }
    }

    /// Fused projection + next-row dot (see [`RowStorage::row_axpy_dot`]).
    #[inline]
    pub fn row_axpy_dot(&self, i: usize, scale: f64, next: usize, y: &mut [f64]) -> f64 {
        match self {
            Storage::Dense(m) => RowStorage::row_axpy_dot(m, i, scale, next, y),
            Storage::Csr(m) => RowStorage::row_axpy_dot(m, i, scale, next, y),
        }
    }

    /// Column-ranged residual dot (see [`RowStorage::row_dot_range`]).
    #[inline]
    pub fn row_dot_range(&self, i: usize, lo: usize, hi: usize, x: &[f64]) -> f64 {
        match self {
            Storage::Dense(m) => RowStorage::row_dot_range(m, i, lo, hi, x),
            Storage::Csr(m) => RowStorage::row_dot_range(m, i, lo, hi, x),
        }
    }

    /// Column-ranged projection update (see [`RowStorage::row_axpy_range`]).
    #[inline]
    pub fn row_axpy_range(&self, i: usize, scale: f64, lo: usize, hi: usize, y: &mut [f64]) {
        match self {
            Storage::Dense(m) => RowStorage::row_axpy_range(m, i, scale, lo, hi, y),
            Storage::Csr(m) => RowStorage::row_axpy_range(m, i, scale, lo, hi, y),
        }
    }

    /// Iterate row `i`'s `(column, value)` entries (see
    /// [`RowStorage::row_entries`]).
    #[inline]
    pub fn row_entries(&self, i: usize) -> RowEntries<'_> {
        match self {
            Storage::Dense(m) => RowStorage::row_entries(m, i),
            Storage::Csr(m) => RowStorage::row_entries(m, i),
        }
    }

    /// Squared Euclidean norm of every column (REK's column sampling
    /// weights; see [`RowStorage::col_norms_sq`]).
    pub fn col_norms_sq(&self) -> Vec<f64> {
        match self {
            Storage::Dense(m) => m.col_norms_sq(),
            Storage::Csr(m) => m.col_norms_sq(),
        }
    }

    /// Column dot product `<A_(j), y>` (see [`RowStorage::col_dot`]).
    #[inline]
    pub fn col_dot(&self, j: usize, y: &[f64]) -> f64 {
        match self {
            Storage::Dense(m) => m.col_dot(j, y),
            Storage::Csr(m) => m.col_dot(j, y),
        }
    }

    /// Column update `y += scale * A_(j)` (see [`RowStorage::col_axpy`]).
    #[inline]
    pub fn col_axpy(&self, j: usize, scale: f64, y: &mut [f64]) {
        match self {
            Storage::Dense(m) => m.col_axpy(j, scale, y),
            Storage::Csr(m) => m.col_axpy(j, scale, y),
        }
    }

    /// Contiguous block of rows `[start, end)` in the same backend. Dense
    /// blocks and CSR blocks both alias the parent's `Arc` storage
    /// ([`Storage::shares_storage`] holds until a dense block is mutated).
    pub fn row_block(&self, start: usize, end: usize) -> Result<Storage> {
        match self {
            Storage::Dense(m) => Ok(Storage::Dense(m.row_block(start, end)?)),
            Storage::Csr(m) => Ok(Storage::Csr(m.row_block(start, end)?)),
        }
    }

    /// Top-left `rows x cols` submatrix in the same backend (§3.1 cropping).
    pub fn crop(&self, rows: usize, cols: usize) -> Result<Storage> {
        match self {
            Storage::Dense(m) => Ok(Storage::Dense(m.crop(rows, cols)?)),
            Storage::Csr(m) => Ok(Storage::Csr(m.crop(rows, cols)?)),
        }
    }

    /// Gram matrix `AᵀA` (always dense: it is `n x n` and feeds the dense
    /// spectral-bound machinery).
    pub fn gram(&self) -> Matrix {
        match self {
            Storage::Dense(m) => m.gram(),
            Storage::Csr(m) => m.gram(),
        }
    }

    // -- Checked kernel entry points ------------------------------------
    //
    // The raw kernels (`dot`/`axpy`/`axpy_dot` and the `row_*` trait
    // methods above) guard length mismatches only with `debug_assert_eq!`
    // to keep the hot loops branch-free: in release a mismatched caller
    // silently computes over the common prefix. Internal callers uphold
    // the contract (vectors are sized once per solve from the system's
    // dimensions), but *external* callers reach the kernels through these
    // `try_*` boundary methods, which validate shapes once per call and
    // return a typed [`Error::InvalidArgument`] instead.

    /// Shape-check helper for the `try_*` boundary: row index in range,
    /// vector exactly `cols` long.
    fn check_row_vec(&self, what: &str, i: usize, len: usize) -> Result<()> {
        if i >= self.rows() {
            return Err(Error::InvalidArgument(format!(
                "{what}: row index {i} out of range for {} rows",
                self.rows()
            )));
        }
        if len != self.cols() {
            return Err(Error::InvalidArgument(format!(
                "{what}: vector has len {len}, matrix has {} cols",
                self.cols()
            )));
        }
        Ok(())
    }

    /// Checked [`Storage::row_dot`]: validates the row index and the
    /// length of `x` before touching the branch-free kernel.
    pub fn try_row_dot(&self, i: usize, x: &[f64]) -> Result<f64> {
        self.check_row_vec("try_row_dot", i, x.len())?;
        Ok(self.row_dot(i, x))
    }

    /// Checked [`Storage::row_axpy`]: validates the row index and the
    /// length of `y` before touching the branch-free kernel.
    pub fn try_row_axpy(&self, i: usize, scale: f64, y: &mut [f64]) -> Result<()> {
        self.check_row_vec("try_row_axpy", i, y.len())?;
        self.row_axpy(i, scale, y);
        Ok(())
    }

    /// Checked [`Storage::row_axpy_dot`]: validates both row indices and
    /// the length of `y` before touching the fused kernel.
    pub fn try_row_axpy_dot(&self, i: usize, scale: f64, next: usize, y: &mut [f64]) -> Result<f64> {
        self.check_row_vec("try_row_axpy_dot", i, y.len())?;
        self.check_row_vec("try_row_axpy_dot", next, y.len())?;
        Ok(self.row_axpy_dot(i, scale, next, y))
    }

    /// Checked `y = A x`: validates `x` against `cols` and `y` against
    /// `rows`, then runs the (blocked, possibly SIMD) GEMV kernel.
    pub fn try_gemv_into(&self, x: &[f64], y: &mut [f64]) -> Result<()> {
        if x.len() != self.cols() || y.len() != self.rows() {
            return Err(Error::InvalidArgument(format!(
                "try_gemv_into: A is {}x{}, x has len {}, y has len {}",
                self.rows(),
                self.cols(),
                x.len(),
                y.len()
            )));
        }
        RowStorage::gemv_block_into(self, x, y);
        Ok(())
    }
}

impl RowStorage for Storage {
    fn rows(&self) -> usize {
        Storage::rows(self)
    }

    fn cols(&self) -> usize {
        Storage::cols(self)
    }

    fn row_norms_sq(&self) -> Vec<f64> {
        Storage::row_norms_sq(self)
    }

    fn row_dot(&self, i: usize, x: &[f64]) -> f64 {
        Storage::row_dot(self, i, x)
    }

    fn row_axpy(&self, i: usize, scale: f64, y: &mut [f64]) {
        Storage::row_axpy(self, i, scale, y);
    }

    fn row_axpy_dot(&self, i: usize, scale: f64, next: usize, y: &mut [f64]) -> f64 {
        Storage::row_axpy_dot(self, i, scale, next, y)
    }

    fn row_dot_range(&self, i: usize, lo: usize, hi: usize, x: &[f64]) -> f64 {
        Storage::row_dot_range(self, i, lo, hi, x)
    }

    fn row_axpy_range(&self, i: usize, scale: f64, lo: usize, hi: usize, y: &mut [f64]) {
        Storage::row_axpy_range(self, i, scale, lo, hi, y);
    }

    fn row_entries(&self, i: usize) -> RowEntries<'_> {
        Storage::row_entries(self, i)
    }

    fn col_norms_sq(&self) -> Vec<f64> {
        Storage::col_norms_sq(self)
    }

    fn col_dot(&self, j: usize, y: &[f64]) -> f64 {
        Storage::col_dot(self, j, y)
    }

    fn col_axpy(&self, j: usize, scale: f64, y: &mut [f64]) {
        Storage::col_axpy(self, j, scale, y);
    }

    fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            Storage::Dense(m) => RowStorage::gemv_into(m, x, y),
            Storage::Csr(m) => RowStorage::gemv_into(m, x, y),
        }
    }

    fn gemv_block_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            Storage::Dense(m) => RowStorage::gemv_block_into(m, x, y),
            Storage::Csr(m) => RowStorage::gemv_block_into(m, x, y),
        }
    }

    fn gemv_transpose_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            Storage::Dense(m) => RowStorage::gemv_transpose_into(m, x, y),
            Storage::Csr(m) => RowStorage::gemv_transpose_into(m, x, y),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_sample(m: usize, n: usize) -> Matrix {
        let data: Vec<f64> = (0..m * n).map(|i| ((i * 13 % 17) as f64) - 8.0).collect();
        Matrix::from_vec(m, n, data).unwrap()
    }

    #[test]
    fn dense_row_ops_are_bitwise_the_kernels() {
        let a = dense_sample(5, 11);
        let x: Vec<f64> = (0..11).map(|i| (i as f64 * 0.37).sin()).collect();
        for i in 0..5 {
            let d_trait = RowStorage::row_dot(&a, i, &x);
            let d_kernel = dot(a.row(i), &x);
            assert_eq!(d_trait.to_bits(), d_kernel.to_bits());

            let mut y1 = x.clone();
            let mut y2 = x.clone();
            RowStorage::row_axpy(&a, i, 0.731, &mut y1);
            axpy(0.731, a.row(i), &mut y2);
            assert_eq!(y1, y2);

            let next = (i + 1) % 5;
            let mut v1 = x.clone();
            let mut v2 = x.clone();
            let f1 = RowStorage::row_axpy_dot(&a, i, -0.2, next, &mut v1);
            let f2 = axpy_dot(-0.2, a.row(i), a.row(next), &mut v2);
            assert_eq!(f1.to_bits(), f2.to_bits());
            assert_eq!(v1, v2);
        }
    }

    #[test]
    fn dense_ranged_ops_match_slicing() {
        let a = dense_sample(3, 10);
        let x: Vec<f64> = (0..10).map(|i| 1.0 / (i as f64 + 1.0)).collect();
        let (lo, hi) = (3, 8);
        let d = RowStorage::row_dot_range(&a, 1, lo, hi, &x);
        assert_eq!(d.to_bits(), dot(&a.row(1)[lo..hi], &x[lo..hi]).to_bits());

        let mut y1 = x.clone();
        RowStorage::row_axpy_range(&a, 1, 2.0, lo, hi, &mut y1);
        for j in 0..10 {
            let expect = if (lo..hi).contains(&j) { x[j] + 2.0 * a.row(1)[j] } else { x[j] };
            assert_eq!(y1[j].to_bits(), expect.to_bits(), "j={j}");
        }
    }

    #[test]
    fn dense_row_entries_include_zeros() {
        let a = Matrix::from_vec(1, 4, vec![0.0, 2.0, 0.0, -1.0]).unwrap();
        let entries: Vec<(usize, f64)> = RowStorage::row_entries(&a, 0).collect();
        assert_eq!(entries, vec![(0, 0.0), (1, 2.0), (2, 0.0), (3, -1.0)]);
    }

    #[test]
    fn csr_row_ops_match_dense_within_tolerance() {
        let d = dense_sample(6, 9);
        let s = CsrMatrix::from_dense(&d);
        let x: Vec<f64> = (0..9).map(|i| (i as f64 * 0.11).cos()).collect();
        for i in 0..6 {
            let dd = RowStorage::row_dot(&d, i, &x);
            let ds = RowStorage::row_dot(&s, i, &x);
            assert!((dd - ds).abs() < 1e-12, "row {i}: {dd} vs {ds}");

            let mut y1 = x.clone();
            let mut y2 = x.clone();
            RowStorage::row_axpy(&d, i, 0.4, &mut y1);
            RowStorage::row_axpy(&s, i, 0.4, &mut y2);
            for (u, v) in y1.iter().zip(&y2) {
                assert!((u - v).abs() < 1e-12);
            }

            let r = RowStorage::row_dot_range(&s, i, 2, 7, &x);
            let rd = RowStorage::row_dot_range(&d, i, 2, 7, &x);
            assert!((r - rd).abs() < 1e-12);
        }
    }

    #[test]
    fn csr_axpy_touches_only_stored_coordinates() {
        let s = CsrMatrix::from_triplets(2, 5, &[(0, 1, 3.0), (0, 3, -2.0)]).unwrap();
        let sentinel = vec![10.0, 20.0, 30.0, 40.0, 50.0];
        let mut y = sentinel.clone();
        RowStorage::row_axpy(&s, 0, 2.0, &mut y);
        assert_eq!(y, vec![10.0, 26.0, 30.0, 36.0, 50.0]);
        let mut z = sentinel.clone();
        // Empty row 1: the update is a no-op and the dot over row 0 reads
        // only coordinates 1 and 3.
        let f = RowStorage::row_axpy_dot(&s, 1, 7.0, 0, &mut z);
        assert_eq!(z, sentinel);
        assert_eq!(f, 3.0 * 20.0 + (-2.0) * 40.0);
    }

    #[test]
    fn sparse_row_entries_are_sorted_stored_only() {
        let s = CsrMatrix::from_triplets(1, 6, &[(0, 4, 1.5), (0, 2, -3.0)]).unwrap();
        let entries: Vec<(usize, f64)> = RowStorage::row_entries(&s, 0).collect();
        assert_eq!(entries, vec![(2, -3.0), (4, 1.5)]);
    }

    #[test]
    fn storage_enum_dispatches_both_backends() {
        let d = dense_sample(4, 6);
        let s: Storage = CsrMatrix::from_dense(&d).into();
        let dense: Storage = d.clone().into();
        assert_eq!(dense.rows(), 4);
        assert_eq!(s.cols(), 6);
        assert!(dense.as_dense().is_some() && dense.as_csr().is_none());
        assert!(s.as_csr().is_some() && s.as_dense().is_none());
        for (a, b) in dense.row_norms_sq().iter().zip(&s.row_norms_sq()) {
            assert_eq!(a.to_bits(), b.to_bits(), "no explicit zeros: norms are bitwise");
        }
        assert!((dense.frobenius_sq() - s.frobenius_sq()).abs() < 1e-12);
        let x: Vec<f64> = (0..6).map(|i| i as f64 - 2.5).collect();
        let mut yd = vec![0.0; 4];
        let mut ys = vec![0.0; 4];
        RowStorage::gemv_into(&dense, &x, &mut yd);
        RowStorage::gemv_into(&s, &x, &mut ys);
        for (u, v) in yd.iter().zip(&ys) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn column_ops_are_bitwise_across_backends_without_zeros() {
        // dense_sample hits zero at (i*13 % 17) == 8; shift the pattern so
        // every entry is nonzero and the CSR twin stores the full matrix —
        // then both backends run the same per-column accumulation sequence
        // and the results must be bitwise equal, not just close.
        let data: Vec<f64> = (0..5 * 7).map(|i| ((i * 13 % 17) as f64) - 8.25).collect();
        let d = Matrix::from_vec(5, 7, data).unwrap();
        let s = CsrMatrix::from_dense(&d);
        assert_eq!(s.nnz(), 35, "twin must store every entry");
        let y: Vec<f64> = (0..5).map(|i| (i as f64 * 0.53).cos()).collect();
        for (a, b) in d.col_norms_sq().iter().zip(&s.col_norms_sq()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for j in 0..7 {
            assert_eq!(
                RowStorage::col_dot(&d, j, &y).to_bits(),
                RowStorage::col_dot(&s, j, &y).to_bits(),
                "col {j} dot"
            );
            let mut zd = y.clone();
            let mut zs = y.clone();
            RowStorage::col_axpy(&d, j, -0.375, &mut zd);
            RowStorage::col_axpy(&s, j, -0.375, &mut zs);
            for (u, v) in zd.iter().zip(&zs) {
                assert_eq!(u.to_bits(), v.to_bits(), "col {j} axpy");
            }
        }
        // Enum dispatch reaches the same code.
        let sd: Storage = d.clone().into();
        let sc: Storage = s.into();
        assert_eq!(sd.col_dot(3, &y).to_bits(), sc.col_dot(3, &y).to_bits());
        assert_eq!(sd.col_norms_sq(), sc.col_norms_sq());
    }

    #[test]
    fn storage_sharing_is_per_backend() {
        let d = dense_sample(3, 3);
        let sd: Storage = d.clone().into();
        let sd2 = sd.clone();
        assert!(sd.shares_storage(&sd2));
        let sc: Storage = CsrMatrix::from_dense(&d).into();
        let sc2 = sc.clone();
        assert!(sc.shares_storage(&sc2));
        assert!(!sd.shares_storage(&sc), "different backends never share");
        let block = sc.row_block(1, 3).unwrap();
        assert!(block.shares_storage(&sc), "CSR row blocks alias the parent");
    }

    #[test]
    fn storage_row_block_and_crop_stay_in_backend() {
        let d = dense_sample(4, 4);
        let sd: Storage = d.clone().into();
        let sc: Storage = CsrMatrix::from_dense(&d).into();
        assert!(sd.row_block(1, 3).unwrap().as_dense().is_some());
        assert!(sc.row_block(1, 3).unwrap().as_csr().is_some());
        assert!(sd.crop(2, 2).unwrap().as_dense().is_some());
        assert!(sc.crop(2, 2).unwrap().as_csr().is_some());
        assert!(sc.row_block(3, 5).is_err());
    }

    #[test]
    fn checked_boundary_rejects_bad_shapes_and_accepts_good() {
        for st in [
            Storage::from(dense_sample(4, 6)),
            Storage::from(CsrMatrix::from_dense(&dense_sample(4, 6))),
        ] {
            let x_good: Vec<f64> = (0..6).map(|i| i as f64).collect();
            let x_short = vec![1.0; 5];
            // NB: these run in release too (no debug_assert involved).
            assert!(st.try_row_dot(0, &x_good).is_ok());
            assert!(st.try_row_dot(0, &x_short).is_err());
            assert!(st.try_row_dot(4, &x_good).is_err(), "row index OOB");
            let mut y = x_good.clone();
            assert!(st.try_row_axpy(1, 0.5, &mut y).is_ok());
            assert!(st.try_row_axpy(1, 0.5, &mut y[..5]).is_err());
            assert!(st.try_row_axpy_dot(1, 0.5, 2, &mut y).is_ok());
            assert!(st.try_row_axpy_dot(1, 0.5, 9, &mut y).is_err(), "next OOB");
            let mut out = vec![0.0; 4];
            assert!(st.try_gemv_into(&x_good, &mut out).is_ok());
            assert!(st.try_gemv_into(&x_short, &mut out).is_err());
            assert!(st.try_gemv_into(&x_good, &mut out[..3]).is_err());
            // The checked GEMV matches the unchecked kernel bitwise.
            let mut reference = vec![0.0; 4];
            RowStorage::gemv_block_into(&st, &x_good, &mut reference);
            st.try_gemv_into(&x_good, &mut out).unwrap();
            for (u, v) in out.iter().zip(&reference) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }

    #[test]
    fn gemv_transpose_agrees_across_backends() {
        let d = dense_sample(5, 4);
        let s = CsrMatrix::from_dense(&d);
        let x: Vec<f64> = (0..5).map(|i| (i as f64).sqrt() - 1.0).collect();
        let mut yd = vec![0.0; 4];
        let mut ys = vec![0.0; 4];
        RowStorage::gemv_transpose_into(&d, &x, &mut yd);
        RowStorage::gemv_transpose_into(&s, &x, &mut ys);
        for (u, v) in yd.iter().zip(&ys) {
            assert!((u - v).abs() < 1e-10);
        }
    }
}
