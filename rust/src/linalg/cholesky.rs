//! Cholesky factorization of a symmetric positive-definite matrix.
//!
//! Used by the inverse power iteration: finding `σ_min(A)` for the optimal
//! RKA relaxation parameter requires the *smallest* eigenvalue of `G = AᵀA`,
//! which we obtain by iterating `G⁻¹` — i.e. solving `G z = v` repeatedly
//! with a factorization computed once.

use super::matrix::Matrix;
use crate::error::{Error, Result};

/// Lower-triangular Cholesky factor `L` with `G = L Lᵀ`.
pub struct Cholesky {
    l: Matrix,
}

impl Cholesky {
    /// Factorize a symmetric positive-definite matrix.
    ///
    /// Returns `Error::InvalidArgument` if the matrix is not square or a
    /// non-positive pivot appears (not SPD, up to roundoff).
    pub fn new(g: &Matrix) -> Result<Self> {
        if g.rows() != g.cols() {
            return Err(Error::InvalidArgument(format!(
                "cholesky needs a square matrix, got {}x{}",
                g.rows(),
                g.cols()
            )));
        }
        let n = g.rows();
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // sum = G[i][j] - Σ_{k<j} L[i][k] L[j][k]
                let mut sum = g[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return Err(Error::InvalidArgument(format!(
                            "matrix not positive definite (pivot {} at row {})",
                            sum, i
                        )));
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// The lower-triangular factor.
    pub fn factor(&self) -> &Matrix {
        &self.l
    }

    /// Solve `G x = b` via forward + backward substitution.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.l.rows();
        if b.len() != n {
            return Err(Error::Dimension(format!(
                "cholesky solve: order {}, rhs len {}",
                n,
                b.len()
            )));
        }
        // Forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            let row = self.l.row(i);
            for k in 0..i {
                sum -= row[k] * y[k];
            }
            y[i] = sum / row[i];
        }
        // Backward: Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in i + 1..n {
                sum -= self.l[(k, i)] * x[k];
            }
            x[i] = sum / self.l[(i, i)];
        }
        Ok(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemv::gemv;

    fn spd() -> Matrix {
        // 4 2 1 / 2 5 3 / 1 3 6 — diagonally dominant, SPD.
        Matrix::from_vec(3, 3, vec![4.0, 2.0, 1.0, 2.0, 5.0, 3.0, 1.0, 3.0, 6.0]).unwrap()
    }

    #[test]
    fn factor_reconstructs() {
        let g = spd();
        let ch = Cholesky::new(&g).unwrap();
        let l = ch.factor();
        let llt = l.matmul(&l.transpose()).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                assert!((llt[(i, j)] - g[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn solve_roundtrips() {
        let g = spd();
        let ch = Cholesky::new(&g).unwrap();
        let x_true = vec![1.0, -2.0, 0.5];
        let b = gemv(&g, &x_true).unwrap();
        let x = ch.solve(&b).unwrap();
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn rejects_non_spd() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]).unwrap(); // eigenvalues 3, -1
        assert!(Cholesky::new(&m).is_err());
    }

    #[test]
    fn rejects_non_square() {
        let m = Matrix::zeros(2, 3);
        assert!(Cholesky::new(&m).is_err());
    }

    #[test]
    fn solve_rejects_bad_rhs() {
        let ch = Cholesky::new(&spd()).unwrap();
        assert!(ch.solve(&[1.0, 2.0]).is_err());
    }
}
