//! Singular values via one-sided Jacobi.
//!
//! Test oracle for `solvers::alpha` (which uses the cheaper power /
//! inverse-power iterations on `AᵀA`). One-sided Jacobi orthogonalizes the
//! *columns* of A by plane rotations; at convergence the column norms are
//! the singular values. Robust for the small/medium matrices the tests use.

use super::matrix::Matrix;
use super::vector::{dot, norm2};
use crate::error::{Error, Result};

/// All singular values of `a`, descending.
///
/// `tol` bounds the normalized off-diagonal inner products; a few sweeps
/// (typically < 15) suffice for random dense matrices.
pub fn jacobi_singular_values(a: &Matrix, tol: f64, max_sweeps: usize) -> Result<Vec<f64>> {
    if a.rows() < a.cols() {
        return Err(Error::InvalidArgument(
            "one-sided jacobi expects m >= n (overdetermined, as in the paper)".into(),
        ));
    }
    let m = a.rows();
    let n = a.cols();
    // Work on columns: transpose into column-major (each "row" of `cols` is a column of A).
    let mut cols: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..m).map(|i| a[(i, j)]).collect())
        .collect();

    for _sweep in 0..max_sweeps {
        let mut converged = true;
        for p in 0..n {
            for q in p + 1..n {
                let app = dot(&cols[p], &cols[p]);
                let aqq = dot(&cols[q], &cols[q]);
                let apq = dot(&cols[p], &cols[q]);
                if apq.abs() > tol * (app * aqq).sqrt().max(1e-300) {
                    converged = false;
                    // Jacobi rotation that zeroes the (p,q) inner product.
                    let tau = (aqq - app) / (2.0 * apq);
                    let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                    let c = 1.0 / (1.0 + t * t).sqrt();
                    let s = c * t;
                    for i in 0..m {
                        let vp = cols[p][i];
                        let vq = cols[q][i];
                        cols[p][i] = c * vp - s * vq;
                        cols[q][i] = s * vp + c * vq;
                    }
                }
            }
        }
        if converged {
            let mut sv: Vec<f64> = cols.iter().map(|c| norm2(c)).collect();
            sv.sort_by(|x, y| y.partial_cmp(x).unwrap());
            return Ok(sv);
        }
    }
    Err(Error::NoConvergence { iterations: max_sweeps, residual: f64::NAN })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Mt19937;

    #[test]
    fn diagonal_matrix_sv() {
        let a = Matrix::from_vec(3, 2, vec![3.0, 0.0, 0.0, -4.0, 0.0, 0.0]).unwrap();
        let sv = jacobi_singular_values(&a, 1e-14, 50).unwrap();
        assert!((sv[0] - 4.0).abs() < 1e-12);
        assert!((sv[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn sv_squared_match_gram_eigenvalues() {
        let mut rng = Mt19937::new(99);
        let (m, n) = (25, 5);
        let data: Vec<f64> = (0..m * n).map(|_| rng.next_f64() * 4.0 - 2.0).collect();
        let a = Matrix::from_vec(m, n, data).unwrap();
        let sv = jacobi_singular_values(&a, 1e-13, 100).unwrap();
        let eig = crate::linalg::eig::jacobi_eigenvalues(&a.gram(), 1e-12, 200).unwrap();
        for (s, e) in sv.iter().zip(&eig) {
            assert!((s * s - e).abs() < 1e-8 * e.max(1.0), "σ²={} vs λ={}", s * s, e);
        }
    }

    #[test]
    fn frobenius_identity() {
        // Σ σ² == ‖A‖²_F
        let mut rng = Mt19937::new(3);
        let (m, n) = (12, 4);
        let data: Vec<f64> = (0..m * n).map(|_| rng.next_f64() - 0.5).collect();
        let a = Matrix::from_vec(m, n, data).unwrap();
        let sv = jacobi_singular_values(&a, 1e-13, 100).unwrap();
        let sum_sq: f64 = sv.iter().map(|s| s * s).sum();
        assert!((sum_sq - a.frobenius_sq()).abs() < 1e-9);
    }

    #[test]
    fn underdetermined_rejected() {
        let a = Matrix::zeros(2, 3);
        assert!(jacobi_singular_values(&a, 1e-12, 10).is_err());
    }
}
