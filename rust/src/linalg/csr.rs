//! Compressed sparse row (CSR) matrix.
//!
//! The paper restricts itself to dense row-major systems, but Kaczmarz's
//! real-world niche — tomography, signal recovery — is overwhelmingly
//! sparse, and the strongest related work (block sparse Kaczmarz with
//! averaging, arXiv 2203.10838) is exactly our RKAB shape on sparse data.
//! [`CsrMatrix`] is the sparse counterpart of [`Matrix`]: the same
//! row-centric contract (every Kaczmarz variant touches whole rows), stored
//! as the classic values / column-indices / row-pointer triple.
//!
//! Storage follows the dense matrix's `Arc` discipline: all three arrays sit
//! behind `Arc`s, so `clone()` is three refcount bumps and a 16-lane
//! `BatchSolver` over a resident sparse system holds **one** copy of the
//! entries. [`CsrMatrix::row_block`] goes further than the dense equivalent:
//! because `row_ptr` entries are absolute offsets into the shared arrays, a
//! row block is a *view* — it reuses the parent's `values`/`col_indices`
//! `Arc`s outright and only materializes a `(rows + 1)`-long pointer slice.

use super::matrix::Matrix;
use super::vector::dot;
use crate::error::{Error, Result};
use std::sync::Arc;

/// Sparse row-major matrix in compressed sparse row form (cheaply clonable;
/// entry arrays are `Arc`-shared like dense [`Matrix`] storage).
///
/// Row `i`'s stored entries are `values[row_ptr[i]..row_ptr[i + 1]]` with
/// matching column indices in `col_indices` (sorted, no duplicates).
/// `row_ptr` offsets are *absolute* indices into the shared arrays, which is
/// what lets [`CsrMatrix::row_block`] alias the parent's storage instead of
/// copying it.
///
/// ```
/// use kaczmarz::linalg::CsrMatrix;
///
/// // 2x4 system from (row, col, value) triplets; duplicates are summed.
/// let a = CsrMatrix::from_triplets(
///     2,
///     4,
///     &[(0, 1, 2.0), (1, 3, -1.0), (0, 1, 1.0), (1, 0, 4.0)],
/// )
/// .unwrap();
/// assert_eq!(a.nnz(), 3);
/// assert_eq!(a.row_cols(0), &[1]);
/// assert_eq!(a.row_values(0), &[3.0]);
/// assert_eq!(a.row_cols(1), &[0, 3]);
/// assert_eq!(a.density(), 3.0 / 8.0);
/// ```
#[derive(Clone, Debug)]
pub struct CsrMatrix {
    values: Arc<Vec<f64>>,
    col_indices: Arc<Vec<usize>>,
    row_ptr: Arc<Vec<usize>>,
    rows: usize,
    cols: usize,
}

impl CsrMatrix {
    /// Build from `(row, col, value)` triplets in any order.
    ///
    /// Entries are sorted into CSR order and duplicate coordinates are
    /// summed (the Matrix Market convention). Returns a dimension error if
    /// any coordinate is out of range.
    ///
    /// ```
    /// use kaczmarz::linalg::CsrMatrix;
    /// let a = CsrMatrix::from_triplets(3, 3, &[(2, 0, 5.0), (0, 2, 1.0)]).unwrap();
    /// assert_eq!(a.to_dense().row(2), &[5.0, 0.0, 0.0]);
    /// assert!(CsrMatrix::from_triplets(3, 3, &[(3, 0, 1.0)]).is_err());
    /// ```
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &[(usize, usize, f64)],
    ) -> Result<Self> {
        for &(r, c, _) in triplets {
            if r >= rows || c >= cols {
                return Err(Error::Dimension(format!(
                    "triplet entry ({r}, {c}) out of range for a {rows}x{cols} matrix"
                )));
            }
        }
        let mut entries = triplets.to_vec();
        entries.sort_by_key(|e| (e.0, e.1));
        let mut values: Vec<f64> = Vec::with_capacity(entries.len());
        let mut col_indices: Vec<usize> = Vec::with_capacity(entries.len());
        let mut row_ptr: Vec<usize> = Vec::with_capacity(rows + 1);
        row_ptr.push(0);
        let mut cur = 0usize; // the row currently being filled
        for (r, c, v) in entries {
            while cur < r {
                row_ptr.push(values.len());
                cur += 1;
            }
            if values.len() > row_ptr[cur] && col_indices.last() == Some(&c) {
                *values.last_mut().unwrap() += v; // duplicate coordinate: sum
            } else {
                col_indices.push(c);
                values.push(v);
            }
        }
        while cur < rows {
            row_ptr.push(values.len());
            cur += 1;
        }
        Ok(CsrMatrix {
            values: Arc::new(values),
            col_indices: Arc::new(col_indices),
            row_ptr: Arc::new(row_ptr),
            rows,
            cols,
        })
    }

    /// Compress a dense matrix, keeping every entry that is not exactly zero.
    pub fn from_dense(a: &Matrix) -> Self {
        let mut values = Vec::new();
        let mut col_indices = Vec::new();
        let mut row_ptr = Vec::with_capacity(a.rows() + 1);
        row_ptr.push(0);
        for i in 0..a.rows() {
            for (j, &v) in a.row(i).iter().enumerate() {
                if v != 0.0 {
                    col_indices.push(j);
                    values.push(v);
                }
            }
            row_ptr.push(values.len());
        }
        CsrMatrix {
            values: Arc::new(values),
            col_indices: Arc::new(col_indices),
            row_ptr: Arc::new(row_ptr),
            rows: a.rows(),
            cols: a.cols(),
        }
    }

    /// Materialize as a dense [`Matrix`] (tests and oracles only).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let row = out.row_mut(i);
            for (j, v) in self.row_cols(i).iter().zip(self.row_values(i)) {
                row[*j] = *v;
            }
        }
        out
    }

    /// Number of rows (`m` in the paper).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns (`n` in the paper).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored-entry range of row `i` (absolute offsets into the shared
    /// arrays — see the type docs).
    #[inline]
    fn range(&self, i: usize) -> std::ops::Range<usize> {
        debug_assert!(i < self.rows);
        self.row_ptr[i]..self.row_ptr[i + 1]
    }

    /// Column indices of row `i`'s stored entries (sorted ascending).
    #[inline]
    pub fn row_cols(&self, i: usize) -> &[usize] {
        &self.col_indices[self.range(i)]
    }

    /// Values of row `i`'s stored entries (matching [`CsrMatrix::row_cols`]).
    #[inline]
    pub fn row_values(&self, i: usize) -> &[f64] {
        &self.values[self.range(i)]
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.row_ptr[self.rows] - self.row_ptr[0]
    }

    /// Fraction of positions that hold a stored entry, in `[0, 1]`.
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Do `self` and `other` share one set of entry arrays (`Arc::ptr_eq`)?
    ///
    /// True after a `clone()` and between a [`CsrMatrix::row_block`] view
    /// and its parent — same observable copy-on-write contract as
    /// [`Matrix::shares_storage`].
    pub fn shares_storage(&self, other: &CsrMatrix) -> bool {
        Arc::ptr_eq(&self.values, &other.values)
    }

    /// Squared Euclidean norm of every row: `‖A^(i)‖²`.
    ///
    /// Runs the same 8-lane [`dot`] kernel as the dense path over each row's
    /// stored values, so a CSR matrix holding exactly the entries of a dense
    /// one (no explicit zeros dropped) produces *bitwise identical* norms —
    /// and therefore identical eq.-4 sampling sequences.
    pub fn row_norms_sq(&self) -> Vec<f64> {
        (0..self.rows).map(|i| dot(self.row_values(i), self.row_values(i))).collect()
    }

    /// Squared Frobenius norm `‖A‖²_F` over the stored entries.
    pub fn frobenius_sq(&self) -> f64 {
        let all = &self.values[self.row_ptr[0]..self.row_ptr[self.rows]];
        dot(all, all)
    }

    /// Squared Euclidean norm of every column over the stored entries — the
    /// column dual of [`CsrMatrix::row_norms_sq`], precomputed once per
    /// solve by REK's column sampling.
    ///
    /// Accumulates in row order, the same per-column order as the dense
    /// pass, so a CSR matrix holding exactly a dense one's entries yields
    /// bitwise-identical column norms.
    pub fn col_norms_sq(&self) -> Vec<f64> {
        let mut norms = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (j, v) in self.row_cols(i).iter().zip(self.row_values(i)) {
                norms[*j] += v * v;
            }
        }
        norms
    }

    /// Column dot product `<A_(j), y>` (`y` of length `rows`): binary-search
    /// each row's sorted column list for `j`. Columns are the one axis CSR
    /// cannot slice, so REK's column projections pay an
    /// `O(m·log(nnz/row))` scan here instead of a transpose copy.
    pub fn col_dot(&self, j: usize, y: &[f64]) -> f64 {
        debug_assert!(j < self.cols);
        debug_assert_eq!(y.len(), self.rows);
        let mut acc = 0.0;
        for (i, yi) in y.iter().enumerate() {
            if let Ok(k) = self.row_cols(i).binary_search(&j) {
                acc += self.row_values(i)[k] * yi;
            }
        }
        acc
    }

    /// Column update `y += scale * A_(j)` (`y` of length `rows`), touching
    /// only rows that store column `j`.
    pub fn col_axpy(&self, j: usize, scale: f64, y: &mut [f64]) {
        debug_assert!(j < self.cols);
        debug_assert_eq!(y.len(), self.rows);
        for (i, yi) in y.iter_mut().enumerate() {
            if let Ok(k) = self.row_cols(i).binary_search(&j) {
                *yi += scale * self.row_values(i)[k];
            }
        }
    }

    /// Contiguous block of rows `[start, end)` as a zero-copy view: the
    /// entry arrays are `Arc`-shared with the parent
    /// ([`CsrMatrix::shares_storage`] holds); only the small row-pointer
    /// slice is materialized.
    pub fn row_block(&self, start: usize, end: usize) -> Result<CsrMatrix> {
        if start > end || end > self.rows {
            return Err(Error::Dimension(format!(
                "row block [{start}, {end}) out of range for {} rows",
                self.rows
            )));
        }
        Ok(CsrMatrix {
            values: Arc::clone(&self.values),
            col_indices: Arc::clone(&self.col_indices),
            row_ptr: Arc::new(self.row_ptr[start..=end].to_vec()),
            rows: end - start,
            cols: self.cols,
        })
    }

    /// "Crop" the top-left `rows x cols` submatrix (the §3.1 derivation of
    /// smaller systems from the largest one), filtering stored entries.
    pub fn crop(&self, rows: usize, cols: usize) -> Result<CsrMatrix> {
        if rows > self.rows || cols > self.cols {
            return Err(Error::Dimension(format!(
                "cannot crop {}x{} out of {}x{}",
                rows, cols, self.rows, self.cols
            )));
        }
        let mut values = Vec::new();
        let mut col_indices = Vec::new();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        row_ptr.push(0);
        for i in 0..rows {
            for (j, v) in self.row_cols(i).iter().zip(self.row_values(i)) {
                if *j < cols {
                    col_indices.push(*j);
                    values.push(*v);
                }
            }
            row_ptr.push(values.len());
        }
        Ok(CsrMatrix {
            values: Arc::new(values),
            col_indices: Arc::new(col_indices),
            row_ptr: Arc::new(row_ptr),
            rows,
            cols,
        })
    }

    /// Gram matrix `AᵀA` (`n x n`, dense) accumulated from stored-entry
    /// outer products — feeds the `alpha*` spectral bounds exactly like the
    /// dense path.
    pub fn gram(&self) -> Matrix {
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        for r in 0..self.rows {
            let cols = self.row_cols(r);
            let vals = self.row_values(r);
            // Entries are column-sorted, so the inner loop over `k >= idx`
            // touches only the upper triangle; mirror at the end.
            for (idx, (&i, &vi)) in cols.iter().zip(vals).enumerate() {
                let grow = g.row_mut(i);
                for (&j, &vj) in cols[idx..].iter().zip(&vals[idx..]) {
                    grow[j] += vi * vj;
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }
}

/// Structural equality: same shape and same stored entries per row.
///
/// Manual because [`CsrMatrix::row_block`] views keep *absolute* `row_ptr`
/// offsets into the shared arrays — a view and an entry-identical freshly
/// built matrix must compare equal even though their raw pointers differ.
impl PartialEq for CsrMatrix {
    fn eq(&self, other: &CsrMatrix) -> bool {
        if self.rows != other.rows || self.cols != other.cols {
            return false;
        }
        (0..self.rows).all(|i| {
            self.row_cols(i) == other.row_cols(i) && self.row_values(i) == other.row_values(i)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1, 0, 2], [0, 0, 0], [0, 3, 4]] — includes an empty row.
        CsrMatrix::from_triplets(3, 3, &[(0, 0, 1.0), (0, 2, 2.0), (2, 1, 3.0), (2, 2, 4.0)])
            .unwrap()
    }

    #[test]
    fn from_triplets_sorts_and_sums() {
        let a = CsrMatrix::from_triplets(2, 3, &[(1, 2, 5.0), (0, 1, 1.0), (0, 1, 2.0)]).unwrap();
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.row_cols(0), &[1]);
        assert_eq!(a.row_values(0), &[3.0]);
        assert_eq!(a.row_cols(1), &[2]);
    }

    #[test]
    fn from_triplets_rejects_out_of_range() {
        assert!(CsrMatrix::from_triplets(2, 2, &[(2, 0, 1.0)]).is_err());
        assert!(CsrMatrix::from_triplets(2, 2, &[(0, 2, 1.0)]).is_err());
    }

    #[test]
    fn dense_round_trip() {
        let d = Matrix::from_vec(2, 3, vec![1.0, 0.0, 2.0, 0.0, -3.0, 0.0]).unwrap();
        let s = CsrMatrix::from_dense(&d);
        assert_eq!(s.nnz(), 3);
        assert_eq!(s.to_dense(), d);
    }

    #[test]
    fn row_norms_match_dense_bitwise() {
        let a = sample();
        let dense_norms = a.to_dense().row_norms_sq();
        for (s, d) in a.row_norms_sq().iter().zip(&dense_norms) {
            assert_eq!(s.to_bits(), d.to_bits());
        }
        assert_eq!(a.row_norms_sq()[1], 0.0, "empty row has zero norm");
    }

    #[test]
    fn frobenius_over_stored_entries() {
        let a = sample();
        assert_eq!(a.frobenius_sq(), 1.0 + 4.0 + 9.0 + 16.0);
    }

    #[test]
    fn column_ops_match_dense_oracle() {
        // sample() is [[1, 0, 2], [0, 0, 0], [0, 3, 4]]: column 0 is only
        // stored in row 0, column 1 only in row 2 — the binary-search skips
        // must behave exactly like dense zeros.
        let a = sample();
        assert_eq!(a.col_norms_sq(), vec![1.0, 9.0, 4.0 + 16.0]);
        let y = [2.0, -1.0, 0.5];
        assert_eq!(a.col_dot(0, &y), 2.0);
        assert_eq!(a.col_dot(1, &y), 1.5);
        assert_eq!(a.col_dot(2, &y), 4.0 + 2.0);
        let mut z = y;
        a.col_axpy(2, 10.0, &mut z);
        assert_eq!(z, [22.0, -1.0, 40.5]);

        let d = a.to_dense();
        assert_eq!(d.col_norms_sq(), a.col_norms_sq());
        for j in 0..3 {
            assert_eq!(d.col_dot(j, &y).to_bits(), a.col_dot(j, &y).to_bits(), "col {j}");
        }
    }

    #[test]
    fn row_block_is_a_view() {
        let a = sample();
        let b = a.row_block(1, 3).unwrap();
        assert_eq!(b.rows(), 2);
        assert!(b.shares_storage(&a), "row block aliases the parent's entries");
        assert_eq!(b.row_cols(1), &[1, 2]);
        assert_eq!(b.row_values(1), &[3.0, 4.0]);
        assert_eq!(b.nnz(), 2);
        assert!(a.row_block(2, 4).is_err());
    }

    #[test]
    fn view_equals_fresh_copy() {
        let a = sample();
        let view = a.row_block(2, 3).unwrap();
        let fresh = CsrMatrix::from_triplets(1, 3, &[(0, 1, 3.0), (0, 2, 4.0)]).unwrap();
        assert_eq!(view, fresh, "absolute row_ptr offsets must not leak into equality");
    }

    #[test]
    fn crop_filters_entries() {
        let a = sample();
        let c = a.crop(2, 2).unwrap();
        assert_eq!(c.rows(), 2);
        assert_eq!(c.cols(), 2);
        assert_eq!(c.nnz(), 1); // only (0,0) survives
        assert_eq!(c.row_values(0), &[1.0]);
        assert!(a.crop(4, 1).is_err());
    }

    #[test]
    fn gram_matches_dense_oracle() {
        let a = sample();
        let d = a.to_dense();
        let expect = d.transpose().matmul(&d).unwrap();
        let g = a.gram();
        for i in 0..3 {
            for j in 0..3 {
                assert!((g[(i, j)] - expect[(i, j)]).abs() < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn clone_shares_storage() {
        let a = sample();
        let c = a.clone();
        assert!(c.shares_storage(&a));
        assert_eq!(c, a);
    }
}
