//! Row-major dense matrix.
//!
//! Row-major layout is deliberate: every Kaczmarz variant touches whole rows
//! (`<A^(i), x>` then `x += scale * A^(i)`), so a row must be a contiguous
//! slice. This is the same choice the paper's C++ implementation makes.
//!
//! Storage sits behind an [`Arc`] with copy-on-write semantics: `clone()` is
//! a reference-count bump, and the clone only pays for its own buffer if it
//! is *mutated* afterwards. This is what lets the batch-serving layer keep
//! one resident `A` shared across every solver lane — a 16-lane
//! `BatchSolver` over a multi-GiB system holds one matrix, not sixteen —
//! while code that builds and then fills a fresh matrix (the generator, IO,
//! `crop`) mutates its sole reference in place, copy-free. Reads go through
//! one extra pointer indirection, which is noise next to the `O(n)` row
//! kernels behind every access.

use crate::error::{Error, Result};
use std::sync::Arc;

/// Dense row-major matrix of `f64` (cheaply clonable, copy-on-write).
///
/// A `Matrix` may be a *window* into a larger shared buffer
/// ([`Matrix::row_block`] and full-width [`Matrix::crop`] produce these
/// without copying); `offset` locates the window's first element. Windows
/// behave exactly like owned matrices — mutation detaches them onto their
/// own buffer first (copy-on-write, observable via
/// [`Matrix::shares_storage`]).
#[derive(Clone, Debug)]
pub struct Matrix {
    data: Arc<Vec<f64>>,
    offset: usize,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// Zero matrix of shape `rows x cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: Arc::new(vec![0.0; rows * cols]), offset: 0, rows, cols }
    }

    /// Build from a flat row-major buffer.
    ///
    /// Returns an error if `data.len() != rows * cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Dimension(format!(
                "buffer of len {} cannot be a {}x{} matrix",
                data.len(),
                rows,
                cols
            )));
        }
        Ok(Matrix { data: Arc::new(data), offset: 0, rows, cols })
    }

    /// The window of the shared buffer this matrix occupies.
    #[inline]
    fn buf(&self) -> &[f64] {
        &self.data[self.offset..self.offset + self.rows * self.cols]
    }

    /// Copy-on-write access to the storage: clones the buffer first if (and
    /// only if) it is shared with another `Matrix`, and detaches window
    /// views onto their own exactly-sized buffer. Single mutation gateway —
    /// every `&mut` accessor funnels through here.
    #[inline]
    fn data_mut(&mut self) -> &mut [f64] {
        if self.offset != 0 || self.data.len() != self.rows * self.cols {
            // A window into a larger shared buffer: mutating through
            // `Arc::make_mut` would either copy the whole parent buffer or
            // (worse, as sole owner) write into rows outside the window.
            // Detach onto an owned, exactly-sized buffer instead.
            self.data = Arc::new(self.buf().to_vec());
            self.offset = 0;
        }
        Arc::make_mut(&mut self.data).as_mut_slice()
    }

    /// Do `self` and `other` share one storage buffer (`Arc::ptr_eq`)?
    ///
    /// True after a `clone()` until either side is mutated. The batch
    /// integration tests use this to assert that serving lanes really hold
    /// *one* resident matrix.
    pub fn shares_storage(&self, other: &Matrix) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Identity matrix of order `n`.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Number of rows (`m` in the paper).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns (`n` in the paper).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Contiguous view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[self.offset + i * self.cols..self.offset + (i + 1) * self.cols]
    }

    /// Mutable view of row `i` (copy-on-write if the storage is shared).
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        let cols = self.cols;
        &mut self.data_mut()[i * cols..(i + 1) * cols]
    }

    /// Iterator over rows as slices.
    pub fn rows_iter(&self) -> impl Iterator<Item = &[f64]> {
        self.buf().chunks_exact(self.cols)
    }

    /// Flat row-major buffer (the window this matrix occupies).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        self.buf()
    }

    /// Flat mutable row-major buffer (copy-on-write if shared).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        self.data_mut()
    }

    /// Squared Euclidean norm of every row: `‖A^(i)‖²`.
    ///
    /// Precomputed once per system; the Kaczmarz scale factor divides by it
    /// on every iteration.
    pub fn row_norms_sq(&self) -> Vec<f64> {
        self.rows_iter().map(super::vector::norm2_sq).collect()
    }

    /// Squared Frobenius norm `‖A‖²_F = Σ ‖A^(i)‖²`.
    pub fn frobenius_sq(&self) -> f64 {
        super::vector::norm2_sq(self.buf())
    }

    /// Squared Euclidean norm of every column: `‖A_(j)‖²` — the column dual
    /// of [`Matrix::row_norms_sq`], precomputed once per solve by REK's
    /// column sampling.
    ///
    /// One row-major pass: column `j`'s norm accumulates `a_ij²` in row
    /// order, which is the same per-column accumulation order the CSR
    /// backend uses over stored entries — a CSR twin holding exactly this
    /// matrix's entries produces bitwise-identical column norms.
    pub fn col_norms_sq(&self) -> Vec<f64> {
        let mut norms = vec![0.0; self.cols];
        for row in self.rows_iter() {
            for (acc, v) in norms.iter_mut().zip(row) {
                *acc += v * v;
            }
        }
        norms
    }

    /// Column dot product `<A_(j), y>` (`y` of length `rows`), accumulated
    /// in row order — REK's column-projection residual.
    pub fn col_dot(&self, j: usize, y: &[f64]) -> f64 {
        debug_assert!(j < self.cols);
        debug_assert_eq!(y.len(), self.rows);
        let mut acc = 0.0;
        for (yi, row) in y.iter().zip(self.rows_iter()) {
            acc += row[j] * yi;
        }
        acc
    }

    /// Column update `y += scale * A_(j)` (`y` of length `rows`).
    pub fn col_axpy(&self, j: usize, scale: f64, y: &mut [f64]) {
        debug_assert!(j < self.cols);
        debug_assert_eq!(y.len(), self.rows);
        for (yi, row) in y.iter_mut().zip(self.rows_iter()) {
            *yi += scale * row[j];
        }
    }

    /// "Crop" the top-left `rows x cols` submatrix.
    ///
    /// The paper generates its largest matrix once and derives all smaller
    /// systems by cropping so matrices of different sizes stay comparable
    /// (§3.1); this implements that derivation.
    pub fn crop(&self, rows: usize, cols: usize) -> Result<Matrix> {
        if rows > self.rows || cols > self.cols {
            return Err(Error::Dimension(format!(
                "cannot crop {}x{} out of {}x{}",
                rows, cols, self.rows, self.cols
            )));
        }
        if cols == self.cols {
            // Full-width crop keeps the row-major layout intact: alias the
            // shared buffer instead of copying ([`Matrix::shares_storage`]
            // holds until the crop is mutated).
            return Ok(Matrix {
                data: Arc::clone(&self.data),
                offset: self.offset,
                rows,
                cols,
            });
        }
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..cols]);
        }
        Ok(out)
    }

    /// Contiguous block of rows `[start, end)` — a zero-copy window into the
    /// shared buffer ([`Matrix::shares_storage`] holds; mutation detaches
    /// the block copy-on-write, leaving the parent untouched).
    pub fn row_block(&self, start: usize, end: usize) -> Result<Matrix> {
        if start > end || end > self.rows {
            return Err(Error::Dimension(format!(
                "row block [{start}, {end}) out of range for {} rows",
                self.rows
            )));
        }
        Ok(Matrix {
            data: Arc::clone(&self.data),
            offset: self.offset + start * self.cols,
            rows: end - start,
            cols: self.cols,
        })
    }

    /// Gram matrix `AᵀA` (`n x n`).
    ///
    /// Used by the `alpha*` computation (σ² of A are eigenvalues of AᵀA) and
    /// by CGLS tests. Accumulates rank-1 row outer products, which walks `A`
    /// exactly once in row-major order.
    pub fn gram(&self) -> Matrix {
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        for row in self.rows_iter() {
            // Only the upper triangle; mirror at the end.
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                let grow = g.row_mut(i);
                for j in i..n {
                    grow[j] += ri * row[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// Transpose (used by test oracles; the solvers never materialize Aᵀ).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Dense matmul (test oracle only — O(mnk), not a hot path).
    pub fn matmul(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.rows {
            return Err(Error::Dimension(format!(
                "{}x{} * {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let orow = out.row_mut(i);
                for j in 0..brow.len() {
                    orow[j] += aik * brow[j];
                }
            }
        }
        Ok(out)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[self.offset + i * self.cols + j]
    }
}

/// Structural equality on shape and elements.
///
/// Manual because a window ([`Matrix::row_block`]) and an element-identical
/// owned matrix must compare equal even though their offsets and buffer
/// lengths differ.
impl PartialEq for Matrix {
    fn eq(&self, other: &Matrix) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.as_slice() == other.as_slice()
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        let idx = i * self.cols + j;
        &mut self.data_mut()[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Matrix {
        Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap()
    }

    #[test]
    fn shape_and_index() {
        let m = sample();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 2)], 6.0);
    }

    #[test]
    fn from_vec_rejects_bad_len() {
        assert!(Matrix::from_vec(2, 2, vec![1.0; 5]).is_err());
    }

    #[test]
    fn row_views() {
        let m = sample();
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.rows_iter().count(), 2);
    }

    #[test]
    fn row_mut_writes_through() {
        let mut m = sample();
        m.row_mut(1)[0] = -4.0;
        assert_eq!(m[(1, 0)], -4.0);
    }

    #[test]
    fn column_ops() {
        // sample() is [[1, 2, 3], [4, 5, 6]].
        let m = sample();
        assert_eq!(m.col_norms_sq(), vec![17.0, 29.0, 45.0]);
        let y = [10.0, 0.5];
        assert_eq!(m.col_dot(0, &y), 12.0);
        assert_eq!(m.col_dot(2, &y), 33.0);
        let mut z = y;
        m.col_axpy(1, 2.0, &mut z);
        assert_eq!(z, [14.0, 10.5]);
        // Column ops must honor row-block windows, not the backing buffer.
        let block = m.row_block(1, 2).unwrap();
        assert_eq!(block.col_norms_sq(), vec![16.0, 25.0, 36.0]);
        assert_eq!(block.col_dot(0, &[3.0]), 12.0);
    }

    #[test]
    fn row_norms_and_frobenius() {
        let m = sample();
        let norms = m.row_norms_sq();
        assert_eq!(norms, vec![14.0, 77.0]);
        assert_eq!(m.frobenius_sq(), 91.0);
    }

    #[test]
    fn crop_top_left() {
        let m = sample();
        let c = m.crop(1, 2).unwrap();
        assert_eq!(c.rows(), 1);
        assert_eq!(c.cols(), 2);
        assert_eq!(c.row(0), &[1.0, 2.0]);
        assert!(m.crop(3, 1).is_err());
    }

    #[test]
    fn row_block_extracts() {
        let m = sample();
        let b = m.row_block(1, 2).unwrap();
        assert_eq!(b.rows(), 1);
        assert_eq!(b.row(0), &[4.0, 5.0, 6.0]);
        assert!(m.row_block(1, 3).is_err());
    }

    #[test]
    fn gram_matches_transpose_matmul() {
        let m = sample();
        let g = m.gram();
        let expect = m.transpose().matmul(&m).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                assert!((g[(i, j)] - expect[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn identity_matmul_is_noop() {
        let m = sample();
        let id = Matrix::identity(3);
        let p = m.matmul(&id).unwrap();
        assert_eq!(p, m);
    }

    #[test]
    fn transpose_involution() {
        let m = sample();
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn clone_shares_storage_until_mutation() {
        let m = sample();
        let mut c = m.clone();
        assert!(c.shares_storage(&m), "clone is a refcount bump");
        assert_eq!(c, m);
        c.row_mut(0)[0] = 99.0; // copy-on-write detaches the clone
        assert!(!c.shares_storage(&m));
        assert_eq!(m[(0, 0)], 1.0, "original must be untouched");
        assert_eq!(c[(0, 0)], 99.0);
        assert_ne!(c, m);
    }

    #[test]
    fn sole_owner_mutates_in_place() {
        let mut m = sample();
        let p = m.as_slice().as_ptr();
        m.row_mut(1)[0] = -4.0;
        m[(0, 1)] = 7.0;
        m.as_mut_slice()[2] = 0.5;
        assert_eq!(m.as_slice().as_ptr(), p, "unshared storage never reallocates");
    }

    #[test]
    fn distinct_constructions_do_not_share() {
        assert!(!sample().shares_storage(&sample()));
    }

    #[test]
    fn row_block_is_a_zero_copy_window() {
        let m = sample();
        let b = m.row_block(1, 2).unwrap();
        assert!(b.shares_storage(&m), "row block aliases the parent buffer");
        assert_eq!(b.as_slice(), &[4.0, 5.0, 6.0]);
        assert_eq!(b[(0, 2)], 6.0);
        assert_eq!(b.frobenius_sq(), 77.0);
        assert_eq!(b, Matrix::from_vec(1, 3, vec![4.0, 5.0, 6.0]).unwrap());
    }

    #[test]
    fn window_mutation_detaches_and_spares_parent() {
        let m = sample();
        let mut b = m.row_block(0, 1).unwrap();
        b.row_mut(0)[1] = 99.0;
        assert!(!b.shares_storage(&m), "mutation detaches the window");
        assert_eq!(b.as_slice(), &[1.0, 99.0, 3.0]);
        assert_eq!(m[(0, 1)], 2.0, "parent must be untouched");
        assert_eq!(b.as_slice().len(), 3, "detached window owns an exactly-sized buffer");
    }

    #[test]
    fn nested_windows_stay_consistent() {
        let m = Matrix::from_vec(4, 2, (0..8).map(|i| i as f64).collect()).unwrap();
        let b = m.row_block(1, 4).unwrap();
        let bb = b.row_block(1, 3).unwrap();
        assert!(bb.shares_storage(&m));
        assert_eq!(bb.row(0), &[4.0, 5.0]);
        assert_eq!(bb.row(1), &[6.0, 7.0]);
        assert_eq!(bb.row_norms_sq(), vec![16.0 + 25.0, 36.0 + 49.0]);
    }

    #[test]
    fn full_width_crop_shares_storage() {
        let m = sample();
        let c = m.crop(1, 3).unwrap();
        assert!(c.shares_storage(&m), "full-width crop is a window");
        assert_eq!(c.row(0), &[1.0, 2.0, 3.0]);
        let narrower = m.crop(2, 2).unwrap();
        assert!(!narrower.shares_storage(&m), "narrowing crop must re-pack rows");
        assert_eq!(narrower.row(1), &[4.0, 5.0]);
    }
}
