//! Row-major dense matrix.
//!
//! Row-major layout is deliberate: every Kaczmarz variant touches whole rows
//! (`<A^(i), x>` then `x += scale * A^(i)`), so a row must be a contiguous
//! slice. This is the same choice the paper's C++ implementation makes.
//!
//! Storage sits behind an [`Arc`] with copy-on-write semantics: `clone()` is
//! a reference-count bump, and the clone only pays for its own buffer if it
//! is *mutated* afterwards. This is what lets the batch-serving layer keep
//! one resident `A` shared across every solver lane — a 16-lane
//! `BatchSolver` over a multi-GiB system holds one matrix, not sixteen —
//! while code that builds and then fills a fresh matrix (the generator, IO,
//! `crop`) mutates its sole reference in place, copy-free. Reads go through
//! one extra pointer indirection, which is noise next to the `O(n)` row
//! kernels behind every access.

use crate::error::{Error, Result};
use std::sync::Arc;

/// Dense row-major matrix of `f64` (cheaply clonable, copy-on-write).
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Arc<Vec<f64>>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// Zero matrix of shape `rows x cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: Arc::new(vec![0.0; rows * cols]), rows, cols }
    }

    /// Build from a flat row-major buffer.
    ///
    /// Returns an error if `data.len() != rows * cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Dimension(format!(
                "buffer of len {} cannot be a {}x{} matrix",
                data.len(),
                rows,
                cols
            )));
        }
        Ok(Matrix { data: Arc::new(data), rows, cols })
    }

    /// Copy-on-write access to the storage: clones the buffer first if (and
    /// only if) it is shared with another `Matrix`. Single mutation
    /// gateway — every `&mut` accessor funnels through here.
    #[inline]
    fn data_mut(&mut self) -> &mut Vec<f64> {
        Arc::make_mut(&mut self.data)
    }

    /// Do `self` and `other` share one storage buffer (`Arc::ptr_eq`)?
    ///
    /// True after a `clone()` until either side is mutated. The batch
    /// integration tests use this to assert that serving lanes really hold
    /// *one* resident matrix.
    pub fn shares_storage(&self, other: &Matrix) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Identity matrix of order `n`.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Number of rows (`m` in the paper).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns (`n` in the paper).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Contiguous view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i` (copy-on-write if the storage is shared).
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        let cols = self.cols;
        &mut self.data_mut()[i * cols..(i + 1) * cols]
    }

    /// Iterator over rows as slices.
    pub fn rows_iter(&self) -> impl Iterator<Item = &[f64]> {
        self.data.chunks_exact(self.cols)
    }

    /// Flat row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Flat mutable row-major buffer (copy-on-write if shared).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        self.data_mut()
    }

    /// Squared Euclidean norm of every row: `‖A^(i)‖²`.
    ///
    /// Precomputed once per system; the Kaczmarz scale factor divides by it
    /// on every iteration.
    pub fn row_norms_sq(&self) -> Vec<f64> {
        self.rows_iter().map(super::vector::norm2_sq).collect()
    }

    /// Squared Frobenius norm `‖A‖²_F = Σ ‖A^(i)‖²`.
    pub fn frobenius_sq(&self) -> f64 {
        super::vector::norm2_sq(&self.data)
    }

    /// "Crop" the top-left `rows x cols` submatrix.
    ///
    /// The paper generates its largest matrix once and derives all smaller
    /// systems by cropping so matrices of different sizes stay comparable
    /// (§3.1); this implements that derivation.
    pub fn crop(&self, rows: usize, cols: usize) -> Result<Matrix> {
        if rows > self.rows || cols > self.cols {
            return Err(Error::Dimension(format!(
                "cannot crop {}x{} out of {}x{}",
                rows, cols, self.rows, self.cols
            )));
        }
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..cols]);
        }
        Ok(out)
    }

    /// Contiguous block of rows `[start, end)` as a new matrix.
    pub fn row_block(&self, start: usize, end: usize) -> Result<Matrix> {
        if start > end || end > self.rows {
            return Err(Error::Dimension(format!(
                "row block [{start}, {end}) out of range for {} rows",
                self.rows
            )));
        }
        Ok(Matrix {
            data: Arc::new(self.data[start * self.cols..end * self.cols].to_vec()),
            rows: end - start,
            cols: self.cols,
        })
    }

    /// Gram matrix `AᵀA` (`n x n`).
    ///
    /// Used by the `alpha*` computation (σ² of A are eigenvalues of AᵀA) and
    /// by CGLS tests. Accumulates rank-1 row outer products, which walks `A`
    /// exactly once in row-major order.
    pub fn gram(&self) -> Matrix {
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        for row in self.rows_iter() {
            // Only the upper triangle; mirror at the end.
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                let grow = g.row_mut(i);
                for j in i..n {
                    grow[j] += ri * row[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// Transpose (used by test oracles; the solvers never materialize Aᵀ).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Dense matmul (test oracle only — O(mnk), not a hot path).
    pub fn matmul(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.rows {
            return Err(Error::Dimension(format!(
                "{}x{} * {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let orow = out.row_mut(i);
                for j in 0..brow.len() {
                    orow[j] += aik * brow[j];
                }
            }
        }
        Ok(out)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        let idx = i * self.cols + j;
        &mut self.data_mut()[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Matrix {
        Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap()
    }

    #[test]
    fn shape_and_index() {
        let m = sample();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 2)], 6.0);
    }

    #[test]
    fn from_vec_rejects_bad_len() {
        assert!(Matrix::from_vec(2, 2, vec![1.0; 5]).is_err());
    }

    #[test]
    fn row_views() {
        let m = sample();
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.rows_iter().count(), 2);
    }

    #[test]
    fn row_mut_writes_through() {
        let mut m = sample();
        m.row_mut(1)[0] = -4.0;
        assert_eq!(m[(1, 0)], -4.0);
    }

    #[test]
    fn row_norms_and_frobenius() {
        let m = sample();
        let norms = m.row_norms_sq();
        assert_eq!(norms, vec![14.0, 77.0]);
        assert_eq!(m.frobenius_sq(), 91.0);
    }

    #[test]
    fn crop_top_left() {
        let m = sample();
        let c = m.crop(1, 2).unwrap();
        assert_eq!(c.rows(), 1);
        assert_eq!(c.cols(), 2);
        assert_eq!(c.row(0), &[1.0, 2.0]);
        assert!(m.crop(3, 1).is_err());
    }

    #[test]
    fn row_block_extracts() {
        let m = sample();
        let b = m.row_block(1, 2).unwrap();
        assert_eq!(b.rows(), 1);
        assert_eq!(b.row(0), &[4.0, 5.0, 6.0]);
        assert!(m.row_block(1, 3).is_err());
    }

    #[test]
    fn gram_matches_transpose_matmul() {
        let m = sample();
        let g = m.gram();
        let expect = m.transpose().matmul(&m).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                assert!((g[(i, j)] - expect[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn identity_matmul_is_noop() {
        let m = sample();
        let id = Matrix::identity(3);
        let p = m.matmul(&id).unwrap();
        assert_eq!(p, m);
    }

    #[test]
    fn transpose_involution() {
        let m = sample();
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn clone_shares_storage_until_mutation() {
        let m = sample();
        let mut c = m.clone();
        assert!(c.shares_storage(&m), "clone is a refcount bump");
        assert_eq!(c, m);
        c.row_mut(0)[0] = 99.0; // copy-on-write detaches the clone
        assert!(!c.shares_storage(&m));
        assert_eq!(m[(0, 0)], 1.0, "original must be untouched");
        assert_eq!(c[(0, 0)], 99.0);
        assert_ne!(c, m);
    }

    #[test]
    fn sole_owner_mutates_in_place() {
        let mut m = sample();
        let p = m.as_slice().as_ptr();
        m.row_mut(1)[0] = -4.0;
        m[(0, 1)] = 7.0;
        m.as_mut_slice()[2] = 0.5;
        assert_eq!(m.as_slice().as_ptr(), p, "unshared storage never reallocates");
    }

    #[test]
    fn distinct_constructions_do_not_share() {
        assert!(!sample().shares_storage(&sample()));
    }
}
