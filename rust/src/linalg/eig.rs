//! Extreme eigenvalues of symmetric matrices.
//!
//! The optimal RKA relaxation parameter (paper eq. 6) needs
//! `s_min = σ²_min(A)/‖A‖²_F` and `s_max = σ²_max(A)/‖A‖²_F`, i.e. the
//! extreme eigenvalues of `G = AᵀA`. The paper notes this computation is
//! "considerably high" cost — Table 2 charges ~2500 s for it — and we
//! reproduce both the value (power/inverse-power iteration) and the cost
//! accounting (see `solvers::alpha`).

use super::cholesky::Cholesky;
use super::gemv::gemv_into;
use super::matrix::Matrix;
use super::vector::{dot, norm2, scale_in_place};
use crate::error::{Error, Result};
use crate::rng::Mt19937;

/// Result of an eigenvalue iteration.
#[derive(Debug, Clone, Copy)]
pub struct EigResult {
    /// Converged eigenvalue estimate.
    pub value: f64,
    /// Iterations used.
    pub iterations: usize,
}

fn random_unit_vector(n: usize, seed: u32) -> Vec<f64> {
    let mut rng = Mt19937::new(seed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
    let nrm = norm2(&v);
    scale_in_place(&mut v, 1.0 / nrm);
    v
}

/// Largest eigenvalue of a symmetric matrix by power iteration.
///
/// Converges when two successive Rayleigh quotients agree to `tol`
/// (relative). For `G = AᵀA` this yields `σ²_max(A)`.
pub fn power_iteration(g: &Matrix, tol: f64, max_iter: usize) -> Result<EigResult> {
    if g.rows() != g.cols() {
        return Err(Error::InvalidArgument("power iteration needs square matrix".into()));
    }
    let n = g.rows();
    let mut v = random_unit_vector(n, 0x9e3779b9);
    let mut w = vec![0.0; n];
    let mut lambda = 0.0f64;
    for it in 1..=max_iter {
        gemv_into(g, &v, &mut w);
        let new_lambda = dot(&v, &w); // Rayleigh quotient (v normalized)
        let nrm = norm2(&w);
        if nrm == 0.0 {
            return Ok(EigResult { value: 0.0, iterations: it });
        }
        for k in 0..n {
            v[k] = w[k] / nrm;
        }
        if (new_lambda - lambda).abs() <= tol * new_lambda.abs().max(1e-300) {
            return Ok(EigResult { value: new_lambda, iterations: it });
        }
        lambda = new_lambda;
    }
    Err(Error::NoConvergence { iterations: max_iter, residual: lambda })
}

/// Smallest eigenvalue of an SPD matrix by inverse power iteration.
///
/// Factorizes once with Cholesky, then iterates `G z = v`. For `G = AᵀA` of
/// a full-rank `A` this yields `σ²_min(A)`.
pub fn inverse_power_iteration(g: &Matrix, tol: f64, max_iter: usize) -> Result<EigResult> {
    let chol = Cholesky::new(g)?;
    let n = g.rows();
    let mut v = random_unit_vector(n, 0x85ebca6b);
    let mut mu = 0.0f64; // eigenvalue of G⁻¹
    for it in 1..=max_iter {
        let z = chol.solve(&v)?;
        let new_mu = dot(&v, &z);
        let nrm = norm2(&z);
        for k in 0..n {
            v[k] = z[k] / nrm;
        }
        if (new_mu - mu).abs() <= tol * new_mu.abs().max(1e-300) {
            return Ok(EigResult { value: 1.0 / new_mu, iterations: it });
        }
        mu = new_mu;
    }
    Err(Error::NoConvergence { iterations: max_iter, residual: 1.0 / mu.max(1e-300) })
}

/// All eigenvalues of a symmetric matrix by the cyclic Jacobi method.
///
/// O(n³) per sweep — used as the *test oracle* for the iterative routines
/// and for small systems in examples; never on a hot path.
pub fn jacobi_eigenvalues(g: &Matrix, tol: f64, max_sweeps: usize) -> Result<Vec<f64>> {
    if g.rows() != g.cols() {
        return Err(Error::InvalidArgument("jacobi needs square matrix".into()));
    }
    let n = g.rows();
    let mut a = g.clone();
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += a[(i, j)] * a[(i, j)];
            }
        }
        if off.sqrt() <= tol {
            let mut eig: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
            eig.sort_by(|x, y| y.partial_cmp(x).unwrap());
            return Ok(eig);
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = a[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let theta = (a[(q, q)] - a[(p, p)]) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q.
                for k in 0..n {
                    let akp = a[(k, p)];
                    let akq = a[(k, q)];
                    a[(k, p)] = c * akp - s * akq;
                    a[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[(p, k)];
                    let aqk = a[(q, k)];
                    a[(p, k)] = c * apk - s * aqk;
                    a[(q, k)] = s * apk + c * aqk;
                }
            }
        }
    }
    Err(Error::NoConvergence { iterations: max_sweeps, residual: f64::NAN })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sym() -> Matrix {
        // Eigenvalues of [[2,1],[1,2]] are 3 and 1.
        Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]).unwrap()
    }

    #[test]
    fn power_finds_largest() {
        let r = power_iteration(&sym(), 1e-12, 1000).unwrap();
        assert!((r.value - 3.0).abs() < 1e-8, "got {}", r.value);
    }

    #[test]
    fn inverse_power_finds_smallest() {
        let r = inverse_power_iteration(&sym(), 1e-12, 1000).unwrap();
        assert!((r.value - 1.0).abs() < 1e-8, "got {}", r.value);
    }

    #[test]
    fn jacobi_finds_all() {
        let eig = jacobi_eigenvalues(&sym(), 1e-12, 100).unwrap();
        assert!((eig[0] - 3.0).abs() < 1e-10);
        assert!((eig[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn iterative_matches_jacobi_on_random_gram() {
        use crate::rng::Mt19937;
        let mut rng = Mt19937::new(7);
        let m = 30;
        let n = 6;
        let data: Vec<f64> = (0..m * n).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
        let a = Matrix::from_vec(m, n, data).unwrap();
        let g = a.gram();
        let eig = jacobi_eigenvalues(&g, 1e-12, 200).unwrap();
        let hi = power_iteration(&g, 1e-13, 5000).unwrap().value;
        let lo = inverse_power_iteration(&g, 1e-13, 5000).unwrap().value;
        assert!((hi - eig[0]).abs() / eig[0] < 1e-6, "hi {hi} vs {}", eig[0]);
        assert!((lo - eig[n - 1]).abs() / eig[n - 1] < 1e-6, "lo {lo} vs {}", eig[n - 1]);
    }

    #[test]
    fn non_square_rejected() {
        let m = Matrix::zeros(2, 3);
        assert!(power_iteration(&m, 1e-8, 10).is_err());
        assert!(jacobi_eigenvalues(&m, 1e-8, 10).is_err());
    }
}
