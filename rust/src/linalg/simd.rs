//! Explicit SIMD kernels with one-time runtime dispatch.
//!
//! The scalar 8-lane kernels in [`super::vector`] are the *bitwise
//! reference path*: their chunked accumulator layout is part of the
//! crate's reproducibility contract (the RKAB fused sweep, dense storage
//! dispatch, and batch serving are all gated bitwise against it in CI).
//! This module adds AVX2+FMA implementations of the same three hot loops
//! — `dot`, `axpy`, and the fused `axpy_dot` — via `std::arch`, selected
//! once per process by [`active_flavor`].
//!
//! Dispatch rules:
//!
//! - The host is probed once (`is_x86_feature_detected!`, cached in a
//!   [`OnceLock`]). AVX2+FMA hosts run the SIMD kernels; everything else
//!   (including non-x86_64 builds) runs the scalar reference.
//! - `KACZMARZ_KERNEL=scalar` in the environment forces the scalar path
//!   regardless of host capability — this is how CI re-runs the bitwise
//!   gates on the reference kernels.
//! - [`force_flavor`] is the programmatic equivalent; requests are
//!   clamped to host capability, so forcing `Avx2Fma` on a host without
//!   the features can never dispatch an unsupported instruction.
//!
//! Numerics: FMA contracts `a*b + c` into one rounding, so the SIMD
//! results legally differ from the scalar reference in the last ulps —
//! equivalence is asserted to a *relative tolerance* (see
//! `bench_micro_hotpath` and `tests/simd_kernels.rs`), never `to_bits`
//! across flavors. Within the SIMD flavor the fused `axpy_dot` keeps the
//! exact accumulator structure of the SIMD `dot` (two 4-lane registers,
//! eight doubles per trip, identical tail and reduction order), so
//! fused-vs-separate stays bitwise *within* a flavor, and every existing
//! in-process bitwise gate passes under either dispatch.

use std::sync::OnceLock;

/// Which kernel implementation the process dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelFlavor {
    /// Portable 8-lane scalar kernels — the bitwise reference path.
    Scalar,
    /// AVX2 + FMA `std::arch` kernels (x86_64 only).
    Avx2Fma,
}

impl KernelFlavor {
    /// Stable lowercase name, as reported in `BENCH_micro.json` and by
    /// `kaczmarz info` (`"scalar"` / `"avx2+fma"`).
    pub fn name(self) -> &'static str {
        match self {
            KernelFlavor::Scalar => "scalar",
            KernelFlavor::Avx2Fma => "avx2+fma",
        }
    }
}

static FLAVOR: OnceLock<KernelFlavor> = OnceLock::new();

/// The best flavor this host can run, ignoring any override.
pub fn detected_flavor() -> KernelFlavor {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return KernelFlavor::Avx2Fma;
        }
    }
    KernelFlavor::Scalar
}

/// The flavor the hot-path kernels dispatch to, resolved once per
/// process: `KACZMARZ_KERNEL=scalar` forces the reference path, any
/// other value (or no value) selects [`detected_flavor`]. The first
/// call — or a prior [`force_flavor`] — pins the answer for the
/// lifetime of the process.
pub fn active_flavor() -> KernelFlavor {
    *FLAVOR.get_or_init(|| match std::env::var("KACZMARZ_KERNEL") {
        Ok(v) if v.eq_ignore_ascii_case("scalar") => KernelFlavor::Scalar,
        _ => detected_flavor(),
    })
}

/// Programmatically pin the kernel flavor before first use.
///
/// Requests are clamped to host capability ([`Avx2Fma`] on a host
/// without AVX2+FMA degrades to [`Scalar`]; forcing an unsupported
/// instruction set is never possible). Returns `true` when the active
/// flavor now equals the clamped request — `false` means dispatch was
/// already resolved to something else and cannot change.
///
/// [`Avx2Fma`]: KernelFlavor::Avx2Fma
/// [`Scalar`]: KernelFlavor::Scalar
pub fn force_flavor(requested: KernelFlavor) -> bool {
    let clamped = match requested {
        KernelFlavor::Scalar => KernelFlavor::Scalar,
        KernelFlavor::Avx2Fma => detected_flavor(),
    };
    let _ = FLAVOR.set(clamped);
    active_flavor() == clamped
}

/// `true` when the dispatched kernels are the AVX2+FMA flavor. The hot
/// paths in [`super::vector`] branch on this once per call (an atomic
/// load), keeping the inner loops themselves branch-free.
#[inline]
pub(crate) fn use_avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        active_flavor() == KernelFlavor::Avx2Fma
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

// ---------------------------------------------------------------------------
// Explicit per-flavor entry points (Option-returning, always safe).
//
// These run the AVX2 kernels whenever the *host* supports them,
// independent of the process-wide dispatch — benches and the
// property-test suite use them to time and compare both flavors inside
// one process. `None` means the host cannot run AVX2+FMA.
// ---------------------------------------------------------------------------

/// AVX2+FMA `dot`, or `None` when the host lacks the features.
pub fn dot_avx2(a: &[f64], b: &[f64]) -> Option<f64> {
    #[cfg(target_arch = "x86_64")]
    {
        if detected_flavor() == KernelFlavor::Avx2Fma {
            // SAFETY: the feature probe above confirmed AVX2 and FMA.
            return Some(unsafe { avx::dot(a, b) });
        }
    }
    let _ = (a, b);
    None
}

/// AVX2+FMA `axpy` (`y += alpha * x`); returns `false` (leaving `y`
/// untouched) when the host lacks the features.
pub fn axpy_avx2(alpha: f64, x: &[f64], y: &mut [f64]) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if detected_flavor() == KernelFlavor::Avx2Fma {
            // SAFETY: the feature probe above confirmed AVX2 and FMA.
            unsafe { avx::axpy(alpha, x, y) };
            return true;
        }
    }
    let _ = (alpha, x, y);
    false
}

/// AVX2+FMA fused `axpy_dot`, or `None` (leaving `y` untouched) when
/// the host lacks the features.
pub fn axpy_dot_avx2(alpha: f64, x: &[f64], z: &[f64], y: &mut [f64]) -> Option<f64> {
    #[cfg(target_arch = "x86_64")]
    {
        if detected_flavor() == KernelFlavor::Avx2Fma {
            // SAFETY: the feature probe above confirmed AVX2 and FMA.
            return Some(unsafe { avx::axpy_dot(alpha, x, z, y) });
        }
    }
    let _ = (alpha, x, z, y);
    None
}

// ---------------------------------------------------------------------------
// The AVX2+FMA kernels themselves.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx {
    //! Raw `#[target_feature]` kernels. Callers must have verified
    //! AVX2+FMA support (see the safe wrappers in the parent module).

    use std::arch::x86_64::{
        __m256d, _mm256_add_pd, _mm256_castpd256_pd128, _mm256_extractf128_pd, _mm256_fmadd_pd,
        _mm256_loadu_pd, _mm256_set1_pd, _mm256_setzero_pd, _mm256_storeu_pd, _mm_add_pd,
        _mm_add_sd, _mm_cvtsd_f64, _mm_unpackhi_pd,
    };

    /// Horizontal sum of a 4-lane register, in the fixed order
    /// `(l0 + l2) + (l1 + l3)` — the same reduction every kernel here
    /// shares so fused and separate dots stay bitwise-equal.
    ///
    /// # Safety
    /// Requires AVX2 (the cast/extract/unpack intrinsics); callers are
    /// inside `#[target_feature(enable = "avx2")]` contexts.
    #[inline]
    unsafe fn hsum4(v: __m256d) -> f64 {
        // SAFETY: pure register-to-register intrinsics; the caller contract
        // (AVX2 enabled) is exactly what they require.
        unsafe {
            let lo = _mm256_castpd256_pd128(v); // lanes 0, 1
            let hi = _mm256_extractf128_pd::<1>(v); // lanes 2, 3
            let sum2 = _mm_add_pd(lo, hi); // [l0+l2, l1+l3]
            let shuf = _mm_unpackhi_pd(sum2, sum2); // [l1+l3, l1+l3]
            _mm_cvtsd_f64(_mm_add_sd(sum2, shuf)) // (l0+l2) + (l1+l3)
        }
    }

    /// AVX2+FMA dot product: two 4-lane FMA accumulators (eight doubles
    /// per trip), scalar tail, reduction `hsum4(acc0 + acc1) + tail`.
    ///
    /// # Safety
    /// The host must support AVX2 and FMA (`is_x86_feature_detected!`).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len().min(b.len());
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // SAFETY: every offset below is < n = min(a.len(), b.len()), so all
        // loads stay inside the borrowed slices; the intrinsics themselves
        // need AVX2+FMA, which is the caller contract of this fn.
        unsafe {
            let mut acc0 = _mm256_setzero_pd();
            let mut acc1 = _mm256_setzero_pd();
            let mut i = 0usize;
            while i + 8 <= n {
                acc0 = _mm256_fmadd_pd(
                    _mm256_loadu_pd(pa.add(i)),
                    _mm256_loadu_pd(pb.add(i)),
                    acc0,
                );
                acc1 = _mm256_fmadd_pd(
                    _mm256_loadu_pd(pa.add(i + 4)),
                    _mm256_loadu_pd(pb.add(i + 4)),
                    acc1,
                );
                i += 8;
            }
            let mut tail = 0.0;
            while i < n {
                tail += *pa.add(i) * *pb.add(i);
                i += 1;
            }
            hsum4(_mm256_add_pd(acc0, acc1)) + tail
        }
    }

    /// AVX2+FMA `y += alpha * x`, eight doubles per trip plus a scalar
    /// tail.
    ///
    /// # Safety
    /// The host must support AVX2 and FMA (`is_x86_feature_detected!`).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len().min(y.len());
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        // SAFETY: every offset below is < n = min(x.len(), y.len()); loads
        // read inside `x`/`y` and stores write inside `y` only (the slices
        // cannot overlap — `x` is shared, `y` exclusive). The intrinsics
        // need AVX2+FMA, which is the caller contract of this fn.
        unsafe {
            let va = _mm256_set1_pd(alpha);
            let mut i = 0usize;
            while i + 8 <= n {
                let y0 = _mm256_fmadd_pd(
                    va,
                    _mm256_loadu_pd(px.add(i)),
                    _mm256_loadu_pd(py.add(i)),
                );
                let y1 = _mm256_fmadd_pd(
                    va,
                    _mm256_loadu_pd(px.add(i + 4)),
                    _mm256_loadu_pd(py.add(i + 4)),
                );
                _mm256_storeu_pd(py.add(i), y0);
                _mm256_storeu_pd(py.add(i + 4), y1);
                i += 8;
            }
            while i < n {
                *py.add(i) += alpha * *px.add(i);
                i += 1;
            }
        }
    }

    /// AVX2+FMA fused projection kernel: `y += alpha * x`, returning
    /// `<z, y>` over the updated `y`.
    ///
    /// The dot accumulators mirror [`dot`] lane-for-lane (acc0 holds
    /// lanes `i..i+4`, acc1 lanes `i+4..i+8`, same tail, same
    /// `hsum4(acc0 + acc1) + tail` reduction), so the fused result is
    /// bit-identical to `axpy(alpha, x, y); dot(z, y)` *within this
    /// flavor* — the same contract the scalar pair keeps.
    ///
    /// # Safety
    /// The host must support AVX2 and FMA (`is_x86_feature_detected!`).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy_dot(alpha: f64, x: &[f64], z: &[f64], y: &mut [f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(z.len(), y.len());
        let n = x.len().min(z.len()).min(y.len());
        let px = x.as_ptr();
        let pz = z.as_ptr();
        let py = y.as_mut_ptr();
        // SAFETY: every offset below is < n = min of the three lengths;
        // loads read inside `x`/`z`/`y` and stores write inside `y` only
        // (`y` is the one exclusive borrow, so it cannot alias `x` or `z`).
        // The intrinsics need AVX2+FMA, the caller contract of this fn.
        unsafe {
            let va = _mm256_set1_pd(alpha);
            let mut acc0 = _mm256_setzero_pd();
            let mut acc1 = _mm256_setzero_pd();
            let mut i = 0usize;
            while i + 8 <= n {
                let y0 = _mm256_fmadd_pd(
                    va,
                    _mm256_loadu_pd(px.add(i)),
                    _mm256_loadu_pd(py.add(i)),
                );
                let y1 = _mm256_fmadd_pd(
                    va,
                    _mm256_loadu_pd(px.add(i + 4)),
                    _mm256_loadu_pd(py.add(i + 4)),
                );
                _mm256_storeu_pd(py.add(i), y0);
                _mm256_storeu_pd(py.add(i + 4), y1);
                acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(pz.add(i)), y0, acc0);
                acc1 = _mm256_fmadd_pd(_mm256_loadu_pd(pz.add(i + 4)), y1, acc1);
                i += 8;
            }
            let mut tail = 0.0;
            while i < n {
                let yv = *py.add(i) + alpha * *px.add(i);
                *py.add(i) = yv;
                tail += *pz.add(i) * yv;
                i += 1;
            }
            hsum4(_mm256_add_pd(acc0, acc1)) + tail
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flavor_names_are_stable() {
        assert_eq!(KernelFlavor::Scalar.name(), "scalar");
        assert_eq!(KernelFlavor::Avx2Fma.name(), "avx2+fma");
    }

    #[test]
    fn detected_flavor_is_consistent() {
        // Whatever the host is, two probes agree and active_flavor is
        // one of the two variants.
        assert_eq!(detected_flavor(), detected_flavor());
        let f = active_flavor();
        assert!(f == KernelFlavor::Scalar || f == KernelFlavor::Avx2Fma);
    }

    #[test]
    fn force_is_clamped_to_host_capability() {
        // After any prior resolution this may return false, but it must
        // never leave the process dispatching to an unsupported flavor.
        let _ = force_flavor(KernelFlavor::Avx2Fma);
        if detected_flavor() == KernelFlavor::Scalar {
            assert_eq!(active_flavor(), KernelFlavor::Scalar);
        }
    }

    #[test]
    fn avx2_wrappers_agree_with_scalar_when_available() {
        let n = 37; // crosses the 8-lane boundary with a 5-element tail
        let a: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).sin()).collect();
        let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).cos()).collect();
        if let Some(d) = dot_avx2(&a, &b) {
            let reference = super::super::vector::dot_scalar(&a, &b);
            let rel = (d - reference).abs() / reference.abs().max(1e-30);
            assert!(rel < 1e-12, "simd dot diverged: rel={rel:e}");
        }
    }
}
