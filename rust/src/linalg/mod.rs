//! Linear-algebra substrate.
//!
//! Everything the paper's experiments rely on, built from scratch (no BLAS
//! available in this environment): a row-major dense matrix, a CSR sparse
//! matrix behind the same row-access contract ([`RowStorage`], dispatched
//! through the two-variant [`Storage`] enum every solver runs against),
//! vector kernels tuned for the Kaczmarz hot path (`dot`, `axpy`, with
//! runtime-dispatched AVX2+FMA implementations in [`simd`] and the scalar
//! 8-lane bodies kept as the bitwise reference),
//! matrix-vector products, a Cholesky factorization, and
//! eigen/singular-value routines (power and inverse-power iteration, and a
//! one-sided Jacobi SVD used as the test oracle) needed to compute the
//! optimal RKA relaxation parameter `alpha*` (eq. 6 of the paper).

pub mod cholesky;
pub mod csr;
pub mod eig;
pub mod gemv;
pub mod matrix;
pub mod simd;
pub mod storage;
pub mod svd;
pub mod vector;

pub use cholesky::Cholesky;
pub use csr::CsrMatrix;
pub use eig::{inverse_power_iteration, power_iteration};
pub use gemv::{
    gemv, gemv_block_into, gemv_into, gemv_panel, gemv_transpose, gemv_transpose_into,
    set_gemv_panel,
};
pub use matrix::Matrix;
pub use simd::{active_flavor, detected_flavor, force_flavor, KernelFlavor};
pub use storage::{RowEntries, RowStorage, Storage};
pub use svd::jacobi_singular_values;
pub use vector::{
    axpy, axpy_dot, axpy_dot_scalar, axpy_scalar, dot, dot_scalar, norm2, norm2_sq,
    scale_in_place, sub,
};
