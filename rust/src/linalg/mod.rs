//! Dense linear-algebra substrate.
//!
//! Everything the paper's experiments rely on, built from scratch (no BLAS
//! available in this environment): a row-major dense matrix, vector kernels
//! tuned for the Kaczmarz hot path (`dot`, `axpy`), matrix-vector products,
//! a Cholesky factorization, and eigen/singular-value routines (power and
//! inverse-power iteration, and a one-sided Jacobi SVD used as the test
//! oracle) needed to compute the optimal RKA relaxation parameter
//! `alpha*` (eq. 6 of the paper).

pub mod cholesky;
pub mod eig;
pub mod gemv;
pub mod matrix;
pub mod svd;
pub mod vector;

pub use cholesky::Cholesky;
pub use eig::{inverse_power_iteration, power_iteration};
pub use gemv::{gemv, gemv_block_into, gemv_into, gemv_transpose, gemv_transpose_into};
pub use matrix::Matrix;
pub use svd::jacobi_singular_values;
pub use vector::{axpy, axpy_dot, dot, norm2, norm2_sq, scale_in_place, sub};
