//! Matrix-vector products.
//!
//! `gemv` (`y = A x`) is the CGLS workhorse; `gemv_transpose` (`y = Aᵀ x`)
//! avoids materializing `Aᵀ` by accumulating row-scaled axpys, which keeps
//! the access pattern row-major and cache-friendly. `gemv_block_into` is the
//! cache-blocked variant for wide matrices: it tiles the columns into
//! L1-sized panels so the `x` panel stays resident across all rows instead
//! of being re-streamed from L2/L3 once per row.

use super::matrix::Matrix;
use super::storage::RowStorage;
use super::vector::dot;
use crate::error::{Error, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Default column-panel width for [`gemv_block_into`]: 4096 f64 = 32 KiB,
/// one L1d's worth of `x`, leaving the row stream the other half of the
/// cache. [`gemv_panel`] may override this per host.
pub(crate) const GEMV_PANEL: usize = 4096;

/// Host-tuned panel override; 0 means "unset, fall back to env/default".
static TUNED_PANEL: AtomicUsize = AtomicUsize::new(0);

/// `KACZMARZ_GEMV_PANEL` env override, parsed once.
static ENV_PANEL: OnceLock<Option<usize>> = OnceLock::new();

/// Pin the blocked-GEMV panel width for this process (in f64 elements).
///
/// Called by the autotuner (`kaczmarz tune` / a loaded tune file) after
/// probing candidate widths on this host. Zero or absurd values are
/// ignored; the width is clamped to `[64, 1 << 20]`. Unlike the kernel
/// flavor this is re-settable — later tune loads win.
pub fn set_gemv_panel(panel: usize) {
    if panel > 0 {
        TUNED_PANEL.store(panel.clamp(64, 1 << 20), Ordering::Relaxed);
    }
}

/// The panel width [`gemv_block_into`] uses on dense storage, resolved as:
/// a [`set_gemv_panel`] pin (the tuner), else a positive
/// `KACZMARZ_GEMV_PANEL` environment value, else the default
/// [`GEMV_PANEL`] = 4096.
pub fn gemv_panel() -> usize {
    let tuned = TUNED_PANEL.load(Ordering::Relaxed);
    if tuned > 0 {
        return tuned;
    }
    ENV_PANEL
        .get_or_init(|| {
            std::env::var("KACZMARZ_GEMV_PANEL")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&p| p > 0)
                .map(|p| p.clamp(64, 1 << 20))
        })
        .unwrap_or(GEMV_PANEL)
}

/// `y = A x` (allocates the output). Storage-generic: accepts any
/// [`RowStorage`] backend — dense, CSR, or the [`Storage`](super::Storage)
/// enum a [`LinearSystem`](crate::data::LinearSystem) holds.
pub fn gemv<S: RowStorage + ?Sized>(a: &S, x: &[f64]) -> Result<Vec<f64>> {
    if x.len() != a.cols() {
        return Err(Error::Dimension(format!(
            "gemv: A is {}x{}, x has len {}",
            a.rows(),
            a.cols(),
            x.len()
        )));
    }
    let mut y = vec![0.0; a.rows()];
    gemv_into(a, x, &mut y);
    Ok(y)
}

/// `y = A x` into a caller-provided buffer (no allocation; hot path).
///
/// The dense backend delegates to the cache-blocked kernel when a row no
/// longer fits L1 alongside `x`; below that size blocking only adds loop
/// overhead. Sparse rows already touch only their stored columns.
pub fn gemv_into<S: RowStorage + ?Sized>(a: &S, x: &[f64], y: &mut [f64]) {
    a.gemv_into(x, y);
}

/// Cache-blocked `y = A x`: on dense storage, columns are processed in
/// panels of [`GEMV_PANEL`], each panel's slice of `x` staying L1-resident
/// while every row's matching segment streams past it once.
///
/// Same 8-lane `dot` per (row, panel) pair; per-row partials are accumulated
/// panel-major, so the summation associates as
/// `(panel_0 + panel_1) + panel_2 + ...` rather than one long chain — the
/// usual f64 reassociation caveat applies when comparing against
/// [`gemv_into`] on narrow matrices (both are exact for the panel-sized
/// case, where the two kernels coincide). CSR storage has no panel to
/// block, so this coincides with [`gemv_into`] there.
pub fn gemv_block_into<S: RowStorage + ?Sized>(a: &S, x: &[f64], y: &mut [f64]) {
    a.gemv_block_into(x, y);
}

/// Panel-width-parameterized body of [`gemv_block_into`] (exposed to tests
/// and the autotune probe so small matrices exercise multi-panel paths and
/// the tuner can time candidate widths).
pub(crate) fn gemv_block_into_with_panel(a: &Matrix, x: &[f64], y: &mut [f64], panel: usize) {
    gemv_block_rows_with_panel(a, x, y, 0, panel);
}

/// Row-range slice of the blocked GEMV: computes rows
/// `r0 .. r0 + y.len()` of `A x` into `y`, panels walked in the same
/// panel-major order as the full kernel.
///
/// Each output element accumulates its per-panel partial dots in exactly
/// the order [`gemv_block_into_with_panel`] would, so splitting the row
/// range across workers (see `parallel::gemv`) and running this per range
/// reproduces the serial result *bitwise*, element for element.
pub(crate) fn gemv_block_rows_with_panel(
    a: &Matrix,
    x: &[f64],
    y: &mut [f64],
    r0: usize,
    panel: usize,
) {
    debug_assert_eq!(x.len(), a.cols());
    debug_assert!(r0 + y.len() <= a.rows());
    debug_assert!(panel > 0);
    let n = a.cols();
    y.fill(0.0);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + panel).min(n);
        let xp = &x[lo..hi];
        for (k, yi) in y.iter_mut().enumerate() {
            *yi += dot(&a.row(r0 + k)[lo..hi], xp);
        }
        lo = hi;
    }
}

/// `y = Aᵀ x` (allocates the output). Storage-generic like [`gemv`].
pub fn gemv_transpose<S: RowStorage + ?Sized>(a: &S, x: &[f64]) -> Result<Vec<f64>> {
    if x.len() != a.rows() {
        return Err(Error::Dimension(format!(
            "gemv_transpose: A is {}x{}, x has len {}",
            a.rows(),
            a.cols(),
            x.len()
        )));
    }
    let mut y = vec![0.0; a.cols()];
    gemv_transpose_into(a, x, &mut y);
    Ok(y)
}

/// `y = Aᵀ x` into a caller-provided buffer.
///
/// Walks A row-by-row (`y += x_i * A^(i)`), never touching a column stride.
pub fn gemv_transpose_into<S: RowStorage + ?Sized>(a: &S, x: &[f64], y: &mut [f64]) {
    a.gemv_transpose_into(x, y);
}

/// Serializes tests that mutate the process-wide panel pin (here and in
/// `coordinator::autotune`): without it, concurrent test threads observe
/// each other's transient pins.
#[cfg(test)]
pub(crate) static PANEL_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    fn a() -> Matrix {
        Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap()
    }

    #[test]
    fn gemv_basic() {
        let y = gemv(&a(), &[1.0, 0.0, -1.0]).unwrap();
        assert_eq!(y, vec![-2.0, -2.0]);
    }

    #[test]
    fn gemv_rejects_bad_shape() {
        assert!(gemv(&a(), &[1.0, 2.0]).is_err());
    }

    #[test]
    fn gemv_transpose_basic() {
        let y = gemv_transpose(&a(), &[1.0, -1.0]).unwrap();
        assert_eq!(y, vec![-3.0, -3.0, -3.0]);
    }

    #[test]
    fn gemv_transpose_rejects_bad_shape() {
        assert!(gemv_transpose(&a(), &[1.0, 2.0, 3.0]).is_err());
    }

    #[test]
    fn blocked_gemv_matches_unblocked() {
        // Panel widths that split the 7 columns at every boundary.
        let m = Matrix::from_vec(
            3,
            7,
            (0..21).map(|i| ((i * 13 % 17) as f64) - 8.0).collect(),
        )
        .unwrap();
        let x: Vec<f64> = (0..7).map(|i| (i as f64 * 0.3).cos()).collect();
        let mut reference = vec![0.0; 3];
        for (yi, row) in reference.iter_mut().zip(m.rows_iter()) {
            *yi = dot(row, &x);
        }
        for panel in [1usize, 2, 3, 4, 7, 100] {
            let mut y = vec![f64::NAN; 3];
            gemv_block_into_with_panel(&m, &x, &mut y, panel);
            for (u, v) in y.iter().zip(&reference) {
                assert!((u - v).abs() < 1e-12, "panel {panel}: {u} vs {v}");
            }
        }
    }

    #[test]
    fn ranged_blocked_gemv_is_bitwise_slice_of_full() {
        let rows = 5;
        let cols = 23;
        let m = Matrix::from_vec(
            rows,
            cols,
            (0..rows * cols)
                .map(|i| ((i * 29 % 31) as f64 - 15.0) * 0.37)
                .collect(),
        )
        .unwrap();
        let x: Vec<f64> = (0..cols).map(|i| (i as f64 * 0.21).sin()).collect();
        for panel in [3usize, 8, 23, 64] {
            let mut full = vec![0.0; rows];
            gemv_block_into_with_panel(&m, &x, &mut full, panel);
            // Split the rows 0..2 / 2..5 and recompute each range.
            let mut lo_part = vec![f64::NAN; 2];
            let mut hi_part = vec![f64::NAN; 3];
            gemv_block_rows_with_panel(&m, &x, &mut lo_part, 0, panel);
            gemv_block_rows_with_panel(&m, &x, &mut hi_part, 2, panel);
            let stitched: Vec<f64> = lo_part.iter().chain(&hi_part).copied().collect();
            for (i, (u, v)) in stitched.iter().zip(&full).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "panel {panel}, row {i}");
            }
        }
    }

    #[test]
    fn gemv_panel_pins_and_clamps() {
        let _guard = PANEL_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // Default, or a previous panel test's restored default.
        assert_eq!(gemv_panel(), GEMV_PANEL);
        // Pins clamp into [64, 1 << 20]; zero is ignored. Only values
        // >= the default are probed here so concurrently running tests
        // never see a *smaller* panel (which could change blocked-path
        // rounding for wide matrices mid-run).
        set_gemv_panel(8192);
        assert_eq!(gemv_panel(), 8192);
        set_gemv_panel(usize::MAX);
        assert_eq!(gemv_panel(), 1 << 20);
        set_gemv_panel(0);
        assert_eq!(gemv_panel(), 1 << 20, "zero must not unset the pin");
        // Restore the default so the rest of the suite is unaffected.
        set_gemv_panel(GEMV_PANEL);
        assert_eq!(gemv_panel(), GEMV_PANEL);
    }

    #[test]
    fn transpose_consistency() {
        // gemv_transpose(A, x) == gemv(Aᵀ, x)
        let m = a();
        let x = [0.5, -2.5];
        let via_t = gemv(&m.transpose(), &x).unwrap();
        let direct = gemv_transpose(&m, &x).unwrap();
        for (u, v) in via_t.iter().zip(&direct) {
            assert!((u - v).abs() < 1e-12);
        }
    }
}
