//! Matrix-vector products.
//!
//! `gemv` (`y = A x`) is the CGLS workhorse; `gemv_transpose` (`y = Aᵀ x`)
//! avoids materializing `Aᵀ` by accumulating row-scaled axpys, which keeps
//! the access pattern row-major and cache-friendly. `gemv_block_into` is the
//! cache-blocked variant for wide matrices: it tiles the columns into
//! L1-sized panels so the `x` panel stays resident across all rows instead
//! of being re-streamed from L2/L3 once per row.

use super::matrix::Matrix;
use super::storage::RowStorage;
use super::vector::dot;
use crate::error::{Error, Result};

/// Column-panel width for [`gemv_block_into`]: 4096 f64 = 32 KiB, one L1d's
/// worth of `x`, leaving the row stream the other half of the cache.
pub(crate) const GEMV_PANEL: usize = 4096;

/// `y = A x` (allocates the output). Storage-generic: accepts any
/// [`RowStorage`] backend — dense, CSR, or the [`Storage`](super::Storage)
/// enum a [`LinearSystem`](crate::data::LinearSystem) holds.
pub fn gemv<S: RowStorage + ?Sized>(a: &S, x: &[f64]) -> Result<Vec<f64>> {
    if x.len() != a.cols() {
        return Err(Error::Dimension(format!(
            "gemv: A is {}x{}, x has len {}",
            a.rows(),
            a.cols(),
            x.len()
        )));
    }
    let mut y = vec![0.0; a.rows()];
    gemv_into(a, x, &mut y);
    Ok(y)
}

/// `y = A x` into a caller-provided buffer (no allocation; hot path).
///
/// The dense backend delegates to the cache-blocked kernel when a row no
/// longer fits L1 alongside `x`; below that size blocking only adds loop
/// overhead. Sparse rows already touch only their stored columns.
pub fn gemv_into<S: RowStorage + ?Sized>(a: &S, x: &[f64], y: &mut [f64]) {
    a.gemv_into(x, y);
}

/// Cache-blocked `y = A x`: on dense storage, columns are processed in
/// panels of [`GEMV_PANEL`], each panel's slice of `x` staying L1-resident
/// while every row's matching segment streams past it once.
///
/// Same 8-lane `dot` per (row, panel) pair; per-row partials are accumulated
/// panel-major, so the summation associates as
/// `(panel_0 + panel_1) + panel_2 + ...` rather than one long chain — the
/// usual f64 reassociation caveat applies when comparing against
/// [`gemv_into`] on narrow matrices (both are exact for the panel-sized
/// case, where the two kernels coincide). CSR storage has no panel to
/// block, so this coincides with [`gemv_into`] there.
pub fn gemv_block_into<S: RowStorage + ?Sized>(a: &S, x: &[f64], y: &mut [f64]) {
    a.gemv_block_into(x, y);
}

/// Panel-width-parameterized body of [`gemv_block_into`] (exposed to tests
/// so small matrices exercise multi-panel paths).
pub(crate) fn gemv_block_into_with_panel(a: &Matrix, x: &[f64], y: &mut [f64], panel: usize) {
    debug_assert_eq!(x.len(), a.cols());
    debug_assert_eq!(y.len(), a.rows());
    debug_assert!(panel > 0);
    let n = a.cols();
    y.fill(0.0);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + panel).min(n);
        let xp = &x[lo..hi];
        for (yi, row) in y.iter_mut().zip(a.rows_iter()) {
            *yi += dot(&row[lo..hi], xp);
        }
        lo = hi;
    }
}

/// `y = Aᵀ x` (allocates the output). Storage-generic like [`gemv`].
pub fn gemv_transpose<S: RowStorage + ?Sized>(a: &S, x: &[f64]) -> Result<Vec<f64>> {
    if x.len() != a.rows() {
        return Err(Error::Dimension(format!(
            "gemv_transpose: A is {}x{}, x has len {}",
            a.rows(),
            a.cols(),
            x.len()
        )));
    }
    let mut y = vec![0.0; a.cols()];
    gemv_transpose_into(a, x, &mut y);
    Ok(y)
}

/// `y = Aᵀ x` into a caller-provided buffer.
///
/// Walks A row-by-row (`y += x_i * A^(i)`), never touching a column stride.
pub fn gemv_transpose_into<S: RowStorage + ?Sized>(a: &S, x: &[f64], y: &mut [f64]) {
    a.gemv_transpose_into(x, y);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a() -> Matrix {
        Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap()
    }

    #[test]
    fn gemv_basic() {
        let y = gemv(&a(), &[1.0, 0.0, -1.0]).unwrap();
        assert_eq!(y, vec![-2.0, -2.0]);
    }

    #[test]
    fn gemv_rejects_bad_shape() {
        assert!(gemv(&a(), &[1.0, 2.0]).is_err());
    }

    #[test]
    fn gemv_transpose_basic() {
        let y = gemv_transpose(&a(), &[1.0, -1.0]).unwrap();
        assert_eq!(y, vec![-3.0, -3.0, -3.0]);
    }

    #[test]
    fn gemv_transpose_rejects_bad_shape() {
        assert!(gemv_transpose(&a(), &[1.0, 2.0, 3.0]).is_err());
    }

    #[test]
    fn blocked_gemv_matches_unblocked() {
        // Panel widths that split the 7 columns at every boundary.
        let m = Matrix::from_vec(
            3,
            7,
            (0..21).map(|i| ((i * 13 % 17) as f64) - 8.0).collect(),
        )
        .unwrap();
        let x: Vec<f64> = (0..7).map(|i| (i as f64 * 0.3).cos()).collect();
        let mut reference = vec![0.0; 3];
        for (yi, row) in reference.iter_mut().zip(m.rows_iter()) {
            *yi = dot(row, &x);
        }
        for panel in [1usize, 2, 3, 4, 7, 100] {
            let mut y = vec![f64::NAN; 3];
            gemv_block_into_with_panel(&m, &x, &mut y, panel);
            for (u, v) in y.iter().zip(&reference) {
                assert!((u - v).abs() < 1e-12, "panel {panel}: {u} vs {v}");
            }
        }
    }

    #[test]
    fn transpose_consistency() {
        // gemv_transpose(A, x) == gemv(Aᵀ, x)
        let m = a();
        let x = [0.5, -2.5];
        let via_t = gemv(&m.transpose(), &x).unwrap();
        let direct = gemv_transpose(&m, &x).unwrap();
        for (u, v) in via_t.iter().zip(&direct) {
            assert!((u - v).abs() < 1e-12);
        }
    }
}
