//! Matrix-vector products.
//!
//! `gemv` (`y = A x`) is the CGLS workhorse; `gemv_transpose` (`y = Aᵀ x`)
//! avoids materializing `Aᵀ` by accumulating row-scaled axpys, which keeps
//! the access pattern row-major and cache-friendly.

use super::matrix::Matrix;
use super::vector::{axpy, dot};
use crate::error::{Error, Result};

/// `y = A x` (allocates the output).
pub fn gemv(a: &Matrix, x: &[f64]) -> Result<Vec<f64>> {
    if x.len() != a.cols() {
        return Err(Error::Dimension(format!(
            "gemv: A is {}x{}, x has len {}",
            a.rows(),
            a.cols(),
            x.len()
        )));
    }
    let mut y = vec![0.0; a.rows()];
    gemv_into(a, x, &mut y);
    Ok(y)
}

/// `y = A x` into a caller-provided buffer (no allocation; hot path).
pub fn gemv_into(a: &Matrix, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), a.cols());
    debug_assert_eq!(y.len(), a.rows());
    for (yi, row) in y.iter_mut().zip(a.rows_iter()) {
        *yi = dot(row, x);
    }
}

/// `y = Aᵀ x` (allocates the output).
pub fn gemv_transpose(a: &Matrix, x: &[f64]) -> Result<Vec<f64>> {
    if x.len() != a.rows() {
        return Err(Error::Dimension(format!(
            "gemv_transpose: A is {}x{}, x has len {}",
            a.rows(),
            a.cols(),
            x.len()
        )));
    }
    let mut y = vec![0.0; a.cols()];
    gemv_transpose_into(a, x, &mut y);
    Ok(y)
}

/// `y = Aᵀ x` into a caller-provided buffer.
///
/// Walks A row-by-row (`y += x_i * A^(i)`), never touching a column stride.
pub fn gemv_transpose_into(a: &Matrix, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), a.rows());
    debug_assert_eq!(y.len(), a.cols());
    y.fill(0.0);
    for (xi, row) in x.iter().zip(a.rows_iter()) {
        if *xi != 0.0 {
            axpy(*xi, row, y);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a() -> Matrix {
        Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap()
    }

    #[test]
    fn gemv_basic() {
        let y = gemv(&a(), &[1.0, 0.0, -1.0]).unwrap();
        assert_eq!(y, vec![-2.0, -2.0]);
    }

    #[test]
    fn gemv_rejects_bad_shape() {
        assert!(gemv(&a(), &[1.0, 2.0]).is_err());
    }

    #[test]
    fn gemv_transpose_basic() {
        let y = gemv_transpose(&a(), &[1.0, -1.0]).unwrap();
        assert_eq!(y, vec![-3.0, -3.0, -3.0]);
    }

    #[test]
    fn gemv_transpose_rejects_bad_shape() {
        assert!(gemv_transpose(&a(), &[1.0, 2.0, 3.0]).is_err());
    }

    #[test]
    fn transpose_consistency() {
        // gemv_transpose(A, x) == gemv(Aᵀ, x)
        let m = a();
        let x = [0.5, -2.5];
        let via_t = gemv(&m.transpose(), &x).unwrap();
        let direct = gemv_transpose(&m, &x).unwrap();
        for (u, v) in via_t.iter().zip(&direct) {
            assert!((u - v).abs() < 1e-12);
        }
    }
}
