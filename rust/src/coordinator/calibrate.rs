//! The paper's §3.1 measurement protocol.
//!
//! "First, we determine the number of iterations, k, that parallel
//! implementations take to achieve a given error; then we measure the
//! runtime using that previously calculated value as the maximum number of
//! iterations." Runs are repeated over seeds (the paper uses 10; enough for
//! ~1% time deviation) and iteration counts averaged.
//!
//! Two calibration modes:
//!
//! - **reference-stopped** (the paper's): pass options carrying
//!   [`StoppingCriterion::ReferenceError`](crate::solvers::StoppingCriterion) —
//!   requires the system to know its solution;
//! - **residual-stopped** ([`calibrate_iterations_residual`]): calibrate
//!   against `‖Ax - b‖² < tol`, which needs no reference — so the
//!   calibrate-then-time protocol runs on systems with *unknown* solutions,
//!   the serving case.
//!
//! A configuration where **every** seed fails to converge (e.g. the Fig. 10
//! divergence corner) yields [`Error::CalibrationFailed`] instead of the
//! former silent `mean_iterations = 0.0` — which turned into a zero
//! fixed-iteration budget downstream and timed nothing at all.

use crate::data::LinearSystem;
use crate::error::{Error, Result};
use crate::metrics::mean_std;
use crate::solvers::{SolveOptions, SolveResult, Solver};

/// Result of an iteration-count calibration. Only produced when at least
/// one seed converged ([`calibrate_iterations`] errors otherwise), so
/// `mean_iterations` is always a real average.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Mean iterations to reach the tolerance.
    pub mean_iterations: f64,
    /// Std-dev across seeds.
    pub std_iterations: f64,
    /// Fraction of seeds that converged (divergers excluded from the mean).
    pub converged_fraction: f64,
    /// Mean total rows used.
    pub mean_rows_used: f64,
}

impl Calibration {
    /// Mean iterations rounded for use as a fixed budget.
    ///
    /// Saturating and finite-checked: a NaN or negative mean yields 0, a
    /// mean beyond `usize::MAX` yields `usize::MAX` — never the undefined
    /// behavior-adjacent garbage of a bare `as usize` on a non-finite
    /// float. (With [`calibrate_iterations`] returning an error on
    /// all-divergent configurations, a well-formed `Calibration` should
    /// never hit these guards; they protect hand-built values.)
    pub fn iterations(&self) -> usize {
        let rounded = self.mean_iterations.round();
        if !rounded.is_finite() || rounded <= 0.0 {
            0
        } else if rounded >= usize::MAX as f64 {
            usize::MAX
        } else {
            rounded as usize
        }
    }
}

/// Run `make_solver(seed)` for `seeds` seeds to the `opts` tolerance and
/// average the iteration counts of the seeds that converged.
///
/// Returns [`Error::CalibrationFailed`] when *no* seed converges — there is
/// no budget to average, and the old behavior (averaging an empty vector
/// into `mean_iterations = 0.0`) handed downstream timing runs a zero
/// fixed-iteration budget.
pub fn calibrate_iterations<S: Solver>(
    make_solver: impl Fn(u32) -> S,
    system: &LinearSystem,
    opts: &SolveOptions,
    seeds: u32,
) -> Result<Calibration> {
    assert!(seeds >= 1);
    let mut iters = Vec::with_capacity(seeds as usize);
    let mut rows = Vec::with_capacity(seeds as usize);
    let mut converged = 0u32;
    let mut diverged = 0u32;
    for seed in 0..seeds {
        let r: SolveResult = make_solver(seed).solve(system, opts);
        if r.converged {
            converged += 1;
            iters.push(r.iterations as f64);
            rows.push(r.rows_used as f64);
        } else if r.diverged {
            diverged += 1;
        }
    }
    if converged == 0 {
        return Err(Error::CalibrationFailed { seeds, diverged });
    }
    let (mean_iterations, std_iterations) = mean_std(&iters);
    let (mean_rows_used, _) = mean_std(&rows);
    Ok(Calibration {
        mean_iterations,
        std_iterations,
        converged_fraction: converged as f64 / seeds as f64,
        mean_rows_used,
    })
}

/// Residual-stopped calibration: like [`calibrate_iterations`] but against
/// `‖Ax - b‖² < tolerance` (checked every `check_every` iterations), which
/// needs **no reference solution** — the §3.1 calibrate-then-time protocol
/// for systems whose answer is unknown. Everything else in `opts`
/// (iteration cap, divergence factor, history step) is honored as given.
pub fn calibrate_iterations_residual<S: Solver>(
    make_solver: impl Fn(u32) -> S,
    system: &LinearSystem,
    opts: &SolveOptions,
    tolerance: f64,
    check_every: usize,
    seeds: u32,
) -> Result<Calibration> {
    let opts = opts.clone().with_residual_stopping(tolerance, check_every);
    calibrate_iterations(make_solver, system, &opts, seeds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::linalg::Matrix;
    use crate::solvers::rk::RkSolver;
    use crate::solvers::rkab::RkabSolver;

    #[test]
    fn calibration_averages_over_seeds() {
        let sys = DatasetBuilder::new(300, 15).seed(1).consistent();
        let c = calibrate_iterations(RkSolver::new, &sys, &SolveOptions::default(), 4)
            .expect("consistent system converges");
        assert_eq!(c.converged_fraction, 1.0);
        assert!(c.mean_iterations > 100.0);
        assert!(c.iterations() > 0);
        // seeds differ => nonzero spread (almost surely)
        assert!(c.std_iterations > 0.0);
    }

    #[test]
    fn all_divergent_configuration_is_an_error_not_a_zero_budget() {
        let sys = DatasetBuilder::new(200, 10).seed(2).consistent();
        let opts = SolveOptions {
            divergence_factor: 1e4,
            max_iterations: 50_000,
            ..Default::default()
        };
        // alpha=3.9 with large blocks diverges (Fig. 10b behaviour).
        let err = calibrate_iterations(|s| RkabSolver::new(s, 4, 100, 3.9), &sys, &opts, 3)
            .err()
            .expect("all seeds diverge: must be an error, not iterations() == 0");
        match err {
            Error::CalibrationFailed { seeds, diverged } => {
                assert_eq!(seeds, 3);
                assert_eq!(diverged, 3);
            }
            other => panic!("expected CalibrationFailed, got {other:?}"),
        }
    }

    #[test]
    fn residual_mode_calibrates_without_a_reference() {
        // The serving case: the system has no known solution at all; the
        // paper's reference-stopped mode cannot run (error_sq would panic),
        // the residual mode must.
        let built = DatasetBuilder::new(300, 15).seed(3).consistent();
        let sys = LinearSystem::new(built.a.clone(), built.b.clone(), None, true);
        let c = calibrate_iterations_residual(
            RkSolver::new,
            &sys,
            &SolveOptions::default(),
            1e-6,
            8,
            4,
        )
        .expect("reference-free residual calibration");
        assert_eq!(c.converged_fraction, 1.0);
        assert!(c.iterations() > 0);
    }

    #[test]
    fn residual_and_reference_calibration_agree_exactly_on_identity() {
        // On the identity system the two stopping metrics coincide bit for
        // bit (‖x - x*‖² = ‖Ix - b‖² with b = x*), so at check_every = 1
        // the two calibrations must produce identical iteration counts.
        let n = 24;
        let x_star: Vec<f64> = (0..n).map(|i| 1.0 + i as f64).collect();
        let sys = LinearSystem::new(Matrix::identity(n), x_star.clone(), Some(x_star), true);
        let by_ref =
            calibrate_iterations(RkSolver::new, &sys, &SolveOptions::default(), 3).unwrap();
        let by_res = calibrate_iterations_residual(
            RkSolver::new,
            &sys,
            &SolveOptions::default(),
            SolveOptions::default().tolerance(),
            1,
            3,
        )
        .unwrap();
        assert_eq!(by_ref.mean_iterations, by_res.mean_iterations);
        assert_eq!(by_ref.std_iterations, by_res.std_iterations);
    }

    #[test]
    fn iterations_rounding_is_saturating_and_finite_checked() {
        let base = Calibration {
            mean_iterations: 0.0,
            std_iterations: 0.0,
            converged_fraction: 0.0,
            mean_rows_used: 0.0,
        };
        let with = |m: f64| Calibration { mean_iterations: m, ..base.clone() };
        assert_eq!(with(1234.4).iterations(), 1234);
        assert_eq!(with(0.6).iterations(), 1);
        assert_eq!(with(f64::NAN).iterations(), 0);
        assert_eq!(with(f64::NEG_INFINITY).iterations(), 0);
        assert_eq!(with(-3.0).iterations(), 0);
        assert_eq!(with(f64::INFINITY).iterations(), usize::MAX);
        assert_eq!(with(1e30).iterations(), usize::MAX);
    }
}
