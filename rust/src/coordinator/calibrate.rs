//! The paper's §3.1 measurement protocol.
//!
//! "First, we determine the number of iterations, k, that parallel
//! implementations take to achieve a given error; then we measure the
//! runtime using that previously calculated value as the maximum number of
//! iterations." Runs are repeated over seeds (the paper uses 10; enough for
//! ~1% time deviation) and iteration counts averaged.

use crate::data::LinearSystem;
use crate::metrics::mean_std;
use crate::solvers::{SolveOptions, SolveResult, Solver};

/// Result of an iteration-count calibration.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Mean iterations to reach the tolerance.
    pub mean_iterations: f64,
    /// Std-dev across seeds.
    pub std_iterations: f64,
    /// Fraction of seeds that converged (divergers excluded from the mean).
    pub converged_fraction: f64,
    /// Mean total rows used.
    pub mean_rows_used: f64,
}

impl Calibration {
    /// Mean iterations rounded for use as a fixed budget.
    pub fn iterations(&self) -> usize {
        self.mean_iterations.round() as usize
    }
}

/// Run `make_solver(seed)` for `seeds` seeds to the `opts` tolerance and
/// average the iteration counts.
pub fn calibrate_iterations<S: Solver>(
    make_solver: impl Fn(u32) -> S,
    system: &LinearSystem,
    opts: &SolveOptions,
    seeds: u32,
) -> Calibration {
    assert!(seeds >= 1);
    let mut iters = Vec::with_capacity(seeds as usize);
    let mut rows = Vec::with_capacity(seeds as usize);
    let mut converged = 0u32;
    for seed in 0..seeds {
        let r: SolveResult = make_solver(seed).solve(system, opts);
        if r.converged {
            converged += 1;
            iters.push(r.iterations as f64);
            rows.push(r.rows_used as f64);
        }
    }
    let (mean_iterations, std_iterations) = mean_std(&iters);
    let (mean_rows_used, _) = mean_std(&rows);
    Calibration {
        mean_iterations,
        std_iterations,
        converged_fraction: converged as f64 / seeds as f64,
        mean_rows_used,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::solvers::rk::RkSolver;
    use crate::solvers::rkab::RkabSolver;

    #[test]
    fn calibration_averages_over_seeds() {
        let sys = DatasetBuilder::new(300, 15).seed(1).consistent();
        let c = calibrate_iterations(
            RkSolver::new,
            &sys,
            &SolveOptions::default(),
            4,
        );
        assert_eq!(c.converged_fraction, 1.0);
        assert!(c.mean_iterations > 100.0);
        assert!(c.iterations() > 0);
        // seeds differ => nonzero spread (almost surely)
        assert!(c.std_iterations > 0.0);
    }

    #[test]
    fn divergers_excluded() {
        let sys = DatasetBuilder::new(200, 10).seed(2).consistent();
        let opts = SolveOptions {
            divergence_factor: 1e4,
            max_iterations: 50_000,
            ..Default::default()
        };
        // alpha=3.9 with large blocks diverges (Fig. 10b behaviour).
        let c = calibrate_iterations(|s| RkabSolver::new(s, 4, 100, 3.9), &sys, &opts, 3);
        assert_eq!(c.converged_fraction, 0.0);
        assert_eq!(c.mean_iterations, 0.0);
    }
}
