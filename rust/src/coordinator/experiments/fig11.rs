//! Fig. 11 — distributed RKAB: time vs block size under the two
//! process/node configurations (§3.4.3).
//!
//! Paper workload: 80000 x 1000 and 80000 x 10000, np = 40-ish; scaled:
//! 8000 x 250 and 8000 x 1000, np = 8. The paper's point: with the matrix
//! partitioned, bs = n is no longer the right rule — each rank's submatrix
//! may be underdetermined (fewer than n rows), so information saturates
//! earlier and large blocks reuse rows.

use crate::coordinator::{Experiment, Scale};
use crate::data::DatasetBuilder;
use crate::distributed::{DistRkab, Placement, SimCluster};
use crate::report::{fmt_seconds, Report, Table};
use crate::solvers::SolveOptions;

/// Fig. 11 driver.
pub struct Fig11;

impl Experiment for Fig11 {
    fn id(&self) -> &'static str {
        "fig11"
    }

    fn title(&self) -> &'static str {
        "Fig 11: distributed RKAB time vs block size, two placements"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        let np = if scale.factor < 0.5 { 4 } else { 8 };

        for (panel, n0) in [("(a) n small", 250usize), ("(b) n large", 1_000)] {
            let m = scale.dim(8_000);
            let n = scale.dim(n0);
            let sys = DatasetBuilder::new(m, n).seed(61).consistent();
            let rows_per_rank = m / np;
            report.text(format!(
                "Panel {panel}: {m} x {n}, np = {np}; per-rank submatrix \
                 {rows_per_rank} x {n} ({}).\n",
                if rows_per_rank >= n { "overdetermined" } else { "underdetermined" }
            ));

            let block_sizes: Vec<usize> =
                vec![5, n / 5, n / 2, n, 2 * n].into_iter().filter(|&b| b >= 1).collect();
            let mut t = Table::new(
                format!("Fig 11{panel}: simulated time vs bs"),
                &["bs", "iters", "t 24/node", "t 2/node"],
            );
            for bs in block_sizes {
                let mut times = Vec::new();
                let mut iters = 0usize;
                for placement in [Placement::full_node(), Placement::two_per_node()] {
                    let cluster = SimCluster::new(np, placement);
                    let cal =
                        DistRkab::new(3, bs, 1.0).solve(&sys, &SolveOptions::default(), &cluster);
                    iters = cal.iterations;
                    let timed = DistRkab::new(3, bs, 1.0).solve(
                        &sys,
                        &SolveOptions::default().with_fixed_iterations(cal.iterations.max(1)),
                        &cluster,
                    );
                    times.push(timed.sim_seconds);
                }
                t.row(vec![
                    bs.to_string(),
                    iters.to_string(),
                    fmt_seconds(times[0]),
                    fmt_seconds(times[1]),
                ]);
            }
            report.table(&t);
        }
        report.text(
            "**Shape check (paper Fig. 11):** small blocks favor packing a node \
             (latency-bound Allreduce); large blocks favor 2-per-node (compute/\
             memory-bound); for the wide system 2-per-node wins at every bs.\n",
        );
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs_both_panels() {
        let md = Fig11.run(Scale::smoke()).to_markdown();
        assert!(md.contains("Fig 11(a)"));
        assert!(md.contains("Fig 11(b)"));
    }
}
