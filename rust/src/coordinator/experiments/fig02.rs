//! Fig. 2 — block-sequential parallelization of each RK iteration (§3.2).
//!
//! Paper: speedup vs threads for (a) small n (no speedup at all, slowdowns)
//! and (b) large n (some speedup, far from ideal, degrading at 64 threads).
//! Workload: fixed row count, n ∈ {50..1000} (a) and {2000, 4000} (b).
//!
//! Timing: per-iteration cost from the calibrated CostModel (measured
//! projection cost + modeled barriers — see coordinator::timing). Iteration
//! counts are irrelevant here (same chain for every q), so speedup =
//! t_iter(1) / t_iter(q).

use crate::coordinator::experiments::thread_counts;
use crate::coordinator::{CostModel, Experiment, Scale};
use crate::data::DatasetBuilder;
use crate::report::{fmt_seconds, fmt_speedup, Report, Table};

/// Fig. 2 driver.
pub struct Fig02;

impl Experiment for Fig02 {
    fn id(&self) -> &'static str {
        "fig2"
    }

    fn title(&self) -> &'static str {
        "Fig 2: block-sequential RK speedup vs threads"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        report.text(
            "Paper workload: m = 20000, n in {50, 100, 500, 1000} (a) and n in \
             {2000, 4000} (b), threads 1-64. Scaled here by the factor below; \
             per-iteration timing composed from the measured projection cost + \
             modeled barrier crossings (see DESIGN.md §3).\n",
        );
        report.text(format!("Scale factor: {} (m = {}).\n", scale.factor, scale.dim(20_000)));

        let m = scale.dim(20_000);
        let small_n = [50usize, 100, 500, 1000];
        let large_n = [2000usize, 4000];

        for (panel, ns) in [("(a) small n", &small_n[..]), ("(b) large n", &large_n[..])] {
            let mut t = Table::new(
                format!("Fig 2{panel}: speedup (t_seq / t_par)"),
                &["n", "t_iter seq", "q=2", "q=4", "q=8", "q=16", "q=64"],
            );
            for &n in ns {
                let n_scaled = scale.dim(n);
                let sys = DatasetBuilder::new(m, n_scaled).seed(42).consistent();
                let model = CostModel::calibrate(&sys);
                let t1 = model.block_seq_iteration(1);
                let mut cells = vec![n_scaled.to_string(), fmt_seconds(t1)];
                for &q in &thread_counts()[1..] {
                    cells.push(fmt_speedup(t1 / model.block_seq_iteration(q)));
                }
                t.row(cells);
            }
            report.table(&t);
        }
        report.text(
            "**Shape check (paper Fig. 2):** small n shows no speedup (<1 for all q); \
             large n improves but stays far from ideal and drops from 16 to 64 threads.\n",
        );
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_produces_both_panels() {
        let md = Fig02.run(Scale::smoke()).to_markdown();
        assert!(md.contains("Fig 2(a)"));
        assert!(md.contains("Fig 2(b)"));
    }
}
