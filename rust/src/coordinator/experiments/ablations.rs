//! Ablations beyond the paper's figures — design choices §3.3.1 discusses in
//! prose but never plots:
//!
//! - [`AblationAveraging`] — the four result-gathering strategies of
//!   Algorithm 1 (critical / atomic / reduce / gather-matrix): identical
//!   semantics (verified), different gather cost;
//! - [`AblationSampling`] — alias-table vs CDF-binary-search row sampling on
//!   the *sequential* RK hot loop (this one is honest wall-clock: it is
//!   single-threaded, so the 1-core container measures it directly);
//! - [`AblationAutotune`] — the automatic block-size tuner (our extension of
//!   the paper's future work) vs the bs = n rule of thumb.

use crate::coordinator::autotune::{autotune_block_size, AutotuneConfig};
use crate::coordinator::{calibrate_iterations, CostModel, Experiment, Scale};
use crate::data::DatasetBuilder;
use crate::metrics::Stopwatch;
use crate::parallel::AveragingStrategy;
use crate::report::{fmt_seconds, Report, Table};
use crate::rng::{AliasTable, DiscreteDistribution, Mt19937};
use crate::solvers::rkab::RkabSolver;
use crate::solvers::{SolveOptions, Solver};

/// Averaging-strategy ablation (Algorithm 1's four gathers).
pub struct AblationAveraging;

impl Experiment for AblationAveraging {
    fn id(&self) -> &'static str {
        "ablation-averaging"
    }

    fn title(&self) -> &'static str {
        "Ablation: RKA averaging strategies (critical/atomic/reduce/matrix)"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        let m = scale.dim(4_000);
        let n = scale.dim(1_000);
        let sys = DatasetBuilder::new(m, n).seed(81).consistent();
        let model = CostModel::calibrate(&sys);

        let mut t = Table::new(
            format!("Modeled per-iteration gather cost, n = {n}"),
            &["q", "critical", "atomic", "reduce", "matrix"],
        );
        for q in [2usize, 4, 8, 16, 64] {
            t.row(vec![
                q.to_string(),
                fmt_seconds(model.rka_iteration(q, AveragingStrategy::Critical)),
                fmt_seconds(model.rka_iteration(q, AveragingStrategy::Atomic)),
                fmt_seconds(model.rka_iteration(q, AveragingStrategy::Reduce)),
                fmt_seconds(model.rka_iteration(q, AveragingStrategy::MatrixGather)),
            ]);
        }
        report.table(&t);
        report.text(
            "**Shape check (paper §3.3.1 prose):** the critical section is the \
             fastest gather at every thread count; atomics pay CAS+invalidation \
             traffic, reduce pays the zero+combine, the gather matrix pays \
             cross-thread cache lines. All four converge identically \
             (rust/tests/parallel_integration.rs).\n",
        );
        report
    }
}

/// Sampling-distribution ablation (alias vs CDF) — measured wall-clock.
pub struct AblationSampling;

impl Experiment for AblationSampling {
    fn id(&self) -> &'static str {
        "ablation-sampling"
    }

    fn title(&self) -> &'static str {
        "Ablation: alias-table vs CDF binary-search row sampling"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        let mut t = Table::new(
            "Sampling cost (measured) and share of an RK iteration",
            &["m", "alias ns/draw", "cdf ns/draw", "proj ns", "alias share", "cdf share"],
        );
        for m0 in [4_000usize, 40_000, 160_000] {
            let m = scale.dim(m0);
            let n = scale.dim(250);
            let sys = DatasetBuilder::new(m, n).seed(83).consistent();
            let alias = AliasTable::new(sys.sampling_weights());
            let cdf = DiscreteDistribution::new(sys.sampling_weights());
            let mut rng = Mt19937::new(1);
            let draws = 2_000_000usize;
            let sw = Stopwatch::start();
            let mut acc = 0usize;
            for _ in 0..draws {
                acc += alias.sample(&mut rng);
            }
            let t_alias = sw.seconds() / draws as f64;
            let sw = Stopwatch::start();
            for _ in 0..draws {
                acc += cdf.sample(&mut rng);
            }
            let t_cdf = sw.seconds() / draws as f64;
            std::hint::black_box(acc);
            let model = CostModel::calibrate(&sys);
            t.row(vec![
                m.to_string(),
                format!("{:.1}", t_alias * 1e9),
                format!("{:.1}", t_cdf * 1e9),
                format!("{:.1}", model.t_proj * 1e9),
                format!("{:.1}%", 100.0 * t_alias / (model.t_proj + t_alias)),
                format!("{:.1}%", 100.0 * t_cdf / (model.t_proj + t_cdf)),
            ]);
        }
        report.table(&t);
        report.text(
            "**Shape check:** O(1) alias sampling is flat in m while the CDF \
             binary search grows with log m; on narrow systems the sampler is a \
             visible share of the iteration, which is why the solvers adopted \
             the alias table in the §Perf pass.\n",
        );
        report
    }
}

/// Auto block-size tuner vs the bs = n rule (our future-work extension).
pub struct AblationAutotune;

impl Experiment for AblationAutotune {
    fn id(&self) -> &'static str {
        "ablation-autotune"
    }

    fn title(&self) -> &'static str {
        "Ablation: automatic RKAB block-size tuner vs bs = n"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        let m = scale.dim(8_000);
        let n = scale.dim(500);
        let q = 4usize;
        let sys = DatasetBuilder::new(m, n).seed(85).consistent();
        let model = CostModel::calibrate(&sys);

        let sw = Stopwatch::start();
        let (best, probes) = autotune_block_size(&sys, &model, &AutotuneConfig::new(q))
            .expect("default candidate set is never empty");
        let tune_cost = sw.seconds();

        let mut t = Table::new(
            format!("Tuner probes ({m} x {n}, q = {q}; probe cost {} wall)", fmt_seconds(tune_cost)),
            &["bs", "probe iters", "err^2 after probe", "modeled time", "score (decay/s)"],
        );
        for p in &probes {
            t.row(vec![
                p.block_size.to_string(),
                p.iterations.to_string(),
                format!("{:.2e}", p.metric_sq),
                fmt_seconds(p.modeled_seconds),
                format!("{:.1}", p.score),
            ]);
        }
        report.table(&t);

        // Full solves: tuned bs vs the rule of thumb.
        let opts = SolveOptions::default();
        let mut t = Table::new("Full solve to eps = 1e-8", &["bs", "iterations", "modeled time"]);
        for bs in [best, n] {
            let cal =
                calibrate_iterations(|s| RkabSolver::new(s, q, bs, 1.0), &sys, &opts, scale.seeds)
                    .expect("RKAB(a=1) converges on consistent systems");
            t.row(vec![
                format!("{bs}{}", if bs == best { " (tuned)" } else { " (= n)" }),
                cal.iterations().to_string(),
                fmt_seconds(cal.mean_iterations * model.rkab_iteration(q, bs)),
            ]);
        }
        report.table(&t);
        report.text(
            "**Shape check:** the tuner lands near the bs = n rule on full-matrix \
             sampling (validating the paper's heuristic) while remaining \
             applicable where the rule breaks (partitioned sampling, Fig. 9 / \
             §3.4.3).\n",
        );
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_ablation_averaging() {
        let md = AblationAveraging.run(Scale::smoke()).to_markdown();
        assert!(md.contains("critical"));
    }

    #[test]
    fn smoke_ablation_autotune() {
        let md = AblationAutotune.run(Scale::smoke()).to_markdown();
        assert!(md.contains("tuned"));
    }
}
