//! Fig. 6 — distributed-memory RKA under the two process/node configurations
//! (§3.3.2): fill whole 24-core nodes vs 2 processes per node.
//!
//! Paper workload: (a) 20000 x 2000, (b) 40000 x 4000; np in 1-48;
//! alpha = alpha*. Scaled: (a) 4000 x 400, (b) 8000 x 800.
//!
//! Times are simulated: measured per-rank compute x the LLC-contention
//! factor + alpha-beta Allreduce cost (distributed::network).

use crate::coordinator::experiments::process_counts;
use crate::coordinator::{Experiment, Scale};
use crate::data::DatasetBuilder;
use crate::distributed::{DistRka, Placement, SimCluster};
use crate::report::{fmt_seconds, fmt_speedup, Report, Table};
use crate::solvers::alpha::full_matrix_alpha;
use crate::solvers::SolveOptions;

/// Fig. 6 driver.
pub struct Fig06;

impl Experiment for Fig06 {
    fn id(&self) -> &'static str {
        "fig6"
    }

    fn title(&self) -> &'static str {
        "Fig 6: distributed RKA, 24-per-node vs 2-per-node"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        report.text(
            "Simulated cluster (DESIGN.md §3): ranks are threads with private \
             memory; Allreduce is real recursive doubling; times = max over ranks \
             of contention-adjusted compute + alpha-beta comm.\n",
        );

        for (panel, m0, n0) in [("(a) smaller system", 4_000usize, 400usize), ("(b) larger system", 8_000, 800)] {
            let m = scale.dim(m0);
            let n = scale.dim(n0);
            let sys = DatasetBuilder::new(m, n).seed(21).consistent();

            let mut t = Table::new(
                format!("Fig 6{panel}: {m} x {n}, simulated time and speedup vs np"),
                &["np", "t 24/node", "t 2/node", "speedup 24/node", "speedup 2/node"],
            );

            // Baseline: np = 1.
            let cluster1 = SimCluster::new(1, Placement::full_node());
            let (alpha1, _) = full_matrix_alpha(&sys, 1).expect("alpha");
            let base = DistRka::new(3, alpha1).solve(&sys, &SolveOptions::default(), &cluster1);
            // Re-time with fixed iterations (stopping test off the clock).
            let base_timed = DistRka::new(3, alpha1).solve(
                &sys,
                &SolveOptions::default().with_fixed_iterations(base.iterations),
                &cluster1,
            );
            let t1 = base_timed.sim_seconds;

            for &np in process_counts(scale).iter().filter(|&&np| np > 1) {
                let (alpha, _) = full_matrix_alpha(&sys, np).expect("alpha*");
                let mut times = Vec::new();
                for placement in [Placement::full_node(), Placement::two_per_node()] {
                    let cluster = SimCluster::new(np, placement);
                    // Calibrate iterations at tolerance, then timed run.
                    let cal = DistRka::new(3, alpha).solve(&sys, &SolveOptions::default(), &cluster);
                    let timed = DistRka::new(3, alpha).solve(
                        &sys,
                        &SolveOptions::default().with_fixed_iterations(cal.iterations.max(1)),
                        &cluster,
                    );
                    times.push(timed.sim_seconds);
                }
                t.row(vec![
                    np.to_string(),
                    fmt_seconds(times[0]),
                    fmt_seconds(times[1]),
                    fmt_speedup(t1 / times[0]),
                    fmt_speedup(t1 / times[1]),
                ]);
            }
            report.table(&t);
        }
        report.text(
            "**Shape check (paper Fig. 6):** for the smaller system packing a node \
             wins (cheap intra-node links); for the larger system 2-per-node \
             overtakes at higher np (cache contention dominates); 48 ranks are \
             slower than 24 under both configurations.\n",
        );
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs_both_panels() {
        let md = Fig06.run(Scale::smoke()).to_markdown();
        assert!(md.contains("Fig 6(a)"));
        assert!(md.contains("Fig 6(b)"));
    }
}
