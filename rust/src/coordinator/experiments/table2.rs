//! Table 2 — the paper's headline comparison: RKAB(alpha=1) vs RKA(alpha=1)
//! vs RKA(alpha*) vs the cost of *computing* alpha*, plus the sequential RK
//! reference (§3.4.2).
//!
//! Paper workload: 80000 x 10000, bs = n, threads 2-64; RK sequential time
//! 50 s; computing alpha* ~2500 s. Scaled workload: 8000 x 1000.

use crate::coordinator::experiments::thread_counts;
use crate::coordinator::{calibrate_iterations, CostModel, Experiment, Scale};
use crate::data::DatasetBuilder;
use crate::parallel::AveragingStrategy;
use crate::report::{fmt_seconds, Report, Table};
use crate::solvers::alpha::full_matrix_alpha;
use crate::solvers::rk::RkSolver;
use crate::solvers::rka::RkaSolver;
use crate::solvers::rkab::RkabSolver;
use crate::solvers::SolveOptions;

/// Table 2 driver.
pub struct Table2;

impl Experiment for Table2 {
    fn id(&self) -> &'static str {
        "table2"
    }

    fn title(&self) -> &'static str {
        "Table 2: RKAB vs RKA vs the cost of alpha*"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        let m = scale.dim(8_000);
        let n = scale.dim(1_000);
        report.text(format!(
            "Paper: 80000 x 10000, bs = n, RK sequential = 50 s, computing alpha* \
             ~2500 s. Scaled: {m} x {n}, bs = n = {n}.\n"
        ));
        let sys = DatasetBuilder::new(m, n).seed(51).consistent();
        let model = CostModel::calibrate(&sys);
        let opts = SolveOptions::default();

        // Sequential RK reference. RK/RKA(a<=a*)/RKAB(a=1) on a consistent
        // system converge for every seed, so calibration cannot fail here.
        let rk = calibrate_iterations(RkSolver::new, &sys, &opts, scale.seeds)
            .expect("RK converges on consistent systems");
        let rk_time = rk.mean_iterations * model.rk_iteration();
        report.text(format!(
            "Sequential RK: {} iterations, modeled time {}.\n",
            rk.iterations(),
            fmt_seconds(rk_time)
        ));

        let mut t = Table::new(
            format!("Execution times, {m} x {n} (bs = n for RKAB)"),
            &["Threads", "RKAB (a=1)", "RKA (a=1)", "RKA (a=a*)", "Computing a*"],
        );
        let qs: Vec<usize> = thread_counts().into_iter().filter(|&q| q > 1).collect();
        for q in qs {
            let rkab = calibrate_iterations(
                |s| RkabSolver::new(s, q, n, 1.0),
                &sys,
                &opts,
                scale.seeds,
            )
            .expect("RKAB(a=1) converges on consistent systems");
            let rkab_time = rkab.mean_iterations * model.rkab_iteration(q, n);

            let rka1 = calibrate_iterations(|s| RkaSolver::new(s, q, 1.0), &sys, &opts, scale.seeds)
                .expect("RKA(a=1) converges on consistent systems");
            let rka1_time =
                rka1.mean_iterations * model.rka_iteration(q, AveragingStrategy::Critical);

            let (astar, alpha_cost) = full_matrix_alpha(&sys, q).expect("alpha*");
            let rkao =
                calibrate_iterations(|s| RkaSolver::new(s, q, astar), &sys, &opts, scale.seeds)
                    .expect("RKA(a*) converges on consistent systems");
            let rkao_time =
                rkao.mean_iterations * model.rka_iteration(q, AveragingStrategy::Critical);

            t.row(vec![
                q.to_string(),
                fmt_seconds(rkab_time),
                fmt_seconds(rka1_time),
                fmt_seconds(rkao_time),
                fmt_seconds(alpha_cost),
            ]);
        }
        report.table(&t);
        report.text(
            "**Shape check (paper Table 2):** RKAB(a=1) always beats RKA(a=1); \
             RKA(a*) catches RKAB only at mid thread counts — and once the \
             'Computing a*' column is charged, RKAB(a=1) is the practical choice. \
             Neither parallel method consistently beats sequential RK.\n",
        );
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_emits_all_columns() {
        let md = Table2.run(Scale::smoke()).to_markdown();
        assert!(md.contains("RKAB (a=1)"));
        assert!(md.contains("Computing a*"));
        assert!(md.contains("Sequential RK"));
    }
}
