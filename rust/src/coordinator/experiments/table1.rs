//! Table 1 — iterations under {full, partial}-matrix alpha x {full-matrix,
//! distributed} row sampling (§3.3.1).
//!
//! Paper workload: 40000 x 10000, threads 2-16, alpha = alpha*.
//! Scaled workload: 4000 x 1000 by default.

use crate::coordinator::{calibrate_iterations, Experiment, Scale};
use crate::data::DatasetBuilder;
use crate::report::{Report, Table};
use crate::solvers::alpha::{full_matrix_alpha, partial_matrix_alphas};
use crate::solvers::rka::{RkaSolver, Weights};
use crate::solvers::sampling::SamplingScheme;
use crate::solvers::SolveOptions;

/// Table 1 driver.
pub struct Table1;

impl Experiment for Table1 {
    fn id(&self) -> &'static str {
        "table1"
    }

    fn title(&self) -> &'static str {
        "Table 1: sampling scheme x alpha source (RKA iterations)"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        let m = scale.dim(4_000);
        let n = scale.dim(1_000);
        report.text(format!(
            "Paper: 40000 x 10000. Scaled here: {m} x {n}. Cells are mean \
             iterations to eps = 1e-8; parentheses = difference vs column 2 \
             (Full alpha / Full access), matching the paper's layout.\n"
        ));

        let sys = DatasetBuilder::new(m, n).seed(13).consistent();
        let opts = SolveOptions::default();
        let mut t = Table::new(
            format!("RKA iterations, {m} x {n}"),
            &[
                "Threads",
                "Full a / Full access",
                "Full a / Distributed",
                "Partial a / Full access",
                "Partial a / Distributed",
            ],
        );

        for q in [2usize, 4, 8, 16] {
            let (alpha_full, _) = full_matrix_alpha(&sys, q).expect("alpha*");
            let (alphas_part, _) = partial_matrix_alphas(&sys, q).expect("partial alpha");
            let cell = |weights: Weights, scheme: SamplingScheme| {
                calibrate_iterations(
                    |s| RkaSolver::new(s, q, 1.0).with_weights(weights.clone()).with_scheme(scheme),
                    &sys,
                    &opts,
                    scale.seeds,
                )
                .expect("RKA at alpha* converges on consistent systems")
                .iterations() as i64
            };
            let base = cell(Weights::Uniform(alpha_full), SamplingScheme::FullMatrix);
            let fd = cell(Weights::Uniform(alpha_full), SamplingScheme::Partitioned);
            let pf = cell(Weights::PerWorker(alphas_part.clone()), SamplingScheme::FullMatrix);
            let pd = cell(Weights::PerWorker(alphas_part), SamplingScheme::Partitioned);
            t.row(vec![
                q.to_string(),
                base.to_string(),
                format!("{fd} ({:+})", fd - base),
                format!("{pf} ({:+})", pf - base),
                format!("{pd} ({:+})", pd - base),
            ]);
        }
        report.table(&t);
        report.text(
            "**Shape check (paper Table 1):** partial-matrix alpha changes the \
             count by well under 1%; the sampling scheme shifts it slightly either \
             way, with the distributed approach mildly better at low q.\n",
        );
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_produces_four_scenarios() {
        let md = Table1.run(Scale::smoke()).to_markdown();
        assert!(md.contains("Full a / Full access"));
        assert!(md.contains("Partial a / Distributed"));
    }
}
