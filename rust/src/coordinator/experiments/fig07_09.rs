//! Figs. 7, 8 and 9 — RKAB block-size study (§3.4.2).
//!
//! - Fig. 7: iterations / total rows / time vs block size, 80000 x 1000
//!   (scaled 8000 x 250), threads 1-64, alpha = 1. The paper's rule of
//!   thumb emerges: time flattens until bs ≈ n and rises past it.
//! - Fig. 8: total time for wider systems (n = 4000, 10000 scaled) plus the
//!   sequential RK reference line.
//! - Fig. 9: Full Matrix Access vs Distributed Approach sampling for a
//!   40000 x 10000 (scaled) system — distributed sampling degrades for
//!   large bs because per-worker partitions run out of fresh rows.

use crate::coordinator::{calibrate_iterations, CostModel, Experiment, Scale};
use crate::data::DatasetBuilder;
use crate::report::{fmt_seconds, Report, Table};
use crate::solvers::rk::RkSolver;
use crate::solvers::rkab::RkabSolver;
use crate::solvers::sampling::SamplingScheme;
use crate::solvers::SolveOptions;

fn block_sizes(n: usize) -> Vec<usize> {
    // The paper's {5, 10, 100, 500, 1000, 2000, 4000, 10000} pattern,
    // expressed relative to n: a couple of tiny blocks, fractions of n, n,
    // and multiples of n.
    vec![5, 10, n / 10, n / 2, n, 2 * n, 4 * n]
        .into_iter()
        .filter(|&b| b >= 1)
        .collect()
}

fn qs(scale: Scale) -> Vec<usize> {
    if scale.factor < 0.5 {
        vec![1, 2, 4]
    } else {
        vec![1, 2, 4, 8, 16, 64]
    }
}

/// Fig. 7 driver.
pub struct Fig07;

impl Experiment for Fig07 {
    fn id(&self) -> &'static str {
        "fig7"
    }

    fn title(&self) -> &'static str {
        "Fig 7: RKAB iterations / total rows / time vs block size"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        let m = scale.dim(8_000);
        let n = scale.dim(250);
        report.text(format!(
            "Paper: 80000 x 1000, threads 1-64, alpha = 1. Scaled: {m} x {n}.\n"
        ));
        let sys = DatasetBuilder::new(m, n).seed(31).consistent();
        let model = CostModel::calibrate(&sys);
        let opts = SolveOptions::default();

        let headers: Vec<String> = std::iter::once("bs".to_string())
            .chain(qs(scale).iter().map(|q| format!("q={q}")))
            .collect();
        let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
        let mut iters_t = Table::new("Fig 7a: iterations", &hdr_refs);
        let mut rows_t = Table::new("Fig 7b: total rows used", &hdr_refs);
        let mut time_t = Table::new("Fig 7c: modeled time", &hdr_refs);

        for bs in block_sizes(n) {
            let mut ic = vec![bs.to_string()];
            let mut rc = vec![bs.to_string()];
            let mut tc = vec![bs.to_string()];
            for &q in &qs(scale) {
                let cal = calibrate_iterations(
                    |s| RkabSolver::new(s, q, bs, 1.0),
                    &sys,
                    &opts,
                    scale.seeds,
                )
                .expect("RKAB(a=1) converges on consistent systems");
                ic.push(cal.iterations().to_string());
                rc.push(format!("{:.0}", cal.mean_rows_used));
                tc.push(fmt_seconds(cal.mean_iterations * model.rkab_iteration(q, bs)));
            }
            iters_t.row(ic);
            rows_t.row(rc);
            time_t.row(tc);
        }
        report.table(&iters_t);
        report.table(&rows_t);
        report.table(&time_t);
        report.text(format!(
            "**Shape check (paper Fig. 7):** iterations fall with bs; total rows \
             stay ~flat until bs = n = {n} then grow; time falls with bs and \
             rises again past bs > n — the bs = n rule of thumb.\n"
        ));
        report
    }
}

/// Fig. 8 driver.
pub struct Fig08;

impl Experiment for Fig08 {
    fn id(&self) -> &'static str {
        "fig8"
    }

    fn title(&self) -> &'static str {
        "Fig 8: RKAB total time for wider systems (+ sequential RK line)"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        // Wider systems are expensive (rows_used = iters*q*bs with bs ~ n),
        // so this figure trims the grid: q <= 8, bs in {n/10, n/2, n, 2n},
        // and 2 calibration seeds.
        let seeds = scale.seeds.min(2);
        let fig8_qs = [1usize, 2, 4, 8];
        for n0 in [1_000usize, 2_000] {
            let m = scale.dim(8_000);
            let n = scale.dim(n0);
            let sys = DatasetBuilder::new(m, n).seed(33).consistent();
            let model = CostModel::calibrate(&sys);
            let opts = SolveOptions::default();
            let rk = calibrate_iterations(RkSolver::new, &sys, &opts, seeds)
                .expect("RK converges on consistent systems");
            let rk_time = rk.mean_iterations * model.rk_iteration();

            let headers: Vec<String> = std::iter::once("bs".to_string())
                .chain(fig8_qs.iter().map(|q| format!("q={q}")))
                .collect();
            let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
            let mut t = Table::new(
                format!("{m} x {n}: modeled time (sequential RK = {})", fmt_seconds(rk_time)),
                &hdr_refs,
            );
            for bs in [n / 10, n / 2, n, 2 * n] {
                let bs = bs.max(1);
                let mut tc = vec![bs.to_string()];
                for &q in &fig8_qs {
                    let cal = calibrate_iterations(
                        |s| RkabSolver::new(s, q, bs, 1.0),
                        &sys,
                        &opts,
                        seeds,
                    )
                    .expect("RKAB(a=1) converges on consistent systems");
                    tc.push(fmt_seconds(cal.mean_iterations * model.rkab_iteration(q, bs)));
                }
                t.row(tc);
            }
            report.table(&t);
        }
        report.text(
            "**Shape check (paper Fig. 8):** the time penalty past bs = n shrinks \
             as n grows; RKAB rarely beats sequential RK, and when it does the \
             margin is small.\n",
        );
        report
    }
}

/// Fig. 9 driver.
pub struct Fig09;

impl Experiment for Fig09 {
    fn id(&self) -> &'static str {
        "fig9"
    }

    fn title(&self) -> &'static str {
        "Fig 9: RKAB Full Matrix Access vs Distributed Approach sampling"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        let m = scale.dim(4_000);
        let n = scale.dim(1_000);
        report.text(format!("Paper: 40000 x 10000. Scaled: {m} x {n}.\n"));
        let sys = DatasetBuilder::new(m, n).seed(35).consistent();
        let model = CostModel::calibrate(&sys);
        let opts = SolveOptions::default();
        let q = 4usize;

        let mut t = Table::new(
            format!("q = {q}: iterations / rows / modeled time per scheme"),
            &["bs", "iters full", "iters dist", "rows full", "rows dist", "t full", "t dist"],
        );
        for bs in block_sizes(n) {
            let full = calibrate_iterations(
                |s| RkabSolver::new(s, q, bs, 1.0).with_scheme(SamplingScheme::FullMatrix),
                &sys,
                &opts,
                scale.seeds,
            )
            .expect("RKAB(a=1) converges on consistent systems");
            let dist = calibrate_iterations(
                |s| RkabSolver::new(s, q, bs, 1.0).with_scheme(SamplingScheme::Partitioned),
                &sys,
                &opts,
                scale.seeds,
            )
            .expect("RKAB(a=1) converges on consistent systems");
            t.row(vec![
                bs.to_string(),
                full.iterations().to_string(),
                dist.iterations().to_string(),
                format!("{:.0}", full.mean_rows_used),
                format!("{:.0}", dist.mean_rows_used),
                fmt_seconds(full.mean_iterations * model.rkab_iteration(q, bs)),
                fmt_seconds(dist.mean_iterations * model.rkab_iteration(q, bs)),
            ]);
        }
        report.table(&t);
        report.text(
            "**Shape check (paper Fig. 9):** the distributed approach needs more \
             iterations at large bs (each worker's partition has only m/q rows of \
             information), so its time curve turns up earlier — the bs = n rule \
             does not transfer to partitioned sampling.\n",
        );
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_fig7_has_three_tables() {
        let md = Fig07.run(Scale::smoke()).to_markdown();
        assert!(md.contains("Fig 7a"));
        assert!(md.contains("Fig 7b"));
        assert!(md.contains("Fig 7c"));
    }

    #[test]
    fn smoke_fig9_compares_schemes() {
        let md = Fig09.run(Scale::smoke()).to_markdown();
        assert!(md.contains("iters dist"));
    }
}
