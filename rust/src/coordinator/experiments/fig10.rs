//! Fig. 10 — RKAB iterations as a function of alpha, for several block
//! sizes; divergence region included (§3.4.2).
//!
//! Paper workload: 80000 x 1000 (scaled 8000 x 250), q in {2, 4}, alpha
//! swept from 1 to the RKA alpha* for that q. The paper's findings: alpha*
//! is NOT optimal for RKAB; the optimal alpha shrinks as bs grows; for q = 4
//! large alpha with large bs diverges (cells marked "div").

use crate::coordinator::{calibrate_iterations, Experiment, Scale};
use crate::data::DatasetBuilder;
use crate::report::{Report, Table};
use crate::solvers::alpha::full_matrix_alpha;
use crate::solvers::rkab::RkabSolver;
use crate::solvers::SolveOptions;

/// Fig. 10 driver.
pub struct Fig10;

impl Experiment for Fig10 {
    fn id(&self) -> &'static str {
        "fig10"
    }

    fn title(&self) -> &'static str {
        "Fig 10: RKAB iterations vs alpha (divergence region)"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        let m = scale.dim(8_000);
        let n = scale.dim(250);
        report.text(format!("Paper: 80000 x 1000, q in {{2, 4}}. Scaled: {m} x {n}.\n"));
        let sys = DatasetBuilder::new(m, n).seed(41).consistent();
        let opts = SolveOptions {
            divergence_factor: 1e6,
            max_iterations: 30_000_000,
            ..Default::default()
        };
        let block_sizes: Vec<usize> = vec![5, n / 5, n / 2, n].into_iter().filter(|&b| b >= 1).collect();

        for q in [2usize, 4] {
            let (astar, _) = full_matrix_alpha(&sys, q).expect("alpha*");
            // Evenly spaced test alphas in [1, alpha*], like the paper's
            // {1.0, 1.2, ..., 1.999} for q = 2.
            let alphas: Vec<f64> = (0..6).map(|i| 1.0 + (astar - 1.0) * i as f64 / 5.0).collect();

            let headers: Vec<String> = std::iter::once("alpha".into())
                .chain(block_sizes.iter().map(|b| format!("bs={b}")))
                .collect();
            let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
            let mut t =
                Table::new(format!("q = {q} (alpha* = {astar:.3}): iterations"), &hdr_refs);
            for &alpha in &alphas {
                let mut cells = vec![format!("{alpha:.3}")];
                for &bs in &block_sizes {
                    // The divergence corner is the point of this figure: an
                    // all-divergent calibration is a "div" cell, not a
                    // crash (and no longer a silent zero-iteration budget).
                    let cell = match calibrate_iterations(
                        |s| RkabSolver::new(s, q, bs, alpha),
                        &sys,
                        &opts,
                        scale.seeds,
                    ) {
                        Ok(cal) => cal.iterations().to_string(),
                        Err(_) => "div".to_string(),
                    };
                    cells.push(cell);
                }
                t.row(cells);
            }
            report.table(&t);
        }
        report.text(
            "**Shape check (paper Fig. 10):** the best alpha for RKAB is below \
             alpha* and decreases as bs grows; for q = 4 the large-alpha / \
             large-bs corner diverges ('div' cells).\n",
        );
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_sweeps_alpha() {
        let md = Fig10.run(Scale::smoke()).to_markdown();
        assert!(md.contains("alpha*"));
        assert!(md.contains("q = 2"));
    }
}
