//! Fig. 1 — CK vs RK row-selection on a highly coherent consistent system.
//!
//! Paper: a 2-D geometric illustration; cyclic selection crawls between
//! nearly-parallel hyperplanes, randomized selection hops. We reproduce it
//! quantitatively: error trajectories of both methods on a coherent system
//! plus the iterations-to-tolerance ratio.

use crate::coordinator::{Experiment, Scale};
use crate::data::coherent_system;
use crate::report::{Report, Table};
use crate::solvers::ck::CkSolver;
use crate::solvers::rk::RkSolver;
use crate::solvers::{SolveOptions, Solver};

/// Fig. 1 driver.
pub struct Fig01;

impl Experiment for Fig01 {
    fn id(&self) -> &'static str {
        "fig1"
    }

    fn title(&self) -> &'static str {
        "Fig 1: CK vs RK on a coherent system"
    }

    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        report.text(
            "Consecutive rows subtend a small angle (coherent matrix); the paper's \
             geometric picture predicts CK crawls while RK converges quickly.\n",
        );

        let m = scale.dim(400);
        let sys = coherent_system(m, 2, 0.002, 11);
        let opts = SolveOptions::default()
            .with_tolerance(1e-6)
            .with_max_iterations(20_000_000)
            .with_history_step(if scale.factor < 0.5 { 50 } else { 500 });

        let ck = CkSolver::new().solve(&sys, &opts);
        let rk = RkSolver::new(7).solve(&sys, &opts);

        let mut t = Table::new(
            format!("Error trajectories ({m} x 2 coherent system)"),
            &["iteration", "CK error", "RK error"],
        );
        let len = ck.history.len().max(rk.history.len());
        for i in (0..len).step_by((len / 20).max(1)) {
            let fmt = |h: &crate::metrics::History| {
                h.errors.get(i).map(|e| format!("{e:.3e}")).unwrap_or_else(|| "converged".into())
            };
            t.row(vec![
                ck.history.iterations.get(i).or(rk.history.iterations.get(i)).copied().unwrap_or(0).to_string(),
                fmt(&ck.history),
                fmt(&rk.history),
            ]);
        }
        report.table(&t);

        let mut s = Table::new("Iterations to ||x-x*||^2 < 1e-6", &["method", "iterations", "converged"]);
        s.row(vec!["CK".into(), ck.iterations.to_string(), ck.converged.to_string()]);
        s.row(vec!["RK".into(), rk.iterations.to_string(), rk.converged.to_string()]);
        report.table(&s);
        report.text(format!(
            "**Shape check (paper Fig. 1):** RK needs {}x fewer iterations than CK.\n",
            if rk.iterations > 0 { ck.iterations / rk.iterations.max(1) } else { 0 }
        ));
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs_and_shows_rk_advantage() {
        let r = Fig01.run(Scale::smoke());
        let md = r.to_markdown();
        assert!(md.contains("CK"));
        assert!(md.contains("Shape check"));
    }
}
