//! Figs. 4 and 5 — shared-memory RKA: iterations and speedup vs rows and
//! thread count, for alpha = 1 (Fig. 4) and alpha = alpha* (Fig. 5).
//!
//! Paper workload: n = 4000, m in {20000 ... 160000}, threads 2-64.
//! Scaled workload: n = 500, m in {2500, 5000, 10000} by default.
//!
//! Protocol per (m, q): calibrate iterations over seeds with the sequential-
//! semantics RKA (bit-exact with the threaded engine), then time =
//! iterations x CostModel::rka_iteration(q, Critical). The RK baseline is
//! timed as iterations_RK x t_proj.

use crate::coordinator::experiments::thread_counts;
use crate::coordinator::{calibrate_iterations, CostModel, Experiment, Scale};
use crate::data::DatasetBuilder;
use crate::parallel::AveragingStrategy;
use crate::report::{fmt_speedup, Report, Table};
use crate::solvers::alpha::full_matrix_alpha;
use crate::solvers::rk::RkSolver;
use crate::solvers::rka::RkaSolver;
use crate::solvers::SolveOptions;

fn run_panel(scale: Scale, optimal: bool) -> Report {
    let mut report = Report::new();
    let which = if optimal { "alpha = alpha* (Fig 5)" } else { "alpha = 1 (Fig 4)" };
    report.text(format!("# Shared-memory RKA, {which}\n"));
    report.text(
        "Paper workload: n = 4000, m in 20000-160000, threads 2-64. Iteration \
         counts from real runs (sequential-semantics RKA, bit-exact with the \
         threaded engine); times composed via the calibrated cost model.\n",
    );

    let n = scale.dim(500);
    let ms: Vec<usize> = [2_500usize, 5_000, 10_000].iter().map(|&m| scale.dim(m)).collect();
    let opts = SolveOptions::default();
    let qs = thread_counts();

    let mut iters_table = Table::new(
        format!("Iterations vs m (n = {n})"),
        &["m", "RK (q=1)", "q=2", "q=4", "q=8", "q=16", "q=64"],
    );
    let mut speedup_table = Table::new(
        "Speedup vs RK (modeled wall time)",
        &["m", "q=2", "q=4", "q=8", "q=16", "q=64"],
    );

    for &m in &ms {
        let sys = DatasetBuilder::new(m, n).seed(7).consistent();
        let model = CostModel::calibrate(&sys);
        let rk_cal = calibrate_iterations(RkSolver::new, &sys, &opts, scale.seeds)
            .expect("RK converges on consistent systems");
        let rk_time = rk_cal.mean_iterations * model.rk_iteration();

        let mut iter_cells = vec![m.to_string(), rk_cal.iterations().to_string()];
        let mut speed_cells = vec![m.to_string()];
        for &q in &qs[1..] {
            let alpha = if optimal { full_matrix_alpha(&sys, q).expect("alpha*").0 } else { 1.0 };
            let cal = calibrate_iterations(
                |s| RkaSolver::new(s, q, alpha),
                &sys,
                &opts,
                scale.seeds,
            )
            .expect("RKA at alpha <= alpha* converges on consistent systems");
            let time = cal.mean_iterations * model.rka_iteration(q, AveragingStrategy::Critical);
            iter_cells.push(cal.iterations().to_string());
            speed_cells.push(fmt_speedup(rk_time / time));
        }
        iters_table.row(iter_cells);
        speedup_table.row(speed_cells);
    }
    report.table(&iters_table);
    report.table(&speedup_table);
    report.text(if optimal {
        "**Shape check (paper Fig. 5):** with alpha*, iterations drop roughly \
         proportionally to q (except 64); speedups improve from 2 to 16 threads \
         then fall at 64 — and the cost of computing alpha* is NOT included here \
         (Table 2 charges it).\n"
    } else {
        "**Shape check (paper Fig. 4):** RKA needs fewer iterations than RK with \
         diminishing returns in q, but the sequential averaging makes it *slower* \
         than RK at every thread count, worsening as q grows.\n"
    });
    report
}

/// Fig. 4 driver (alpha = 1).
pub struct Fig04;

impl Experiment for Fig04 {
    fn id(&self) -> &'static str {
        "fig4"
    }
    fn title(&self) -> &'static str {
        "Fig 4: shared-memory RKA, alpha = 1"
    }
    fn run(&self, scale: Scale) -> Report {
        run_panel(scale, false)
    }
}

/// Fig. 5 driver (alpha = alpha*).
pub struct Fig05;

impl Experiment for Fig05 {
    fn id(&self) -> &'static str {
        "fig5"
    }
    fn title(&self) -> &'static str {
        "Fig 5: shared-memory RKA, alpha = alpha*"
    }
    fn run(&self, scale: Scale) -> Report {
        run_panel(scale, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_fig4() {
        let md = Fig04.run(Scale::smoke()).to_markdown();
        assert!(md.contains("Iterations vs m"));
        assert!(md.contains("Speedup vs RK"));
    }
}
