//! Per-figure / per-table experiment drivers (paper order).
//!
//! Every driver states the paper's original workload, the container-scaled
//! workload actually run (DESIGN.md §3), and emits the same rows/series the
//! paper's figure shows. EXPERIMENTS.md records paper-vs-measured for each.

pub mod ablations;
pub mod fig01;
pub mod fig02;
pub mod fig04_05;
pub mod fig06;
pub mod fig07_09;
pub mod fig10;
pub mod fig11;
pub mod fig12_14;
pub mod table1;
pub mod table2;

use crate::coordinator::Scale;

/// Thread counts used by the shared-memory figures (the paper's 1-64).
pub fn thread_counts() -> Vec<usize> {
    vec![1, 2, 4, 8, 16, 64]
}

/// Process counts used by the distributed figures (the paper's 1-48).
pub fn process_counts(scale: Scale) -> Vec<usize> {
    if scale.factor < 0.5 {
        vec![1, 2, 4, 8]
    } else {
        vec![1, 2, 4, 8, 12, 24, 48]
    }
}
