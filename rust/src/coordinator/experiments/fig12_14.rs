//! Figs. 12, 13, 14 — inconsistent systems: the convergence horizon (§3.5).
//!
//! Paper workload: inconsistent 80000 x 1000 (scaled 8000 x 250), error
//! `||x - x_LS||` and residual `||Ax - b||` stored every `step` iterations,
//! q in {1, 2, 5, 10, 20, 50}:
//!
//! - Fig. 12: RKA, alpha = 1 — larger q lowers the error plateau;
//! - Fig. 13: RKA, alpha = alpha* — stabilizes *faster* but the plateau is
//!   not uniformly lower (only the largest q helps);
//! - Fig. 14: RKAB, bs = n, alpha = 1 — same horizon effect as RKA with far
//!   fewer (but heavier) iterations.
//!
//! The `zoo` experiment extends the panel with a head-to-head on the same
//! workload: plain RK and RKA stall at the convergence horizon, weighted
//! RKA shifts it, and REK (which also iterates on the right-hand side)
//! passes below it toward x_LS.

use crate::coordinator::{Experiment, Scale};
use crate::data::DatasetBuilder;
use crate::metrics::History;
use crate::report::{Report, Table};
use crate::solvers::alpha::full_matrix_alpha;
use crate::solvers::cgls::attach_least_squares;
use crate::solvers::rek::RekSolver;
use crate::solvers::rk::RkSolver;
use crate::solvers::rka::{RkaSolver, Weights};
use crate::solvers::rkab::RkabSolver;
use crate::solvers::{SolveOptions, Solver};

const QS: [usize; 6] = [1, 2, 5, 10, 20, 50];

fn horizon_panel(
    which: &str,
    scale: Scale,
    runner: impl Fn(&crate::data::LinearSystem, usize) -> History,
) -> Report {
    let mut report = Report::new();
    report.text(format!("# {which}\n"));
    let m = scale.dim(8_000);
    let n = scale.dim(250);
    report.text(format!(
        "Paper: inconsistent 80000 x 1000 (b = b_cons + N(0,1) noise), x_LS via \
         CGLS. Scaled: {m} x {n}.\n"
    ));
    let mut sys = DatasetBuilder::new(m, n).seed(71).inconsistent();
    attach_least_squares(&mut sys, 1e-12, 50_000).expect("CGLS");

    let histories: Vec<(usize, History)> = QS
        .iter()
        .map(|&q| (q, runner(&sys, q)))
        .collect();

    let headers: Vec<String> = std::iter::once("iteration".into())
        .chain(QS.iter().map(|q| format!("q={q}")))
        .collect();
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();

    for (title, pick) in [
        ("Error ||x - x_LS||", 0usize),
        ("Residual ||Ax - b|| (LS residual marked below)", 1),
    ] {
        let mut t = Table::new(title, &hdr_refs);
        let len = histories[0].1.len();
        for i in (0..len).step_by((len / 15).max(1)) {
            let mut cells = vec![histories[0].1.iterations[i].to_string()];
            for (_, h) in &histories {
                let v = if pick == 0 { h.errors[i] } else { h.residuals[i] };
                cells.push(format!("{v:.4e}"));
            }
            t.row(cells);
        }
        report.table(&t);
    }

    let ls_resid = sys.residual_norm(sys.x_ls.as_ref().unwrap());
    let mut t = Table::new("Stabilized horizon (mean of last 5 samples)", &hdr_refs);
    let mut err_cells = vec!["error tail".to_string()];
    let mut res_cells = vec!["residual tail".to_string()];
    for (_, h) in &histories {
        err_cells.push(format!("{:.4e}", h.tail_error(5).unwrap_or(f64::NAN)));
        let tail_res = h.residuals[h.residuals.len().saturating_sub(5)..]
            .iter()
            .sum::<f64>()
            / 5.0;
        res_cells.push(format!("{tail_res:.4e}"));
    }
    t.row(err_cells);
    t.row(res_cells);
    report.table(&t);
    report.text(format!("Least-squares residual ||A x_LS - b|| = {ls_resid:.4e}.\n"));
    report
}

/// Fig. 12 driver (RKA, alpha = 1).
pub struct Fig12;

impl Experiment for Fig12 {
    fn id(&self) -> &'static str {
        "fig12"
    }
    fn title(&self) -> &'static str {
        "Fig 12: RKA (alpha=1) convergence horizon on inconsistent systems"
    }
    fn run(&self, scale: Scale) -> Report {
        let iters = if scale.factor < 0.5 { 6_000 } else { 30_000 };
        let mut r = horizon_panel(self.title(), scale, |sys, q| {
            let opts = SolveOptions::default()
                .with_fixed_iterations(iters)
                .with_history_step(iters / 60);
            RkaSolver::new(2, q, 1.0).solve(sys, &opts).history
        });
        r.text(
            "**Shape check (paper Fig. 12):** the error plateau decreases \
             monotonically with q; for large q the residual approaches the LS \
             residual (without the error reaching zero).\n",
        );
        r
    }
}

/// Fig. 13 driver (RKA, alpha = alpha*).
pub struct Fig13;

impl Experiment for Fig13 {
    fn id(&self) -> &'static str {
        "fig13"
    }
    fn title(&self) -> &'static str {
        "Fig 13: RKA (alpha=alpha*) convergence horizon"
    }
    fn run(&self, scale: Scale) -> Report {
        let iters = if scale.factor < 0.5 { 6_000 } else { 30_000 };
        let mut r = horizon_panel(self.title(), scale, |sys, q| {
            let (astar, _) = full_matrix_alpha(sys, q).expect("alpha*");
            let opts = SolveOptions::default()
                .with_fixed_iterations(iters)
                .with_history_step(iters / 60);
            RkaSolver::new(2, q, astar).solve(sys, &opts).history
        });
        r.text(
            "**Shape check (paper Fig. 13):** with alpha* the curves stabilize in \
             fewer iterations than alpha = 1, but only the largest q lowers the \
             plateau — alpha* (a consistent-system optimum) can *raise* the \
             horizon for small q.\n",
        );
        r
    }
}

/// Fig. 14 driver (RKAB, bs = n, alpha = 1).
pub struct Fig14;

impl Experiment for Fig14 {
    fn id(&self) -> &'static str {
        "fig14"
    }
    fn title(&self) -> &'static str {
        "Fig 14: RKAB (alpha=1, bs=n) convergence horizon"
    }
    fn run(&self, scale: Scale) -> Report {
        let mut r = horizon_panel(self.title(), scale, |sys, q| {
            let n = sys.cols();
            // The paper shows the first 30 iterations, step = 1 — each RKAB
            // iteration does q*n row updates.
            let opts = SolveOptions::default().with_fixed_iterations(60).with_history_step(1);
            RkabSolver::new(2, q, n, 1.0).solve(sys, &opts).history
        });
        r.text(
            "**Shape check (paper Fig. 14):** same horizon-vs-q relationship as \
             Fig. 12 but reached in ~30 heavy iterations instead of ~30000 light \
             ones — RKAB matches RKA's horizon reduction at equal row weights.\n",
        );
        r
    }
}

/// Solver-zoo head-to-head on the Figs. 12-14 workload.
pub struct SolverZoo;

impl Experiment for SolverZoo {
    fn id(&self) -> &'static str {
        "zoo"
    }
    fn title(&self) -> &'static str {
        "Solver zoo: RK vs RKA vs weighted RKA vs REK on an inconsistent system"
    }
    fn run(&self, scale: Scale) -> Report {
        let mut report = Report::new();
        report.text(format!("# {}\n", self.title()));
        let m = scale.dim(8_000);
        let n = scale.dim(250);
        report.text(format!(
            "Same workload as Figs. 12-14 (inconsistent, x_LS via CGLS), scaled \
             {m} x {n}. Every solver gets the same row budget; REK additionally \
             spends one column pass per iteration (noted, not charged as rows).\n"
        ));
        let mut sys = DatasetBuilder::new(m, n).seed(71).inconsistent();
        attach_least_squares(&mut sys, 1e-12, 50_000).expect("CGLS");

        let rows = if scale.factor < 0.5 { 6_000 } else { 30_000 };
        let q = 10usize;
        let runs: Vec<(&str, crate::solvers::SolveResult)> = vec![
            (
                "RK",
                RkSolver::new(2).solve(&sys, &SolveOptions::default().with_fixed_iterations(rows)),
            ),
            (
                "RKA q=10 (uniform)",
                RkaSolver::new(2, q, 1.0)
                    .solve(&sys, &SolveOptions::default().with_fixed_iterations(rows / q)),
            ),
            (
                "RKA q=10 (1/||a_i||^2 weights)",
                RkaSolver::new(2, q, 1.0)
                    .with_weights(Weights::InverseRowNorm(1.0))
                    .solve(&sys, &SolveOptions::default().with_fixed_iterations(rows / q)),
            ),
            (
                "REK",
                RekSolver::new(2)
                    .solve(&sys, &SolveOptions::default().with_fixed_iterations(rows)),
            ),
        ];

        let mut t = Table::new(
            "Head-to-head at equal row budget",
            &["solver", "rows used", "||x - x_LS||", "||Ax - b||"],
        );
        for (name, r) in &runs {
            t.row(vec![
                name.to_string(),
                r.rows_used.to_string(),
                format!("{:.4e}", sys.error_sq(&r.x).sqrt()),
                format!("{:.4e}", sys.residual_norm(&r.x)),
            ]);
        }
        report.table(&t);
        let ls_resid = sys.residual_norm(sys.x_ls.as_ref().unwrap());
        report.text(format!("Least-squares residual ||A x_LS - b|| = {ls_resid:.4e}.\n"));
        report.text(
            "**Shape check (Zouzias-Freris REK):** RK and both RKA variants stall \
             at the convergence horizon ||x - x_LS|| > 0, while REK's error keeps \
             contracting toward x_LS; every solver's residual is floored at the LS \
             residual, so the separation is visible only in the error column.\n",
        );
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_fig12_shows_horizon_ordering() {
        let md = Fig12.run(Scale::smoke()).to_markdown();
        assert!(md.contains("Stabilized horizon"));
        assert!(md.contains("q=50"));
    }

    #[test]
    fn smoke_fig14_runs() {
        let md = Fig14.run(Scale::smoke()).to_markdown();
        assert!(md.contains("Least-squares residual"));
    }

    #[test]
    fn smoke_zoo_reports_all_solvers() {
        let md = SolverZoo.run(Scale::smoke()).to_markdown();
        assert!(md.contains("REK"));
        assert!(md.contains("1/||a_i||^2 weights"));
        assert!(md.contains("Head-to-head"));
    }
}
