//! Shared-memory cost model: composes experiment timings from *measured*
//! primitive costs on this machine plus modeled synchronization costs.
//!
//! Why a model at all: this container exposes a single core (DESIGN.md §3),
//! so the multi-threaded implementations — whose *semantics* are validated
//! exactly against the sequential references — cannot demonstrate wall-clock
//! scaling here. The paper's own analysis of Algorithms 1/3 decomposes each
//! iteration into (a) the per-row projection each thread does independently,
//! (b) the gather of results (sequential under the critical section), and
//! (c) barrier crossings. We measure (a) and (b) directly (they are
//! single-threaded operations) and model (c) plus cache-coherence
//! amplification with documented constants.

use crate::data::LinearSystem;
use crate::metrics::Stopwatch;
use crate::parallel::shared::AtomicF64Vec;
use crate::parallel::AveragingStrategy;
use crate::solvers::rk::RkSolver;
use crate::solvers::{SolveOptions, Solver};

/// Measured + modeled primitive costs (all seconds).
#[derive(Clone, Debug)]
pub struct CostModel {
    /// One RK projection (dot + axpy) on an `n`-column row of the target
    /// system, measured by timing a real RK run (includes true cache
    /// behaviour against the full matrix).
    pub t_proj: f64,
    /// Plain `x[i] += v[i]` per element (the critical-section gather).
    pub t_add_per_elem: f64,
    /// Atomic CAS-add per element, uncontended (the Atomic strategy).
    pub t_atomic_per_elem: f64,
    /// `memcpy` per element (the x_prev copy / v init).
    pub t_copy_per_elem: f64,
    /// Modeled barrier cost per stage; a crossing costs
    /// `t_barrier_stage * ceil(log2 q)`.
    pub t_barrier_stage: f64,
    /// Effective parallel-speedup cap for streaming (memory-bound) work —
    /// cores share DRAM bandwidth; dense row sweeps saturate around 6-8
    /// concurrent readers on the paper's class of hardware.
    pub bandwidth_cap: f64,
    /// Cache-invalidation amplification for contended atomics.
    pub atomic_contention: f64,
    /// Columns this model was calibrated for.
    pub n: usize,
}

impl CostModel {
    /// Calibrate against a real system (measures projection/add/copy costs).
    pub fn calibrate(system: &LinearSystem) -> Self {
        let n = system.cols();
        // (a) projection cost from a real fixed-iteration RK run.
        let iters = (2_000_000 / n.max(1)).clamp(2_000, 200_000);
        let r = RkSolver::new(99).solve(system, &SolveOptions::default().with_fixed_iterations(iters));
        let t_proj = r.seconds / r.iterations as f64;

        // (b) gather-add, atomic-add, copy per element.
        let len = n.max(1024);
        let reps = (20_000_000 / len).max(16);
        let src: Vec<f64> = (0..len).map(|i| i as f64 * 0.5).collect();
        let mut dst = vec![0.0f64; len];

        let sw = Stopwatch::start();
        for _ in 0..reps {
            for i in 0..len {
                dst[i] += src[i];
            }
            std::hint::black_box(&mut dst);
        }
        let t_add_per_elem = sw.seconds() / (reps * len) as f64;

        let atomic = AtomicF64Vec::zeros(len);
        let reps_a = (reps / 4).max(4);
        let sw = Stopwatch::start();
        for _ in 0..reps_a {
            for i in 0..len {
                atomic.add(i, src[i]);
            }
        }
        let t_atomic_per_elem = sw.seconds() / (reps_a * len) as f64;

        let sw = Stopwatch::start();
        for _ in 0..reps {
            dst.copy_from_slice(&src);
            std::hint::black_box(&mut dst);
        }
        let t_copy_per_elem = sw.seconds() / (reps * len) as f64;

        CostModel {
            t_proj,
            t_add_per_elem,
            t_atomic_per_elem,
            t_copy_per_elem,
            // OpenMP-class centralized barriers cost a few hundred ns per
            // log2(q) stage on real multi-socket hardware (measured figures
            // for GOMP/LLVM range 0.5-5 µs end-to-end at 16-64 threads).
            t_barrier_stage: 400e-9,
            bandwidth_cap: 6.0,
            atomic_contention: 0.5,
            n,
        }
    }

    /// Barrier crossing cost for `q` threads (free for a single thread).
    #[inline]
    pub fn t_barrier(&self, q: usize) -> f64 {
        if q <= 1 {
            return 0.0;
        }
        self.t_barrier_stage * (q as f64).log2().ceil().max(1.0)
    }

    /// Sequential RK per-iteration time.
    pub fn rk_iteration(&self) -> f64 {
        self.t_proj
    }

    /// Parallel RKA per-iteration time under a gather strategy (Algorithm 1).
    ///
    /// Threads project concurrently (one row each — bandwidth capped), then:
    /// - Critical/Reduce: the gather is `q` sequential n-element adds;
    /// - Atomic: `q` concurrent atomic sweeps amplified by invalidations;
    /// - MatrixGather: write own row, extra barrier, parallel column average
    ///   reading q rows (bandwidth capped), with coherence amplification.
    pub fn rka_iteration(&self, q: usize, strategy: AveragingStrategy) -> f64 {
        let n = self.n as f64;
        let qf = q as f64;
        let par = qf.min(self.bandwidth_cap);
        // x_prev chunked copy + the concurrent projections (oversubscribed
        // threads serialize past the bandwidth cap).
        let base = self.t_copy_per_elem * n / par + self.t_proj * qf / par + 3.0 * self.t_barrier(q);
        let gather = match strategy {
            AveragingStrategy::Critical => qf * self.t_add_per_elem * n,
            AveragingStrategy::Reduce => {
                // zero x + private partial + q sequential combines
                self.t_copy_per_elem * n / par + self.t_add_per_elem * n + qf * self.t_add_per_elem * n
            }
            AveragingStrategy::Atomic => {
                // q concurrent sweeps; every line bounces between caches.
                qf * self.t_atomic_per_elem * n * (1.0 + self.atomic_contention * (qf - 1.0)) / par
            }
            AveragingStrategy::MatrixGather => {
                // Write own row (concurrent) + extra barrier + column
                // averaging that reads q rows written by *other* threads:
                // every line arrives via a coherence miss, so the read
                // bandwidth amplification scales with q (the paper's "cache
                // blocks that belong to different threads" point).
                self.t_copy_per_elem * n / par
                    + self.t_barrier(q)
                    + qf * self.t_add_per_elem * n / par * qf.max(2.0)
            }
        };
        base + gather
    }

    /// Parallel RKAB per-iteration time (Algorithm 3).
    pub fn rkab_iteration(&self, q: usize, block_size: usize) -> f64 {
        let n = self.n as f64;
        let qf = q as f64;
        let par = qf.min(self.bandwidth_cap);
        // v = x copy, bs projections (each thread its own block; concurrent
        // threads share bandwidth), v -= x, barrier, q sequential adds.
        let bs = block_size as f64;
        // v = x copy + concurrent block sweeps (q threads, `par`-way
        // effective) + v -= x + two barriers + the q-sequential gather.
        self.t_copy_per_elem * n
            + bs * self.t_proj * qf / par
            + self.t_add_per_elem * n
            + 2.0 * self.t_barrier(q)
            + qf * self.t_add_per_elem * n
    }

    /// Block-sequential RK per-iteration time (§3.2): chunked dot + chunked
    /// update + 4 barriers + the partial-sum combine.
    pub fn block_seq_iteration(&self, q: usize) -> f64 {
        let qf = q as f64;
        let par = qf.min(self.bandwidth_cap);
        if q == 1 {
            return self.t_proj;
        }
        self.t_proj / par + 4.0 * self.t_barrier(q) + qf * 20e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;

    fn model() -> CostModel {
        let sys = DatasetBuilder::new(400, 200).seed(1).consistent();
        CostModel::calibrate(&sys)
    }

    #[test]
    fn calibration_yields_positive_costs() {
        let m = model();
        assert!(m.t_proj > 0.0);
        assert!(m.t_add_per_elem > 0.0);
        assert!(m.t_atomic_per_elem >= m.t_add_per_elem * 0.5);
        assert!(m.t_copy_per_elem > 0.0);
    }

    #[test]
    fn rka_gather_cost_grows_with_q() {
        let m = model();
        let t2 = m.rka_iteration(2, AveragingStrategy::Critical);
        let t16 = m.rka_iteration(16, AveragingStrategy::Critical);
        assert!(t16 > t2, "t16 {t16} t2 {t2}");
    }

    #[test]
    fn critical_beats_alternatives_at_scale() {
        // The paper found the critical section fastest of the four.
        let m = model();
        for q in [8usize, 16] {
            let crit = m.rka_iteration(q, AveragingStrategy::Critical);
            for s in [
                AveragingStrategy::Atomic,
                AveragingStrategy::Reduce,
                AveragingStrategy::MatrixGather,
            ] {
                assert!(
                    m.rka_iteration(q, s) >= crit * 0.9,
                    "{s:?} unexpectedly cheap at q={q}"
                );
            }
        }
    }

    #[test]
    fn rkab_amortizes_gather() {
        // Per-row cost of RKAB must fall as block size grows.
        let m = model();
        let per_row_small = m.rkab_iteration(4, 1) / 1.0;
        let per_row_big = m.rkab_iteration(4, 200) / 200.0;
        assert!(per_row_big < per_row_small / 2.0, "{per_row_big} vs {per_row_small}");
    }

    #[test]
    fn block_seq_no_speedup_for_small_n() {
        let sys = DatasetBuilder::new(400, 50).seed(1).consistent();
        let m = CostModel::calibrate(&sys);
        // Speedup = t(1)/t(q) must be < 1 for tiny n (Fig. 2a).
        let t1 = m.block_seq_iteration(1);
        let t8 = m.block_seq_iteration(8);
        assert!(t8 > t1 * 0.9, "small-n block-seq should not win: {t8} vs {t1}");
    }
}
