//! Experiment coordinator: the paper's evaluation, one driver per figure or
//! table.
//!
//! Each [`Experiment`] follows the paper's protocol (§3.1):
//!
//! 1. **Calibrate** — run the algorithm to the ε = 1e-8 stopping criterion
//!    for several seeds, average the iteration counts ([`calibrate`]);
//! 2. **Time** — charge the averaged iteration count through the calibrated
//!    [`timing::CostModel`] (shared memory) or the simulated cluster
//!    (distributed), keeping the stopping test off the clock;
//! 3. **Report** — emit the same rows/series the paper's figure shows.
//!
//! `Scale` shrinks the paper's matrix dimensions to this container (the
//! shapes being compared are size-stable; see DESIGN.md §3).

pub mod autotune;
pub mod calibrate;
pub mod experiments;
pub mod timing;

pub use autotune::{
    autotune_block_size, autotune_block_size_residual, autotune_gemv_panel, AutotuneConfig,
    TunedParams,
};
pub use calibrate::{calibrate_iterations, calibrate_iterations_residual, Calibration};
pub use timing::CostModel;

use crate::report::Report;

/// Experiment scaling knob.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    /// Multiplier on the default (already container-scaled) dimensions.
    /// `1.0` = the documented EXPERIMENTS.md runs; smaller = smoke tests.
    pub factor: f64,
    /// Seeds used in the calibration averages (paper: 10).
    pub seeds: u32,
}

impl Default for Scale {
    fn default() -> Self {
        Scale { factor: 1.0, seeds: 5 }
    }
}

impl Scale {
    /// Quick smoke-test scale (CI-sized).
    pub fn smoke() -> Self {
        Scale { factor: 0.15, seeds: 2 }
    }

    /// Scale a dimension, keeping a sane floor.
    pub fn dim(&self, d: usize) -> usize {
        ((d as f64 * self.factor) as usize).max(8)
    }
}

/// One reproducible unit of the paper's evaluation.
pub trait Experiment {
    /// Short id, e.g. "fig4".
    fn id(&self) -> &'static str;
    /// Human title matching the paper.
    fn title(&self) -> &'static str;
    /// Run and produce the report.
    fn run(&self, scale: Scale) -> Report;
}

/// All experiments, in paper order.
pub fn registry() -> Vec<Box<dyn Experiment>> {
    vec![
        Box::new(experiments::fig01::Fig01),
        Box::new(experiments::fig02::Fig02),
        Box::new(experiments::fig04_05::Fig04),
        Box::new(experiments::fig04_05::Fig05),
        Box::new(experiments::table1::Table1),
        Box::new(experiments::fig06::Fig06),
        Box::new(experiments::fig07_09::Fig07),
        Box::new(experiments::fig07_09::Fig08),
        Box::new(experiments::fig07_09::Fig09),
        Box::new(experiments::fig10::Fig10),
        Box::new(experiments::table2::Table2),
        Box::new(experiments::fig11::Fig11),
        Box::new(experiments::fig12_14::Fig12),
        Box::new(experiments::fig12_14::Fig13),
        Box::new(experiments::fig12_14::Fig14),
        Box::new(experiments::fig12_14::SolverZoo),
        Box::new(experiments::ablations::AblationAveraging),
        Box::new(experiments::ablations::AblationSampling),
        Box::new(experiments::ablations::AblationAutotune),
    ]
}

/// Find an experiment by id.
pub fn find(id: &str) -> Option<Box<dyn Experiment>> {
    registry().into_iter().find(|e| e.id() == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_all_paper_experiments() {
        let ids: Vec<&str> = registry().iter().map(|e| e.id()).collect();
        for want in [
            "fig1", "fig2", "fig4", "fig5", "table1", "fig6", "fig7", "fig8", "fig9",
            "fig10", "table2", "fig11", "fig12", "fig13", "fig14", "zoo",
        ] {
            assert!(ids.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn find_by_id() {
        assert!(find("fig7").is_some());
        assert!(find("fig99").is_none());
    }

    #[test]
    fn scale_floors_dimensions() {
        let s = Scale { factor: 0.001, seeds: 1 };
        assert_eq!(s.dim(100), 8);
        assert_eq!(Scale::default().dim(100), 100);
    }
}
