//! Automatic block-size selection for RKAB — the paper's explicit
//! future-work item (§3.4.3: "Further investigation into this topic is
//! necessary to find a systematic way to choose block size").
//!
//! The tuner probes candidate block sizes with a *fixed row budget* (so
//! every probe does the same amount of raw work), scores each candidate by
//! metric-decay per modeled second
//!
//! ```text
//! score(bs) = ln(metric_0 / metric_bs) / (iterations * T_iter(q, bs))
//! ```
//!
//! and returns the argmax. The probe honors both effects the paper
//! identified: larger bs amortizes the gather (numerator grows per second)
//! but wastes rows past bs ≈ n (numerator stalls), and under partitioned
//! sampling the per-worker information limit (m/q rows) caps useful bs.
//!
//! Two scorers share that protocol:
//!
//! - [`autotune_block_size`] — the paper's metric `‖x - x*‖²`
//!   ([`LinearSystem::error_sq`]): bit-compatible with the reproduction
//!   experiments, but it needs a known reference solution, which serving
//!   systems do not have;
//! - [`autotune_block_size_residual`] — the **reference-free** scorer:
//!   probes run with a telemetry-grade history (`history_step` =
//!   probe length), and the decay is read from each probe's *own*
//!   `StopCheck` residual samples (`‖b‖` at `x^(0) = 0` down to
//!   `‖A x - b‖` after the probe) instead of `system.error_sq`. This is
//!   the tuner a production RKAB deployment can actually run — on
//!   consistent systems it agrees with the reference scorer (equal probe
//!   trajectories, monotone-related metrics; `tests/telemetry_streaming.rs`
//!   pins the agreement within seed noise).
//!
//! Candidate hygiene: candidates (default `{n/10, n/4, n/2, n, 2n}` *and*
//! user-supplied sets) are clamped to ≥ 1 and deduplicated after clamping
//! (`n/10` is 0 below n = 10, and clamping can alias small candidates); an
//! empty candidate set is a typed [`Error::InvalidArgument`], never a
//! divide-by-zero probe.

use super::timing::CostModel;
use crate::data::LinearSystem;
use crate::error::{Error, Result};
use crate::linalg::gemv::gemv_block_into_with_panel;
use crate::linalg::Matrix;
use crate::solvers::rkab::RkabSolver;
use crate::solvers::sampling::SamplingScheme;
use crate::solvers::{SolveOptions, SolveResult, Solver};

/// One probe outcome.
#[derive(Clone, Debug)]
pub struct ProbeResult {
    /// Candidate block size.
    pub block_size: usize,
    /// Probe iterations run (row_budget / (q*bs)).
    pub iterations: usize,
    /// Squared value of the scored metric after the probe: the reference
    /// error `‖x - x*‖²` under [`autotune_block_size`], the residual
    /// `‖Ax - b‖²` under [`autotune_block_size_residual`].
    pub metric_sq: f64,
    /// Modeled wall time of the probe.
    pub modeled_seconds: f64,
    /// Metric-decay rate per modeled second (higher = better).
    pub score: f64,
}

/// Tuner configuration.
#[derive(Clone, Debug)]
pub struct AutotuneConfig {
    /// Worker count the solve will use.
    pub q: usize,
    /// Relaxation weight.
    pub alpha: f64,
    /// Sampling scheme.
    pub scheme: SamplingScheme,
    /// Rows each probe may consume in total (default 24 * n * q).
    pub row_budget: Option<usize>,
    /// Candidate block sizes (default {n/10, n/4, n/2, n, 2n}); clamped to
    /// ≥ 1 and deduplicated before probing, so a small-n default set (or a
    /// user set containing 0) degrades gracefully instead of dividing by
    /// zero.
    pub candidates: Option<Vec<usize>>,
    /// RNG seed for the probes.
    pub seed: u32,
}

impl AutotuneConfig {
    /// Default tuner for `q` workers.
    pub fn new(q: usize) -> Self {
        AutotuneConfig {
            q,
            alpha: 1.0,
            scheme: SamplingScheme::FullMatrix,
            row_budget: None,
            candidates: None,
            seed: 0xA070,
        }
    }
}

/// The probed candidate set: defaults or user-supplied, clamped to ≥ 1,
/// deduplicated after clamping (order-preserving, so the probe sequence —
/// and therefore the scores — stay bit-compatible for already-valid sets).
fn candidate_set(n: usize, cfg: &AutotuneConfig) -> Result<Vec<usize>> {
    let raw = cfg
        .candidates
        .clone()
        .unwrap_or_else(|| vec![n / 10, n / 4, n / 2, n, 2 * n]);
    let mut seen = std::collections::HashSet::new();
    let candidates: Vec<usize> =
        raw.into_iter().map(|b| b.max(1)).filter(|b| seen.insert(*b)).collect();
    if candidates.is_empty() {
        return Err(Error::InvalidArgument(
            "autotune: empty block-size candidate set (supply at least one candidate >= 1)"
                .to_string(),
        ));
    }
    Ok(candidates)
}

/// Shared probe driver: run every candidate under the fixed row budget and
/// score it by the decay of the metric `metrics` extracts — which returns
/// `(metric_0², metric_end²)` for one finished probe.
fn probe_candidates<F>(
    system: &LinearSystem,
    model: &CostModel,
    cfg: &AutotuneConfig,
    history_samples: bool,
    metrics: F,
) -> Result<(usize, Vec<ProbeResult>)>
where
    F: Fn(&SolveResult) -> (f64, f64),
{
    let n = system.cols();
    let q = cfg.q;
    let budget = cfg.row_budget.unwrap_or(24 * n * q);
    let candidates = candidate_set(n, cfg)?;

    let mut results = Vec::with_capacity(candidates.len());
    for &bs in &candidates {
        let iterations = (budget / (q * bs)).max(1);
        let mut opts = SolveOptions::default().with_fixed_iterations(iterations);
        if history_samples {
            // Bracket the probe with exactly two StopCheck samples (k = 0
            // and k = iterations): the residual scorer reads its metric
            // from the probe's own telemetry instead of the reference.
            opts = opts.with_history_step(iterations);
        }
        let r = RkabSolver::new(cfg.seed, q, bs, cfg.alpha)
            .with_scheme(cfg.scheme)
            .solve(system, &opts);
        let (m0_sq, metric_sq) = metrics(&r);
        let (m0_sq, metric_sq) = (m0_sq.max(1e-300), metric_sq.max(1e-300));
        let modeled_seconds = iterations as f64 * model.rkab_iteration(q, bs);
        // ln of the *norm* ratio = 0.5 ln of the squared ratio.
        let decay = 0.5 * (m0_sq / metric_sq).ln();
        let score = if decay > 0.0 { decay / modeled_seconds } else { f64::NEG_INFINITY };
        results.push(ProbeResult { block_size: bs, iterations, metric_sq, modeled_seconds, score });
    }
    let best = results
        .iter()
        .max_by(|a, b| a.score.total_cmp(&b.score))
        .map(|r| r.block_size)
        .unwrap_or(n);
    Ok((best, results))
}

/// Probe all candidates, scoring by the paper's reference-error metric, and
/// return (best block size, all probe results). Bit-compatible with the
/// reproduction experiments — and like them, it requires the system to
/// carry a reference solution. For serving systems (no reference), use
/// [`autotune_block_size_residual`].
pub fn autotune_block_size(
    system: &LinearSystem,
    model: &CostModel,
    cfg: &AutotuneConfig,
) -> Result<(usize, Vec<ProbeResult>)> {
    let n = system.cols();
    let err0 = system.error_sq(&vec![0.0; n]);
    probe_candidates(system, model, cfg, false, |r| (err0, system.error_sq(&r.x)))
}

/// Probe all candidates, scoring by **residual** decay per modeled second —
/// the reference-free tuner. The same fixed-row-budget protocol as
/// [`autotune_block_size`], but each probe's metric is read from its own
/// `StopCheck` history samples (`‖A x^(k)- b‖` at `k = 0` and at the probe
/// end), so it runs on real inconsistent workloads where no reference
/// solution exists. On consistent systems it agrees with the
/// reference-error scorer within seed noise (the two metrics decay
/// together); on inconsistent systems the residual is the only measurable
/// quantity, and its decay toward the least-squares floor is exactly what
/// Moorman et al. (arXiv:2002.04126) monitor for RKA-family methods.
pub fn autotune_block_size_residual(
    system: &LinearSystem,
    model: &CostModel,
    cfg: &AutotuneConfig,
) -> Result<(usize, Vec<ProbeResult>)> {
    probe_candidates(system, model, cfg, true, |r| {
        let first = r.history.residuals.first().copied().unwrap_or(0.0);
        let last = r.history.residuals.last().copied().unwrap_or(0.0);
        (first * first, last * last)
    })
}

// ---------------------------------------------------------------------------
// Host-level kernel tuning: the blocked-GEMV panel width.
// ---------------------------------------------------------------------------

/// Timing probe for one blocked-GEMV panel-width candidate.
#[derive(Clone, Debug)]
pub struct GemvPanelProbe {
    /// Candidate panel width (f64 elements).
    pub panel: usize,
    /// Best-of-reps wall time of one full `y = A x` at this width.
    pub seconds: f64,
}

/// Panel widths [`autotune_gemv_panel`] probes: 8–64 KiB of `x` per
/// panel, bracketing typical L1d sizes (the default is 4096 = 32 KiB).
pub const GEMV_PANEL_CANDIDATES: [usize; 4] = [1024, 2048, 4096, 8192];

/// Probe the blocked-GEMV panel width on this host: time a full
/// `y = A x` over `a` at every candidate width (best of `reps` runs,
/// after one warm-up) and return the fastest, plus every probe for
/// reporting. NaN-safe argmin via `total_cmp`; `reps` is clamped to
/// ≥ 1.
///
/// The pick feeds [`crate::linalg::set_gemv_panel`], which the residual
/// stopping path, serving, and `gemv_block_into` all read — see the
/// `kaczmarz tune` subcommand, which persists it via [`TunedParams`].
/// The matrix should be wide enough that blocking matters (cols well
/// past the largest candidate) for the timings to separate; smaller
/// probes still return a valid, if noisy, pick.
pub fn autotune_gemv_panel(a: &Matrix, reps: usize) -> (usize, Vec<GemvPanelProbe>) {
    let reps = reps.max(1);
    let n = a.cols();
    let x: Vec<f64> = (0..n).map(|i| ((i % 64) as f64 - 31.5) * 0.031).collect();
    let mut y = vec![0.0; a.rows()];
    let mut probes = Vec::with_capacity(GEMV_PANEL_CANDIDATES.len());
    for &panel in &GEMV_PANEL_CANDIDATES {
        // Warm-up pass: fault pages and warm the cache hierarchy so the
        // first timed rep is not charged for cold misses.
        gemv_block_into_with_panel(a, &x, &mut y, panel);
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            gemv_block_into_with_panel(a, &x, &mut y, panel);
            let dt = t0.elapsed().as_secs_f64();
            if dt < best {
                best = dt;
            }
        }
        probes.push(GemvPanelProbe { panel, seconds: best });
    }
    let best_panel = probes
        .iter()
        .min_by(|u, v| u.seconds.total_cmp(&v.seconds))
        .map(|p| p.panel)
        .unwrap_or(GEMV_PANEL_CANDIDATES[2]);
    (best_panel, probes)
}

/// Host-tuned parameters the `kaczmarz tune` subcommand persists and the
/// CLI re-applies at startup (`KACZMARZ_TUNE_FILE`, or
/// `./kaczmarz-tune.json`): the blocked-GEMV panel width for this host
/// and the serving-shaped RKAB block size picked by the reference-free
/// scorer ([`autotune_block_size_residual`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TunedParams {
    /// Blocked-GEMV panel width (f64 elements), from
    /// [`autotune_gemv_panel`].
    pub gemv_panel: Option<usize>,
    /// RKAB block size for serving solves, from
    /// [`autotune_block_size_residual`].
    pub rkab_block: Option<usize>,
}

impl TunedParams {
    /// Serialize as the tune-file JSON (hand-rolled like every other
    /// emitter in this offline crate; unset fields are `null`).
    pub fn to_json(&self) -> String {
        let field = |v: Option<usize>| v.map_or("null".to_string(), |p| p.to_string());
        format!(
            "{{\n  \"gemv_panel\": {},\n  \"rkab_block\": {}\n}}\n",
            field(self.gemv_panel),
            field(self.rkab_block)
        )
    }

    /// Parse a tune file produced by [`TunedParams::to_json`]. The
    /// scanner accepts only the flat `"key": <integer|null>` shape this
    /// crate writes; a key that is present but malformed is a typed
    /// [`Error::InvalidArgument`], a missing key is simply unset.
    pub fn parse(text: &str) -> Result<TunedParams> {
        fn field(text: &str, key: &str) -> Result<Option<usize>> {
            let pat = format!("\"{key}\"");
            let Some(at) = text.find(&pat) else {
                return Ok(None);
            };
            let rest = &text[at + pat.len()..];
            let rest = rest.trim_start();
            let Some(rest) = rest.strip_prefix(':') else {
                return Err(Error::InvalidArgument(format!("tune file: expected ':' after {pat}")));
            };
            let rest = rest.trim_start();
            if rest.starts_with("null") {
                return Ok(None);
            }
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            digits
                .parse::<usize>()
                .map(Some)
                .map_err(|_| Error::InvalidArgument(format!("tune file: bad value for {pat}")))
        }
        Ok(TunedParams {
            gemv_panel: field(text, "gemv_panel")?,
            rkab_block: field(text, "rkab_block")?,
        })
    }

    /// Write the tune file (see [`TunedParams::to_json`]).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json())?;
        Ok(())
    }

    /// Read and parse a tune file.
    pub fn load(path: &std::path::Path) -> Result<TunedParams> {
        TunedParams::parse(&std::fs::read_to_string(path)?)
    }

    /// Apply the host-level pieces to this process: pins the blocked-GEMV
    /// panel via [`crate::linalg::set_gemv_panel`]. (`rkab_block` is
    /// consumed per-solve by the CLI/serving layer, not pinned globally.)
    pub fn apply(&self) {
        if let Some(panel) = self.gemv_panel {
            crate::linalg::set_gemv_panel(panel);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;

    #[test]
    fn tuner_prefers_blocks_near_n_for_full_sampling() {
        // The paper's rule of thumb: bs ≈ n minimizes time. The tuner must
        // land within [n/4, 2n] (exact argmax depends on the calibrated
        // constants; the point is it avoids tiny and huge blocks).
        let sys = DatasetBuilder::new(2000, 100).seed(1).consistent();
        let model = CostModel::calibrate(&sys);
        let (best, results) =
            autotune_block_size(&sys, &model, &AutotuneConfig::new(4)).unwrap();
        assert!(results.len() >= 4);
        assert!(
            best >= 25 && best <= 200,
            "tuner picked bs={best}, probes: {:?}",
            results.iter().map(|r| (r.block_size, r.score)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn tuner_scores_tiny_blocks_worse() {
        let sys = DatasetBuilder::new(2000, 100).seed(2).consistent();
        let model = CostModel::calibrate(&sys);
        let (_, results) = autotune_block_size(&sys, &model, &AutotuneConfig::new(8)).unwrap();
        let score_of = |bs: usize| {
            results.iter().find(|r| r.block_size == bs).map(|r| r.score).unwrap()
        };
        // bs = n/10 pays the gather every 10 rows: strictly worse than bs = n.
        assert!(score_of(10) < score_of(100), "{results:?}");
    }

    #[test]
    fn probe_respects_budget() {
        let sys = DatasetBuilder::new(500, 50).seed(3).consistent();
        let model = CostModel::calibrate(&sys);
        let cfg = AutotuneConfig { row_budget: Some(4000), ..AutotuneConfig::new(2) };
        let (_, results) = autotune_block_size(&sys, &model, &cfg).unwrap();
        for r in &results {
            let rows = r.iterations * 2 * r.block_size;
            assert!(rows <= 4000 + 2 * r.block_size, "bs {} used {rows}", r.block_size);
        }
    }

    #[test]
    fn small_n_default_candidates_are_clamped_and_deduped() {
        // n = 4: raw defaults {0, 1, 2, 4, 8} — the 0 must become 1, and
        // the clamp-induced duplicate must collapse, so every probe has a
        // positive block size and no candidate is probed twice.
        let sys = DatasetBuilder::new(60, 4).seed(7).consistent();
        let model = CostModel::calibrate(&sys);
        let (best, results) = autotune_block_size(&sys, &model, &AutotuneConfig::new(2)).unwrap();
        assert!(best >= 1);
        let sizes: Vec<usize> = results.iter().map(|r| r.block_size).collect();
        assert!(sizes.iter().all(|&b| b >= 1), "{sizes:?}");
        let mut deduped = sizes.clone();
        deduped.dedup();
        assert_eq!(sizes, deduped, "duplicate candidates probed");
    }

    #[test]
    fn user_candidates_with_zero_are_clamped_not_divided_by() {
        let sys = DatasetBuilder::new(100, 8).seed(8).consistent();
        let model = CostModel::calibrate(&sys);
        let cfg = AutotuneConfig {
            candidates: Some(vec![0, 8, 8, 0]),
            row_budget: Some(1000),
            ..AutotuneConfig::new(2)
        };
        // 0 clamps to 1; duplicates (including the two clamped zeros)
        // collapse: exactly {1, 8} is probed, in that order.
        let (_, results) = autotune_block_size(&sys, &model, &cfg).unwrap();
        let sizes: Vec<usize> = results.iter().map(|r| r.block_size).collect();
        assert_eq!(sizes, vec![1, 8]);
    }

    #[test]
    fn empty_candidate_set_is_a_typed_error() {
        let sys = DatasetBuilder::new(100, 8).seed(9).consistent();
        let model = CostModel::calibrate(&sys);
        let cfg = AutotuneConfig { candidates: Some(vec![]), ..AutotuneConfig::new(2) };
        let err = autotune_block_size(&sys, &model, &cfg).err().expect("must be rejected");
        assert!(matches!(err, Error::InvalidArgument(_)), "{err:?}");
        let err =
            autotune_block_size_residual(&sys, &model, &cfg).err().expect("must be rejected");
        assert!(matches!(err, Error::InvalidArgument(_)), "{err:?}");
    }

    #[test]
    fn gemv_panel_probe_covers_every_candidate() {
        // A small matrix keeps this fast; timings are noisy there, but the
        // contract under test is structural: every candidate probed once,
        // positive times, and the pick is one of the candidates.
        let sys = DatasetBuilder::new(64, 256).seed(13).consistent();
        let (best, probes) = autotune_gemv_panel(&sys.a, 2);
        assert_eq!(
            probes.iter().map(|p| p.panel).collect::<Vec<_>>(),
            GEMV_PANEL_CANDIDATES.to_vec()
        );
        assert!(probes.iter().all(|p| p.seconds >= 0.0 && p.seconds.is_finite()));
        assert!(GEMV_PANEL_CANDIDATES.contains(&best));
    }

    #[test]
    fn tuned_params_json_roundtrip() {
        for params in [
            TunedParams { gemv_panel: Some(2048), rkab_block: Some(100) },
            TunedParams { gemv_panel: Some(8192), rkab_block: None },
            TunedParams::default(),
        ] {
            let text = params.to_json();
            assert_eq!(TunedParams::parse(&text).unwrap(), params, "{text}");
        }
        // Malformed values are typed errors, missing keys are unset.
        assert!(TunedParams::parse("{\"gemv_panel\": x}").is_err());
        assert_eq!(TunedParams::parse("{}").unwrap(), TunedParams::default());
    }

    #[test]
    fn tuned_params_save_load_apply() {
        let _guard =
            crate::linalg::gemv::PANEL_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir().join("kaczmarz-tune-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tune.json");
        let params = TunedParams { gemv_panel: Some(8192), rkab_block: Some(64) };
        params.save(&path).unwrap();
        let loaded = TunedParams::load(&path).unwrap();
        assert_eq!(loaded, params);
        // Only values >= the default panel are applied in tests (smaller
        // ones could change blocked-GEMV rounding for concurrently running
        // wide-matrix tests); restore the default afterwards.
        loaded.apply();
        assert_eq!(crate::linalg::gemv_panel(), 8192);
        crate::linalg::set_gemv_panel(4096);
        assert!(TunedParams::load(&dir.join("missing.json")).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn residual_tuner_runs_without_any_reference_solution() {
        // A serving-shaped system: nobody knows x*. error_sq would panic,
        // so a clean pass proves the scorer never touched the reference.
        let src = DatasetBuilder::new(400, 20).seed(11).consistent();
        let sys = crate::data::LinearSystem::new(src.a.clone(), src.b.clone(), None, true);
        let model = CostModel::calibrate(&src); // calibration needs no reference either way
        let (best, results) =
            autotune_block_size_residual(&sys, &model, &AutotuneConfig::new(2)).unwrap();
        assert!(best >= 1);
        assert!(results.iter().all(|r| r.metric_sq.is_finite()));
        // Consistent system, healthy probes: the residual must decay, so at
        // least one candidate gets a finite positive score.
        assert!(results.iter().any(|r| r.score > 0.0), "{results:?}");
    }
}
