//! Automatic block-size selection for RKAB — the paper's explicit
//! future-work item (§3.4.3: "Further investigation into this topic is
//! necessary to find a systematic way to choose block size").
//!
//! The tuner probes candidate block sizes with a *fixed row budget* (so
//! every probe does the same amount of raw work), scores each candidate by
//! error-decay per modeled second
//!
//! ```text
//! score(bs) = ln(err_0 / err_bs) / (iterations * T_iter(q, bs))
//! ```
//!
//! and returns the argmax. The probe honors both effects the paper
//! identified: larger bs amortizes the gather (numerator grows per second)
//! but wastes rows past bs ≈ n (numerator stalls), and under partitioned
//! sampling the per-worker information limit (m/q rows) caps useful bs.

use super::timing::CostModel;
use crate::data::LinearSystem;
use crate::solvers::rkab::RkabSolver;
use crate::solvers::sampling::SamplingScheme;
use crate::solvers::{SolveOptions, Solver};

/// One probe outcome.
#[derive(Clone, Debug)]
pub struct ProbeResult {
    /// Candidate block size.
    pub block_size: usize,
    /// Probe iterations run (row_budget / (q*bs)).
    pub iterations: usize,
    /// Squared error after the probe.
    pub err_sq: f64,
    /// Modeled wall time of the probe.
    pub modeled_seconds: f64,
    /// Error-decay rate per modeled second (higher = better).
    pub score: f64,
}

/// Tuner configuration.
#[derive(Clone, Debug)]
pub struct AutotuneConfig {
    /// Worker count the solve will use.
    pub q: usize,
    /// Relaxation weight.
    pub alpha: f64,
    /// Sampling scheme.
    pub scheme: SamplingScheme,
    /// Rows each probe may consume in total (default 24 * n * q).
    pub row_budget: Option<usize>,
    /// Candidate block sizes (default {n/10, n/4, n/2, n, 2n} clamped).
    pub candidates: Option<Vec<usize>>,
    /// RNG seed for the probes.
    pub seed: u32,
}

impl AutotuneConfig {
    /// Default tuner for `q` workers.
    pub fn new(q: usize) -> Self {
        AutotuneConfig {
            q,
            alpha: 1.0,
            scheme: SamplingScheme::FullMatrix,
            row_budget: None,
            candidates: None,
            seed: 0xA070,
        }
    }
}

/// Probe all candidates and return (best block size, all probe results).
pub fn autotune_block_size(
    system: &LinearSystem,
    model: &CostModel,
    cfg: &AutotuneConfig,
) -> (usize, Vec<ProbeResult>) {
    let n = system.cols();
    let q = cfg.q;
    let budget = cfg.row_budget.unwrap_or(24 * n * q);
    let candidates = cfg.candidates.clone().unwrap_or_else(|| {
        let mut c: Vec<usize> = [n / 10, n / 4, n / 2, n, 2 * n]
            .into_iter()
            .map(|b| b.max(1))
            .collect();
        c.dedup();
        c
    });

    let mut results = Vec::with_capacity(candidates.len());
    let err0 = system.error_sq(&vec![0.0; n]).max(1e-300);
    for &bs in &candidates {
        let iterations = (budget / (q * bs)).max(1);
        let opts = SolveOptions::default().with_fixed_iterations(iterations);
        let r = RkabSolver::new(cfg.seed, q, bs, cfg.alpha)
            .with_scheme(cfg.scheme)
            .solve(system, &opts);
        let err_sq = system.error_sq(&r.x).max(1e-300);
        let modeled_seconds = iterations as f64 * model.rkab_iteration(q, bs);
        // ln of the *norm* ratio = 0.5 ln of the squared ratio.
        let decay = 0.5 * (err0 / err_sq).ln();
        let score = if decay > 0.0 { decay / modeled_seconds } else { f64::NEG_INFINITY };
        results.push(ProbeResult { block_size: bs, iterations, err_sq, modeled_seconds, score });
    }
    let best = results
        .iter()
        .max_by(|a, b| a.score.partial_cmp(&b.score).unwrap())
        .map(|r| r.block_size)
        .unwrap_or(n);
    (best, results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;

    #[test]
    fn tuner_prefers_blocks_near_n_for_full_sampling() {
        // The paper's rule of thumb: bs ≈ n minimizes time. The tuner must
        // land within [n/4, 2n] (exact argmax depends on the calibrated
        // constants; the point is it avoids tiny and huge blocks).
        let sys = DatasetBuilder::new(2000, 100).seed(1).consistent();
        let model = CostModel::calibrate(&sys);
        let (best, results) = autotune_block_size(&sys, &model, &AutotuneConfig::new(4));
        assert!(results.len() >= 4);
        assert!(
            best >= 25 && best <= 200,
            "tuner picked bs={best}, probes: {:?}",
            results.iter().map(|r| (r.block_size, r.score)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn tuner_scores_tiny_blocks_worse() {
        let sys = DatasetBuilder::new(2000, 100).seed(2).consistent();
        let model = CostModel::calibrate(&sys);
        let (_, results) = autotune_block_size(&sys, &model, &AutotuneConfig::new(8));
        let score_of = |bs: usize| {
            results.iter().find(|r| r.block_size == bs).map(|r| r.score).unwrap()
        };
        // bs = n/10 pays the gather every 10 rows: strictly worse than bs = n.
        assert!(score_of(10) < score_of(100), "{results:?}");
    }

    #[test]
    fn probe_respects_budget() {
        let sys = DatasetBuilder::new(500, 50).seed(3).consistent();
        let model = CostModel::calibrate(&sys);
        let cfg = AutotuneConfig { row_budget: Some(4000), ..AutotuneConfig::new(2) };
        let (_, results) = autotune_block_size(&sys, &model, &cfg);
        for r in &results {
            let rows = r.iterations * 2 * r.block_size;
            assert!(rows <= 4000 + 2 * r.block_size, "bs {} used {rows}", r.block_size);
        }
    }
}
