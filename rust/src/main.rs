//! `kaczmarz` — CLI for the parallel Randomized Kaczmarz reproduction.
//!
//! Subcommands:
//!   list                         list the paper's experiments
//!   experiment <id> [--scale f] [--seeds k] [--out dir]
//!                                run one experiment (fig1..fig14, table1/2)
//!   all [--scale f] [--out dir]  run the full evaluation suite
//!   solve [--method rk|ck|rka|rkab|rek|asyrk|pjrt] [--rows m] [--cols n]
//!         [--sampling random|greedy] [--weights uniform|norm]
//!         [--mtx file] [--residual [--check-every k]] [--history step]
//!         [--watch] ...
//!                                one-off solve on a generated system, or —
//!                                with --mtx — on a Matrix Market file
//!                                loaded into CSR sparse storage (b = A x
//!                                for a seeded x, so the solution is known);
//!                                --solver is an alias for --method; `rek`
//!                                runs Randomized Extended Kaczmarz (least
//!                                squares on inconsistent systems);
//!                                --sampling greedy swaps eq. 4 row draws
//!                                for the max-residual Motzkin scan
//!                                (sequential rk/rka/rkab only);
//!                                --weights norm averages RKA/RKAB workers
//!                                by inverse row norms instead of uniformly;
//!                                --residual stops on ‖Ax-b‖² instead of
//!                                the reference error; --history records
//!                                the convergence curve every `step`
//!                                iterations and prints it (error and
//!                                residual channels); --watch streams the
//!                                dual-channel curve line-by-line *while*
//!                                the solve runs (live telemetry sink)
//!   tune [--rows m] [--cols n] [--q w] [--seed s] [--reps r] [--out file]
//!                                probe this host: blocked-GEMV panel width
//!                                (candidates {1024, 2048, 4096, 8192}) and
//!                                the serving RKAB block size via the
//!                                reference-free residual scorer
//!                                (autotune_block_size_residual); persists
//!                                the picks (default kaczmarz-tune.json)
//!                                and applies them to this process
//!   serve [--addr a] [--capacity-mb n] [--lanes n] [--max-pending n]
//!         [--preload name:MxN:seed,...]
//!                                boot the framed-TCP serving front end:
//!                                preloaded systems become resident in the
//!                                LRU registry, solves run on persistent
//!                                lanes behind a bounded admission queue
//!                                (SUBMIT/POLL/CANCEL/STATS/PING wire
//!                                frames, newline-delimited)
//!   submit [--addr a] [--system s] [--solver rk|rek|ck] [--seed n]
//!          [--tol t] [--check k] [--fixed n] [--max-iterations n]
//!          [--deadline-ms n] [--cancel-after k] [--min-samples k]
//!          [--expect-error kind]
//!                                submit one job to a running server and
//!                                stream its mid-solve samples; the assert
//!                                flags make it a smoke-test client (exit 1
//!                                when fewer than --min-samples samples
//!                                arrived, or when the outcome does not
//!                                match --expect-error / clean completion);
//!                                --cancel-after k cancels the job from a
//!                                second connection after the k-th sample
//!   info                         version, kernel flavor (avx2+fma or
//!                                scalar; KACZMARZ_KERNEL=scalar forces the
//!                                bitwise reference path), gemv panel, core
//!                                count, artifact status
//!
//! At startup every subcommand loads and applies a tune file when one is
//! present: `$KACZMARZ_TUNE_FILE`, else `./kaczmarz-tune.json`. A tuned
//! `rkab_block` also becomes the default `--bs` for `solve`.

use kaczmarz::cli::Args;
use kaczmarz::coordinator::{
    autotune_block_size_residual, autotune_gemv_panel, find, registry, AutotuneConfig, CostModel,
    Scale, TunedParams,
};
use kaczmarz::data::DatasetBuilder;
use kaczmarz::parallel::{AsyRkSolver, ParallelRka, ParallelRkab};
use kaczmarz::runtime::{default_artifacts_dir, Manifest, PjrtRkabSolver};
use kaczmarz::serve::wire::SubmitFrame;
use kaczmarz::serve::{client, FrontEndConfig, RemoteOutcome, SolveFrontEnd, SystemRegistry, WireServer};
use kaczmarz::solvers::ck::CkSolver;
use kaczmarz::solvers::rek::RekSolver;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rka::{RkaSolver, Weights};
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{require_randomized, SamplingStrategy, SolveOptions, SolveResult, Solver};
use std::path::PathBuf;

fn main() {
    let args = Args::from_env();
    let tuned = load_tune_file();
    match args.command.as_str() {
        "list" => cmd_list(),
        "experiment" => cmd_experiment(&args),
        "all" => cmd_all(&args),
        "solve" => cmd_solve(&args, &tuned),
        "tune" => cmd_tune(&args),
        "serve" => cmd_serve(&args),
        "submit" => cmd_submit(&args),
        "info" | "" => cmd_info(&tuned),
        other => {
            eprintln!(
                "unknown command '{other}'; try: list, experiment, all, solve, tune, \
                 serve, submit, info"
            );
            std::process::exit(2);
        }
    }
}

/// Load and apply the host tune file, if any: `$KACZMARZ_TUNE_FILE` wins,
/// else `./kaczmarz-tune.json`. Applying pins the blocked-GEMV panel for
/// this process; the returned params also feed `solve`'s `--bs` default.
/// A missing file is normal (untuned host); an unreadable one is reported
/// and ignored rather than aborting the command.
fn load_tune_file() -> TunedParams {
    let explicit = std::env::var("KACZMARZ_TUNE_FILE").ok();
    let path = PathBuf::from(explicit.clone().unwrap_or_else(|| "kaczmarz-tune.json".into()));
    if !path.exists() {
        if explicit.is_some() {
            eprintln!("tune file {} not found; running untuned", path.display());
        }
        return TunedParams::default();
    }
    match TunedParams::load(&path) {
        Ok(t) => {
            t.apply();
            eprintln!(
                "applied tune file {} (gemv_panel={:?}, rkab_block={:?})",
                path.display(),
                t.gemv_panel,
                t.rkab_block
            );
            t
        }
        Err(e) => {
            eprintln!("ignoring unreadable tune file {}: {e}", path.display());
            TunedParams::default()
        }
    }
}

fn scale_from(args: &Args) -> Scale {
    Scale {
        factor: args.get_parse("scale", 1.0),
        seeds: args.get_parse("seeds", 5u32),
    }
}

fn cmd_list() {
    println!("{:<8} {}", "id", "title");
    for e in registry() {
        println!("{:<8} {}", e.id(), e.title());
    }
}

fn cmd_experiment(args: &Args) {
    let Some(id) = args.positional.first() else {
        eprintln!("usage: kaczmarz experiment <id> [--scale f] [--seeds k] [--out dir]");
        std::process::exit(2);
    };
    let Some(exp) = find(id) else {
        eprintln!("no experiment '{id}'; see `kaczmarz list`");
        std::process::exit(2);
    };
    let scale = scale_from(args);
    eprintln!("running {} (scale {}, seeds {})...", exp.id(), scale.factor, scale.seeds);
    let report = exp.run(scale);
    let out = PathBuf::from(args.get("out", "results"));
    let path = report.write(&out, exp.id()).expect("write report");
    println!("{}", report.to_markdown());
    eprintln!("wrote {}", path.display());
}

fn cmd_all(args: &Args) {
    let scale = scale_from(args);
    let out = PathBuf::from(args.get("out", "results"));
    for exp in registry() {
        eprintln!("=== {} ===", exp.id());
        let report = exp.run(scale);
        let path = report.write(&out, exp.id()).expect("write report");
        eprintln!("wrote {}", path.display());
    }
    eprintln!("all experiments written to {}", out.display());
}

fn print_result(name: &str, sys_err: f64, r: &SolveResult) {
    println!(
        "{name}: iterations={} rows_used={} converged={} diverged={} time={:.3}s err^2={:.3e}",
        r.iterations, r.rows_used, r.converged, r.diverged, r.seconds, sys_err
    );
    if !r.history.is_empty() {
        // Dual-channel curve: the residual column is always there; the
        // error column only when the system carried a reference solution.
        if r.history.has_reference_channel() {
            println!("{:>12} {:>14} {:>14}", "iteration", "||x - x_ref||", "||Ax - b||");
            for i in 0..r.history.len() {
                println!(
                    "{:>12} {:>14.6e} {:>14.6e}",
                    r.history.iterations[i], r.history.errors[i], r.history.residuals[i]
                );
            }
        } else {
            println!("{:>12} {:>14}", "iteration", "||Ax - b||");
            for i in 0..r.history.len() {
                println!(
                    "{:>12} {:>14.6e}",
                    r.history.iterations[i], r.history.residuals[i]
                );
            }
        }
    }
}

/// `kaczmarz tune`: probe this host's blocked-GEMV panel width and the
/// serving RKAB block size, persist both, and apply them immediately.
fn cmd_tune(args: &Args) {
    let rows = args.get_parse("rows", 2000usize);
    let cols = args.get_parse("cols", 200usize);
    let q = args.get_parse("q", 4usize);
    let seed = args.get_parse("seed", 1u32);
    let reps = args.get_parse("reps", 5usize);
    let out = PathBuf::from(args.get("out", "kaczmarz-tune.json"));

    // Panel probe: a short, *wide* dense matrix (cols span many panels)
    // so the candidate widths actually change the x-panel residency the
    // blocking exists for. Fixed shape — the probe measures the host, not
    // the workload.
    let (panel_rows, panel_cols) = (256usize, 16384usize);
    eprintln!("probing gemv panel widths on a {panel_rows} x {panel_cols} dense system...");
    let probe_sys = DatasetBuilder::new(panel_rows, panel_cols).seed(seed).consistent();
    let a = probe_sys.a.as_dense().expect("generated systems are dense");
    let (best_panel, panel_probes) = autotune_gemv_panel(a, reps);
    println!("{:>8} {:>12}", "panel", "seconds");
    for p in &panel_probes {
        let mark = if p.panel == best_panel { "  <-- best" } else { "" };
        println!("{:>8} {:>12.6}{mark}", p.panel, p.seconds);
    }

    // Serving block-size probe: the reference-free residual scorer on a
    // solve-shaped system (same default shape as `solve`).
    eprintln!("probing rkab block sizes on a {rows} x {cols} system (q={q})...");
    let sys = DatasetBuilder::new(rows, cols).seed(seed).consistent();
    let model = CostModel::calibrate(&sys);
    let (best_bs, bs_probes) = match autotune_block_size_residual(&sys, &model, &AutotuneConfig::new(q))
    {
        Ok(r) => r,
        Err(e) => {
            eprintln!("block-size probe failed: {e}");
            std::process::exit(1);
        }
    };
    println!("{:>8} {:>12} {:>14}", "bs", "iterations", "score");
    for p in &bs_probes {
        let mark = if p.block_size == best_bs { "  <-- best" } else { "" };
        println!("{:>8} {:>12} {:>14.6e}{mark}", p.block_size, p.iterations, p.score);
    }

    let tuned = TunedParams { gemv_panel: Some(best_panel), rkab_block: Some(best_bs) };
    tuned.apply();
    match tuned.save(&out) {
        Ok(()) => println!(
            "tuned: gemv_panel={best_panel} rkab_block={best_bs} -> {}",
            out.display()
        ),
        Err(e) => {
            eprintln!("failed to write {}: {e}", out.display());
            std::process::exit(1);
        }
    }
}

fn cmd_solve(args: &Args, tuned: &TunedParams) {
    let q = args.get_parse("q", 4usize);
    let alpha = args.get_parse("alpha", 1.0f64);
    let seed = args.get_parse("seed", 1u32);
    // --solver is an alias for --method (solver-zoo phrasing).
    let method = args.get("solver", &args.get("method", "rk"));
    let inconsistent = args.has("inconsistent");
    let mtx = args.get("mtx", "");

    // Row-selection rule: eq. 4 sampling (default) or the greedy Motzkin
    // max-residual scan. Only the sequential solvers hold the iterate at
    // selection time, so everything else rejects greedy with a typed error.
    let sampling = match args.get("sampling", "random").as_str() {
        "random" => SamplingStrategy::Randomized,
        "greedy" => SamplingStrategy::Greedy,
        other => {
            eprintln!("unknown --sampling '{other}'; try: random, greedy");
            std::process::exit(2);
        }
    };
    if !matches!(method.as_str(), "rk" | "rka" | "rkab") {
        if let Err(e) = require_randomized(&method, sampling) {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }

    // Averaging weights for RKA/RKAB: uniform 1/q (default, the paper's
    // eq. 7) or inverse-row-norm heterogeneous weights (--weights norm).
    let norm_weights = match args.get("weights", "uniform").as_str() {
        "uniform" => false,
        "norm" => true,
        other => {
            eprintln!("unknown --weights '{other}'; try: uniform, norm");
            std::process::exit(2);
        }
    };
    if norm_weights && !matches!(method.as_str(), "rka" | "rkab") {
        let e = kaczmarz::error::Error::InvalidArgument(format!(
            "--weights norm reweights the averaging step of rka/rkab only (got '{method}')"
        ));
        eprintln!("{e}");
        std::process::exit(2);
    }

    let sys = if mtx.is_empty() {
        let m = args.get_parse("rows", 2000usize);
        let n = args.get_parse("cols", 200usize);
        eprintln!(
            "generating {m} x {n} {} system...",
            if inconsistent { "inconsistent" } else { "consistent" }
        );
        let builder = DatasetBuilder::new(m, n).seed(seed);
        let mut sys = if inconsistent { builder.inconsistent() } else { builder.consistent() };
        if inconsistent {
            kaczmarz::solvers::cgls::attach_least_squares(&mut sys, 1e-12, 100_000)
                .expect("CGLS failed");
        }
        sys
    } else {
        // A Matrix Market file carries only A; the loader draws a seeded
        // x_true and sets b = A x_true, so the system is consistent and the
        // solve runs on CSR sparse storage end to end.
        if inconsistent {
            eprintln!("--mtx builds a consistent system; ignoring --inconsistent");
        }
        eprintln!("loading sparse system from {mtx}...");
        match kaczmarz::data::io::load_mtx_system(std::path::Path::new(&mtx), seed) {
            Ok(sys) => {
                let a = sys.a.as_csr().expect("mtx loads are CSR");
                eprintln!(
                    "loaded {} x {} system, {} stored entries ({:.2}% dense)",
                    sys.rows(),
                    sys.cols(),
                    a.nnz(),
                    100.0 * a.density()
                );
                sys
            }
            Err(e) => {
                eprintln!("failed to load {mtx}: {e}");
                std::process::exit(2);
            }
        }
    };
    // Defaults that depend on the system shape come after it exists. A
    // host tune file's rkab_block takes over the --bs default (an explicit
    // --bs always wins).
    let n = sys.cols();
    let bs = args.get_parse("bs", tuned.rkab_block.unwrap_or(n));

    // --residual stops on ‖Ax - b‖² (the reference-free serving criterion,
    // checked every --check-every iterations); default is the paper's
    // reference-error rule. --history records the dual-channel convergence
    // curve every `step` iterations (works with either criterion).
    let mut opts = SolveOptions::default()
        .with_tolerance(args.get_parse("tolerance", 1e-8))
        .with_max_iterations(args.get_parse("max-iterations", 100_000_000))
        .with_history_step(args.get_parse("history", 0usize));
    if args.has("residual") {
        opts = opts.with_residual_stopping(
            args.get_parse("tolerance", 1e-8),
            args.get_parse("check-every", 32usize),
        );
    }

    // --watch: stream the dual-channel curve line-by-line while the solve
    // runs, via a callback telemetry sink. Samples flow from the solve's
    // amortized checkpoints; if the run has none yet (reference-error
    // stopping with no --history), default to a history step so there is
    // something to stream.
    if args.has("watch") {
        if opts.history_step == 0 && !args.has("residual") {
            opts = opts.with_history_step(args.get_parse("history", 1000usize));
        }
        opts = opts.with_progress(kaczmarz::metrics::ProgressSink::callback(|s| {
            match s.reference_err {
                Some(e) => println!(
                    "watch k={:<10} ||Ax-b||={:<12.6e} ||x-x_ref||={:<12.6e} t={:.3}s",
                    s.k,
                    s.residual,
                    e,
                    s.elapsed.as_secs_f64()
                ),
                None => println!(
                    "watch k={:<10} ||Ax-b||={:<12.6e} t={:.3}s",
                    s.k,
                    s.residual,
                    s.elapsed.as_secs_f64()
                ),
            }
        }));
    }

    let r = match method.as_str() {
        "ck" => CkSolver::new().solve(&sys, &opts),
        "rk" => RkSolver::new(seed).with_sampling(sampling).solve(&sys, &opts),
        "rka" => {
            let mut solver = RkaSolver::new(seed, q, alpha).with_sampling(sampling);
            if norm_weights {
                solver = solver.with_weights(Weights::InverseRowNorm(alpha));
            }
            solver.solve(&sys, &opts)
        }
        "rkab" => {
            let mut solver = RkabSolver::new(seed, q, bs, alpha).with_sampling(sampling);
            if norm_weights {
                solver = solver.with_weights(Weights::InverseRowNorm(alpha));
            }
            solver.solve(&sys, &opts)
        }
        "rek" => RekSolver::new(seed).solve(&sys, &opts),
        "rka-par" => ParallelRka::new(seed, q, alpha).solve(&sys, &opts),
        "rkab-par" => ParallelRkab::new(seed, q, bs, alpha).solve(&sys, &opts),
        "asyrk" => AsyRkSolver::new(seed, q).solve(&sys, &opts),
        "pjrt" => {
            let dir = default_artifacts_dir();
            let solver = PjrtRkabSolver::new(&dir, seed, q, bs, n, alpha)
                .expect("PJRT solver (run `make artifacts`; shape must be exported)");
            solver.solve(&sys, &opts).expect("PJRT solve")
        }
        other => {
            eprintln!(
                "unknown method '{other}'; try: ck, rk, rka, rkab, rek, \
                 rka-par, rkab-par, asyrk, pjrt"
            );
            std::process::exit(2);
        }
    };
    print_result(&method, sys.error_sq(&r.x), &r);
}

/// Parse a `--preload` entry `name:MxN:seed` (seed optional, default 1).
fn parse_preload(spec: &str) -> Option<(String, usize, usize, u32)> {
    let (name, rest) = spec.split_once(':')?;
    let (shape, seed) = match rest.split_once(':') {
        Some((shape, seed)) => (shape, seed.parse().ok()?),
        None => (rest, 1u32),
    };
    let (m, n) = shape.split_once('x')?;
    Some((name.to_string(), m.parse().ok()?, n.parse().ok()?, seed))
}

/// `kaczmarz serve`: boot the framed-TCP serving front end and run until
/// killed. Preloaded systems are generated consistent (known x*), resident
/// in the LRU registry, and served by persistent admission lanes.
fn cmd_serve(args: &Args) {
    let addr = args.get("addr", "127.0.0.1:7070");
    let capacity_mb = args.get_parse("capacity-mb", 512usize);
    let lanes = args.get_parse(
        "lanes",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
    );
    let max_pending = args.get_parse("max-pending", 64usize);
    let preload = args.get("preload", "demo:2000x200:1");

    let registry = std::sync::Arc::new(SystemRegistry::new(capacity_mb.saturating_mul(1 << 20)));
    for spec in preload.split(',').filter(|s| !s.trim().is_empty()) {
        let Some((name, m, n, seed)) = parse_preload(spec.trim()) else {
            eprintln!("bad --preload entry '{spec}'; want name:MxN:seed");
            std::process::exit(2);
        };
        eprintln!("loading resident system '{name}': {m} x {n} (seed {seed})...");
        let evicted = registry.insert(&name, DatasetBuilder::new(m, n).seed(seed).consistent());
        for gone in evicted {
            eprintln!("evicted '{gone}' (LRU, over {capacity_mb} MB budget)");
        }
    }
    let front = std::sync::Arc::new(SolveFrontEnd::new(
        registry,
        FrontEndConfig { lanes, max_pending },
    ));
    let server = match WireServer::bind(&addr, front) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("cannot bind {addr}: {e}");
            std::process::exit(1);
        }
    };
    let handle = match server.spawn() {
        Ok(h) => h,
        Err(e) => {
            eprintln!("cannot start accept loop: {e}");
            std::process::exit(1);
        }
    };
    // stdout so scripts can scrape the resolved address (port 0 supported).
    println!("serving on {}", handle.addr());
    println!("lanes={lanes} max_pending={max_pending} capacity_mb={capacity_mb}");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `kaczmarz submit`: one streaming job against a running server, with
/// smoke-test assertions baked in (see the module docs).
fn cmd_submit(args: &Args) {
    let addr = args.get("addr", "127.0.0.1:7070");
    let mut frame = SubmitFrame::new(args.get("system", "demo"));
    frame.solver = args.get("solver", "rk");
    frame.seed = args.get_parse("seed", 0u32);
    frame.tol = args.get_parse("tol", 1e-8);
    frame.check = args.get_parse("check", 32usize);
    if args.has("max-iterations") {
        frame.max_iterations = Some(args.get_parse("max-iterations", 0usize));
    }
    if args.has("fixed") {
        frame.fixed_iterations = Some(args.get_parse("fixed", 0usize));
    }
    if args.has("deadline-ms") {
        frame.deadline_ms = Some(args.get_parse("deadline-ms", 0u64));
    }
    let cancel_after = args.get_parse("cancel-after", 0usize); // 0 = never
    let min_samples = args.get_parse("min-samples", 0usize);
    let expect_error = args.get("expect-error", "");

    let cancel_addr = addr.clone();
    let mut samples = 0usize;
    let outcome = client::submit_streaming(&addr, &frame, |id, k, residual, ms| {
        samples += 1;
        println!("sample id={id} k={k} residual={residual:.6e} t={ms}ms");
        if cancel_after > 0 && samples == cancel_after {
            match client::cancel(&cancel_addr, id) {
                Ok(applied) => eprintln!("cancel sent for job {id} (applied={applied})"),
                Err(e) => eprintln!("cancel for job {id} failed: {e}"),
            }
        }
    });
    let (id, outcome) = match outcome {
        Ok(v) => v,
        Err(e) => {
            eprintln!("submit failed: {e}");
            std::process::exit(1);
        }
    };
    match &outcome {
        RemoteOutcome::Done { iterations, converged, residual, queue_wait_ms, dropped } => {
            println!(
                "done id={id} iterations={iterations} converged={converged} \
                 residual={residual:.6e} queue_wait_ms={queue_wait_ms} dropped={dropped}"
            );
        }
        RemoteOutcome::Failed { kind, msg } => {
            println!("failed id={id} kind={} msg={msg}", kind.token());
        }
    }

    // Smoke assertions: exit 1 on any violated expectation.
    let mut ok = true;
    if samples < min_samples {
        eprintln!("ASSERT FAILED: streamed {samples} samples, need >= {min_samples}");
        ok = false;
    }
    if expect_error.is_empty() {
        if !matches!(outcome, RemoteOutcome::Done { .. }) {
            eprintln!("ASSERT FAILED: expected clean completion, got {outcome:?}");
            ok = false;
        }
    } else {
        match &outcome {
            RemoteOutcome::Failed { kind, .. } if kind.token() == expect_error => {}
            other => {
                eprintln!("ASSERT FAILED: expected error kind '{expect_error}', got {other:?}");
                ok = false;
            }
        }
    }
    if !ok {
        std::process::exit(1);
    }
}

fn cmd_info(tuned: &TunedParams) {
    println!("kaczmarz {} — parallel Randomized Kaczmarz reproduction", kaczmarz::version());
    println!(
        "cores: {}",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(0)
    );
    // Kernel dispatch: what this host supports vs what this process runs
    // (KACZMARZ_KERNEL=scalar forces the bitwise reference path).
    println!(
        "kernels: {} (host supports {})",
        kaczmarz::linalg::active_flavor().name(),
        kaczmarz::linalg::detected_flavor().name()
    );
    println!(
        "gemv panel: {}{}",
        kaczmarz::linalg::gemv_panel(),
        if tuned.gemv_panel.is_some() { " (tuned)" } else { "" }
    );
    let dir = default_artifacts_dir();
    match Manifest::load(&dir) {
        Ok(m) => println!("artifacts: {} entries at {}", m.entries().len(), dir.display()),
        Err(_) => println!("artifacts: NOT BUILT (run `make artifacts`) at {}", dir.display()),
    }
}
