//! Streaming solve telemetry: live convergence samples pushed to a
//! [`ProgressSink`] *while the solve runs*.
//!
//! Histories ([`super::History`]) answer "what did the convergence curve
//! look like?" — after the solve returns. Long-running serving jobs need the
//! other tense: *is this RKA job still making progress right now?* Moorman
//! et al. (arXiv:2002.04126) motivate exactly this — RKA's value on
//! inconsistent systems is its error-horizon behavior, which an operator can
//! only act on by watching the residual live. And Liu, Wright & Sridhar's
//! asynchronous solver (arXiv:1401.4780) dictates the design constraint: a
//! monitor that stalls workers destroys the async speedup, so a sink must
//! **never block the iterate**.
//!
//! Two sink flavors, both non-blocking by construction:
//!
//! - [`ProgressSink::callback`] — the solve invokes your closure inline at
//!   each telemetry checkpoint. Latency on the solver thread is whatever the
//!   closure costs, so keep it cheap (push to your own queue, update a
//!   gauge, print a line);
//! - [`ProgressSink::bounded`] — a bounded in-memory channel. The solver
//!   side **drops the oldest sample** when the channel is full (a live
//!   monitor wants the freshest state, not a complete backlog) and never
//!   waits for the consumer; the [`ProgressReceiver`] side polls with
//!   [`ProgressReceiver::try_recv`] / [`ProgressReceiver::recv_timeout`] /
//!   [`ProgressReceiver::drain`].
//!
//! Samples are emitted at the solve's *existing* amortized checkpoints —
//! history samples (`history_step`) and residual stopping checkpoints
//! (`check_every`) — where the `O(m·n)` residual GEMV is already being paid,
//! so attaching a sink adds **zero new GEMVs** to the hot path (the
//! `bench_micro_hotpath` sink-overhead rows put a number on this). A solve
//! that never computes a residual (reference-error stopping or a fixed
//! budget, with `history_step = 0`) has no checkpoints and emits nothing:
//! pair the sink with residual stopping or a history step.
//!
//! # Example
//!
//! ```
//! use kaczmarz::data::DatasetBuilder;
//! use kaczmarz::metrics::ProgressSink;
//! use kaczmarz::solvers::{rk::RkSolver, SolveOptions, Solver};
//!
//! let sys = DatasetBuilder::new(200, 10).seed(1).consistent();
//! let (sink, rx) = ProgressSink::bounded(64);
//! let opts = SolveOptions::default()
//!     .with_residual_stopping(1e-10, 16)
//!     .with_progress(sink);
//! let result = RkSolver::new(7).solve(&sys, &opts);
//! assert!(result.converged);
//! let samples = rx.drain();
//! assert!(!samples.is_empty());
//! // The residual stream decays toward the stopping tolerance.
//! assert!(samples.last().unwrap().residual < samples[0].residual);
//! ```

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// One live telemetry sample, emitted mid-solve at an amortized checkpoint.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sample {
    /// Iteration number (for AsyRK: the racy global update count the
    /// monitor polls — same convention as its history).
    pub k: usize,
    /// Residual norm `‖A x^(k) - b‖` — always present; the value the
    /// checkpoint's GEMV already computed.
    pub residual: f64,
    /// Reference-error norm `‖x^(k) - x_ref‖`, only when the system carries
    /// a reference solution (`None` on serving systems, matching the
    /// dual-channel [`super::History`] contract).
    pub reference_err: Option<f64>,
    /// Wall-clock time since the solve started.
    pub elapsed: Duration,
}

/// Shared state of a bounded progress channel.
struct ChannelShared {
    state: Mutex<ChannelState>,
    ready: Condvar,
}

struct ChannelState {
    queue: VecDeque<Sample>,
    capacity: usize,
    /// Samples discarded because the channel was full (drop-oldest policy).
    dropped: u64,
}

#[derive(Clone)]
enum SinkKind {
    Callback(Arc<dyn Fn(&Sample) + Send + Sync>),
    Channel(Arc<ChannelShared>),
}

/// A non-blocking consumer of live [`Sample`]s, attached to a solve via
/// [`crate::solvers::SolveOptions::with_progress`].
///
/// Cloning a sink is cheap (it is `Arc`-backed) and clones feed the same
/// destination. A sink never influences the solve it observes: it reads the
/// iterate's already-computed metrics and cannot stall, reorder, or perturb
/// the iteration (`tests/telemetry_streaming.rs` pins the solved `x` bitwise
/// against a sink-free run). See the [module docs](self) for flavors,
/// checkpoint placement, and the zero-new-GEMV guarantee.
#[derive(Clone)]
pub struct ProgressSink {
    kind: SinkKind,
}

impl fmt::Debug for ProgressSink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.kind {
            SinkKind::Callback(_) => f.write_str("ProgressSink::Callback"),
            SinkKind::Channel(c) => {
                let st = c.state.lock().unwrap();
                f.debug_struct("ProgressSink::Channel")
                    .field("capacity", &st.capacity)
                    .field("queued", &st.queue.len())
                    .field("dropped", &st.dropped)
                    .finish()
            }
        }
    }
}

impl ProgressSink {
    /// Sink that invokes `f` inline on the solver (or monitor) thread at
    /// each telemetry checkpoint. Keep `f` cheap: its latency is paid by
    /// the solve — though only at the amortized checkpoints, never per
    /// iteration.
    ///
    /// ```
    /// use kaczmarz::metrics::ProgressSink;
    /// use std::sync::atomic::{AtomicUsize, Ordering};
    /// use std::sync::Arc;
    ///
    /// let seen = Arc::new(AtomicUsize::new(0));
    /// let counter = Arc::clone(&seen);
    /// let sink = ProgressSink::callback(move |_sample| {
    ///     counter.fetch_add(1, Ordering::Relaxed);
    /// });
    /// // Attach via SolveOptions::with_progress(sink); nothing emitted yet.
    /// assert_eq!(seen.load(Ordering::Relaxed), 0);
    /// # let _ = sink;
    /// ```
    pub fn callback(f: impl Fn(&Sample) + Send + Sync + 'static) -> ProgressSink {
        ProgressSink { kind: SinkKind::Callback(Arc::new(f)) }
    }

    /// Bounded-channel sink: the solve pushes samples, the returned
    /// [`ProgressReceiver`] polls them from another thread. When the channel
    /// holds `capacity` samples the **oldest is dropped** to make room —
    /// the producer never waits, so a slow (or absent) consumer cannot
    /// stall the iterate. Dropped-sample count is reported by
    /// [`ProgressReceiver::dropped`].
    pub fn bounded(capacity: usize) -> (ProgressSink, ProgressReceiver) {
        assert!(capacity >= 1, "channel capacity must be >= 1");
        let shared = Arc::new(ChannelShared {
            state: Mutex::new(ChannelState {
                queue: VecDeque::with_capacity(capacity),
                capacity,
                dropped: 0,
            }),
            ready: Condvar::new(),
        });
        (
            ProgressSink { kind: SinkKind::Channel(Arc::clone(&shared)) },
            ProgressReceiver { shared },
        )
    }

    /// Samples this sink has discarded so far under the drop-oldest policy
    /// (always 0 for the callback flavor, which has no queue to overflow).
    /// The serving layer reads this after a job finishes to surface the
    /// count in `SolveReport::dropped_samples` — same number the consumer
    /// side sees via [`ProgressReceiver::dropped`].
    pub fn dropped(&self) -> u64 {
        match &self.kind {
            SinkKind::Callback(_) => 0,
            SinkKind::Channel(c) => c.state.lock().unwrap().dropped,
        }
    }

    /// Push one sample into the sink (called by the solve's `StopCheck` at
    /// its checkpoints). Never blocks on a consumer: the callback flavor
    /// runs inline, the channel flavor drops the oldest queued sample when
    /// full.
    pub(crate) fn emit(&self, sample: Sample) {
        match &self.kind {
            SinkKind::Callback(f) => f(&sample),
            SinkKind::Channel(c) => {
                let mut st = c.state.lock().unwrap();
                if st.queue.len() == st.capacity {
                    st.queue.pop_front();
                    st.dropped += 1;
                }
                st.queue.push_back(sample);
                drop(st);
                c.ready.notify_one();
            }
        }
    }
}

/// Consumer half of [`ProgressSink::bounded`].
///
/// All methods are poll-style: nothing here can block indefinitely, and
/// nothing the receiver does can stall the producing solve (the producer
/// side drops oldest instead of waiting). The channel has no "closed"
/// state — a solve simply stops emitting when it returns — so a monitor
/// loop should poll with [`ProgressReceiver::recv_timeout`] until the solve
/// call it is watching completes.
pub struct ProgressReceiver {
    shared: Arc<ChannelShared>,
}

impl ProgressReceiver {
    /// Pop the oldest queued sample, or `None` when the channel is empty.
    pub fn try_recv(&self) -> Option<Sample> {
        self.shared.state.lock().unwrap().queue.pop_front()
    }

    /// Pop the oldest queued sample, waiting up to `timeout` for one to
    /// arrive. `None` on timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Sample> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(s) = st.queue.pop_front() {
                return Some(s);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, res) = self.shared.ready.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if res.timed_out() && st.queue.is_empty() {
                return None;
            }
        }
    }

    /// Pop everything currently queued, oldest first.
    pub fn drain(&self) -> Vec<Sample> {
        self.shared.state.lock().unwrap().queue.drain(..).collect()
    }

    /// Number of samples currently queued.
    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// True when nothing is queued right now.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Samples discarded so far by the drop-oldest policy (a nonzero value
    /// means the consumer fell behind the producer; the *freshest* samples
    /// were kept).
    pub fn dropped(&self) -> u64 {
        self.shared.state.lock().unwrap().dropped
    }
}

impl fmt::Debug for ProgressReceiver {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let st = self.shared.state.lock().unwrap();
        f.debug_struct("ProgressReceiver")
            .field("capacity", &st.capacity)
            .field("queued", &st.queue.len())
            .field("dropped", &st.dropped)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn sample(k: usize, residual: f64) -> Sample {
        Sample { k, residual, reference_err: None, elapsed: Duration::from_millis(k as u64) }
    }

    #[test]
    fn callback_sink_runs_inline() {
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        let sink = ProgressSink::callback(move |s| {
            assert!(s.residual >= 0.0);
            c.fetch_add(1, Ordering::Relaxed);
        });
        for k in 0..5 {
            sink.emit(sample(k, 1.0));
        }
        assert_eq!(count.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn bounded_channel_delivers_in_order() {
        let (sink, rx) = ProgressSink::bounded(8);
        for k in 0..5 {
            sink.emit(sample(k, k as f64));
        }
        let got = rx.drain();
        assert_eq!(got.len(), 5);
        assert!(got.windows(2).all(|w| w[0].k < w[1].k));
        assert_eq!(rx.dropped(), 0);
        assert!(rx.is_empty());
    }

    #[test]
    fn full_channel_drops_oldest_never_blocks() {
        let (sink, rx) = ProgressSink::bounded(3);
        for k in 0..10 {
            sink.emit(sample(k, 0.0)); // never blocks, no consumer running
        }
        // Producer and consumer sides agree on the drop count.
        assert_eq!(sink.dropped(), 7);
        let got = rx.drain();
        // Freshest three survive; seven oldest were dropped.
        assert_eq!(got.iter().map(|s| s.k).collect::<Vec<_>>(), vec![7, 8, 9]);
        assert_eq!(rx.dropped(), 7);
    }

    #[test]
    fn callback_sink_reports_zero_dropped() {
        let sink = ProgressSink::callback(|_| {});
        for k in 0..5 {
            sink.emit(sample(k, 0.0));
        }
        assert_eq!(sink.dropped(), 0);
    }

    #[test]
    fn recv_timeout_sees_cross_thread_samples() {
        let (sink, rx) = ProgressSink::bounded(4);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            sink.emit(sample(1, 2.0));
        });
        let got = rx.recv_timeout(Duration::from_secs(5)).expect("sample must arrive");
        assert_eq!(got.k, 1);
        t.join().unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), None);
    }

    #[test]
    fn try_recv_is_nonblocking() {
        let (sink, rx) = ProgressSink::bounded(2);
        assert_eq!(rx.try_recv(), None);
        sink.emit(sample(3, 1.5));
        assert_eq!(rx.try_recv().map(|s| s.k), Some(3));
        assert_eq!(rx.try_recv(), None);
    }

    #[test]
    fn cloned_sinks_feed_one_channel() {
        let (sink, rx) = ProgressSink::bounded(8);
        let clone = sink.clone();
        sink.emit(sample(0, 1.0));
        clone.emit(sample(1, 0.5));
        assert_eq!(rx.len(), 2);
    }

    #[test]
    fn debug_formats_do_not_panic() {
        let (sink, rx) = ProgressSink::bounded(2);
        sink.emit(sample(0, 1.0));
        let _ = format!("{sink:?} {rx:?}");
        let cb = ProgressSink::callback(|_| {});
        assert!(format!("{cb:?}").contains("Callback"));
    }
}
