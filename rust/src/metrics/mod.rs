//! Measurement utilities: timers, step-sampled histories, summary stats,
//! and streaming telemetry sinks ([`progress`]).

pub mod progress;

pub use progress::{ProgressReceiver, ProgressSink, Sample};

use std::time::{Duration, Instant};

/// Wall-clock stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed time.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn seconds(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Which measurement channel of a [`History`] to read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Channel {
    /// `‖x^(k) - x_ref‖` against the known reference solution — the paper's
    /// §3.5 reproduction protocol. Only available when the system carries a
    /// reference ([`History::has_reference_channel`]).
    ReferenceError,
    /// `‖A x^(k) - b‖` — computable for *any* system, reference or not.
    /// This is the serving-side convergence curve and the quantity Moorman
    /// et al. (arXiv:2002.04126) and Liu–Wright (arXiv:1401.4780) state
    /// their guarantees in.
    Residual,
}

/// Step-sampled convergence history, mirroring the paper's §3.5 protocol
/// ("stored the error and residual every `step` iterations") — made
/// **dual-channel and reference-optional**:
///
/// - the **residual channel** (`‖Ax - b‖`) is recorded for *every* sample —
///   it needs nothing but the system itself;
/// - the **reference-error channel** (`‖x - x_ref‖`) is recorded only when
///   the system actually carries a reference solution. On reference-free
///   (serving) systems it stays empty instead of panicking.
///
/// [`History::min_error`] and [`History::tail_error`] read the
/// reference-error channel when it is populated and fall back to the
/// residual channel otherwise ([`History::primary_channel`]); use
/// [`History::min_in`] / [`History::tail_in`] to address a channel
/// explicitly.
#[derive(Clone, Debug, Default)]
pub struct History {
    /// Sampling period; 0 disables recording.
    pub step: usize,
    /// Recorded iteration numbers.
    pub iterations: Vec<usize>,
    /// Reference-error channel `‖x^(k) - x_ref‖` — one entry per recorded
    /// iteration when a reference exists, **empty** on reference-free
    /// systems.
    pub errors: Vec<f64>,
    /// Residual channel `‖A x^(k) - b‖` — one entry per recorded iteration,
    /// always populated.
    pub residuals: Vec<f64>,
}

impl History {
    /// History that records every `step` iterations (0 = never).
    pub fn every(step: usize) -> Self {
        History { step, ..Default::default() }
    }

    /// Should iteration `k` be recorded?
    #[inline]
    pub fn due(&self, k: usize) -> bool {
        self.step != 0 && k % self.step == 0
    }

    /// Record one sample. `error` is `None` on reference-free systems; a
    /// history must be recorded consistently — either every sample carries
    /// the reference channel or none does (the per-solve recorder in
    /// `StopCheck` guarantees this by deciding once per solve).
    pub fn record(&mut self, k: usize, error: Option<f64>, residual: f64) {
        if let Some(e) = error {
            debug_assert_eq!(
                self.errors.len(),
                self.iterations.len(),
                "reference channel must be all-or-nothing across samples"
            );
            self.errors.push(e);
        }
        self.iterations.push(k);
        self.residuals.push(residual);
    }

    /// Number of samples taken.
    pub fn len(&self) -> usize {
        self.iterations.len()
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.iterations.is_empty()
    }

    /// True when the reference-error channel was recorded (the system
    /// carried a reference solution at solve time).
    pub fn has_reference_channel(&self) -> bool {
        !self.errors.is_empty()
    }

    /// The samples of one channel. [`Channel::ReferenceError`] may be empty
    /// (reference-free solve); [`Channel::Residual`] has one entry per
    /// recorded iteration.
    pub fn channel(&self, c: Channel) -> &[f64] {
        match c {
            Channel::ReferenceError => &self.errors,
            Channel::Residual => &self.residuals,
        }
    }

    /// The channel [`History::min_error`] / [`History::tail_error`] read:
    /// the reference-error channel when populated, the residual channel
    /// otherwise — so convergence-curve consumers work unchanged on
    /// reference-free systems.
    pub fn primary_channel(&self) -> Channel {
        if self.has_reference_channel() {
            Channel::ReferenceError
        } else {
            Channel::Residual
        }
    }

    /// Minimum recorded value of one channel (`None` when the channel is
    /// empty). NaN-safe: ordered by [`f64::total_cmp`].
    pub fn min_in(&self, c: Channel) -> Option<f64> {
        self.channel(c).iter().copied().min_by(|a, b| a.total_cmp(b))
    }

    /// Mean of the last `k` recorded values of one channel (`None` when the
    /// channel is empty or `k` is 0 — an empty tail has no mean).
    pub fn tail_in(&self, c: Channel, k: usize) -> Option<f64> {
        let ch = self.channel(c);
        if ch.is_empty() || k == 0 {
            return None;
        }
        let tail = &ch[ch.len().saturating_sub(k)..];
        Some(tail.iter().sum::<f64>() / tail.len() as f64)
    }

    /// Minimum recorded value of the [primary channel](History::primary_channel)
    /// (the convergence-horizon estimate).
    pub fn min_error(&self) -> Option<f64> {
        self.min_in(self.primary_channel())
    }

    /// Mean of the last `k` recorded values of the
    /// [primary channel](History::primary_channel) (the stabilized horizon).
    pub fn tail_error(&self, k: usize) -> Option<f64> {
        self.tail_in(self.primary_channel(), k)
    }
}

/// Mean and (population) standard deviation.
///
/// An empty slice yields `(0.0, 0.0)` — callers that must distinguish
/// "no data" from "zero mean" (e.g. the calibration protocol) have to check
/// emptiness themselves *before* averaging; `coordinator::calibrate` does
/// exactly that and returns [`crate::error::Error::CalibrationFailed`]
/// instead of a silent zero.
pub fn mean_std(values: &[f64]) -> (f64, f64) {
    if values.is_empty() {
        return (0.0, 0.0);
    }
    let mean = values.iter().sum::<f64>() / values.len() as f64;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / values.len() as f64;
    (mean, var.sqrt())
}

/// Median of a sample (copies + sorts; fine for experiment-sized data).
///
/// An empty slice yields `0.0` (same sentinel convention as [`mean_std`]).
/// NaN inputs are tolerated: ordering uses [`f64::total_cmp`], which sorts
/// NaNs to the ends instead of panicking mid-sort the way
/// `partial_cmp(..).unwrap()` did.
pub fn median(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v = values.to_vec();
    v.sort_by(f64::total_cmp);
    let mid = v.len() / 2;
    if v.len() % 2 == 0 {
        (v[mid - 1] + v[mid]) / 2.0
    } else {
        v[mid]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.seconds() >= 0.004);
    }

    #[test]
    fn history_due_and_record() {
        let mut h = History::every(10);
        assert!(h.due(0));
        assert!(!h.due(5));
        assert!(h.due(20));
        h.record(0, Some(1.0), 2.0);
        h.record(10, Some(0.5), 1.0);
        assert_eq!(h.len(), 2);
        assert_eq!(h.min_error(), Some(0.5));
        assert!(h.has_reference_channel());
        assert_eq!(h.primary_channel(), Channel::ReferenceError);
        assert_eq!(h.min_in(Channel::Residual), Some(1.0));
    }

    #[test]
    fn history_disabled() {
        let h = History::every(0);
        assert!(!h.due(0));
        assert!(h.is_empty());
        assert_eq!(h.min_error(), None);
    }

    #[test]
    fn reference_free_history_reads_residual_channel() {
        // No reference at solve time: the error channel stays empty and the
        // min/tail accessors transparently read the residual channel.
        let mut h = History::every(1);
        h.record(0, None, 4.0);
        h.record(1, None, 2.0);
        h.record(2, None, 1.0);
        assert!(!h.has_reference_channel());
        assert!(h.errors.is_empty());
        assert_eq!(h.residuals.len(), 3);
        assert_eq!(h.primary_channel(), Channel::Residual);
        assert_eq!(h.min_error(), Some(1.0));
        assert_eq!(h.tail_error(2), Some(1.5));
        assert_eq!(h.min_in(Channel::ReferenceError), None);
        assert_eq!(h.tail_in(Channel::ReferenceError, 5), None);
    }

    #[test]
    fn tail_error_averages_last_k() {
        let mut h = History::every(1);
        for (i, e) in [4.0, 3.0, 2.0, 1.0].iter().enumerate() {
            h.record(i, Some(*e), 0.0);
        }
        assert_eq!(h.tail_error(2), Some(1.5));
        assert_eq!(h.tail_error(100), Some(2.5));
    }

    #[test]
    fn stats() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert_eq!(m, 5.0);
        assert_eq!(s, 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn median_survives_nan_and_empty() {
        // partial_cmp().unwrap() used to panic here; total_cmp sorts NaN to
        // the high end and the finite median survives.
        let v = [3.0, f64::NAN, 1.0, 2.0, f64::NAN];
        assert_eq!(median(&v), 3.0); // sorted: 1, 2, 3, NaN, NaN -> mid = 3
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn min_in_is_nan_safe() {
        let mut h = History::every(1);
        h.record(0, Some(f64::NAN), 5.0);
        h.record(1, Some(2.0), f64::NAN);
        // total_cmp orders NaN above every finite value: the finite min wins.
        assert_eq!(h.min_in(Channel::ReferenceError), Some(2.0));
        assert_eq!(h.min_in(Channel::Residual), Some(5.0));
    }
}
