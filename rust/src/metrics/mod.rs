//! Measurement utilities: timers, step-sampled histories, summary stats.

use std::time::{Duration, Instant};

/// Wall-clock stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed time.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn seconds(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Step-sampled history of (iteration, error, residual), mirroring the
/// paper's §3.5 protocol ("stored the error and residual every `step`
/// iterations").
#[derive(Clone, Debug, Default)]
pub struct History {
    /// Sampling period; 0 disables recording.
    pub step: usize,
    /// Recorded iteration numbers.
    pub iterations: Vec<usize>,
    /// `‖x^(k) - x_ref‖` at each recorded iteration.
    pub errors: Vec<f64>,
    /// `‖A x^(k) - b‖` at each recorded iteration.
    pub residuals: Vec<f64>,
}

impl History {
    /// History that records every `step` iterations (0 = never).
    pub fn every(step: usize) -> Self {
        History { step, ..Default::default() }
    }

    /// Should iteration `k` be recorded?
    #[inline]
    pub fn due(&self, k: usize) -> bool {
        self.step != 0 && k % self.step == 0
    }

    /// Record one sample.
    pub fn record(&mut self, k: usize, error: f64, residual: f64) {
        self.iterations.push(k);
        self.errors.push(error);
        self.residuals.push(residual);
    }

    /// Number of samples taken.
    pub fn len(&self) -> usize {
        self.iterations.len()
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.iterations.is_empty()
    }

    /// Minimum recorded error (the convergence-horizon estimate).
    pub fn min_error(&self) -> Option<f64> {
        self.errors.iter().copied().fold(None, |m, e| match m {
            None => Some(e),
            Some(v) => Some(v.min(e)),
        })
    }

    /// Mean of the last `k` recorded errors (the stabilized horizon).
    pub fn tail_error(&self, k: usize) -> Option<f64> {
        if self.errors.is_empty() {
            return None;
        }
        let tail = &self.errors[self.errors.len().saturating_sub(k)..];
        Some(tail.iter().sum::<f64>() / tail.len() as f64)
    }
}

/// Mean and (population) standard deviation.
pub fn mean_std(values: &[f64]) -> (f64, f64) {
    if values.is_empty() {
        return (0.0, 0.0);
    }
    let mean = values.iter().sum::<f64>() / values.len() as f64;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / values.len() as f64;
    (mean, var.sqrt())
}

/// Median of a sample (copies + sorts; fine for experiment-sized data).
pub fn median(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mid = v.len() / 2;
    if v.len() % 2 == 0 {
        (v[mid - 1] + v[mid]) / 2.0
    } else {
        v[mid]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.seconds() >= 0.004);
    }

    #[test]
    fn history_due_and_record() {
        let mut h = History::every(10);
        assert!(h.due(0));
        assert!(!h.due(5));
        assert!(h.due(20));
        h.record(0, 1.0, 2.0);
        h.record(10, 0.5, 1.0);
        assert_eq!(h.len(), 2);
        assert_eq!(h.min_error(), Some(0.5));
    }

    #[test]
    fn history_disabled() {
        let h = History::every(0);
        assert!(!h.due(0));
        assert!(h.is_empty());
        assert_eq!(h.min_error(), None);
    }

    #[test]
    fn tail_error_averages_last_k() {
        let mut h = History::every(1);
        for (i, e) in [4.0, 3.0, 2.0, 1.0].iter().enumerate() {
            h.record(i, *e, 0.0);
        }
        assert_eq!(h.tail_error(2), Some(1.5));
        assert_eq!(h.tail_error(100), Some(2.5));
    }

    #[test]
    fn stats() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert_eq!(m, 5.0);
        assert_eq!(s, 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }
}
