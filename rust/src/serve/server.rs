//! Framed-TCP binding of the serving front end ([`WireServer`]).
//!
//! One listener thread accepts connections; each connection gets a handler
//! thread that reads [`wire`](super::wire) request lines and writes reply
//! lines. Connection threads are control-plane only — solves always run on
//! the front end's persistent lanes, so the zero-per-solve-spawn discipline
//! holds: a connection thread costs one blocked `read_line`, never a solve.
//!
//! **Streaming.** A `SUBMIT ... stream=1` connection stays open: the
//! handler attaches a bounded drop-oldest
//! [`ProgressSink`](crate::metrics::ProgressSink) to the job and forwards
//! its `(k, residual, elapsed)` samples as `SAMPLE` lines until the
//! terminal `DONE`/`ERR` frame. If the client vanishes mid-stream (write
//! failure), the handler cancels the job — an abandoned client must not
//! keep consuming lane time (the same never-block discipline as the sink
//! itself).

use super::admission::{JobStatus, SolveFrontEnd, SubmitRequest};
use super::wire::{self, ErrKind, Reply, Request, SubmitFrame};
use crate::error::{Error, Result};
use crate::metrics::ProgressSink;
use crate::solvers::ck::CkSolver;
use crate::solvers::rek::RekSolver;
use crate::solvers::rk::RkSolver;
use crate::solvers::{SolveOptions, Solver};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How long a streaming handler waits for the next sample before checking
/// the job's terminal status.
const STREAM_POLL: Duration = Duration::from_millis(20);

/// Capacity of the per-streamed-job sample channel (drop-oldest beyond it).
const STREAM_CHANNEL: usize = 256;

/// A bound-but-not-yet-serving wire server.
pub struct WireServer {
    listener: TcpListener,
    front: Arc<SolveFrontEnd>,
}

impl WireServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7070"`, or port 0 for an ephemeral
    /// port) over `front`.
    pub fn bind(addr: &str, front: Arc<SolveFrontEnd>) -> Result<WireServer> {
        let listener = TcpListener::bind(addr).map_err(Error::Io)?;
        Ok(WireServer { listener, front })
    }

    /// The address actually bound (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().map_err(Error::Io)
    }

    /// Start accepting connections on a background thread.
    pub fn spawn(self) -> Result<ServerHandle> {
        let addr = self.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = Arc::clone(&stop);
            let front = Arc::clone(&self.front);
            let listener = self.listener;
            std::thread::Builder::new()
                .name("kaczmarz-serve-accept".into())
                .spawn(move || accept_loop(&listener, &front, &stop))
                .map_err(Error::Io)?
        };
        Ok(ServerHandle { addr, front: self.front, stop, accept: Some(accept) })
    }
}

/// A running wire server; dropping it (or calling
/// [`ServerHandle::shutdown`]) stops the accept loop.
pub struct ServerHandle {
    addr: SocketAddr,
    front: Arc<SolveFrontEnd>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The address clients should connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The front end behind the server (shared: in-process callers may
    /// submit directly while remote clients go through the wire).
    pub fn front(&self) -> &Arc<SolveFrontEnd> {
        &self.front
    }

    /// Stop accepting and join the accept loop. Live connection handlers
    /// finish their current exchange and exit when their client closes.
    pub fn shutdown(mut self) {
        self.stop_accepting();
    }

    fn stop_accepting(&mut self) {
        self.stop.store(true, Ordering::Release);
        // Unblock the accept() call with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_accepting();
    }
}

fn accept_loop(listener: &TcpListener, front: &Arc<SolveFrontEnd>, stop: &Arc<AtomicBool>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            return;
        }
        let Ok(stream) = conn else { continue };
        let front = Arc::clone(front);
        // Detached control-plane thread: it blocks on client reads and dies
        // with the connection; solves never run here.
        let _ = std::thread::Builder::new()
            .name("kaczmarz-serve-conn".into())
            .spawn(move || {
                let _ = handle_connection(stream, &front);
            });
    }
}

/// Serve one connection until the client closes or a write fails.
fn handle_connection(stream: TcpStream, front: &Arc<SolveFrontEnd>) -> std::io::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match wire::parse_request(&line) {
            Err(msg) => write_reply(&mut writer, &Reply::Err { kind: ErrKind::Proto, msg })?,
            Ok(Request::Ping) => write_reply(&mut writer, &Reply::Pong)?,
            Ok(Request::Stats) => {
                let stats = front.stats();
                write_reply(
                    &mut writer,
                    &Reply::Stats {
                        resident: front.registry().len(),
                        pending: front.pending(),
                        submitted: stats.submitted,
                        completed: stats.completed,
                        cancelled: stats.cancelled,
                        deadline_missed: stats.deadline_missed,
                        rejected: stats.rejected,
                    },
                )?;
            }
            Ok(Request::Cancel { id }) => {
                let applied = front.cancel(id);
                write_reply(&mut writer, &Reply::Ack { id, applied })?;
            }
            Ok(Request::Poll { id }) => {
                let reply = match front.status(id) {
                    None => Reply::Err {
                        kind: ErrKind::Invalid,
                        msg: format!("unknown job id {id}"),
                    },
                    Some(JobStatus::Queued) => Reply::Queued { id },
                    Some(JobStatus::Running) => Reply::Running { id },
                    Some(terminal) => terminal_reply(id, &terminal),
                };
                write_reply(&mut writer, &reply)?;
            }
            Ok(Request::Submit(frame)) => handle_submit(front, &mut writer, frame)?,
        }
    }
    Ok(())
}

fn handle_submit(
    front: &Arc<SolveFrontEnd>,
    writer: &mut BufWriter<TcpStream>,
    frame: SubmitFrame,
) -> std::io::Result<()> {
    let Some(solver) = solver_for(&frame) else {
        return write_reply(
            writer,
            &Reply::Err {
                kind: ErrKind::Invalid,
                msg: format!("unknown solver '{}' (have: rk, rek, ck)", frame.solver),
            },
        );
    };
    let mut opts = SolveOptions::default().with_residual_stopping(frame.tol, frame.check.max(1));
    if let Some(max) = frame.max_iterations {
        opts = opts.with_max_iterations(max);
    }
    if let Some(fixed) = frame.fixed_iterations {
        opts = opts.with_fixed_iterations(fixed);
    }
    let receiver = if frame.stream {
        let (sink, rx) = ProgressSink::bounded(STREAM_CHANNEL);
        opts = opts.with_progress(sink);
        Some(rx)
    } else {
        None
    };
    let mut request = SubmitRequest::new(frame.system, solver).with_opts(opts);
    if let Some(ms) = frame.deadline_ms {
        request = request.with_deadline(Duration::from_millis(ms));
    }
    let id = match front.submit(request) {
        Ok(id) => id,
        Err(e) => {
            return write_reply(
                writer,
                &Reply::Err { kind: ErrKind::of(&e), msg: e.to_string() },
            );
        }
    };
    write_reply(writer, &Reply::Queued { id })?;

    let Some(rx) = receiver else { return Ok(()) };
    // Streaming mode: forward samples until the job turns terminal. A write
    // failure means the client is gone — cancel so the lane stops burning
    // checkpoints on an unobserved job.
    let stream_outcome: std::io::Result<()> = (|| {
        loop {
            if let Some(sample) = rx.recv_timeout(STREAM_POLL) {
                write_reply(writer, &sample_reply(id, &sample))?;
                continue;
            }
            match front.status(id) {
                Some(status) if status.is_terminal() => {
                    for sample in rx.drain() {
                        write_reply(writer, &sample_reply(id, &sample))?;
                    }
                    write_reply(writer, &terminal_reply(id, &status))?;
                    return Ok(());
                }
                Some(_) => continue,
                None => return Ok(()), // forgotten externally; nothing to stream
            }
        }
    })();
    if stream_outcome.is_err() {
        front.cancel(id);
    }
    stream_outcome
}

/// Map a wire solver selector onto a crate solver.
fn solver_for(frame: &SubmitFrame) -> Option<Arc<dyn Solver + Send + Sync>> {
    Some(match frame.solver.as_str() {
        "rk" => Arc::new(RkSolver::new(frame.seed)),
        "rek" => Arc::new(RekSolver::new(frame.seed)),
        "ck" => Arc::new(CkSolver::new()),
        _ => return None,
    })
}

fn sample_reply(id: u64, sample: &crate::metrics::Sample) -> Reply {
    Reply::Sample {
        id,
        k: sample.k,
        residual: sample.residual,
        reference_err: sample.reference_err,
        elapsed_ms: sample.elapsed.as_millis() as u64,
    }
}

fn terminal_reply(id: u64, status: &JobStatus) -> Reply {
    match status {
        JobStatus::Done(report) => Reply::Done {
            id,
            iterations: report.result.iterations,
            converged: report.result.converged,
            residual: report.residual_norm,
            queue_wait_ms: report.queue_wait.as_millis() as u64,
            dropped: report.dropped_samples,
        },
        JobStatus::Failed(e) => Reply::Err { kind: ErrKind::of(e), msg: e.to_string() },
        _ => unreachable!("terminal_reply called on a non-terminal status"),
    }
}

fn write_reply(writer: &mut BufWriter<TcpStream>, reply: &Reply) -> std::io::Result<()> {
    writer.write_all(reply.to_line().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::serve::admission::FrontEndConfig;
    use crate::serve::registry::SystemRegistry;

    fn boot() -> ServerHandle {
        let registry = Arc::new(SystemRegistry::new(usize::MAX));
        registry.insert("demo", DatasetBuilder::new(200, 12).seed(1).consistent());
        let front = Arc::new(SolveFrontEnd::new(
            registry,
            FrontEndConfig { lanes: 2, max_pending: 16 },
        ));
        WireServer::bind("127.0.0.1:0", front).unwrap().spawn().unwrap()
    }

    fn exchange(conn: &TcpStream, req: &Request) -> Reply {
        let mut w = BufWriter::new(conn.try_clone().unwrap());
        w.write_all(req.to_line().as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
        w.flush().unwrap();
        read_reply(conn)
    }

    fn read_reply(conn: &TcpStream) -> Reply {
        let mut r = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        wire::parse_reply(&line).unwrap()
    }

    #[test]
    fn ping_stats_and_unknown_solver_over_a_socket() {
        let server = boot();
        let conn = TcpStream::connect(server.addr()).unwrap();
        assert_eq!(exchange(&conn, &Request::Ping), Reply::Pong);
        match exchange(&conn, &Request::Stats) {
            Reply::Stats { resident, submitted, .. } => {
                assert_eq!(resident, 1);
                assert_eq!(submitted, 0);
            }
            other => panic!("expected STATS, got {other:?}"),
        }
        let mut bad = SubmitFrame::new("demo");
        bad.solver = "sor".into();
        match exchange(&conn, &Request::Submit(bad)) {
            Reply::Err { kind: ErrKind::Invalid, msg } => assert!(msg.contains("sor")),
            other => panic!("expected invalid-solver ERR, got {other:?}"),
        }
        server.shutdown();
    }

    #[test]
    fn submit_poll_roundtrip_reaches_done() {
        let server = boot();
        let conn = TcpStream::connect(server.addr()).unwrap();
        let id = match exchange(&conn, &Request::Submit(SubmitFrame::new("demo"))) {
            Reply::Queued { id } => id,
            other => panic!("expected QUEUED, got {other:?}"),
        };
        let deadline = std::time::Instant::now() + Duration::from_secs(60);
        loop {
            match exchange(&conn, &Request::Poll { id }) {
                Reply::Done { converged, residual, .. } => {
                    assert!(converged);
                    assert!(residual < 1e-3);
                    break;
                }
                Reply::Queued { .. } | Reply::Running { .. } => {
                    assert!(std::time::Instant::now() < deadline, "poll timed out");
                    std::thread::sleep(Duration::from_millis(5));
                }
                other => panic!("unexpected poll reply {other:?}"),
            }
        }
        server.shutdown();
    }

    #[test]
    fn streaming_submit_emits_samples_then_done() {
        let server = boot();
        let conn = TcpStream::connect(server.addr()).unwrap();
        let mut frame = SubmitFrame::new("demo");
        frame.stream = true;
        frame.check = 4; // frequent checkpoints → guaranteed samples
        frame.tol = 1e-10;
        let mut w = BufWriter::new(conn.try_clone().unwrap());
        w.write_all(Request::Submit(frame).to_line().as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
        w.flush().unwrap();

        let mut samples = 0usize;
        let mut done = false;
        let reader = BufReader::new(conn.try_clone().unwrap());
        for line in reader.lines() {
            match wire::parse_reply(&line.unwrap()).unwrap() {
                Reply::Queued { .. } => {}
                Reply::Sample { residual, .. } => {
                    assert!(residual.is_finite());
                    samples += 1;
                }
                Reply::Done { converged, .. } => {
                    assert!(converged);
                    done = true;
                    break;
                }
                other => panic!("unexpected stream frame {other:?}"),
            }
        }
        assert!(done, "stream ended without DONE");
        assert!(samples >= 1, "streamed no samples");
        server.shutdown();
    }

    #[test]
    fn cancel_over_the_wire_is_acked_and_typed() {
        let server = boot();
        let conn = TcpStream::connect(server.addr()).unwrap();
        // Unsatisfiable tolerance: runs until cancelled.
        let mut frame = SubmitFrame::new("demo");
        frame.tol = 0.0;
        frame.check = 4;
        frame.max_iterations = Some(usize::MAX / 2);
        let id = match exchange(&conn, &Request::Submit(frame)) {
            Reply::Queued { id } => id,
            other => panic!("expected QUEUED, got {other:?}"),
        };
        match exchange(&conn, &Request::Cancel { id }) {
            Reply::Ack { applied, .. } => assert!(applied),
            other => panic!("expected ACK, got {other:?}"),
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(60);
        loop {
            match exchange(&conn, &Request::Poll { id }) {
                Reply::Err { kind, .. } => {
                    assert_eq!(kind, ErrKind::Cancelled);
                    break;
                }
                Reply::Queued { .. } | Reply::Running { .. } => {
                    assert!(std::time::Instant::now() < deadline, "cancel never landed");
                    std::thread::sleep(Duration::from_millis(5));
                }
                other => panic!("unexpected poll reply {other:?}"),
            }
        }
        server.shutdown();
    }
}
