//! Admission-controlled asynchronous submission path: bounded queue depth,
//! per-job deadlines, cooperative cancellation, and queue-wait accounting.
//!
//! [`SolveQueue`](crate::batch::SolveQueue) answers "run these N jobs and
//! give me N reports" — a *synchronous* shape. A serving front end faces a
//! different one: jobs arrive whenever clients feel like it, clients
//! disappear, and the worst failure mode is an invisible backlog. The
//! [`SolveFrontEnd`] applies the same discipline the drop-oldest
//! [`ProgressSink`](crate::metrics::ProgressSink) applies to telemetry —
//! *never block, never buffer unboundedly* — to admission itself:
//!
//! - **Bounded queue depth.** [`SolveFrontEnd::submit`] either enqueues the
//!   job or refuses it with the typed
//!   [`Error::Overloaded`](crate::error::Error::Overloaded) — back-pressure
//!   by refusal, visible to the client, instead of a queue that grows until
//!   every admitted job's latency is unbounded.
//! - **Per-job deadlines.** A deadline budget is armed **at submit** (queue
//!   wait counts against it; see [`SolveControl::with_deadline`]). A job
//!   whose deadline lapses while queued fails at dequeue without touching a
//!   lane; one that lapses mid-solve halts at its next
//!   `StopCheck` checkpoint. Either way the client gets the typed
//!   [`Error::DeadlineExceeded`](crate::error::Error::DeadlineExceeded) and
//!   the lane moves on to the next job.
//! - **Cooperative cancellation.** [`SolveFrontEnd::cancel`] flips the
//!   job's [`SolveControl`]; a running solve stops consuming checkpoints at
//!   its next poll, a queued job is discarded at dequeue. No thread is ever
//!   killed — an abandoned client costs at most one checkpoint interval.
//! - **Queue-wait and dropped-sample accounting.** Every completed job's
//!   [`SolveReport`] carries `queue_wait` (submit → dequeue) and
//!   `dropped_samples` (its sink's drop-oldest count); the front end's
//!   [`FrontStats`] aggregate the conservation totals the property tests
//!   and the load-test bench row check.
//!
//! ## Threading model
//!
//! Lanes are **persistent threads spawned once** at construction — the
//! crate-wide zero-per-solve-spawn discipline, in the only shape an
//! open-ended server can use it (the [`WorkerPool`]'s `run` is a barrier
//! dispatch: it returns when its closure set finishes, which a server never
//! does). Each lane runs jobs sequentially with the crate's sequential
//! solvers; per-job parallel solvers would need a dedicated pool per lane
//! (see the [`crate::batch`] docs on pool nesting) and are the wrong shape
//! for throughput serving anyway — scale with in-flight jobs, not threads
//! per job.
//!
//! [`WorkerPool`]: crate::parallel::pool::WorkerPool

use super::control::{Halt, SolveControl};
use super::registry::SystemRegistry;
use crate::batch::SolveReport;
use crate::data::LinearSystem;
use crate::error::{Error, Result};
use crate::solvers::{SolveOptions, Solver};
use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Sizing knobs for a [`SolveFrontEnd`].
#[derive(Clone, Copy, Debug)]
pub struct FrontEndConfig {
    /// Persistent worker lanes (concurrent solves). Defaults to the host's
    /// hardware thread count.
    pub lanes: usize,
    /// Admission bound: jobs allowed to *wait* (running jobs do not count).
    /// A submit that finds this many pending is refused with
    /// [`Error::Overloaded`](crate::error::Error::Overloaded).
    pub max_pending: usize,
}

impl Default for FrontEndConfig {
    fn default() -> Self {
        FrontEndConfig {
            lanes: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            max_pending: 64,
        }
    }
}

/// One job submission: which resident system, which solver, what options.
pub struct SubmitRequest {
    /// Registry name of the system to solve (resolved at submit time; the
    /// job keeps its `Arc`, so a later eviction cannot invalidate it).
    pub system: String,
    /// Optional right-hand-side override. When set, the lane solves a cheap
    /// clone of the resident system (`Arc`-backed matrix storage — the big
    /// allocation is still shared) with this `b` swapped in and the
    /// reference cleared, exactly like [`crate::batch::BatchSolver`] lanes.
    pub rhs: Option<Vec<f64>>,
    /// Per-job solver (shared trait object — one solver instance can serve
    /// many jobs concurrently; `solve` takes `&self`).
    pub solver: Arc<dyn Solver + Send + Sync>,
    /// Solve options. Serving jobs default to residual stopping (the
    /// reference-free criterion); any `control` token set here is replaced
    /// by the front end's own (which [`SolveFrontEnd::cancel`] drives).
    pub opts: SolveOptions,
    /// Deadline budget measured from submit (`None` = no deadline).
    pub deadline: Option<Duration>,
}

impl SubmitRequest {
    /// A request against resident system `system` with serving defaults:
    /// residual stopping at `1e-8`, checked every 32 iterations.
    pub fn new(system: impl Into<String>, solver: Arc<dyn Solver + Send + Sync>) -> Self {
        SubmitRequest {
            system: system.into(),
            rhs: None,
            solver,
            opts: SolveOptions::default().with_residual_stopping(1e-8, 32),
            deadline: None,
        }
    }

    /// Replace the solve options.
    pub fn with_opts(mut self, opts: SolveOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Solve with this right-hand side instead of the resident one.
    pub fn with_rhs(mut self, rhs: Vec<f64>) -> Self {
        self.rhs = Some(rhs);
        self
    }

    /// Give the job `budget` from submit to completion.
    pub fn with_deadline(mut self, budget: Duration) -> Self {
        self.deadline = Some(budget);
        self
    }
}

impl fmt::Debug for SubmitRequest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SubmitRequest")
            .field("system", &self.system)
            .field("solver", &self.solver.name())
            .field("rhs_override", &self.rhs.is_some())
            .field("deadline", &self.deadline)
            .finish()
    }
}

/// Where a submitted job currently stands.
#[derive(Clone, Debug)]
pub enum JobStatus {
    /// Waiting for a lane.
    Queued,
    /// A lane is solving it right now.
    Running,
    /// Finished; the report carries the solve outcome plus the serving
    /// stats (`queue_wait`, `dropped_samples`).
    Done(SolveReport),
    /// Refused or halted with a typed error (`Cancelled`,
    /// `DeadlineExceeded`, or a validation failure observed at dequeue).
    /// `Arc`-wrapped because [`Error`] is deliberately not `Clone` and
    /// status snapshots are.
    Failed(Arc<Error>),
}

impl JobStatus {
    /// Done or Failed — nothing further will happen to this job.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Done(_) | JobStatus::Failed(_))
    }
}

/// Aggregate counters over a front end's lifetime. Conservation invariant
/// (once every accepted job is terminal):
/// `submitted == completed + cancelled + deadline_missed + failed_other`.
/// Refused submissions count in `rejected` only — they were never admitted.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FrontStats {
    /// Jobs accepted into the queue.
    pub submitted: u64,
    /// Submissions refused with `Overloaded`.
    pub rejected: u64,
    /// Jobs that finished with a report.
    pub completed: u64,
    /// Jobs that ended `Cancelled`.
    pub cancelled: u64,
    /// Jobs that ended `DeadlineExceeded` (queued or mid-solve).
    pub deadline_missed: u64,
    /// Jobs that failed for any other reason.
    pub failed_other: u64,
    /// Sum of `dropped_samples` over completed jobs (telemetry the
    /// drop-oldest sinks shed; the solves themselves never blocked).
    pub dropped_samples: u64,
}

struct QueuedJob {
    id: u64,
    request: SubmitRequest,
    system: Arc<LinearSystem>,
    control: SolveControl,
    submitted: Instant,
}

struct State {
    queue: VecDeque<QueuedJob>,
    jobs: HashMap<u64, (JobStatus, SolveControl)>,
    next_id: u64,
    stats: FrontStats,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Lanes wait here for work (or shutdown).
    work_ready: Condvar,
    /// Waiters in [`SolveFrontEnd::wait`] park here for terminal statuses.
    job_done: Condvar,
    max_pending: usize,
}

/// The admission-controlled serving front end (see [module docs](self)).
pub struct SolveFrontEnd {
    registry: Arc<SystemRegistry>,
    shared: Arc<Shared>,
    lanes: Vec<JoinHandle<()>>,
}

impl SolveFrontEnd {
    /// Boot a front end over `registry`: spawns `config.lanes` persistent
    /// lane threads (once — never again per job).
    pub fn new(registry: Arc<SystemRegistry>, config: FrontEndConfig) -> Self {
        let lanes_n = config.lanes.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                queue: VecDeque::new(),
                jobs: HashMap::new(),
                next_id: 0,
                stats: FrontStats::default(),
                shutdown: false,
            }),
            work_ready: Condvar::new(),
            job_done: Condvar::new(),
            max_pending: config.max_pending.max(1),
        });
        let lanes = (0..lanes_n)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("kaczmarz-serve-{i}"))
                    .spawn(move || lane_loop(&shared))
                    .expect("spawn serve lane")
            })
            .collect();
        SolveFrontEnd { registry, shared, lanes }
    }

    /// The registry this front end serves from.
    pub fn registry(&self) -> &Arc<SystemRegistry> {
        &self.registry
    }

    /// Submit a job. Validates admission-synchronously (unknown system,
    /// rhs shape, reference-consulting options on a reference-free setup)
    /// and refuses with [`Error::Overloaded`] when `max_pending` jobs are
    /// already waiting; otherwise returns the job id to poll/cancel with.
    /// The deadline clock starts now, not at dequeue.
    pub fn submit(&self, request: SubmitRequest) -> Result<u64> {
        // Resolve + validate before taking the queue lock: the registry has
        // its own lock and the checks are read-only.
        let system = self.registry.get(&request.system).ok_or_else(|| {
            Error::InvalidArgument(format!(
                "no resident system named '{}' (see the registry's names_by_recency)",
                request.system
            ))
        })?;
        if let Some(rhs) = &request.rhs {
            if rhs.len() != system.rows() {
                return Err(Error::Dimension(format!(
                    "rhs override of len {} does not match system '{}' with {} rows",
                    rhs.len(),
                    request.system,
                    system.rows()
                )));
            }
            if request.opts.consults_reference() {
                return Err(Error::InvalidArgument(
                    "an rhs-override job has no reference solution, so reference-error \
                     stopping is unavailable (stop on the residual or fix the iteration \
                     budget)"
                        .into(),
                ));
            }
        } else if system.reference_solution().is_none() && request.opts.consults_reference() {
            return Err(Error::InvalidArgument(format!(
                "resident system '{}' has no reference solution, so reference-error \
                 stopping is unavailable (stop on the residual or fix the iteration budget)",
                request.system
            )));
        }

        let control = match request.deadline {
            Some(budget) => SolveControl::with_deadline(budget),
            None => SolveControl::new(),
        };
        let mut st = self.shared.state.lock().unwrap();
        if st.shutdown {
            return Err(Error::InvalidArgument("front end is shut down".into()));
        }
        if st.queue.len() >= self.shared.max_pending {
            st.stats.rejected += 1;
            return Err(Error::Overloaded {
                pending: st.queue.len(),
                capacity: self.shared.max_pending,
            });
        }
        let id = st.next_id;
        st.next_id += 1;
        st.stats.submitted += 1;
        st.jobs.insert(id, (JobStatus::Queued, control.clone()));
        st.queue.push_back(QueuedJob {
            id,
            request,
            system,
            control,
            submitted: Instant::now(),
        });
        drop(st);
        self.shared.work_ready.notify_one();
        Ok(id)
    }

    /// Request cancellation of a job. Returns `true` when the job exists
    /// and was not yet terminal (the cancel may still lose the race against
    /// completion — poll the final status to know). Queued jobs are
    /// discarded at dequeue; running jobs halt at their next checkpoint.
    pub fn cancel(&self, id: u64) -> bool {
        let st = self.shared.state.lock().unwrap();
        match st.jobs.get(&id) {
            Some((status, control)) if !status.is_terminal() => {
                control.cancel();
                true
            }
            _ => false,
        }
    }

    /// Snapshot of a job's current status (`None` for unknown ids).
    pub fn status(&self, id: u64) -> Option<JobStatus> {
        self.shared.state.lock().unwrap().jobs.get(&id).map(|(s, _)| s.clone())
    }

    /// Block until the job reaches a terminal status, up to `timeout`.
    /// Returns the status at return time — check
    /// [`JobStatus::is_terminal`] to distinguish completion from timeout.
    pub fn wait(&self, id: u64, timeout: Duration) -> Option<JobStatus> {
        let deadline = Instant::now() + timeout;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            match st.jobs.get(&id) {
                None => return None,
                Some((s, _)) if s.is_terminal() => return Some(s.clone()),
                Some((s, _)) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return Some(s.clone());
                    }
                    let (guard, _) =
                        self.shared.job_done.wait_timeout(st, deadline - now).unwrap();
                    st = guard;
                }
            }
        }
    }

    /// Drop a terminal job's record (frees the status map entry). `true`
    /// when something was forgotten; running/queued jobs are refused.
    pub fn forget(&self, id: u64) -> bool {
        let mut st = self.shared.state.lock().unwrap();
        match st.jobs.get(&id) {
            Some((s, _)) if s.is_terminal() => {
                st.jobs.remove(&id);
                true
            }
            _ => false,
        }
    }

    /// Jobs currently waiting for a lane.
    pub fn pending(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// Aggregate lifetime counters.
    pub fn stats(&self) -> FrontStats {
        self.shared.state.lock().unwrap().stats.clone()
    }
}

impl Drop for SolveFrontEnd {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            // Cancel whatever is still queued or running so lanes drain
            // promptly instead of finishing long solves nobody can observe.
            for (_, (status, control)) in st.jobs.iter() {
                if !status.is_terminal() {
                    control.cancel();
                }
            }
        }
        self.shared.work_ready.notify_all();
        for lane in self.lanes.drain(..) {
            let _ = lane.join();
        }
    }
}

impl fmt::Debug for SolveFrontEnd {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let st = self.shared.state.lock().unwrap();
        f.debug_struct("SolveFrontEnd")
            .field("lanes", &self.lanes.len())
            .field("pending", &st.queue.len())
            .field("max_pending", &self.shared.max_pending)
            .field("stats", &st.stats)
            .finish()
    }
}

/// Map a halt reason onto the crate's typed error.
fn halt_error(halt: Halt, control: &SolveControl) -> Error {
    match halt {
        Halt::Cancelled => Error::Cancelled,
        Halt::DeadlineExceeded => Error::DeadlineExceeded {
            budget_ms: control.deadline_budget().map_or(0, |d| d.as_millis() as u64),
        },
    }
}

/// One persistent lane: dequeue, pre-check the control token, solve with it
/// attached, publish the outcome. Runs until shutdown.
fn lane_loop(shared: &Shared) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(job) = st.queue.pop_front() {
                    if let Some((status, _)) = st.jobs.get_mut(&job.id) {
                        *status = JobStatus::Running;
                    }
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        let queue_wait = job.submitted.elapsed();

        // Pre-flight: a deadline that lapsed while queued (or a cancel that
        // arrived first) fails the job here, before any solve work.
        let status = match job.control.poll() {
            Some(halt) => JobStatus::Failed(Arc::new(halt_error(halt, &job.control))),
            None => run_job(&job, queue_wait),
        };

        let mut st = shared.state.lock().unwrap();
        match &status {
            JobStatus::Done(report) => {
                st.stats.completed += 1;
                st.stats.dropped_samples += report.dropped_samples;
            }
            JobStatus::Failed(e) => match **e {
                Error::Cancelled => st.stats.cancelled += 1,
                Error::DeadlineExceeded { .. } => st.stats.deadline_missed += 1,
                _ => st.stats.failed_other += 1,
            },
            _ => unreachable!("lane outcomes are terminal"),
        }
        if let Some((slot, _)) = st.jobs.get_mut(&job.id) {
            *slot = status;
        }
        drop(st);
        shared.job_done.notify_all();
    }
}

/// Solve one admitted job on the calling lane.
fn run_job(job: &QueuedJob, queue_wait: Duration) -> JobStatus {
    // The front end's control token rides in the options so the solve's
    // StopCheck checkpoints poll it; any client-supplied token is replaced
    // (documented on `SubmitRequest::opts`).
    let opts = job.request.opts.clone().with_control(job.control.clone());
    let result = match &job.request.rhs {
        Some(rhs) => {
            // Cheap per-job clone: matrix storage is Arc-backed, only the
            // O(m)/O(n) side vectors are copied (the BatchSolver pattern).
            let mut sys = (*job.system).clone();
            sys.b.copy_from_slice(rhs);
            sys.x_true = None;
            sys.x_ls = None;
            sys.consistent = true;
            let result = job.request.solver.solve(&sys, &opts);
            match job.control.halted() {
                Some(halt) => return JobStatus::Failed(Arc::new(halt_error(halt, &job.control))),
                None => {
                    let residual_norm = sys.residual_norm(&result.x);
                    return done_report(job, result, residual_norm, queue_wait, &opts);
                }
            }
        }
        None => job.request.solver.solve(&job.system, &opts),
    };
    match job.control.halted() {
        Some(halt) => JobStatus::Failed(Arc::new(halt_error(halt, &job.control))),
        None => {
            let residual_norm = job.system.residual_norm(&result.x);
            done_report(job, result, residual_norm, queue_wait, &opts)
        }
    }
}

fn done_report(
    job: &QueuedJob,
    result: crate::solvers::SolveResult,
    residual_norm: f64,
    queue_wait: Duration,
    opts: &SolveOptions,
) -> JobStatus {
    let dropped_samples = opts.progress.as_ref().map_or(0, |s| s.dropped());
    JobStatus::Done(SolveReport {
        job: job.id as usize,
        solver: job.request.solver.name(),
        result,
        residual_norm,
        queue_wait,
        dropped_samples,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::solvers::rk::RkSolver;

    fn registry_with(name: &str, m: usize, n: usize) -> Arc<SystemRegistry> {
        let reg = Arc::new(SystemRegistry::new(usize::MAX));
        reg.insert(name, DatasetBuilder::new(m, n).seed(1).consistent());
        reg
    }

    fn rk() -> Arc<dyn Solver + Send + Sync> {
        Arc::new(RkSolver::new(7))
    }

    /// A request that converges quickly on the resident system.
    fn quick(system: &str) -> SubmitRequest {
        SubmitRequest::new(system, rk())
            .with_opts(SolveOptions::default().with_residual_stopping(1e-8, 16))
    }

    /// A request that can never satisfy its tolerance (runs until halted or
    /// the max-iteration cap).
    fn endless(system: &str) -> SubmitRequest {
        SubmitRequest::new(system, rk()).with_opts(
            SolveOptions::default()
                .with_residual_stopping(0.0, 16)
                .with_max_iterations(usize::MAX / 2),
        )
    }

    #[test]
    fn submit_wait_done_roundtrip() {
        let front = SolveFrontEnd::new(
            registry_with("demo", 120, 8),
            FrontEndConfig { lanes: 2, max_pending: 8 },
        );
        let id = front.submit(quick("demo")).unwrap();
        let status = front.wait(id, Duration::from_secs(60)).expect("known id");
        match status {
            JobStatus::Done(report) => {
                assert!(report.result.converged);
                assert!(report.residual_norm < 1e-3);
                assert_eq!(report.job, id as usize);
            }
            other => panic!("expected Done, got {other:?}"),
        }
        let stats = front.stats();
        assert_eq!(stats.submitted, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn unknown_system_and_bad_rhs_are_refused_at_submit() {
        let front = SolveFrontEnd::new(registry_with("demo", 60, 6), FrontEndConfig::default());
        let err = front.submit(quick("nope")).unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err:?}");
        let err = front.submit(quick("demo").with_rhs(vec![0.0; 3])).unwrap_err();
        assert!(matches!(err, Error::Dimension(_)), "{err:?}");
        // rhs override + reference-error stopping: no reference to consult.
        let err = front
            .submit(
                SubmitRequest::new("demo", rk())
                    .with_opts(SolveOptions::default())
                    .with_rhs(vec![0.0; 60]),
            )
            .unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err:?}");
    }

    #[test]
    fn rhs_override_solves_against_the_override() {
        let front = SolveFrontEnd::new(registry_with("demo", 120, 8), FrontEndConfig::default());
        let reg = Arc::clone(front.registry());
        let sys = reg.get("demo").unwrap();
        // b = A * (2,2,...,2): the solve must recover that x, not the
        // resident one.
        let x_want = vec![2.0; sys.cols()];
        let rhs = crate::linalg::gemv(&sys.a, &x_want).unwrap();
        let id = front.submit(quick("demo").with_rhs(rhs)).unwrap();
        match front.wait(id, Duration::from_secs(60)).unwrap() {
            JobStatus::Done(report) => {
                assert!(report.result.converged);
                let err: f64 = report
                    .result
                    .x
                    .iter()
                    .zip(&x_want)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                assert!(err < 1e-6, "recovered wrong solution, err^2 = {err:.3e}");
            }
            other => panic!("expected Done, got {other:?}"),
        }
    }

    #[test]
    fn cancelled_queued_job_fails_without_running() {
        // One lane, blocked by an endless job: the second job sits queued,
        // gets cancelled, and must fail typed at dequeue.
        let front = SolveFrontEnd::new(
            registry_with("demo", 120, 8),
            FrontEndConfig { lanes: 1, max_pending: 8 },
        );
        let blocker = front.submit(endless("demo")).unwrap();
        let queued = front.submit(quick("demo")).unwrap();
        assert!(front.cancel(queued));
        assert!(front.cancel(blocker));
        for id in [blocker, queued] {
            match front.wait(id, Duration::from_secs(60)).unwrap() {
                JobStatus::Failed(e) => assert!(matches!(*e, Error::Cancelled), "{e}"),
                other => panic!("expected Failed(Cancelled), got {other:?}"),
            }
        }
        let stats = front.stats();
        assert_eq!(stats.cancelled, 2);
        assert_eq!(stats.completed, 0);
    }

    #[test]
    fn cancel_unknown_or_finished_returns_false() {
        let front = SolveFrontEnd::new(registry_with("demo", 120, 8), FrontEndConfig::default());
        assert!(!front.cancel(999));
        let id = front.submit(quick("demo")).unwrap();
        assert!(front.wait(id, Duration::from_secs(60)).unwrap().is_terminal());
        assert!(!front.cancel(id));
        // Terminal jobs can be forgotten exactly once.
        assert!(front.forget(id));
        assert!(!front.forget(id));
        assert!(front.status(id).is_none());
    }

    #[test]
    fn shutdown_drains_lanes_even_with_endless_jobs() {
        let front = SolveFrontEnd::new(
            registry_with("demo", 120, 8),
            FrontEndConfig { lanes: 2, max_pending: 8 },
        );
        for _ in 0..4 {
            front.submit(endless("demo")).unwrap();
        }
        // Drop must cancel-and-join promptly rather than waiting out
        // usize::MAX/2 iterations. (A hang here fails the test by timeout.)
        drop(front);
    }
}
