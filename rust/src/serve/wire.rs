//! Newline-delimited wire frames for the serving front end.
//!
//! One frame per line, `VERB key=value ...` — a deliberately boring,
//! debuggable format (`nc localhost 7070` is a working client). The codec
//! here is **pure**: no sockets, no I/O — [`parse_request`]/[`parse_reply`]
//! and the `to_line` encoders round-trip plain strings, so every frame is
//! testable byte-for-byte (and Miri-clean; the socket binding lives in
//! [`super::server`]/[`super::client`]).
//!
//! ## Frames
//!
//! Client → server:
//!
//! | frame | meaning |
//! |---|---|
//! | `SUBMIT system=S [solver=rk] [seed=N] [tol=T] [check=K] [max=N] [fixed=N] [deadline_ms=N] [stream=1]` | submit a job against resident system `S` |
//! | `POLL id=N` | snapshot job `N`'s status |
//! | `CANCEL id=N` | request cooperative cancellation of job `N` |
//! | `STATS` | registry + admission counters |
//! | `PING` | liveness probe |
//!
//! Server → client:
//!
//! | frame | meaning |
//! |---|---|
//! | `QUEUED id=N` | job admitted (also the `POLL` reply while it waits) |
//! | `RUNNING id=N` | `POLL` reply while a lane solves it |
//! | `ACK id=N applied=0\|1` | `CANCEL` reply: whether a live job was found |
//! | `SAMPLE id=N k=K residual=R err=E elapsed_ms=M` | one mid-solve telemetry sample (`err=-` on reference-free systems); streamed line-by-line when the submit asked for `stream=1` |
//! | `DONE id=N iterations=K converged=B residual=R queue_wait_ms=M dropped=D` | terminal success |
//! | `ERR kind=K msg=...` | terminal failure; `kind` is one of `overloaded`, `deadline`, `cancelled`, `invalid`, `proto` |
//! | `STATS resident=... pending=... submitted=... completed=... cancelled=... deadline_missed=... rejected=...` | counters snapshot |
//! | `PONG` | liveness reply |
//!
//! ## What streaming costs on the wire
//!
//! The distributed layer prices every message as `α + bytes/β`
//! ([`NetworkModel::message_cost`]); the same vocabulary prices serving
//! telemetry. A `SAMPLE` line is ~[`SAMPLE_LINE_BYTES`] bytes — deep in the
//! latency-dominated regime where the α term is everything — so streaming
//! `s` samples costs `s · (α + SAMPLE_LINE_BYTES/β)` ≈ `s·α`:
//! per-checkpoint telemetry is cheap in *bandwidth* but pays full message
//! *latency* per line, which is why samples ride the solve's existing
//! amortized checkpoints (`check_every`) instead of every iteration — see
//! [`stream_cost_estimate`].

use crate::distributed::network::{NetworkModel, Placement};
use crate::error::Error;

/// Conservative size of one encoded `SAMPLE` line in bytes (verb, five
/// `key=value` tokens with shortest-round-trip floats, newline).
pub const SAMPLE_LINE_BYTES: usize = 72;

/// Seconds to ship `samples` telemetry lines client-ward under `model`,
/// pricing each line as one `α + bytes/β` message between `from` and `to`
/// (inter- vs intra-node resolved by `placement`, exactly as the simulated
/// cluster prices its gathers).
pub fn stream_cost_estimate(
    model: &NetworkModel,
    samples: usize,
    from: usize,
    to: usize,
    placement: Placement,
) -> f64 {
    samples as f64 * model.message_cost(from, to, SAMPLE_LINE_BYTES, placement)
}

/// Body of a `SUBMIT` frame (defaults match
/// [`SubmitRequest::new`](super::SubmitRequest::new): residual stopping,
/// reference-free).
#[derive(Clone, Debug, PartialEq)]
pub struct SubmitFrame {
    /// Registry name of the resident system.
    pub system: String,
    /// Solver selector (the server maps it; `"rk"` by default, `"rek"` and
    /// `"ck"` also resident).
    pub solver: String,
    /// Sampling seed.
    pub seed: u32,
    /// Residual-stopping tolerance on `‖Ax - b‖²`.
    pub tol: f64,
    /// Check the residual every this many iterations.
    pub check: usize,
    /// Hard iteration cap (`None` = solver default).
    pub max_iterations: Option<usize>,
    /// Fixed-budget mode: exactly this many iterations, nothing measured.
    pub fixed_iterations: Option<usize>,
    /// Deadline budget in milliseconds, measured from submit.
    pub deadline_ms: Option<u64>,
    /// Stream `SAMPLE` lines before the terminal frame.
    pub stream: bool,
}

impl SubmitFrame {
    /// A submit against `system` with wire defaults.
    pub fn new(system: impl Into<String>) -> Self {
        SubmitFrame {
            system: system.into(),
            solver: "rk".into(),
            seed: 0,
            tol: 1e-8,
            check: 32,
            max_iterations: None,
            fixed_iterations: None,
            deadline_ms: None,
            stream: false,
        }
    }
}

/// A parsed client → server frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Submit a job.
    Submit(SubmitFrame),
    /// Snapshot a job's status.
    Poll {
        /// Job id from the `QUEUED` ack.
        id: u64,
    },
    /// Request cooperative cancellation.
    Cancel {
        /// Job id from the `QUEUED` ack.
        id: u64,
    },
    /// Ask for registry + admission counters.
    Stats,
    /// Liveness probe.
    Ping,
}

/// Typed error classes carried by `ERR` frames — the wire image of the
/// crate's serving [`Error`](crate::error::Error) variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrKind {
    /// Admission queue full ([`Error::Overloaded`]); retry with backoff.
    Overloaded,
    /// Deadline budget elapsed ([`Error::DeadlineExceeded`]).
    Deadline,
    /// Job cancelled ([`Error::Cancelled`]).
    Cancelled,
    /// Anything else typed the job failed with (unknown system, bad shape…).
    Invalid,
    /// The frame itself could not be parsed.
    Proto,
}

impl ErrKind {
    /// Wire token for this kind.
    pub fn token(self) -> &'static str {
        match self {
            ErrKind::Overloaded => "overloaded",
            ErrKind::Deadline => "deadline",
            ErrKind::Cancelled => "cancelled",
            ErrKind::Invalid => "invalid",
            ErrKind::Proto => "proto",
        }
    }

    /// Classify a crate error into its wire kind.
    pub fn of(err: &Error) -> ErrKind {
        match err {
            Error::Overloaded { .. } => ErrKind::Overloaded,
            Error::DeadlineExceeded { .. } => ErrKind::Deadline,
            Error::Cancelled => ErrKind::Cancelled,
            _ => ErrKind::Invalid,
        }
    }

    fn from_token(tok: &str) -> Option<ErrKind> {
        Some(match tok {
            "overloaded" => ErrKind::Overloaded,
            "deadline" => ErrKind::Deadline,
            "cancelled" => ErrKind::Cancelled,
            "invalid" => ErrKind::Invalid,
            "proto" => ErrKind::Proto,
            _ => return None,
        })
    }
}

/// A parsed server → client frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    /// Job admitted / still waiting for a lane.
    Queued {
        /// Job id to poll/cancel with.
        id: u64,
    },
    /// A lane is solving the job right now.
    Running {
        /// Job id.
        id: u64,
    },
    /// Reply to `CANCEL`: whether the cancel found a live job to act on
    /// (it may still lose the race against completion — poll for the
    /// terminal frame to know).
    Ack {
        /// Job id.
        id: u64,
        /// `true` when the job existed and was not yet terminal.
        applied: bool,
    },
    /// One mid-solve telemetry sample.
    Sample {
        /// Job id.
        id: u64,
        /// Iteration number at the checkpoint.
        k: usize,
        /// Residual norm `‖Ax - b‖` at the checkpoint.
        residual: f64,
        /// Reference-error norm, when the system carries a reference.
        reference_err: Option<f64>,
        /// Milliseconds since the solve started.
        elapsed_ms: u64,
    },
    /// Terminal success.
    Done {
        /// Job id.
        id: u64,
        /// Iterations the solve spent.
        iterations: usize,
        /// Whether the stopping criterion was met.
        converged: bool,
        /// Final residual norm against the job's system.
        residual: f64,
        /// Milliseconds the job waited for a lane (submit → dequeue).
        queue_wait_ms: u64,
        /// Telemetry samples the job's sink shed (drop-oldest).
        dropped: u64,
    },
    /// Terminal failure.
    Err {
        /// Error class.
        kind: ErrKind,
        /// Human-readable detail (rest of the line; may contain spaces).
        msg: String,
    },
    /// Counters snapshot.
    Stats {
        /// Systems resident in the registry.
        resident: usize,
        /// Jobs waiting for a lane.
        pending: usize,
        /// Jobs accepted over the front end's lifetime.
        submitted: u64,
        /// Jobs that finished with a report.
        completed: u64,
        /// Jobs that ended cancelled.
        cancelled: u64,
        /// Jobs that ended past deadline.
        deadline_missed: u64,
        /// Submissions refused with `overloaded`.
        rejected: u64,
    },
    /// Liveness reply.
    Pong,
}

impl Request {
    /// Encode as one wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Request::Submit(s) => {
                let mut line = format!(
                    "SUBMIT system={} solver={} seed={} tol={:?} check={}",
                    s.system, s.solver, s.seed, s.tol, s.check
                );
                if let Some(max) = s.max_iterations {
                    line.push_str(&format!(" max={max}"));
                }
                if let Some(fixed) = s.fixed_iterations {
                    line.push_str(&format!(" fixed={fixed}"));
                }
                if let Some(ms) = s.deadline_ms {
                    line.push_str(&format!(" deadline_ms={ms}"));
                }
                if s.stream {
                    line.push_str(" stream=1");
                }
                line
            }
            Request::Poll { id } => format!("POLL id={id}"),
            Request::Cancel { id } => format!("CANCEL id={id}"),
            Request::Stats => "STATS".into(),
            Request::Ping => "PING".into(),
        }
    }
}

impl Reply {
    /// Encode as one wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Reply::Queued { id } => format!("QUEUED id={id}"),
            Reply::Running { id } => format!("RUNNING id={id}"),
            Reply::Ack { id, applied } => {
                format!("ACK id={id} applied={}", if *applied { 1 } else { 0 })
            }
            Reply::Sample { id, k, residual, reference_err, elapsed_ms } => {
                let err = match reference_err {
                    Some(e) => format!("{e:?}"),
                    None => "-".into(),
                };
                format!(
                    "SAMPLE id={id} k={k} residual={residual:?} err={err} elapsed_ms={elapsed_ms}"
                )
            }
            Reply::Done { id, iterations, converged, residual, queue_wait_ms, dropped } => {
                format!(
                    "DONE id={id} iterations={iterations} converged={} residual={residual:?} \
                     queue_wait_ms={queue_wait_ms} dropped={dropped}",
                    if *converged { 1 } else { 0 }
                )
            }
            Reply::Err { kind, msg } => format!("ERR kind={} msg={msg}", kind.token()),
            Reply::Stats {
                resident,
                pending,
                submitted,
                completed,
                cancelled,
                deadline_missed,
                rejected,
            } => format!(
                "STATS resident={resident} pending={pending} submitted={submitted} \
                 completed={completed} cancelled={cancelled} \
                 deadline_missed={deadline_missed} rejected={rejected}"
            ),
            Reply::Pong => "PONG".into(),
        }
    }
}

/// `key=value` lookup over a frame's tokens.
fn field<'a>(tokens: &[&'a str], key: &str) -> Option<&'a str> {
    tokens.iter().find_map(|t| t.strip_prefix(key)?.strip_prefix('='))
}

fn parse_field<T: std::str::FromStr>(tokens: &[&str], key: &str) -> Result<T, String> {
    let raw = field(tokens, key).ok_or_else(|| format!("missing {key}="))?;
    raw.parse().map_err(|_| format!("bad {key}={raw}"))
}

fn opt_field<T: std::str::FromStr>(tokens: &[&str], key: &str) -> Result<Option<T>, String> {
    match field(tokens, key) {
        None => Ok(None),
        Some(raw) => raw.parse().map(Some).map_err(|_| format!("bad {key}={raw}")),
    }
}

/// Parse one client → server line. The error string is ready to ship back
/// in an `ERR kind=proto` frame.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let line = line.trim();
    let tokens: Vec<&str> = line.split_whitespace().collect();
    let verb = *tokens.first().ok_or("empty frame")?;
    let rest = &tokens[1..];
    match verb {
        "SUBMIT" => {
            let mut frame =
                SubmitFrame::new(field(rest, "system").ok_or("missing system=")?.to_string());
            if let Some(solver) = field(rest, "solver") {
                frame.solver = solver.to_string();
            }
            if let Some(seed) = opt_field(rest, "seed")? {
                frame.seed = seed;
            }
            if let Some(tol) = opt_field(rest, "tol")? {
                frame.tol = tol;
            }
            if let Some(check) = opt_field(rest, "check")? {
                frame.check = check;
            }
            frame.max_iterations = opt_field(rest, "max")?;
            frame.fixed_iterations = opt_field(rest, "fixed")?;
            frame.deadline_ms = opt_field(rest, "deadline_ms")?;
            frame.stream = matches!(field(rest, "stream"), Some("1") | Some("true"));
            Ok(Request::Submit(frame))
        }
        "POLL" => Ok(Request::Poll { id: parse_field(rest, "id")? }),
        "CANCEL" => Ok(Request::Cancel { id: parse_field(rest, "id")? }),
        "STATS" => Ok(Request::Stats),
        "PING" => Ok(Request::Ping),
        other => Err(format!("unknown verb {other}")),
    }
}

/// Parse one server → client line (the client half of the codec).
pub fn parse_reply(line: &str) -> Result<Reply, String> {
    let line = line.trim();
    let tokens: Vec<&str> = line.split_whitespace().collect();
    let verb = *tokens.first().ok_or("empty frame")?;
    let rest = &tokens[1..];
    match verb {
        "QUEUED" => Ok(Reply::Queued { id: parse_field(rest, "id")? }),
        "RUNNING" => Ok(Reply::Running { id: parse_field(rest, "id")? }),
        "ACK" => Ok(Reply::Ack {
            id: parse_field(rest, "id")?,
            applied: field(rest, "applied") == Some("1"),
        }),
        "SAMPLE" => {
            let err_raw = field(rest, "err").ok_or("missing err=")?;
            let reference_err = if err_raw == "-" {
                None
            } else {
                Some(err_raw.parse().map_err(|_| format!("bad err={err_raw}"))?)
            };
            Ok(Reply::Sample {
                id: parse_field(rest, "id")?,
                k: parse_field(rest, "k")?,
                residual: parse_field(rest, "residual")?,
                reference_err,
                elapsed_ms: parse_field(rest, "elapsed_ms")?,
            })
        }
        "DONE" => Ok(Reply::Done {
            id: parse_field(rest, "id")?,
            iterations: parse_field(rest, "iterations")?,
            converged: field(rest, "converged") == Some("1"),
            residual: parse_field(rest, "residual")?,
            queue_wait_ms: parse_field(rest, "queue_wait_ms")?,
            dropped: parse_field(rest, "dropped")?,
        }),
        "ERR" => {
            let kind = ErrKind::from_token(field(rest, "kind").ok_or("missing kind=")?)
                .ok_or("unknown error kind")?;
            // msg= takes the rest of the line verbatim (it contains spaces).
            let msg = line
                .split_once(" msg=")
                .map(|(_, m)| m.to_string())
                .ok_or("missing msg=")?;
            Ok(Reply::Err { kind, msg })
        }
        "STATS" => Ok(Reply::Stats {
            resident: parse_field(rest, "resident")?,
            pending: parse_field(rest, "pending")?,
            submitted: parse_field(rest, "submitted")?,
            completed: parse_field(rest, "completed")?,
            cancelled: parse_field(rest, "cancelled")?,
            deadline_missed: parse_field(rest, "deadline_missed")?,
            rejected: parse_field(rest, "rejected")?,
        }),
        "PONG" => Ok(Reply::Pong),
        other => Err(format!("unknown verb {other}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_frames_round_trip() {
        let frames = vec![
            Request::Submit(SubmitFrame::new("demo")),
            Request::Submit(SubmitFrame {
                system: "ct-scan".into(),
                solver: "rek".into(),
                seed: 42,
                tol: 1e-10,
                check: 16,
                max_iterations: Some(1_000_000),
                fixed_iterations: Some(500),
                deadline_ms: Some(250),
                stream: true,
            }),
            Request::Poll { id: 7 },
            Request::Cancel { id: 0 },
            Request::Stats,
            Request::Ping,
        ];
        for frame in frames {
            let line = frame.to_line();
            assert_eq!(parse_request(&line).unwrap(), frame, "line: {line}");
        }
    }

    #[test]
    fn reply_frames_round_trip() {
        let frames = vec![
            Reply::Queued { id: 3 },
            Reply::Running { id: 3 },
            Reply::Ack { id: 3, applied: true },
            Reply::Ack { id: 9, applied: false },
            Reply::Sample {
                id: 3,
                k: 4096,
                residual: 1.25e-4,
                reference_err: Some(3.5e-5),
                elapsed_ms: 18,
            },
            Reply::Sample { id: 3, k: 1, residual: 0.5, reference_err: None, elapsed_ms: 0 },
            Reply::Done {
                id: 3,
                iterations: 8192,
                converged: true,
                residual: 9.99e-9,
                queue_wait_ms: 12,
                dropped: 2,
            },
            Reply::Err {
                kind: ErrKind::Overloaded,
                msg: "overloaded: admission queue is full (64 pending, capacity 64); retry \
                      with backoff"
                    .into(),
            },
            Reply::Stats {
                resident: 2,
                pending: 5,
                submitted: 100,
                completed: 90,
                cancelled: 4,
                deadline_missed: 3,
                rejected: 11,
            },
            Reply::Pong,
        ];
        for frame in frames {
            let line = frame.to_line();
            assert_eq!(parse_reply(&line).unwrap(), frame, "line: {line}");
        }
    }

    #[test]
    fn err_msg_keeps_spaces_and_equals_signs() {
        let reply = Reply::Err {
            kind: ErrKind::Invalid,
            msg: "rhs override of len 3 does not match system 'demo' (want = 60)".into(),
        };
        assert_eq!(parse_reply(&reply.to_line()).unwrap(), reply);
    }

    #[test]
    fn malformed_frames_are_typed_proto_errors() {
        let bad_requests = [
            "",
            "  ",
            "NOPE id=1",
            "SUBMIT solver=rk",      // missing system=
            "POLL",                  // missing id=
            "POLL id=banana",        // unparseable id
            "SUBMIT system=d tol=x", // unparseable float
        ];
        for bad in bad_requests {
            assert!(parse_request(bad).is_err(), "{bad:?} must not parse");
        }
        let bad_replies = [
            "",
            "NOPE id=1",
            "QUEUED",                // missing id=
            "ERR kind=weird msg=hm", // unknown error kind
            "ERR kind=proto",        // missing msg=
            "SAMPLE id=1 k=2 residual=0.5 elapsed_ms=1", // missing err=
            "DONE id=1 iterations=2 converged=1 residual=x queue_wait_ms=0 dropped=0",
        ];
        for bad in bad_replies {
            assert!(parse_reply(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn submit_defaults_match_the_documented_wire_defaults() {
        let parsed = parse_request("SUBMIT system=demo").unwrap();
        match parsed {
            Request::Submit(f) => {
                assert_eq!(f.solver, "rk");
                assert_eq!(f.seed, 0);
                assert_eq!(f.tol, 1e-8);
                assert_eq!(f.check, 32);
                assert!(f.max_iterations.is_none());
                assert!(f.fixed_iterations.is_none());
                assert!(f.deadline_ms.is_none());
                assert!(!f.stream);
            }
            other => panic!("expected Submit, got {other:?}"),
        }
    }

    #[test]
    fn stream_cost_is_latency_dominated_for_sample_lines() {
        let model = NetworkModel::default();
        let placement = Placement::two_per_node();
        // Ranks 0 and 2 sit on different nodes under ppn=2: inter-node cost.
        let cost = stream_cost_estimate(&model, 1000, 0, 2, placement);
        let alpha_only = 1000.0 * model.alpha_inter;
        // The byte term exists but α dominates for 72-byte lines.
        assert!(cost > alpha_only);
        assert!(cost < 2.0 * alpha_only, "cost {cost} vs alpha-only {alpha_only}");
    }
}
