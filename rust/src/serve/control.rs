//! Cooperative per-job cancellation and deadline tokens.
//!
//! A serving front end must never let a slow or abandoned client pin a
//! worker lane: Liu, Wright & Sridhar (arXiv:1401.4780) make the same
//! argument for their asynchronous solver's monitor — anything that can
//! block the iterate destroys the throughput story. The telemetry side of
//! that discipline is the drop-oldest [`ProgressSink`]; this module is the
//! *control* side: a [`SolveControl`] token attached to a job via
//! [`SolveOptions::with_control`] is polled at the solve's **existing
//! [`StopCheck`] checkpoints** (every sequential/parallel/distributed loop
//! consults it each iteration; the AsyRK monitor consults it each poll), so
//! a cancel or an elapsed deadline halts the loop cooperatively — no thread
//! is killed, no lock is held, and a job that nobody waits for anymore
//! stops consuming checkpoints instead of running out its budget.
//!
//! The token is two atomics and an optional deadline instant:
//!
//! - the **cancel flag** (`Release` store by the canceller, `Acquire` load
//!   in the solve loop — the pairing is loom-locked in `tests/loom.rs`);
//! - the **halt cell**, a first-write-wins record of *why* the solve
//!   stopped, written by whichever poll first observes a halt condition.
//!   The admission layer reads it after `solve` returns to map the outcome
//!   onto the typed [`Error::Cancelled`] / [`Error::DeadlineExceeded`];
//! - the **deadline**, fixed at token construction (`now + budget`), so
//!   queue wait counts against the budget — a job that waited out its
//!   deadline in the admission queue fails without ever touching a lane.
//!
//! A solve with no token attached pays nothing: the options field is an
//! `Option`, checked once per [`StopCheck`] call. With a token attached the
//! per-iteration cost is one `Acquire` load (plus one clock read when a
//! deadline is set) — noise next to the `O(n)` row projection, and zero
//! effect on the iterate sequence of a run that is never halted (the
//! bitwise-equivalence gates in `bench_micro_hotpath` run tokenless).
//!
//! [`ProgressSink`]: crate::metrics::ProgressSink
//! [`SolveOptions::with_control`]: crate::solvers::SolveOptions::with_control
//! [`StopCheck`]: crate::solvers::SolveOptions
//! [`Error::Cancelled`]: crate::error::Error::Cancelled
//! [`Error::DeadlineExceeded`]: crate::error::Error::DeadlineExceeded

// Atomics come from the loom-swappable shim so the cancel/halt protocol is
// model-checked alongside the pool/barrier protocols (tests/loom.rs).
use crate::parallel::sync::{Arc, AtomicBool, AtomicU8, Ordering};
use std::fmt;
use std::time::{Duration, Instant};

/// Why a controlled solve halted before its stopping criterion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Halt {
    /// [`SolveControl::cancel`] was called.
    Cancelled,
    /// The token's deadline instant passed.
    DeadlineExceeded,
}

const HALT_NONE: u8 = 0;
const HALT_CANCELLED: u8 = 1;
const HALT_DEADLINE: u8 = 2;

struct ControlInner {
    /// Set by [`SolveControl::cancel`]; `Release` store / `Acquire` load so
    /// the halt is visible to the solve loop with a happens-before edge.
    cancel: AtomicBool,
    /// First-write-wins halt reason (`HALT_*`), recorded by the first poll
    /// that observes a halt condition.
    halt: AtomicU8,
    /// Absolute deadline (fixed at construction: `now + budget`).
    deadline: Option<Instant>,
    /// The budget the deadline was built from, kept for error reporting.
    budget: Option<Duration>,
}

/// Shared cancellation/deadline token for one solve job.
///
/// Cloning is cheap (`Arc`-backed) and every clone controls the same job:
/// the submitting client keeps one clone, the admission queue stores
/// another, and the solve loop polls through the options. See the
/// [module docs](self) for the protocol and its cost.
pub struct SolveControl {
    inner: Arc<ControlInner>,
}

impl Clone for SolveControl {
    fn clone(&self) -> Self {
        SolveControl { inner: Arc::clone(&self.inner) }
    }
}

// Hand-rolled so the Debug view shows the *decoded* state — the raw
// atomics would print nothing useful.
impl fmt::Debug for SolveControl {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SolveControl")
            .field("cancelled", &self.is_cancelled())
            .field("halted", &self.halted())
            .field("deadline_budget", &self.inner.budget)
            .finish()
    }
}

impl SolveControl {
    /// A token with no deadline: only [`SolveControl::cancel`] can halt it.
    pub fn new() -> Self {
        SolveControl {
            inner: Arc::new(ControlInner {
                cancel: AtomicBool::new(false),
                halt: AtomicU8::new(HALT_NONE),
                deadline: None,
                budget: None,
            }),
        }
    }

    /// A token whose solve must finish within `budget` **of this call**:
    /// the admission layer constructs it at submit time, so queue wait
    /// counts against the budget.
    pub fn with_deadline(budget: Duration) -> Self {
        SolveControl {
            inner: Arc::new(ControlInner {
                cancel: AtomicBool::new(false),
                halt: AtomicU8::new(HALT_NONE),
                deadline: Some(Instant::now() + budget),
                budget: Some(budget),
            }),
        }
    }

    /// The deadline budget this token was built with (`None` = no deadline).
    pub fn deadline_budget(&self) -> Option<Duration> {
        self.inner.budget
    }

    /// Request cancellation. Returns immediately; the solve halts at its
    /// next checkpoint poll (cooperative — nothing is interrupted mid-row).
    /// Idempotent, and a no-op on a job that already halted or finished.
    pub fn cancel(&self) {
        self.inner.cancel.store(true, Ordering::Release);
    }

    /// Has [`SolveControl::cancel`] been called (whether or not the solve
    /// has noticed yet)?
    pub fn is_cancelled(&self) -> bool {
        self.inner.cancel.load(Ordering::Acquire)
    }

    /// Poll the halt conditions, recording (first-write-wins) and returning
    /// the halt reason if any holds. This is the call the solve loops make
    /// at their [`StopCheck`](crate::solvers::SolveOptions) checkpoints;
    /// admission pre-checks a queued job with it too, so a job whose
    /// deadline expired while queued fails without running.
    pub fn poll(&self) -> Option<Halt> {
        if let Some(h) = self.halted() {
            return Some(h);
        }
        if self.inner.cancel.load(Ordering::Acquire) {
            return Some(self.record(HALT_CANCELLED));
        }
        if let Some(d) = self.inner.deadline {
            if Instant::now() >= d {
                return Some(self.record(HALT_DEADLINE));
            }
        }
        None
    }

    /// The recorded halt reason, if a poll has observed one — without
    /// re-evaluating the conditions. The admission layer reads this after
    /// `solve` returns to decide whether the result is a completion or a
    /// typed failure.
    pub fn halted(&self) -> Option<Halt> {
        match self.inner.halt.load(Ordering::Acquire) {
            HALT_CANCELLED => Some(Halt::Cancelled),
            HALT_DEADLINE => Some(Halt::DeadlineExceeded),
            _ => None,
        }
    }

    /// First-write-wins recording: whichever reason is observed first
    /// sticks, even when polled concurrently from several threads.
    fn record(&self, reason: u8) -> Halt {
        let prev = self
            .inner
            .halt
            .compare_exchange(HALT_NONE, reason, Ordering::AcqRel, Ordering::Acquire)
            .unwrap_or_else(|prev| prev);
        let decoded = if prev == HALT_NONE { reason } else { prev };
        match decoded {
            HALT_CANCELLED => Halt::Cancelled,
            _ => Halt::DeadlineExceeded,
        }
    }
}

impl Default for SolveControl {
    fn default() -> Self {
        SolveControl::new()
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn fresh_token_is_inert() {
        let c = SolveControl::new();
        assert!(!c.is_cancelled());
        assert_eq!(c.poll(), None);
        assert_eq!(c.halted(), None);
        assert_eq!(c.deadline_budget(), None);
    }

    #[test]
    fn cancel_is_observed_and_recorded() {
        let c = SolveControl::new();
        c.cancel();
        assert!(c.is_cancelled());
        // halted() reads the record only — nothing recorded until a poll.
        assert_eq!(c.halted(), None);
        assert_eq!(c.poll(), Some(Halt::Cancelled));
        assert_eq!(c.halted(), Some(Halt::Cancelled));
    }

    #[test]
    fn clones_share_one_token() {
        let c = SolveControl::new();
        let solver_side = c.clone();
        c.cancel();
        assert_eq!(solver_side.poll(), Some(Halt::Cancelled));
        assert_eq!(c.halted(), Some(Halt::Cancelled));
    }

    #[test]
    fn elapsed_deadline_halts() {
        let c = SolveControl::with_deadline(Duration::ZERO);
        assert_eq!(c.poll(), Some(Halt::DeadlineExceeded));
        assert_eq!(c.halted(), Some(Halt::DeadlineExceeded));
        assert_eq!(c.deadline_budget(), Some(Duration::ZERO));
    }

    #[test]
    fn generous_deadline_does_not_halt() {
        let c = SolveControl::with_deadline(Duration::from_secs(3600));
        assert_eq!(c.poll(), None);
    }

    #[test]
    fn first_recorded_reason_wins() {
        // Deadline already elapsed AND cancelled: poll order decides, and
        // the first recorded reason is sticky.
        let c = SolveControl::with_deadline(Duration::ZERO);
        c.cancel();
        // Cancel is checked before the clock, so cancellation is recorded.
        assert_eq!(c.poll(), Some(Halt::Cancelled));
        assert_eq!(c.poll(), Some(Halt::Cancelled));
        assert_eq!(c.halted(), Some(Halt::Cancelled));
    }

    #[test]
    fn debug_shows_decoded_state() {
        let c = SolveControl::new();
        c.cancel();
        let s = format!("{c:?}");
        assert!(s.contains("cancelled"), "{s}");
    }
}
