//! Named resident-system registry: many `Arc`-shared [`LinearSystem`]s
//! cached with LRU eviction and capacity accounting.
//!
//! The serving story starts from a simple observation: for a Kaczmarz shop
//! the expensive object is the *system*, not the solve. Loading a
//! multi-GiB `A`, computing its squared row norms (the eq.-4 sampling
//! distribution) and Frobenius norm — that is per-*system* work, and the
//! paper's throughput pitch only holds if it is paid once and amortized
//! over every request that names the system afterwards. The registry keeps
//! that state **warm**: [`SystemRegistry::get`] hands out an
//! `Arc<LinearSystem>` whose row norms were computed at insert time
//! ([`LinearSystem`] precomputes them on construction), so a job against a
//! resident system does zero per-request preparation, and a thousand
//! concurrent jobs share one matrix — `Arc::ptr_eq`-identical, not cloned
//! (`tests/serving_properties.rs` probes exactly this).
//!
//! Capacity is accounted in **approximate resident bytes**
//! ([`SystemRegistry::resident_bytes`]): dense systems cost `m·n·8` for
//! the matrix plus the `O(m)`/`O(n)` side vectors, CSR systems cost their
//! stored entries (values + column indices) plus row offsets. When an
//! insert would exceed the configured budget, **least-recently-used**
//! entries are evicted until it fits — the freshly inserted system itself
//! is never evicted, so a system larger than the whole budget still
//! becomes resident (alone). Eviction drops the registry's `Arc` only:
//! jobs already holding the system keep it alive until they finish, so
//! eviction can never invalidate an in-flight solve.
//!
//! Sizing guidance lives in the README ("Serving front end"): the short
//! version is to budget against the same memory hierarchy the
//! [`crate::distributed::network::NetworkModel`] encodes — systems that fit
//! the last-level cache re-solve essentially free, dense systems beyond
//! DRAM belong behind the (future) out-of-core backend, not in this
//! registry.

use crate::data::LinearSystem;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Approximate resident footprint of a system, in bytes: matrix storage
/// (dense `m·n·8`, or CSR values + column indices + row offsets) plus the
/// `b`, `row_norms_sq`, and optional reference vectors. An accounting
/// estimate for eviction decisions, not an allocator-exact measurement.
pub fn approx_system_bytes(system: &LinearSystem) -> usize {
    let (m, n) = (system.rows(), system.cols());
    let f = std::mem::size_of::<f64>();
    let matrix = match system.a.as_csr() {
        // values (f64) + column indices (usize) per stored entry, plus the
        // m + 1 row offsets.
        Some(csr) => csr.nnz() * (f + std::mem::size_of::<usize>())
            + (m + 1) * std::mem::size_of::<usize>(),
        None => m * n * f,
    };
    let vectors = (m + m) * f // b + row_norms_sq
        + system.x_true.as_ref().map_or(0, |_| n * f)
        + system.x_ls.as_ref().map_or(0, |_| n * f);
    matrix + vectors
}

struct Entry {
    system: Arc<LinearSystem>,
    bytes: usize,
    /// Logical recency clock value at the last touch (monotonic counter,
    /// not wall time — cheap, and exact for LRU ordering).
    last_used: u64,
}

struct Inner {
    entries: HashMap<String, Entry>,
    resident_bytes: usize,
    /// Monotonic recency clock, bumped on every insert/get.
    tick: u64,
}

/// Thread-safe named cache of resident systems (see [module docs](self)).
///
/// All methods take `&self`; the registry is shared across the admission
/// lanes and the wire server behind one `Arc`.
pub struct SystemRegistry {
    inner: Mutex<Inner>,
    capacity_bytes: usize,
}

impl SystemRegistry {
    /// An empty registry with the given byte budget. The budget bounds the
    /// *sum* of [`approx_system_bytes`] over resident entries; a single
    /// over-budget system is still admitted (alone) rather than rejected —
    /// refusing to serve the workload's one big system would defeat the
    /// point of a cache.
    pub fn new(capacity_bytes: usize) -> Self {
        SystemRegistry {
            inner: Mutex::new(Inner { entries: HashMap::new(), resident_bytes: 0, tick: 0 }),
            capacity_bytes,
        }
    }

    /// Make `system` resident under `name`, evicting least-recently-used
    /// entries until the budget holds (the new entry itself is exempt).
    /// Replaces any previous entry of the same name. Returns the names
    /// evicted to make room, in eviction order.
    pub fn insert(&self, name: impl Into<String>, system: LinearSystem) -> Vec<String> {
        let name = name.into();
        let bytes = approx_system_bytes(&system);
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(old) = inner.entries.remove(&name) {
            inner.resident_bytes -= old.bytes;
        }
        inner.resident_bytes += bytes;
        inner
            .entries
            .insert(name.clone(), Entry { system: Arc::new(system), bytes, last_used: tick });

        // Evict oldest-touched entries (never the one just inserted) until
        // the budget holds or nothing else is left to evict.
        let mut evicted = Vec::new();
        while inner.resident_bytes > self.capacity_bytes && inner.entries.len() > 1 {
            let victim = inner
                .entries
                .iter()
                .filter(|(k, _)| **k != name)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("len > 1 entries minus the protected one is non-empty");
            let e = inner.entries.remove(&victim).expect("victim key just observed");
            inner.resident_bytes -= e.bytes;
            evicted.push(victim);
        }
        evicted
    }

    /// Fetch a resident system by name, bumping its recency. The returned
    /// `Arc` shares the registry's storage (no clone): drop it when the job
    /// finishes and the system stays resident; keep it across an eviction
    /// and the system stays *alive* (for you) even though it left the
    /// cache.
    pub fn get(&self, name: &str) -> Option<Arc<LinearSystem>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let e = inner.entries.get_mut(name)?;
        e.last_used = tick;
        Some(Arc::clone(&e.system))
    }

    /// Is `name` resident right now? (Does not bump recency.)
    pub fn contains(&self, name: &str) -> bool {
        self.inner.lock().unwrap().entries.contains_key(name)
    }

    /// Remove one entry by name; `true` if it was resident.
    pub fn remove(&self, name: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.entries.remove(name) {
            Some(e) => {
                inner.resident_bytes -= e.bytes;
                true
            }
            None => false,
        }
    }

    /// Number of resident systems.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current accounted footprint (sum of [`approx_system_bytes`] over
    /// resident entries).
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().resident_bytes
    }

    /// The configured byte budget.
    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    /// Resident names with their shapes, least-recently-used first — the
    /// order the next over-budget insert would evict them in.
    pub fn names_by_recency(&self) -> Vec<(String, usize, usize)> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<(&String, &Entry)> = inner.entries.iter().collect();
        v.sort_by_key(|(_, e)| e.last_used);
        v.into_iter().map(|(k, e)| (k.clone(), e.system.rows(), e.system.cols())).collect()
    }
}

impl std::fmt::Debug for SystemRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("SystemRegistry")
            .field("entries", &inner.entries.len())
            .field("resident_bytes", &inner.resident_bytes)
            .field("capacity_bytes", &self.capacity_bytes)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;

    fn sys(m: usize, n: usize, seed: u32) -> LinearSystem {
        DatasetBuilder::new(m, n).seed(seed).consistent()
    }

    #[test]
    fn dense_byte_accounting_scales_with_shape() {
        let small = approx_system_bytes(&sys(40, 8, 1));
        let big = approx_system_bytes(&sys(80, 8, 1));
        assert!(big > small);
        // Dominated by the m*n*8 matrix term.
        assert!(approx_system_bytes(&sys(40, 8, 1)) >= 40 * 8 * 8);
    }

    #[test]
    fn csr_byte_accounting_counts_stored_entries_only() {
        use crate::data::SparseDatasetBuilder;
        let sparse = SparseDatasetBuilder::new(200, 40, 0.05).seed(3).consistent();
        let dense = sys(200, 40, 3);
        // 5% density: far below the dense footprint.
        assert!(approx_system_bytes(&sparse) < approx_system_bytes(&dense) / 2);
    }

    #[test]
    fn get_returns_arc_shared_resident_system() {
        let reg = SystemRegistry::new(usize::MAX);
        reg.insert("demo", sys(60, 6, 1));
        let a = reg.get("demo").unwrap();
        let b = reg.get("demo").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "both handles must share one resident system");
        assert!(reg.get("absent").is_none());
    }

    #[test]
    fn insert_evicts_least_recently_used_first() {
        let one = approx_system_bytes(&sys(60, 6, 1));
        // Room for two systems of this shape, not three.
        let reg = SystemRegistry::new(2 * one + one / 2);
        assert!(reg.insert("a", sys(60, 6, 1)).is_empty());
        assert!(reg.insert("b", sys(60, 6, 2)).is_empty());
        // Touch "a": "b" becomes the LRU entry.
        reg.get("a").unwrap();
        let evicted = reg.insert("c", sys(60, 6, 3));
        assert_eq!(evicted, vec!["b".to_string()]);
        assert!(reg.contains("a") && reg.contains("c") && !reg.contains("b"));
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn over_budget_system_is_admitted_alone() {
        let reg = SystemRegistry::new(1); // absurdly small budget
        reg.insert("small", sys(40, 4, 1));
        let evicted = reg.insert("huge", sys(80, 8, 2));
        assert_eq!(evicted, vec!["small".to_string()]);
        assert!(reg.contains("huge"));
        assert_eq!(reg.len(), 1);
        assert!(reg.resident_bytes() > reg.capacity_bytes());
    }

    #[test]
    fn replacing_a_name_keeps_accounting_exact() {
        let reg = SystemRegistry::new(usize::MAX);
        reg.insert("x", sys(60, 6, 1));
        let after_first = reg.resident_bytes();
        reg.insert("x", sys(60, 6, 2)); // same shape, same bytes
        assert_eq!(reg.resident_bytes(), after_first);
        assert_eq!(reg.len(), 1);
        assert!(reg.remove("x"));
        assert!(!reg.remove("x"));
        assert_eq!(reg.resident_bytes(), 0);
        assert!(reg.is_empty());
    }

    #[test]
    fn eviction_does_not_invalidate_held_handles() {
        let one = approx_system_bytes(&sys(60, 6, 1));
        let reg = SystemRegistry::new(one + one / 2);
        reg.insert("a", sys(60, 6, 1));
        let held = reg.get("a").unwrap();
        reg.insert("b", sys(60, 6, 2)); // evicts "a"
        assert!(!reg.contains("a"));
        // The held Arc still works: solve state intact.
        assert_eq!(held.rows(), 60);
        assert!(held.row_norms_sq.iter().all(|&r| r > 0.0));
    }

    #[test]
    fn names_by_recency_reports_eviction_order() {
        let reg = SystemRegistry::new(usize::MAX);
        reg.insert("a", sys(40, 4, 1));
        reg.insert("b", sys(40, 4, 2));
        reg.get("a").unwrap();
        let names: Vec<String> = reg.names_by_recency().into_iter().map(|(n, ..)| n).collect();
        assert_eq!(names, vec!["b".to_string(), "a".to_string()]);
    }
}
