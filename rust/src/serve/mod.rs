//! Network serving front end: resident systems, admission control, and a
//! framed-TCP wire protocol.
//!
//! The [`batch`](crate::batch) layer made the solver core a *throughput
//! engine* for callers inside the process. This module is the remaining
//! serving story from the roadmap — callers **outside** the process:
//!
//! - [`registry`] — named resident [`LinearSystem`](crate::data::LinearSystem)s
//!   behind `Arc`s, with LRU eviction under a byte budget. Loading a
//!   multi-GiB dense system per request would dwarf any solve; residency
//!   amortizes it across every job that names the system, and the
//!   precomputed squared row norms (the eq.-4 sampling distribution) stay
//!   warm with it.
//! - [`control`] — the cooperative [`SolveControl`] token: cancellation and
//!   per-job deadlines observed at the existing
//!   [`StopCheck`](crate::solvers::StopCheck) checkpoints, so remote
//!   callers can abandon work without any thread ever being killed.
//! - [`admission`] — the [`SolveFrontEnd`]: a bounded submission queue that
//!   refuses work with the typed
//!   [`Error::Overloaded`](crate::error::Error::Overloaded) instead of
//!   buffering unboundedly, persistent lane threads (spawned once), and
//!   queue-wait / dropped-sample accounting in every
//!   [`SolveReport`](crate::batch::SolveReport).
//! - [`wire`] — the newline-delimited frame codec (`SUBMIT`/`POLL`/
//!   `CANCEL`/`SAMPLE`/`DONE`/`ERR`…), kept free of any socket so it is
//!   testable byte-for-byte, plus the α-β cost model for what streaming
//!   telemetry costs on the wire.
//! - [`server`] / [`client`] — the framed-TCP binding of the two:
//!   `kaczmarz serve` boots a [`WireServer`] over a registry + front end;
//!   `kaczmarz submit` is the minimal client, streaming mid-solve
//!   [`Sample`](crate::metrics::Sample)s line by line.
//!
//! ## Concurrency discipline
//!
//! This module deliberately contains **no `unsafe` and no
//! `Ordering::Relaxed`**: the only lock-free state is the
//! [`SolveControl`] token (loom-checked in `tests/loom.rs`), and
//! everything else uses plain `Mutex`/`Condvar` — serving control planes
//! are cold paths; the hot path is the solve itself, which this module
//! never touches.

pub mod admission;
pub mod client;
pub mod control;
pub mod registry;
pub mod server;
pub mod wire;

pub use admission::{FrontEndConfig, FrontStats, JobStatus, SolveFrontEnd, SubmitRequest};
pub use client::RemoteOutcome;
pub use control::{Halt, SolveControl};
pub use registry::{approx_system_bytes, SystemRegistry};
pub use server::{ServerHandle, WireServer};
