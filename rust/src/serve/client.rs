//! Minimal wire client: submit, stream, poll, cancel over framed TCP.
//!
//! This is the library behind `kaczmarz submit` — and a reference for what
//! any client in any language needs: open a TCP connection, write one
//! `SUBMIT` line, read newline-delimited frames back. No handshake, no
//! binary framing, no state beyond the job id.

use super::wire::{self, ErrKind, Reply, Request, SubmitFrame};
use crate::error::{Error, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};

/// Terminal outcome of a remote job, as reported over the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum RemoteOutcome {
    /// The job finished with a report.
    Done {
        /// Iterations the solve spent.
        iterations: usize,
        /// Whether the stopping criterion was met.
        converged: bool,
        /// Final residual norm against the job's system.
        residual: f64,
        /// Milliseconds the job waited for a lane.
        queue_wait_ms: u64,
        /// Telemetry samples the job's sink shed.
        dropped: u64,
    },
    /// The job (or the submission itself) failed with a typed error.
    Failed {
        /// Wire error class (`overloaded`, `deadline`, `cancelled`, …).
        kind: ErrKind,
        /// Server-side error message.
        msg: String,
    },
}

fn proto_err(msg: impl Into<String>) -> Error {
    Error::InvalidArgument(format!("wire protocol: {}", msg.into()))
}

fn send_line(writer: &mut BufWriter<TcpStream>, req: &Request) -> Result<()> {
    writer.write_all(req.to_line().as_bytes()).map_err(Error::Io)?;
    writer.write_all(b"\n").map_err(Error::Io)?;
    writer.flush().map_err(Error::Io)
}

fn read_frame(reader: &mut BufReader<TcpStream>) -> Result<Reply> {
    let mut line = String::new();
    let n = reader.read_line(&mut line).map_err(Error::Io)?;
    if n == 0 {
        return Err(proto_err("server closed the connection mid-exchange"));
    }
    wire::parse_reply(&line).map_err(proto_err)
}

/// Submit `frame` and stream it to completion: `on_sample(id, k, residual,
/// elapsed_ms)` fires per mid-solve `SAMPLE` line (the id lets the callback
/// act on the job — e.g. [`cancel`] it from a second connection), and the
/// terminal frame becomes the returned [`RemoteOutcome`]. The frame's
/// `stream` flag is forced on (a non-streaming submit has no terminal frame
/// to wait for — use [`poll`] for fire-and-poll clients). A refused
/// submission (overloaded, unknown system…) returns `Ok` with
/// [`RemoteOutcome::Failed`] and job id 0 — the refusal is data, not a
/// transport failure.
pub fn submit_streaming(
    addr: impl ToSocketAddrs,
    frame: &SubmitFrame,
    mut on_sample: impl FnMut(u64, usize, f64, u64),
) -> Result<(u64, RemoteOutcome)> {
    let conn = TcpStream::connect(addr).map_err(Error::Io)?;
    let mut reader = BufReader::new(conn.try_clone().map_err(Error::Io)?);
    let mut writer = BufWriter::new(conn);
    let mut frame = frame.clone();
    frame.stream = true;
    send_line(&mut writer, &Request::Submit(frame))?;
    let id = match read_frame(&mut reader)? {
        Reply::Queued { id } => id,
        Reply::Err { kind, msg } => return Ok((0, RemoteOutcome::Failed { kind, msg })),
        other => return Err(proto_err(format!("expected QUEUED, got {}", other.to_line()))),
    };
    loop {
        match read_frame(&mut reader)? {
            Reply::Sample { k, residual, elapsed_ms, .. } => {
                on_sample(id, k, residual, elapsed_ms)
            }
            Reply::Done { iterations, converged, residual, queue_wait_ms, dropped, .. } => {
                return Ok((
                    id,
                    RemoteOutcome::Done {
                        iterations,
                        converged,
                        residual,
                        queue_wait_ms,
                        dropped,
                    },
                ));
            }
            Reply::Err { kind, msg } => return Ok((id, RemoteOutcome::Failed { kind, msg })),
            other => {
                return Err(proto_err(format!("unexpected stream frame {}", other.to_line())))
            }
        }
    }
}

/// Snapshot a job's status: `None` while it is still queued/running,
/// `Some(outcome)` once terminal.
pub fn poll(addr: impl ToSocketAddrs, id: u64) -> Result<Option<RemoteOutcome>> {
    let conn = TcpStream::connect(addr).map_err(Error::Io)?;
    let mut reader = BufReader::new(conn.try_clone().map_err(Error::Io)?);
    let mut writer = BufWriter::new(conn);
    send_line(&mut writer, &Request::Poll { id })?;
    match read_frame(&mut reader)? {
        Reply::Queued { .. } | Reply::Running { .. } => Ok(None),
        Reply::Done { iterations, converged, residual, queue_wait_ms, dropped, .. } => {
            Ok(Some(RemoteOutcome::Done { iterations, converged, residual, queue_wait_ms, dropped }))
        }
        Reply::Err { kind, msg } => Ok(Some(RemoteOutcome::Failed { kind, msg })),
        other => Err(proto_err(format!("unexpected poll reply {}", other.to_line()))),
    }
}

/// Request cancellation of job `id` (usually from a second connection while
/// the first streams it). Returns whether the server found a live job.
pub fn cancel(addr: impl ToSocketAddrs, id: u64) -> Result<bool> {
    let conn = TcpStream::connect(addr).map_err(Error::Io)?;
    let mut reader = BufReader::new(conn.try_clone().map_err(Error::Io)?);
    let mut writer = BufWriter::new(conn);
    send_line(&mut writer, &Request::Cancel { id })?;
    match read_frame(&mut reader)? {
        Reply::Ack { applied, .. } => Ok(applied),
        other => Err(proto_err(format!("expected ACK, got {}", other.to_line()))),
    }
}

/// Liveness probe: `Ok` once the server answers `PING` with `PONG` (the
/// smoke script's readiness gate).
pub fn ping(addr: impl ToSocketAddrs) -> Result<()> {
    let conn = TcpStream::connect(addr).map_err(Error::Io)?;
    let mut reader = BufReader::new(conn.try_clone().map_err(Error::Io)?);
    let mut writer = BufWriter::new(conn);
    send_line(&mut writer, &Request::Ping)?;
    match read_frame(&mut reader)? {
        Reply::Pong => Ok(()),
        other => Err(proto_err(format!("expected PONG, got {}", other.to_line()))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::serve::admission::{FrontEndConfig, SolveFrontEnd};
    use crate::serve::registry::SystemRegistry;
    use crate::serve::server::{ServerHandle, WireServer};
    use std::sync::Arc;
    use std::time::Duration;

    fn boot() -> ServerHandle {
        let registry = Arc::new(SystemRegistry::new(usize::MAX));
        registry.insert("demo", DatasetBuilder::new(200, 12).seed(1).consistent());
        let front = Arc::new(SolveFrontEnd::new(
            registry,
            FrontEndConfig { lanes: 2, max_pending: 16 },
        ));
        WireServer::bind("127.0.0.1:0", front).unwrap().spawn().unwrap()
    }

    #[test]
    fn ping_then_stream_a_job_to_done() {
        let server = boot();
        ping(server.addr()).unwrap();
        let mut frame = SubmitFrame::new("demo");
        frame.check = 4;
        frame.tol = 1e-10;
        let mut samples = 0usize;
        let (id, outcome) =
            submit_streaming(server.addr(), &frame, |_id, _k, residual, _ms| {
                assert!(residual.is_finite());
                samples += 1;
            })
            .unwrap();
        match outcome {
            RemoteOutcome::Done { converged, residual, .. } => {
                assert!(converged);
                assert!(residual * residual <= 1e-9, "residual {residual}");
            }
            other => panic!("expected Done, got {other:?}"),
        }
        assert!(samples >= 1, "no samples streamed");
        // The job is terminal now; poll agrees from a fresh connection.
        assert!(poll(server.addr(), id).unwrap().is_some());
        server.shutdown();
    }

    #[test]
    fn refused_submission_is_failed_data_not_transport_error() {
        let server = boot();
        let (_, outcome) = submit_streaming(
            server.addr(),
            &SubmitFrame::new("no-such-system"),
            |_, _, _, _| {},
        )
        .unwrap();
        match outcome {
            RemoteOutcome::Failed { kind, msg } => {
                assert_eq!(kind, ErrKind::Invalid);
                assert!(msg.contains("no-such-system"), "{msg}");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        server.shutdown();
    }

    #[test]
    fn past_deadline_job_fails_typed_over_the_wire() {
        let server = boot();
        let mut frame = SubmitFrame::new("demo");
        frame.tol = 0.0; // unsatisfiable
        frame.check = 4;
        frame.max_iterations = Some(usize::MAX / 2);
        frame.deadline_ms = Some(1);
        let (_, outcome) = submit_streaming(server.addr(), &frame, |_, _, _, _| {}).unwrap();
        match outcome {
            RemoteOutcome::Failed { kind, .. } => assert_eq!(kind, ErrKind::Deadline),
            other => panic!("expected deadline failure, got {other:?}"),
        }
        // A sibling normal job still completes: one stuck deadline must not
        // poison the lanes.
        let mut ok = SubmitFrame::new("demo");
        ok.check = 4;
        let (_, outcome) = submit_streaming(server.addr(), &ok, |_, _, _, _| {}).unwrap();
        assert!(matches!(outcome, RemoteOutcome::Done { converged: true, .. }));
        server.shutdown();
    }

    #[test]
    fn cancel_from_second_connection_stops_streamed_job() {
        let server = boot();
        let addr = server.addr();
        let mut frame = SubmitFrame::new("demo");
        frame.tol = 0.0; // runs until cancelled
        frame.check = 4;
        frame.max_iterations = Some(usize::MAX / 2);
        let (_, outcome) = submit_streaming(addr, &frame, move |id, _k, _r, _ms| {
            // First sample: the job is provably mid-solve; cancel it from a
            // second connection. Repeated cancels are harmless.
            let _ = cancel(addr, id);
        })
        .unwrap();
        match outcome {
            RemoteOutcome::Failed { kind, .. } => assert_eq!(kind, ErrKind::Cancelled),
            other => panic!("expected cancelled, got {other:?}"),
        }
        // Conservation: the front end counted exactly one cancel.
        let stats = server.front().stats();
        assert_eq!(stats.submitted, 1);
        assert_eq!(stats.cancelled, 1);
        std::thread::sleep(Duration::from_millis(10));
        server.shutdown();
    }
}
