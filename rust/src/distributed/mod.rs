//! Distributed-memory layer — the paper's MPI side, simulated.
//!
//! The paper runs RKA/RKAB on the Navigator cluster (43 nodes, 2 x 12-core
//! Xeon E5-2697v2, 96 GB each) over MPI. That hardware is not available
//! here, so this module builds the closest substrate that exercises the same
//! code paths (see DESIGN.md §3):
//!
//! - [`comm`] — ranks are participants of one dispatch on the persistent
//!   [`crate::parallel::pool`] with *private* memory (each owns only its
//!   row partition, like an MPI process), exchanging messages over
//!   channels; `Allreduce` is real recursive doubling, including the
//!   non-power-of-two pre/post folding (the paper uses np ∈ {12, 24, 48});
//! - [`network`] — an α-β cost model with distinct intra-/inter-node links
//!   and a process-placement map (24-per-node vs 2-per-node, the two
//!   configurations of Figs. 6 and 11), plus an LLC-contention penalty that
//!   reproduces the paper's "memory access time beats communication time for
//!   large systems" effect;
//! - [`rka_dist`] — Algorithm 2; [`rkab_dist`] — Algorithm 4.
//!
//! Wall-clock compute time is *measured* per rank; communication time is
//! *modeled*; the reported simulated time is
//! `max over ranks (compute + comm)` per the bulk-synchronous structure.

pub mod cluster;
pub mod comm;
pub mod network;
pub mod rka_dist;
pub mod rkab_dist;

pub use cluster::{DistResult, SimCluster};
pub use comm::Communicator;
pub use network::{NetworkModel, Placement};
pub use rka_dist::DistRka;
pub use rkab_dist::DistRkab;
