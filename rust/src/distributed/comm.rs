//! Message-passing between simulated ranks.
//!
//! Each rank owns a [`Communicator`]: senders to every peer and one inbox.
//! Receives are *tagged by source* — messages from other partners arriving
//! early are stashed, exactly the discipline `MPI_Recv(source=...)` gives.
//!
//! `allreduce_sum` implements recursive doubling with the standard
//! fold-to-power-of-two pre/post phases so the paper's np ∈ {12, 24, 48}
//! work, and charges every message to the α-β model. MPI's tree/hypercube
//! Allreduce is O(log np) rounds — the very property the paper contrasts
//! against OpenMP's O(q) critical section (§3.3.2).

use super::network::{NetworkModel, Placement};
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};

/// One message: source rank + payload.
struct Msg {
    from: usize,
    data: Vec<f64>,
}

/// Per-rank endpoint of the simulated interconnect.
pub struct Communicator {
    rank: usize,
    np: usize,
    peers: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    stash: VecDeque<Msg>,
    /// Modeled communication seconds accumulated by this rank.
    pub comm_seconds: f64,
    model: NetworkModel,
    placement: Placement,
}

impl Communicator {
    /// Wire up a full interconnect for `np` ranks.
    pub fn create_world(
        np: usize,
        model: &NetworkModel,
        placement: Placement,
    ) -> Vec<Communicator> {
        let mut senders = Vec::with_capacity(np);
        let mut receivers = Vec::with_capacity(np);
        for _ in 0..np {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        receivers
            .into_iter()
            .enumerate()
            .map(|(rank, inbox)| Communicator {
                rank,
                np,
                peers: senders.clone(),
                inbox,
                stash: VecDeque::new(),
                comm_seconds: 0.0,
                model: model.clone(),
                placement,
            })
            .collect()
    }

    /// This rank's id.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// World size.
    pub fn world_size(&self) -> usize {
        self.np
    }

    /// Send `data` to `to` (charges the α-β cost to this rank).
    pub fn send(&mut self, to: usize, data: Vec<f64>) {
        self.comm_seconds +=
            self.model.message_cost(self.rank, to, data.len() * 8, self.placement);
        self.peers[to]
            .send(Msg { from: self.rank, data })
            .expect("peer hung up");
    }

    /// Blocking receive of the next message *from `from`* (others stashed).
    pub fn recv_from(&mut self, from: usize) -> Vec<f64> {
        // Check the stash first.
        if let Some(pos) = self.stash.iter().position(|m| m.from == from) {
            return self.stash.remove(pos).unwrap().data;
        }
        loop {
            let msg = self.inbox.recv().expect("world disconnected");
            if msg.from == from {
                return msg.data;
            }
            self.stash.push_back(msg);
        }
    }

    /// In-place sum-Allreduce via recursive doubling.
    ///
    /// Non-power-of-two worlds fold the `r = np - 2^k` extra ranks into the
    /// power-of-two core first and broadcast back after (the classic MPICH
    /// scheme). After return every rank holds the elementwise sum.
    pub fn allreduce_sum(&mut self, x: &mut [f64]) {
        let np = self.np;
        if np == 1 {
            return;
        }
        let pof2 = np.next_power_of_two() / if np.is_power_of_two() { 1 } else { 2 };
        let rem = np - pof2;
        let rank = self.rank;

        // Pre-phase: ranks [0, 2*rem) pair up; odd of each pair sends its
        // data to the even and drops out of the core exchange.
        let mut core_rank: Option<usize> = None;
        if rank < 2 * rem {
            if rank % 2 == 1 {
                // Donor: send, wait for the result in the post-phase.
                let partner = rank - 1;
                self.send(partner, x.to_vec());
            } else {
                let partner = rank + 1;
                let other = self.recv_from(partner);
                for (xi, oi) in x.iter_mut().zip(&other) {
                    *xi += oi;
                }
                core_rank = Some(rank / 2);
            }
        } else {
            core_rank = Some(rank - rem);
        }

        // Core: recursive doubling among pof2 virtual ranks.
        if let Some(vrank) = core_rank {
            let to_real = |v: usize| if v < rem { 2 * v } else { v + rem };
            let mut mask = 1usize;
            while mask < pof2 {
                let vpartner = vrank ^ mask;
                let partner = to_real(vpartner);
                // Exchange: send ours, receive theirs (full-duplex; charge
                // one message cost each way — send() charges ours).
                self.send(partner, x.to_vec());
                let theirs = self.recv_from(partner);
                for (xi, ti) in x.iter_mut().zip(&theirs) {
                    *xi += ti;
                }
                mask <<= 1;
            }
        }

        // Post-phase: evens send the final result back to their donors.
        if rank < 2 * rem {
            if rank % 2 == 0 {
                self.send(rank + 1, x.to_vec());
            } else {
                let result = self.recv_from(rank - 1);
                x.copy_from_slice(&result);
            }
        }
    }

    /// Broadcast a single flag from rank 0 (used for stop decisions).
    pub fn broadcast_flag(&mut self, flag: &mut f64) {
        // Binomial tree from rank 0: node r's parent clears r's lowest set
        // bit; its children are r + m for m = lowbit(r)/2, lowbit(r)/4, ... 1.
        let np = self.np;
        if np == 1 {
            return;
        }
        let rank = self.rank;
        if rank != 0 {
            let parent = rank & (rank - 1);
            let v = self.recv_from(parent);
            *flag = v[0];
        }
        let mut m = if rank == 0 {
            np.next_power_of_two() / 2
        } else {
            (rank & rank.wrapping_neg()) / 2
        };
        while m >= 1 {
            let child = rank + m;
            if child < np {
                self.send(child, vec![*flag]);
            }
            m /= 2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Worlds run as pool dispatches via SimCluster (default network model,
    // packed placement), so these tests also exercise the engine the
    // distributed solvers actually run on.
    fn run_world<F>(np: usize, f: F) -> Vec<Vec<f64>>
    where
        F: Fn(&mut Communicator) -> Vec<f64> + Sync,
    {
        super::super::cluster::SimCluster::new(np, Placement::full_node())
            .run(|_rank, c| f(c))
    }

    #[test]
    fn allreduce_sums_across_world_sizes() {
        for np in [1usize, 2, 3, 4, 5, 8, 12] {
            let results = run_world(np, |c| {
                // Rank r contributes [r, 2r, r²].
                let r = c.rank() as f64;
                let mut x = vec![r, 2.0 * r, r * r];
                c.allreduce_sum(&mut x);
                x
            });
            let s: f64 = (0..np).map(|r| r as f64).sum();
            let sq: f64 = (0..np).map(|r| (r * r) as f64).sum();
            for (rank, x) in results.iter().enumerate() {
                assert_eq!(x[0], s, "np={np} rank={rank}");
                assert_eq!(x[1], 2.0 * s);
                assert_eq!(x[2], sq);
            }
        }
    }

    #[test]
    fn allreduce_charges_comm_time() {
        let results = run_world(4, |c| {
            let mut x = vec![1.0; 1000];
            c.allreduce_sum(&mut x);
            vec![c.comm_seconds]
        });
        for x in &results {
            assert!(x[0] > 0.0, "no comm time charged");
        }
    }

    #[test]
    fn broadcast_flag_reaches_everyone() {
        for np in [1usize, 2, 3, 5, 8] {
            let results = run_world(np, |c| {
                let mut flag = if c.rank() == 0 { 7.5 } else { 0.0 };
                c.broadcast_flag(&mut flag);
                vec![flag]
            });
            for (rank, x) in results.iter().enumerate() {
                assert_eq!(x[0], 7.5, "np={np} rank={rank}");
            }
        }
    }

    #[test]
    fn tagged_receive_stashes_out_of_order() {
        let results = run_world(3, |c| {
            match c.rank() {
                0 => {
                    // Both peers send immediately; receive 2 first, then 1.
                    let a = c.recv_from(2);
                    let b = c.recv_from(1);
                    vec![a[0], b[0]]
                }
                r => {
                    c.send(0, vec![r as f64]);
                    vec![]
                }
            }
        });
        assert_eq!(results[0], vec![2.0, 1.0]);
    }
}
