//! Simulated cluster runner and result types.
//!
//! A [`SimCluster`] runs its ranks as participants of one dispatch on the
//! persistent [`crate::parallel::pool`] — the same engine the shared-memory
//! solvers use — so a distributed solve performs zero `thread::spawn` calls
//! after pool warm-up, exactly like the shared-memory side. Ranks keep
//! *private* memories and communicate only through their
//! [`Communicator`] channels, so pool threads still model MPI processes
//! faithfully.

use super::comm::Communicator;
use super::network::{NetworkModel, Placement};
use crate::metrics::History;
use crate::parallel::pool::WorkerPool;
use std::sync::{Arc, Mutex};

/// A simulated cluster: `np` ranks under a placement and a network model.
pub struct SimCluster {
    /// Number of MPI-like processes.
    pub np: usize,
    /// Network cost model.
    pub model: NetworkModel,
    /// Process-to-node placement.
    pub placement: Placement,
    /// Worker-pool override (`None` = the process-global pool).
    pool: Option<Arc<WorkerPool>>,
}

impl SimCluster {
    /// Cluster with the default Navigator-like model.
    pub fn new(np: usize, placement: Placement) -> Self {
        assert!(np >= 1);
        SimCluster { np, model: NetworkModel::default(), placement, pool: None }
    }

    /// Run the ranks on a dedicated pool instead of the process-global one
    /// (useful when composing with solvers that also dispatch — nesting on
    /// the *same* pool fails fast by design).
    pub fn with_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Run one closure per rank, each as a participant of a single pool
    /// dispatch; returns the per-rank outputs in rank order.
    ///
    /// Every rank owns its [`Communicator`] for the duration of the run and
    /// blocks in channel receives while waiting for peers, so the dispatch
    /// stays deadlock-free even when `np` exceeds the core count (a parked
    /// receive yields the CPU; same discipline as the scoped-thread
    /// formulation this replaces, but with zero per-solve thread spawns).
    ///
    /// ```
    /// use kaczmarz::distributed::{Placement, SimCluster};
    ///
    /// let cluster = SimCluster::new(3, Placement::two_per_node());
    /// let sums = cluster.run(|rank, comm| {
    ///     let mut x = vec![rank as f64];
    ///     comm.allreduce_sum(&mut x);
    ///     x[0]
    /// });
    /// assert_eq!(sums, vec![3.0, 3.0, 3.0]);
    /// ```
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &mut Communicator) -> T + Sync,
    {
        let comms = Communicator::create_world(self.np, &self.model, self.placement);
        // Hand each participant its own endpoint and result slot. A rank
        // panic drops its Communicator, which hangs up the peers' channels
        // and unwinds the whole world; the pool drains the dispatch and
        // re-raises on this thread.
        let endpoints: Vec<Mutex<Option<Communicator>>> =
            comms.into_iter().map(|c| Mutex::new(Some(c))).collect();
        let out: Vec<Mutex<Option<T>>> = (0..self.np).map(|_| Mutex::new(None)).collect();
        let pool = self.pool.as_deref().unwrap_or_else(|| crate::parallel::pool::global());
        pool.run(self.np, |rank| {
            let mut comm =
                endpoints[rank].lock().unwrap().take().expect("rank dispatched once");
            let result = f(rank, &mut comm);
            *out[rank].lock().unwrap() = Some(result);
        });
        out.into_iter()
            .map(|slot| slot.into_inner().unwrap().expect("rank produced an output"))
            .collect()
    }

    /// Ranks co-located with `rank` on its node (for contention accounting).
    pub fn ranks_on_node(&self, rank: usize) -> usize {
        let node = self.placement.node_of(rank);
        (0..self.np).filter(|&r| self.placement.node_of(r) == node).count()
    }
}

/// Per-rank timing breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct RankStats {
    /// Measured compute seconds (iteration work only).
    pub compute_seconds: f64,
    /// Modeled communication seconds (α-β model).
    pub comm_seconds: f64,
    /// Contention-adjusted compute seconds.
    pub adjusted_compute_seconds: f64,
}

/// Result of a distributed solve.
#[derive(Clone, Debug)]
pub struct DistResult {
    /// Final (replicated) solution estimate.
    pub x: Vec<f64>,
    /// Outer iterations executed.
    pub iterations: usize,
    /// Stopping criterion met (always false for fixed-iteration runs,
    /// which measure nothing).
    pub converged: bool,
    /// Divergence detected.
    pub diverged: bool,
    /// Total rows processed across ranks.
    pub rows_used: usize,
    /// Host wall-clock of the whole run (threads + channels; *not* the
    /// number to compare against the paper).
    pub wall_seconds: f64,
    /// Simulated time: `max over ranks (adjusted compute + modeled comm)` —
    /// the number Figs. 6 and 11 are built from.
    pub sim_seconds: f64,
    /// Per-rank breakdown.
    pub rank_stats: Vec<RankStats>,
    /// Error/residual history recorded by rank 0.
    pub history: History,
}

impl DistResult {
    /// Aggregate sim time from rank stats (max of per-rank totals).
    pub fn sim_total(stats: &[RankStats]) -> f64 {
        stats
            .iter()
            .map(|s| s.adjusted_compute_seconds + s.comm_seconds)
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_executes_every_rank() {
        let cluster = SimCluster::new(5, Placement::two_per_node());
        let out = cluster.run(|rank, c| {
            assert_eq!(c.rank(), rank);
            rank * 10
        });
        assert_eq!(out, vec![0, 10, 20, 30, 40]);
    }

    #[test]
    fn ranks_on_node_counts() {
        let cluster = SimCluster::new(5, Placement::two_per_node());
        assert_eq!(cluster.ranks_on_node(0), 2); // node 0: ranks 0,1
        assert_eq!(cluster.ranks_on_node(4), 1); // node 2: rank 4 alone
        let packed = SimCluster::new(5, Placement::full_node());
        assert_eq!(packed.ranks_on_node(0), 5);
    }

    #[test]
    fn sim_total_is_max_over_ranks() {
        let stats = vec![
            RankStats { compute_seconds: 1.0, comm_seconds: 0.5, adjusted_compute_seconds: 1.2 },
            RankStats { compute_seconds: 0.8, comm_seconds: 1.5, adjusted_compute_seconds: 0.9 },
        ];
        assert!((DistResult::sim_total(&stats) - 2.4).abs() < 1e-12);
    }
}
