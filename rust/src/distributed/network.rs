//! Network cost model and process placement.
//!
//! An α-β (latency-bandwidth) model with two link classes. Message cost:
//! `T(bytes) = α_link + bytes / B_link`, link class decided by whether the
//! two ranks share a node under the chosen [`Placement`]. Constants default
//! to values representative of the paper's testbed generation (dual-socket
//! Xeon E5 v2 nodes on FDR InfiniBand); what matters for reproduction is the
//! *ratio* intra/inter, not the absolute numbers.

/// How ranks are packed onto cluster nodes.
///
/// The paper's two configurations (§3.3.2): fill whole 24-core nodes
/// (`ppn = 24`) or spread 2 processes per node (`ppn = 2`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    /// Processes per node.
    pub ppn: usize,
}

impl Placement {
    /// Fill whole nodes (1 process per core, 24-core nodes).
    pub fn full_node() -> Self {
        Placement { ppn: 24 }
    }

    /// Two processes per node (one per socket).
    pub fn two_per_node() -> Self {
        Placement { ppn: 2 }
    }

    /// Node index hosting `rank` (block placement, like `mpirun --map-by`).
    #[inline]
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.ppn
    }

    /// Number of nodes needed for `np` ranks.
    pub fn nodes_for(&self, np: usize) -> usize {
        np.div_ceil(self.ppn)
    }
}

/// α-β network model + LLC contention penalty.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    /// Per-message latency between ranks on the same node (seconds).
    pub alpha_intra: f64,
    /// Per-message latency across nodes (seconds).
    pub alpha_inter: f64,
    /// Intra-node bandwidth (bytes/second) — shared-memory transport.
    pub bw_intra: f64,
    /// Inter-node bandwidth (bytes/second).
    pub bw_inter: f64,
    /// Last-level cache per node (bytes); working sets beyond this pay the
    /// contention penalty.
    pub llc_bytes: f64,
    /// Compute-slowdown factor at full memory contention (the paper's
    /// "processes on the same node contend for entries in the L3 cache").
    pub mem_penalty: f64,
    /// Cores per node (contention scales with co-located ranks).
    pub cores_per_node: usize,
}

impl Default for NetworkModel {
    fn default() -> Self {
        // Navigator-generation link constants: FDR InfiniBand (~5.8 GB/s
        // payload, ~1.5 µs latency), shared-memory transport ~10 GB/s /
        // 0.5 µs. `llc_bytes` is NOT the physical 60 MB of the paper's
        // nodes: because this repo runs the experiments at ~1/25 of the
        // paper's matrix areas (DESIGN.md §3), the cache threshold is scaled
        // so the *regime boundary* is preserved — the paper's smaller system
        // (20000 x 2000) behaves cache-friendly under full packing while the
        // larger one (40000 x 4000) contends; at our scaled sizes that
        // boundary sits between ~13 MB and ~50 MB of per-node working set.
        NetworkModel {
            alpha_intra: 0.5e-6,
            alpha_inter: 1.5e-6,
            bw_intra: 10.0e9,
            bw_inter: 5.8e9,
            llc_bytes: 24.0e6,
            mem_penalty: 0.5,
            cores_per_node: 24,
        }
    }
}

impl NetworkModel {
    /// Cost of one point-to-point message of `bytes` between two ranks.
    pub fn message_cost(&self, from: usize, to: usize, bytes: usize, placement: Placement) -> f64 {
        if placement.node_of(from) == placement.node_of(to) {
            self.alpha_intra + bytes as f64 / self.bw_intra
        } else {
            self.alpha_inter + bytes as f64 / self.bw_inter
        }
    }

    /// Compute-time multiplier for a rank whose node hosts `ranks_on_node`
    /// ranks each holding `bytes_per_rank` of working set.
    ///
    /// Reproduces the §3.3.2 observation: once the per-node working set
    /// exceeds the LLC, row fetches stream from DRAM and the node's memory
    /// bandwidth is *shared* — the slowdown grows with the number of
    /// co-located ranks (up to `ranks_on_node - 1` extra queueing), weighted
    /// by how far the working set overflows the cache (`overflow`) and by
    /// the memory-bound fraction of the row sweep (`mem_penalty`). This is a
    /// bandwidth-sharing model, not a fixed cap: packing 24 ranks on a node
    /// whose working set spills is several times slower per rank, which is
    /// exactly why the paper's larger systems favor 2-per-node placement.
    pub fn contention_factor(&self, ranks_on_node: usize, bytes_per_rank: usize) -> f64 {
        let ws = ranks_on_node as f64 * bytes_per_rank as f64;
        if ws <= self.llc_bytes {
            return 1.0;
        }
        let overflow = (1.0 - self.llc_bytes / ws).clamp(0.0, 1.0);
        1.0 + self.mem_penalty * overflow * (ranks_on_node.saturating_sub(1)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_maps_ranks_to_nodes() {
        let p = Placement::two_per_node();
        assert_eq!(p.node_of(0), 0);
        assert_eq!(p.node_of(1), 0);
        assert_eq!(p.node_of(2), 1);
        assert_eq!(p.nodes_for(48), 24);
        assert_eq!(Placement::full_node().nodes_for(48), 2);
    }

    #[test]
    fn intra_cheaper_than_inter() {
        let m = NetworkModel::default();
        let p = Placement::two_per_node();
        let intra = m.message_cost(0, 1, 8000, p);
        let inter = m.message_cost(0, 2, 8000, p);
        assert!(intra < inter);
    }

    #[test]
    fn message_cost_scales_with_bytes() {
        let m = NetworkModel::default();
        let p = Placement::full_node();
        let small = m.message_cost(0, 1, 8, p);
        let big = m.message_cost(0, 1, 8_000_000, p);
        assert!(big > small * 10.0);
    }

    #[test]
    fn contention_kicks_in_past_llc() {
        let m = NetworkModel::default();
        // Working set under LLC: no penalty.
        assert_eq!(m.contention_factor(24, 1_000_000), 1.0);
        // 24 ranks x 100 MB >> 60 MB LLC: penalty close to 1 + mem_penalty.
        let f = m.contention_factor(24, 100_000_000);
        assert!(f > 1.5, "factor {f}");
        // 2 ranks x 100 MB: still overflows but little crowding.
        let f2 = m.contention_factor(2, 100_000_000);
        assert!(f2 < f, "2-rank factor {f2} should be below 24-rank {f}");
    }

    #[test]
    fn single_rank_never_penalized_much() {
        let m = NetworkModel::default();
        let f = m.contention_factor(1, 1_000_000_000);
        assert!((f - 1.0).abs() < 1e-9, "solo rank factor {f}");
    }
}
