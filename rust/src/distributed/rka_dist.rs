//! Distributed RKA — the paper's Algorithm 2.
//!
//! The system is partitioned by rows across ranks (that is the point of the
//! distributed version: data sets too large for one machine). Each rank per
//! iteration samples one of *its* rows, folds the projection into its copy
//! of the iterate, divides by `np`, and an `Allreduce(+)` forms the average:
//!
//! ```text
//! row   <- sampled from local partition          (line 2)
//! scale <- alpha (b_row - <A^(row), x>) / ‖A^(row)‖²   (line 3)
//! x     <- (x + scale A^(row)ᵀ) / np              (lines 4-5)
//! Allreduce(x, +)                                 (line 6)
//! ```
//!
//! No `x_prev` is needed — ranks have private memories (the paper makes this
//! exact observation when comparing Algorithm 2 to Algorithm 1).

use super::cluster::{DistResult, RankStats, SimCluster};
use super::comm::Communicator;
use crate::data::LinearSystem;
use crate::metrics::{History, Stopwatch};
use crate::solvers::rka::Weights;
use crate::solvers::sampling::{RowSampler, SamplingScheme};
use crate::solvers::{SolveOptions, StopCheck};

/// Distributed-memory RKA (Algorithm 2).
pub struct DistRka {
    /// Base RNG seed (rank `r` derives its own stream).
    pub seed: u32,
    /// Row weights (uniform alpha or per-rank partial-matrix alphas).
    pub weights: Weights,
}

impl DistRka {
    /// Uniform-weight distributed RKA.
    pub fn new(seed: u32, alpha: f64) -> Self {
        DistRka { seed, weights: Weights::Uniform(alpha) }
    }

    /// Use per-rank weights. [`Weights::InverseRowNorm`] is rejected: its
    /// per-iteration normalization needs every rank's sampled row before
    /// the allreduce (use the sequential `RkaSolver`).
    pub fn with_weights(mut self, weights: Weights) -> Self {
        assert!(
            !matches!(weights, Weights::InverseRowNorm(_)),
            "inverse-row-norm weights are sequential-only (RkaSolver/RkabSolver)"
        );
        self.weights = weights;
        self
    }

    /// Run on the given simulated cluster.
    pub fn solve(
        &self,
        system: &LinearSystem,
        opts: &SolveOptions,
        cluster: &SimCluster,
    ) -> DistResult {
        let np = cluster.np;
        let n = system.cols();
        // Fail on the caller's thread: a rank panicking on an unsampleable
        // partition would strand its peers in recv.
        crate::solvers::sampling::assert_partitions_sampleable(
            system,
            crate::solvers::SamplingScheme::Partitioned,
            np,
        );
        // Per-rank working set: its row partition (what an MPI rank stores).
        let bytes_per_rank = (system.rows() / np).max(1) * n * 8;

        let sw = Stopwatch::start();
        let outputs = cluster.run(|rank, comm| self.rank_loop(rank, comm, system, opts, np));
        let wall_seconds = sw.seconds();

        self.collect(outputs, cluster, bytes_per_rank, wall_seconds, np)
    }

    fn rank_loop(
        &self,
        rank: usize,
        comm: &mut Communicator,
        system: &LinearSystem,
        opts: &SolveOptions,
        np: usize,
    ) -> RankOutput {
        let n = system.cols();
        let timed = opts.fixed_iterations.is_some();
        // Matrix is distributed: each rank samples only its own partition
        // (this *is* the Distributed Approach of §3.3.1).
        let mut sampler =
            RowSampler::new(system, SamplingScheme::Partitioned, rank, np, self.seed);
        let mut x = vec![0.0; n];
        // Stopping state and history recording live with the rank that
        // decides (rank 0).
        let mut stopper = (rank == 0).then(|| StopCheck::new(system, opts));
        let mut compute_seconds = 0.0;
        let mut k = 0usize;
        let alpha = self.weights.get(rank);
        let inv_np = 1.0 / np as f64;
        let (mut converged, mut diverged);

        loop {
            // Stop decision: rank 0 evaluates, everyone follows. In timed
            // runs the iteration budget is known to all ranks, so no
            // communication is needed (matching the paper's protocol of
            // excluding the stopping test from timings) and no metric is
            // ever evaluated — such runs report converged = false. In
            // criterion runs rank 0 broadcasts the decision.
            let mut flag = 0.0f64;
            if rank == 0 {
                let stopper = stopper.as_mut().expect("rank 0 owns the stopper");
                let (stop, c, d) = stopper.check(k, &x);
                flag = if stop {
                    if c {
                        1.0
                    } else if d {
                        2.0
                    } else {
                        3.0
                    }
                } else {
                    0.0
                };
            }
            if !timed {
                comm.broadcast_flag(&mut flag);
            } else if k >= opts.fixed_iterations.unwrap() {
                // Budget spent, nothing measured: stop, not converged.
                flag = 3.0;
            }
            if flag != 0.0 {
                converged = flag == 1.0;
                diverged = flag == 2.0;
                break;
            }

            // Lines 2-5 of Algorithm 2 (measured as compute).
            let t0 = Stopwatch::start();
            let i = sampler.sample();
            let scale = alpha * (system.b[i] - system.a.row_dot(i, &x)) / system.row_norms_sq[i];
            system.a.row_axpy(i, scale, &mut x);
            for xi in x.iter_mut() {
                *xi *= inv_np;
            }
            compute_seconds += t0.seconds();

            // Line 6 (modeled comm charged inside the communicator).
            comm.allreduce_sum(&mut x);
            k += 1;
        }

        RankOutput {
            x,
            iterations: k,
            converged,
            diverged,
            history: stopper.map(StopCheck::into_history).unwrap_or_default(),
            compute_seconds,
            comm_seconds: comm.comm_seconds,
        }
    }

    fn collect(
        &self,
        outputs: Vec<RankOutput>,
        cluster: &SimCluster,
        bytes_per_rank: usize,
        wall_seconds: f64,
        np: usize,
    ) -> DistResult {
        let rank_stats: Vec<RankStats> = outputs
            .iter()
            .enumerate()
            .map(|(r, o)| RankStats {
                compute_seconds: o.compute_seconds,
                comm_seconds: o.comm_seconds,
                adjusted_compute_seconds: o.compute_seconds
                    * cluster.model.contention_factor(cluster.ranks_on_node(r), bytes_per_rank),
            })
            .collect();
        let sim_seconds = DistResult::sim_total(&rank_stats);
        let first = &outputs[0];
        DistResult {
            x: first.x.clone(),
            iterations: first.iterations,
            converged: first.converged,
            diverged: first.diverged,
            rows_used: first.iterations * np,
            wall_seconds,
            sim_seconds,
            rank_stats,
            history: outputs.into_iter().next().unwrap().history,
        }
    }
}

/// What each rank reports back.
pub(crate) struct RankOutput {
    /// Final local iterate (replicated after the last Allreduce).
    pub x: Vec<f64>,
    /// Outer iterations this rank executed.
    pub iterations: usize,
    /// Stopping criterion met (rank 0's decision, broadcast to all; always
    /// false for fixed-iteration runs, which measure nothing).
    pub converged: bool,
    /// Divergence detected.
    pub diverged: bool,
    /// Error/residual history (recorded by rank 0 only).
    pub history: History,
    /// Measured compute seconds (iteration work only).
    pub compute_seconds: f64,
    /// Modeled communication seconds charged by the Communicator.
    pub comm_seconds: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::distributed::network::Placement;
    use crate::solvers::rka::RkaSolver;
    use crate::solvers::Solver;

    #[test]
    fn converges_on_consistent_system() {
        let sys = DatasetBuilder::new(300, 12).seed(1).consistent();
        let cluster = SimCluster::new(4, Placement::two_per_node());
        let r = DistRka::new(3, 1.0).solve(&sys, &SolveOptions::default(), &cluster);
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-8);
        assert_eq!(r.rows_used, r.iterations * 4);
    }

    #[test]
    fn matches_sequential_partitioned_rka() {
        // Algorithm 2 ≡ eq. 7 with partitioned sampling; same seeds => same
        // iterates up to Allreduce reassociation.
        let sys = DatasetBuilder::new(200, 10).seed(2).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(200);
        let cluster = SimCluster::new(4, Placement::full_node());
        let dist = DistRka::new(7, 1.0).solve(&sys, &opts, &cluster);
        let seq = RkaSolver::new(7, 4, 1.0)
            .with_scheme(SamplingScheme::Partitioned)
            .solve(&sys, &opts);
        let drift: f64 =
            dist.x.iter().zip(&seq.x).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        let scale = seq.x.iter().map(|v| v.abs()).fold(0.0, f64::max);
        assert!(drift < 1e-6 * scale.max(1.0), "drift {drift}");
    }

    #[test]
    fn nonpow2_world_sizes_work() {
        let sys = DatasetBuilder::new(240, 10).seed(3).consistent();
        for np in [3usize, 5, 12] {
            let cluster = SimCluster::new(np, Placement::two_per_node());
            let opts = SolveOptions::default().with_fixed_iterations(100);
            let r = DistRka::new(3, 1.0).solve(&sys, &opts, &cluster);
            assert_eq!(r.iterations, 100, "np={np}");
            assert!(r.sim_seconds > 0.0);
        }
    }

    #[test]
    fn comm_time_grows_with_np() {
        let sys = DatasetBuilder::new(240, 20).seed(4).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(50);
        let comm_at = |np: usize| {
            let cluster = SimCluster::new(np, Placement::two_per_node());
            let r = DistRka::new(3, 1.0).solve(&sys, &opts, &cluster);
            r.rank_stats.iter().map(|s| s.comm_seconds).fold(0.0, f64::max)
        };
        let c2 = comm_at(2);
        let c8 = comm_at(8);
        // log2(8)=3 rounds vs 1 round: strictly more modeled comm.
        assert!(c8 > 2.0 * c2, "c8 {c8} vs c2 {c2}");
    }

    #[test]
    fn per_rank_weights_supported() {
        let sys = DatasetBuilder::new(200, 10).seed(5).consistent();
        let (alphas, _) = crate::solvers::alpha::partial_matrix_alphas(&sys, 4).unwrap();
        let cluster = SimCluster::new(4, Placement::two_per_node());
        let r = DistRka::new(3, 1.0)
            .with_weights(Weights::PerWorker(alphas))
            .solve(&sys, &SolveOptions::default(), &cluster);
        assert!(r.converged);
    }
}
