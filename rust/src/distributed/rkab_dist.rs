//! Distributed RKAB — the paper's Algorithm 4.
//!
//! Like Algorithm 2 but each rank applies `block_size` sequential Kaczmarz
//! projections to its private iterate before the `Allreduce`, with the
//! `1/np` folded into the last in-block update:
//!
//! ```text
//! for b in 0..bs-1:  x <- x + scale_b A^(row_b)ᵀ        (lines 2-6)
//! x <- (x + scale A^(row)ᵀ) / np                        (lines 7-10)
//! Allreduce(x, +)                                        (line 11)
//! ```
//!
//! Communication happens once per `block_size` rows — the amortization that
//! makes the distributed version viable (Fig. 11).

use super::cluster::{DistResult, RankStats, SimCluster};
use super::comm::Communicator;
use super::rka_dist::RankOutput;
use crate::data::LinearSystem;
use crate::linalg::vector::scale_in_place;
use crate::metrics::Stopwatch;
use crate::solvers::rkab::block_sweep;
use crate::solvers::sampling::{RowSampler, SamplingScheme};
use crate::solvers::{SolveOptions, StopCheck};

/// Distributed-memory RKAB (Algorithm 4).
pub struct DistRkab {
    /// Base RNG seed (rank `r` derives its own stream).
    pub seed: u32,
    /// Rows per rank between Allreduces.
    pub block_size: usize,
    /// Uniform relaxation weight.
    pub alpha: f64,
}

impl DistRkab {
    /// Distributed RKAB.
    pub fn new(seed: u32, block_size: usize, alpha: f64) -> Self {
        assert!(block_size >= 1);
        DistRkab { seed, block_size, alpha }
    }

    /// Run on the given simulated cluster.
    pub fn solve(
        &self,
        system: &LinearSystem,
        opts: &SolveOptions,
        cluster: &SimCluster,
    ) -> DistResult {
        let np = cluster.np;
        let n = system.cols();
        // Fail on the caller's thread: a rank panicking on an unsampleable
        // partition would strand its peers in recv.
        crate::solvers::sampling::assert_partitions_sampleable(
            system,
            SamplingScheme::Partitioned,
            np,
        );
        let bytes_per_rank = (system.rows() / np).max(1) * n * 8;

        let sw = Stopwatch::start();
        let outputs = cluster.run(|rank, comm| self.rank_loop(rank, comm, system, opts, np));
        let wall_seconds = sw.seconds();

        let rank_stats: Vec<RankStats> = outputs
            .iter()
            .enumerate()
            .map(|(r, o)| RankStats {
                compute_seconds: o.compute_seconds,
                comm_seconds: o.comm_seconds,
                adjusted_compute_seconds: o.compute_seconds
                    * cluster.model.contention_factor(cluster.ranks_on_node(r), bytes_per_rank),
            })
            .collect();
        let sim_seconds = DistResult::sim_total(&rank_stats);
        let first = &outputs[0];
        DistResult {
            x: first.x.clone(),
            iterations: first.iterations,
            converged: first.converged,
            diverged: first.diverged,
            rows_used: first.iterations * np * self.block_size,
            wall_seconds,
            sim_seconds,
            rank_stats,
            history: outputs.into_iter().next().unwrap().history,
        }
    }

    fn rank_loop(
        &self,
        rank: usize,
        comm: &mut Communicator,
        system: &LinearSystem,
        opts: &SolveOptions,
        np: usize,
    ) -> RankOutput {
        let n = system.cols();
        let timed = opts.fixed_iterations.is_some();
        let mut sampler =
            RowSampler::new(system, SamplingScheme::Partitioned, rank, np, self.seed);
        let mut x = vec![0.0; n];
        let mut idx = Vec::with_capacity(self.block_size); // sweep scratch
        // Stopping state and history recording live with the rank that
        // decides (rank 0).
        let mut stopper = (rank == 0).then(|| StopCheck::new(system, opts));
        let mut compute_seconds = 0.0;
        let mut k = 0usize;
        let inv_np = 1.0 / np as f64;
        let (mut converged, mut diverged);

        loop {
            let mut flag = 0.0f64;
            if rank == 0 {
                let stopper = stopper.as_mut().expect("rank 0 owns the stopper");
                let (stop, c, d) = stopper.check(k, &x);
                flag = if stop {
                    if c {
                        1.0
                    } else if d {
                        2.0
                    } else {
                        3.0
                    }
                } else {
                    0.0
                };
            }
            if !timed {
                comm.broadcast_flag(&mut flag);
            } else if k >= opts.fixed_iterations.unwrap() {
                // Budget spent, nothing measured: stop, not converged.
                flag = 3.0;
            }
            if flag != 0.0 {
                converged = flag == 1.0;
                diverged = flag == 2.0;
                break;
            }

            let t0 = Stopwatch::start();
            // Lines 2-10: the bs in-block projections on the private x via
            // the fused sweep shared with the sequential reference, then the
            // 1/np pre-average of line 10.
            block_sweep(system, &mut sampler, self.block_size, self.alpha, &mut x, &mut idx);
            scale_in_place(&mut x, inv_np);
            compute_seconds += t0.seconds();

            // Line 11.
            comm.allreduce_sum(&mut x);
            k += 1;
        }

        RankOutput {
            x,
            iterations: k,
            converged,
            diverged,
            history: stopper.map(StopCheck::into_history).unwrap_or_default(),
            compute_seconds,
            comm_seconds: comm.comm_seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::distributed::network::Placement;
    use crate::solvers::rkab::RkabSolver;
    use crate::solvers::Solver;

    #[test]
    fn converges_on_consistent_system() {
        let sys = DatasetBuilder::new(300, 12).seed(1).consistent();
        let cluster = SimCluster::new(4, Placement::two_per_node());
        let r = DistRkab::new(3, 12, 1.0).solve(&sys, &SolveOptions::default(), &cluster);
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-8);
        assert_eq!(r.rows_used, r.iterations * 4 * 12);
    }

    #[test]
    fn matches_sequential_partitioned_rkab() {
        let sys = DatasetBuilder::new(200, 10).seed(2).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(40);
        let cluster = SimCluster::new(4, Placement::full_node());
        let dist = DistRkab::new(7, 8, 1.0).solve(&sys, &opts, &cluster);
        let seq = RkabSolver::new(7, 4, 8, 1.0)
            .with_scheme(SamplingScheme::Partitioned)
            .solve(&sys, &opts);
        let drift: f64 =
            dist.x.iter().zip(&seq.x).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        let scale = seq.x.iter().map(|v| v.abs()).fold(0.0, f64::max);
        assert!(drift < 1e-6 * scale.max(1.0), "drift {drift}");
    }

    #[test]
    fn larger_blocks_less_comm_per_row() {
        let sys = DatasetBuilder::new(400, 20).seed(3).consistent();
        let comm_per_row = |bs: usize| {
            let cluster = SimCluster::new(4, Placement::two_per_node());
            let opts = SolveOptions::default().with_fixed_iterations(50);
            let r = DistRkab::new(3, bs, 1.0).solve(&sys, &opts, &cluster);
            let comm = r.rank_stats.iter().map(|s| s.comm_seconds).fold(0.0, f64::max);
            comm / r.rows_used as f64
        };
        let per_row_small = comm_per_row(1);
        let per_row_big = comm_per_row(20);
        assert!(
            per_row_big < per_row_small / 10.0,
            "bs=20 {per_row_big:.3e} vs bs=1 {per_row_small:.3e}"
        );
    }

    #[test]
    fn block_size_one_matches_dist_rka() {
        use crate::distributed::rka_dist::DistRka;
        let sys = DatasetBuilder::new(150, 8).seed(4).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(60);
        let cluster = SimCluster::new(3, Placement::two_per_node());
        let a = DistRkab::new(9, 1, 1.0).solve(&sys, &opts, &cluster);
        let b = DistRka::new(9, 1.0).solve(&sys, &opts, &cluster);
        let drift: f64 = a.x.iter().zip(&b.x).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
        let scale = b.x.iter().map(|v| v.abs()).fold(0.0, f64::max);
        assert!(drift < 1e-9 * scale.max(1.0), "drift {drift}");
    }
}
