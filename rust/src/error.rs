//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the kaczmarz library.
#[derive(Error, Debug)]
pub enum Error {
    /// Dimension mismatch between operands.
    #[error("dimension mismatch: {0}")]
    Dimension(String),

    /// An iterative routine failed to converge within its budget.
    #[error("no convergence after {iterations} iterations (last residual {residual:.3e})")]
    NoConvergence { iterations: usize, residual: f64 },

    /// A solver diverged (error grew instead of shrinking).
    #[error("solver diverged at iteration {iteration} (error {error:.3e})")]
    Diverged { iteration: usize, error: f64 },

    /// Invalid configuration or argument.
    #[error("invalid argument: {0}")]
    InvalidArgument(String),

    /// Missing AOT artifact (run `make artifacts`).
    #[error("artifact not found: {0} (run `make artifacts`)")]
    ArtifactMissing(String),

    /// PJRT / XLA runtime failure.
    #[error("xla runtime: {0}")]
    Xla(String),

    /// Filesystem / IO failure.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_dimension() {
        let e = Error::Dimension("A is 3x4, x has 5".into());
        assert!(e.to_string().contains("3x4"));
    }

    #[test]
    fn error_display_no_convergence() {
        let e = Error::NoConvergence { iterations: 10, residual: 0.5 };
        let s = e.to_string();
        assert!(s.contains("10"));
        assert!(s.contains("5.000e-1"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
