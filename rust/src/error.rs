//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no derive crates are available
//! offline); the display strings are part of the crate's contract — tests
//! and the CLI match on them.

use std::fmt;

/// Errors produced by the kaczmarz library.
#[derive(Debug)]
pub enum Error {
    /// Dimension mismatch between operands.
    Dimension(String),

    /// An iterative routine failed to converge within its budget.
    NoConvergence {
        /// Iterations performed before giving up.
        iterations: usize,
        /// Residual at the last iteration.
        residual: f64,
    },

    /// A solver diverged (error grew instead of shrinking).
    Diverged {
        /// Iteration at which divergence was detected.
        iteration: usize,
        /// Error magnitude at detection.
        error: f64,
    },

    /// Invalid configuration or argument.
    InvalidArgument(String),

    /// Iteration-count calibration (§3.1 protocol) failed: no seed reached
    /// the stopping tolerance, so there is no iteration budget to average —
    /// previously this silently produced `mean_iterations = 0.0` and a
    /// zero fixed-iteration budget downstream.
    CalibrationFailed {
        /// Seeds attempted.
        seeds: u32,
        /// How many of them were flagged as diverged (the rest exhausted
        /// their iteration cap unconverged).
        diverged: u32,
    },

    /// A row of the system has zero norm: it carries no constraint and every
    /// Kaczmarz projection against it divides by zero.
    DegenerateRow {
        /// Index of the offending row.
        row: usize,
    },

    /// A sampling strategy the chosen engine cannot run. The greedy Motzkin
    /// scan needs the current iterate at every selection, which only the
    /// sequential solvers (RK/RKA/RKAB) hold — parallel, asynchronous, and
    /// distributed engines draw rows without it.
    UnsupportedSampling {
        /// Engine that rejected the strategy.
        engine: String,
    },

    /// The serving front end's admission queue is full: the job was
    /// rejected at submit time instead of being buffered unboundedly
    /// (back-pressure by refusal — the pool never builds an invisible
    /// backlog a slow client could hide behind).
    Overloaded {
        /// Jobs already waiting in the admission queue.
        pending: usize,
        /// The queue's configured depth bound.
        capacity: usize,
    },

    /// A job's deadline elapsed — while it was still queued, or mid-solve
    /// (the iterate loop noticed at a [`StopCheck`] checkpoint and halted
    /// cooperatively). The clock starts at *submit*, so queue wait counts
    /// against the budget.
    ///
    /// [`StopCheck`]: crate::solvers::SolveOptions
    DeadlineExceeded {
        /// The job's deadline budget, in milliseconds from submit.
        budget_ms: u64,
    },

    /// The job was cancelled by the client (or by the server on behalf of a
    /// disconnected client) before it finished.
    Cancelled,

    /// Missing AOT artifact (run `make artifacts`).
    ArtifactMissing(String),

    /// PJRT / XLA runtime failure.
    Xla(String),

    /// Filesystem / IO failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Dimension(msg) => write!(f, "dimension mismatch: {msg}"),
            Error::NoConvergence { iterations, residual } => write!(
                f,
                "no convergence after {iterations} iterations (last residual {residual:.3e})"
            ),
            Error::Diverged { iteration, error } => {
                write!(f, "solver diverged at iteration {iteration} (error {error:.3e})")
            }
            Error::InvalidArgument(msg) => write!(f, "invalid argument: {msg}"),
            Error::CalibrationFailed { seeds, diverged } => write!(
                f,
                "calibration failed: 0 of {seeds} seeds converged \
                 ({diverged} diverged, {} hit the iteration cap)",
                seeds.saturating_sub(*diverged)
            ),
            Error::DegenerateRow { row } => write!(
                f,
                "degenerate system: row {row} has zero norm (cannot be projected against)"
            ),
            Error::UnsupportedSampling { engine } => write!(
                f,
                "unsupported sampling: '{engine}' cannot run the greedy Motzkin scan \
                 (sequential rk/rka/rkab only)"
            ),
            Error::Overloaded { pending, capacity } => write!(
                f,
                "overloaded: admission queue is full ({pending} pending, capacity {capacity}); \
                 retry with backoff"
            ),
            Error::DeadlineExceeded { budget_ms } => {
                write!(f, "deadline exceeded: job budget of {budget_ms} ms elapsed before completion")
            }
            Error::Cancelled => write!(f, "cancelled: job was cancelled before completion"),
            Error::ArtifactMissing(what) => {
                write!(f, "artifact not found: {what} (run `make artifacts`)")
            }
            Error::Xla(msg) => write!(f, "xla runtime: {msg}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_dimension() {
        let e = Error::Dimension("A is 3x4, x has 5".into());
        assert!(e.to_string().contains("3x4"));
    }

    #[test]
    fn error_display_no_convergence() {
        let e = Error::NoConvergence { iterations: 10, residual: 0.5 };
        let s = e.to_string();
        assert!(s.contains("10"));
        assert!(s.contains("5.000e-1"));
    }

    #[test]
    fn error_display_degenerate_row() {
        let e = Error::DegenerateRow { row: 7 };
        assert!(e.to_string().contains("row 7"));
    }

    #[test]
    fn error_display_calibration_failed() {
        let e = Error::CalibrationFailed { seeds: 5, diverged: 3 };
        let s = e.to_string();
        assert!(s.contains("0 of 5"));
        assert!(s.contains("3 diverged"));
        assert!(s.contains("2 hit the iteration cap"));
    }

    #[test]
    fn error_display_unsupported_sampling() {
        let e = Error::UnsupportedSampling { engine: "rka-par".into() };
        let s = e.to_string();
        assert!(s.contains("rka-par"));
        assert!(s.contains("greedy"));
    }

    #[test]
    fn error_display_overloaded() {
        let e = Error::Overloaded { pending: 64, capacity: 64 };
        let s = e.to_string();
        assert!(s.contains("overloaded"));
        assert!(s.contains("64 pending"));
        assert!(s.contains("capacity 64"));
    }

    #[test]
    fn error_display_deadline_exceeded() {
        let e = Error::DeadlineExceeded { budget_ms: 250 };
        let s = e.to_string();
        assert!(s.contains("deadline exceeded"));
        assert!(s.contains("250 ms"));
    }

    #[test]
    fn error_display_cancelled() {
        assert!(Error::Cancelled.to_string().contains("cancelled"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        use std::error::Error as _;
        assert!(e.source().is_some());
    }
}
