//! Report emitters: markdown tables, CSV, and simple aligned text output for
//! the experiment drivers and benches.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-oriented table.
#[derive(Clone, Debug)]
pub struct Table {
    /// Table caption.
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given caption and column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the table has no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as GitHub markdown.
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {}\n", self.title);
        let _ = writeln!(s, "| {} |", self.headers.join(" | "));
        let _ = writeln!(s, "|{}|", self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    /// Render as a JSON array of objects, one per row, keyed by column
    /// header — the machine-readable form the perf-tracking CI lane
    /// archives (`BENCH_micro.json`). Hand-rolled (no serde offline);
    /// every value is emitted as a JSON string exactly as tabulated.
    pub fn to_json(&self) -> String {
        let mut s = String::from("[");
        for (i, r) in self.rows.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str("\n  {");
            for (j, (h, c)) in self.headers.iter().zip(r).enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                let _ = write!(s, "{}: {}", json_string(h), json_string(c));
            }
            s.push('}');
        }
        s.push_str("\n]");
        s
    }

    /// Render as CSV (headers first).
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.headers.join(","));
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.join(","));
        }
        s
    }

    /// Render as aligned plain text (for terminal output).
    pub fn to_text(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut s = String::new();
        let _ = writeln!(s, "== {} ==", self.title);
        let _ = writeln!(s, "{}", fmt_row(&self.headers));
        let _ = writeln!(s, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for r in &self.rows {
            let _ = writeln!(s, "{}", fmt_row(r));
        }
        s
    }
}

/// A report: a list of sections, each free text or a table.
#[derive(Default)]
pub struct Report {
    sections: Vec<String>,
}

impl Report {
    /// Empty report.
    pub fn new() -> Self {
        Report::default()
    }

    /// Append a markdown paragraph.
    pub fn text(&mut self, text: impl Into<String>) {
        self.sections.push(text.into());
    }

    /// Append a table (markdown form).
    pub fn table(&mut self, t: &Table) {
        self.sections.push(t.to_markdown());
    }

    /// Full markdown document.
    pub fn to_markdown(&self) -> String {
        self.sections.join("\n")
    }

    /// Write to `<dir>/<name>.md` (creates the directory).
    pub fn write(&self, dir: &Path, name: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.md"));
        std::fs::write(&path, self.to_markdown())?;
        Ok(path)
    }
}

/// Quote and escape a string for JSON output (quotes, backslashes, control
/// characters). Used by [`Table::to_json`] and the bench harnesses'
/// machine-readable emitters.
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_seconds(s: f64) -> String {
    if !s.is_finite() {
        return "-".to_string();
    }
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Format a speedup ratio.
pub fn fmt_speedup(s: f64) -> String {
    if s.is_finite() {
        format!("{s:.2}x")
    } else {
        "-".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["30".into(), "40".into()]);
        t
    }

    #[test]
    fn markdown_renders() {
        let md = sample().to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 30 | 40 |"));
    }

    #[test]
    fn csv_renders() {
        let csv = sample().to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("a,b"));
    }

    #[test]
    fn json_renders_and_escapes() {
        let json = sample().to_json();
        assert!(json.contains("{\"a\": \"1\", \"b\": \"2\"}"), "{json}");
        assert!(json.contains("{\"a\": \"30\", \"b\": \"40\"}"), "{json}");
        assert!(json.starts_with('[') && json.ends_with(']'));
        assert_eq!(json_string("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(json_string("\u{1}"), "\"\\u0001\"");
        // Empty table: a valid, empty JSON array.
        let t = Table::new("empty", &["a"]);
        assert_eq!(t.to_json(), "[\n]");
    }

    #[test]
    fn text_aligns() {
        let txt = sample().to_text();
        assert!(txt.contains("Demo"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn report_writes_file() {
        let mut r = Report::new();
        r.text("hello");
        r.table(&sample());
        let dir = std::env::temp_dir().join("kcz_report_test");
        let path = r.write(&dir, "demo").unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains("hello"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_seconds(2.5), "2.50 s");
        assert_eq!(fmt_seconds(0.0025), "2.50 ms");
        assert_eq!(fmt_seconds(2.5e-6), "2.50 µs");
        assert_eq!(fmt_speedup(1.5), "1.50x");
    }
}
