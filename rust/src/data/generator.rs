//! Data-set generation per §3.1 of the paper, plus a sparse (CSR) variant
//! for exercising the storage-generic solve loops.

use super::dataset::LinearSystem;
use crate::linalg::{gemv, CsrMatrix, Matrix};
use crate::rng::{Mt19937, NormalSampler};

/// Builder for the paper's synthetic overdetermined systems.
///
/// Matrix entries of row `i` are drawn from `N(μ_i, σ_i)` with
/// `μ_i ~ U[-5, 5]`, `σ_i ~ U[1, 20]` — a different gaussian per row, as in
/// §3.1. The solution `x` is drawn from the same family and `b = A x`, so
/// the system is consistent, full rank (w.p. 1) and its unique solution is
/// known exactly.
pub struct DatasetBuilder {
    rows: usize,
    cols: usize,
    seed: u32,
    mu_range: (f64, f64),
    sigma_range: (f64, f64),
    noise_sd: f64,
}

impl DatasetBuilder {
    /// A builder for an `m x n` system with the paper's parameter ranges.
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(rows > 0 && cols > 0, "empty system");
        DatasetBuilder {
            rows,
            cols,
            seed: 2024,
            mu_range: (-5.0, 5.0),
            sigma_range: (1.0, 20.0),
            noise_sd: 1.0,
        }
    }

    /// Set the generator seed (distinct seeds give distinct systems).
    pub fn seed(mut self, seed: u32) -> Self {
        self.seed = seed;
        self
    }

    /// Override the per-row mean range (default [-5, 5]).
    pub fn mu_range(mut self, lo: f64, hi: f64) -> Self {
        assert!(lo <= hi);
        self.mu_range = (lo, hi);
        self
    }

    /// Override the per-row σ range (default [1, 20]).
    pub fn sigma_range(mut self, lo: f64, hi: f64) -> Self {
        assert!(0.0 < lo && lo <= hi);
        self.sigma_range = (lo, hi);
        self
    }

    /// Std-dev of the inconsistency noise ξ (default 1.0, the paper's N(0,1)).
    pub fn noise_sd(mut self, sd: f64) -> Self {
        assert!(sd > 0.0);
        self.noise_sd = sd;
        self
    }

    fn generate_matrix_and_x(&self) -> (Matrix, Vec<f64>) {
        let mut rng = Mt19937::new(self.seed);
        let mut normal = NormalSampler::new();
        let mut a = Matrix::zeros(self.rows, self.cols);
        let (mu_lo, mu_hi) = self.mu_range;
        let (sg_lo, sg_hi) = self.sigma_range;
        for i in 0..self.rows {
            // A different gaussian per row (§3.1).
            let mu = mu_lo + (mu_hi - mu_lo) * rng.next_f64();
            let sd = sg_lo + (sg_hi - sg_lo) * rng.next_f64();
            for v in a.row_mut(i) {
                *v = normal.sample(&mut rng, mu, sd);
            }
        }
        // x from "the same probability distribution used for matrix elements".
        let mu = mu_lo + (mu_hi - mu_lo) * rng.next_f64();
        let sd = sg_lo + (sg_hi - sg_lo) * rng.next_f64();
        let x: Vec<f64> = (0..self.cols).map(|_| normal.sample(&mut rng, mu, sd)).collect();
        (a, x)
    }

    /// Consistent system: `b = A x_true` exactly.
    pub fn consistent(&self) -> LinearSystem {
        let (a, x) = self.generate_matrix_and_x();
        let b = gemv(&a, &x).expect("shapes by construction");
        LinearSystem::new(a, b, Some(x), true)
    }

    /// Inconsistent system: `b_LS = A x + ξ`, `ξ ~ N(0, noise_sd)` (§3.1).
    ///
    /// `x_ls` is *not* filled in here — callers compute it with
    /// `solvers::cgls` exactly as the paper does. (`x_true` keeps the
    /// pre-noise generating solution for diagnostics.)
    pub fn inconsistent(&self) -> LinearSystem {
        let mut sys = self.consistent();
        // An independent stream for the noise so the consistent and
        // inconsistent systems share A and x exactly (paper derives the
        // inconsistent set from the consistent one).
        let mut rng = Mt19937::new(self.seed ^ 0xdead_beef);
        let mut normal = NormalSampler::new();
        for bi in sys.b.iter_mut() {
            *bi += normal.sample(&mut rng, 0.0, self.noise_sd);
        }
        sys.consistent = false;
        sys
    }

    /// The paper's cropping protocol: generate the largest matrix once, then
    /// derive an `rows x cols` system by taking the top-left submatrix
    /// (keeps systems of different sizes comparable).
    pub fn crop_from(&self, largest: &LinearSystem) -> LinearSystem {
        let a = largest
            .a
            .crop(self.rows, self.cols)
            .expect("crop dims must not exceed source");
        // The cropped system needs its own consistent rhs: reuse the source
        // x_true truncated to `cols`, recompute b = A x.
        let x: Vec<f64> = largest
            .x_true
            .as_ref()
            .expect("source must carry x_true")
            .iter()
            .take(self.cols)
            .copied()
            .collect();
        let b = gemv(&a, &x).expect("shapes by construction");
        LinearSystem::new(a, b, Some(x), true)
    }
}

/// Builder for deterministic sparse systems on CSR storage.
///
/// Each row gets `max(1, round(density * cols))` entries at distinct
/// MT19937-chosen columns, with values from the same per-row gaussian family
/// as [`DatasetBuilder`] (`μ_i ~ U[-5, 5]`, `σ_i ~ U[1, 20]`). The one-entry
/// floor keeps every row norm positive, so the constructor's degenerate-row
/// check never fires on generated data; it also means the effective density
/// never drops below `1/cols`. Same seed ⇒ same system, independent of
/// thread count or platform — exactly the discipline of the dense builder.
pub struct SparseDatasetBuilder {
    rows: usize,
    cols: usize,
    density: f64,
    seed: u32,
    mu_range: (f64, f64),
    sigma_range: (f64, f64),
    noise_sd: f64,
}

impl SparseDatasetBuilder {
    /// A builder for an `m x n` system with the given fill fraction.
    pub fn new(rows: usize, cols: usize, density: f64) -> Self {
        assert!(rows > 0 && cols > 0, "empty system");
        assert!(density > 0.0 && density <= 1.0, "density must be in (0, 1]");
        SparseDatasetBuilder {
            rows,
            cols,
            density,
            seed: 2024,
            mu_range: (-5.0, 5.0),
            sigma_range: (1.0, 20.0),
            noise_sd: 1.0,
        }
    }

    /// Set the generator seed (distinct seeds give distinct systems).
    pub fn seed(mut self, seed: u32) -> Self {
        self.seed = seed;
        self
    }

    /// Std-dev of the inconsistency noise ξ (default 1.0, as in §3.1).
    pub fn noise_sd(mut self, sd: f64) -> Self {
        assert!(sd > 0.0);
        self.noise_sd = sd;
        self
    }

    fn generate(&self) -> (CsrMatrix, Vec<f64>) {
        let mut rng = Mt19937::new(self.seed);
        let mut normal = NormalSampler::new();
        let (mu_lo, mu_hi) = self.mu_range;
        let (sg_lo, sg_hi) = self.sigma_range;
        let per_row = ((self.density * self.cols as f64).round() as usize).clamp(1, self.cols);
        let mut entries = Vec::with_capacity(self.rows * per_row);
        let mut columns: Vec<usize> = (0..self.cols).collect();
        for i in 0..self.rows {
            // A different gaussian per row, like the dense §3.1 builder.
            let mu = mu_lo + (mu_hi - mu_lo) * rng.next_f64();
            let sd = sg_lo + (sg_hi - sg_lo) * rng.next_f64();
            // Distinct columns via a fresh shuffle (Fisher–Yates on the RNG
            // stream): the row pattern is deterministic in the seed.
            rng.shuffle(&mut columns);
            for &j in &columns[..per_row] {
                entries.push((i, j, normal.sample(&mut rng, mu, sd)));
            }
        }
        let a = CsrMatrix::from_triplets(self.rows, self.cols, &entries)
            .expect("indices in range by construction");
        let mu = mu_lo + (mu_hi - mu_lo) * rng.next_f64();
        let sd = sg_lo + (sg_hi - sg_lo) * rng.next_f64();
        let x: Vec<f64> = (0..self.cols).map(|_| normal.sample(&mut rng, mu, sd)).collect();
        (a, x)
    }

    /// Consistent sparse system: `b = A x_true` exactly, CSR storage.
    pub fn consistent(&self) -> LinearSystem {
        let (a, x) = self.generate();
        let b = gemv(&a, &x).expect("shapes by construction");
        LinearSystem::new(a, b, Some(x), true)
    }

    /// Inconsistent sparse system: `b = A x + ξ`, `ξ ~ N(0, noise_sd)`.
    ///
    /// Uses an independent noise stream (`seed ^ 0xdead_beef`, matching the
    /// dense builder) so the consistent and inconsistent systems share `A`
    /// and `x_true` exactly.
    pub fn inconsistent(&self) -> LinearSystem {
        let mut sys = self.consistent();
        let mut rng = Mt19937::new(self.seed ^ 0xdead_beef);
        let mut normal = NormalSampler::new();
        for bi in sys.b.iter_mut() {
            *bi += normal.sample(&mut rng, 0.0, self.noise_sd);
        }
        sys.consistent = false;
        sys
    }
}

/// A highly coherent consistent system for the Fig. 1 demonstration:
/// *consecutive* rows subtend a small angle (the matrix is "coherent" in the
/// Wallace–Sekmen sense), which makes cyclic Kaczmarz crawl — each projection
/// moves to a hyperplane almost parallel to the previous one — while
/// randomized Kaczmarz hops between distant hyperplanes.
///
/// Row `i` samples a smooth curve on the sphere:
/// `A[i][j] = cos((j+1)·θ_i + φ_j)` with `θ_i = i · step_angle` and random
/// phases `φ_j`. Small `step_angle` ⇒ consecutive rows nearly parallel;
/// the differing per-column frequencies keep the full row set diverse (and
/// the matrix full rank).
pub fn coherent_system(rows: usize, cols: usize, step_angle: f64, seed: u32) -> LinearSystem {
    assert!(rows >= 2 && cols >= 2);
    assert!(step_angle > 0.0);
    let mut rng = Mt19937::new(seed);
    let mut normal = NormalSampler::new();
    let phases: Vec<f64> = (0..cols)
        .map(|_| rng.next_f64() * 2.0 * std::f64::consts::PI)
        .collect();
    let mut a = Matrix::zeros(rows, cols);
    for i in 0..rows {
        let theta = i as f64 * step_angle;
        let row = a.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            *v = ((j + 1) as f64 * theta + phases[j]).cos();
        }
    }
    let x: Vec<f64> = (0..cols).map(|_| normal.standard(&mut rng)).collect();
    let b = gemv(&a, &x).expect("shapes by construction");
    LinearSystem::new(a, b, Some(x), true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vector::dot;

    #[test]
    fn consistent_has_zero_residual_at_x_true() {
        let sys = DatasetBuilder::new(50, 8).seed(3).consistent();
        let x = sys.x_true.clone().unwrap();
        assert!(sys.residual_norm(&x) < 1e-9 * sys.frobenius_sq.sqrt());
        assert!(sys.consistent);
    }

    #[test]
    fn inconsistent_shares_matrix_with_consistent() {
        let b = DatasetBuilder::new(40, 6).seed(9);
        let cons = b.consistent();
        let inco = b.inconsistent();
        assert_eq!(cons.a, inco.a);
        assert!(!inco.consistent);
        // b differs by the noise
        let diff: f64 = cons.b.iter().zip(&inco.b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 0.0);
    }

    #[test]
    fn inconsistent_noise_has_unit_scale() {
        let sys = DatasetBuilder::new(5000, 4).seed(1).inconsistent();
        let cons = DatasetBuilder::new(5000, 4).seed(1).consistent();
        let noise: Vec<f64> = sys.b.iter().zip(&cons.b).map(|(y, x)| y - x).collect();
        let mean = noise.iter().sum::<f64>() / noise.len() as f64;
        let var = noise.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / noise.len() as f64;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn seeds_change_data() {
        let a = DatasetBuilder::new(10, 4).seed(1).consistent();
        let b = DatasetBuilder::new(10, 4).seed(2).consistent();
        assert_ne!(a.a, b.a);
    }

    #[test]
    fn crop_matches_paper_protocol() {
        let big = DatasetBuilder::new(100, 20).seed(5).consistent();
        let small = DatasetBuilder::new(30, 8).crop_from(&big);
        assert_eq!(small.rows(), 30);
        assert_eq!(small.cols(), 8);
        // Entries coincide with the source's top-left block.
        for i in 0..30 {
            assert_eq!(small.a.row(i), &big.a.row(i)[..8]);
        }
        // And the cropped system is itself consistent.
        let x = small.x_true.clone().unwrap();
        assert!(small.residual_norm(&x) < 1e-9 * small.frobenius_sq.sqrt());
    }

    #[test]
    fn sparse_builder_is_deterministic_and_sparse() {
        let a = SparseDatasetBuilder::new(40, 20, 0.1).seed(5).consistent();
        let b = SparseDatasetBuilder::new(40, 20, 0.1).seed(5).consistent();
        let c = SparseDatasetBuilder::new(40, 20, 0.1).seed(6).consistent();
        assert_eq!(a.a, b.a);
        assert_eq!(a.b, b.b);
        assert_ne!(a.a, c.a);
        let csr = a.a.as_csr().expect("sparse builder must produce CSR storage");
        assert_eq!(csr.nnz(), 40 * 2, "10% of 20 cols = 2 entries per row");
    }

    #[test]
    fn sparse_consistent_has_zero_residual_at_x_true() {
        let sys = SparseDatasetBuilder::new(60, 12, 0.25).seed(3).consistent();
        let x = sys.x_true.clone().unwrap();
        assert!(sys.residual_norm(&x) < 1e-9 * sys.frobenius_sq.sqrt());
        assert!(sys.consistent);
    }

    #[test]
    fn sparse_inconsistent_shares_matrix_with_consistent() {
        let b = SparseDatasetBuilder::new(30, 8, 0.4).seed(9);
        let cons = b.consistent();
        let inco = b.inconsistent();
        assert_eq!(cons.a, inco.a);
        assert!(!inco.consistent);
        let diff: f64 = cons.b.iter().zip(&inco.b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 0.0);
    }

    #[test]
    fn sparse_density_floor_keeps_rows_nondegenerate() {
        // density far below 1/cols still yields one entry per row.
        let sys = SparseDatasetBuilder::new(25, 50, 0.001).seed(2).consistent();
        assert_eq!(sys.a.as_csr().unwrap().nnz(), 25);
        for (i, &norm) in sys.row_norms_sq.iter().enumerate() {
            assert!(norm > 0.0, "row {i} degenerate");
        }
    }

    #[test]
    fn coherent_rows_nearly_parallel() {
        let sys = coherent_system(20, 10, 0.001, 7);
        // cos(angle) between consecutive rows should be ~1.
        for i in 0..19 {
            let r0 = sys.a.row(i);
            let r1 = sys.a.row(i + 1);
            let cos = dot(r0, r1)
                / (dot(r0, r0).sqrt() * dot(r1, r1).sqrt());
            assert!(cos > 0.99, "rows {i},{} cos {cos}", i + 1);
        }
    }

    #[test]
    fn coherent_system_is_consistent_and_diverse() {
        let sys = coherent_system(200, 6, 0.002, 3);
        let x = sys.x_true.clone().unwrap();
        assert!(sys.residual_norm(&x) < 1e-8);
        // Distant rows should NOT be nearly parallel.
        let r0 = sys.a.row(0);
        let r_far = sys.a.row(199);
        let cos = dot(r0, r_far) / (dot(r0, r0).sqrt() * dot(r_far, r_far).sqrt());
        assert!(cos.abs() < 0.95, "far rows still coherent: cos {cos}");
    }
}
