//! Binary persistence for generated systems.
//!
//! Benches regenerate multi-hundred-MB matrices otherwise; the format is a
//! trivial little-endian dump with a magic header, no external serialization
//! crates being available offline.

use super::dataset::LinearSystem;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"KCZSYS01";

fn write_f64s<W: Write>(w: &mut W, v: &[f64]) -> Result<()> {
    for &x in v {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f64s<R: Read>(r: &mut R, n: usize) -> Result<Vec<f64>> {
    let mut buf = vec![0u8; n * 8];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn write_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Save a system to `path`.
pub fn save(sys: &LinearSystem, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_u64(&mut w, sys.rows() as u64)?;
    write_u64(&mut w, sys.cols() as u64)?;
    write_u64(&mut w, sys.consistent as u64)?;
    write_u64(&mut w, sys.x_true.is_some() as u64)?;
    write_u64(&mut w, sys.x_ls.is_some() as u64)?;
    write_f64s(&mut w, sys.a.as_slice())?;
    write_f64s(&mut w, &sys.b)?;
    if let Some(x) = &sys.x_true {
        write_f64s(&mut w, x)?;
    }
    if let Some(x) = &sys.x_ls {
        write_f64s(&mut w, x)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a system saved by [`save`].
pub fn load(path: &Path) -> Result<LinearSystem> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::InvalidArgument(format!(
            "{} is not a kaczmarz system file",
            path.display()
        )));
    }
    let rows = read_u64(&mut r)? as usize;
    let cols = read_u64(&mut r)? as usize;
    let consistent = read_u64(&mut r)? != 0;
    let has_true = read_u64(&mut r)? != 0;
    let has_ls = read_u64(&mut r)? != 0;
    let a = Matrix::from_vec(rows, cols, read_f64s(&mut r, rows * cols)?)?;
    let b = read_f64s(&mut r, rows)?;
    let x_true = if has_true { Some(read_f64s(&mut r, cols)?) } else { None };
    let x_ls = if has_ls { Some(read_f64s(&mut r, cols)?) } else { None };
    let mut sys = LinearSystem::new(a, b, x_true, consistent);
    sys.x_ls = x_ls;
    Ok(sys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;

    #[test]
    fn roundtrip_consistent() {
        let sys = DatasetBuilder::new(12, 5).seed(4).consistent();
        let tmp = std::env::temp_dir().join("kcz_io_test_c.bin");
        save(&sys, &tmp).unwrap();
        let back = load(&tmp).unwrap();
        assert_eq!(back.a, sys.a);
        assert_eq!(back.b, sys.b);
        assert_eq!(back.x_true, sys.x_true);
        assert_eq!(back.consistent, sys.consistent);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn roundtrip_with_xls() {
        let mut sys = DatasetBuilder::new(10, 3).seed(8).inconsistent();
        sys.x_ls = Some(vec![1.0, 2.0, 3.0]);
        let tmp = std::env::temp_dir().join("kcz_io_test_ls.bin");
        save(&sys, &tmp).unwrap();
        let back = load(&tmp).unwrap();
        assert_eq!(back.x_ls, sys.x_ls);
        assert!(!back.consistent);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let tmp = std::env::temp_dir().join("kcz_io_test_bad.bin");
        std::fs::write(&tmp, b"NOTMAGIC________").unwrap();
        assert!(load(&tmp).is_err());
        std::fs::remove_file(&tmp).ok();
    }
}
