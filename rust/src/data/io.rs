//! Persistence for generated systems.
//!
//! Two formats live here:
//!
//! - the crate's own binary dump (magic header + little-endian f64s) for
//!   round-tripping dense generated systems — benches regenerate
//!   multi-hundred-MB matrices otherwise, and no external serialization
//!   crates are available offline;
//! - a Matrix Market coordinate reader ([`load_mtx`]) so real sparse test
//!   matrices load straight into [`CsrMatrix`] storage, with the same
//!   strictness discipline as the binary loader (typed errors, degenerate
//!   rows rejected).

use super::dataset::LinearSystem;
use crate::error::{Error, Result};
use crate::linalg::{CsrMatrix, Matrix};
use crate::rng::{Mt19937, NormalSampler};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"KCZSYS01";

fn write_f64s<W: Write>(w: &mut W, v: &[f64]) -> Result<()> {
    for &x in v {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f64s<R: Read>(r: &mut R, n: usize) -> Result<Vec<f64>> {
    let mut buf = vec![0u8; n * 8];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn write_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Save a system to `path`.
///
/// Degenerate (zero-norm) rows are rejected up front with
/// [`Error::DegenerateRow`]: `load` refuses them (disk data is untrusted),
/// so failing fast at write time keeps the save/load roundtrip symmetric —
/// anything this function persists, `load` will accept. The binary format
/// is a dense dump, so CSR-backed systems are rejected with
/// [`Error::InvalidArgument`] rather than densified silently.
pub fn save(sys: &LinearSystem, path: &Path) -> Result<()> {
    let dense = sys.a.as_dense().ok_or_else(|| {
        Error::InvalidArgument("binary save supports dense systems only".into())
    })?;
    if let Some(row) = sys.degenerate_row() {
        return Err(Error::DegenerateRow { row });
    }
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_u64(&mut w, sys.rows() as u64)?;
    write_u64(&mut w, sys.cols() as u64)?;
    write_u64(&mut w, sys.consistent as u64)?;
    write_u64(&mut w, sys.x_true.is_some() as u64)?;
    write_u64(&mut w, sys.x_ls.is_some() as u64)?;
    write_f64s(&mut w, dense.as_slice())?;
    write_f64s(&mut w, &sys.b)?;
    if let Some(x) = &sys.x_true {
        write_f64s(&mut w, x)?;
    }
    if let Some(x) = &sys.x_ls {
        write_f64s(&mut w, x)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a system saved by [`save`].
pub fn load(path: &Path) -> Result<LinearSystem> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::InvalidArgument(format!(
            "{} is not a kaczmarz system file",
            path.display()
        )));
    }
    let rows = read_u64(&mut r)? as usize;
    let cols = read_u64(&mut r)? as usize;
    let consistent = read_u64(&mut r)? != 0;
    let has_true = read_u64(&mut r)? != 0;
    let has_ls = read_u64(&mut r)? != 0;
    let a = Matrix::from_vec(rows, cols, read_f64s(&mut r, rows * cols)?)?;
    let b = read_f64s(&mut r, rows)?;
    let x_true = if has_true { Some(read_f64s(&mut r, cols)?) } else { None };
    let x_ls = if has_ls { Some(read_f64s(&mut r, cols)?) } else { None };
    // Disk data is untrusted: reject degenerate rows with a typed error
    // instead of letting a zero norm NaN-poison a later solve.
    let mut sys = LinearSystem::try_new(a, b, x_true, consistent)?;
    sys.x_ls = x_ls;
    Ok(sys)
}

/// Load a Matrix Market coordinate file into CSR storage.
///
/// Only the plain `matrix coordinate real general` flavor is supported —
/// anything else (pattern/complex fields, symmetric storage, dense `array`
/// format) fails with a typed [`Error::InvalidArgument`] naming the file.
/// Entries are 1-indexed per the format; duplicates are summed (the
/// convention assemblers rely on); indices outside the declared shape are
/// rejected with [`Error::Dimension`].
pub fn load_mtx(path: &Path) -> Result<CsrMatrix> {
    let f = std::fs::File::open(path)?;
    parse_mtx(BufReader::new(f), &path.display().to_string())
}

fn parse_usize(tok: &str, origin: &str, what: &str) -> Result<usize> {
    tok.parse().map_err(|_| Error::InvalidArgument(format!("{origin}: bad {what} {tok:?}")))
}

fn parse_mtx<R: BufRead>(r: R, origin: &str) -> Result<CsrMatrix> {
    let mut lines = r.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::InvalidArgument(format!("{origin}: empty Matrix Market file")))??;
    let head: Vec<String> = header.split_whitespace().map(|t| t.to_ascii_lowercase()).collect();
    let expect = ["%%matrixmarket", "matrix", "coordinate", "real", "general"];
    if head.len() != 5 || head.iter().zip(expect).any(|(a, b)| a.as_str() != b) {
        return Err(Error::InvalidArgument(format!(
            "{origin}: unsupported header {header:?} (need \
             \"%%MatrixMarket matrix coordinate real general\")"
        )));
    }
    let mut dims: Option<(usize, usize, usize)> = None;
    let mut entries: Vec<(usize, usize, f64)> = Vec::new();
    for line in lines {
        let line = line?;
        let s = line.trim();
        if s.is_empty() || s.starts_with('%') {
            continue; // comment lines may appear anywhere
        }
        let toks: Vec<&str> = s.split_whitespace().collect();
        match dims {
            None => {
                if toks.len() != 3 {
                    return Err(Error::InvalidArgument(format!(
                        "{origin}: malformed size line {s:?} (need \"rows cols nnz\")"
                    )));
                }
                let m = parse_usize(toks[0], origin, "row count")?;
                let n = parse_usize(toks[1], origin, "column count")?;
                let nnz = parse_usize(toks[2], origin, "entry count")?;
                if m == 0 || n == 0 {
                    return Err(Error::Dimension(format!("{origin}: empty {m}x{n} matrix")));
                }
                entries.reserve(nnz);
                dims = Some((m, n, nnz));
            }
            Some((m, n, nnz)) => {
                if entries.len() == nnz {
                    return Err(Error::InvalidArgument(format!(
                        "{origin}: more than the declared {nnz} entries"
                    )));
                }
                if toks.len() != 3 {
                    return Err(Error::InvalidArgument(format!(
                        "{origin}: malformed entry {s:?} (need \"row col value\")"
                    )));
                }
                let i = parse_usize(toks[0], origin, "entry row")?;
                let j = parse_usize(toks[1], origin, "entry col")?;
                let v: f64 = toks[2].parse().map_err(|_| {
                    Error::InvalidArgument(format!("{origin}: bad value {:?}", toks[2]))
                })?;
                if i == 0 || i > m || j == 0 || j > n {
                    return Err(Error::Dimension(format!(
                        "{origin}: entry ({i}, {j}) outside 1..={m} x 1..={n}"
                    )));
                }
                entries.push((i - 1, j - 1, v));
            }
        }
    }
    let (m, n, nnz) =
        dims.ok_or_else(|| Error::InvalidArgument(format!("{origin}: missing size line")))?;
    if entries.len() != nnz {
        return Err(Error::InvalidArgument(format!(
            "{origin}: {} entries but the header declares {nnz}",
            entries.len()
        )));
    }
    CsrMatrix::from_triplets(m, n, &entries)
}

/// Build a solvable consistent system from a Matrix Market file.
///
/// `.mtx` files carry only the matrix, so the right-hand side is
/// manufactured the way the §3.1 generator does: a seeded solution `x_true`
/// is drawn from the paper's entry distribution and `b = A x_true`, giving a
/// consistent system with a known solution on CSR storage. Rows with no
/// stored entries (or all-zero values) are rejected by the constructor with
/// [`Error::DegenerateRow`] — such a row carries no constraint and would
/// NaN-poison a projection.
pub fn load_mtx_system(path: &Path, seed: u32) -> Result<LinearSystem> {
    let a = load_mtx(path)?;
    let mut rng = Mt19937::new(seed);
    let mut normal = NormalSampler::new();
    let mu = -5.0 + 10.0 * rng.next_f64();
    let sd = 1.0 + 19.0 * rng.next_f64();
    let x: Vec<f64> = (0..a.cols()).map(|_| normal.sample(&mut rng, mu, sd)).collect();
    let b = crate::linalg::gemv(&a, &x)?;
    LinearSystem::try_new(a, b, Some(x), true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;

    #[test]
    fn roundtrip_consistent() {
        let sys = DatasetBuilder::new(12, 5).seed(4).consistent();
        let tmp = std::env::temp_dir().join("kcz_io_test_c.bin");
        save(&sys, &tmp).unwrap();
        let back = load(&tmp).unwrap();
        assert_eq!(back.a, sys.a);
        assert_eq!(back.b, sys.b);
        assert_eq!(back.x_true, sys.x_true);
        assert_eq!(back.consistent, sys.consistent);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn roundtrip_with_xls() {
        let mut sys = DatasetBuilder::new(10, 3).seed(8).inconsistent();
        sys.x_ls = Some(vec![1.0, 2.0, 3.0]);
        let tmp = std::env::temp_dir().join("kcz_io_test_ls.bin");
        save(&sys, &tmp).unwrap();
        let back = load(&tmp).unwrap();
        assert_eq!(back.x_ls, sys.x_ls);
        assert!(!back.consistent);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let tmp = std::env::temp_dir().join("kcz_io_test_bad.bin");
        std::fs::write(&tmp, b"NOTMAGIC________").unwrap();
        assert!(load(&tmp).is_err());
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn zero_norm_row_rejected_at_save_time() {
        // Regression: a degenerate row must fail fast when persisting (and
        // symmetrically at load, below) — never resurface as a NaN later.
        let mut sys = DatasetBuilder::new(8, 3).seed(2).consistent();
        sys.a.row_mut(5).fill(0.0);
        let sys = super::super::dataset::LinearSystem::new(sys.a, sys.b, sys.x_true, true);
        let tmp = std::env::temp_dir().join("kcz_io_test_zero_row_save.bin");
        let err = save(&sys, &tmp).err().expect("degenerate row must not persist");
        std::fs::remove_file(&tmp).ok();
        assert!(
            matches!(err, crate::error::Error::DegenerateRow { row: 5 }),
            "got {err:?}"
        );
    }

    #[test]
    fn zero_norm_row_on_disk_is_rejected_typed() {
        // A file produced by something other than `save` (or an older build)
        // carrying an all-zero row must be rejected with the typed error.
        // Hand-write the binary format: 2x2 system whose row 1 is zero.
        let tmp = std::env::temp_dir().join("kcz_io_test_zero_row_load.bin");
        {
            let f = std::fs::File::create(&tmp).unwrap();
            let mut w = BufWriter::new(f);
            w.write_all(MAGIC).unwrap();
            write_u64(&mut w, 2).unwrap(); // rows
            write_u64(&mut w, 2).unwrap(); // cols
            write_u64(&mut w, 1).unwrap(); // consistent
            write_u64(&mut w, 0).unwrap(); // no x_true
            write_u64(&mut w, 0).unwrap(); // no x_ls
            write_f64s(&mut w, &[1.0, 2.0, 0.0, 0.0]).unwrap(); // A (row 1 zero)
            write_f64s(&mut w, &[3.0, 0.0]).unwrap(); // b
            w.flush().unwrap();
        }
        let err = load(&tmp).err().expect("degenerate row must be rejected");
        std::fs::remove_file(&tmp).ok();
        assert!(
            matches!(err, crate::error::Error::DegenerateRow { row: 1 }),
            "got {err:?}"
        );
    }

    #[test]
    fn csr_systems_refuse_binary_save() {
        let sys = crate::data::SparseDatasetBuilder::new(8, 4, 0.5).seed(3).consistent();
        let tmp = std::env::temp_dir().join("kcz_io_test_csr_save.bin");
        let err = save(&sys, &tmp).err().expect("CSR save must be rejected");
        std::fs::remove_file(&tmp).ok();
        assert!(matches!(err, Error::InvalidArgument(_)), "got {err:?}");
    }

    const MTX: &str = "%%MatrixMarket matrix coordinate real general\n\
                       % a 3x4 test matrix\n\
                       3 4 5\n\
                       1 1 2.0\n\
                       1 4 -1.5\n\
                       2 2 3.0\n\
                       3 3 4.0\n\
                       3 3 1.0\n";

    #[test]
    fn mtx_parses_one_indexed_entries_and_sums_duplicates() {
        let a = parse_mtx(MTX.as_bytes(), "test").unwrap();
        assert_eq!((a.rows(), a.cols()), (3, 4));
        assert_eq!(a.nnz(), 4); // the duplicate (3,3) pair merged
        let d = a.to_dense();
        assert_eq!(d.row(0), &[2.0, 0.0, 0.0, -1.5]);
        assert_eq!(d.row(1), &[0.0, 3.0, 0.0, 0.0]);
        assert_eq!(d.row(2), &[0.0, 0.0, 5.0, 0.0]);
    }

    #[test]
    fn mtx_file_roundtrip_builds_consistent_csr_system() {
        let tmp = std::env::temp_dir().join("kcz_io_test.mtx");
        std::fs::write(&tmp, MTX).unwrap();
        let sys = load_mtx_system(&tmp, 7).unwrap();
        std::fs::remove_file(&tmp).ok();
        assert!(sys.a.as_csr().is_some(), "mtx loads must stay sparse");
        assert!(sys.consistent);
        let x = sys.x_true.clone().unwrap();
        assert!(sys.residual_norm(&x) < 1e-9 * sys.frobenius_sq.sqrt());
    }

    #[test]
    fn mtx_rejects_wrong_header() {
        let bad = "%%MatrixMarket matrix array real general\n2 2\n1.0\n2.0\n3.0\n4.0\n";
        let err = parse_mtx(bad.as_bytes(), "test").err().unwrap();
        assert!(matches!(err, Error::InvalidArgument(_)), "got {err:?}");
    }

    #[test]
    fn mtx_rejects_entry_count_mismatch() {
        let short = "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n2 2 1.0\n";
        let err = parse_mtx(short.as_bytes(), "test").err().unwrap();
        assert!(matches!(err, Error::InvalidArgument(_)), "got {err:?}");
        let long =
            "%%MatrixMarket matrix coordinate real general\n1 1 1\n1 1 1.0\n1 1 2.0\n";
        let err = parse_mtx(long.as_bytes(), "test").err().unwrap();
        assert!(matches!(err, Error::InvalidArgument(_)), "got {err:?}");
    }

    #[test]
    fn mtx_rejects_out_of_range_indices() {
        let oob = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        let err = parse_mtx(oob.as_bytes(), "test").err().unwrap();
        assert!(matches!(err, Error::Dimension(_)), "got {err:?}");
        let zero = "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n";
        let err = parse_mtx(zero.as_bytes(), "test").err().unwrap();
        assert!(matches!(err, Error::Dimension(_)), "got {err:?}");
    }

    #[test]
    fn mtx_empty_row_rejected_as_degenerate() {
        // Row 2 of the 3-row matrix has no stored entries: no constraint.
        let mtx = "%%MatrixMarket matrix coordinate real general\n3 2 2\n1 1 1.0\n3 2 2.0\n";
        let tmp = std::env::temp_dir().join("kcz_io_test_degenerate.mtx");
        std::fs::write(&tmp, mtx).unwrap();
        let err = load_mtx_system(&tmp, 1).err().expect("empty row must be rejected");
        std::fs::remove_file(&tmp).ok();
        assert!(
            matches!(err, crate::error::Error::DegenerateRow { row: 1 }),
            "got {err:?}"
        );
    }
}
