//! Binary persistence for generated systems.
//!
//! Benches regenerate multi-hundred-MB matrices otherwise; the format is a
//! trivial little-endian dump with a magic header, no external serialization
//! crates being available offline.

use super::dataset::LinearSystem;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"KCZSYS01";

fn write_f64s<W: Write>(w: &mut W, v: &[f64]) -> Result<()> {
    for &x in v {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f64s<R: Read>(r: &mut R, n: usize) -> Result<Vec<f64>> {
    let mut buf = vec![0u8; n * 8];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn write_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Save a system to `path`.
///
/// Degenerate (zero-norm) rows are rejected up front with
/// [`Error::DegenerateRow`]: `load` refuses them (disk data is untrusted),
/// so failing fast at write time keeps the save/load roundtrip symmetric —
/// anything this function persists, `load` will accept.
pub fn save(sys: &LinearSystem, path: &Path) -> Result<()> {
    if let Some(row) = sys.degenerate_row() {
        return Err(Error::DegenerateRow { row });
    }
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_u64(&mut w, sys.rows() as u64)?;
    write_u64(&mut w, sys.cols() as u64)?;
    write_u64(&mut w, sys.consistent as u64)?;
    write_u64(&mut w, sys.x_true.is_some() as u64)?;
    write_u64(&mut w, sys.x_ls.is_some() as u64)?;
    write_f64s(&mut w, sys.a.as_slice())?;
    write_f64s(&mut w, &sys.b)?;
    if let Some(x) = &sys.x_true {
        write_f64s(&mut w, x)?;
    }
    if let Some(x) = &sys.x_ls {
        write_f64s(&mut w, x)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a system saved by [`save`].
pub fn load(path: &Path) -> Result<LinearSystem> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::InvalidArgument(format!(
            "{} is not a kaczmarz system file",
            path.display()
        )));
    }
    let rows = read_u64(&mut r)? as usize;
    let cols = read_u64(&mut r)? as usize;
    let consistent = read_u64(&mut r)? != 0;
    let has_true = read_u64(&mut r)? != 0;
    let has_ls = read_u64(&mut r)? != 0;
    let a = Matrix::from_vec(rows, cols, read_f64s(&mut r, rows * cols)?)?;
    let b = read_f64s(&mut r, rows)?;
    let x_true = if has_true { Some(read_f64s(&mut r, cols)?) } else { None };
    let x_ls = if has_ls { Some(read_f64s(&mut r, cols)?) } else { None };
    // Disk data is untrusted: reject degenerate rows with a typed error
    // instead of letting a zero norm NaN-poison a later solve.
    let mut sys = LinearSystem::try_new(a, b, x_true, consistent)?;
    sys.x_ls = x_ls;
    Ok(sys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;

    #[test]
    fn roundtrip_consistent() {
        let sys = DatasetBuilder::new(12, 5).seed(4).consistent();
        let tmp = std::env::temp_dir().join("kcz_io_test_c.bin");
        save(&sys, &tmp).unwrap();
        let back = load(&tmp).unwrap();
        assert_eq!(back.a, sys.a);
        assert_eq!(back.b, sys.b);
        assert_eq!(back.x_true, sys.x_true);
        assert_eq!(back.consistent, sys.consistent);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn roundtrip_with_xls() {
        let mut sys = DatasetBuilder::new(10, 3).seed(8).inconsistent();
        sys.x_ls = Some(vec![1.0, 2.0, 3.0]);
        let tmp = std::env::temp_dir().join("kcz_io_test_ls.bin");
        save(&sys, &tmp).unwrap();
        let back = load(&tmp).unwrap();
        assert_eq!(back.x_ls, sys.x_ls);
        assert!(!back.consistent);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let tmp = std::env::temp_dir().join("kcz_io_test_bad.bin");
        std::fs::write(&tmp, b"NOTMAGIC________").unwrap();
        assert!(load(&tmp).is_err());
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn zero_norm_row_rejected_at_save_time() {
        // Regression: a degenerate row must fail fast when persisting (and
        // symmetrically at load, below) — never resurface as a NaN later.
        let mut sys = DatasetBuilder::new(8, 3).seed(2).consistent();
        sys.a.row_mut(5).fill(0.0);
        let sys = super::super::dataset::LinearSystem::new(sys.a, sys.b, sys.x_true, true);
        let tmp = std::env::temp_dir().join("kcz_io_test_zero_row_save.bin");
        let err = save(&sys, &tmp).err().expect("degenerate row must not persist");
        std::fs::remove_file(&tmp).ok();
        assert!(
            matches!(err, crate::error::Error::DegenerateRow { row: 5 }),
            "got {err:?}"
        );
    }

    #[test]
    fn zero_norm_row_on_disk_is_rejected_typed() {
        // A file produced by something other than `save` (or an older build)
        // carrying an all-zero row must be rejected with the typed error.
        // Hand-write the binary format: 2x2 system whose row 1 is zero.
        let tmp = std::env::temp_dir().join("kcz_io_test_zero_row_load.bin");
        {
            let f = std::fs::File::create(&tmp).unwrap();
            let mut w = BufWriter::new(f);
            w.write_all(MAGIC).unwrap();
            write_u64(&mut w, 2).unwrap(); // rows
            write_u64(&mut w, 2).unwrap(); // cols
            write_u64(&mut w, 1).unwrap(); // consistent
            write_u64(&mut w, 0).unwrap(); // no x_true
            write_u64(&mut w, 0).unwrap(); // no x_ls
            write_f64s(&mut w, &[1.0, 2.0, 0.0, 0.0]).unwrap(); // A (row 1 zero)
            write_f64s(&mut w, &[3.0, 0.0]).unwrap(); // b
            w.flush().unwrap();
        }
        let err = load(&tmp).err().expect("degenerate row must be rejected");
        std::fs::remove_file(&tmp).ok();
        assert!(
            matches!(err, crate::error::Error::DegenerateRow { row: 1 }),
            "got {err:?}"
        );
    }
}
