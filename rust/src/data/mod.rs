//! Workload generation — the paper's §3.1 data sets, rebuilt.
//!
//! - consistent overdetermined systems with per-row gaussian entries
//!   (μ ∈ [-5, 5], σ ∈ [1, 20]), smaller systems obtained by cropping the
//!   largest one;
//! - inconsistent systems derived by perturbing `b` with N(0,1) noise, with
//!   the least-squares reference solution computed by CGLS;
//! - highly coherent systems (small angles between consecutive rows) for the
//!   Fig. 1 CK-vs-RK demonstration;
//! - deterministic sparse systems on CSR storage (density-parameterized) for
//!   the storage-generic solve loops;
//! - binary save/load so benches can reuse a generated data set, and a
//!   Matrix Market reader for real sparse test matrices.

pub mod dataset;
pub mod generator;
pub mod io;

pub use dataset::LinearSystem;
pub use generator::{coherent_system, DatasetBuilder, SparseDatasetBuilder};
