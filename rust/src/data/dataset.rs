//! The `LinearSystem` type shared by all solvers and experiments.

use crate::error::{Error, Result};
use crate::linalg::vector::dist_sq;
use crate::linalg::{gemv, norm2, sub, Storage};

/// A (possibly inconsistent) linear system `Ax = b` plus reference solutions.
///
/// `row_norms_sq` and `frobenius_sq` are precomputed once: every Kaczmarz
/// variant needs `‖A^(i)‖²` per iteration and the sampling distribution
/// needs all of them up front (paper eq. 4).
///
/// The matrix sits behind the two-variant [`Storage`] enum — dense
/// ([`Matrix`](crate::linalg::Matrix), the paper's layout) or sparse
/// ([`CsrMatrix`](crate::linalg::CsrMatrix)) — and every solver in the
/// crate runs against either backend. Constructors take
/// `impl Into<Storage>`, so existing call sites keep passing a bare matrix.
#[derive(Clone, Debug)]
pub struct LinearSystem {
    /// Coefficient matrix (m x n, m >= n in all paper experiments).
    pub a: Storage,
    /// Right-hand side (len m).
    pub b: Vec<f64>,
    /// The unique solution for consistent systems (`x*`), if known.
    pub x_true: Option<Vec<f64>>,
    /// The least-squares solution for inconsistent systems (`x_LS`), if known.
    pub x_ls: Option<Vec<f64>>,
    /// Squared row norms `‖A^(i)‖²`.
    pub row_norms_sq: Vec<f64>,
    /// Squared Frobenius norm `‖A‖²_F`.
    pub frobenius_sq: f64,
    /// Whether the system is consistent by construction.
    pub consistent: bool,
}

impl LinearSystem {
    /// Wrap a matrix + rhs, precomputing norms. `x_true`/`x_ls` optional.
    ///
    /// Zero-norm rows are *tolerated* here (synthetic workloads like the CT
    /// example can produce rays that miss the grid): they carry sampling
    /// weight 0, so the randomized solvers never draw them, and the
    /// deterministic scanners (CK, AsyRK) skip them explicitly. Use
    /// [`LinearSystem::try_new`] on untrusted input to reject them up front
    /// with a typed error instead.
    pub fn new(
        a: impl Into<Storage>,
        b: Vec<f64>,
        x_true: Option<Vec<f64>>,
        consistent: bool,
    ) -> Self {
        let a = a.into();
        assert_eq!(a.rows(), b.len(), "rhs length must equal row count");
        let row_norms_sq = a.row_norms_sq();
        let frobenius_sq = row_norms_sq.iter().sum();
        LinearSystem { a, b, x_true, x_ls: None, row_norms_sq, frobenius_sq, consistent }
    }

    /// Strict constructor: like [`LinearSystem::new`] but rejects degenerate
    /// (zero-norm) rows with [`Error::DegenerateRow`] instead of carrying
    /// them. A zero row constrains nothing and every Kaczmarz projection
    /// against it divides by `‖A^(i)‖² = 0` — a NaN that silently poisons
    /// the whole iterate. This is the entry point for data read from disk
    /// or built by applications.
    pub fn try_new(
        a: impl Into<Storage>,
        b: Vec<f64>,
        x_true: Option<Vec<f64>>,
        consistent: bool,
    ) -> Result<Self> {
        let a = a.into();
        if a.rows() != b.len() {
            return Err(Error::Dimension(format!(
                "rhs of len {} does not match {} rows",
                b.len(),
                a.rows()
            )));
        }
        let sys = LinearSystem::new(a, b, x_true, consistent);
        if let Some(row) = sys.degenerate_row() {
            return Err(Error::DegenerateRow { row });
        }
        Ok(sys)
    }

    /// Index of the first degenerate (zero-norm) row, if any — the single
    /// predicate behind [`LinearSystem::try_new`] and `data::io::save`'s
    /// strictness, so the two cannot drift apart.
    pub fn degenerate_row(&self) -> Option<usize> {
        self.row_norms_sq.iter().position(|&nrm| nrm <= 0.0)
    }

    /// Rows (`m`).
    pub fn rows(&self) -> usize {
        self.a.rows()
    }

    /// Columns (`n`).
    pub fn cols(&self) -> usize {
        self.a.cols()
    }

    /// The reference solution experiments measure error against:
    /// `x*` for consistent systems, `x_LS` for inconsistent ones.
    pub fn reference_solution(&self) -> Option<&[f64]> {
        if self.consistent {
            self.x_true.as_deref()
        } else {
            self.x_ls.as_deref().or(self.x_true.as_deref())
        }
    }

    /// Squared error `‖x - x_ref‖²` against the reference solution.
    ///
    /// Panics if no reference solution is known (the generator always sets
    /// one). Solvers consult this lazily and only under reference-error
    /// stopping: fixed-iteration and residual-stopped runs never call it —
    /// history recording included, which degrades to its residual channel
    /// when no reference exists — so systems *without* a reference are
    /// solvable (and observable) under those protocols. This is the
    /// contract `SolveOptions::consults_reference` encodes and
    /// `tests/stopping_properties.rs` / `tests/observability_properties.rs`
    /// pin down.
    pub fn error_sq(&self, x: &[f64]) -> f64 {
        let r = self.reference_solution().expect("no reference solution");
        dist_sq(x, r)
    }

    /// Residual norm `‖Ax - b‖`.
    pub fn residual_norm(&self, x: &[f64]) -> f64 {
        let ax = gemv(&self.a, x).expect("shape checked at construction");
        norm2(&sub(&ax, &self.b))
    }

    /// Row-sampling weights for eq. 4 (`‖A^(i)‖²`; the samplers normalize).
    ///
    /// A degenerate (zero-norm) row has weight 0 and is therefore never
    /// drawn by any eq.-4 sampler — the randomized solvers are NaN-safe
    /// against such rows by construction.
    pub fn sampling_weights(&self) -> &[f64] {
        &self.row_norms_sq
    }

    /// Restrict to a contiguous block of rows (used to hand each distributed
    /// rank its partition: rows `[lo, hi)` with `lo = floor(t·m/q)`,
    /// `hi = floor((t+1)·m/q)` as in §3.3.1).
    pub fn row_partition(&self, part: usize, parts: usize) -> (usize, usize) {
        assert!(parts > 0 && part < parts);
        let m = self.rows();
        (part * m / parts, (part + 1) * m / parts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn tiny() -> LinearSystem {
        // x_true = [1, 1]
        let a = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]).unwrap();
        let b = vec![1.0, 1.0, 2.0];
        LinearSystem::new(a, b, Some(vec![1.0, 1.0]), true)
    }

    #[test]
    fn norms_precomputed() {
        let s = tiny();
        assert_eq!(s.row_norms_sq, vec![1.0, 1.0, 2.0]);
        assert_eq!(s.frobenius_sq, 4.0);
    }

    #[test]
    fn error_and_residual() {
        let s = tiny();
        assert_eq!(s.error_sq(&[1.0, 1.0]), 0.0);
        assert!(s.residual_norm(&[1.0, 1.0]) < 1e-12);
        assert!(s.error_sq(&[0.0, 0.0]) > 0.0);
    }

    #[test]
    fn partition_covers_all_rows() {
        let s = tiny();
        let (l0, h0) = s.row_partition(0, 2);
        let (l1, h1) = s.row_partition(1, 2);
        assert_eq!(l0, 0);
        assert_eq!(h0, l1);
        assert_eq!(h1, 3);
    }

    #[test]
    fn reference_prefers_ls_when_inconsistent() {
        let mut s = tiny();
        s.consistent = false;
        s.x_ls = Some(vec![0.9, 1.1]);
        assert_eq!(s.reference_solution().unwrap(), &[0.9, 1.1]);
    }

    #[test]
    #[should_panic]
    fn rhs_length_checked() {
        let a = Matrix::zeros(3, 2);
        LinearSystem::new(a, vec![0.0; 2], None, true);
    }

    #[test]
    fn try_new_rejects_zero_norm_rows() {
        // Row 1 is all zeros: no constraint, and ‖A^(1)‖² = 0 would NaN any
        // projection against it.
        let a = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 0.0, 1.0, 1.0]).unwrap();
        let err = LinearSystem::try_new(a, vec![1.0, 0.0, 2.0], None, true)
            .err()
            .expect("zero row must be rejected");
        match err {
            Error::DegenerateRow { row } => assert_eq!(row, 1),
            other => panic!("expected DegenerateRow, got {other:?}"),
        }
    }

    #[test]
    fn try_new_accepts_full_rank_rows() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let sys = LinearSystem::try_new(a, vec![1.0, 2.0], Some(vec![1.0, 2.0]), true).unwrap();
        assert_eq!(sys.rows(), 2);
    }

    #[test]
    fn try_new_rejects_bad_rhs_with_typed_error() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        assert!(matches!(
            LinearSystem::try_new(a, vec![1.0], None, true),
            Err(Error::Dimension(_))
        ));
    }

    #[test]
    fn zero_norm_row_never_sampled_and_solvers_stay_finite() {
        // Lenient construction keeps the zero row but gives it weight 0:
        // RK must converge on the remaining rows without ever producing NaN.
        use crate::solvers::rk::RkSolver;
        use crate::solvers::{SolveOptions, Solver};
        let mut sys = crate::data::DatasetBuilder::new(60, 5).seed(11).consistent();
        let m = sys.rows();
        sys.a.row_mut(m / 2).fill(0.0);
        sys.b[m / 2] = 0.0; // consistent: 0·x = 0
        let sys = LinearSystem::new(sys.a, sys.b, sys.x_true, true);
        assert_eq!(sys.sampling_weights()[m / 2], 0.0);
        let r = RkSolver::new(3).solve(&sys, &SolveOptions::default().with_tolerance(1e-10));
        assert!(r.converged);
        assert!(r.x.iter().all(|v| v.is_finite()), "NaN leaked into the iterate");
    }
}
