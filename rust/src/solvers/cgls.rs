//! Conjugate Gradient for Least Squares (CGLS).
//!
//! The paper (§3.1) computes the least-squares reference solution `x_LS` of
//! the inconsistent data set with CGLS; experiments then measure
//! `‖x^(k) - x_LS‖`. CGLS applies CG to the normal equations `AᵀA x = Aᵀb`
//! using only products with `A` and `Aᵀ` (never forming `AᵀA`).

use crate::data::LinearSystem;
use crate::error::{Error, Result};
use crate::linalg::gemv::{gemv_into, gemv_transpose_into};
use crate::linalg::vector::{axpy, norm2_sq};

/// Solve `min ‖Ax - b‖` to relative normal-equation residual `tol`.
///
/// Returns `x_LS`; errors out if `max_iter` is exhausted first.
pub fn solve_least_squares(system: &LinearSystem, tol: f64, max_iter: usize) -> Result<Vec<f64>> {
    let m = system.rows();
    let n = system.cols();
    let a = &system.a;

    let mut x = vec![0.0; n];
    // r = b - A x  (x = 0 ⇒ r = b)
    let mut r = system.b.clone();
    // s = Aᵀ r
    let mut s = vec![0.0; n];
    gemv_transpose_into(a, &r, &mut s);
    let mut p = s.clone();
    let mut gamma = norm2_sq(&s);
    let gamma0 = gamma;
    if gamma0 == 0.0 {
        return Ok(x); // b orthogonal to range(A): x = 0 is the LS solution
    }
    let mut q = vec![0.0; m];

    for _ in 0..max_iter {
        // q = A p
        gemv_into(a, &p, &mut q);
        let qq = norm2_sq(&q);
        if qq == 0.0 {
            break; // p in null space (rank deficient); x is optimal over explored space
        }
        let alpha = gamma / qq;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &q, &mut r);
        gemv_transpose_into(a, &r, &mut s);
        let gamma_new = norm2_sq(&s);
        if gamma_new <= tol * tol * gamma0 {
            return Ok(x);
        }
        let beta = gamma_new / gamma;
        gamma = gamma_new;
        // p = s + beta p
        for i in 0..n {
            p[i] = s[i] + beta * p[i];
        }
    }
    Err(Error::NoConvergence { iterations: max_iter, residual: gamma.sqrt() })
}

/// Convenience: fill `system.x_ls` in place (no-op when already set).
pub fn attach_least_squares(system: &mut LinearSystem, tol: f64, max_iter: usize) -> Result<()> {
    if system.x_ls.is_none() {
        system.x_ls = Some(solve_least_squares(system, tol, max_iter)?);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::linalg::gemv::gemv_transpose;
    use crate::linalg::{norm2, sub};

    #[test]
    fn exact_on_consistent_system() {
        let sys = DatasetBuilder::new(80, 10).seed(5).consistent();
        let x = solve_least_squares(&sys, 1e-12, 1000).unwrap();
        let x_true = sys.x_true.as_ref().unwrap();
        let rel = norm2(&sub(&x, x_true)) / norm2(x_true);
        assert!(rel < 1e-8, "rel err {rel}");
    }

    #[test]
    fn normal_equations_hold_on_inconsistent_system() {
        // x_LS is characterized by Aᵀ(Ax - b) = 0.
        let sys = DatasetBuilder::new(120, 8).seed(6).inconsistent();
        let x = solve_least_squares(&sys, 1e-12, 2000).unwrap();
        let ax = crate::linalg::gemv::gemv(&sys.a, &x).unwrap();
        let resid = sub(&ax, &sys.b);
        let grad = gemv_transpose(&sys.a, &resid).unwrap();
        let scale = norm2(&sys.b) * sys.frobenius_sq.sqrt();
        assert!(norm2(&grad) / scale < 1e-9, "grad norm {}", norm2(&grad));
    }

    #[test]
    fn ls_residual_no_worse_than_any_probe() {
        let sys = DatasetBuilder::new(60, 5).seed(7).inconsistent();
        let x = solve_least_squares(&sys, 1e-12, 1000).unwrap();
        let r_ls = sys.residual_norm(&x);
        // Perturbations can only increase the residual.
        for i in 0..5 {
            let mut probe = x.clone();
            probe[i] += 0.1;
            assert!(sys.residual_norm(&probe) >= r_ls);
        }
    }

    #[test]
    fn attach_is_idempotent() {
        let mut sys = DatasetBuilder::new(40, 4).seed(8).inconsistent();
        attach_least_squares(&mut sys, 1e-10, 500).unwrap();
        let first = sys.x_ls.clone().unwrap();
        attach_least_squares(&mut sys, 1e-10, 500).unwrap();
        assert_eq!(sys.x_ls.unwrap(), first);
    }
}
