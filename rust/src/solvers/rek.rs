//! Randomized Extended Kaczmarz (Zouzias–Freris 2013).
//!
//! Plain RK on an inconsistent system stalls at a convergence horizon: the
//! rows' hyperplanes have no common point, so the iterate orbits `x_LS` at a
//! distance set by the noise (paper §2.2, and the survey Ferreira et al.,
//! arXiv 2401.02842 §4). REK removes the wall with a second, *column*-space
//! projection stream. It maintains `z ≈ the component of b outside
//! range(A)`: each step projects `z` orthogonally to one column
//! (`z ← z − (<A_(j), z> / ‖A_(j)‖²) A_(j)`, column `j` sampled
//! `∝ ‖A_(j)‖²`), driving `z → b − A x_LS`. The row step is then ordinary
//! RK against the *deflated* right-hand side `b − z`, whose system **is**
//! consistent with solution `x_LS` — so the iterates converge to the
//! least-squares solution itself.
//!
//! Practical consequence for stopping: the **reference-error** channel
//! (`‖x − x_LS‖²`) now reaches any tolerance, where RK/RKA flatten out at
//! their horizon. The **residual** channel still floors at the least-squares
//! residual `‖b − A x_LS‖²` — that is a property of the system, not the
//! solver — so residual stopping tolerances below the CGLS floor remain
//! unreachable for REK too. Use reference stopping (or a residual tolerance
//! above the floor) exactly as with every other solver here.

use super::{SolveOptions, SolveResult, Solver, StopCheck};
use crate::data::LinearSystem;
use crate::metrics::Stopwatch;
use crate::rng::{derive_seed, AliasTable, Mt19937};

/// Randomized Extended Kaczmarz solver.
///
/// Runs on either storage backend: the dense column ops stride the row-major
/// buffer, the CSR ones binary-search each row's stored columns (see
/// [`RowStorage::col_dot`](crate::linalg::RowStorage::col_dot)).
///
/// ```
/// use kaczmarz::data::DatasetBuilder;
/// use kaczmarz::solvers::cgls::attach_least_squares;
/// use kaczmarz::solvers::rek::RekSolver;
/// use kaczmarz::solvers::{SolveOptions, Solver};
///
/// // Inconsistent system: plain RK stalls at a horizon away from x_LS;
/// // REK converges to x_LS itself.
/// let mut sys = DatasetBuilder::new(120, 6).seed(3).inconsistent();
/// attach_least_squares(&mut sys, 1e-12, 20_000).unwrap();
/// let r = RekSolver::new(7).solve(&sys, &SolveOptions::default().with_tolerance(1e-6));
/// assert!(r.converged);
/// assert!(sys.error_sq(&r.x) < 1e-6);
/// ```
pub struct RekSolver {
    /// RNG seed. The row and column streams are derived sub-streams
    /// (`derive_seed(seed, 0)` / `derive_seed(seed, 1)`), so one seed pins
    /// the whole trajectory.
    pub seed: u32,
}

impl RekSolver {
    /// REK with the standard unit projections.
    pub fn new(seed: u32) -> Self {
        RekSolver { seed }
    }
}

impl Solver for RekSolver {
    fn name(&self) -> &'static str {
        "REK"
    }

    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult {
        let n = system.cols();
        let mut x = vec![0.0; n];
        // z starts at b and is driven toward b's out-of-range(A) component.
        let mut z = system.b.clone();
        let mut row_rng = Mt19937::new(derive_seed(self.seed, 0));
        let mut col_rng = Mt19937::new(derive_seed(self.seed, 1));
        let row_dist = AliasTable::new(system.sampling_weights());
        // Column norms are this solver's one extra precomputation; zero
        // columns get zero sampling probability, mirroring eq. 4 for rows.
        let col_norms_sq = system.a.col_norms_sq();
        let col_dist = AliasTable::new(&col_norms_sq);
        let mut stopper = StopCheck::new(system, opts);

        let sw = Stopwatch::start();
        let mut k = 0usize;
        let (mut converged, mut diverged);
        loop {
            let (stop, c, d) = stopper.check(k, &x);
            converged = c;
            diverged = d;
            if stop {
                break;
            }
            // Column step: project z orthogonally to column j, removing
            // range(A) components from it.
            let j = col_dist.sample(&mut col_rng);
            let zscale = -system.a.col_dot(j, &z) / col_norms_sq[j];
            system.a.col_axpy(j, zscale, &mut z);
            // Row step: plain RK projection against the deflated rhs b − z.
            let i = row_dist.sample(&mut row_rng);
            let residual = system.b[i] - z[i] - system.a.row_dot(i, &x);
            let scale = residual / system.row_norms_sq[i];
            system.a.row_axpy(i, scale, &mut x);
            k += 1;
        }

        SolveResult {
            x,
            iterations: k,
            converged,
            diverged,
            seconds: sw.seconds(),
            rows_used: k,
            history: stopper.into_history(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::solvers::cgls::attach_least_squares;
    use crate::solvers::rk::RkSolver;

    #[test]
    fn converges_on_consistent_system() {
        // On a consistent system z → 0 and REK behaves like (deflated) RK.
        let sys = DatasetBuilder::new(200, 10).seed(1).consistent();
        let r = RekSolver::new(42).solve(&sys, &SolveOptions::default().with_tolerance(1e-12));
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-12);
    }

    #[test]
    fn reaches_least_squares_solution_where_rk_stalls() {
        let mut sys = DatasetBuilder::new(300, 5).seed(9).inconsistent();
        attach_least_squares(&mut sys, 1e-12, 10_000).unwrap();
        let opts = SolveOptions::default().with_tolerance(1e-10).with_max_iterations(200_000);
        // Same system and tolerance as rk.rs's stall test: RK cannot hit
        // 1e-10 of x_LS on a noisy system, REK must.
        let rk = RkSolver::new(3).solve(&sys, &opts);
        assert!(!rk.converged, "RK is expected to stall on this system");
        let rek = RekSolver::new(3).solve(&sys, &opts);
        assert!(rek.converged, "REK stalled: error {}", sys.error_sq(&rek.x));
        assert!(sys.error_sq(&rek.x) < 1e-10);
    }

    #[test]
    fn trajectory_is_seed_deterministic() {
        let mut sys = DatasetBuilder::new(120, 6).seed(5).inconsistent();
        attach_least_squares(&mut sys, 1e-12, 10_000).unwrap();
        let opts = SolveOptions::default().with_fixed_iterations(500);
        let a = RekSolver::new(11).solve(&sys, &opts);
        let b = RekSolver::new(11).solve(&sys, &opts);
        for (u, v) in a.x.iter().zip(&b.x) {
            assert_eq!(u.to_bits(), v.to_bits(), "same seed, same trajectory");
        }
        let c = RekSolver::new(12).solve(&sys, &opts);
        assert!(a.x.iter().zip(&c.x).any(|(u, v)| u != v), "different seed must differ");
    }
}
