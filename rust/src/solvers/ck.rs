//! Cyclic Kaczmarz (the original 1937 method, paper eq. 3).
//!
//! Rows are used in order `i = k mod m`. Kept both as the historical
//! baseline and for the Fig. 1 coherent-system demonstration, where cyclic
//! selection crawls and randomized selection does not.

use super::{SolveOptions, SolveResult, Solver, StopCheck};
use crate::data::LinearSystem;
use crate::metrics::Stopwatch;

/// Cyclic Kaczmarz solver.
pub struct CkSolver {
    /// Relaxation parameter `alpha_i` in (0, 2); 1.0 = pure projection.
    pub relaxation: f64,
}

impl CkSolver {
    /// Cyclic Kaczmarz with unit relaxation.
    pub fn new() -> Self {
        CkSolver { relaxation: 1.0 }
    }

    /// Override the relaxation parameter.
    pub fn with_relaxation(relaxation: f64) -> Self {
        assert!(relaxation > 0.0 && relaxation < 2.0, "alpha must be in (0,2)");
        CkSolver { relaxation }
    }
}

impl Default for CkSolver {
    fn default() -> Self {
        Self::new()
    }
}

impl Solver for CkSolver {
    fn name(&self) -> &'static str {
        "CK"
    }

    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult {
        let m = system.rows();
        let n = system.cols();
        let mut x = vec![0.0; n];
        // Timing protocol (§3.1): with `fixed_iterations` set, StopCheck
        // never evaluates the metric, so the stopping test is off the clock
        // and the reference solution is never consulted. History recording
        // (dual-channel, reference-optional) also lives in StopCheck.
        let mut stopper = StopCheck::new(system, opts);

        let sw = Stopwatch::start();
        let mut k = 0usize;
        let (mut converged, mut diverged);
        loop {
            let (stop, c, d) = stopper.check(k, &x);
            converged = c;
            diverged = d;
            if stop {
                break;
            }
            // i = k mod m: one projection per iteration. Degenerate rows
            // (zero norm ⇒ zero-division NaN) carry no constraint; the
            // cyclic sweep steps over them, still counting the iteration so
            // `i = k mod m` keeps its meaning.
            let i = k % m;
            if system.row_norms_sq[i] > 0.0 {
                let residual = system.b[i] - system.a.row_dot(i, &x);
                let scale = self.relaxation * residual / system.row_norms_sq[i];
                system.a.row_axpy(i, scale, &mut x);
            }
            k += 1;
        }

        SolveResult {
            x,
            iterations: k,
            converged,
            diverged,
            seconds: sw.seconds(),
            rows_used: k,
            history: stopper.into_history(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;

    #[test]
    fn converges_on_small_consistent_system() {
        let sys = DatasetBuilder::new(60, 5).seed(1).consistent();
        let r = CkSolver::new().solve(&sys, &SolveOptions::default().with_tolerance(1e-10));
        assert!(r.converged, "iterations {}", r.iterations);
        assert!(sys.error_sq(&r.x) < 1e-10);
    }

    #[test]
    fn fixed_iterations_runs_exactly() {
        let sys = DatasetBuilder::new(30, 4).seed(2).consistent();
        let r = CkSolver::new().solve(&sys, &SolveOptions::default().with_fixed_iterations(123));
        assert_eq!(r.iterations, 123);
        assert_eq!(r.rows_used, 123);
    }

    #[test]
    fn history_recorded_on_step() {
        let sys = DatasetBuilder::new(30, 4).seed(3).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(100).with_history_step(10);
        let r = CkSolver::new().solve(&sys, &opts);
        assert_eq!(r.history.len(), 11); // k = 0,10,...,100 (final state included)
        // error decreases overall
        assert!(r.history.errors.last().unwrap() < r.history.errors.first().unwrap());
    }

    #[test]
    #[should_panic]
    fn relaxation_out_of_range_panics() {
        CkSolver::with_relaxation(2.5);
    }
}
