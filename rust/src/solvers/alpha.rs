//! Optimal uniform row weight `alpha*` for RKA (paper eq. 6).
//!
//! For consistent systems and uniform weights `w_i = alpha`, Moorman et al.
//! derive the convergence-optimal value from the extreme singular values:
//!
//! ```text
//! s_min = σ²_min(A) / ‖A‖²_F      s_max = σ²_max(A) / ‖A‖²_F
//!
//! alpha* = q / (1 + (q-1) s_min)                     if s_max - s_min <= 1/(q-1)
//!        = 2q / (1 + (q-1)(s_min + s_max))           otherwise
//! ```
//!
//! The paper stresses that computing `alpha*` is expensive (Table 2 charges
//! ~2500 s — the singular values of the full matrix) and therefore also
//! evaluates a *partial-matrix* variant where each worker computes its own
//! `alpha` from only the rows it owns (§3.3.1, Table 1). Both are here, and
//! both report their computation time so Table 2 can charge it.

use crate::data::LinearSystem;
use crate::error::Result;
use crate::linalg::eig::{inverse_power_iteration, power_iteration};
use crate::metrics::Stopwatch;

/// Extreme-singular-value summary of a (sub)matrix.
#[derive(Clone, Copy, Debug)]
pub struct SpectralBounds {
    /// `σ²_min / ‖A‖²_F`.
    pub s_min: f64,
    /// `σ²_max / ‖A‖²_F`.
    pub s_max: f64,
    /// Seconds spent computing the bounds (charged by Table 2).
    pub seconds: f64,
}

/// Compute `s_min`/`s_max` over rows `[lo, hi)` of the system.
///
/// Builds the Gram matrix of the row block (n x n), then runs power and
/// inverse-power iteration. For the full matrix pass `0..m`.
pub fn spectral_bounds(system: &LinearSystem, lo: usize, hi: usize) -> Result<SpectralBounds> {
    let sw = Stopwatch::start();
    let block = system.a.row_block(lo, hi)?;
    let fro_sq: f64 = system.row_norms_sq[lo..hi].iter().sum();
    let g = block.gram();
    let hi_eig = power_iteration(&g, 1e-10, 50_000)?;
    // An underdetermined block (fewer rows than columns) has sigma_min = 0
    // exactly; a near-singular Gram can also defeat the Cholesky-based
    // inverse iteration numerically — in both cases report 0 rather than
    // failing (the partial-matrix alpha of §3.3.1 then degenerates to q,
    // which is the correct limit of eq. 6).
    let s_min = if block.rows() < block.cols() {
        0.0
    } else {
        match inverse_power_iteration(&g, 1e-10, 50_000) {
            Ok(e) => e.value / fro_sq,
            Err(_) => 0.0,
        }
    };
    Ok(SpectralBounds {
        s_min,
        s_max: hi_eig.value / fro_sq,
        seconds: sw.seconds(),
    })
}

/// Paper eq. 6: the optimal uniform weight for `q` workers.
pub fn optimal_alpha(bounds: &SpectralBounds, q: usize) -> f64 {
    assert!(q >= 1);
    if q == 1 {
        // RKA with one worker is RK; eq. 6 degenerates to 1/(1) = 1... but
        // formally q/(1+0) = 1, consistent.
        return 1.0;
    }
    let qf = q as f64;
    let (smin, smax) = (bounds.s_min, bounds.s_max);
    if smax - smin <= 1.0 / (qf - 1.0) {
        qf / (1.0 + (qf - 1.0) * smin)
    } else {
        2.0 * qf / (1.0 + (qf - 1.0) * (smin + smax))
    }
}

/// Full-matrix `alpha*` (one value shared by all workers) + its cost.
pub fn full_matrix_alpha(system: &LinearSystem, q: usize) -> Result<(f64, f64)> {
    let b = spectral_bounds(system, 0, system.rows())?;
    Ok((optimal_alpha(&b, q), b.seconds))
}

/// Partial-matrix `alpha` (§3.3.1): worker `t` of `q` computes its own value
/// from the row partition it owns. Returns one alpha per worker plus the
/// *maximum* per-worker cost (they run concurrently in the paper).
pub fn partial_matrix_alphas(system: &LinearSystem, q: usize) -> Result<(Vec<f64>, f64)> {
    let mut alphas = Vec::with_capacity(q);
    let mut max_cost = 0.0f64;
    for t in 0..q {
        let (lo, hi) = system.row_partition(t, q);
        let b = spectral_bounds(system, lo, hi)?;
        alphas.push(optimal_alpha(&b, q));
        max_cost = max_cost.max(b.seconds);
    }
    Ok((alphas, max_cost))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::linalg::jacobi_singular_values;

    #[test]
    fn bounds_match_jacobi_svd() {
        let sys = DatasetBuilder::new(60, 6).seed(10).consistent();
        let b = spectral_bounds(&sys, 0, 60).unwrap();
        let sv = jacobi_singular_values(sys.a.as_dense().unwrap(), 1e-13, 200).unwrap();
        let smax = sv[0] * sv[0] / sys.frobenius_sq;
        let smin = sv[5] * sv[5] / sys.frobenius_sq;
        assert!((b.s_max - smax).abs() / smax < 1e-6);
        assert!((b.s_min - smin).abs() / smin < 1e-5);
    }

    #[test]
    fn alpha_exceeds_one_and_is_bounded_by_q() {
        // For well-conditioned random matrices alpha* ≈ q (the paper observes
        // 1.999 and 3.992 for q = 2, 4).
        let sys = DatasetBuilder::new(400, 20).seed(11).consistent();
        let b = spectral_bounds(&sys, 0, 400).unwrap();
        for q in [2usize, 4, 8, 16] {
            let a = optimal_alpha(&b, q);
            assert!(a > 1.0, "alpha {a} for q {q}");
            assert!(a <= q as f64 + 1e-9, "alpha {a} for q {q}");
        }
    }

    #[test]
    fn q1_is_unit() {
        let b = SpectralBounds { s_min: 0.01, s_max: 0.2, seconds: 0.0 };
        assert_eq!(optimal_alpha(&b, 1), 1.0);
    }

    #[test]
    fn branch_selection() {
        // Tight spectrum -> first branch.
        let tight = SpectralBounds { s_min: 0.10, s_max: 0.12, seconds: 0.0 };
        let a1 = optimal_alpha(&tight, 4);
        assert!((a1 - 4.0 / (1.0 + 3.0 * 0.10)).abs() < 1e-12);
        // Wide spectrum -> second branch.
        let wide = SpectralBounds { s_min: 0.01, s_max: 0.9, seconds: 0.0 };
        let a2 = optimal_alpha(&wide, 4);
        assert!((a2 - 8.0 / (1.0 + 3.0 * 0.91)).abs() < 1e-12);
    }

    #[test]
    fn partial_alphas_close_to_full_for_few_workers() {
        // Table 1's observation: partial-matrix alpha barely changes the
        // iteration count because the per-partition spectra resemble the
        // full spectrum when partitions are large.
        let sys = DatasetBuilder::new(300, 10).seed(12).consistent();
        let (full, _) = full_matrix_alpha(&sys, 2).unwrap();
        let (parts, _) = partial_matrix_alphas(&sys, 2).unwrap();
        for p in parts {
            assert!((p - full).abs() / full < 0.05, "partial {p} vs full {full}");
        }
    }
}
