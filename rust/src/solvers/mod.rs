//! Sequential solvers — the reference semantics every parallel
//! implementation in this crate is validated against.
//!
//! - [`ck`] — cyclic Kaczmarz (paper eq. 3, rows used in order);
//! - [`rk`] — Randomized Kaczmarz (Strohmer–Vershynin sampling, eq. 4);
//! - [`rka`] — Randomized Kaczmarz with Averaging (Moorman et al., eq. 7),
//!   sequential semantics of Algorithm 1;
//! - [`rkab`] — the paper's new block-averaging variant (eqs. 8–9),
//!   sequential semantics of Algorithm 3;
//! - [`cgls`] — Conjugate Gradient for Least Squares, the paper's oracle for
//!   `x_LS` on inconsistent systems;
//! - [`alpha`] — the optimal uniform weight `alpha*` (eq. 6), from the full
//!   matrix or a per-worker partition.

pub mod alpha;
pub mod cgls;
pub mod ck;
pub mod rk;
pub mod rka;
pub mod rkab;
pub mod sampling;

pub use sampling::{RowSampler, SamplingScheme};

use crate::data::LinearSystem;
use crate::metrics::History;

/// Convergence / iteration-budget options shared by every solver.
#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// Stop when `‖x^(k) - x_ref‖² < tolerance` (paper: ε = 1e-8).
    pub tolerance: f64,
    /// Hard iteration cap.
    pub max_iterations: usize,
    /// When `Some(k)`, ignore the tolerance and run exactly `k` iterations —
    /// the paper's timing protocol (calibrate iterations first, then time a
    /// fixed-iteration run so the stopping test is off the clock).
    pub fixed_iterations: Option<usize>,
    /// Record error/residual every `history_step` iterations (0 = off).
    pub history_step: usize,
    /// Declare divergence when the error exceeds `divergence_factor` x the
    /// initial error (used by the Fig. 10 α sweep, where RKAB can diverge).
    pub divergence_factor: f64,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            tolerance: 1e-8,
            max_iterations: 10_000_000,
            fixed_iterations: None,
            history_step: 0,
            divergence_factor: 1e6,
        }
    }
}

impl SolveOptions {
    /// Set the squared-error tolerance.
    pub fn with_tolerance(mut self, tol: f64) -> Self {
        self.tolerance = tol;
        self
    }

    /// Set the iteration cap.
    pub fn with_max_iterations(mut self, it: usize) -> Self {
        self.max_iterations = it;
        self
    }

    /// Run exactly `it` iterations (timing protocol).
    pub fn with_fixed_iterations(mut self, it: usize) -> Self {
        self.fixed_iterations = Some(it);
        self
    }

    /// Record history every `step` iterations.
    pub fn with_history_step(mut self, step: usize) -> Self {
        self.history_step = step;
        self
    }
}

/// Outcome of a solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    /// Final solution estimate.
    pub x: Vec<f64>,
    /// Iterations executed.
    pub iterations: usize,
    /// Whether the tolerance was met (always true for fixed-iteration runs
    /// that were calibrated to converge).
    pub converged: bool,
    /// Whether divergence was detected.
    pub diverged: bool,
    /// Wall-clock seconds of the iteration loop only.
    pub seconds: f64,
    /// Total rows processed (iterations x workers x block for the block
    /// methods; equals `iterations` for RK/CK).
    pub rows_used: usize,
    /// Step-sampled error/residual history (empty unless requested).
    pub history: History,
}

/// A solver over a `LinearSystem`.
pub trait Solver {
    /// Human-readable name used in reports.
    fn name(&self) -> &'static str;
    /// Run the solver.
    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult;
}

/// Shared inner-loop helper: should we stop at iteration `k` with squared
/// error `err_sq`? Returns `(stop, converged, diverged)`.
#[inline]
pub(crate) fn stop_check(
    opts: &SolveOptions,
    k: usize,
    err_sq: f64,
    initial_err_sq: f64,
) -> (bool, bool, bool) {
    if let Some(fixed) = opts.fixed_iterations {
        return (k >= fixed, true, false);
    }
    if err_sq < opts.tolerance {
        return (true, true, false);
    }
    if err_sq > initial_err_sq * opts.divergence_factor && initial_err_sq > 0.0 {
        return (true, false, true);
    }
    (k >= opts.max_iterations, false, false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stop_check_fixed_iterations_overrides_tolerance() {
        let opts = SolveOptions::default().with_fixed_iterations(10);
        // not done yet even though error tiny
        assert_eq!(stop_check(&opts, 5, 0.0, 1.0), (false, true, false));
        assert_eq!(stop_check(&opts, 10, 1e9, 1.0), (true, true, false));
    }

    #[test]
    fn stop_check_tolerance() {
        let opts = SolveOptions::default().with_tolerance(1e-4);
        assert_eq!(stop_check(&opts, 3, 1e-5, 1.0), (true, true, false));
        assert_eq!(stop_check(&opts, 3, 1e-3, 1.0), (false, false, false));
    }

    #[test]
    fn stop_check_divergence() {
        let opts = SolveOptions { divergence_factor: 10.0, ..Default::default() };
        let (stop, conv, div) = stop_check(&opts, 3, 100.0, 1.0);
        assert!(stop && !conv && div);
    }

    #[test]
    fn stop_check_budget() {
        let opts = SolveOptions::default().with_max_iterations(100);
        assert_eq!(stop_check(&opts, 100, 1.0, 1.0), (true, false, false));
    }
}
