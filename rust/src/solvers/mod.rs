//! Sequential solvers — the reference semantics every parallel
//! implementation in this crate is validated against.
//!
//! - [`ck`] — cyclic Kaczmarz (paper eq. 3, rows used in order);
//! - [`rk`] — Randomized Kaczmarz (Strohmer–Vershynin sampling, eq. 4);
//! - [`rka`] — Randomized Kaczmarz with Averaging (Moorman et al., eq. 7),
//!   sequential semantics of Algorithm 1;
//! - [`rkab`] — the paper's new block-averaging variant (eqs. 8–9),
//!   sequential semantics of Algorithm 3;
//! - [`rek`] — Randomized Extended Kaczmarz (Zouzias–Freris), whose column
//!   projections make the iterates converge to the least-squares solution
//!   of *inconsistent* systems instead of stalling at a horizon;
//! - [`cgls`] — Conjugate Gradient for Least Squares, the paper's oracle for
//!   `x_LS` on inconsistent systems;
//! - [`alpha`] — the optimal uniform weight `alpha*` (eq. 6), from the full
//!   matrix or a per-worker partition.

pub mod alpha;
pub mod cgls;
pub mod ck;
pub mod rek;
pub mod rk;
pub mod rka;
pub mod rkab;
pub mod sampling;

pub use sampling::{
    require_randomized, GreedySelector, RowSampler, SamplingScheme, SamplingStrategy,
};

use crate::data::LinearSystem;
use crate::linalg::vector::dist_sq;
use crate::metrics::{History, ProgressSink, Sample};
use crate::parallel::residual_gemv_into;
use crate::serve::SolveControl;

/// What quantity the convergence test measures, and against what bound.
///
/// The paper stops on `‖x^(k) - x*‖² < ε`, which needs a *reference
/// solution* — fine for reproduction experiments (the generator always
/// knows `x*`), useless for serving, where the answer is exactly what is
/// being computed. Moorman et al. (arXiv:2002.04126) analyze RKA through
/// the residual for this reason, and Liu–Wright–Sridhar (arXiv:1401.4780)
/// stop their asynchronous solver on residual-style criteria; the
/// [`StoppingCriterion::Residual`] variant brings that here.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StoppingCriterion {
    /// Stop when `‖x^(k) - x_ref‖² < tolerance` (paper §3.5, ε = 1e-8).
    /// Requires the system to carry a reference solution
    /// ([`LinearSystem::reference_solution`]); evaluated every iteration.
    ReferenceError {
        /// Squared-error bound `ε`.
        tolerance: f64,
    },
    /// Stop when `‖A x^(k) - b‖² < tolerance` — computable for any system,
    /// no reference needed. The test costs a full `O(m·n)` mat-vec (run
    /// through [`gemv_block_into`](crate::linalg::gemv_block_into), or its
    /// pool-parallel twin [`residual_gemv_into`] on large systems), so it
    /// is evaluated only every
    /// `check_every` iterations to stay off the hot path; on a consistent
    /// system any positive tolerance is achievable, on an inconsistent one
    /// only tolerances above the least-squares floor `‖A x_LS - b‖²` are.
    Residual {
        /// Squared-residual bound.
        tolerance: f64,
        /// Evaluate the (expensive) residual test every this many
        /// iterations; 1 = every iteration. Must be >= 1.
        check_every: usize,
    },
}

impl StoppingCriterion {
    /// The tolerance bound, whichever quantity it applies to.
    #[inline]
    pub fn tolerance(&self) -> f64 {
        match *self {
            StoppingCriterion::ReferenceError { tolerance } => tolerance,
            StoppingCriterion::Residual { tolerance, .. } => tolerance,
        }
    }
}

/// Convergence / iteration-budget options shared by every solver.
#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// Convergence test: reference error (paper default) or residual.
    pub stopping: StoppingCriterion,
    /// Hard iteration cap.
    pub max_iterations: usize,
    /// When `Some(k)`, ignore the stopping criterion and run exactly `k`
    /// iterations — the paper's timing protocol (calibrate iterations
    /// first, then time a fixed-iteration run so the stopping test is off
    /// the clock). Such runs evaluate *no* convergence metric at all (the
    /// initial error is lazy), so they work on systems without a reference
    /// solution — and they report `converged = false`, because nothing was
    /// measured.
    pub fixed_iterations: Option<usize>,
    /// Record a convergence-history sample every `history_step` iterations
    /// (0 = off). Recording is **dual-channel and reference-optional**: the
    /// residual channel `‖Ax - b‖` is always recorded (one amortized
    /// [`gemv_block_into`](crate::linalg::gemv_block_into) per sample), the
    /// reference-error channel
    /// `‖x - x_ref‖` only when the system actually carries a reference —
    /// so reference-free serving jobs can request convergence curves too
    /// (see [`crate::metrics::History`]).
    pub history_step: usize,
    /// Declare divergence when the stopping metric exceeds
    /// `divergence_factor` x its initial value (used by the Fig. 10 α
    /// sweep, where RKAB can diverge).
    pub divergence_factor: f64,
    /// Live telemetry sink: when set, the solve streams a
    /// [`Sample`] (`k`, residual, optional reference error, elapsed) at
    /// every checkpoint where the residual is already being computed —
    /// history samples (`history_step`) and residual stopping checkpoints
    /// (`check_every`) — so attaching a sink adds **zero new GEMVs** to the
    /// hot path. Emission is non-blocking by construction (see
    /// [`ProgressSink`]): a slow or absent consumer can never stall the
    /// iterate, and the solved `x` is bitwise identical with or without a
    /// sink. A solve with no such checkpoints (reference-error stopping or
    /// a fixed budget, `history_step = 0`) emits nothing — pair the sink
    /// with residual stopping or a history step.
    pub progress: Option<ProgressSink>,
    /// Cooperative cancellation/deadline token: when set, every
    /// [`StopCheck`]-driven loop polls it each iteration (the AsyRK monitor
    /// each poll) and halts — `converged = false`, no error, the partial
    /// iterate returned — as soon as the token reports a cancel or an
    /// elapsed deadline. The *reason* is recorded on the token
    /// ([`SolveControl::halted`]); the serving layer maps it onto the typed
    /// [`Error::Cancelled`](crate::error::Error::Cancelled) /
    /// [`Error::DeadlineExceeded`](crate::error::Error::DeadlineExceeded).
    /// Absent (the default) the solve pays nothing for the mechanism.
    pub control: Option<SolveControl>,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            stopping: StoppingCriterion::ReferenceError { tolerance: 1e-8 },
            max_iterations: 10_000_000,
            fixed_iterations: None,
            history_step: 0,
            divergence_factor: 1e6,
            progress: None,
            control: None,
        }
    }
}

impl SolveOptions {
    /// Set the stopping tolerance, keeping the current criterion kind.
    pub fn with_tolerance(mut self, tol: f64) -> Self {
        self.stopping = match self.stopping {
            StoppingCriterion::ReferenceError { .. } => {
                StoppingCriterion::ReferenceError { tolerance: tol }
            }
            StoppingCriterion::Residual { check_every, .. } => {
                StoppingCriterion::Residual { tolerance: tol, check_every }
            }
        };
        self
    }

    /// Stop on the squared residual `‖Ax - b‖² < tol`, evaluated every
    /// `check_every` iterations (the reference-free serving criterion).
    pub fn with_residual_stopping(mut self, tol: f64, check_every: usize) -> Self {
        assert!(check_every >= 1, "check_every must be >= 1");
        self.stopping = StoppingCriterion::Residual { tolerance: tol, check_every };
        self
    }

    /// The stopping tolerance (whichever criterion is active).
    pub fn tolerance(&self) -> f64 {
        self.stopping.tolerance()
    }

    /// Set the iteration cap.
    pub fn with_max_iterations(mut self, it: usize) -> Self {
        self.max_iterations = it;
        self
    }

    /// Run exactly `it` iterations (timing protocol).
    pub fn with_fixed_iterations(mut self, it: usize) -> Self {
        self.fixed_iterations = Some(it);
        self
    }

    /// Record history every `step` iterations.
    pub fn with_history_step(mut self, step: usize) -> Self {
        self.history_step = step;
        self
    }

    /// Stream live [`Sample`]s to `sink` at the solve's amortized
    /// checkpoints (see [`SolveOptions::progress`]).
    pub fn with_progress(mut self, sink: ProgressSink) -> Self {
        self.progress = Some(sink);
        self
    }

    /// Attach a cooperative cancellation/deadline token (see
    /// [`SolveOptions::control`]). Keep a clone of the token to cancel the
    /// job or to read why it halted.
    pub fn with_control(mut self, control: SolveControl) -> Self {
        self.control = Some(control);
        self
    }

    /// Would a solve under these options *require* the system's reference
    /// solution? True only when the convergence test measures against it:
    /// reference-error stopping outside the fixed-iteration protocol.
    /// History recording does **not** require one — histories are
    /// dual-channel, and on a reference-free system only the residual
    /// channel is recorded (the reference channel stays empty rather than
    /// panicking). Residual-stopped and fixed-iteration runs therefore
    /// never touch the reference regardless of `history_step`, so they are
    /// valid on systems that do not carry one. The batch layer validates
    /// jobs against this predicate so the two can never drift.
    pub fn consults_reference(&self) -> bool {
        self.fixed_iterations.is_none()
            && matches!(self.stopping, StoppingCriterion::ReferenceError { .. })
    }
}

/// Outcome of a solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    /// Final solution estimate.
    pub x: Vec<f64>,
    /// Iterations executed.
    pub iterations: usize,
    /// Whether the stopping criterion was met. Fixed-iteration runs never
    /// evaluate the criterion, so they always report `false` — the budget
    /// was spent as requested, nothing was measured. For a quality signal
    /// on such runs use residual stopping, or inspect the residual of the
    /// returned iterate.
    pub converged: bool,
    /// Whether divergence was detected.
    pub diverged: bool,
    /// Wall-clock seconds of the iteration loop only.
    pub seconds: f64,
    /// Total rows processed (iterations x workers x block for the block
    /// methods; equals `iterations` for RK/CK).
    pub rows_used: usize,
    /// Step-sampled convergence history (empty unless requested via
    /// `history_step`). Dual-channel: the residual channel is always
    /// recorded, the reference-error channel only when the system carries
    /// a reference solution — see [`History`].
    pub history: History,
}

/// A solver over a `LinearSystem`.
pub trait Solver {
    /// Human-readable name used in reports.
    fn name(&self) -> &'static str;
    /// Run the solver.
    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult;
}

/// Shared stopping-and-observability state for every solver inner loop.
///
/// One `StopCheck` lives per solve (per rank 0 / participant 0 in the
/// parallel and distributed engines) and owns everything the convergence
/// decision *and* the convergence curve need:
///
/// - the **lazy initial metric** — the divergence test compares against the
///   metric's value at `x^(0)`, but that value is only computed on the
///   *first evaluation*, so fixed-iteration runs (which never evaluate)
///   never touch the reference solution at all. This is what lets the batch
///   layer run reference-free jobs without patching in a dummy `x_ref`;
/// - the **residual scratch** — residual stopping *and* history recording
///   need `A x` (length `m`), computed through [`residual_gemv_into`] into a
///   buffer allocated once per solve, never per check;
/// - the **history recorder** — [`StopCheck::check`] records a
///   [`History`] sample whenever iteration `k` is due, so the eleven solve
///   loops share one recording implementation instead of open-coding it.
///   Recording is dual-channel: the residual channel always, the
///   reference-error channel only when the system carries a reference —
///   a reference-free history costs one amortized residual GEMV per
///   sample instead of an `error_sq` panic;
/// - the **telemetry stream** — when the options carry a
///   [`ProgressSink`], every checkpoint that computes the residual anyway
///   (history samples, residual stopping evaluations) also pushes a live
///   [`Sample`] to the sink, reusing the just-computed value: streaming
///   adds zero GEMVs, and the sink flavors are non-blocking by
///   construction, so the iterate sequence is bit-identical with or
///   without one.
///
/// Under [`StoppingCriterion::ReferenceError`] the decision sequence —
/// metric every iteration, tolerance then divergence then budget — is
/// exactly the pre-`StopCheck` behavior, bit for bit.
pub(crate) struct StopCheck<'a> {
    system: &'a LinearSystem,
    opts: &'a SolveOptions,
    /// Metric value at the first evaluation (the `x = 0` state), lazily
    /// filled; the divergence reference.
    initial: Option<f64>,
    /// `A x` scratch, shared by the residual criterion and the residual
    /// history channel (empty when neither is active).
    ax: Vec<f64>,
    /// The convergence curve recorded by [`StopCheck::check`] /
    /// [`StopCheck::record_sample`]; reclaimed via
    /// [`StopCheck::into_history`].
    history: History,
    /// Whether history samples carry the reference-error channel (decided
    /// once per solve: does the system have a reference solution?).
    record_reference: bool,
    /// Solve start time — the `elapsed` clock of streamed [`Sample`]s.
    start: std::time::Instant,
}

impl<'a> StopCheck<'a> {
    pub(crate) fn new(system: &'a LinearSystem, opts: &'a SolveOptions) -> Self {
        let needs_residual_metric = matches!(opts.stopping, StoppingCriterion::Residual { .. })
            && opts.fixed_iterations.is_none();
        let ax = if needs_residual_metric || opts.history_step != 0 {
            vec![0.0; system.rows()]
        } else {
            Vec::new()
        };
        StopCheck {
            system,
            opts,
            initial: None,
            ax,
            history: History::every(opts.history_step),
            record_reference: system.reference_solution().is_some(),
            start: std::time::Instant::now(),
        }
    }

    /// Will [`StopCheck::check`] at iteration `k` evaluate the convergence
    /// metric? False for every `k` in fixed-iteration runs; false between
    /// residual checkpoints. Note that `check` may still read the iterate
    /// on such iterations to record history — materializing callers should
    /// gate on [`StopCheck::needs_iterate_at`], which covers both.
    #[inline]
    pub(crate) fn evaluates_at(&self, k: usize) -> bool {
        if self.opts.fixed_iterations.is_some() {
            return false;
        }
        match self.opts.stopping {
            StoppingCriterion::ReferenceError { .. } => true,
            StoppingCriterion::Residual { check_every, .. } => k % check_every == 0,
        }
    }

    /// Will [`StopCheck::check`] at iteration `k` read the iterate at all —
    /// for the convergence metric *or* for a due history sample? Callers
    /// that must *materialize* the iterate before checking (the shared-
    /// memory engines snapshot atomics into a buffer) use this to skip the
    /// snapshot on iterations where `check` would not look at it.
    #[inline]
    pub(crate) fn needs_iterate_at(&self, k: usize) -> bool {
        self.history.due(k) || self.evaluates_at(k)
    }

    /// `‖Ax - b‖²` through the blocked GEMV and the per-solve scratch.
    ///
    /// Large systems split the GEMV's row range across the worker pool
    /// ([`residual_gemv_into`] — bitwise identical to the serial blocked
    /// kernel, and automatically serial when this check fires from inside
    /// an engine's own pool dispatch), so residual stopping and telemetry
    /// stay cheap at 100k x 10k scale.
    fn residual_sq(&mut self, x: &[f64]) -> f64 {
        debug_assert_eq!(self.ax.len(), self.system.rows(), "residual scratch not allocated");
        residual_gemv_into(&self.system.a, x, &mut self.ax);
        dist_sq(&self.ax, &self.system.b)
    }

    /// The squared stopping metric for iterate `x`.
    fn metric(&mut self, x: &[f64]) -> f64 {
        match self.opts.stopping {
            StoppingCriterion::ReferenceError { .. } => self.system.error_sq(x),
            StoppingCriterion::Residual { .. } => self.residual_sq(x),
        }
    }

    /// Record one history sample for iterate `x` at iteration `k`,
    /// regardless of cadence, returning the squared residual it computed
    /// (so a caller about to evaluate the residual *metric* on the same
    /// iterate can reuse it instead of paying the `O(m·n)` GEMV twice).
    /// [`StopCheck::check`] calls this on the `history_step` cadence; the
    /// AsyRK monitor — whose "iteration" is a racy global update count
    /// with no loop boundary — calls it directly on its own polling
    /// cadence.
    pub(crate) fn record_sample(&mut self, k: usize, x: &[f64]) -> f64 {
        let residual_sq = self.residual_sq(x);
        let error = if self.record_reference {
            Some(self.system.error_sq(x).sqrt())
        } else {
            None
        };
        self.history.record(k, error, residual_sq.sqrt());
        // The history sample doubles as a telemetry checkpoint: stream the
        // values just computed (no extra GEMV, no extra error_sq).
        if let Some(sink) = &self.opts.progress {
            sink.emit(Sample {
                k,
                residual: residual_sq.sqrt(),
                reference_err: error,
                elapsed: self.start.elapsed(),
            });
        }
        residual_sq
    }

    /// Stream a telemetry sample from a residual stopping checkpoint (the
    /// residual was just computed as the stopping metric; the reference
    /// error, when the system carries one, costs only `O(n)` on top).
    fn emit_checkpoint(&self, k: usize, residual_sq: f64, x: &[f64]) {
        if let Some(sink) = &self.opts.progress {
            let reference_err =
                if self.record_reference { Some(self.system.error_sq(x).sqrt()) } else { None };
            sink.emit(Sample {
                k,
                residual: residual_sq.sqrt(),
                reference_err,
                elapsed: self.start.elapsed(),
            });
        }
    }

    /// The recorded convergence curve (call once, after the solve loop).
    pub(crate) fn into_history(self) -> History {
        self.history
    }

    /// Full stopping decision at iteration `k`: `(stop, converged,
    /// diverged)`, recording a history sample first when `k` is due (so the
    /// stopping iteration's state is included in the curve). `x` is only
    /// read when [`StopCheck::needs_iterate_at`]`(k)` is true, so callers
    /// may pass a stale buffer on other iterations.
    pub(crate) fn check(&mut self, k: usize, x: &[f64]) -> (bool, bool, bool) {
        // Cooperative halt: a cancelled or past-deadline job stops at the
        // very next checkpoint, before paying another metric evaluation or
        // history GEMV. `converged` and `diverged` both stay false — the
        // run was interrupted, not measured; the reason lands on the token
        // (first-write-wins) for the serving layer to read.
        if self.halt_requested() {
            return (true, false, false);
        }
        let recorded_residual_sq = if self.history.due(k) {
            Some(self.record_sample(k, x))
        } else {
            None
        };
        if let Some(fixed) = self.opts.fixed_iterations {
            return (k >= fixed, false, false);
        }
        if self.evaluates_at(k) {
            let (converged, diverged) = self.check_now_reusing(k, x, recorded_residual_sq);
            if converged || diverged {
                return (true, converged, diverged);
            }
        }
        (k >= self.opts.max_iterations, false, false)
    }

    /// Poll the options' cancellation/deadline token, if any. `true` means
    /// the loop must halt now (the reason is recorded on the token).
    /// [`StopCheck::check`] consults this every call; the AsyRK monitor —
    /// which handles its own budget and never calls `check` — polls it
    /// directly in its monitoring loop.
    pub(crate) fn halt_requested(&self) -> bool {
        self.opts.control.as_ref().is_some_and(|c| c.poll().is_some())
    }

    /// Baseline evaluation at the true `x^(0)` (the AsyRK monitor, before
    /// its polling loop): pins the lazy initial metric and applies the
    /// tolerance/divergence decision like a poll would, but streams **no**
    /// telemetry — the first poll emits its own `k = 0` sample, and a
    /// baseline emission on the same iterate count would duplicate it,
    /// desyncing the stream from the recorded history.
    pub(crate) fn check_baseline(&mut self, x: &[f64]) -> (bool, bool) {
        let m = self.metric(x);
        self.decide(m)
    }

    /// Cadence-free convergence/divergence test with residual reuse:
    /// [`StopCheck::check`] runs it on its cadence, the AsyRK monitor
    /// (which has no iteration boundary to hang `check_every` off of, and
    /// handles the budget itself) runs it per poll with `k` set to its
    /// global update count. When the stopping metric *is* the residual and
    /// [`StopCheck::record_sample`] just computed it for this same
    /// iterate, the caller passes it back here and the O(m·n) GEMV is not
    /// paid a second time (bit-equal — same computation on the same `x`);
    /// it falls back to evaluating the metric in every other case.
    /// Residual evaluations double as telemetry checkpoints: a freshly
    /// computed residual metric is streamed to the progress sink (a reused
    /// one was already streamed by the history sample that computed it).
    pub(crate) fn check_now_reusing(
        &mut self,
        k: usize,
        x: &[f64],
        recorded_residual_sq: Option<f64>,
    ) -> (bool, bool) {
        let m = match (self.opts.stopping, recorded_residual_sq) {
            (StoppingCriterion::Residual { .. }, Some(r)) => r,
            _ => self.metric(x),
        };
        if recorded_residual_sq.is_none()
            && matches!(self.opts.stopping, StoppingCriterion::Residual { .. })
        {
            self.emit_checkpoint(k, m, x);
        }
        self.decide(m)
    }

    /// The single copy of the decision sequence — tolerance, then
    /// divergence — applied to an already-computed squared metric.
    fn decide(&mut self, m: f64) -> (bool, bool) {
        let initial = *self.initial.get_or_insert(m);
        if m < self.opts.tolerance() {
            return (true, false);
        }
        // A non-finite metric is divergence: between residual checkpoints
        // the iterate can blow straight past inf into NaN, and NaN compares
        // false against every threshold — without this test such a run
        // would spin out its whole iteration budget unflagged.
        if !m.is_finite() || (m > initial * self.opts.divergence_factor && initial > 0.0) {
            return (false, true);
        }
        (false, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    /// 2x2 identity system with `x* = [3, 4]`: error_sq(x) and
    /// residual_sq(x) are both `‖x - [3,4]‖²`, which makes the two
    /// criteria directly comparable in these unit tests.
    fn identity_system() -> LinearSystem {
        let a = Matrix::identity(2);
        LinearSystem::new(a, vec![3.0, 4.0], Some(vec![3.0, 4.0]), true)
    }

    #[test]
    fn fixed_iterations_stop_at_budget_without_converging() {
        let sys = identity_system();
        let opts = SolveOptions::default().with_fixed_iterations(10);
        let mut sc = StopCheck::new(&sys, &opts);
        // Not done yet, even at the exact solution (nothing is measured).
        assert_eq!(sc.check(5, &[3.0, 4.0]), (false, false, false));
        // At budget: stop, but converged stays false — nothing was measured.
        assert_eq!(sc.check(10, &[0.0, 0.0]), (true, false, false));
        // The metric (and thus the reference) was never touched.
        assert!(sc.initial.is_none());
    }

    #[test]
    fn reference_error_tolerance_decision() {
        let sys = identity_system();
        let opts = SolveOptions::default().with_tolerance(1e-4);
        let mut sc = StopCheck::new(&sys, &opts);
        assert!(sc.evaluates_at(0) && sc.evaluates_at(1));
        assert_eq!(sc.check(3, &[0.0, 0.0]), (false, false, false));
        assert_eq!(sc.check(4, &[3.0, 4.000001]), (true, true, false));
    }

    #[test]
    fn residual_tolerance_respects_check_every() {
        let sys = identity_system();
        let opts = SolveOptions::default().with_residual_stopping(1e-4, 8);
        let mut sc = StopCheck::new(&sys, &opts);
        assert!(sc.evaluates_at(0));
        assert!(!sc.evaluates_at(3));
        assert!(sc.evaluates_at(16));
        // Prime the initial metric at x = 0.
        assert_eq!(sc.check(0, &[0.0, 0.0]), (false, false, false));
        // Off-cadence: converged iterate is NOT noticed.
        assert_eq!(sc.check(3, &[3.0, 4.0]), (false, false, false));
        // On-cadence: it is.
        assert_eq!(sc.check(8, &[3.0, 4.0]), (true, true, false));
    }

    #[test]
    fn divergence_measured_against_lazy_initial_metric() {
        let sys = identity_system();
        let opts = SolveOptions { divergence_factor: 10.0, ..Default::default() };
        let mut sc = StopCheck::new(&sys, &opts);
        // First evaluation pins the initial metric: ‖0 - [3,4]‖² = 25.
        assert_eq!(sc.check(0, &[0.0, 0.0]), (false, false, false));
        assert_eq!(sc.initial, Some(25.0));
        // 10x the initial error => diverged.
        let far = [3.0 + 100.0, 4.0];
        let (stop, conv, div) = sc.check(3, &far);
        assert!(stop && !conv && div);
    }

    #[test]
    fn iteration_cap_stops_unconverged() {
        let sys = identity_system();
        let opts = SolveOptions::default().with_max_iterations(100);
        let mut sc = StopCheck::new(&sys, &opts);
        assert_eq!(sc.check(100, &[0.0, 0.0]), (true, false, false));
    }

    #[test]
    fn residual_and_reference_agree_on_identity_system() {
        // On the identity system the two metrics coincide, so the two
        // criteria must make identical decisions at equal tolerances.
        let sys = identity_system();
        let ref_opts = SolveOptions::default().with_tolerance(1e-4);
        let res_opts = SolveOptions::default().with_residual_stopping(1e-4, 1);
        for x in [[0.0, 0.0], [3.0, 4.01], [3.0, 4.0]] {
            let mut a = StopCheck::new(&sys, &ref_opts);
            let mut b = StopCheck::new(&sys, &res_opts);
            assert_eq!(a.check(1, &x), b.check(1, &x), "at {x:?}");
        }
    }

    #[test]
    fn consults_reference_predicate() {
        let reference = SolveOptions::default();
        assert!(reference.consults_reference());
        let fixed = SolveOptions::default().with_fixed_iterations(10);
        assert!(!fixed.consults_reference());
        // History no longer forces a reference: the curve is dual-channel
        // and degrades to residual-only on reference-free systems.
        let fixed_history = SolveOptions::default().with_fixed_iterations(10).with_history_step(2);
        assert!(!fixed_history.consults_reference());
        let residual = SolveOptions::default().with_residual_stopping(1e-8, 32);
        assert!(!residual.consults_reference());
        let residual_history = residual.with_history_step(5);
        assert!(!residual_history.consults_reference());
        // The only consulting shape: reference-error stopping, unfixed.
        assert!(SolveOptions::default().with_history_step(5).consults_reference());
    }

    #[test]
    fn check_records_history_on_cadence_including_the_stop_iteration() {
        let sys = identity_system();
        let opts = SolveOptions::default().with_fixed_iterations(10).with_history_step(5);
        let mut sc = StopCheck::new(&sys, &opts);
        for k in 0..=10 {
            let (stop, ..) = sc.check(k, &[1.0, 1.0]);
            assert_eq!(stop, k >= 10);
        }
        let h = sc.into_history();
        assert_eq!(h.iterations, vec![0, 5, 10]); // final state included
        // Referenced system: both channels populated, one entry per sample.
        assert_eq!(h.errors.len(), 3);
        assert_eq!(h.residuals.len(), 3);
        // Identity system: error and residual coincide (‖x - [3,4]‖).
        for (e, r) in h.errors.iter().zip(&h.residuals) {
            assert!((e - r).abs() < 1e-12);
        }
    }

    #[test]
    fn reference_free_history_records_residual_channel_only() {
        // No reference solution at all: error_sq would panic, so a clean
        // pass proves recording never touched it.
        let a = Matrix::identity(2);
        let sys = LinearSystem::new(a, vec![3.0, 4.0], None, true);
        let opts = SolveOptions::default()
            .with_residual_stopping(1e-9, 2)
            .with_history_step(2)
            .with_max_iterations(6);
        let mut sc = StopCheck::new(&sys, &opts);
        assert!(sc.needs_iterate_at(0));
        assert!(!sc.needs_iterate_at(1));
        for k in 0..=6 {
            if sc.check(k, &[0.0, 0.0]).0 {
                break;
            }
        }
        let h = sc.into_history();
        assert!(!h.has_reference_channel());
        assert_eq!(h.errors.len(), 0);
        assert_eq!(h.iterations, vec![0, 2, 4, 6]);
        assert!(h.residuals.iter().all(|r| (r - 5.0).abs() < 1e-12));
        assert_eq!(h.min_error(), Some(5.0)); // falls back to the residual channel
    }

    #[test]
    fn needs_iterate_covers_history_and_metric_cadence() {
        let sys = identity_system();
        let opts = SolveOptions::default().with_residual_stopping(1e-8, 8).with_history_step(6);
        let sc = StopCheck::new(&sys, &opts);
        assert!(sc.needs_iterate_at(0)); // both due
        assert!(sc.needs_iterate_at(6)); // history only
        assert!(sc.needs_iterate_at(8)); // metric only
        assert!(!sc.needs_iterate_at(5)); // neither
        // Fixed runs evaluate no metric but still record due samples.
        let fixed = SolveOptions::default().with_fixed_iterations(100).with_history_step(6);
        let sc = StopCheck::new(&sys, &fixed);
        assert!(!sc.evaluates_at(6));
        assert!(sc.needs_iterate_at(6));
        assert!(!sc.needs_iterate_at(5));
    }

    #[test]
    fn sink_streams_history_checkpoints_mid_solve() {
        let sys = identity_system();
        let (sink, rx) = crate::metrics::ProgressSink::bounded(16);
        let opts = SolveOptions::default()
            .with_fixed_iterations(10)
            .with_history_step(5)
            .with_progress(sink);
        let mut sc = StopCheck::new(&sys, &opts);
        for k in 0..=10 {
            sc.check(k, &[1.0, 1.0]);
        }
        let h = sc.into_history();
        let samples = rx.drain();
        // One streamed sample per recorded history sample, same k, same
        // residual value (the sink reuses the recorder's GEMV).
        assert_eq!(samples.len(), h.len());
        for (i, s) in samples.iter().enumerate() {
            assert_eq!(s.k, h.iterations[i]);
            assert_eq!(s.residual.to_bits(), h.residuals[i].to_bits());
            assert_eq!(s.reference_err.map(f64::to_bits), Some(h.errors[i].to_bits()));
        }
    }

    #[test]
    fn sink_streams_residual_stopping_checkpoints_without_history() {
        // No reference, no history: emission piggybacks on the residual
        // stopping metric alone (and never touches error_sq — the system
        // has none to touch).
        let a = Matrix::identity(2);
        let sys = LinearSystem::new(a, vec![3.0, 4.0], None, true);
        let (sink, rx) = crate::metrics::ProgressSink::bounded(16);
        let opts = SolveOptions::default()
            .with_residual_stopping(1e-9, 4)
            .with_max_iterations(8)
            .with_progress(sink);
        let mut sc = StopCheck::new(&sys, &opts);
        for k in 0..=8 {
            if sc.check(k, &[0.0, 0.0]).0 {
                break;
            }
        }
        let ks: Vec<usize> = rx.drain().iter().map(|s| s.k).collect();
        assert_eq!(ks, vec![0, 4, 8]); // exactly the check_every cadence
        // History recording stayed off: the sink is observability-only.
        assert!(sc.into_history().is_empty());
    }

    #[test]
    fn sink_does_not_double_emit_when_history_and_metric_coincide() {
        let sys = identity_system();
        let (sink, rx) = crate::metrics::ProgressSink::bounded(32);
        // history_step == check_every: every checkpoint is both.
        let opts = SolveOptions::default()
            .with_residual_stopping(1e-30, 4)
            .with_history_step(4)
            .with_max_iterations(8)
            .with_progress(sink);
        let mut sc = StopCheck::new(&sys, &opts);
        for k in 0..=8 {
            if sc.check(k, &[0.0, 0.0]).0 {
                break;
            }
        }
        let ks: Vec<usize> = rx.drain().iter().map(|s| s.k).collect();
        assert_eq!(ks, vec![0, 4, 8], "one sample per checkpoint, not two");
    }

    #[test]
    fn sink_emits_nothing_without_amortized_checkpoints() {
        // Reference-error stopping computes no residual, and with
        // history_step = 0 there is no other checkpoint: the sink stays
        // silent (documented behavior) rather than paying new GEMVs.
        let sys = identity_system();
        let (sink, rx) = crate::metrics::ProgressSink::bounded(8);
        let opts = SolveOptions::default().with_tolerance(1e-20).with_progress(sink);
        let mut sc = StopCheck::new(&sys, &opts);
        for k in 0..5 {
            sc.check(k, &[1.0, 1.0]);
        }
        assert!(rx.is_empty());
    }

    #[test]
    fn cancelled_control_halts_check_without_converging() {
        use crate::serve::{Halt, SolveControl};
        let sys = identity_system();
        let ctl = SolveControl::new();
        let opts = SolveOptions::default().with_tolerance(1e-20).with_control(ctl.clone());
        let mut sc = StopCheck::new(&sys, &opts);
        assert_eq!(sc.check(0, &[0.0, 0.0]), (false, false, false));
        ctl.cancel();
        // Halt at the very next checkpoint: stop, but neither converged nor
        // diverged — and the reason is recorded on the token.
        assert_eq!(sc.check(1, &[0.0, 0.0]), (true, false, false));
        assert_eq!(ctl.halted(), Some(Halt::Cancelled));
    }

    #[test]
    fn elapsed_deadline_halts_even_fixed_budget_runs() {
        use crate::serve::{Halt, SolveControl};
        let sys = identity_system();
        // Fixed-iteration runs evaluate no metric, but the control token is
        // still polled — a deadline can stop a timed run mid-budget.
        let ctl = SolveControl::with_deadline(std::time::Duration::ZERO);
        let opts = SolveOptions::default().with_fixed_iterations(1000).with_control(ctl.clone());
        let mut sc = StopCheck::new(&sys, &opts);
        assert_eq!(sc.check(3, &[0.0, 0.0]), (true, false, false));
        assert_eq!(ctl.halted(), Some(Halt::DeadlineExceeded));
        // Nothing was measured on the way out.
        assert!(sc.initial.is_none());
    }

    #[test]
    fn inert_control_changes_no_decision() {
        use crate::serve::SolveControl;
        let sys = identity_system();
        let plain = SolveOptions::default().with_tolerance(1e-4);
        let controlled = plain.clone().with_control(SolveControl::new());
        for x in [[0.0, 0.0], [3.0, 4.01], [3.0, 4.0]] {
            let mut a = StopCheck::new(&sys, &plain);
            let mut b = StopCheck::new(&sys, &controlled);
            assert_eq!(a.check(1, &x), b.check(1, &x), "at {x:?}");
        }
    }

    #[test]
    fn with_tolerance_keeps_criterion_kind() {
        let o = SolveOptions::default().with_residual_stopping(1e-2, 16).with_tolerance(1e-6);
        assert_eq!(o.stopping, StoppingCriterion::Residual { tolerance: 1e-6, check_every: 16 });
        assert_eq!(o.tolerance(), 1e-6);
        let o = SolveOptions::default().with_tolerance(1e-3);
        assert_eq!(o.stopping, StoppingCriterion::ReferenceError { tolerance: 1e-3 });
    }
}
