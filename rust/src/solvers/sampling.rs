//! Row-sampling schemes shared by RKA/RKAB (sequential and parallel).
//!
//! The paper compares two ways a worker can sample rows (§3.3.1, Table 1;
//! §3.4.2, Fig. 9):
//!
//! - **Full Matrix Access** — every worker samples from all `m` rows with
//!   the eq. 4 distribution (duplicate samples across workers possible);
//! - **Distributed Approach** — the rows are partitioned
//!   (`[⌊t·m/q⌋, ⌊(t+1)·m/q⌋)` for worker `t`) and each worker samples only
//!   from its own block, so workers never collide.
//!
//! Orthogonal to *where* a worker may sample is *how* rows are picked:
//! [`SamplingStrategy`] chooses between the paper's randomized eq.-4 rule
//! and greedy Motzkin max-residual selection ([`GreedySelector`]), which the
//! survey (Ferreira et al., arXiv 2401.02842) lists as the classic
//! deterministic alternative. Greedy selection needs the current iterate at
//! every draw, so only the sequential solvers support it — other engines
//! reject it up front through [`require_randomized`].

use crate::data::LinearSystem;
use crate::error::{Error, Result};
use crate::linalg::gemv_block_into;
use crate::rng::{derive_seed, AliasTable, Mt19937};

/// How workers pick rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplingScheme {
    /// Every worker samples from the whole matrix (may collide).
    FullMatrix,
    /// Worker `t` samples only from its row partition.
    Partitioned,
}

/// Row-*selection* rule, orthogonal to the [`SamplingScheme`] access
/// pattern: the paper's randomized eq.-4 rule, or greedy Motzkin
/// max-residual selection.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SamplingStrategy {
    /// Sample row `i` with probability `‖A^(i)‖² / ‖A‖²_F` (eq. 4).
    #[default]
    Randomized,
    /// Deterministically take the row(s) with the largest squared hyperplane
    /// distance at the current iterate (Motzkin's method). Each selection
    /// costs a full residual scan, but every projection then removes the
    /// worst constraint violation. Sequential RK/RKA/RKAB only — engines
    /// whose workers draw rows without the shared iterate reject it with
    /// [`Error::UnsupportedSampling`].
    Greedy,
}

/// Gate for engines that cannot run the greedy scan: `Ok` for
/// [`SamplingStrategy::Randomized`], [`Error::UnsupportedSampling`] naming
/// `engine` for [`SamplingStrategy::Greedy`].
pub fn require_randomized(engine: &str, strategy: SamplingStrategy) -> Result<()> {
    match strategy {
        SamplingStrategy::Randomized => Ok(()),
        SamplingStrategy::Greedy => Err(Error::UnsupportedSampling { engine: engine.to_string() }),
    }
}

/// Greedy Motzkin row selection (max-residual / maximal-distance rule):
/// scan every row's squared hyperplane distance
/// `(b_i - <A^(i), x>)² / ‖A^(i)‖²` at the current iterate and take the
/// largest. One selection costs an `O(m·n)` blocked GEMV — `m` times an
/// eq.-4 draw — but pays off on coherent or skewed-row-norm systems where
/// randomized sampling keeps revisiting near-satisfied rows.
///
/// The selector owns its scan scratch, so steady-state selection allocates
/// nothing, and it is fully deterministic: ties break toward the lowest row
/// index.
pub struct GreedySelector {
    ax: Vec<f64>,
    chosen: Vec<usize>,
    seen: Vec<bool>,
}

impl GreedySelector {
    /// Selector for `system` (allocates the length-`m` scan scratch).
    pub fn new(system: &LinearSystem) -> Self {
        GreedySelector {
            ax: vec![0.0; system.rows()],
            chosen: Vec::new(),
            seen: vec![false; system.rows()],
        }
    }

    /// The `k` distinct rows with the largest squared hyperplane distances
    /// at `x`, in non-increasing distance order (`k` is clamped to the row
    /// count; ties break toward the lower index).
    ///
    /// The argmax uses `total_cmp` (the crate's NaN-safe argmax
    /// convention, as in the autotune scorers and `History` scans): a NaN
    /// distance — a diverging iterate, or `0/0` on a zero row — is
    /// ordered deterministically instead of poisoning every comparison,
    /// so even an all-NaN scan selects a valid row (the lowest unchosen
    /// index) rather than fabricating an out-of-range one. Distances are
    /// `>= +0.0`, so the finite-case pick order is identical to the
    /// plain `>` argmax this replaces. Already-chosen rows are skipped
    /// via a reusable bitmap, so selecting `k` rows costs `O(k·m)`, not
    /// the `O(k²·m)` of rescanning the chosen list per candidate.
    ///
    /// The returned slice is valid until the next `select` call.
    pub fn select(&mut self, system: &LinearSystem, x: &[f64], k: usize) -> &[usize] {
        gemv_block_into(&system.a, x, &mut self.ax);
        let m = system.rows();
        self.chosen.clear();
        self.seen.clear();
        self.seen.resize(m, false);
        for _ in 0..k.min(m) {
            let mut best = usize::MAX;
            let mut best_d = f64::NEG_INFINITY;
            for i in 0..m {
                if self.seen[i] {
                    continue;
                }
                let r = system.b[i] - self.ax[i];
                let d = r * r / system.row_norms_sq[i];
                // The first unseen row always seeds the argmax, so `best`
                // is a valid index by the end of the scan no matter what
                // the distances are.
                if best == usize::MAX || d.total_cmp(&best_d) == std::cmp::Ordering::Greater {
                    best_d = d;
                    best = i;
                }
            }
            debug_assert!(best < m);
            self.seen[best] = true;
            self.chosen.push(best);
        }
        &self.chosen
    }

    /// The squared hyperplane distance of row `i` as of the last
    /// [`GreedySelector::select`] scan (diagnostics and property tests).
    pub fn last_distance_sq(&self, system: &LinearSystem, i: usize) -> f64 {
        let r = system.b[i] - self.ax[i];
        r * r / system.row_norms_sq[i]
    }
}

/// Pre-flight check for per-worker samplers: under [`SamplingScheme::Partitioned`]
/// every worker's row block must contain at least one positive-weight row,
/// otherwise that worker's `AliasTable` cannot be built (all-degenerate
/// block, or an empty block when `q` exceeds the row count).
///
/// Call this on the *caller's* thread before entering a parallel region:
/// the same condition failing inside a pool participant or a simulated
/// rank would strand its peers at a barrier/recv instead of panicking
/// cleanly.
pub fn assert_partitions_sampleable(system: &LinearSystem, scheme: SamplingScheme, q: usize) {
    if scheme != SamplingScheme::Partitioned {
        return;
    }
    for t in 0..q {
        let (lo, hi) = system.row_partition(t, q);
        assert!(
            system.sampling_weights()[lo..hi].iter().any(|&w| w > 0.0),
            "partitioned sampling: worker {t}'s row block [{lo}, {hi}) has no \
             positive-weight rows (degenerate or empty partition)"
        );
    }
}

/// A per-worker row sampler: owns the worker's RNG stream and its (possibly
/// restricted) sampling distribution; yields *global* row indices.
pub struct RowSampler {
    rng: Mt19937,
    dist: AliasTable,
    offset: usize,
}

impl RowSampler {
    /// Sampler for worker `t` of `q` under `scheme`, seeded from `base_seed`
    /// (each worker gets a distinct derived stream, as the paper requires).
    pub fn new(
        system: &LinearSystem,
        scheme: SamplingScheme,
        t: usize,
        q: usize,
        base_seed: u32,
    ) -> Self {
        let rng = Mt19937::new(derive_seed(base_seed, t));
        match scheme {
            SamplingScheme::FullMatrix => RowSampler {
                rng,
                dist: AliasTable::new(system.sampling_weights()),
                offset: 0,
            },
            SamplingScheme::Partitioned => {
                let (lo, hi) = system.row_partition(t, q);
                RowSampler {
                    rng,
                    dist: AliasTable::new(&system.sampling_weights()[lo..hi]),
                    offset: lo,
                }
            }
        }
    }

    /// Draw a global row index.
    #[inline]
    pub fn sample(&mut self) -> usize {
        self.offset + self.dist.sample(&mut self.rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;

    #[test]
    fn full_matrix_covers_all_rows() {
        let sys = DatasetBuilder::new(50, 4).seed(1).consistent();
        let mut s = RowSampler::new(&sys, SamplingScheme::FullMatrix, 0, 4, 7);
        let mut seen = vec![false; 50];
        for _ in 0..5000 {
            seen[s.sample()] = true;
        }
        assert!(seen.iter().filter(|&&b| b).count() > 45);
    }

    #[test]
    fn partitioned_stays_in_partition() {
        let sys = DatasetBuilder::new(50, 4).seed(1).consistent();
        for t in 0..4 {
            let (lo, hi) = sys.row_partition(t, 4);
            let mut s = RowSampler::new(&sys, SamplingScheme::Partitioned, t, 4, 7);
            for _ in 0..1000 {
                let i = s.sample();
                assert!(i >= lo && i < hi, "worker {t} sampled {i} outside [{lo},{hi})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "no positive-weight rows")]
    fn partitioned_preflight_rejects_degenerate_partition() {
        // Worker 0's whole block [0, 4) is zero rows: the pre-flight must
        // fail cleanly on the caller's thread (a panic inside a parallel
        // region would strand the other participants at their barrier).
        let mut sys = DatasetBuilder::new(8, 3).seed(4).consistent();
        for i in 0..4 {
            sys.a.row_mut(i).fill(0.0);
            sys.b[i] = 0.0;
        }
        let sys = crate::data::LinearSystem::new(sys.a, sys.b, sys.x_true, true);
        assert_partitions_sampleable(&sys, SamplingScheme::Partitioned, 2);
    }

    #[test]
    fn preflight_accepts_full_matrix_and_healthy_partitions() {
        let sys = DatasetBuilder::new(50, 4).seed(1).consistent();
        assert_partitions_sampleable(&sys, SamplingScheme::Partitioned, 4);
        // FullMatrix never restricts, so even q > m is fine.
        assert_partitions_sampleable(&sys, SamplingScheme::FullMatrix, 100);
    }

    #[test]
    fn greedy_selector_takes_most_violated_rows_in_order() {
        let sys = DatasetBuilder::new(30, 5).seed(6).consistent();
        let x = vec![0.0; 5];
        let mut g = GreedySelector::new(&sys);
        let chosen: Vec<usize> = g.select(&sys, &x, 3).to_vec();
        assert_eq!(chosen.len(), 3);
        // Oracle: rank all rows by distance at x = 0, i.e. b_i² / ‖A^(i)‖².
        let mut ranked: Vec<(f64, usize)> = (0..30)
            .map(|i| (sys.b[i] * sys.b[i] / sys.row_norms_sq[i], i))
            .collect();
        ranked.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let expect: Vec<usize> = ranked[..3].iter().map(|&(_, i)| i).collect();
        assert_eq!(chosen, expect, "top-3 by squared distance, descending");
        // Distances must be reportable and non-increasing along the pick.
        let d: Vec<f64> = chosen.iter().map(|&i| g.last_distance_sq(&sys, i)).collect();
        assert!(d[0] >= d[1] && d[1] >= d[2]);
    }

    #[test]
    fn greedy_selector_survives_nan_iterate() {
        // Regression: a diverging iterate (e.g. an asyrk overshoot feeding
        // a later sequential greedy solve) makes every hyperplane distance
        // NaN. The old `d > best_d` argmax never fired on NaN and pushed
        // its usize::MAX sentinel as a row index — an out-of-bounds panic
        // deep inside the solve loop. The total_cmp argmax must keep
        // returning valid, distinct rows.
        let sys = DatasetBuilder::new(12, 4).seed(3).consistent();
        let x_nan = vec![f64::NAN; 4];
        let mut g = GreedySelector::new(&sys);
        let chosen: Vec<usize> = g.select(&sys, &x_nan, 5).to_vec();
        assert_eq!(chosen.len(), 5);
        let mut sorted = chosen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 5, "rows must be distinct: {chosen:?}");
        assert!(chosen.iter().all(|&i| i < 12), "all indices in range: {chosen:?}");
        // All-NaN ties break toward the lowest unchosen index, so the
        // pick order is fully deterministic.
        assert_eq!(chosen, vec![0, 1, 2, 3, 4]);
        // The selector must remain usable after the poisoned scan: a
        // healthy iterate on the same selector picks finite rows again.
        let healthy: Vec<usize> = g.select(&sys, &[0.0; 4], 2).to_vec();
        assert_eq!(healthy.len(), 2);
        assert!(g.last_distance_sq(&sys, healthy[0]).is_finite());
    }

    #[test]
    fn greedy_selector_clamps_k_to_row_count() {
        let sys = DatasetBuilder::new(4, 3).seed(6).consistent();
        let mut g = GreedySelector::new(&sys);
        let chosen = g.select(&sys, &[0.0; 3], 99);
        assert_eq!(chosen.len(), 4);
        let mut sorted = chosen.to_vec();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3], "k > m returns each row once");
    }

    #[test]
    fn require_randomized_gates_greedy_only() {
        assert!(require_randomized("rka-par", SamplingStrategy::Randomized).is_ok());
        let err = require_randomized("rka-par", SamplingStrategy::Greedy).unwrap_err();
        assert!(matches!(err, Error::UnsupportedSampling { ref engine } if engine == "rka-par"));
        assert_eq!(SamplingStrategy::default(), SamplingStrategy::Randomized);
    }

    #[test]
    fn workers_have_distinct_streams() {
        let sys = DatasetBuilder::new(100, 4).seed(2).consistent();
        let mut a = RowSampler::new(&sys, SamplingScheme::FullMatrix, 0, 2, 9);
        let mut b = RowSampler::new(&sys, SamplingScheme::FullMatrix, 1, 2, 9);
        let same = (0..200).filter(|_| a.sample() == b.sample()).count();
        assert!(same < 50, "streams look identical: {same}/200 equal");
    }
}
