//! Row-sampling schemes shared by RKA/RKAB (sequential and parallel).
//!
//! The paper compares two ways a worker can sample rows (§3.3.1, Table 1;
//! §3.4.2, Fig. 9):
//!
//! - **Full Matrix Access** — every worker samples from all `m` rows with
//!   the eq. 4 distribution (duplicate samples across workers possible);
//! - **Distributed Approach** — the rows are partitioned
//!   (`[⌊t·m/q⌋, ⌊(t+1)·m/q⌋)` for worker `t`) and each worker samples only
//!   from its own block, so workers never collide.

use crate::data::LinearSystem;
use crate::rng::{derive_seed, AliasTable, Mt19937};

/// How workers pick rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplingScheme {
    /// Every worker samples from the whole matrix (may collide).
    FullMatrix,
    /// Worker `t` samples only from its row partition.
    Partitioned,
}

/// Pre-flight check for per-worker samplers: under [`SamplingScheme::Partitioned`]
/// every worker's row block must contain at least one positive-weight row,
/// otherwise that worker's `AliasTable` cannot be built (all-degenerate
/// block, or an empty block when `q` exceeds the row count).
///
/// Call this on the *caller's* thread before entering a parallel region:
/// the same condition failing inside a pool participant or a simulated
/// rank would strand its peers at a barrier/recv instead of panicking
/// cleanly.
pub fn assert_partitions_sampleable(system: &LinearSystem, scheme: SamplingScheme, q: usize) {
    if scheme != SamplingScheme::Partitioned {
        return;
    }
    for t in 0..q {
        let (lo, hi) = system.row_partition(t, q);
        assert!(
            system.sampling_weights()[lo..hi].iter().any(|&w| w > 0.0),
            "partitioned sampling: worker {t}'s row block [{lo}, {hi}) has no \
             positive-weight rows (degenerate or empty partition)"
        );
    }
}

/// A per-worker row sampler: owns the worker's RNG stream and its (possibly
/// restricted) sampling distribution; yields *global* row indices.
pub struct RowSampler {
    rng: Mt19937,
    dist: AliasTable,
    offset: usize,
}

impl RowSampler {
    /// Sampler for worker `t` of `q` under `scheme`, seeded from `base_seed`
    /// (each worker gets a distinct derived stream, as the paper requires).
    pub fn new(
        system: &LinearSystem,
        scheme: SamplingScheme,
        t: usize,
        q: usize,
        base_seed: u32,
    ) -> Self {
        let rng = Mt19937::new(derive_seed(base_seed, t));
        match scheme {
            SamplingScheme::FullMatrix => RowSampler {
                rng,
                dist: AliasTable::new(system.sampling_weights()),
                offset: 0,
            },
            SamplingScheme::Partitioned => {
                let (lo, hi) = system.row_partition(t, q);
                RowSampler {
                    rng,
                    dist: AliasTable::new(&system.sampling_weights()[lo..hi]),
                    offset: lo,
                }
            }
        }
    }

    /// Draw a global row index.
    #[inline]
    pub fn sample(&mut self) -> usize {
        self.offset + self.dist.sample(&mut self.rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;

    #[test]
    fn full_matrix_covers_all_rows() {
        let sys = DatasetBuilder::new(50, 4).seed(1).consistent();
        let mut s = RowSampler::new(&sys, SamplingScheme::FullMatrix, 0, 4, 7);
        let mut seen = vec![false; 50];
        for _ in 0..5000 {
            seen[s.sample()] = true;
        }
        assert!(seen.iter().filter(|&&b| b).count() > 45);
    }

    #[test]
    fn partitioned_stays_in_partition() {
        let sys = DatasetBuilder::new(50, 4).seed(1).consistent();
        for t in 0..4 {
            let (lo, hi) = sys.row_partition(t, 4);
            let mut s = RowSampler::new(&sys, SamplingScheme::Partitioned, t, 4, 7);
            for _ in 0..1000 {
                let i = s.sample();
                assert!(i >= lo && i < hi, "worker {t} sampled {i} outside [{lo},{hi})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "no positive-weight rows")]
    fn partitioned_preflight_rejects_degenerate_partition() {
        // Worker 0's whole block [0, 4) is zero rows: the pre-flight must
        // fail cleanly on the caller's thread (a panic inside a parallel
        // region would strand the other participants at their barrier).
        let mut sys = DatasetBuilder::new(8, 3).seed(4).consistent();
        for i in 0..4 {
            sys.a.row_mut(i).fill(0.0);
            sys.b[i] = 0.0;
        }
        let sys = crate::data::LinearSystem::new(sys.a, sys.b, sys.x_true, true);
        assert_partitions_sampleable(&sys, SamplingScheme::Partitioned, 2);
    }

    #[test]
    fn preflight_accepts_full_matrix_and_healthy_partitions() {
        let sys = DatasetBuilder::new(50, 4).seed(1).consistent();
        assert_partitions_sampleable(&sys, SamplingScheme::Partitioned, 4);
        // FullMatrix never restricts, so even q > m is fine.
        assert_partitions_sampleable(&sys, SamplingScheme::FullMatrix, 100);
    }

    #[test]
    fn workers_have_distinct_streams() {
        let sys = DatasetBuilder::new(100, 4).seed(2).consistent();
        let mut a = RowSampler::new(&sys, SamplingScheme::FullMatrix, 0, 2, 9);
        let mut b = RowSampler::new(&sys, SamplingScheme::FullMatrix, 1, 2, 9);
        let same = (0..200).filter(|_| a.sample() == b.sample()).count();
        assert!(same < 50, "streams look identical: {same}/200 equal");
    }
}
