//! Randomized Kaczmarz with Averaging (Moorman et al. 2021) — sequential
//! semantics of the paper's Algorithm 1 / eq. 7:
//!
//! ```text
//! x^(k+1) = x^(k) + (alpha/q) Σ_{i ∈ τ_k}  (b_i - <A^(i), x^(k)>)/‖A^(i)‖²  A^(i)ᵀ
//! ```
//!
//! Each of the `q` (virtual) workers samples one row per iteration from its
//! own RNG stream; all projections use the *previous* iterate (that is what
//! `x^(prev)` in Algorithm 1 enforces) and are then averaged. This module is
//! the semantic reference: `parallel::rka_shared` and `distributed::rka_dist`
//! must produce exactly the same iterates given the same seeds.
//!
//! With `q = 1` this is exactly RK.

use super::sampling::{RowSampler, SamplingScheme};
use super::{SolveOptions, SolveResult, Solver, StopCheck};
use crate::data::LinearSystem;
use crate::linalg::vector::axpy;
use crate::metrics::Stopwatch;

/// Per-worker relaxation weights.
#[derive(Clone, Debug)]
pub enum Weights {
    /// One uniform `alpha` for all workers (the paper's main setting).
    Uniform(f64),
    /// A distinct `alpha` per worker — the partial-matrix variant of §3.3.1.
    PerWorker(Vec<f64>),
}

impl Weights {
    /// Weight for worker `t`.
    #[inline]
    pub fn get(&self, t: usize) -> f64 {
        match self {
            Weights::Uniform(a) => *a,
            Weights::PerWorker(v) => v[t],
        }
    }

    /// Number of per-worker entries (None for uniform).
    pub fn len(&self) -> Option<usize> {
        match self {
            Weights::Uniform(_) => None,
            Weights::PerWorker(v) => Some(v.len()),
        }
    }

    /// True when there are zero per-worker entries (uniform weights always
    /// apply to every worker, so they count as non-empty).
    pub fn is_empty(&self) -> bool {
        matches!(self, Weights::PerWorker(v) if v.is_empty())
    }
}

/// RKA with `q` virtual workers (sequential reference implementation).
pub struct RkaSolver {
    /// Base RNG seed; worker `t` uses `derive_seed(seed, t)`.
    pub seed: u32,
    /// Number of averaged updates per iteration (`q` in eq. 7).
    pub q: usize,
    /// Row weights (uniform `alpha` or per-worker).
    pub weights: Weights,
    /// Row-sampling scheme (Full Matrix Access vs Distributed Approach).
    pub scheme: SamplingScheme,
}

impl RkaSolver {
    /// RKA with uniform weights and full-matrix sampling.
    pub fn new(seed: u32, q: usize, alpha: f64) -> Self {
        assert!(q >= 1, "q must be >= 1");
        RkaSolver { seed, q, weights: Weights::Uniform(alpha), scheme: SamplingScheme::FullMatrix }
    }

    /// Override the sampling scheme.
    pub fn with_scheme(mut self, scheme: SamplingScheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Use per-worker weights (partial-matrix alphas).
    pub fn with_weights(mut self, weights: Weights) -> Self {
        if let Some(len) = weights.len() {
            assert_eq!(len, self.q, "need one weight per worker");
        }
        self.weights = weights;
        self
    }
}

impl Solver for RkaSolver {
    fn name(&self) -> &'static str {
        "RKA"
    }

    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult {
        let n = system.cols();
        let q = self.q;
        let mut x = vec![0.0; n];
        let mut delta = vec![0.0; n]; // accumulated averaged update
        let mut samplers: Vec<RowSampler> = (0..q)
            .map(|t| RowSampler::new(system, self.scheme, t, q, self.seed))
            .collect();
        // Stopping decisions and history recording both live in StopCheck.
        let mut stopper = StopCheck::new(system, opts);

        let sw = Stopwatch::start();
        let mut k = 0usize;
        let (mut converged, mut diverged);
        loop {
            let (stop, c, d) = stopper.check(k, &x);
            converged = c;
            diverged = d;
            if stop {
                break;
            }
            // All q projections against the same x^(k) (the x^(prev) rule).
            delta.fill(0.0);
            for (t, sampler) in samplers.iter_mut().enumerate() {
                let i = sampler.sample();
                let scale = self.weights.get(t) * (system.b[i] - system.a.row_dot(i, &x))
                    / (q as f64 * system.row_norms_sq[i]);
                system.a.row_axpy(i, scale, &mut delta);
            }
            axpy(1.0, &delta, &mut x);
            k += 1;
        }

        SolveResult {
            x,
            iterations: k,
            converged,
            diverged,
            seconds: sw.seconds(),
            rows_used: k * q,
            history: stopper.into_history(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::solvers::rk::RkSolver;

    #[test]
    fn converges_with_unit_alpha() {
        let sys = DatasetBuilder::new(200, 10).seed(1).consistent();
        let r = RkaSolver::new(3, 4, 1.0).solve(&sys, &SolveOptions::default());
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-8);
        assert_eq!(r.rows_used, r.iterations * 4);
    }

    #[test]
    fn more_workers_fewer_iterations() {
        // Fig. 4a: iterations decrease with q. The effect is strongest for
        // well-overdetermined systems (the paper's are 5:1 to 40:1), so use a
        // 20:1 aspect ratio and average over seeds to beat sampling noise.
        let sys = DatasetBuilder::new(2000, 100).seed(2).consistent();
        let opts = SolveOptions::default().with_tolerance(1e-8);
        let avg = |q: usize| -> f64 {
            (0..3)
                .map(|s| RkaSolver::new(s, q, 1.0).solve(&sys, &opts).iterations)
                .sum::<usize>() as f64
                / 3.0
        };
        let i1 = avg(1);
        let i8 = avg(8);
        assert!(i8 < 0.9 * i1, "q=8 took {i8} vs q=1 {i1}");
    }

    #[test]
    fn optimal_alpha_beats_unit_alpha() {
        // Fig. 5a vs 4a: alpha* reduces iterations much more than alpha = 1.
        let sys = DatasetBuilder::new(400, 20).seed(3).consistent();
        let opts = SolveOptions::default().with_tolerance(1e-8);
        let (astar, _) = crate::solvers::alpha::full_matrix_alpha(&sys, 8).unwrap();
        let unit = RkaSolver::new(5, 8, 1.0).solve(&sys, &opts).iterations;
        let opt = RkaSolver::new(5, 8, astar).solve(&sys, &opts).iterations;
        assert!(opt < unit, "alpha* {opt} vs alpha=1 {unit}");
    }

    #[test]
    fn q1_matches_rk_exactly() {
        // "Note that, if q = 1, we recover the RK method."
        let sys = DatasetBuilder::new(100, 8).seed(4).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(500);
        let rka = RkaSolver::new(9, 1, 1.0).solve(&sys, &opts);
        // RK with the same derived stream:
        let rk = RkSolver { seed: crate::rng::derive_seed(9, 0), relaxation: 1.0 }
            .solve(&sys, &opts);
        for (a, b) in rka.x.iter().zip(&rk.x) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn partitioned_sampling_converges_too() {
        let sys = DatasetBuilder::new(200, 10).seed(6).consistent();
        let r = RkaSolver::new(3, 4, 1.0)
            .with_scheme(SamplingScheme::Partitioned)
            .solve(&sys, &SolveOptions::default());
        assert!(r.converged);
    }

    #[test]
    fn per_worker_weights_converge() {
        let sys = DatasetBuilder::new(200, 10).seed(7).consistent();
        let (alphas, _) = crate::solvers::alpha::partial_matrix_alphas(&sys, 4).unwrap();
        let r = RkaSolver::new(3, 4, 1.0)
            .with_weights(Weights::PerWorker(alphas))
            .solve(&sys, &SolveOptions::default());
        assert!(r.converged);
    }

    #[test]
    fn reduces_horizon_on_inconsistent_systems() {
        // §3.5 / Fig. 12: larger q ⇒ lower error plateau vs x_LS.
        let mut sys = DatasetBuilder::new(400, 10).seed(8).inconsistent();
        crate::solvers::cgls::attach_least_squares(&mut sys, 1e-12, 5000).unwrap();
        let opts = SolveOptions::default()
            .with_fixed_iterations(20_000)
            .with_history_step(500);
        let h1 = RkaSolver::new(2, 1, 1.0).solve(&sys, &opts).history;
        let h20 = RkaSolver::new(2, 20, 1.0).solve(&sys, &opts).history;
        let tail1 = h1.tail_error(10).unwrap();
        let tail20 = h20.tail_error(10).unwrap();
        assert!(
            tail20 < tail1 / 2.0,
            "horizon q=20 ({tail20:.3e}) should be well below q=1 ({tail1:.3e})"
        );
    }
}
