//! Randomized Kaczmarz with Averaging (Moorman et al. 2021) — sequential
//! semantics of the paper's Algorithm 1 / eq. 7:
//!
//! ```text
//! x^(k+1) = x^(k) + (alpha/q) Σ_{i ∈ τ_k}  (b_i - <A^(i), x^(k)>)/‖A^(i)‖²  A^(i)ᵀ
//! ```
//!
//! Each of the `q` (virtual) workers samples one row per iteration from its
//! own RNG stream; all projections use the *previous* iterate (that is what
//! `x^(prev)` in Algorithm 1 enforces) and are then averaged. This module is
//! the semantic reference: `parallel::rka_shared` and `distributed::rka_dist`
//! must produce exactly the same iterates given the same seeds.
//!
//! With `q = 1` this is exactly RK.

use super::sampling::{GreedySelector, RowSampler, SamplingScheme, SamplingStrategy};
use super::{SolveOptions, SolveResult, Solver, StopCheck};
use crate::data::LinearSystem;
use crate::linalg::vector::axpy;
use crate::metrics::Stopwatch;

/// Per-worker relaxation weights.
///
/// ```
/// use kaczmarz::data::DatasetBuilder;
/// use kaczmarz::solvers::rka::{RkaSolver, Weights};
/// use kaczmarz::solvers::{SolveOptions, Solver};
///
/// let sys = DatasetBuilder::new(150, 8).seed(1).consistent();
/// // Moorman-style inverse-row-norm weighting: each iteration's averaged
/// // step leans toward the sampled rows with the smallest norms.
/// let r = RkaSolver::new(5, 4, 1.0)
///     .with_weights(Weights::InverseRowNorm(1.0))
///     .solve(&sys, &SolveOptions::default());
/// assert!(r.converged);
/// ```
#[derive(Clone, Debug)]
pub enum Weights {
    /// One uniform `alpha` for all workers (the paper's main setting).
    Uniform(f64),
    /// A distinct `alpha` per worker — the partial-matrix variant of §3.3.1.
    PerWorker(Vec<f64>),
    /// Moorman et al.'s heterogeneous averaging (arXiv 2002.04126 §3):
    /// worker `t`'s update gets weight `λ_t ∝ 1/‖A^(i_t)‖²` over the rows
    /// sampled *this iteration*, normalized so `Σ λ_t = 1`; the carried
    /// `f64` is the overall relaxation `alpha` multiplying the combination.
    /// Sequential RKA/RKAB only — the normalization needs every worker's
    /// sampled row, which the parallel/distributed engines never share.
    InverseRowNorm(f64),
}

impl Weights {
    /// Weight for worker `t`. For [`Weights::InverseRowNorm`] this is the
    /// base `alpha`; the per-draw `λ_t` factor is applied at the update site
    /// where the sampled rows are known.
    #[inline]
    pub fn get(&self, t: usize) -> f64 {
        match self {
            Weights::Uniform(a) => *a,
            Weights::PerWorker(v) => v[t],
            Weights::InverseRowNorm(a) => *a,
        }
    }

    /// Number of per-worker entries (None for uniform and inverse-row-norm
    /// weights, which apply to any worker count).
    pub fn len(&self) -> Option<usize> {
        match self {
            Weights::Uniform(_) | Weights::InverseRowNorm(_) => None,
            Weights::PerWorker(v) => Some(v.len()),
        }
    }

    /// True when there are zero per-worker entries (uniform weights always
    /// apply to every worker, so they count as non-empty).
    pub fn is_empty(&self) -> bool {
        matches!(self, Weights::PerWorker(v) if v.is_empty())
    }
}

/// RKA with `q` virtual workers (sequential reference implementation).
pub struct RkaSolver {
    /// Base RNG seed; worker `t` uses `derive_seed(seed, t)`.
    pub seed: u32,
    /// Number of averaged updates per iteration (`q` in eq. 7).
    pub q: usize,
    /// Row weights (uniform `alpha`, per-worker, or inverse-row-norm).
    pub weights: Weights,
    /// Row-sampling scheme (Full Matrix Access vs Distributed Approach).
    pub scheme: SamplingScheme,
    /// Row-selection rule (randomized eq. 4 by default, or greedy Motzkin).
    pub sampling: SamplingStrategy,
}

impl RkaSolver {
    /// RKA with uniform weights and full-matrix randomized sampling.
    pub fn new(seed: u32, q: usize, alpha: f64) -> Self {
        assert!(q >= 1, "q must be >= 1");
        RkaSolver {
            seed,
            q,
            weights: Weights::Uniform(alpha),
            scheme: SamplingScheme::FullMatrix,
            sampling: SamplingStrategy::default(),
        }
    }

    /// Override the sampling scheme.
    pub fn with_scheme(mut self, scheme: SamplingScheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Use per-worker weights (partial-matrix alphas) or inverse-row-norm
    /// averaging.
    pub fn with_weights(mut self, weights: Weights) -> Self {
        if let Some(len) = weights.len() {
            assert_eq!(len, self.q, "need one weight per worker");
        }
        self.weights = weights;
        self
    }

    /// Override the row-selection rule. Under
    /// [`SamplingStrategy::Greedy`] each iteration projects against the `q`
    /// *most violated* distinct rows at `x^(k)` instead of `q` random draws
    /// (deterministic; the sampling scheme and seed become irrelevant).
    pub fn with_sampling(mut self, sampling: SamplingStrategy) -> Self {
        self.sampling = sampling;
        self
    }
}

impl Solver for RkaSolver {
    fn name(&self) -> &'static str {
        "RKA"
    }

    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult {
        let n = system.cols();
        let q = self.q;
        let mut x = vec![0.0; n];
        let mut delta = vec![0.0; n]; // accumulated averaged update
        let mut samplers: Vec<RowSampler> = (0..q)
            .map(|t| RowSampler::new(system, self.scheme, t, q, self.seed))
            .collect();
        let mut greedy =
            (self.sampling == SamplingStrategy::Greedy).then(|| GreedySelector::new(system));
        let mut rows: Vec<usize> = Vec::with_capacity(q);
        // Stopping decisions and history recording both live in StopCheck.
        let mut stopper = StopCheck::new(system, opts);

        let sw = Stopwatch::start();
        let mut k = 0usize;
        let (mut converged, mut diverged);
        loop {
            let (stop, c, d) = stopper.check(k, &x);
            converged = c;
            diverged = d;
            if stop {
                break;
            }
            // Pick this iteration's q rows up front (all projections use the
            // same x^(k) — the x^(prev) rule — so draw order is irrelevant).
            rows.clear();
            match greedy.as_mut() {
                Some(g) => rows.extend_from_slice(g.select(system, &x, q)),
                None => rows.extend(samplers.iter_mut().map(RowSampler::sample)),
            }
            delta.fill(0.0);
            match &self.weights {
                Weights::InverseRowNorm(alpha) => {
                    // λ_t = (1/‖A^(i_t)‖²) / Σ_s (1/‖A^(i_s)‖²): the scale
                    // folds λ_t into the usual residual/norm projection.
                    let inv_sum: f64 =
                        rows.iter().map(|&i| 1.0 / system.row_norms_sq[i]).sum();
                    for &i in &rows {
                        let lambda = 1.0 / (system.row_norms_sq[i] * inv_sum);
                        let scale = alpha * lambda * (system.b[i] - system.a.row_dot(i, &x))
                            / system.row_norms_sq[i];
                        system.a.row_axpy(i, scale, &mut delta);
                    }
                }
                _ => {
                    for (t, &i) in rows.iter().enumerate() {
                        let scale = self.weights.get(t) * (system.b[i] - system.a.row_dot(i, &x))
                            / (q as f64 * system.row_norms_sq[i]);
                        system.a.row_axpy(i, scale, &mut delta);
                    }
                }
            }
            axpy(1.0, &delta, &mut x);
            k += 1;
        }

        SolveResult {
            x,
            iterations: k,
            converged,
            diverged,
            seconds: sw.seconds(),
            rows_used: k * q,
            history: stopper.into_history(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::solvers::rk::RkSolver;

    #[test]
    fn converges_with_unit_alpha() {
        let sys = DatasetBuilder::new(200, 10).seed(1).consistent();
        let r = RkaSolver::new(3, 4, 1.0).solve(&sys, &SolveOptions::default());
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-8);
        assert_eq!(r.rows_used, r.iterations * 4);
    }

    #[test]
    fn more_workers_fewer_iterations() {
        // Fig. 4a: iterations decrease with q. The effect is strongest for
        // well-overdetermined systems (the paper's are 5:1 to 40:1), so use a
        // 20:1 aspect ratio and average over seeds to beat sampling noise.
        let sys = DatasetBuilder::new(2000, 100).seed(2).consistent();
        let opts = SolveOptions::default().with_tolerance(1e-8);
        let avg = |q: usize| -> f64 {
            (0..3)
                .map(|s| RkaSolver::new(s, q, 1.0).solve(&sys, &opts).iterations)
                .sum::<usize>() as f64
                / 3.0
        };
        let i1 = avg(1);
        let i8 = avg(8);
        assert!(i8 < 0.9 * i1, "q=8 took {i8} vs q=1 {i1}");
    }

    #[test]
    fn optimal_alpha_beats_unit_alpha() {
        // Fig. 5a vs 4a: alpha* reduces iterations much more than alpha = 1.
        let sys = DatasetBuilder::new(400, 20).seed(3).consistent();
        let opts = SolveOptions::default().with_tolerance(1e-8);
        let (astar, _) = crate::solvers::alpha::full_matrix_alpha(&sys, 8).unwrap();
        let unit = RkaSolver::new(5, 8, 1.0).solve(&sys, &opts).iterations;
        let opt = RkaSolver::new(5, 8, astar).solve(&sys, &opts).iterations;
        assert!(opt < unit, "alpha* {opt} vs alpha=1 {unit}");
    }

    #[test]
    fn q1_matches_rk_exactly() {
        // "Note that, if q = 1, we recover the RK method."
        let sys = DatasetBuilder::new(100, 8).seed(4).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(500);
        let rka = RkaSolver::new(9, 1, 1.0).solve(&sys, &opts);
        // RK with the same derived stream:
        let rk = RkSolver::new(crate::rng::derive_seed(9, 0)).solve(&sys, &opts);
        for (a, b) in rka.x.iter().zip(&rk.x) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn partitioned_sampling_converges_too() {
        let sys = DatasetBuilder::new(200, 10).seed(6).consistent();
        let r = RkaSolver::new(3, 4, 1.0)
            .with_scheme(SamplingScheme::Partitioned)
            .solve(&sys, &SolveOptions::default());
        assert!(r.converged);
    }

    #[test]
    fn per_worker_weights_converge() {
        let sys = DatasetBuilder::new(200, 10).seed(7).consistent();
        let (alphas, _) = crate::solvers::alpha::partial_matrix_alphas(&sys, 4).unwrap();
        let r = RkaSolver::new(3, 4, 1.0)
            .with_weights(Weights::PerWorker(alphas))
            .solve(&sys, &SolveOptions::default());
        assert!(r.converged);
    }

    #[test]
    fn inverse_row_norm_weights_converge_and_differ_from_uniform() {
        let sys = DatasetBuilder::new(200, 10).seed(7).consistent();
        let opts = SolveOptions::default();
        let r = RkaSolver::new(3, 4, 1.0)
            .with_weights(Weights::InverseRowNorm(1.0))
            .solve(&sys, &opts);
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-8);
        // Same seeds, different weighting: the trajectories must diverge
        // (the generator draws per-row sigmas, so row norms are unequal).
        let fixed = SolveOptions::default().with_fixed_iterations(50);
        let u = RkaSolver::new(3, 4, 1.0).solve(&sys, &fixed);
        let w = RkaSolver::new(3, 4, 1.0)
            .with_weights(Weights::InverseRowNorm(1.0))
            .solve(&sys, &fixed);
        assert!(u.x.iter().zip(&w.x).any(|(a, b)| a != b), "weighting had no effect");
    }

    #[test]
    fn greedy_sampling_converges_deterministically() {
        let sys = DatasetBuilder::new(150, 8).seed(11).consistent();
        let greedy = RkaSolver::new(3, 4, 1.0).with_sampling(SamplingStrategy::Greedy);
        let r = greedy.solve(&sys, &SolveOptions::default());
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-8);
        // Greedy ignores the seed entirely: different seeds, same iterates.
        let fixed = SolveOptions::default().with_fixed_iterations(80);
        let a = RkaSolver::new(3, 4, 1.0).with_sampling(SamplingStrategy::Greedy);
        let b = RkaSolver::new(99, 4, 1.0).with_sampling(SamplingStrategy::Greedy);
        let (ra, rb) = (a.solve(&sys, &fixed), b.solve(&sys, &fixed));
        for (u, v) in ra.x.iter().zip(&rb.x) {
            assert_eq!(u.to_bits(), v.to_bits(), "greedy must be seed-independent");
        }
    }

    #[test]
    fn reduces_horizon_on_inconsistent_systems() {
        // §3.5 / Fig. 12: larger q ⇒ lower error plateau vs x_LS.
        let mut sys = DatasetBuilder::new(400, 10).seed(8).inconsistent();
        crate::solvers::cgls::attach_least_squares(&mut sys, 1e-12, 5000).unwrap();
        let opts = SolveOptions::default()
            .with_fixed_iterations(20_000)
            .with_history_step(500);
        let h1 = RkaSolver::new(2, 1, 1.0).solve(&sys, &opts).history;
        let h20 = RkaSolver::new(2, 20, 1.0).solve(&sys, &opts).history;
        let tail1 = h1.tail_error(10).unwrap();
        let tail20 = h20.tail_error(10).unwrap();
        assert!(
            tail20 < tail1 / 2.0,
            "horizon q=20 ({tail20:.3e}) should be well below q=1 ({tail1:.3e})"
        );
    }
}
