//! Randomized Kaczmarz (Strohmer–Vershynin 2009), paper §2.2.
//!
//! Identical to cyclic Kaczmarz except the row index is sampled with
//! probability `‖A^(l)‖² / ‖A‖²_F` (eq. 4). This is the sequential baseline
//! every parallel method in the paper is compared against.

use super::sampling::{GreedySelector, SamplingStrategy};
use super::{SolveOptions, SolveResult, Solver, StopCheck};
use crate::data::LinearSystem;
use crate::metrics::Stopwatch;
use crate::rng::{AliasTable, Mt19937};

/// Randomized Kaczmarz solver.
pub struct RkSolver {
    /// RNG seed (the paper runs 10 seeds and averages iteration counts).
    pub seed: u32,
    /// Relaxation parameter (1.0 = pure projection).
    pub relaxation: f64,
    /// Row-selection rule (randomized eq. 4 by default, or greedy Motzkin).
    pub sampling: SamplingStrategy,
}

impl RkSolver {
    /// RK with unit relaxation.
    pub fn new(seed: u32) -> Self {
        RkSolver { seed, relaxation: 1.0, sampling: SamplingStrategy::default() }
    }

    /// Override the relaxation parameter.
    pub fn with_relaxation(seed: u32, relaxation: f64) -> Self {
        assert!(relaxation > 0.0 && relaxation < 2.0, "alpha must be in (0,2)");
        RkSolver { seed, relaxation, sampling: SamplingStrategy::default() }
    }

    /// Override the row-selection rule. Under [`SamplingStrategy::Greedy`]
    /// every step projects against the single most-violated row at the
    /// current iterate (Motzkin's method; deterministic, seed-independent).
    pub fn with_sampling(mut self, sampling: SamplingStrategy) -> Self {
        self.sampling = sampling;
        self
    }
}

impl Solver for RkSolver {
    fn name(&self) -> &'static str {
        "RK"
    }

    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult {
        let n = system.cols();
        let mut x = vec![0.0; n];
        let mut rng = Mt19937::new(self.seed);
        // Alias table: O(1) row sampling (see rng::distribution docs).
        let dist = AliasTable::new(system.sampling_weights());
        let mut greedy =
            (self.sampling == SamplingStrategy::Greedy).then(|| GreedySelector::new(system));
        // Stopping decisions and history recording both live in StopCheck.
        let mut stopper = StopCheck::new(system, opts);

        let sw = Stopwatch::start();
        let mut k = 0usize;
        let (mut converged, mut diverged);
        loop {
            let (stop, c, d) = stopper.check(k, &x);
            converged = c;
            diverged = d;
            if stop {
                break;
            }
            let i = match greedy.as_mut() {
                Some(g) => g.select(system, &x, 1)[0],
                None => dist.sample(&mut rng),
            };
            // Storage-generic row ops: bitwise the old dot/axpy on dense,
            // stored-entries-only on CSR.
            let residual = system.b[i] - system.a.row_dot(i, &x);
            let scale = self.relaxation * residual / system.row_norms_sq[i];
            system.a.row_axpy(i, scale, &mut x);
            k += 1;
        }

        SolveResult {
            x,
            iterations: k,
            converged,
            diverged,
            seconds: sw.seconds(),
            rows_used: k,
            history: stopper.into_history(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{coherent_system, DatasetBuilder};
    use crate::solvers::ck::CkSolver;

    #[test]
    fn converges_on_consistent_system() {
        let sys = DatasetBuilder::new(200, 10).seed(1).consistent();
        let r = RkSolver::new(42).solve(&sys, &SolveOptions::default().with_tolerance(1e-12));
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-12);
    }

    #[test]
    fn different_seeds_different_iteration_counts() {
        let sys = DatasetBuilder::new(200, 10).seed(1).consistent();
        let opts = SolveOptions::default().with_tolerance(1e-10);
        let it: Vec<usize> =
            (0..4).map(|s| RkSolver::new(s).solve(&sys, &opts).iterations).collect();
        // At least two runs should differ (sampling order differs).
        assert!(it.windows(2).any(|w| w[0] != w[1]), "{it:?}");
    }

    #[test]
    fn beats_cyclic_on_coherent_system() {
        // Fig. 1 in miniature: consecutive rows nearly parallel makes CK
        // crawl; RK jumps between distant hyperplanes and needs far fewer
        // iterations at equal tolerance.
        let sys = coherent_system(400, 4, 0.002, 11);
        let opts = SolveOptions::default().with_tolerance(1e-6).with_max_iterations(4_000_000);
        let ck = CkSolver::new().solve(&sys, &opts);
        let rk = RkSolver::new(7).solve(&sys, &opts);
        assert!(rk.converged);
        assert!(
            !ck.converged || ck.iterations > 2 * rk.iterations,
            "ck {} rk {}",
            ck.iterations,
            rk.iterations
        );
    }

    #[test]
    fn greedy_beats_randomized_on_coherent_system() {
        // Motzkin's selling point: on a coherent system random sampling
        // keeps drawing near-satisfied rows, while the max-distance rule
        // always projects against the worst violation.
        let sys = coherent_system(400, 4, 0.002, 11);
        let opts = SolveOptions::default().with_tolerance(1e-6).with_max_iterations(4_000_000);
        let rand = RkSolver::new(7).solve(&sys, &opts);
        let greedy = RkSolver::new(7).with_sampling(SamplingStrategy::Greedy).solve(&sys, &opts);
        assert!(greedy.converged);
        assert!(
            greedy.iterations < rand.iterations,
            "greedy {} vs randomized {}",
            greedy.iterations,
            rand.iterations
        );
    }

    #[test]
    fn does_not_reach_ls_solution_on_inconsistent() {
        // §2.2: RK stalls at a convergence horizon away from x_LS.
        let sys = DatasetBuilder::new(300, 5).seed(9).inconsistent();
        let mut sys = sys;
        sys.x_ls = Some(crate::solvers::cgls::solve_least_squares(&sys, 1e-12, 10_000).unwrap());
        let opts = SolveOptions::default().with_tolerance(1e-10).with_max_iterations(200_000);
        let r = RkSolver::new(3).solve(&sys, &opts);
        assert!(!r.converged, "RK should not hit 1e-10 of x_LS on noisy system");
    }
}
