//! Randomized Kaczmarz with Averaging with Blocks (RKAB) — the paper's new
//! method (§3.4, eqs. 8–9), sequential semantics of Algorithm 3.
//!
//! Each (virtual) worker `γ` starts from the shared iterate,
//! `v_γ^(0) = x^(k)`, applies `block_size` *sequential* Kaczmarz projections
//! to its private `v_γ`, and the next iterate is the plain average
//! `x^(k+1) = (1/q) Σ_γ v_γ`. Averaging thus happens once per block instead
//! of once per row, which is the whole point: communication is amortized by
//! a factor of `block_size`.
//!
//! `block_size = 1` recovers RKA (with the slight difference that RKAB's
//! in-block updates apply `alpha` directly rather than `alpha/q`; for bs = 1
//! the two coincide when weights are uniform — tested below).

use super::rka::Weights;
use super::sampling::{GreedySelector, RowSampler, SamplingScheme, SamplingStrategy};
use super::{SolveOptions, SolveResult, Solver, StopCheck};
use crate::data::LinearSystem;
use crate::linalg::vector::axpy;
use crate::metrics::Stopwatch;

/// One worker's in-block sweep: `block_size` sequential Kaczmarz projections
/// applied to the private iterate `v` (eq. 8 / Algorithm 3 lines 5-11).
///
/// This is the single implementation of the RKAB hot loop, shared by the
/// sequential reference (below), the shared-memory engine
/// (`parallel::rkab_shared`) and the simulated cluster
/// (`distributed::rkab_dist`). The `block_size` row indices are drawn up
/// front (same sampler stream as drawing them one-by-one), then the sweep
/// runs on the storage's fused `row_axpy_dot` flavor. On dense storage that
/// is the [`axpy_dot`](crate::linalg::axpy_dot) kernel: projection `j`'s
/// update of `v` and projection `j+1`'s residual dot product execute in one
/// pass over `v`, halving the traffic of the scalar dot-then-axpy
/// formulation while producing bit-identical iterates (see `axpy_dot`'s
/// lane-structure guarantee). On CSR storage the update touches only the
/// sampled row's stored coordinates of `v`. `indices` is caller-owned
/// scratch so the hot path allocates nothing.
///
/// Public so `bench_micro_hotpath` measures this exact function (not a
/// drifting copy) against the row-loop baseline.
pub fn block_sweep(
    system: &LinearSystem,
    sampler: &mut RowSampler,
    block_size: usize,
    alpha: f64,
    v: &mut [f64],
    indices: &mut Vec<usize>,
) {
    debug_assert!(block_size >= 1);
    indices.clear();
    for _ in 0..block_size {
        indices.push(sampler.sample());
    }
    sweep_indices(system, indices, alpha, v);
}

/// The fused projection sweep over an explicit, pre-selected index list —
/// the inner core of [`block_sweep`], split out so the greedy path (which
/// picks its block by Motzkin scan instead of drawing it) runs the exact
/// same kernel chain. `indices` must be non-empty.
pub fn sweep_indices(system: &LinearSystem, indices: &[usize], alpha: f64, v: &mut [f64]) {
    debug_assert!(!indices.is_empty());
    let len = indices.len();
    let mut d = system.a.row_dot(indices[0], v);
    for j in 0..len {
        let i = indices[j];
        let scale = alpha * (system.b[i] - d) / system.row_norms_sq[i];
        if j + 1 < len {
            d = system.a.row_axpy_dot(i, scale, indices[j + 1], v);
        } else {
            system.a.row_axpy(i, scale, v);
        }
    }
}

/// RKAB with `q` virtual workers (sequential reference implementation).
pub struct RkabSolver {
    /// Base RNG seed; worker `t` derives its own stream.
    pub seed: u32,
    /// Number of workers whose block results are averaged.
    pub q: usize,
    /// Rows each worker processes between averagings (`bs`).
    pub block_size: usize,
    /// In-block relaxation and block-averaging weights:
    /// [`Weights::Uniform`] is the paper's single `alpha` with plain `1/q`
    /// averaging (the pre-zoo solver, bitwise); [`Weights::PerWorker`]
    /// gives worker `γ` its own in-block `alpha`; with
    /// [`Weights::InverseRowNorm`] the in-block `alpha` stays uniform but
    /// worker results are averaged with weights
    /// `λ_γ ∝ 1/Σ_{i ∈ block_γ} ‖A^(i)‖²` (Moorman-style heterogeneous
    /// averaging at block granularity).
    pub weights: Weights,
    /// Row-sampling scheme.
    pub scheme: SamplingScheme,
    /// Row-selection rule (randomized eq. 4 by default, or greedy Motzkin).
    pub sampling: SamplingStrategy,
}

impl RkabSolver {
    /// RKAB with full-matrix sampling and a uniform in-block `alpha`.
    pub fn new(seed: u32, q: usize, block_size: usize, alpha: f64) -> Self {
        assert!(q >= 1 && block_size >= 1);
        RkabSolver {
            seed,
            q,
            block_size,
            weights: Weights::Uniform(alpha),
            scheme: SamplingScheme::FullMatrix,
            sampling: SamplingStrategy::default(),
        }
    }

    /// Override the sampling scheme.
    pub fn with_scheme(mut self, scheme: SamplingScheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Use per-worker in-block alphas or inverse-row-norm block averaging.
    pub fn with_weights(mut self, weights: Weights) -> Self {
        if let Some(len) = weights.len() {
            assert_eq!(len, self.q, "need one weight per worker");
        }
        self.weights = weights;
        self
    }

    /// Override the row-selection rule. Under [`SamplingStrategy::Greedy`]
    /// the block is the `block_size` most-violated distinct rows at `x^(k)`,
    /// selected once per iteration and swept by every worker — so greedy
    /// RKAB is deterministic, and with uniform weights all workers produce
    /// the same block result (use [`Weights::PerWorker`] to differentiate
    /// them).
    pub fn with_sampling(mut self, sampling: SamplingStrategy) -> Self {
        self.sampling = sampling;
        self
    }
}

impl Solver for RkabSolver {
    fn name(&self) -> &'static str {
        "RKAB"
    }

    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult {
        let n = system.cols();
        let q = self.q;
        let mut x = vec![0.0; n];
        let mut v = vec![0.0; n]; // per-worker private iterate (reused)
        let mut acc = vec![0.0; n]; // Σ_γ v_γ
        let mut idx = Vec::with_capacity(self.block_size); // sweep scratch
        let mut samplers: Vec<RowSampler> = (0..q)
            .map(|t| RowSampler::new(system, self.scheme, t, q, self.seed))
            .collect();
        let mut greedy =
            (self.sampling == SamplingStrategy::Greedy).then(|| GreedySelector::new(system));
        let norm_weighted = matches!(self.weights, Weights::InverseRowNorm(_));
        // Stopping decisions and history recording both live in StopCheck.
        let mut stopper = StopCheck::new(system, opts);

        let sw = Stopwatch::start();
        let mut k = 0usize;
        let (mut converged, mut diverged);
        loop {
            let (stop, c, d) = stopper.check(k, &x);
            converged = c;
            diverged = d;
            if stop {
                break;
            }
            // Greedy block: one Motzkin scan per iteration at x^(k); every
            // worker sweeps the same most-violated rows.
            if let Some(g) = greedy.as_mut() {
                idx.clear();
                idx.extend_from_slice(g.select(system, &x, self.block_size));
            }
            acc.fill(0.0);
            // With inverse-row-norm weights: Σ_γ λ_raw_γ · v_γ, normalized
            // after the loop by Σ λ_raw (so one pass suffices).
            let mut raw_sum = 0.0;
            for (t, sampler) in samplers.iter_mut().enumerate() {
                // v_γ^(0) = x^(k); then bs sequential projections on v (eq. 8),
                // via the shared fused-kernel sweep.
                v.copy_from_slice(&x);
                let alpha_t = self.weights.get(t);
                if greedy.is_some() {
                    sweep_indices(system, &idx, alpha_t, &mut v);
                } else {
                    block_sweep(system, sampler, self.block_size, alpha_t, &mut v, &mut idx);
                }
                if norm_weighted {
                    let raw = 1.0 / idx.iter().map(|&i| system.row_norms_sq[i]).sum::<f64>();
                    raw_sum += raw;
                    axpy(raw, &v, &mut acc);
                } else {
                    axpy(1.0, &v, &mut acc);
                }
            }
            // x^(k+1): plain 1/q average (eq. 9), or the λ-weighted
            // combination when inverse-row-norm weighting is on.
            let inv = if norm_weighted { 1.0 / raw_sum } else { 1.0 / q as f64 };
            for (xi, ai) in x.iter_mut().zip(&acc) {
                *xi = ai * inv;
            }
            k += 1;
        }

        SolveResult {
            x,
            iterations: k,
            converged,
            diverged,
            seconds: sw.seconds(),
            rows_used: k * q * self.block_size,
            history: stopper.into_history(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::solvers::rka::RkaSolver;

    #[test]
    fn converges_on_consistent_system() {
        let sys = DatasetBuilder::new(300, 12).seed(1).consistent();
        let r = RkabSolver::new(3, 4, 12, 1.0).solve(&sys, &SolveOptions::default());
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-8);
        assert_eq!(r.rows_used, r.iterations * 4 * 12);
    }

    #[test]
    fn bs1_matches_rka_with_unit_alpha() {
        // With bs = 1 and uniform alpha = 1 the update degenerates to eq. 7.
        // Wait — RKAB applies alpha, not alpha/q, inside the block; but the
        // averaging (1/q)Σ(x + d_γ) = x + (1/q)Σd_γ reproduces eq. 7 exactly
        // when each worker does one projection. Verify numerically.
        let sys = DatasetBuilder::new(120, 6).seed(4).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(300);
        let a = RkabSolver::new(9, 3, 1, 1.0).solve(&sys, &opts);
        let b = RkaSolver::new(9, 3, 1.0).solve(&sys, &opts);
        for (u, v) in a.x.iter().zip(&b.x) {
            assert!((u - v).abs() < 1e-9, "{u} vs {v}");
        }
    }

    #[test]
    fn larger_blocks_fewer_iterations() {
        // Fig. 7a: increasing bs decreases iterations.
        let sys = DatasetBuilder::new(400, 20).seed(5).consistent();
        let opts = SolveOptions::default().with_tolerance(1e-8);
        let i5 = RkabSolver::new(2, 4, 5, 1.0).solve(&sys, &opts).iterations;
        let i20 = RkabSolver::new(2, 4, 20, 1.0).solve(&sys, &opts).iterations;
        assert!(i20 < i5, "bs=20 {i20} vs bs=5 {i5}");
    }

    #[test]
    fn divergence_detected_for_large_alpha() {
        // Fig. 10b: RKAB can diverge when alpha approaches alpha* for q=4
        // and blocks are large. alpha=3.9 with big blocks must not loop
        // forever — the divergence check has to fire (in-block updates with
        // alpha near 2 already oscillate; ~4 explodes).
        let sys = DatasetBuilder::new(200, 10).seed(6).consistent();
        let opts = SolveOptions {
            divergence_factor: 1e4,
            max_iterations: 50_000,
            ..Default::default()
        };
        let r = RkabSolver::new(1, 4, 100, 3.9).solve(&sys, &opts);
        assert!(r.diverged, "expected divergence, got {:?} iters", r.iterations);
    }

    #[test]
    fn reduces_horizon_like_rka() {
        // Fig. 14: RKAB with bs = n lowers the error plateau as q grows.
        let mut sys = DatasetBuilder::new(400, 10).seed(7).inconsistent();
        crate::solvers::cgls::attach_least_squares(&mut sys, 1e-12, 5000).unwrap();
        let opts = SolveOptions::default().with_fixed_iterations(400).with_history_step(10);
        let h1 = RkabSolver::new(2, 1, 10, 1.0).solve(&sys, &opts).history;
        let h20 = RkabSolver::new(2, 20, 10, 1.0).solve(&sys, &opts).history;
        let t1 = h1.tail_error(5).unwrap();
        let t20 = h20.tail_error(5).unwrap();
        assert!(t20 < t1, "q=20 tail {t20:.3e} vs q=1 {t1:.3e}");
    }

    #[test]
    fn partitioned_scheme_converges() {
        let sys = DatasetBuilder::new(300, 12).seed(8).consistent();
        let r = RkabSolver::new(3, 4, 12, 1.0)
            .with_scheme(SamplingScheme::Partitioned)
            .solve(&sys, &SolveOptions::default());
        assert!(r.converged);
    }
}
