//! Shared-memory primitives for the parallel engine.
//!
//! OpenMP lets every thread read and write the same arrays, relying on the
//! program's barriers/critical sections for soundness. Rust's safe layer
//! cannot express that, so [`SharedSlice`] provides the same model behind a
//! small unsafe surface with an explicit protocol (below), and
//! [`SpinBarrier`] provides the cheap sense-reversing barrier OpenMP
//! runtimes use (std's futex Barrier costs microseconds per crossing, which
//! would drown the per-iteration work the paper measures).
//!
//! # SharedSlice protocol
//!
//! A `SharedSlice` hands out raw views of one `Vec<f64>`. Callers must
//! guarantee, via barriers/mutexes, that between two synchronization points
//! either (a) all accesses are reads, or (b) writers touch disjoint index
//! ranges. Every use in this crate is one of:
//! - chunked writes where thread `t` owns `chunk(t, q)` (disjoint);
//! - whole-slice writes inside a `Mutex` critical section;
//! - read-only phases separated from write phases by a barrier.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// A `Vec<f64>` that multiple threads may access under the module protocol.
pub struct SharedSlice {
    data: UnsafeCell<Vec<f64>>,
}

// SAFETY: all mutation goes through `as_mut_unchecked`, whose callers uphold
// the disjointness/synchronization protocol documented on the module.
unsafe impl Sync for SharedSlice {}

impl SharedSlice {
    /// Zero-initialized shared buffer.
    pub fn zeros(n: usize) -> Self {
        SharedSlice { data: UnsafeCell::new(vec![0.0; n]) }
    }

    /// Wrap an existing vector.
    pub fn from_vec(v: Vec<f64>) -> Self {
        SharedSlice { data: UnsafeCell::new(v) }
    }

    /// Length of the buffer.
    pub fn len(&self) -> usize {
        // SAFETY: len never changes after construction.
        unsafe { (*self.data.get()).len() }
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read-only view.
    ///
    /// # Safety
    /// Caller must ensure no thread writes the slice concurrently.
    #[inline]
    pub unsafe fn as_ref_unchecked(&self) -> &[f64] {
        &*self.data.get()
    }

    /// Mutable view.
    ///
    /// # Safety
    /// Caller must ensure writes follow the module protocol (disjoint ranges
    /// or exclusive access between synchronization points).
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn as_mut_unchecked(&self) -> &mut [f64] {
        &mut *self.data.get()
    }

    /// Consume and return the inner vector (end of the parallel region).
    pub fn into_vec(self) -> Vec<f64> {
        self.data.into_inner()
    }

    /// The index range thread `t` of `q` owns in chunked phases:
    /// `[⌊t·n/q⌋, ⌊(t+1)·n/q⌋)` — same partition the paper's `omp for`
    /// static schedule produces.
    pub fn chunk(&self, t: usize, q: usize) -> (usize, usize) {
        let n = self.len();
        (t * n / q, (t + 1) * n / q)
    }
}

/// A vector of `f64` with per-entry atomic access.
///
/// Used where OpenMP code would rely on `atomic` updates or on hardware
/// cache coherence for racy-but-benign accesses (the `atomic` averaging
/// strategy of §3.3.1 and the HOGWILD!-style AsyRK of §2.3.3). Bits are
/// stored in `AtomicU64`; relaxed loads/stores compile to plain moves, so
/// the read path costs the same as a plain slice.
pub struct AtomicF64Vec {
    data: Vec<std::sync::atomic::AtomicU64>,
}

impl AtomicF64Vec {
    /// Zero-initialized vector of length `n`.
    pub fn zeros(n: usize) -> Self {
        AtomicF64Vec { data: (0..n).map(|_| std::sync::atomic::AtomicU64::new(0)).collect() }
    }

    /// Length.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Relaxed load of entry `i`.
    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        f64::from_bits(self.data[i].load(Ordering::Relaxed))
    }

    /// Relaxed store of entry `i`.
    #[inline]
    pub fn set(&self, i: usize, v: f64) {
        self.data[i].store(v.to_bits(), Ordering::Relaxed);
    }

    /// Atomic `x[i] += delta` via compare-exchange loop.
    #[inline]
    pub fn add(&self, i: usize, delta: f64) {
        let cell = &self.data[i];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + delta).to_bits();
            match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Copy out the current contents (only meaningful at a sync point).
    pub fn snapshot(&self) -> Vec<f64> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }

    /// Copy the contents into `out` (no allocation).
    pub fn snapshot_into(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.len());
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.get(i);
        }
    }

    /// Chunk bounds identical to [`SharedSlice::chunk`].
    pub fn chunk(&self, t: usize, q: usize) -> (usize, usize) {
        let n = self.len();
        (t * n / q, (t + 1) * n / q)
    }
}

/// Sense-reversing centralized spin barrier.
///
/// All waiters spin on a generation counter; the last arrival flips it.
/// ~50-100ns per crossing at the thread counts used here, versus several µs
/// for `std::sync::Barrier` — the difference is material because RKA crosses
/// barriers every iteration (§3.3.1) and the iteration itself is only O(n).
pub struct SpinBarrier {
    count: AtomicUsize,
    generation: AtomicUsize,
    total: usize,
}

/// Pure-spin budget before a waiter starts yielding its timeslice.
///
/// Uncontended crossings resolve in well under this many probes, so the
/// fast path never syscalls. Past the budget the waiter `yield_now`s on
/// every probe: when `q` exceeds the core count a pure spin barrier
/// live-locks (the arrivals that would release the barrier cannot be
/// scheduled while the waiters burn their timeslices), and CI machines are
/// exactly where that happens — the paper runs 64 threads, this container
/// may have 2 cores.
const SPIN_LIMIT: u32 = 64;

impl SpinBarrier {
    /// Barrier for `total` threads.
    pub fn new(total: usize) -> Self {
        assert!(total > 0);
        SpinBarrier { count: AtomicUsize::new(0), generation: AtomicUsize::new(0), total }
    }

    /// Block until all `total` threads arrive: spin up to [`SPIN_LIMIT`]
    /// probes, then spin-then-yield so oversubscribed runs keep making
    /// progress.
    pub fn wait(&self) {
        let gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.total {
            // Last arrival: reset and release the others.
            self.count.store(0, Ordering::Release);
            self.generation.store(gen.wrapping_add(1), Ordering::Release);
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == gen {
                if spins < SPIN_LIMIT {
                    spins += 1;
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::pool::WorkerPool;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn shared_slice_chunks_partition() {
        let s = SharedSlice::zeros(10);
        let (l0, h0) = s.chunk(0, 3);
        let (l1, h1) = s.chunk(1, 3);
        let (l2, h2) = s.chunk(2, 3);
        assert_eq!(l0, 0);
        assert_eq!(h0, l1);
        assert_eq!(h1, l2);
        assert_eq!(h2, 10);
    }

    #[test]
    fn shared_slice_disjoint_parallel_writes() {
        let s = SharedSlice::zeros(1000);
        let q = 4;
        WorkerPool::new().run(q, |t| {
            let (lo, hi) = s.chunk(t, q);
            // SAFETY: chunks are disjoint.
            let v = unsafe { s.as_mut_unchecked() };
            for i in lo..hi {
                v[i] = t as f64;
            }
        });
        let v = s.into_vec();
        for t in 0..q {
            let lo = t * 1000 / q;
            assert_eq!(v[lo], t as f64);
        }
    }

    #[test]
    fn spin_barrier_synchronizes_phases() {
        // Each thread increments a phase counter only after the barrier; if
        // the barrier leaked, some thread would observe a stale phase.
        let q = 4;
        let barrier = SpinBarrier::new(q);
        let counter = AtomicU64::new(0);
        WorkerPool::new().run(q, |_| {
            for phase in 0..50u64 {
                barrier.wait();
                // All threads agree the counter equals q*phase here.
                assert_eq!(counter.load(Ordering::SeqCst) / q as u64, phase);
                barrier.wait();
                counter.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 50 * q as u64);
    }

    #[test]
    fn spin_barrier_survives_oversubscription() {
        // More waiters than cores: the yield fallback must keep every phase
        // progressing instead of live-locking the machine (regression for
        // the pure-spin formulation).
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
        let q = 4 * cores;
        let barrier = SpinBarrier::new(q);
        let counter = AtomicU64::new(0);
        WorkerPool::new().run(q, |_| {
            for _ in 0..100u64 {
                barrier.wait();
                counter.fetch_add(1, Ordering::SeqCst);
                barrier.wait();
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 100 * q as u64);
    }

    #[test]
    fn spin_barrier_single_thread_is_noop() {
        let b = SpinBarrier::new(1);
        for _ in 0..10 {
            b.wait();
        }
    }

    #[test]
    fn atomic_vec_get_set_add() {
        let v = AtomicF64Vec::zeros(3);
        v.set(0, 1.5);
        v.add(0, 2.5);
        assert_eq!(v.get(0), 4.0);
        assert_eq!(v.snapshot(), vec![4.0, 0.0, 0.0]);
    }

    #[test]
    fn atomic_adds_do_not_lose_updates() {
        let v = AtomicF64Vec::zeros(4);
        let q = 8;
        let per_thread = 10_000;
        WorkerPool::new().run(q, |_| {
            for _ in 0..per_thread {
                for i in 0..4 {
                    v.add(i, 1.0);
                }
            }
        });
        for i in 0..4 {
            assert_eq!(v.get(i), (q * per_thread) as f64);
        }
    }
}
