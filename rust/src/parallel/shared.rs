//! Shared-memory primitives for the parallel engine.
//!
//! OpenMP lets every thread read and write the same arrays, relying on the
//! program's barriers/critical sections for soundness. Rust's safe layer
//! cannot express that, so [`SharedSlice`] provides the same model behind a
//! small unsafe surface with an explicit protocol (below), and
//! [`SpinBarrier`] provides the cheap sense-reversing barrier OpenMP
//! runtimes use (std's futex Barrier costs microseconds per crossing, which
//! would drown the per-iteration work the paper measures).
//!
//! # SharedSlice protocol
//!
//! A `SharedSlice` hands out raw views of one `Vec<f64>`. All views are
//! derived from a base pointer cached at construction (while the vector was
//! still exclusively owned), never from fresh `&mut` reborrows of the cell:
//! two threads re-borrowing the whole buffer as `&mut [f64]` — even to
//! write disjoint halves — is undefined behavior under Stacked Borrows
//! (each whole-slice `&mut` asserts exclusivity over *every* element), and
//! Miri rejects it. Deriving every view from the one cached raw pointer
//! keeps disjoint concurrent writes well-defined, which is why the mutable
//! accessor is [`SharedSlice::range_mut_unchecked`] (a bounded sub-view)
//! rather than a whole-slice `&mut`.
//!
//! Callers must still guarantee, via barriers/mutexes, that between two
//! synchronization points either (a) all accesses are reads, or (b) writers
//! touch disjoint index ranges. Every use in this crate is one of:
//! - chunked writes where thread `t` owns `chunk(t, q)` (disjoint);
//! - whole-slice writes inside a `Mutex` critical section;
//! - read-only phases separated from write phases by a barrier.
//!
//! # Barrier phases
//!
//! Each solver names the [`SpinBarrier`] crossings its `// SAFETY:`
//! comments appeal to. The protocol is always the same shape — a crossing
//! both *publishes* the writes before it (Release on arrival) and *orders*
//! the accesses after it (Acquire on departure), so a range written before
//! a crossing may be read by any thread after it:
//!
//! - **RKA** ([`super::rka_shared`]): (A) all `q` gather rows written →
//!   safe to reduce/average; (B) stop decision published by thread 0 →
//!   safe for all to read; (C) `x_prev` chunks copied → safe to read next
//!   iteration.
//! - **RKAB** ([`super::rkab_shared`]): per block, (A) stop flag published;
//!   (B) all `q` block results written to the gather matrix → safe to
//!   average into `x`; (C) averaging of `x` chunks complete → safe for all
//!   to read `x` in the next block.
//! - **Block-sequential RK** ([`super::block_seq`]): per iteration, (A) row
//!   choice + stop flag published; (B) all partial dot products written →
//!   thread 0 may reduce; (C) scale published → all may update their `x`
//!   chunk; (D) `x` update complete → safe to read next iteration.
//!
//! The barrier itself is model-checked: `tests/loom.rs` exhaustively
//! verifies (under `RUSTFLAGS="--cfg loom"`) that a write before a crossing
//! is visible after it, including across reused generations — the exact
//! pattern the solvers' phase loops rely on.

use std::cell::UnsafeCell;

use super::sync::{spin_loop_hint, yield_now, AtomicU64, AtomicUsize, Ordering};

/// A `Vec<f64>` that multiple threads may access under the module protocol.
pub struct SharedSlice {
    data: UnsafeCell<Vec<f64>>,
    /// Base pointer of `data`'s buffer, cached while the vector was still
    /// exclusively owned. Every view below derives from this pointer so
    /// concurrent disjoint writes never create overlapping `&mut [f64]`
    /// whole-slice borrows (see module docs).
    base: *mut f64,
    len: usize,
}

// SAFETY: the raw `base` pointer only suppresses the auto impl; it points
// into the owned `data` vector, which moves with the struct, and `f64`
// buffers are sendable.
unsafe impl Send for SharedSlice {}

// SAFETY: all mutation goes through `range_mut_unchecked`, whose callers
// uphold the disjointness/synchronization protocol documented on the
// module.
unsafe impl Sync for SharedSlice {}

impl SharedSlice {
    /// Zero-initialized shared buffer.
    pub fn zeros(n: usize) -> Self {
        SharedSlice::from_vec(vec![0.0; n])
    }

    /// Wrap an existing vector.
    pub fn from_vec(mut v: Vec<f64>) -> Self {
        // Cache the buffer pointer while `v` is exclusively owned; moving
        // the Vec into the cell moves its (ptr, len, cap) header, not the
        // heap buffer, so the pointer stays valid for the struct's life.
        let base = v.as_mut_ptr();
        let len = v.len();
        SharedSlice { data: UnsafeCell::new(v), base, len }
    }

    /// Length of the buffer.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read-only view of the whole buffer.
    ///
    /// # Safety
    /// Caller must ensure no thread writes any element concurrently (reads
    /// may only overlap writes across a barrier crossing, never within a
    /// phase).
    #[inline]
    pub unsafe fn as_ref_unchecked(&self) -> &[f64] {
        // SAFETY: `base`/`len` describe a live, initialized f64 buffer for
        // the life of `self`; the caller guarantees no concurrent writes
        // overlap this read.
        unsafe { std::slice::from_raw_parts(self.base, self.len) }
    }

    /// Mutable view of elements `[lo, hi)`.
    ///
    /// This is deliberately a *range* view: handing each writer only the
    /// sub-slice it owns keeps concurrent `&mut` views non-overlapping,
    /// which the aliasing model requires (a whole-slice `&mut` per thread
    /// would be instant UB even with disjoint index discipline).
    ///
    /// # Safety
    /// Caller must ensure writes follow the module protocol: between two
    /// synchronization points, no other view (read or write) overlaps
    /// `[lo, hi)`.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut_unchecked(&self, lo: usize, hi: usize) -> &mut [f64] {
        debug_assert!(lo <= hi && hi <= self.len);
        // SAFETY: bounds are debug-checked against the fixed buffer length;
        // the view derives from the cached base pointer, and the caller
        // guarantees no overlapping view exists within this phase.
        unsafe { std::slice::from_raw_parts_mut(self.base.add(lo), hi - lo) }
    }

    /// Consume and return the inner vector (end of the parallel region).
    pub fn into_vec(self) -> Vec<f64> {
        self.data.into_inner()
    }

    /// The index range thread `t` of `q` owns in chunked phases:
    /// `[⌊t·n/q⌋, ⌊(t+1)·n/q⌋)` — same partition the paper's `omp for`
    /// static schedule produces.
    pub fn chunk(&self, t: usize, q: usize) -> (usize, usize) {
        let n = self.len;
        (t * n / q, (t + 1) * n / q)
    }
}

/// A vector of `f64` with per-entry atomic access.
///
/// Used where OpenMP code would rely on `atomic` updates or on hardware
/// cache coherence for racy-but-benign accesses (the `atomic` averaging
/// strategy of §3.3.1 and the HOGWILD!-style AsyRK of §2.3.3). Bits are
/// stored in `AtomicU64`; relaxed loads/stores compile to plain moves, so
/// the read path costs the same as a plain slice.
pub struct AtomicF64Vec {
    data: Vec<AtomicU64>,
}

impl AtomicF64Vec {
    /// Zero-initialized vector of length `n`.
    pub fn zeros(n: usize) -> Self {
        AtomicF64Vec { data: (0..n).map(|_| AtomicU64::new(0)).collect() }
    }

    /// Length.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Relaxed load of entry `i`.
    ///
    /// Relaxed is sufficient: entries carry independent numeric payloads
    /// (no other memory is published through them), and the algorithms
    /// reading them (HOGWILD!-style AsyRK, the `atomic` RKA gather)
    /// tolerate stale per-entry values by design. Cross-phase visibility
    /// comes from the surrounding barrier/pool synchronization.
    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        f64::from_bits(self.data[i].load(Ordering::Relaxed))
    }

    /// Relaxed store of entry `i` (see [`AtomicF64Vec::get`] for why
    /// relaxed suffices).
    #[inline]
    pub fn set(&self, i: usize, v: f64) {
        self.data[i].store(v.to_bits(), Ordering::Relaxed);
    }

    /// Atomic `x[i] += delta` via compare-exchange loop.
    ///
    /// Relaxed success/failure orderings are sufficient: the CAS loop only
    /// needs per-entry atomicity (no lost updates), not cross-entry
    /// ordering — totals are read at sync points ordered by the pool.
    #[inline]
    pub fn add(&self, i: usize, delta: f64) {
        let cell = &self.data[i];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + delta).to_bits();
            match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Copy out the current contents (only meaningful at a sync point).
    pub fn snapshot(&self) -> Vec<f64> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }

    /// Copy the contents into `out` (no allocation).
    pub fn snapshot_into(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.len());
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.get(i);
        }
    }

    /// Chunk bounds identical to [`SharedSlice::chunk`].
    pub fn chunk(&self, t: usize, q: usize) -> (usize, usize) {
        let n = self.len();
        (t * n / q, (t + 1) * n / q)
    }
}

/// Sense-reversing centralized spin barrier.
///
/// All waiters spin on a generation counter; the last arrival flips it.
/// ~50-100ns per crossing at the thread counts used here, versus several µs
/// for `std::sync::Barrier` — the difference is material because RKA crosses
/// barriers every iteration (§3.3.1) and the iteration itself is only O(n).
///
/// Ordering protocol (model-checked in `tests/loom.rs`): the `AcqRel`
/// `fetch_add` on arrival makes every waiter's pre-barrier writes visible
/// to the last arrival, and the `Release` generation flip (paired with the
/// waiters' `Acquire` spin loads) re-publishes them to everyone leaving the
/// barrier. Resetting `count` *before* flipping `generation` keeps reuse
/// safe: no thread can re-enter `wait` for generation `g+1` until it
/// observes the flip, by which point the reset is already ordered before
/// it.
pub struct SpinBarrier {
    count: AtomicUsize,
    generation: AtomicUsize,
    total: usize,
}

/// Pure-spin budget before a waiter starts yielding its timeslice.
///
/// Uncontended crossings resolve in well under this many probes, so the
/// fast path never syscalls. Past the budget the waiter `yield_now`s on
/// every probe: when `q` exceeds the core count a pure spin barrier
/// live-locks (the arrivals that would release the barrier cannot be
/// scheduled while the waiters burn their timeslices), and CI machines are
/// exactly where that happens — the paper runs 64 threads, this container
/// may have 2 cores.
const SPIN_LIMIT: u32 = 64;

impl SpinBarrier {
    /// Barrier for `total` threads.
    pub fn new(total: usize) -> Self {
        assert!(total > 0);
        SpinBarrier { count: AtomicUsize::new(0), generation: AtomicUsize::new(0), total }
    }

    /// Block until all `total` threads arrive: spin up to [`SPIN_LIMIT`]
    /// probes, then spin-then-yield so oversubscribed runs keep making
    /// progress.
    pub fn wait(&self) {
        let gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.total {
            // Last arrival: reset and release the others. The count reset
            // must precede the generation flip (see type-level docs).
            self.count.store(0, Ordering::Release);
            self.generation.store(gen.wrapping_add(1), Ordering::Release);
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == gen {
                if spins < SPIN_LIMIT {
                    spins += 1;
                    spin_loop_hint();
                } else {
                    yield_now();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::pool::WorkerPool;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn shared_slice_chunks_partition() {
        let s = SharedSlice::zeros(10);
        let (l0, h0) = s.chunk(0, 3);
        let (l1, h1) = s.chunk(1, 3);
        let (l2, h2) = s.chunk(2, 3);
        assert_eq!(l0, 0);
        assert_eq!(h0, l1);
        assert_eq!(h1, l2);
        assert_eq!(h2, 10);
    }

    #[test]
    fn shared_slice_disjoint_parallel_writes() {
        let n = if cfg!(miri) { 64 } else { 1000 };
        let s = SharedSlice::zeros(n);
        let q = 4;
        WorkerPool::new().run(q, |t| {
            let (lo, hi) = s.chunk(t, q);
            // SAFETY: chunks are disjoint, and each thread only takes a
            // view of its own range.
            let v = unsafe { s.range_mut_unchecked(lo, hi) };
            for x in v.iter_mut() {
                *x = t as f64;
            }
        });
        let v = s.into_vec();
        for t in 0..q {
            let lo = t * n / q;
            assert_eq!(v[lo], t as f64);
        }
    }

    // Aliasing probe (run it under Miri): two *coexisting* range views are
    // legal exactly because each is a bounded sub-view derived from the
    // cached base pointer. The pre-refactor shape — two whole-slice
    // `&mut [f64]` borrows indexed disjointly — fails Miri's Stacked
    // Borrows check on this very pattern.
    #[test]
    fn disjoint_range_views_may_coexist() {
        let s = SharedSlice::zeros(8);
        // SAFETY: [0,4) and [4,8) do not overlap.
        let (a, b) = unsafe { (s.range_mut_unchecked(0, 4), s.range_mut_unchecked(4, 8)) };
        a.fill(1.0);
        b.fill(2.0);
        // Both views written through; neither invalidated the other.
        assert_eq!(a[3], 1.0);
        assert_eq!(b[0], 2.0);
        let v = s.into_vec();
        assert_eq!(v, vec![1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0]);
    }

    // Phase-protocol probe: a write phase followed by a read phase on the
    // same range is legal once the writer's view is dead — the shared view
    // derives from the same base pointer, so it does not conflict with
    // past (ended) mutable views.
    #[test]
    fn write_phase_then_read_phase_is_legal() {
        let s = SharedSlice::zeros(4);
        {
            // SAFETY: exclusive access within this scope (single thread).
            let w = unsafe { s.range_mut_unchecked(0, 4) };
            w[2] = 7.0;
        }
        // SAFETY: the mutable view above is out of scope; this is a
        // read-only phase.
        let r = unsafe { s.as_ref_unchecked() };
        assert_eq!(r[2], 7.0);
    }

    #[test]
    fn spin_barrier_synchronizes_phases() {
        // Each thread increments a phase counter only after the barrier; if
        // the barrier leaked, some thread would observe a stale phase.
        let q = 4;
        let phases: u64 = if cfg!(miri) { 3 } else { 50 };
        let barrier = SpinBarrier::new(q);
        let counter = AtomicU64::new(0);
        WorkerPool::new().run(q, |_| {
            for phase in 0..phases {
                barrier.wait();
                // All threads agree the counter equals q*phase here.
                assert_eq!(counter.load(Ordering::SeqCst) / q as u64, phase);
                barrier.wait();
                counter.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), phases * q as u64);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns 4x available_parallelism threads
    fn spin_barrier_survives_oversubscription() {
        // More waiters than cores: the yield fallback must keep every phase
        // progressing instead of live-locking the machine (regression for
        // the pure-spin formulation).
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
        let q = 4 * cores;
        let barrier = SpinBarrier::new(q);
        let counter = AtomicU64::new(0);
        WorkerPool::new().run(q, |_| {
            for _ in 0..100u64 {
                barrier.wait();
                counter.fetch_add(1, Ordering::SeqCst);
                barrier.wait();
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 100 * q as u64);
    }

    #[test]
    fn spin_barrier_single_thread_is_noop() {
        let b = SpinBarrier::new(1);
        for _ in 0..10 {
            b.wait();
        }
    }

    #[test]
    fn atomic_vec_get_set_add() {
        let v = AtomicF64Vec::zeros(3);
        v.set(0, 1.5);
        v.add(0, 2.5);
        assert_eq!(v.get(0), 4.0);
        assert_eq!(v.snapshot(), vec![4.0, 0.0, 0.0]);
    }

    #[test]
    fn atomic_adds_do_not_lose_updates() {
        let v = AtomicF64Vec::zeros(4);
        let q = if cfg!(miri) { 4 } else { 8 };
        let per_thread = if cfg!(miri) { 50 } else { 10_000 };
        WorkerPool::new().run(q, |_| {
            for _ in 0..per_thread {
                for i in 0..4 {
                    v.add(i, 1.0);
                }
            }
        });
        for i in 0..4 {
            assert_eq!(v.get(i), (q * per_thread) as f64);
        }
    }
}
