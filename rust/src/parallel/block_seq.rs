//! Block-sequential parallelization of RK — §3.2 of the paper.
//!
//! One RK iteration at a time (sequential over iterations), but the two O(n)
//! pieces *inside* the iteration are split across threads:
//!
//! - the dot product `<A^(row), x>` — an `omp reduce(+)` (each thread sums a
//!   chunk, partials are combined);
//! - the update `x += scale * A^(row)` — an `omp for` over entries.
//!
//! The paper's finding, which this module reproduces in Fig. 2, is that the
//! per-iteration work (O(n)) is too small to amortize two barrier crossings,
//! so there is *no* speedup for small n and a poor one for large n.

use super::shared::{SharedSlice, SpinBarrier};
use crate::data::LinearSystem;
use crate::metrics::{History, Stopwatch};
use crate::rng::{AliasTable, Mt19937};
use crate::solvers::{SolveOptions, SolveResult, Solver, StopCheck};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// Block-sequential RK (every iteration's dot/update parallelized).
pub struct BlockSequentialRk {
    /// RNG seed (one stream — row choice is shared by all threads).
    pub seed: u32,
    /// Thread count.
    pub threads: usize,
    /// Relaxation parameter.
    pub relaxation: f64,
    /// Worker-pool override (`None` = the process-global pool).
    pool: Option<std::sync::Arc<super::pool::WorkerPool>>,
}

impl BlockSequentialRk {
    /// Block-sequential RK with unit relaxation.
    pub fn new(seed: u32, threads: usize) -> Self {
        assert!(threads >= 1);
        BlockSequentialRk { seed, threads, relaxation: 1.0, pool: None }
    }

    /// Run on a dedicated pool instead of the process-global one.
    pub fn with_pool(mut self, pool: std::sync::Arc<super::pool::WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }
}

struct Region {
    x: SharedSlice,
    /// Per-thread partial dot products (padded to a cache line each to avoid
    /// false sharing — 8 f64 = 64 bytes).
    partials: SharedSlice,
    /// Row chosen for the current iteration (published by thread 0).
    row: AtomicUsize,
    /// Bits of the combined scale factor (published by thread 0).
    scale_bits: AtomicU64,
    barrier: SpinBarrier,
    stop: AtomicBool,
    converged: AtomicBool,
    diverged: AtomicBool,
}

const PAD: usize = 8; // one cache line of f64 per thread

impl Solver for BlockSequentialRk {
    fn name(&self) -> &'static str {
        "RK-block-seq"
    }

    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult {
        let n = system.cols();
        let q = self.threads;
        let region = Region {
            x: SharedSlice::zeros(n),
            partials: SharedSlice::zeros(q * PAD),
            row: AtomicUsize::new(0),
            scale_bits: AtomicU64::new(0),
            barrier: SpinBarrier::new(q),
            stop: AtomicBool::new(false),
            converged: AtomicBool::new(false),
            diverged: AtomicBool::new(false),
        };
        // One dispatch on the persistent pool = one parallel region.
        let sw = Stopwatch::start();
        let report = std::sync::Mutex::new(None);
        let pool = self.pool.as_deref().unwrap_or_else(|| super::pool::global());
        pool.run(q, |t| {
            let out = self.worker(t, system, opts, &region);
            if let Some(out) = out {
                *report.lock().unwrap() = Some(out);
            }
        });
        let seconds = sw.seconds();

        let (history, iterations) =
            report.into_inner().unwrap().expect("participant 0 reports history");
        SolveResult {
            x: region.x.into_vec(),
            iterations,
            converged: region.converged.load(Ordering::SeqCst),
            diverged: region.diverged.load(Ordering::SeqCst),
            seconds,
            rows_used: iterations,
            history,
        }
    }
}

impl BlockSequentialRk {
    fn worker(
        &self,
        t: usize,
        system: &LinearSystem,
        opts: &SolveOptions,
        region: &Region,
    ) -> Option<(History, usize)> {
        let q = self.threads;
        // Row sampling is *shared* (one RK chain): thread 0 draws, publishes.
        let mut rng = Mt19937::new(self.seed);
        let dist = if t == 0 { Some(AliasTable::new(system.sampling_weights())) } else { None };
        // Stopping state and history recording live with the thread that
        // decides (thread 0).
        let mut stopper = (t == 0).then(|| StopCheck::new(system, opts));
        let mut k = 0usize;
        let (lo, hi) = region.x.chunk(t, q);

        loop {
            region.barrier.wait(); // (A) previous update complete
            if t == 0 {
                // SAFETY: all writers passed barrier (A); x is stable.
                let x = unsafe { region.x.as_ref_unchecked() };
                let stopper = stopper.as_mut().expect("thread 0 owns the stopper");
                let (stop, c, d) = stopper.check(k, x);
                region.converged.store(c, Ordering::SeqCst);
                region.diverged.store(d, Ordering::SeqCst);
                region.stop.store(stop, Ordering::SeqCst);
                if !stop {
                    let i = dist.as_ref().unwrap().sample(&mut rng);
                    region.row.store(i, Ordering::SeqCst);
                }
            }
            region.barrier.wait(); // (B) row/stop published
            if region.stop.load(Ordering::SeqCst) {
                break;
            }
            let i = region.row.load(Ordering::SeqCst);

            // Parallel dot: chunked partial sums (`omp reduce`). The
            // column-ranged storage op keeps the dense path on the exact
            // `dot(&row[lo..hi], &x[lo..hi])` kernel; on CSR it sums only the
            // stored entries that fall in the chunk.
            {
                // SAFETY: x is read-only between barriers (B) and (D).
                let x = unsafe { region.x.as_ref_unchecked() };
                // SAFETY: each thread views and writes only its own padded
                // partials slot.
                let slot = unsafe { region.partials.range_mut_unchecked(t * PAD, t * PAD + 1) };
                slot[0] = system.a.row_dot_range(i, lo, hi, x);
            }
            region.barrier.wait(); // (C) partials ready
            if t == 0 {
                // Combine partials and publish the scale factor.
                // SAFETY: all partials writers passed barrier (C); the slots
                // are read-only until the next iteration's dot phase.
                let partials = unsafe { region.partials.as_ref_unchecked() };
                let mut s = 0.0;
                for r in 0..q {
                    s += partials[r * PAD];
                }
                let scale = self.relaxation * (system.b[i] - s) / system.row_norms_sq[i];
                region.scale_bits.store(scale.to_bits(), Ordering::SeqCst);
            }
            region.barrier.wait(); // (D) scale published
            let scale = f64::from_bits(region.scale_bits.load(Ordering::SeqCst));
            {
                // Parallel update: disjoint chunks (`omp for`), inlining the
                // storage layer's `row_axpy_range` arms shifted onto the
                // chunk view (same element-wise loops, bitwise identical).
                // SAFETY: chunks are disjoint; each thread views and writes
                // only its own `[lo, hi)` range of x.
                let xc = unsafe { region.x.range_mut_unchecked(lo, hi) };
                match system.a.as_dense() {
                    Some(m) => {
                        for (xj, rj) in xc.iter_mut().zip(&m.row(i)[lo..hi]) {
                            *xj += scale * rj;
                        }
                    }
                    None => {
                        for (j, rj) in system.a.row_entries(i) {
                            if (lo..hi).contains(&j) {
                                xc[j - lo] += scale * rj;
                            }
                        }
                    }
                }
            }
            k += 1;
        }

        if t == 0 {
            Some((stopper.expect("thread 0 owns the stopper").into_history(), k))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::solvers::rk::RkSolver;

    #[test]
    fn converges_like_rk() {
        let sys = DatasetBuilder::new(200, 10).seed(1).consistent();
        let r = BlockSequentialRk::new(42, 4).solve(&sys, &SolveOptions::default());
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-8);
    }

    #[test]
    fn identical_chain_to_sequential_rk() {
        // Same seed => same rows => numerically near-identical iterates
        // (chunked dot reassociates the sum, so allow tiny drift).
        let sys = DatasetBuilder::new(150, 8).seed(2).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(400);
        let par = BlockSequentialRk::new(11, 3).solve(&sys, &opts);
        let seq = RkSolver::new(11).solve(&sys, &opts);
        let drift: f64 =
            par.x.iter().zip(&seq.x).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        let scale = seq.x.iter().map(|x| x.abs()).fold(0.0, f64::max);
        assert!(drift < 1e-8 * scale.max(1.0), "drift {drift}");
    }

    #[test]
    fn iteration_count_matches_rk_statistically() {
        // The chain is the same algorithm; iteration counts at equal seeds
        // must be exactly equal (rows identical).
        let sys = DatasetBuilder::new(200, 10).seed(3).consistent();
        let opts = SolveOptions::default();
        let par = BlockSequentialRk::new(7, 2).solve(&sys, &opts);
        let seq = RkSolver::new(7).solve(&sys, &opts);
        assert_eq!(par.iterations, seq.iterations);
    }
}
