//! Parallel RKA — the paper's Algorithm 1, with all four result-gathering
//! strategies of §3.3.1.
//!
//! The whole iteration loop runs inside one parallel region: `q` threads
//! each sample a row, compute the scaled projection against the *previous*
//! iterate `x_prev`, and gather their contributions into the shared `x`.
//! The paper's central finding is that this gather is the bottleneck — it is
//! sequential under the critical section and cache-hostile under every
//! alternative — and this module reproduces all four variants so the claim
//! can be measured:
//!
//! - [`AveragingStrategy::Critical`] — Algorithm 1 as printed: a mutex
//!   serializes `x += scale * A^(row)` (the paper's default and fastest);
//! - [`AveragingStrategy::Atomic`] — per-entry atomic adds, each thread
//!   starting at a different offset; false sharing at chunk boundaries makes
//!   it slower (paper bullet 1);
//! - [`AveragingStrategy::Reduce`] — OpenMP-`reduction` semantics: zero `x`,
//!   accumulate private copies, combine; the zeroing + extra traffic makes
//!   it slower (paper bullet 2);
//! - [`AveragingStrategy::MatrixGather`] — the Fig. 3 (q x n) matrix: each
//!   thread writes its full estimate to a row, then all threads average
//!   disjoint column chunks; the extra barrier + cross-thread cache lines
//!   make it slower (paper bullet 3).

use super::shared::{AtomicF64Vec, SharedSlice, SpinBarrier};
use crate::data::LinearSystem;
use crate::metrics::{History, Stopwatch};
use crate::solvers::rka::Weights;
use crate::solvers::sampling::{RowSampler, SamplingScheme};
use crate::solvers::{SolveOptions, SolveResult, Solver, StopCheck};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// How threads combine their projections into the shared iterate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AveragingStrategy {
    /// Mutex-guarded sequential gather (Algorithm 1 as printed).
    Critical,
    /// Per-entry atomic adds with staggered start offsets.
    Atomic,
    /// OpenMP-`reduction` semantics (zero, accumulate, combine).
    Reduce,
    /// The Fig. 3 gather matrix with parallel column averaging.
    MatrixGather,
}

/// Shared-memory RKA (Algorithm 1).
pub struct ParallelRka {
    /// Base RNG seed (worker `t` derives its own stream).
    pub seed: u32,
    /// Thread count `q`.
    pub q: usize,
    /// Row weights (uniform `alpha` or per-worker partial-matrix alphas).
    pub weights: Weights,
    /// Row-sampling scheme.
    pub scheme: SamplingScheme,
    /// Gather strategy.
    pub strategy: AveragingStrategy,
    /// Worker-pool override (`None` = the process-global pool).
    pool: Option<std::sync::Arc<super::pool::WorkerPool>>,
}

impl ParallelRka {
    /// RKA with uniform weights, full-matrix sampling, critical-section gather.
    pub fn new(seed: u32, q: usize, alpha: f64) -> Self {
        assert!(q >= 1);
        ParallelRka {
            seed,
            q,
            weights: Weights::Uniform(alpha),
            scheme: SamplingScheme::FullMatrix,
            strategy: AveragingStrategy::Critical,
            pool: None,
        }
    }

    /// Select a gather strategy.
    pub fn with_strategy(mut self, strategy: AveragingStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Run on a dedicated pool instead of the process-global one.
    pub fn with_pool(mut self, pool: std::sync::Arc<super::pool::WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Select a sampling scheme.
    pub fn with_scheme(mut self, scheme: SamplingScheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Use per-worker weights. [`Weights::InverseRowNorm`] is rejected: its
    /// per-iteration normalization needs every worker's sampled row, which
    /// the threaded workers never share (use the sequential `RkaSolver`).
    pub fn with_weights(mut self, weights: Weights) -> Self {
        if let Some(len) = weights.len() {
            assert_eq!(len, self.q, "need one weight per worker");
        }
        assert!(
            !matches!(weights, Weights::InverseRowNorm(_)),
            "inverse-row-norm weights are sequential-only (RkaSolver/RkabSolver)"
        );
        self.weights = weights;
        self
    }
}

/// Per-solve shared state visible to every thread.
struct Region {
    x: AtomicF64Vec,
    x_prev: SharedSlice,
    /// Scratch for Reduce (accumulation target) and MatrixGather (q x n rows).
    gather: SharedSlice,
    barrier: SpinBarrier,
    critical: Mutex<()>,
    stop: AtomicBool,
    converged: AtomicBool,
    diverged: AtomicBool,
}

impl Solver for ParallelRka {
    fn name(&self) -> &'static str {
        "RKA-parallel"
    }

    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult {
        let n = system.cols();
        let q = self.q;
        // Fail on the caller's thread, not inside a pool participant (which
        // would strand its peers at the barrier).
        crate::solvers::sampling::assert_partitions_sampleable(system, self.scheme, q);
        let gather_len = match self.strategy {
            AveragingStrategy::MatrixGather => q * n,
            _ => n,
        };
        let region = Region {
            x: AtomicF64Vec::zeros(n),
            x_prev: SharedSlice::zeros(n),
            gather: SharedSlice::zeros(gather_len),
            barrier: SpinBarrier::new(q),
            critical: Mutex::new(()),
            stop: AtomicBool::new(false),
            converged: AtomicBool::new(false),
            diverged: AtomicBool::new(false),
        };
        // One dispatch on the persistent pool = one parallel region; the
        // caller is participant 0 (the paper's "master" thread).
        let sw = Stopwatch::start();
        let report = Mutex::new(None);
        let pool = self.pool.as_deref().unwrap_or_else(|| super::pool::global());
        pool.run(q, |t| {
            let out = self.worker(t, system, opts, &region, &self.weights);
            if let Some(out) = out {
                *report.lock().unwrap() = Some(out);
            }
        });
        let seconds = sw.seconds();

        let (history, iterations) =
            report.into_inner().unwrap().expect("participant 0 reports history");
        SolveResult {
            x: region.x.snapshot(),
            iterations,
            converged: region.converged.load(Ordering::SeqCst),
            diverged: region.diverged.load(Ordering::SeqCst),
            seconds,
            rows_used: iterations * q,
            history,
        }
    }
}

impl ParallelRka {
    /// Body run by every thread of the parallel region. Thread 0 returns the
    /// recorded history and iteration count.
    fn worker(
        &self,
        t: usize,
        system: &LinearSystem,
        opts: &SolveOptions,
        region: &Region,
        weights: &Weights,
    ) -> Option<(History, usize)> {
        let n = system.cols();
        let q = self.q;
        let mut sampler = RowSampler::new(system, self.scheme, t, q, self.seed);
        // Stopping state and history recording live with the thread that
        // decides (thread 0).
        let mut stopper = (t == 0).then(|| StopCheck::new(system, opts));
        // Private buffers (allocated once, reused every iteration).
        let mut local = vec![0.0; n];
        let mut err_buf = vec![0.0; n];
        let mut k = 0usize;

        loop {
            // (A) previous iteration's gather is complete.
            region.barrier.wait();
            if t == 0 {
                // Stopping test + history; the iterate is only snapshotted
                // on iterations where check() will actually read it (off
                // the clock in timed runs, off the hot path between
                // residual checkpoints and history samples).
                let stopper = stopper.as_mut().expect("thread 0 owns the stopper");
                if stopper.needs_iterate_at(k) {
                    region.x.snapshot_into(&mut err_buf);
                }
                let (stop, c, d) = stopper.check(k, &err_buf);
                region.converged.store(c, Ordering::SeqCst);
                region.diverged.store(d, Ordering::SeqCst);
                region.stop.store(stop, Ordering::SeqCst);
            }
            // (B) stop flag published.
            region.barrier.wait();
            if region.stop.load(Ordering::SeqCst) {
                break;
            }

            // x_prev = x, chunked (`omp for` of Algorithm 1 lines 3-4).
            let (lo, hi) = region.x_prev.chunk(t, q);
            {
                // SAFETY: chunks are disjoint and each thread views only its
                // own range; x is only read here (all writers passed
                // barrier B).
                let prev = unsafe { region.x_prev.range_mut_unchecked(lo, hi) };
                for (off, p) in prev.iter_mut().enumerate() {
                    *p = region.x.get(lo + off);
                }
            }
            if matches!(self.strategy, AveragingStrategy::Reduce) {
                // OpenMP `reduction` requires x zeroed before combining.
                for i in lo..hi {
                    region.x.set(i, 0.0);
                }
            }
            // (C) copy complete; x_prev is frozen for this iteration.
            region.barrier.wait();

            // Sample a row and compute the scaled projection (lines 5-6).
            // SAFETY: x_prev is read-only until the next barrier (A).
            let x_prev = unsafe { region.x_prev.as_ref_unchecked() };
            let i = sampler.sample();
            let scale = weights.get(t) * (system.b[i] - system.a.row_dot(i, x_prev))
                / (q as f64 * system.row_norms_sq[i]);
            // Dense storage keeps the exact historical gather loops (bitwise
            // identical); CSR gathers only the row's stored coordinates.
            let dense_row = system.a.as_dense().map(|m| m.row(i));

            match self.strategy {
                AveragingStrategy::Critical => {
                    // Lines 7-9: sequential gather under the critical section.
                    let _guard = region.critical.lock().unwrap();
                    match dense_row {
                        Some(row) => {
                            for j in 0..n {
                                region.x.set(j, region.x.get(j) + scale * row[j]);
                            }
                        }
                        None => {
                            for (j, rj) in system.a.row_entries(i) {
                                region.x.set(j, region.x.get(j) + scale * rj);
                            }
                        }
                    }
                }
                AveragingStrategy::Atomic => {
                    // Staggered start offsets; per-entry atomic adds. The
                    // cache-line invalidation storm this causes is the
                    // paper's explanation for it losing to Critical.
                    match dense_row {
                        Some(row) => {
                            let start = t * n / q;
                            for d in 0..n {
                                let j = if start + d < n { start + d } else { start + d - n };
                                region.x.add(j, scale * row[j]);
                            }
                        }
                        None => {
                            // A sparse row touches few entries; staggering
                            // start offsets buys nothing, so walk in order.
                            for (j, rj) in system.a.row_entries(i) {
                                region.x.add(j, scale * rj);
                            }
                        }
                    }
                }
                AveragingStrategy::Reduce => {
                    // Private partial result: x_prev/q + scale*row (sums over
                    // threads reconstruct eq. 7 after x was zeroed above).
                    let inv_q = 1.0 / q as f64;
                    match dense_row {
                        Some(row) => {
                            for j in 0..n {
                                local[j] = x_prev[j] * inv_q + scale * row[j];
                            }
                        }
                        None => {
                            for j in 0..n {
                                local[j] = x_prev[j] * inv_q;
                            }
                            for (j, rj) in system.a.row_entries(i) {
                                local[j] += scale * rj;
                            }
                        }
                    }
                    let _guard = region.critical.lock().unwrap();
                    for j in 0..n {
                        region.x.set(j, region.x.get(j) + local[j]);
                    }
                }
                AveragingStrategy::MatrixGather => {
                    // Fig. 3: row t of the gather matrix holds this thread's
                    // full estimate x_prev + (q*scale)*A^(row) (the q cancels
                    // in the average, reconstructing eq. 7).
                    {
                        // SAFETY: each thread views and writes only its own
                        // gather row.
                        let mine =
                            unsafe { region.gather.range_mut_unchecked(t * n, (t + 1) * n) };
                        let full_scale = q as f64 * scale;
                        match dense_row {
                            Some(row) => {
                                for j in 0..n {
                                    mine[j] = x_prev[j] + full_scale * row[j];
                                }
                            }
                            None => {
                                mine.copy_from_slice(x_prev);
                                for (j, rj) in system.a.row_entries(i) {
                                    mine[j] += full_scale * rj;
                                }
                            }
                        }
                    }
                    // Extra synchronization point the paper calls out.
                    region.barrier.wait();
                    // Parallel column averaging over disjoint chunks.
                    // SAFETY: all gather-row writers passed the barrier
                    // above; the matrix is read-only until the next
                    // iteration's write phase.
                    let g = unsafe { region.gather.as_ref_unchecked() };
                    let inv_q = 1.0 / q as f64;
                    for j in lo..hi {
                        let mut s = 0.0;
                        for r in 0..q {
                            s += g[r * n + j];
                        }
                        region.x.set(j, s * inv_q);
                    }
                }
            }
            k += 1;
        }

        if t == 0 {
            Some((stopper.expect("thread 0 owns the stopper").into_history(), k))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::solvers::rka::RkaSolver;

    fn all_strategies() -> [AveragingStrategy; 4] {
        [
            AveragingStrategy::Critical,
            AveragingStrategy::Atomic,
            AveragingStrategy::Reduce,
            AveragingStrategy::MatrixGather,
        ]
    }

    #[test]
    fn every_strategy_converges() {
        let sys = DatasetBuilder::new(300, 12).seed(1).consistent();
        for strategy in all_strategies() {
            let r = ParallelRka::new(3, 4, 1.0)
                .with_strategy(strategy)
                .solve(&sys, &SolveOptions::default());
            assert!(r.converged, "{strategy:?} did not converge");
            assert!(sys.error_sq(&r.x) < 1e-8, "{strategy:?} error too big");
        }
    }

    #[test]
    fn matches_sequential_semantics() {
        // Same seeds => same sampled rows => same iterates up to FP
        // reassociation in the gather.
        let sys = DatasetBuilder::new(200, 10).seed(2).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(300);
        let seq = RkaSolver::new(7, 4, 1.0).solve(&sys, &opts);
        for strategy in all_strategies() {
            let par =
                ParallelRka::new(7, 4, 1.0).with_strategy(strategy).solve(&sys, &opts);
            let err: f64 = seq
                .x
                .iter()
                .zip(&par.x)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            let scale = seq.x.iter().map(|v| v.abs()).fold(0.0, f64::max);
            assert!(err < 1e-6 * scale.max(1.0), "{strategy:?} drifted {err} (scale {scale})");
        }
    }

    #[test]
    fn single_thread_equals_rk_stream() {
        let sys = DatasetBuilder::new(100, 8).seed(3).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(200);
        let par = ParallelRka::new(5, 1, 1.0).solve(&sys, &opts);
        let seq = RkaSolver::new(5, 1, 1.0).solve(&sys, &opts);
        for (a, b) in par.x.iter().zip(&seq.x) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn partitioned_sampling_converges() {
        let sys = DatasetBuilder::new(300, 12).seed(4).consistent();
        let r = ParallelRka::new(3, 4, 1.0)
            .with_scheme(SamplingScheme::Partitioned)
            .solve(&sys, &SolveOptions::default());
        assert!(r.converged);
    }

    #[test]
    fn history_recorded_by_thread0() {
        let sys = DatasetBuilder::new(100, 8).seed(5).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(100).with_history_step(25);
        let r = ParallelRka::new(1, 2, 1.0).solve(&sys, &opts);
        assert_eq!(r.history.len(), 5); // k = 0, 25, 50, 75, 100
    }
}
