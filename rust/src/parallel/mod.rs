//! Shared-memory parallel engine — the paper's OpenMP side, rebuilt on
//! `std::thread`.
//!
//! Every method here runs the *whole iteration loop inside one parallel
//! region* (exactly like an OpenMP `parallel` block around the paper's
//! Algorithms 1/3), synchronizing with barriers and a mutex-backed critical
//! section. Regions are dispatched onto the persistent [`pool`] — workers
//! are spawned once per process and reused, so a solve performs zero
//! `thread::spawn` calls on its hot path:
//!
//! - [`pool`] — the persistent worker-pool engine every solver below runs
//!   on (see its docs for the dispatch/ownership protocol);
//! - [`rka_shared`] — Algorithm 1 (RKA) with the paper's four gather
//!   strategies: critical section, atomic entries, reduction, and the
//!   (q x n) gather matrix of Fig. 3;
//! - [`rkab_shared`] — Algorithm 3 (RKAB) with a lock-free deterministic
//!   gather and the fused block-sweep kernel;
//! - [`block_seq`] — §3.2, the block-sequential attempt that parallelizes
//!   the dot product and solution update *inside* each RK iteration;
//! - [`asyrk`] — the HOGWILD!-style lock-free AsyRK baseline (§2.3.3);
//! - [`gemv`] — the pool-parallel residual GEMV behind large-system
//!   stopping/telemetry checks (bitwise-identical row-range split);
//! - [`shared`] — the unsafe-but-disciplined shared buffers and the spin
//!   barrier the engine is built on.
//!
//! All of the above synchronize exclusively through the `sync` shim module,
//! which re-exports `std::sync` on normal builds and the
//! [loom](https://docs.rs/loom) model-checker types under
//! `RUSTFLAGS="--cfg loom"` — `tests/loom.rs` exhaustively explores the
//! barrier, dispatch, and shutdown protocols on every push (see the README
//! "Correctness tooling" section).

pub mod asyrk;
pub mod block_seq;
pub mod gemv;
pub mod pool;
pub mod rka_shared;
pub mod rkab_shared;
pub mod shared;
pub(crate) mod sync;

pub use asyrk::{AsyRkSolver, ShutdownSignal};
pub use block_seq::BlockSequentialRk;
pub use gemv::{residual_gemv_into, residual_gemv_into_with};
pub use pool::WorkerPool;
pub use rka_shared::{AveragingStrategy, ParallelRka};
pub use rkab_shared::ParallelRkab;
pub use shared::{SharedSlice, SpinBarrier};
