//! Persistent worker-pool parallel engine.
//!
//! Every shared-memory solver in this crate runs its whole iteration loop
//! inside one parallel region (an OpenMP `parallel` block in the paper).
//! The seed implementation opened that region with `std::thread::scope`,
//! paying a full spawn+join of `q` OS threads *per solve* — which dominates
//! small-`n` solves and is a non-starter for serving many solve requests
//! back to back. [`WorkerPool`] spawns workers once and reuses them: a solve
//! dispatches a closure to `q - 1` parked workers, runs participant 0 on the
//! calling thread, and parks the workers again afterwards.
//!
//! # Dispatch / ownership protocol
//!
//! Mirroring the [`super::shared::SharedSlice`] protocol docs, the pool has
//! an explicit protocol that makes the lifetime-erasure below sound:
//!
//! 1. `run(q, f)` publishes a type-erased pointer to `f` together with a new
//!    epoch number under the pool mutex, wakes all parked workers, and runs
//!    `f(0)` on the calling thread.
//! 2. A parked worker with identity `t` joins an epoch iff `t < q`; it runs
//!    `f(t)` exactly once and decrements the epoch's `active` count.
//!    Workers with `t >= q` only record the epoch and park again — they
//!    never touch the job pointer.
//! 3. `run` returns only after `active == 0`, i.e. after every participant
//!    has finished executing `f`. The borrow of `f` therefore outlives every
//!    use of the erased pointer, which is what makes step 1 sound.
//! 4. Dispatches are serialized by a separate mutex, so two concurrent
//!    `run` calls on the same pool queue up instead of interleaving epochs.
//!
//! Steps 1–3 are exactly what makes the `'static` lifetime erasure of
//! [`JobPtr`] sound, so they are model-checked rather than trusted:
//! `tests/loom.rs` rebuilds this protocol on the loom primitives behind
//! [`super::sync`] (`RUSTFLAGS="--cfg loom"`) and exhaustively explores its
//! interleavings — including the `t >= q` epoch-skip path — asserting that
//! every participant runs exactly once per epoch and that no worker can
//! still observe the job pointer once `run` has returned.
//!
//! Between solves workers block on a condvar (no CPU burned while parked);
//! *within* a solve, iteration-grained synchronization stays on the solver's
//! own [`super::shared::SpinBarrier`], which is two orders of magnitude
//! cheaper per crossing than a futex wake.
//!
//! Panics in any participant are caught, counted, and re-raised on the
//! calling thread after the epoch drains, so a *completed* epoch never
//! leaves a dangling job pointer behind and a panicked solve does not
//! poison the dispatch mutex for later solves. One limitation is inherited
//! from the scoped-thread formulation this replaces: if a participant
//! panics *out of a barrier-synchronized protocol*, the surviving
//! participants of that solve can keep waiting at their `SpinBarrier` for
//! an arrival that never comes — same hang as with `thread::scope`, but on
//! a shared pool it also blocks later dispatches queued behind the wedged
//! one. Solver closures therefore must not panic between barrier
//! crossings; debug assertions in them are protocol bugs, not recoverable
//! errors. Nested dispatch on the *same* pool from inside a participant is
//! detected and fails fast with a clear message instead of deadlocking
//! (use a dedicated [`WorkerPool`] via the solvers' `with_pool` when
//! composing solvers).
//!
//! The process-wide [`global`] pool grows lazily to the largest `q` ever
//! requested and is shared by [`super::rka_shared::ParallelRka`],
//! [`super::rkab_shared::ParallelRkab`],
//! [`super::block_seq::BlockSequentialRk`],
//! [`super::asyrk::AsyRkSolver`], the simulated-MPI ranks of
//! [`crate::distributed::SimCluster`], and the [`crate::batch`] serving
//! layer: after warm-up, repeated solves perform zero `thread::spawn`
//! calls anywhere in the crate.
//!
//! # Determinism
//!
//! A dispatch hands every participant exactly one call of the current job
//! and nothing else — no stale job pointers, no buffer reuse between
//! epochs — so consecutive solves on one pool are bitwise repeatable
//! whenever the solver itself is deterministic. The crate leans on this:
//! parallel RKAB through its deterministic gather is *bit-identical* to the
//! sequential reference (see [`super::rkab_shared`]), and
//! `tests/parallel_integration.rs` asserts `to_bits()` equality across
//! consecutive dispatches.

#[cfg(not(loom))]
use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::OnceLock;

use super::sync::{thread, Arc, Condvar, Mutex};

#[cfg(not(loom))]
thread_local! {
    /// Identity (PoolInner address) of the pool whose job this thread is
    /// currently executing; 0 when not inside a dispatch. Used to fail fast
    /// on re-entrant dispatch instead of deadlocking on the dispatch mutex.
    static DISPATCHING_POOL: Cell<usize> = Cell::new(0);
}

/// Is the current thread executing inside a [`WorkerPool`] dispatch (as
/// any participant of any pool)?
///
/// Opportunistically-parallel helpers use this to fall back to their
/// serial path instead of attempting a nested `run` on a pool that may be
/// the one currently dispatching (which would fail fast) — e.g. the
/// pool-parallel residual GEMV (`parallel::gemv`) called from a
/// `StopCheck` inside a shared-memory engine's region.
#[cfg(not(loom))]
#[inline]
pub fn in_dispatch() -> bool {
    DISPATCHING_POOL.with(|c| c.get()) != 0
}

/// Loom builds multiplex every model thread onto one scheduler, so a
/// `thread_local!` re-entrance mark would be shared by all of them and
/// report false nesting. The loom suite never nests dispatches, so the
/// guard is compiled out of the model.
#[cfg(loom)]
#[inline]
pub fn in_dispatch() -> bool {
    false
}

/// Run `body` with this thread marked as executing a job of pool `id`,
/// restoring the previous mark afterwards. `body` must not unwind — both
/// call sites pass a `catch_unwind` wrapper, so the restore always runs.
#[cfg(not(loom))]
fn with_dispatch_mark<R>(id: usize, body: impl FnOnce() -> R) -> R {
    let prev = DISPATCHING_POOL.with(|c| c.replace(id));
    let out = body();
    DISPATCHING_POOL.with(|c| c.set(prev));
    out
}

/// No-op under loom (see [`in_dispatch`]).
#[cfg(loom)]
fn with_dispatch_mark<R>(_id: usize, body: impl FnOnce() -> R) -> R {
    body()
}

/// Type-erased handle to the job closure of the current epoch.
///
/// The borrow's lifetime is erased to `'static` at dispatch; the `run`
/// protocol (see module docs) guarantees the pointee outlives every call
/// through the handle, which is what makes the erasure sound. `Send`/`Sync`
/// come for free: a shared reference to a `Sync` closure crosses threads.
#[derive(Clone, Copy)]
struct JobPtr(&'static (dyn Fn(usize) + Sync));

/// Mutable pool state, guarded by `PoolInner::state`.
struct PoolState {
    /// Bumped once per dispatch; workers join an epoch at most once.
    epoch: u64,
    /// Current job, valid for participants of the current epoch only.
    job: Option<JobPtr>,
    /// Participant count of the current epoch (caller + workers `1..q`).
    q: usize,
    /// Workers still executing the current epoch's job.
    active: usize,
    /// Participants of the current epoch that panicked.
    panicked: usize,
    /// Set once on drop; workers exit their loop.
    shutdown: bool,
}

struct PoolInner {
    state: Mutex<PoolState>,
    /// Signaled when a new epoch is published (or on shutdown).
    work_ready: Condvar,
    /// Signaled when the last active worker of an epoch finishes.
    work_done: Condvar,
}

/// A persistent pool of parked worker threads (see module docs).
pub struct WorkerPool {
    inner: Arc<PoolInner>,
    /// Spawned workers (worker `i` has participant identity `i + 1`).
    workers: Mutex<Vec<thread::JoinHandle<()>>>,
    /// Serializes dispatches; held for the whole duration of `run`.
    dispatch: Mutex<()>,
}

impl WorkerPool {
    /// Empty pool; workers are spawned lazily by [`WorkerPool::run`].
    pub fn new() -> Self {
        WorkerPool {
            inner: Arc::new(PoolInner {
                state: Mutex::new(PoolState {
                    epoch: 0,
                    job: None,
                    q: 0,
                    active: 0,
                    panicked: 0,
                    shutdown: false,
                }),
                work_ready: Condvar::new(),
                work_done: Condvar::new(),
            }),
            workers: Mutex::new(Vec::new()),
            dispatch: Mutex::new(()),
        }
    }

    /// Number of resident worker threads (excluding callers).
    pub fn worker_count(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    /// Run `f(t)` for `t in 0..q`: `f(0)` on the calling thread, the rest on
    /// pool workers. Returns after every participant finished. Re-raises the
    /// first panic observed among participants.
    ///
    /// The closure only needs `Fn(usize) + Sync` — participants borrow the
    /// caller's state directly, exactly like a scoped-thread region:
    ///
    /// ```
    /// use kaczmarz::parallel::WorkerPool;
    /// use std::sync::atomic::{AtomicUsize, Ordering};
    ///
    /// let pool = WorkerPool::new();
    /// let hits = AtomicUsize::new(0);
    /// pool.run(4, |_t| {
    ///     hits.fetch_add(1, Ordering::SeqCst);
    /// });
    /// assert_eq!(hits.load(Ordering::SeqCst), 4);
    /// // The workers are parked, not joined: a second dispatch reuses them.
    /// assert_eq!(pool.worker_count(), 3);
    /// ```
    pub fn run<F>(&self, q: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        assert!(q >= 1, "need at least one participant");
        if q == 1 {
            // Degenerate region: no dispatch, no erased pointer.
            f(0);
            return;
        }
        // Pool identity for the re-entrance guard: the address of the
        // shared inner block (stable for the pool's lifetime; works for
        // both the std and loom `Arc`).
        let pool_id = &*self.inner as *const PoolInner as usize;
        // Fail fast on re-entrant dispatch: the outer run() holds the
        // dispatch mutex until its epoch drains, so a nested run() on the
        // same pool could only deadlock. (Nesting on a *different* pool is
        // fine and allowed.)
        #[cfg(not(loom))]
        assert!(
            DISPATCHING_POOL.with(|c| c.get()) != pool_id,
            "nested WorkerPool::run on the same pool from inside a participant would \
             deadlock; give the inner solver a dedicated pool via with_pool"
        );
        // Poison-tolerant acquisition: a previous run that panicked (and was
        // re-raised below) must not brick the pool for later solves.
        let dispatch = self.dispatch.lock().unwrap_or_else(|e| e.into_inner());
        self.ensure_workers(q - 1);

        // Erase the closure's lifetime; sound per the module protocol (the
        // completion wait below outlives every worker's call through it).
        let erased: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: pure lifetime erasure of a fat reference; `run` blocks
        // until `active == 0`, i.e. until no worker can touch it again.
        let job = JobPtr(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                erased,
            )
        });
        {
            let mut st = self.inner.state.lock().unwrap();
            st.job = Some(job);
            st.q = q;
            st.active = q - 1;
            st.panicked = 0;
            st.epoch = st.epoch.wrapping_add(1);
            self.inner.work_ready.notify_all();
        }

        // Participant 0 runs here; catch panics so we always drain workers
        // before unwinding past `f`'s scope.
        let caller_result =
            with_dispatch_mark(pool_id, || catch_unwind(AssertUnwindSafe(|| f(0))));

        let mut st = self.inner.state.lock().unwrap();
        while st.active > 0 {
            st = self.inner.work_done.wait(st).unwrap();
        }
        st.job = None;
        let worker_panics = st.panicked;
        drop(st);
        // Release the dispatch lock *before* re-raising so an unwinding run
        // does not poison it for the next solve on this pool.
        drop(dispatch);

        match caller_result {
            Err(payload) => resume_unwind(payload),
            Ok(()) if worker_panics > 0 => {
                panic!("{worker_panics} pool worker(s) panicked during solve")
            }
            Ok(()) => {}
        }
    }

    /// Grow the resident worker set to at least `needed` threads.
    fn ensure_workers(&self, needed: usize) {
        let mut workers = self.workers.lock().unwrap();
        while workers.len() < needed {
            let t = workers.len() + 1; // participant identity
            let inner = Arc::clone(&self.inner);
            // Named threads on real builds; loom's test scheduler has no
            // thread builder, so the model-checked build spawns plain.
            #[cfg(not(loom))]
            let handle = thread::Builder::new()
                .name(format!("kaczmarz-pool-{t}"))
                .spawn(move || worker_loop(&inner, t))
                .expect("spawn pool worker");
            #[cfg(loom)]
            let handle = thread::spawn(move || worker_loop(&inner, t));
            workers.push(handle);
        }
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::new()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            self.inner.work_ready.notify_all();
        }
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Body of a resident worker with participant identity `t`.
fn worker_loop(inner: &PoolInner, t: usize) {
    let pool_id = inner as *const PoolInner as usize;
    let mut seen_epoch = 0u64;
    loop {
        // Park until a new epoch appears (or shutdown).
        let job = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen_epoch {
                    seen_epoch = st.epoch;
                    if t < st.q {
                        break st.job.expect("epoch published without job");
                    }
                    // Not a participant this epoch; keep parking.
                }
                st = inner.work_ready.wait(st).unwrap();
            }
        };

        // `run` holds the epoch open (active > 0) until we finish, so the
        // closure behind the erased reference is alive; it is `Sync`, so
        // concurrent calls from several workers are allowed.
        let f = job.0;
        let result = with_dispatch_mark(pool_id, || catch_unwind(AssertUnwindSafe(|| f(t))));

        let mut st = inner.state.lock().unwrap();
        if result.is_err() {
            st.panicked += 1;
        }
        st.active -= 1;
        if st.active == 0 {
            inner.work_done.notify_all();
        }
    }
}

/// The process-wide pool shared by all parallel solvers.
///
/// Grows lazily to the largest `q` ever requested and lives for the process
/// lifetime (parked workers cost no CPU). Dispatches are serialized, so
/// concurrent solves queue rather than oversubscribe each other.
///
/// ```
/// let before = kaczmarz::parallel::pool::global().worker_count();
/// kaczmarz::parallel::pool::global().run(2, |_| {});
/// assert!(kaczmarz::parallel::pool::global().worker_count() >= before.max(1));
/// ```
pub fn global() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(WorkerPool::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_participant_exactly_once() {
        let pool = WorkerPool::new();
        for q in [1usize, 2, 3, 8] {
            let hits: Vec<AtomicUsize> = (0..q).map(|_| AtomicUsize::new(0)).collect();
            pool.run(q, |t| {
                hits[t].fetch_add(1, Ordering::SeqCst);
            });
            for (t, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "q={q} t={t}");
            }
        }
    }

    #[test]
    fn workers_are_reused_across_runs() {
        let pool = WorkerPool::new();
        pool.run(4, |_| {});
        let resident = pool.worker_count();
        assert_eq!(resident, 3);
        let runs = if cfg!(miri) { 5 } else { 50 };
        for _ in 0..runs {
            pool.run(4, |_| {});
        }
        // Re-running at the same q spawns nothing new.
        assert_eq!(pool.worker_count(), resident);
    }

    #[test]
    fn pool_grows_to_largest_q_only() {
        let pool = WorkerPool::new();
        pool.run(2, |_| {});
        assert_eq!(pool.worker_count(), 1);
        pool.run(6, |_| {});
        assert_eq!(pool.worker_count(), 5);
        pool.run(3, |_| {});
        assert_eq!(pool.worker_count(), 5);
    }

    #[test]
    fn borrowed_state_is_visible_and_writable() {
        // Participants write disjoint chunks of caller-owned memory.
        let pool = WorkerPool::new();
        let q = 4;
        let n = 1000;
        let data = super::super::shared::SharedSlice::zeros(n);
        pool.run(q, |t| {
            let (lo, hi) = data.chunk(t, q);
            // SAFETY: chunks are disjoint; each participant views only its
            // own range.
            let v = unsafe { data.range_mut_unchecked(lo, hi) };
            for x in v.iter_mut() {
                *x = t as f64 + 1.0;
            }
        });
        let v = data.into_vec();
        assert!(v.iter().all(|&x| x >= 1.0), "some chunk never written");
    }

    #[test]
    fn barrier_phases_work_on_pool_threads() {
        // The solver pattern: per-iteration SpinBarrier phases inside one
        // pool dispatch must synchronize exactly like scoped threads.
        use super::super::shared::SpinBarrier;
        let pool = WorkerPool::new();
        let q = 4;
        let phases = if cfg!(miri) { 3usize } else { 200 };
        let barrier = SpinBarrier::new(q);
        let counter = AtomicUsize::new(0);
        pool.run(q, |_| {
            for phase in 0..phases {
                barrier.wait();
                assert_eq!(counter.load(Ordering::SeqCst) / q, phase);
                barrier.wait();
                counter.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), phases * q);
    }

    #[test]
    fn consecutive_runs_do_not_leak_state() {
        // A worker that skipped an epoch (t >= q) must not fire its stale
        // job later: run at q=6, then q=2, then q=6 again.
        let pool = WorkerPool::new();
        let count = AtomicUsize::new(0);
        pool.run(6, |_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.swap(0, Ordering::SeqCst), 6);
        pool.run(2, |_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.swap(0, Ordering::SeqCst), 2);
        pool.run(6, |_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.swap(0, Ordering::SeqCst), 6);
    }

    #[test]
    fn worker_panic_is_propagated_not_deadlocked() {
        let pool = WorkerPool::new();
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(3, |t| {
                if t == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the caller");
        // The pool must still be usable afterwards.
        let ok = AtomicUsize::new(0);
        pool.run(3, |_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn nested_dispatch_on_same_pool_fails_fast() {
        // Same-pool nesting would block on the dispatch mutex forever; the
        // guard must turn that into an immediate panic...
        let pool = WorkerPool::new();
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(2, |t| {
                if t == 0 {
                    pool.run(2, |_| {});
                }
            });
        }));
        assert!(result.is_err(), "nested same-pool dispatch must panic, not deadlock");
        // ...while different-pool nesting (and the pool itself, afterwards)
        // keeps working.
        let inner_pool = WorkerPool::new();
        let ok = AtomicUsize::new(0);
        pool.run(2, |t| {
            if t == 0 {
                inner_pool.run(2, |_| {
                    ok.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(ok.load(Ordering::SeqCst), 2);
    }

    #[test]
    // The global pool's workers intentionally outlive the test process;
    // Miri reports still-parked threads at exit as a leak.
    #[cfg_attr(miri, ignore)]
    fn global_pool_is_shared() {
        let a = global() as *const WorkerPool;
        let b = global() as *const WorkerPool;
        assert_eq!(a, b);
        global().run(2, |_| {});
        assert!(global().worker_count() >= 1);
    }
}
