//! Loom-swappable synchronization primitives for the parallel engine.
//!
//! Every synchronization type the shared-memory engine is built on —
//! mutexes, condvars, atomics, `Arc`, threads, and the spin hints inside
//! [`super::shared::SpinBarrier`] — is imported through this module
//! instead of `std::sync` directly. A normal build re-exports the `std`
//! types unchanged (zero cost, zero behavior change); a build with
//! `RUSTFLAGS="--cfg loom"` swaps in the [loom](https://docs.rs/loom)
//! model-checker equivalents, under which the `tests/loom.rs` suite
//! exhaustively explores every interleaving (bounded by preemptions) of
//! the barrier, pool-dispatch, and AsyRK-shutdown protocols.
//!
//! What loom adjudicates here is the *synchronization protocol*: that the
//! orderings on [`super::shared::SpinBarrier`] establish happens-before
//! across phases, that [`super::pool::WorkerPool::run`] returns only
//! after every participant's job call completed (no job-pointer
//! use-after-return), and that the [`super::asyrk::ShutdownSignal`]
//! Release/Acquire pairs make the workers' update counts visible to the
//! monitor. The *data discipline* on [`super::shared::SharedSlice`]
//! (disjoint chunked writes through raw views) is per-element and
//! therefore outside loom's vocabulary — the Miri and ThreadSanitizer CI
//! lanes cover that side (see README "Correctness tooling").
//!
//! Keep this module the single chokepoint: new synchronization in
//! `parallel/` must import from here, or the loom lane silently stops
//! covering it.

#[cfg(loom)]
pub(crate) use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
#[cfg(loom)]
pub(crate) use loom::sync::{Arc, Condvar, Mutex};
#[cfg(loom)]
pub(crate) use loom::thread;

#[cfg(not(loom))]
pub(crate) use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
#[cfg(not(loom))]
pub(crate) use std::sync::{Arc, Condvar, Mutex};
#[cfg(not(loom))]
pub(crate) use std::thread;

/// Spin-wait hint: `std::hint::spin_loop` normally; under loom a yield,
/// because loom's scheduler needs an explicit yield point to consider
/// running another thread (a pure spin would never terminate a branch of
/// the exploration).
#[inline]
pub(crate) fn spin_loop_hint() {
    #[cfg(loom)]
    loom::thread::yield_now();
    #[cfg(not(loom))]
    std::hint::spin_loop();
}

/// Yield the timeslice: `std::thread::yield_now` normally, loom's
/// scheduler yield under `cfg(loom)`.
#[inline]
pub(crate) fn yield_now() {
    #[cfg(loom)]
    loom::thread::yield_now();
    #[cfg(not(loom))]
    std::thread::yield_now();
}
