//! AsyRK — the asynchronous (HOGWILD!-style) parallel RK of Liu, Wright &
//! Sridhar, reviewed in §2.3.3 of the paper.
//!
//! Threads never synchronize: each owns a partition of the rows, samples
//! them *without replacement* (reshuffling after each full scan, as the
//! original paper found superior), reads the shared iterate racily, and
//! applies its update with per-entry atomic adds.
//!
//! AsyRK was designed for **sparse** systems, where concurrent updates
//! rarely touch the same entries of `x`. On the dense systems studied here
//! every update touches every entry, so the "memory overwrites are minimal"
//! assumption collapses — this implementation exists as the baseline that
//! demonstrates exactly that (its convergence degrades with thread count and
//! its atomic traffic makes it slow), motivating the paper's synchronous
//! RKA/RKAB line instead.

use super::shared::AtomicF64Vec;
use super::sync::{AtomicBool, AtomicUsize, Ordering};
use crate::data::LinearSystem;
use crate::metrics::Stopwatch;
use crate::rng::{derive_seed, Mt19937};
use crate::solvers::{SolveOptions, SolveResult, Solver, StopCheck};

/// Shutdown/progress protocol between the AsyRK monitor and its workers.
///
/// Three atomics with a pinned ordering protocol (model-checked in
/// `tests/loom.rs`):
///
/// - `stop` — monitor-to-worker shutdown request. `Release` store paired
///   with `Acquire` loads in the worker loop. The original implementation
///   stored `SeqCst` but loaded `Relaxed`; that mix is not a data race
///   (workers read no monitor-owned data after observing `stop`), but it
///   also established no happens-before edge at all, so the `SeqCst` on
///   the store side was pure cost with no pairing. The protocol is now an
///   explicit `Release`/`Acquire` pair, locked by a loom test.
/// - `live` — count of workers still able to update `x`. Workers decrement
///   with `Release` *after* their last update; the monitor reads with
///   `Acquire`. This is the pair the exactness argument rides on: once the
///   monitor observes `live == 0`, every worker's prior (relaxed) update
///   increments are visible, so [`ShutdownSignal::updates`] is the exact
///   final total, not an approximation.
/// - `updates` — global update counter. `Relaxed` increments/reads: the
///   count is monotonic telemetry while workers run (the monitor tolerates
///   staleness by design), and its exact final value is ordered by the
///   `live` pair above or by the pool's own end-of-dispatch handshake.
pub struct ShutdownSignal {
    stop: AtomicBool,
    live: AtomicUsize,
    updates: AtomicUsize,
}

impl ShutdownSignal {
    /// Fresh protocol state for `workers` live workers.
    pub fn new(workers: usize) -> Self {
        ShutdownSignal {
            stop: AtomicBool::new(false),
            live: AtomicUsize::new(workers),
            updates: AtomicUsize::new(0),
        }
    }

    /// Monitor side: request all workers to stop (Release).
    #[inline]
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Release);
    }

    /// Worker side: has a stop been requested? (Acquire, pairing with
    /// [`ShutdownSignal::request_stop`].)
    #[inline]
    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }

    /// Worker side: count one completed row update (Relaxed; see type
    /// docs for why relaxed is sufficient).
    #[inline]
    pub fn record_update(&self) {
        self.updates.fetch_add(1, Ordering::Relaxed);
    }

    /// Total updates recorded so far (Relaxed). While workers are live
    /// this is a monotonic lower bound; after [`ShutdownSignal::live_workers`]
    /// returned 0 (or the dispatch that ran the workers completed) it is
    /// the exact final count.
    #[inline]
    pub fn updates(&self) -> usize {
        self.updates.load(Ordering::Relaxed)
    }

    /// Worker side: announce that this worker will never update again
    /// (Release — publishes all of the worker's prior updates).
    #[inline]
    pub fn worker_exit(&self) {
        self.live.fetch_sub(1, Ordering::Release);
    }

    /// Monitor side: workers still able to produce updates (Acquire,
    /// pairing with [`ShutdownSignal::worker_exit`]).
    #[inline]
    pub fn live_workers(&self) -> usize {
        self.live.load(Ordering::Acquire)
    }
}

/// Lock-free asynchronous RK (HOGWILD! scheme).
pub struct AsyRkSolver {
    /// Base RNG seed.
    pub seed: u32,
    /// Thread count.
    pub threads: usize,
    /// Step size multiplier (the AsyRK theory requires a conservative step;
    /// 1.0 reproduces plain projections).
    pub step: f64,
    /// Worker-pool override (`None` = the process-global pool).
    pool: Option<std::sync::Arc<super::pool::WorkerPool>>,
}

impl AsyRkSolver {
    /// AsyRK with full projection steps.
    pub fn new(seed: u32, threads: usize) -> Self {
        assert!(threads >= 1);
        AsyRkSolver { seed, threads, step: 1.0, pool: None }
    }

    /// Override the step size.
    pub fn with_step(mut self, step: f64) -> Self {
        assert!(step > 0.0 && step <= 1.0);
        self.step = step;
        self
    }

    /// Run on a dedicated pool instead of the process-global one.
    pub fn with_pool(mut self, pool: std::sync::Arc<super::pool::WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }
}

impl Solver for AsyRkSolver {
    fn name(&self) -> &'static str {
        "AsyRK"
    }

    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult {
        let n = system.cols();
        let q = self.threads;
        let x = AtomicF64Vec::zeros(n);
        // Stop request, live-worker count ("when this hits zero nothing can
        // ever update x again"), and the global update counter — with the
        // orderings documented and loom-checked on [`ShutdownSignal`].
        let signal = ShutdownSignal::new(q);

        // Monitor cadence: poll for convergence every `poll_every` global
        // updates (the async loop has no natural iteration boundary, so the
        // criterion's own `check_every` does not apply — the monitor's
        // polling backoff plays that role here).
        let poll_every = (q * 32).max(64);
        let budget = opts.fixed_iterations.unwrap_or(opts.max_iterations);
        let timed = opts.fixed_iterations.is_some();

        // One pool dispatch with q + 1 participants: participant 0 (the
        // calling thread) is the monitor, participants 1..=q run the
        // HOGWILD loop on partition t - 1.
        let sw = Stopwatch::start();
        let monitor_out = std::sync::Mutex::new(None);
        let pool = self.pool.as_deref().unwrap_or_else(|| super::pool::global());
        pool.run(q + 1, |part| {
            if part == 0 {
                // Monitor: stopping test + history, then release the
                // workers. The async loop has no iteration boundary, so the
                // monitor drives StopCheck's recorder directly on its own
                // polling cadence (update counts, not iteration numbers).
                let step = opts.history_step;
                let mut stopper = StopCheck::new(system, opts);
                let mut converged = false;
                let mut diverged = false;
                let mut xbuf = vec![0.0; n];
                if !timed {
                    // Pin the divergence baseline at the true x^(0) = 0
                    // (xbuf is still zeroed — deliberately NOT a snapshot:
                    // the HOGWILD workers are already mutating x, and a racy
                    // first snapshot would make the baseline, and thus the
                    // divergence threshold, scheduling-dependent).
                    let (c, d) = stopper.check_baseline(&xbuf);
                    converged = c;
                    diverged = d;
                }
                let mut last_recorded = usize::MAX;
                while !converged && !diverged {
                    // Cooperative halt (cancel / deadline token): the async
                    // engine's checkpoint is the monitor poll, so the token
                    // is consulted here — workers are then stopped through
                    // the normal shutdown signal below.
                    if stopper.halt_requested() {
                        break;
                    }
                    let done = signal.updates();
                    let tick = if step > 0 { done / step } else { 0 };
                    let record = step > 0 && tick != last_recorded;
                    // Timed runs without history never materialize the
                    // iterate (nor any metric): the budget is the only stop.
                    if !timed || record {
                        x.snapshot_into(&mut xbuf);
                    }
                    let recorded_residual_sq = if record {
                        last_recorded = tick;
                        Some(stopper.record_sample(done, &xbuf))
                    } else {
                        None
                    };
                    if !timed {
                        // Reuse the recorder's residual when it is also the
                        // stopping metric (xbuf has not moved since). Under
                        // residual stopping each poll is also a telemetry
                        // checkpoint, labelled with the global update count.
                        let (c, d) = stopper.check_now_reusing(done, &xbuf, recorded_residual_sq);
                        if c || d {
                            converged = c;
                            diverged = d;
                            break;
                        }
                    }
                    if done >= budget {
                        // Budget exhausted: nothing was measured in timed
                        // runs, the tolerance was missed in criterion runs —
                        // either way, not converged.
                        break;
                    }
                    if signal.live_workers() == 0 {
                        // Every worker exited (all partitions degenerate):
                        // no update can ever arrive, so stop un-converged
                        // instead of spinning forever.
                        break;
                    }
                    // Light backoff so the monitor does not saturate a core.
                    for _ in 0..poll_every {
                        std::hint::spin_loop();
                    }
                }
                signal.request_stop();
                *monitor_out.lock().unwrap() =
                    Some((stopper.into_history(), converged, diverged));
            } else {
                // HOGWILD worker on partition t of q.
                let t = part - 1;
                let mut rng = Mt19937::new(derive_seed(self.seed, t));
                let (lo, hi) = system.row_partition(t, q);
                // Sampling without replacement: shuffle own rows, scan,
                // reshuffle (the AsyRK recipe). Degenerate (zero-norm) rows
                // are dropped up front — projecting on one divides by zero.
                let mut order: Vec<usize> =
                    (lo..hi).filter(|&i| system.row_norms_sq[i] > 0.0).collect();
                if !order.is_empty() {
                    rng.shuffle(&mut order);
                    let mut pos = 0usize;
                    let mut xbuf = vec![0.0; n];
                    while !signal.should_stop() {
                        if pos == order.len() {
                            rng.shuffle(&mut order);
                            pos = 0;
                        }
                        let i = order[pos];
                        pos += 1;
                        // Racy read of x (the HOGWILD ingredient).
                        x.snapshot_into(&mut xbuf);
                        let scale = self.step * (system.b[i] - system.a.row_dot(i, &xbuf))
                            / system.row_norms_sq[i];
                        // Lock-free update: per-entry atomic adds. On CSR
                        // storage only the stored coordinates are touched —
                        // the regime AsyRK was actually designed for, where
                        // concurrent updates rarely collide.
                        for (j, rj) in system.a.row_entries(i) {
                            x.add(j, scale * rj);
                        }
                        signal.record_update();
                    }
                }
                // Signal the monitor this worker can no longer make progress
                // (normal stop, or a partition with nothing but zero rows).
                signal.worker_exit();
            }
        });
        let seconds = sw.seconds();
        let (history, converged, diverged) =
            monitor_out.into_inner().unwrap().expect("monitor reports outcome");
        // Exact: every worker has exited (the pool's end-of-dispatch
        // handshake orders their counter increments before this read).
        let iterations = signal.updates();

        SolveResult {
            x: x.snapshot(),
            iterations,
            converged,
            diverged,
            seconds,
            rows_used: iterations,
            history,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;

    #[test]
    fn converges_single_thread() {
        let sys = DatasetBuilder::new(200, 10).seed(1).consistent();
        let r = AsyRkSolver::new(3, 1).solve(&sys, &SolveOptions::default());
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-6);
    }

    #[test]
    fn converges_multithreaded_on_small_system() {
        // Dense HOGWILD still converges (slowly) at low thread counts.
        let sys = DatasetBuilder::new(200, 10).seed(2).consistent();
        let opts = SolveOptions::default().with_tolerance(1e-6).with_max_iterations(2_000_000);
        let r = AsyRkSolver::new(3, 4).solve(&sys, &opts);
        assert!(r.converged, "async run did not converge in {} updates", r.iterations);
    }

    #[test]
    fn all_degenerate_partitions_terminate_unconverged() {
        // Regression: a system whose every row has zero norm leaves all
        // workers with nothing to project; the monitor must notice the
        // workers exiting and stop instead of waiting on the budget forever
        // (which would also wedge the shared pool dispatch).
        use crate::linalg::Matrix;
        let sys = crate::data::LinearSystem::new(
            Matrix::zeros(8, 4),
            vec![0.0; 8],
            Some(vec![1.0; 4]),
            true,
        );
        let opts = SolveOptions::default().with_fixed_iterations(100);
        let r = AsyRkSolver::new(1, 2).solve(&sys, &opts);
        assert_eq!(r.iterations, 0);
        assert!(!r.converged);
    }

    #[test]
    fn respects_update_budget() {
        let sys = DatasetBuilder::new(100, 8).seed(3).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(5_000);
        let r = AsyRkSolver::new(3, 2).solve(&sys, &opts);
        // Async workers overshoot by whatever lands between monitor checks;
        // it must be the same order of magnitude, not unbounded.
        assert!(r.iterations >= 5_000);
        assert!(r.iterations < 4 * 5_000, "overshoot {}", r.iterations);
    }
}
