//! Parallel RKAB — the paper's Algorithm 3 on the persistent worker pool.
//!
//! Each participant copies the shared iterate into a *private* estimate `v`,
//! applies `block_size` sequential Kaczmarz projections to it (through the
//! fused-kernel sweep shared with the sequential reference — see
//! [`crate::solvers::rkab::block_sweep`]), publishes `v` as row `t` of a
//! `(q x n)` gather buffer, and after a barrier all participants average
//! disjoint column chunks back into `x`. Communication happens once per
//! block instead of once per row — the point of the method (§3.4.2,
//! Table 2).
//!
//! The gather is deliberately *not* Algorithm 1's critical section: summing
//! gather rows in ascending `t` over disjoint column chunks is lock-free,
//! parallel, and associates the floating-point sum exactly like the
//! sequential reference's accumulation loop — so a parallel solve is
//! **bit-identical** to [`crate::solvers::rkab::RkabSolver`] at equal seeds
//! (asserted in `tests/parallel_integration.rs`), which is what makes the
//! pool's no-state-leakage guarantee testable at all.

use super::shared::{SharedSlice, SpinBarrier};
use crate::data::LinearSystem;
use crate::linalg::vector::{axpy, scale_in_place};
use crate::metrics::{History, Stopwatch};
use crate::solvers::rkab::block_sweep;
use crate::solvers::sampling::{RowSampler, SamplingScheme};
use crate::solvers::{SolveOptions, SolveResult, Solver, StopCheck};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Shared-memory RKAB (Algorithm 3).
pub struct ParallelRkab {
    /// Base RNG seed (worker `t` derives its own stream).
    pub seed: u32,
    /// Thread count `q`.
    pub q: usize,
    /// Rows each thread processes between gathers (`bs`).
    pub block_size: usize,
    /// Uniform relaxation weight applied inside the block sweep.
    pub alpha: f64,
    /// Row-sampling scheme.
    pub scheme: SamplingScheme,
    /// Worker-pool override (`None` = the process-global pool).
    pool: Option<std::sync::Arc<super::pool::WorkerPool>>,
}

struct Region {
    /// Shared iterate; written in disjoint column chunks after barrier (C).
    x: SharedSlice,
    /// (q x n) block estimates; row `t` owned by participant `t`.
    gather: SharedSlice,
    barrier: SpinBarrier,
    stop: AtomicBool,
    converged: AtomicBool,
    diverged: AtomicBool,
}

impl ParallelRkab {
    /// RKAB with full-matrix sampling.
    pub fn new(seed: u32, q: usize, block_size: usize, alpha: f64) -> Self {
        assert!(q >= 1 && block_size >= 1);
        ParallelRkab { seed, q, block_size, alpha, scheme: SamplingScheme::FullMatrix, pool: None }
    }

    /// Select a sampling scheme.
    pub fn with_scheme(mut self, scheme: SamplingScheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Run on a dedicated pool instead of the process-global one.
    pub fn with_pool(mut self, pool: std::sync::Arc<super::pool::WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }
}

impl Solver for ParallelRkab {
    fn name(&self) -> &'static str {
        "RKAB-parallel"
    }

    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult {
        let n = system.cols();
        let q = self.q;
        // Fail on the caller's thread, not inside a pool participant (which
        // would strand its peers at the barrier).
        crate::solvers::sampling::assert_partitions_sampleable(system, self.scheme, q);
        let region = Region {
            x: SharedSlice::zeros(n),
            gather: SharedSlice::zeros(q * n),
            barrier: SpinBarrier::new(q),
            stop: AtomicBool::new(false),
            converged: AtomicBool::new(false),
            diverged: AtomicBool::new(false),
        };
        // One dispatch on the persistent pool = one parallel region.
        let sw = Stopwatch::start();
        let report = Mutex::new(None);
        let pool = self.pool.as_deref().unwrap_or_else(|| super::pool::global());
        pool.run(q, |t| {
            let out = self.worker(t, system, opts, &region);
            if let Some(out) = out {
                *report.lock().unwrap() = Some(out);
            }
        });
        let seconds = sw.seconds();

        let (history, iterations) =
            report.into_inner().unwrap().expect("participant 0 reports history");
        SolveResult {
            x: region.x.into_vec(),
            iterations,
            converged: region.converged.load(Ordering::SeqCst),
            diverged: region.diverged.load(Ordering::SeqCst),
            seconds,
            rows_used: iterations * q * self.block_size,
            history,
        }
    }
}

impl ParallelRkab {
    fn worker(
        &self,
        t: usize,
        system: &LinearSystem,
        opts: &SolveOptions,
        region: &Region,
    ) -> Option<(History, usize)> {
        let n = system.cols();
        let q = self.q;
        let mut sampler = RowSampler::new(system, self.scheme, t, q, self.seed);
        // Stopping state and history recording live with the thread that
        // decides (thread 0).
        let mut stopper = (t == 0).then(|| StopCheck::new(system, opts));
        let mut v = vec![0.0; n]; // private block estimate
        let mut idx = Vec::with_capacity(self.block_size); // sweep scratch
        let mut k = 0usize;
        let (lo, hi) = region.x.chunk(t, q);
        let inv_q = 1.0 / q as f64;

        loop {
            // (A) previous iteration's chunked writes to x are complete.
            region.barrier.wait();
            if t == 0 {
                // SAFETY: all writers passed barrier (A); x is stable.
                let x = unsafe { region.x.as_ref_unchecked() };
                let stopper = stopper.as_mut().expect("thread 0 owns the stopper");
                let (stop, c, d) = stopper.check(k, x);
                region.converged.store(c, Ordering::SeqCst);
                region.diverged.store(d, Ordering::SeqCst);
                region.stop.store(stop, Ordering::SeqCst);
            }
            // (B) stop flag published.
            region.barrier.wait();
            if region.stop.load(Ordering::SeqCst) {
                break;
            }

            {
                // v = x^(k), then bs sequential projections on v (eq. 8;
                // Algorithm 3 lines 3-11) through the shared fused sweep.
                // SAFETY: x is read-only until every thread passes (C).
                let x = unsafe { region.x.as_ref_unchecked() };
                v.copy_from_slice(x);
            }
            block_sweep(system, &mut sampler, self.block_size, self.alpha, &mut v, &mut idx);
            {
                // Publish v as gather row t.
                // SAFETY: each thread views and writes only its own row.
                let mine = unsafe { region.gather.range_mut_unchecked(t * n, (t + 1) * n) };
                mine.copy_from_slice(&v);
            }
            // (C) every block estimate published; nobody reads x anymore.
            region.barrier.wait();
            {
                // x^(k+1) = (1/q) Σ_t v_t (eq. 9) over this thread's column
                // chunk, accumulated with t outermost so the inner loops run
                // contiguous (vectorizable) instead of striding across
                // gather rows. Per element the sum still associates in
                // ascending t with one final inv_q multiply — exactly the
                // sequential reference's float association.
                // SAFETY: gather rows are frozen until the next iteration's
                // sweep, which every thread only reaches after barrier
                // (A)+(B) — i.e. after all reads here.
                let g = unsafe { region.gather.as_ref_unchecked() };
                // SAFETY: column chunks are disjoint; each thread views and
                // writes only its own `[lo, hi)` range of x.
                let xc = unsafe { region.x.range_mut_unchecked(lo, hi) };
                xc.fill(0.0);
                for r in 0..q {
                    axpy(1.0, &g[r * n + lo..r * n + hi], xc);
                }
                scale_in_place(xc, inv_q);
            }
            k += 1;
        }

        if t == 0 {
            Some((stopper.expect("thread 0 owns the stopper").into_history(), k))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::solvers::rkab::RkabSolver;

    #[test]
    fn converges_on_consistent_system() {
        let sys = DatasetBuilder::new(300, 12).seed(1).consistent();
        let r = ParallelRkab::new(3, 4, 12, 1.0).solve(&sys, &SolveOptions::default());
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-8);
        assert_eq!(r.rows_used, r.iterations * 4 * 12);
    }

    #[test]
    fn matches_sequential_bitwise() {
        // The deterministic gather reproduces the sequential reference's
        // float association exactly — not just within tolerance.
        let sys = DatasetBuilder::new(200, 10).seed(2).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(50);
        let seq = RkabSolver::new(7, 4, 8, 1.0).solve(&sys, &opts);
        let par = ParallelRkab::new(7, 4, 8, 1.0).solve(&sys, &opts);
        assert_eq!(seq.iterations, par.iterations);
        for (a, b) in seq.x.iter().zip(&par.x) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn partitioned_sampling_converges() {
        let sys = DatasetBuilder::new(300, 12).seed(3).consistent();
        let r = ParallelRkab::new(3, 4, 12, 1.0)
            .with_scheme(SamplingScheme::Partitioned)
            .solve(&sys, &SolveOptions::default());
        assert!(r.converged);
    }

    #[test]
    fn block_size_one_matches_parallel_rka() {
        use crate::parallel::rka_shared::ParallelRka;
        let sys = DatasetBuilder::new(150, 8).seed(4).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(100);
        let a = ParallelRkab::new(9, 3, 1, 1.0).solve(&sys, &opts);
        let b = ParallelRka::new(9, 3, 1.0).solve(&sys, &opts);
        let drift: f64 = a.x.iter().zip(&b.x).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
        let scale = b.x.iter().map(|x| x.abs()).fold(0.0, f64::max);
        assert!(drift < 1e-6 * scale.max(1.0), "drift {drift}");
    }
}
