//! Parallel RKAB — the paper's Algorithm 3.
//!
//! Each thread copies the shared iterate into a *private* estimate `v`,
//! applies `block_size` sequential Kaczmarz projections to it, subtracts the
//! shared iterate (so only the difference is gathered), and after a barrier
//! adds `v/q` to the shared `x` under the critical section. Communication
//! happens once per block instead of once per row — the point of the method.
//!
//! The gather is still the critical section of Algorithm 1, but it now costs
//! O(q·n) once per `block_size` row updates instead of once per row update,
//! which is why RKAB parallelizes where RKA does not (§3.4.2, Table 2).

use super::shared::{AtomicF64Vec, SpinBarrier};
use crate::data::LinearSystem;
use crate::linalg::vector::{axpy, dot};
use crate::metrics::{History, Stopwatch};
use crate::solvers::sampling::{RowSampler, SamplingScheme};
use crate::solvers::{stop_check, SolveOptions, SolveResult, Solver};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Shared-memory RKAB (Algorithm 3).
pub struct ParallelRkab {
    /// Base RNG seed (worker `t` derives its own stream).
    pub seed: u32,
    /// Thread count `q`.
    pub q: usize,
    /// Rows each thread processes between gathers (`bs`).
    pub block_size: usize,
    /// Uniform relaxation weight applied inside the block sweep.
    pub alpha: f64,
    /// Row-sampling scheme.
    pub scheme: SamplingScheme,
}

struct Region {
    x: AtomicF64Vec,
    barrier: SpinBarrier,
    critical: Mutex<()>,
    stop: AtomicBool,
    converged: AtomicBool,
    diverged: AtomicBool,
}

impl ParallelRkab {
    /// RKAB with full-matrix sampling.
    pub fn new(seed: u32, q: usize, block_size: usize, alpha: f64) -> Self {
        assert!(q >= 1 && block_size >= 1);
        ParallelRkab { seed, q, block_size, alpha, scheme: SamplingScheme::FullMatrix }
    }

    /// Select a sampling scheme.
    pub fn with_scheme(mut self, scheme: SamplingScheme) -> Self {
        self.scheme = scheme;
        self
    }
}

impl Solver for ParallelRkab {
    fn name(&self) -> &'static str {
        "RKAB-parallel"
    }

    fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> SolveResult {
        let n = system.cols();
        let q = self.q;
        let region = Region {
            x: AtomicF64Vec::zeros(n),
            barrier: SpinBarrier::new(q),
            critical: Mutex::new(()),
            stop: AtomicBool::new(false),
            converged: AtomicBool::new(false),
            diverged: AtomicBool::new(false),
        };
        let initial_err = system.error_sq(&vec![0.0; n]);
        let timed = opts.fixed_iterations.is_some();

        let sw = Stopwatch::start();
        let mut histories: Vec<Option<(History, usize)>> = Vec::new();
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(q);
            for t in 0..q {
                let region = &region;
                handles.push(scope.spawn(move || {
                    self.worker(t, system, opts, region, initial_err, timed)
                }));
            }
            for h in handles {
                histories.push(h.join().expect("worker panicked"));
            }
        });
        let seconds = sw.seconds();

        let (history, iterations) =
            histories.into_iter().flatten().next().expect("thread 0 reports history");
        SolveResult {
            x: region.x.snapshot(),
            iterations,
            converged: region.converged.load(Ordering::SeqCst),
            diverged: region.diverged.load(Ordering::SeqCst),
            seconds,
            rows_used: iterations * q * self.block_size,
            history,
        }
    }
}

impl ParallelRkab {
    fn worker(
        &self,
        t: usize,
        system: &LinearSystem,
        opts: &SolveOptions,
        region: &Region,
        initial_err: f64,
        timed: bool,
    ) -> Option<(History, usize)> {
        let n = system.cols();
        let q = self.q;
        let mut sampler = RowSampler::new(system, self.scheme, t, q, self.seed);
        let mut history = History::every(if t == 0 { opts.history_step } else { 0 });
        let mut v = vec![0.0; n]; // private block estimate
        let mut err_buf = vec![0.0; n];
        let mut k = 0usize;

        loop {
            // (A) previous gather complete.
            region.barrier.wait();
            if t == 0 {
                let err = if !timed || history.due(k) {
                    region.x.snapshot_into(&mut err_buf);
                    system.error_sq(&err_buf)
                } else {
                    f64::NAN
                };
                if history.due(k) {
                    history.record(k, err.sqrt(), system.residual_norm(&err_buf));
                }
                let (stop, c, d) = stop_check(opts, k, err, initial_err);
                region.converged.store(c, Ordering::SeqCst);
                region.diverged.store(d, Ordering::SeqCst);
                region.stop.store(stop, Ordering::SeqCst);
            }
            // (B) stop flag published.
            region.barrier.wait();
            if region.stop.load(Ordering::SeqCst) {
                break;
            }

            // v = x^(k), then block_size sequential projections on v (eq. 8;
            // Algorithm 3 lines 3-11). x is read-only in this phase.
            for i in 0..n {
                v[i] = region.x.get(i);
            }
            for _ in 0..self.block_size {
                let i = sampler.sample();
                let row = system.a.row(i);
                let scale = self.alpha * (system.b[i] - dot(row, &v)) / system.row_norms_sq[i];
                axpy(scale, row, &mut v);
            }
            // v -= x (lines 12-13), so the gather sums only differences.
            for i in 0..n {
                v[i] -= region.x.get(i);
            }
            // Line 14: nobody may update x while others still read it above.
            region.barrier.wait();
            {
                // Lines 15-17: x += v/q under the critical section.
                let _guard = region.critical.lock().unwrap();
                let inv_q = 1.0 / q as f64;
                for i in 0..n {
                    region.x.set(i, region.x.get(i) + v[i] * inv_q);
                }
            }
            k += 1;
        }

        if t == 0 {
            Some((history, k))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::solvers::rkab::RkabSolver;

    #[test]
    fn converges_on_consistent_system() {
        let sys = DatasetBuilder::new(300, 12).seed(1).consistent();
        let r = ParallelRkab::new(3, 4, 12, 1.0).solve(&sys, &SolveOptions::default());
        assert!(r.converged);
        assert!(sys.error_sq(&r.x) < 1e-8);
        assert_eq!(r.rows_used, r.iterations * 4 * 12);
    }

    #[test]
    fn matches_sequential_semantics() {
        let sys = DatasetBuilder::new(200, 10).seed(2).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(50);
        let seq = RkabSolver::new(7, 4, 8, 1.0).solve(&sys, &opts);
        let par = ParallelRkab::new(7, 4, 8, 1.0).solve(&sys, &opts);
        let drift: f64 =
            seq.x.iter().zip(&par.x).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        let scale = seq.x.iter().map(|x| x.abs()).fold(0.0, f64::max);
        assert!(drift < 1e-6 * scale.max(1.0), "drift {drift}");
    }

    #[test]
    fn partitioned_sampling_converges() {
        let sys = DatasetBuilder::new(300, 12).seed(3).consistent();
        let r = ParallelRkab::new(3, 4, 12, 1.0)
            .with_scheme(SamplingScheme::Partitioned)
            .solve(&sys, &SolveOptions::default());
        assert!(r.converged);
    }

    #[test]
    fn block_size_one_matches_parallel_rka() {
        use crate::parallel::rka_shared::ParallelRka;
        let sys = DatasetBuilder::new(150, 8).seed(4).consistent();
        let opts = SolveOptions::default().with_fixed_iterations(100);
        let a = ParallelRkab::new(9, 3, 1, 1.0).solve(&sys, &opts);
        let b = ParallelRka::new(9, 3, 1.0).solve(&sys, &opts);
        let drift: f64 = a.x.iter().zip(&b.x).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
        let scale = b.x.iter().map(|x| x.abs()).fold(0.0, f64::max);
        assert!(drift < 1e-6 * scale.max(1.0), "drift {drift}");
    }
}
