//! Pool-parallel residual GEMV.
//!
//! Residual stopping and `ProgressSink` telemetry both reduce to one
//! `y = A x` over the full system per checkpoint. At the paper's target
//! scale (100k x 10k dense) that is ~8 GB of row traffic per check —
//! serial, it dwarfs the amortized cost the checkpoint schedule was
//! designed to hide. This module splits the *row range* across the
//! persistent [`WorkerPool`] instead:
//!
//! - each participant computes a contiguous row chunk
//!   `[⌊t·m/q⌋, ⌊(t+1)·m/q⌋)` (the same partition formula the
//!   distributed samplers use) into its disjoint slice of `y`;
//! - within a chunk the dense kernel walks column panels in the exact
//!   panel-major order of the serial blocked GEMV, so every output
//!   element accumulates its partial dots in the same order as the
//!   serial kernel — the parallel result is *bitwise identical*,
//!   element for element, regardless of `q`;
//! - the auto entry point [`residual_gemv_into`] only goes parallel when
//!   it is safe and worth it: never from inside an existing pool
//!   dispatch (a `StopCheck` fired by a shared-memory engine's
//!   participant 0 falls back to the serial kernel — see
//!   [`pool::in_dispatch`]), and never below
//!   [`PARALLEL_GEMV_MIN_ELEMS`], where dispatch overhead beats the
//!   memory-bandwidth win.

use super::pool::{self, WorkerPool};
use crate::linalg::gemv::{gemv_block_rows_with_panel, gemv_panel};
use crate::linalg::{RowStorage, Storage};

/// Smallest `rows * cols` for which [`residual_gemv_into`] dispatches to
/// the pool: 2²¹ f64 elements (16 MiB of matrix) — below that the serial
/// blocked kernel finishes before a dispatch epoch settles.
pub const PARALLEL_GEMV_MIN_ELEMS: usize = 1 << 21;

/// `y = A x` for residual checks: pool-parallel across rows when safe and
/// large enough, otherwise the serial blocked kernel. The result is
/// bitwise identical to [`RowStorage::gemv_block_into`] either way.
///
/// Dispatches on the process-wide [`pool::global`] pool with one
/// participant per hardware thread, clamped to the row count.
pub fn residual_gemv_into(a: &Storage, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), a.cols());
    debug_assert_eq!(y.len(), a.rows());
    let elems = a.rows().saturating_mul(a.cols());
    let q = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(a.rows());
    if q < 2 || elems < PARALLEL_GEMV_MIN_ELEMS || pool::in_dispatch() {
        a.gemv_block_into(x, y);
        return;
    }
    residual_gemv_into_with(a, x, y, pool::global(), q);
}

/// Explicit-pool flavor of [`residual_gemv_into`] (tests and callers that
/// own a dedicated pool). `q` participants, clamped to `[1, rows]`;
/// `q <= 1` runs the serial kernel. Must not be called from inside a
/// dispatch on `pool` (the nested-dispatch fail-fast in
/// [`WorkerPool::run`] applies).
pub fn residual_gemv_into_with(
    a: &Storage,
    x: &[f64],
    y: &mut [f64],
    pool: &WorkerPool,
    q: usize,
) {
    debug_assert_eq!(x.len(), a.cols());
    debug_assert_eq!(y.len(), a.rows());
    let m = a.rows();
    let q = q.clamp(1, m.max(1));
    if q < 2 {
        a.gemv_block_into(x, y);
        return;
    }
    let panel = gemv_panel();
    // Participants write disjoint row ranges of `y` through a raw base
    // pointer: the usual scoped-region pattern this crate's shared-memory
    // engines use, with the disjointness protocol spelled out below.
    let base = SendPtr(y.as_mut_ptr());
    pool.run(q, |t| {
        // The same ⌊t·m/q⌋ contiguous partition as `row_partition`:
        // chunks tile [0, m) exactly, so no two participants overlap.
        let lo = t * m / q;
        let hi = (t + 1) * m / q;
        if lo == hi {
            return;
        }
        // SAFETY: `y` outlives the dispatch (`run` blocks until every
        // participant finishes), and `[lo, hi)` ranges are pairwise
        // disjoint across participants, so each reconstructed slice is
        // the only live mutable view of those elements.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
        match a {
            Storage::Dense(mat) => gemv_block_rows_with_panel(mat, x, chunk, lo, panel),
            Storage::Csr(mat) => {
                for (k, yi) in chunk.iter_mut().enumerate() {
                    *yi = RowStorage::row_dot(mat, lo + k, x);
                }
            }
        }
    });
}

/// Raw `*mut f64` made shareable across the dispatch (see the SAFETY
/// protocol at the use site).
#[derive(Clone, Copy)]
struct SendPtr(*mut f64);

// SAFETY: the pointer is only dereferenced through disjoint per-participant
// ranges while the owning slice is pinned by the blocking dispatch.
unsafe impl Send for SendPtr {}
// SAFETY: same protocol as the Send impl above — shared copies only ever
// dereference pairwise-disjoint ranges during the blocking dispatch.
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CsrMatrix, Matrix};

    fn dense(m: usize, n: usize) -> Matrix {
        Matrix::from_vec(m, n, (0..m * n).map(|i| ((i * 31 % 23) as f64 - 11.0) * 0.13).collect())
            .unwrap()
    }

    #[test]
    fn parallel_residual_gemv_is_bitwise_serial_dense() {
        let pool = WorkerPool::new();
        let a = Storage::from(dense(37, 19));
        let x: Vec<f64> = (0..19).map(|i| (i as f64 * 0.41).sin()).collect();
        let mut serial = vec![0.0; 37];
        a.gemv_block_into(&x, &mut serial);
        // Miri explores the same aliasing protocol with fewer (and smaller)
        // dispatches; the full q sweep runs natively.
        let qs: &[usize] = if cfg!(miri) { &[1, 2, 3] } else { &[1, 2, 3, 5, 8, 37, 50] };
        for &q in qs {
            let mut par = vec![f64::NAN; 37];
            residual_gemv_into_with(&a, &x, &mut par, &pool, q);
            for (i, (u, v)) in par.iter().zip(&serial).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "q={q} row {i}");
            }
        }
    }

    #[test]
    fn parallel_residual_gemv_is_bitwise_serial_csr() {
        let pool = WorkerPool::new();
        let a = Storage::from(CsrMatrix::from_dense(&dense(24, 11)));
        let x: Vec<f64> = (0..11).map(|i| (i as f64 * 0.29).cos()).collect();
        let mut serial = vec![0.0; 24];
        a.gemv_block_into(&x, &mut serial);
        let qs: &[usize] = if cfg!(miri) { &[2, 3] } else { &[2, 4, 7, 24] };
        for &q in qs {
            let mut par = vec![f64::NAN; 24];
            residual_gemv_into_with(&a, &x, &mut par, &pool, q);
            for (i, (u, v)) in par.iter().zip(&serial).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "q={q} row {i}");
            }
        }
    }

    #[test]
    fn auto_entry_is_safe_inside_a_dispatch() {
        // A StopCheck fired from participant 0 of a shared-memory engine
        // runs exactly this shape: residual_gemv_into from inside a pool
        // region. It must detect the dispatch and fall back serial
        // instead of tripping the nested-dispatch fail-fast.
        let pool = WorkerPool::new();
        let a = Storage::from(dense(16, 8));
        let x = vec![0.5; 8];
        let mut serial = vec![0.0; 16];
        a.gemv_block_into(&x, &mut serial);
        let out = std::sync::Mutex::new(vec![0.0; 16]);
        pool.run(3, |t| {
            if t == 0 {
                let mut y = vec![0.0; 16];
                residual_gemv_into(&a, &x, &mut y);
                *out.lock().unwrap() = y;
            }
        });
        assert_eq!(*out.lock().unwrap(), serial);
    }

    #[test]
    fn auto_entry_matches_serial_below_threshold() {
        let a = Storage::from(dense(10, 6));
        let x = vec![1.0; 6];
        let mut serial = vec![0.0; 10];
        a.gemv_block_into(&x, &mut serial);
        let mut auto = vec![f64::NAN; 10];
        residual_gemv_into(&a, &x, &mut auto);
        assert_eq!(auto, serial);
    }
}
