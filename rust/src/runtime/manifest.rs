//! Artifact manifest: the index `aot.py` writes next to the HLO files.
//!
//! Line format: `<name> <kind> <q> <bs> <n> <file>`.

use crate::error::{Error, Result};
use std::path::{Path, PathBuf};

/// What computation an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Eq. (7): one averaged RKA update.
    RkaStep,
    /// Eq. (8): one worker's sequential block sweep.
    RkabBlock,
    /// Eqs. (8)+(9): full RKAB iteration (q sweeps + average).
    RkabRound,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "rka_step" => Ok(ArtifactKind::RkaStep),
            "rkab_block" => Ok(ArtifactKind::RkabBlock),
            "rkab_round" => Ok(ArtifactKind::RkabRound),
            other => Err(Error::InvalidArgument(format!("unknown artifact kind {other}"))),
        }
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// Unique artifact name (also the cache key).
    pub name: String,
    /// Computation kind.
    pub kind: ArtifactKind,
    /// Workers `q` (1 for per-worker kernels).
    pub q: usize,
    /// Block size `bs` (1 for rka_step).
    pub bs: usize,
    /// Columns `n`.
    pub n: usize,
    /// HLO text file, absolute.
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|_| Error::ArtifactMissing(path.display().to_string()))?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 6 {
                return Err(Error::InvalidArgument(format!(
                    "manifest line {} malformed: {line}",
                    lineno + 1
                )));
            }
            let parse_usize = |s: &str, what: &str| -> Result<usize> {
                s.parse().map_err(|_| {
                    Error::InvalidArgument(format!("manifest line {}: bad {what}", lineno + 1))
                })
            };
            entries.push(ArtifactEntry {
                name: parts[0].to_string(),
                kind: ArtifactKind::parse(parts[1])?,
                q: parse_usize(parts[2], "q")?,
                bs: parse_usize(parts[3], "bs")?,
                n: parse_usize(parts[4], "n")?,
                path: dir.join(parts[5]),
            });
        }
        Ok(Manifest { entries })
    }

    /// All entries.
    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Look up by name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Find an artifact of `kind` with the exact shape.
    pub fn find(&self, kind: ArtifactKind, q: usize, bs: usize, n: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.q == q && e.bs == bs && e.n == n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(lines: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("kcz_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), lines).unwrap();
        dir
    }

    #[test]
    fn parses_valid_manifest() {
        let dir = write_manifest(
            "rka_step_q4_n256 rka_step 4 1 256 rka_step_q4_n256.hlo.txt\n\
             rkab_round_q4_bs64_n256 rkab_round 4 64 256 r.hlo.txt\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries().len(), 2);
        let e = m.find(ArtifactKind::RkabRound, 4, 64, 256).unwrap();
        assert_eq!(e.name, "rkab_round_q4_bs64_n256");
        assert!(m.find(ArtifactKind::RkaStep, 4, 1, 999).is_none());
        assert!(m.by_name("rka_step_q4_n256").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_malformed_lines() {
        let dir = write_manifest("too few fields\n");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_artifact_error() {
        let dir = std::env::temp_dir().join("kcz_definitely_absent_dir");
        match Manifest::load(&dir) {
            Err(Error::ArtifactMissing(_)) => {}
            other => panic!("expected ArtifactMissing, got {other:?}"),
        }
    }
}
