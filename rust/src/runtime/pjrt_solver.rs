//! RKAB with the inner update executed by the compiled Pallas kernel.
//!
//! This is the end-to-end proof of the three-layer architecture: L3 (this
//! struct) owns sampling, the iteration loop, stopping, and metrics; the
//! per-iteration compute `x^(k+1) = mean_gamma(block sweep)` is the
//! `rkab_round` artifact — the L2 jax graph vmapping the L1 Pallas kernel —
//! executed on the PJRT CPU client.
//!
//! Semantics are *identical* to [`crate::solvers::rkab::RkabSolver`] with
//! full-matrix sampling given the same seed (same derived worker streams,
//! same sampled rows); the integration tests assert the iterates agree to
//! f64 reassociation tolerance.

use super::engine::PjrtEngine;
use super::manifest::ArtifactKind;
use crate::data::LinearSystem;
use crate::error::{Error, Result};
use crate::metrics::Stopwatch;
use crate::solvers::sampling::{RowSampler, SamplingScheme};
use crate::solvers::{SolveOptions, SolveResult, StopCheck};
use std::cell::RefCell;
use std::path::Path;

/// PJRT-backed RKAB solver.
pub struct PjrtRkabSolver {
    /// Base RNG seed (worker streams derived as in the native solver).
    pub seed: u32,
    /// Number of averaged workers.
    pub q: usize,
    /// Rows per worker per iteration.
    pub block_size: usize,
    /// Uniform relaxation weight.
    pub alpha: f64,
    engine: RefCell<PjrtEngine>,
    artifact: String,
}

impl PjrtRkabSolver {
    /// Build a solver bound to the `rkab_round_q{q}_bs{bs}_n{n}` artifact.
    ///
    /// Fails with `ArtifactMissing` if the shape was not AOT-exported
    /// (extend the catalogue in `python/compile/aot.py` and re-run
    /// `make artifacts`).
    pub fn new(
        artifacts_dir: &Path,
        seed: u32,
        q: usize,
        block_size: usize,
        n: usize,
        alpha: f64,
    ) -> Result<Self> {
        let mut engine = PjrtEngine::new(artifacts_dir)?;
        let entry = engine.find(ArtifactKind::RkabRound, q, block_size, n)?;
        let artifact = entry.name.clone();
        engine.prepare(&artifact)?; // compile up front, off the solve clock
        Ok(PjrtRkabSolver {
            seed,
            q,
            block_size,
            alpha,
            engine: RefCell::new(engine),
            artifact,
        })
    }

    /// Solver name (mirrors the `Solver` trait; kept inherent because
    /// `solve` returns `Result` — PJRT execution can fail).
    pub fn name(&self) -> &'static str {
        "RKAB-pjrt"
    }

    /// Run RKAB with the PJRT-executed inner update.
    ///
    /// The AOT `rkab_round` artifact consumes contiguous row blocks, so the
    /// gather below requires dense storage; CSR systems fail fast with
    /// `InvalidArgument` instead of densifying silently.
    pub fn solve(&self, system: &LinearSystem, opts: &SolveOptions) -> Result<SolveResult> {
        let dense = system.a.as_dense().ok_or_else(|| {
            Error::InvalidArgument("PJRT RKAB requires dense storage (CSR not supported)".into())
        })?;
        let n = system.cols();
        let q = self.q;
        let bs = self.block_size;
        let mut x = vec![0.0; n];
        let mut samplers: Vec<RowSampler> = (0..q)
            .map(|t| RowSampler::new(system, SamplingScheme::FullMatrix, t, q, self.seed))
            .collect();
        // Stopping decisions and history recording both live in StopCheck.
        let mut stopper = StopCheck::new(system, opts);
        let mut engine = self.engine.borrow_mut();

        // Gather buffers (reused across iterations).
        let mut a_blocks = vec![0.0; q * bs * n];
        let mut b_blocks = vec![0.0; q * bs];
        let mut inv_norms = vec![0.0; q * bs];
        let alpha_lit = PjrtEngine::literal(&[self.alpha], &[1])?;

        let sw = Stopwatch::start();
        let mut k = 0usize;
        let (mut converged, mut diverged);
        loop {
            let (stop, c, d) = stopper.check(k, &x);
            converged = c;
            diverged = d;
            if stop {
                break;
            }

            // L3 responsibility: sample q*bs rows, gather their data.
            for (t, sampler) in samplers.iter_mut().enumerate() {
                for j in 0..bs {
                    let i = sampler.sample();
                    let dst = (t * bs + j) * n;
                    a_blocks[dst..dst + n].copy_from_slice(dense.row(i));
                    b_blocks[t * bs + j] = system.b[i];
                    inv_norms[t * bs + j] = 1.0 / system.row_norms_sq[i];
                }
            }

            // L1/L2 responsibility: the compiled rkab_round graph.
            let inputs = [
                PjrtEngine::literal(&a_blocks, &[q as i64, bs as i64, n as i64])?,
                PjrtEngine::literal(&b_blocks, &[q as i64, bs as i64])?,
                PjrtEngine::literal(&inv_norms, &[q as i64, bs as i64])?,
                PjrtEngine::literal(&x, &[n as i64])?,
                alpha_lit.clone(),
            ];
            x = engine.run(&self.artifact, &inputs)?;
            k += 1;
        }

        Ok(SolveResult {
            x,
            iterations: k,
            converged,
            diverged,
            seconds: sw.seconds(),
            rows_used: k * q * bs,
            history: stopper.into_history(),
        })
    }
}
