//! The PJRT execution engine: compile-once cache over the CPU client.

use super::manifest::{ArtifactEntry, ArtifactKind, Manifest};
use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Compile-once, execute-many PJRT wrapper.
///
/// One engine per process is the intended usage; compiled executables are
/// cached by artifact name. All methods take `&mut self` because the cache
/// mutates — the coordinator owns the engine on its event loop, matching the
/// "leader loads artifacts, workers feed it requests" shape.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    /// Create a CPU PJRT client and index the artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtEngine {
            client,
            manifest,
            dir: artifacts_dir.to_path_buf(),
            cache: HashMap::new(),
        })
    }

    /// Platform string of the underlying PJRT client (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The manifest this engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Artifacts directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Find an artifact by kind + shape (exact match).
    pub fn find(&self, kind: ArtifactKind, q: usize, bs: usize, n: usize) -> Result<ArtifactEntry> {
        self.manifest.find(kind, q, bs, n).cloned().ok_or_else(|| {
            Error::ArtifactMissing(format!(
                "{kind:?} with q={q} bs={bs} n={n} (available: {})",
                self.manifest
                    .entries()
                    .iter()
                    .map(|e| e.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        })
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .by_name(name)
            .ok_or_else(|| Error::ArtifactMissing(name.to_string()))?;
        let path = entry.path.clone();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::InvalidArgument("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute the named artifact. The AOT contract is `return_tuple=True`
    /// with a single element, so the result is unwrapped with `to_tuple1`
    /// and returned as a `Vec<f64>`.
    pub fn run(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<f64>> {
        self.prepare(name)?;
        let exe = self.cache.get(name).expect("prepared above");
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f64>()?)
    }

    /// Build an f64 literal of the given shape from a flat buffer.
    pub fn literal(data: &[f64], shape: &[i64]) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(data);
        let expected: i64 = shape.iter().product();
        if expected != data.len() as i64 {
            return Err(Error::Dimension(format!(
                "literal of len {} cannot have shape {shape:?}",
                data.len()
            )));
        }
        Ok(lit.reshape(shape)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine tests that need real artifacts live in
    // rust/tests/runtime_integration.rs (they require `make artifacts`).

    #[test]
    fn literal_shape_checked() {
        let data = vec![1.0f64; 6];
        assert!(PjrtEngine::literal(&data, &[2, 3]).is_ok());
        assert!(PjrtEngine::literal(&data, &[4, 2]).is_err());
    }

    #[test]
    fn missing_dir_reports_artifact_missing() {
        let r = PjrtEngine::new(Path::new("/definitely/not/here"));
        assert!(matches!(r, Err(Error::ArtifactMissing(_))));
    }
}
