//! PJRT runtime — loads the AOT artifacts and executes them from the L3
//! coordinator, Python-free.
//!
//! Pipeline (see /opt/xla-example/load_hlo and DESIGN.md §1):
//!
//! ```text
//! artifacts/<name>.hlo.txt           (written once by `make artifacts`)
//!   -> HloModuleProto::from_text_file   (text parser reassigns 64-bit ids)
//!   -> XlaComputation::from_proto
//!   -> PjRtClient::cpu().compile        (once per shape, cached)
//!   -> execute(&[Literal]) per iteration
//! ```
//!
//! [`PjrtRkabSolver`] is the proof the three layers compose: a full RKAB
//! solver whose inner block update runs through the compiled Pallas kernel,
//! validated numerically against the native Rust solver in
//! `rust/tests/runtime_integration.rs`.

pub mod engine;
pub mod manifest;
pub mod pjrt_solver;

pub use engine::PjrtEngine;
pub use manifest::{ArtifactEntry, ArtifactKind, Manifest};
pub use pjrt_solver::PjrtRkabSolver;

use std::path::PathBuf;

/// Default artifacts directory: `$KACZMARZ_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("KACZMARZ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
