//! MT19937 Mersenne Twister — bit-exact port of the generator the paper uses
//! (C++ `std::mt19937` / Matsumoto-Nishimura 2002 reference code).
//!
//! Known-answer tests below pin the output to the published reference
//! sequence (seed 5489: first output 3499211612).

const N: usize = 624;
const M: usize = 397;
const MATRIX_A: u32 = 0x9908_b0df;
const UPPER_MASK: u32 = 0x8000_0000;
const LOWER_MASK: u32 = 0x7fff_ffff;

/// 32-bit Mersenne Twister state.
#[derive(Clone)]
pub struct Mt19937 {
    mt: [u32; N],
    mti: usize,
}

impl Mt19937 {
    /// Seed exactly like `std::mt19937(seed)`.
    pub fn new(seed: u32) -> Self {
        let mut mt = [0u32; N];
        mt[0] = seed;
        for i in 1..N {
            mt[i] = 1812433253u32
                .wrapping_mul(mt[i - 1] ^ (mt[i - 1] >> 30))
                .wrapping_add(i as u32);
        }
        Mt19937 { mt, mti: N }
    }

    /// Next 32-bit output (tempered).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.mti >= N {
            self.generate();
        }
        let mut y = self.mt[self.mti];
        self.mti += 1;
        y ^= y >> 11;
        y ^= (y << 7) & 0x9d2c_5680;
        y ^= (y << 15) & 0xefc6_0000;
        y ^= y >> 18;
        y
    }

    fn generate(&mut self) {
        for i in 0..N {
            let y = (self.mt[i] & UPPER_MASK) | (self.mt[(i + 1) % N] & LOWER_MASK);
            let mut next = self.mt[(i + M) % N] ^ (y >> 1);
            if y & 1 != 0 {
                next ^= MATRIX_A;
            }
            self.mt[i] = next;
        }
        self.mti = 0;
    }

    /// Uniform double in [0, 1) with 53-bit resolution
    /// (`genrand_res53` from the reference implementation).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        let a = (self.next_u32() >> 5) as f64; // 27 bits
        let b = (self.next_u32() >> 6) as f64; // 26 bits
        (a * 67108864.0 + b) * (1.0 / 9007199254740992.0)
    }

    /// Uniform u64 built from two 32-bit outputs.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` by rejection (unbiased).
    #[inline]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let zone = u32::MAX - (u32::MAX % bound);
        loop {
            let v = self.next_u32();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Fisher-Yates shuffle (used by AsyRK's without-replacement sampling).
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            slice.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_sequence_seed_5489() {
        // First 10 outputs of mt19937 with the default C++ seed 5489.
        let expected: [u32; 10] = [
            3499211612, 581869302, 3890346734, 3586334585, 545404204, 4161255391, 3922919429,
            949333985, 2715962298, 1323567403,
        ];
        let mut rng = Mt19937::new(5489);
        for &e in &expected {
            assert_eq!(rng.next_u32(), e);
        }
    }

    #[test]
    fn tenthousandth_output_seed_5489() {
        // The classic C++11 spec check: the 10000th output of
        // default-seeded mt19937 is 4123659995.
        let mut rng = Mt19937::new(5489);
        let mut last = 0;
        for _ in 0..10000 {
            last = rng.next_u32();
        }
        assert_eq!(last, 4123659995);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Mt19937::new(1);
        for _ in 0..1000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut rng = Mt19937::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_unbiased_range() {
        let mut rng = Mt19937::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Mt19937::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely to be identity
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Mt19937::new(1);
        let mut b = Mt19937::new(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }
}
