//! Sampling distributions over row indices.
//!
//! The RK family samples row `l` with probability `‖A^(l)‖² / ‖A‖²_F`
//! (paper eq. 4). Two interchangeable samplers:
//!
//! - [`DiscreteDistribution`] — cumulative weights + binary search, the same
//!   algorithm family as libstdc++'s `std::discrete_distribution`
//!   (O(log m) per draw).
//! - [`AliasTable`] — Walker's alias method (O(1) per draw, O(m) setup).
//!   Adopted on the hot path during the §Perf pass.
//!
//! Plus [`NormalSampler`], a Box–Muller gaussian used by the dataset
//! generator (§3.1: matrix entries ~ N(μ, σ), noise ~ N(0,1)).

use super::mt19937::Mt19937;

/// CDF + binary-search discrete distribution.
pub struct DiscreteDistribution {
    cumulative: Vec<f64>,
    total: f64,
}

impl DiscreteDistribution {
    /// Build from non-negative weights (need not be normalized).
    ///
    /// Panics on empty weights or a non-positive total, which would make the
    /// distribution meaningless.
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty(), "discrete distribution needs >= 1 weight");
        let mut cumulative = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            assert!(w >= 0.0 && w.is_finite(), "weights must be finite and >= 0, got {w}");
            acc += w;
            cumulative.push(acc);
        }
        assert!(acc > 0.0, "total weight must be positive");
        DiscreteDistribution { cumulative, total: acc }
    }

    /// Number of categories.
    pub fn len(&self) -> usize {
        self.cumulative.len()
    }

    /// True if there are no categories (never: constructor forbids it).
    pub fn is_empty(&self) -> bool {
        self.cumulative.is_empty()
    }

    /// Draw an index.
    #[inline]
    pub fn sample(&self, rng: &mut Mt19937) -> usize {
        let u = rng.next_f64() * self.total;
        // partition_point returns the first index with cumulative > u.
        self.cumulative.partition_point(|&c| c <= u).min(self.cumulative.len() - 1)
    }
}

/// Walker alias table: O(1) sampling from a discrete distribution.
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from non-negative weights (need not be normalized).
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty(), "alias table needs >= 1 weight");
        let n = weights.len();
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0 && total.is_finite(), "total weight must be positive/finite");
        let scale = n as f64 / total;
        let mut prob: Vec<f64> = weights.iter().map(|w| w * scale).collect();
        let mut alias = vec![0u32; n];
        // Split indices into under/over-full stacks.
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            alias[s as usize] = l;
            // l donates (1 - prob[s]) of its mass to s's column.
            prob[l as usize] = (prob[l as usize] + prob[s as usize]) - 1.0;
            if prob[l as usize] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        // Numerical leftovers: saturate.
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = 1.0;
        }
        AliasTable { prob, alias }
    }

    /// Number of categories.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True if empty (never: constructor forbids it).
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw an index: one uniform for the column, one for the coin flip.
    #[inline]
    pub fn sample(&self, rng: &mut Mt19937) -> usize {
        let col = rng.next_below(self.prob.len() as u32) as usize;
        if rng.next_f64() < self.prob[col] {
            col
        } else {
            self.alias[col] as usize
        }
    }
}

/// Box–Muller gaussian sampler with caching of the second variate.
pub struct NormalSampler {
    spare: Option<f64>,
}

impl NormalSampler {
    /// New sampler (stateless apart from the cached spare variate).
    pub fn new() -> Self {
        NormalSampler { spare: None }
    }

    /// Draw from N(mean, sd).
    #[inline]
    pub fn sample(&mut self, rng: &mut Mt19937, mean: f64, sd: f64) -> f64 {
        mean + sd * self.standard(rng)
    }

    /// Draw from N(0, 1).
    pub fn standard(&mut self, rng: &mut Mt19937) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // Polar Box–Muller: rejection-sample a point in the unit disc.
        loop {
            let u = 2.0 * rng.next_f64() - 1.0;
            let v = 2.0 * rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * f);
                return u * f;
            }
        }
    }
}

impl Default for NormalSampler {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frequencies(mut draw: impl FnMut(&mut Mt19937) -> usize, k: usize, n: usize) -> Vec<f64> {
        let mut rng = Mt19937::new(1234);
        let mut counts = vec![0usize; k];
        for _ in 0..n {
            counts[draw(&mut rng)] += 1;
        }
        counts.into_iter().map(|c| c as f64 / n as f64).collect()
    }

    #[test]
    fn discrete_matches_weights() {
        let w = [1.0, 2.0, 3.0, 4.0];
        let d = DiscreteDistribution::new(&w);
        let f = frequencies(|r| d.sample(r), 4, 200_000);
        for (i, &wi) in w.iter().enumerate() {
            assert!((f[i] - wi / 10.0).abs() < 0.01, "cat {i}: {} vs {}", f[i], wi / 10.0);
        }
    }

    #[test]
    fn alias_matches_weights() {
        let w = [0.5, 0.0, 2.5, 1.0, 6.0];
        let t = AliasTable::new(&w);
        let f = frequencies(|r| t.sample(r), 5, 200_000);
        for (i, &wi) in w.iter().enumerate() {
            assert!((f[i] - wi / 10.0).abs() < 0.01, "cat {i}: {} vs {}", f[i], wi / 10.0);
        }
    }

    #[test]
    fn alias_and_discrete_agree_statistically() {
        let w: Vec<f64> = (1..=32).map(|i| (i as f64).sqrt()).collect();
        let total: f64 = w.iter().sum();
        let d = DiscreteDistribution::new(&w);
        let t = AliasTable::new(&w);
        let fd = frequencies(|r| d.sample(r), 32, 100_000);
        let ft = frequencies(|r| t.sample(r), 32, 100_000);
        for i in 0..32 {
            let p = w[i] / total;
            assert!((fd[i] - p).abs() < 0.01);
            assert!((ft[i] - p).abs() < 0.01);
        }
    }

    #[test]
    fn zero_weight_never_sampled() {
        let d = DiscreteDistribution::new(&[1.0, 0.0, 1.0]);
        let mut rng = Mt19937::new(5);
        for _ in 0..10_000 {
            assert_ne!(d.sample(&mut rng), 1);
        }
        let t = AliasTable::new(&[1.0, 0.0, 1.0]);
        for _ in 0..10_000 {
            assert_ne!(t.sample(&mut rng), 1);
        }
    }

    #[test]
    fn single_category() {
        let d = DiscreteDistribution::new(&[3.0]);
        let t = AliasTable::new(&[3.0]);
        let mut rng = Mt19937::new(9);
        assert_eq!(d.sample(&mut rng), 0);
        assert_eq!(t.sample(&mut rng), 0);
    }

    #[test]
    #[should_panic]
    fn empty_weights_panic() {
        DiscreteDistribution::new(&[]);
    }

    #[test]
    #[should_panic]
    fn negative_weight_panics() {
        DiscreteDistribution::new(&[1.0, -0.5]);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Mt19937::new(77);
        let mut ns = NormalSampler::new();
        let n = 200_000;
        let samples: Vec<f64> = (0..n).map(|_| ns.sample(&mut rng, 3.0, 2.0)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean {mean}");
        assert!((var - 4.0).abs() < 0.08, "var {var}");
    }
}
