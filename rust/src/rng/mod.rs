//! Random-number substrate.
//!
//! The paper (§3.1) samples rows with C++ `std::discrete_distribution` driven
//! by `std::mt19937`. No RNG crate is available offline, so we implement
//! both: a bit-exact MT19937 and two discrete-distribution samplers — a
//! CDF/binary-search sampler (what libstdc++ does) and a Walker alias table
//! (O(1) per draw; used on the hot path after the §Perf pass showed the
//! binary search at ~8% of RK runtime on wide systems).

pub mod distribution;
pub mod mt19937;

pub use distribution::{AliasTable, DiscreteDistribution, NormalSampler};
pub use mt19937::Mt19937;

/// Derive a distinct, well-mixed seed for worker `id` from a base seed.
///
/// The paper gives "each thread a different seed"; SplitMix64 finalization
/// guarantees the derived seeds differ in ~half their bits even for
/// consecutive ids.
pub fn derive_seed(base: u32, id: usize) -> u32 {
    let mut z = (base as u64).wrapping_add(0x9e3779b97f4a7c15u64.wrapping_mul(id as u64 + 1));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    (z ^ (z >> 31)) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_seeds_differ() {
        let seeds: Vec<u32> = (0..64).map(|i| derive_seed(42, i)).collect();
        let mut unique = seeds.clone();
        unique.sort_unstable();
        unique.dedup();
        assert_eq!(unique.len(), seeds.len());
    }

    #[test]
    fn derived_seed_depends_on_base() {
        assert_ne!(derive_seed(1, 0), derive_seed(2, 0));
    }
}
