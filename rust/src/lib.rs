//! # kaczmarz — Parallel Randomized Kaczmarz for large-scale dense systems
//!
//! Full reproduction of *"Parallelization Strategies for the Randomized
//! Kaczmarz Algorithm on Large-Scale Dense Systems"* (Ferreira, Acebrón,
//! Monteiro, 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the coordinator: sequential and parallel solvers,
//!   a shared-memory execution engine (the paper's OpenMP side), a simulated
//!   MPI layer with a network cost model (the paper's cluster side), the
//!   experiment drivers for every figure/table, and the PJRT runtime that
//!   executes AOT-compiled kernels.
//! - **L2/L1 (python/compile)** — JAX update graphs and Pallas kernels,
//!   lowered once to HLO text in `artifacts/` by `make artifacts`.
//!
//! Every shared-memory solve dispatches onto the persistent
//! [`parallel::pool`] (workers are spawned once per process), the simulated
//! cluster ranks of [`distributed::SimCluster`] run on the same pool, and
//! the [`batch`] layer turns the pool into a serving engine: many
//! right-hand sides or many independent systems per dispatch. See the
//! repository `README.md` for the guided tour.
//!
//! ## Quickstart
//!
//! ```no_run
//! use kaczmarz::data::DatasetBuilder;
//! use kaczmarz::solvers::{rk::RkSolver, Solver, SolveOptions};
//!
//! let sys = DatasetBuilder::new(2000, 200).seed(1).consistent();
//! let opts = SolveOptions::default().with_tolerance(1e-8);
//! let result = RkSolver::new(42).solve(&sys, &opts);
//! assert!(result.converged);
//! ```
//!
//! See `examples/` for realistic workloads (CT reconstruction, camera
//! calibration, batch serving) and `rust/src/coordinator` for the paper's
//! experiments.

// Documentation is part of this crate's contract: the CI `docs` job builds
// rustdoc with `-D warnings`, so an undocumented public item fails the
// build there rather than rotting silently.
#![warn(missing_docs)]
// Every unsafe operation must sit in an explicit `unsafe {}` block with its
// own `// SAFETY:` justification, even inside `unsafe fn` bodies — the
// `cargo xtask audit-unsafe` lint enforces the comments, this lint keeps
// new unsafe from hiding behind an `unsafe fn` signature.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod batch;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod distributed;
pub mod error;
pub mod linalg;
pub mod metrics;
pub mod parallel;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod solvers;

pub use error::{Error, Result};

/// Crate version string (from Cargo).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
