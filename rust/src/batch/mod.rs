//! Batch-solve serving layer: many solves per pool dispatch.
//!
//! The paper's pitch is *throughput* on large dense systems, and the crate's
//! north star is serving many solve requests back to back. A single solve
//! already runs on the persistent [`crate::parallel::pool`] (no per-solve
//! thread spawns); this module adds the other half of the serving story —
//! amortizing the *per-request* costs when requests share structure:
//!
//! - [`BatchSolver`] — many right-hand sides against **one** system. The
//!   expensive per-system state (the matrix, the squared row norms feeding
//!   the eq.-4 sampling distribution) is prepared once per worker lane
//!   instead of once per request — and the matrix itself is not even
//!   per-lane: `Matrix` storage is `Arc`-backed copy-on-write, so every
//!   lane's `LinearSystem` clone *shares one resident `A`*
//!   (`Matrix::shares_storage`), and a 16-lane batch over a multi-GiB
//!   system costs one matrix, not sixteen. The per-rhs solves are fanned
//!   across the pool workers.
//! - [`SolveQueue`] — many independent `(system, options)` jobs multiplexed
//!   through a **single** pool dispatch, each producing its own
//!   [`SolveReport`]. This is the multi-tenant shape: different systems,
//!   different stopping rules, one engine.
//!
//! Both primitives claim jobs with an atomic counter inside one
//! [`WorkerPool::run`] region (work stealing, so a slow job never blocks the
//! queue behind a fixed partition) and return reports **in job order**.
//!
//! # Stopping in a serving context
//!
//! The paper's stopping rule measures `‖x - x*‖²` against a *known
//! reference solution* — which a serving system, by definition, does not
//! have (the reference is the answer being computed). Serving jobs
//! therefore run in one of two reference-free modes:
//!
//! - **Residual stopping**
//!   ([`SolveOptions::with_residual_stopping`](crate::solvers::SolveOptions::with_residual_stopping)):
//!   stop when `‖Ax - b‖² < tol`. This makes the report's `converged` flag
//!   a *real quality signal* — `true` means the returned iterate provably
//!   fits the data to the requested residual, no reference needed.
//! - **Fixed budget** (`with_fixed_iterations`): spend exactly `k`
//!   iterations. Nothing is measured, so `converged` is always `false`;
//!   judge quality by [`SolveReport::residual_norm`].
//!
//! Either way the solvers never touch the (absent) reference — the initial
//! error is computed lazily, only by runs that actually stop on it — so
//! reference-free jobs run on their systems *in place*: no dummy-reference
//! patching, no per-job system clone (`tests/stopping_properties.rs` pins
//! this down).
//!
//! Reference-free jobs may also request **convergence curves**: histories
//! are dual-channel ([`crate::metrics::History`]), and on a system without
//! a reference only the residual channel `‖Ax - b‖` is recorded — see
//! [`SolveReport::residual_history`].
//!
//! # Live telemetry
//!
//! Curves arrive *after* a job returns; long-running jobs can also be
//! watched **while they run**. Attach a [`crate::metrics::ProgressSink`]
//! per job — [`BatchJob::with_progress`] on the batch side, a
//! `SolveOptions::with_progress` per pushed job on the queue side — and
//! each job streams its `(k, residual, elapsed)` samples to its own sink
//! from the solve's amortized checkpoints (residual stopping checks and/or
//! history samples; no new GEMVs). Sinks are non-blocking by construction
//! (the bounded-channel flavor drops oldest rather than stalling a lane),
//! so 16 receivers can watch 16 lanes converge concurrently without
//! perturbing the batch: results stay bitwise identical to unwatched runs
//! (`tests/telemetry_streaming.rs`).
//!
//! # Determinism guarantee
//!
//! A batched solve is *bitwise identical* to running the same jobs one at a
//! time: each job is solved by the same solver, with the same seed, against
//! numerically identical system state, and no state is shared between jobs.
//! Which lane executes which job is scheduling-dependent, but lanes are
//! exact clones, so the output does not depend on the assignment. The
//! integration tests assert `to_bits()` equality against independent
//! sequential solves.
//!
//! # Solver choice
//!
//! Per-job parallelism and cross-job parallelism compose through *separate*
//! pools: the batch layer dispatches on one pool, so a per-job solver that
//! also dispatches (e.g. [`crate::parallel::ParallelRkab`]) must be given a
//! dedicated pool via its `with_pool` — nesting on the same pool fails fast
//! by design (see the pool's dispatch protocol). For serving, the sequential
//! solvers are usually the right per-job choice: throughput scales with the
//! number of in-flight jobs, not with threads per job.
//!
//! # Example
//!
//! ```
//! use kaczmarz::batch::{BatchJob, BatchSolver};
//! use kaczmarz::data::DatasetBuilder;
//! use kaczmarz::linalg::gemv;
//! use kaczmarz::solvers::rk::RkSolver;
//! use kaczmarz::solvers::SolveOptions;
//!
//! // One system, four right-hand sides b_j = A x_j.
//! let system = DatasetBuilder::new(120, 8).seed(1).consistent();
//! let jobs: Vec<BatchJob> = (0..4)
//!     .map(|j| {
//!         let x = vec![j as f64; 8];
//!         BatchJob::new(gemv(&system.a, &x).unwrap()).with_reference(x)
//!     })
//!     .collect();
//!
//! let batch = BatchSolver::new(&system, RkSolver::new(7));
//! let reports = batch.solve_many(&jobs, &SolveOptions::default()).unwrap();
//! assert_eq!(reports.len(), 4);
//! assert!(reports.iter().all(|r| r.result.converged));
//! ```

pub mod queue;
pub mod solver;

pub use queue::SolveQueue;
pub use solver::{autotuned_rkab, BatchJob, BatchSolver};

use crate::parallel::pool::WorkerPool;
use crate::solvers::SolveResult;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Outcome of one job of a batched solve.
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Index of the job in the submitted batch / queue (reports are returned
    /// in this order, so `reports[j].job == j`).
    pub job: usize,
    /// Name of the solver that produced the result.
    pub solver: &'static str,
    /// The per-job solve outcome (iterate, iterations, convergence flags).
    ///
    /// `result.converged` means the job's stopping criterion was met. Under
    /// `fixed_iterations` nothing is measured, so it is always `false` —
    /// fixed-budget runs answer "how fast", not "how good". For a serving
    /// quality signal, stop on the residual
    /// ([`SolveOptions`](crate::solvers::SolveOptions)`::with_residual_stopping`),
    /// where `converged = true` certifies `‖Ax - b‖² < tol`, or read
    /// [`SolveReport::residual_norm`], which is computed against the job's
    /// own system regardless of stopping mode.
    pub result: SolveResult,
    /// Residual norm `‖A x - b‖` of the returned iterate against *this
    /// job's* system — the serving-meaningful quality number, available even
    /// when no reference solution is known.
    pub residual_norm: f64,
    /// Time the job spent waiting for a lane before its solve started.
    /// Zero for the in-process [`BatchSolver`]/[`SolveQueue`] paths, where
    /// jobs start the moment a lane claims them inside one pool dispatch;
    /// nonzero under the admission-queued serving front end
    /// ([`crate::serve`]), where it is measured submit → dequeue and is the
    /// p50/p99 latency number the load-test bench row reports.
    pub queue_wait: std::time::Duration,
    /// Telemetry samples this job's [`crate::metrics::ProgressSink`]
    /// discarded under the drop-oldest policy (0 when no sink was attached,
    /// or when the consumer kept up). A nonzero count means the *freshest*
    /// samples were kept — the solve itself never blocked
    /// ([`crate::metrics::ProgressReceiver::dropped`] sees the same
    /// number).
    pub dropped_samples: u64,
}

impl SolveReport {
    /// The job's recorded residual convergence curve: `‖A x^(k) - b‖` every
    /// `history_step` iterations (empty unless the job's
    /// [`SolveOptions`](crate::solvers::SolveOptions) requested a history).
    /// Histories are dual-channel and reference-optional, so this is
    /// populated for reference-free serving jobs too; the matching
    /// iteration numbers are in `result.history.iterations`, and the
    /// reference-error channel (when the job carried one) in
    /// `result.history.errors`.
    pub fn residual_history(&self) -> &[f64] {
        &self.result.history.residuals
    }
}

/// Run `jobs` job bodies across `lanes` pool participants inside one
/// dispatch, claiming jobs with an atomic counter, and collect the results
/// in job order.
///
/// `job_fn(lane, job)` must be safe to call concurrently for distinct jobs;
/// the lane index tells it which per-lane scratch state it may use.
pub(crate) fn fan_out<R, F>(pool: &WorkerPool, lanes: usize, jobs: usize, job_fn: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, usize) -> R + Sync,
{
    debug_assert!(lanes >= 1 && jobs >= 1);
    let slots: Vec<Mutex<Option<R>>> = (0..jobs).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    pool.run(lanes, |lane| loop {
        let job = next.fetch_add(1, Ordering::Relaxed);
        if job >= jobs {
            break;
        }
        let out = job_fn(lane, job);
        *slots[job].lock().unwrap() = Some(out);
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("every claimed job stores a result"))
        .collect()
}

/// Default lane count: one per hardware thread.
pub(crate) fn default_workers() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fan_out_runs_every_job_once_in_order() {
        let pool = WorkerPool::new();
        for (lanes, jobs) in [(1usize, 5usize), (3, 8), (4, 2), (2, 1)] {
            let out = fan_out(&pool, lanes, jobs, |_lane, job| job * 10);
            let expect: Vec<usize> = (0..jobs).map(|j| j * 10).collect();
            assert_eq!(out, expect, "lanes={lanes} jobs={jobs}");
        }
    }

    #[test]
    fn fan_out_lane_indices_stay_in_range() {
        let pool = WorkerPool::new();
        let lanes = 3;
        let out = fan_out(&pool, lanes, 16, |lane, _job| lane);
        assert!(out.iter().all(|&l| l < lanes));
    }
}
