//! Many right-hand sides against one system ([`BatchSolver`]).

use super::{default_workers, fan_out, SolveReport};
use crate::coordinator::{autotune_block_size_residual, AutotuneConfig, CostModel};
use crate::data::LinearSystem;
use crate::error::{Error, Result};
use crate::metrics::ProgressSink;
use crate::parallel::pool::WorkerPool;
use crate::solvers::rkab::RkabSolver;
use crate::solvers::{SolveOptions, Solver};
use std::sync::{Arc, Mutex};

/// One right-hand side of a batched solve.
#[derive(Clone, Debug)]
pub struct BatchJob {
    /// Right-hand side `b` (length = rows of the batch system).
    pub rhs: Vec<f64>,
    /// Reference solution the error-based stopping test measures against
    /// (the paper's convention: stop on `‖x - x_ref‖²`, §3.5). `None`
    /// means "answer unknown" — the normal serving case — and such jobs
    /// must run under options that never consult the reference: residual
    /// stopping, or a fixed iteration budget
    /// ([`SolveOptions::consults_reference`]); history recording is fine
    /// either way (reference-free histories record the residual channel
    /// only). [`BatchSolver::solve_many`] validates this up front.
    pub x_ref: Option<Vec<f64>>,
    /// Per-job live telemetry sink: when set, *this job's* solve streams
    /// its convergence [`Sample`](crate::metrics::Sample)s here (overriding
    /// any batch-wide sink in the shared [`SolveOptions`]), so a client can
    /// watch every lane of a batch converge concurrently — one bounded
    /// channel per job demultiplexes the streams for free.
    pub progress: Option<ProgressSink>,
}

impl BatchJob {
    /// Job with an unknown solution (requires reference-free options:
    /// residual stopping or a fixed iteration budget).
    pub fn new(rhs: Vec<f64>) -> Self {
        BatchJob { rhs, x_ref: None, progress: None }
    }

    /// Attach the reference solution for error-based stopping.
    pub fn with_reference(mut self, x_ref: Vec<f64>) -> Self {
        self.x_ref = Some(x_ref);
        self
    }

    /// Stream this job's live convergence samples to `sink` (see
    /// [`BatchJob::progress`]). Pair with residual stopping or a
    /// `history_step` in the batch options so the solve has telemetry
    /// checkpoints to stream from.
    pub fn with_progress(mut self, sink: ProgressSink) -> Self {
        self.progress = Some(sink);
        self
    }
}

/// Solves many right-hand sides against one [`LinearSystem`] by fanning the
/// per-rhs solves across the persistent worker pool.
///
/// The per-system state every Kaczmarz solver needs — the matrix and the
/// squared row norms behind the eq.-4 sampling distribution — is prepared
/// once per worker *lane* (at most `workers` `LinearSystem` clones per
/// call), not once per right-hand side: a lane swaps the rhs in and reuses
/// everything else, so request cost stays O(solve), never O(build system).
/// And a lane clone is cheap even for huge systems: `Matrix` storage is
/// `Arc`-backed, so every lane reads *the same resident `A`*
/// (`Matrix::shares_storage` holds across all lanes; only the O(m) rhs and
/// row-norm vectors are duplicated). See the [module docs](crate::batch)
/// for the determinism guarantee, the serving stopping modes, and how to
/// combine this with per-job parallel solvers.
pub struct BatchSolver<'s, S> {
    system: &'s LinearSystem,
    solver: S,
    workers: usize,
    pool: Option<Arc<WorkerPool>>,
}

impl<'s, S: Solver + Sync> BatchSolver<'s, S> {
    /// Batch solver over `system`, running `solver` per right-hand side with
    /// one lane per hardware thread.
    pub fn new(system: &'s LinearSystem, solver: S) -> Self {
        BatchSolver { system, solver, workers: default_workers(), pool: None }
    }

    /// Cap the number of concurrent lanes (and lane clones of the system).
    pub fn with_workers(mut self, workers: usize) -> Self {
        assert!(workers >= 1, "need at least one lane");
        self.workers = workers;
        self
    }

    /// Dispatch on a dedicated pool instead of the process-global one.
    pub fn with_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Solve every job of the batch; reports come back in job order.
    ///
    /// Fails fast (on the calling thread, before any dispatch) on shape
    /// mismatches and on reference-free jobs whose options *would* consult
    /// the missing reference ([`SolveOptions::consults_reference`]): only
    /// reference-error *stopping* measures against `x_ref`, so jobs
    /// without one need residual stopping or `fixed_iterations` —
    /// `history_step` is allowed in both cases (the history simply records
    /// its residual channel only).
    pub fn solve_many(
        &self,
        jobs: &[BatchJob],
        opts: &SolveOptions,
    ) -> Result<Vec<SolveReport>> {
        let m = self.system.rows();
        let n = self.system.cols();
        for (j, job) in jobs.iter().enumerate() {
            if job.rhs.len() != m {
                return Err(Error::Dimension(format!(
                    "job {j}: rhs of len {} does not match {m} rows",
                    job.rhs.len()
                )));
            }
            match &job.x_ref {
                Some(x_ref) if x_ref.len() != n => {
                    return Err(Error::Dimension(format!(
                        "job {j}: reference of len {} does not match {n} cols",
                        x_ref.len()
                    )));
                }
                None if opts.consults_reference() => {
                    return Err(Error::InvalidArgument(format!(
                        "job {j} has no reference solution: reference-error stopping \
                         needs one (stop on the residual, set fixed_iterations, or \
                         attach x_ref; histories degrade to the residual channel)"
                    )));
                }
                _ => {}
            }
        }
        if jobs.is_empty() {
            return Ok(Vec::new());
        }

        // One lane (system clone) per concurrently-running job, never more
        // than one per job. The clone shares the resident matrix (Arc
        // storage; nothing mutates `a`, so copy-on-write never fires) and
        // copies the precomputed row norms, so no lane ever recomputes —
        // or re-materializes — per-system state.
        let lane_count = self.workers.min(jobs.len()).max(1);
        let lanes: Vec<Mutex<LinearSystem>> =
            (0..lane_count).map(|_| Mutex::new(self.system.clone())).collect();
        let pool = self.pool.as_deref().unwrap_or_else(|| crate::parallel::pool::global());

        Ok(fan_out(pool, lane_count, jobs.len(), |lane, j| {
            let mut sys = lanes[lane].lock().unwrap();
            let job = &jobs[j];
            // Swap this job's rhs/reference into the lane. Everything a
            // solver reads is now numerically identical to a freshly built
            // per-job system, so the result is bitwise equal to an
            // independent solve (asserted in tests/batch_integration.rs).
            // Reference-free jobs leave x_true = None — validated above to
            // run under options that never consult it.
            sys.b.copy_from_slice(&job.rhs);
            sys.x_true = job.x_ref.clone();
            sys.x_ls = None;
            sys.consistent = true;
            // A per-job sink overrides the (shared) batch options so each
            // job's telemetry lands on its own channel. The clone is cheap
            // (options are a handful of scalars plus two Arcs) and happens
            // only for jobs that asked to be watched.
            let result = match &job.progress {
                Some(sink) => {
                    let watched = opts.clone().with_progress(sink.clone());
                    self.solver.solve(&sys, &watched)
                }
                None => self.solver.solve(&sys, opts),
            };
            let residual_norm = sys.residual_norm(&result.x);
            // Jobs start the moment a lane claims them (one pool dispatch),
            // so queue wait is structurally zero here; the drop count comes
            // from the job's own sink, when one was attached.
            let dropped_samples = job.progress.as_ref().map_or(0, |s| s.dropped());
            SolveReport {
                job: j,
                solver: self.solver.name(),
                result,
                residual_norm,
                queue_wait: std::time::Duration::ZERO,
                dropped_samples,
            }
        }))
    }
}

/// Serving hook: size an [`RkabSolver`] for a *resident* system that has no
/// reference solution, then build the solver at the picked block size.
///
/// Probes the system once with the reference-free scorer
/// ([`autotune_block_size_residual`], residual decay per modeled second)
/// over a freshly calibrated [`CostModel`]. A serving process that installs
/// a long-lived system behind a [`BatchSolver`] calls this at install time;
/// the probe cost is amortized over every subsequent right-hand side. When
/// re-probing is undesirable, a block size persisted by `kaczmarz tune`
/// ([`TunedParams::rkab_block`](crate::coordinator::TunedParams)) can be
/// passed straight to [`RkabSolver::new`] instead.
pub fn autotuned_rkab(
    system: &LinearSystem,
    seed: u32,
    q: usize,
    alpha: f64,
) -> Result<(RkabSolver, usize)> {
    let model = CostModel::calibrate(system);
    let (bs, _probes) = autotune_block_size_residual(system, &model, &AutotuneConfig::new(q))?;
    Ok((RkabSolver::new(seed, q, bs, alpha), bs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::linalg::gemv;
    use crate::solvers::rk::RkSolver;

    fn jobs_for(system: &LinearSystem, count: usize) -> Vec<BatchJob> {
        (0..count)
            .map(|j| {
                let x: Vec<f64> =
                    (0..system.cols()).map(|i| (i + j) as f64 / 10.0).collect();
                BatchJob::new(gemv(&system.a, &x).unwrap()).with_reference(x)
            })
            .collect()
    }

    #[test]
    fn solves_every_rhs_in_order() {
        let system = DatasetBuilder::new(150, 8).seed(1).consistent();
        let jobs = jobs_for(&system, 5);
        let batch = BatchSolver::new(&system, RkSolver::new(3)).with_workers(3);
        let reports = batch.solve_many(&jobs, &SolveOptions::default()).unwrap();
        assert_eq!(reports.len(), 5);
        for (j, r) in reports.iter().enumerate() {
            assert_eq!(r.job, j);
            assert!(r.result.converged, "job {j}");
            // err² < 1e-8 at stop and σ_max ~ 1e2 for these row
            // distributions (μ ∈ [-5,5], σ ∈ [1,20]), so residual ~ 1e-2.
            assert!(r.residual_norm < 1e-1, "job {j} residual {}", r.residual_norm);
        }
    }

    #[test]
    fn empty_batch_is_ok() {
        let system = DatasetBuilder::new(60, 5).seed(2).consistent();
        let batch = BatchSolver::new(&system, RkSolver::new(3));
        let reports = batch.solve_many(&[], &SolveOptions::default()).unwrap();
        assert!(reports.is_empty());
    }

    #[test]
    fn rejects_wrong_rhs_length() {
        let system = DatasetBuilder::new(60, 5).seed(3).consistent();
        let batch = BatchSolver::new(&system, RkSolver::new(3));
        let err = batch
            .solve_many(&[BatchJob::new(vec![0.0; 7])], &SolveOptions::default())
            .err()
            .expect("short rhs must be rejected");
        assert!(matches!(err, Error::Dimension(_)), "{err:?}");
    }

    #[test]
    fn rejects_reference_free_jobs_under_tolerance_stopping() {
        let system = DatasetBuilder::new(60, 5).seed(4).consistent();
        let batch = BatchSolver::new(&system, RkSolver::new(3));
        let jobs = [BatchJob::new(vec![0.0; 60])];
        let err = batch
            .solve_many(&jobs, &SolveOptions::default())
            .err()
            .expect("tolerance stopping without a reference must be rejected");
        assert!(matches!(err, Error::InvalidArgument(_)), "{err:?}");
        // The same job is fine under the fixed-iteration protocol.
        let opts = SolveOptions::default().with_fixed_iterations(50);
        let reports = batch.solve_many(&jobs, &opts).unwrap();
        assert_eq!(reports[0].result.iterations, 50);
        assert!(reports[0].residual_norm.is_finite());
    }

    #[test]
    fn per_job_sinks_demultiplex_batch_telemetry() {
        let system = DatasetBuilder::new(150, 8).seed(6).consistent();
        let mut rxs = Vec::new();
        let jobs: Vec<BatchJob> = jobs_for(&system, 3)
            .into_iter()
            .map(|j| {
                let (sink, rx) = ProgressSink::bounded(64);
                rxs.push(rx);
                j.with_progress(sink)
            })
            .collect();
        let opts = SolveOptions::default().with_fixed_iterations(64).with_history_step(16);
        let batch = BatchSolver::new(&system, RkSolver::new(3)).with_workers(2);
        let reports = batch.solve_many(&jobs, &opts).unwrap();
        for (j, rx) in rxs.iter().enumerate() {
            let samples = rx.drain();
            let h = &reports[j].result.history;
            // Each job's channel carries exactly its own curve (correct
            // demultiplexing even with lanes stealing jobs concurrently).
            assert_eq!(samples.len(), h.len(), "job {j}");
            for (s, (k, r)) in samples.iter().zip(h.iterations.iter().zip(&h.residuals)) {
                assert_eq!(s.k, *k, "job {j}");
                assert_eq!(s.residual.to_bits(), r.to_bits(), "job {j}");
            }
        }
    }

    #[test]
    fn autotuned_rkab_serves_reference_free_jobs() {
        let system = DatasetBuilder::new(120, 6).seed(7).consistent();
        let (solver, bs) = autotuned_rkab(&system, 3, 2, 1.0).unwrap();
        assert!(bs >= 1, "probe must pick a positive block size");
        // The picked solver serves reference-free jobs straight away.
        let jobs: Vec<BatchJob> = (0..3)
            .map(|j| {
                let x: Vec<f64> =
                    (0..system.cols()).map(|i| ((i + j) as f64 * 0.4).sin()).collect();
                BatchJob::new(gemv(&system.a, &x).unwrap())
            })
            .collect();
        let opts = SolveOptions::default()
            .with_residual_stopping(1e-8, 50)
            .with_max_iterations(500_000);
        let reports =
            BatchSolver::new(&system, solver).solve_many(&jobs, &opts).unwrap();
        assert_eq!(reports.len(), 3);
        for r in &reports {
            assert!(r.result.converged, "job {}: residual {}", r.job, r.residual_norm);
        }
    }

    #[test]
    fn single_lane_equals_multi_lane_bitwise() {
        // Lane assignment is scheduling-dependent; the results must not be.
        let system = DatasetBuilder::new(150, 8).seed(5).consistent();
        let jobs = jobs_for(&system, 6);
        let opts = SolveOptions::default().with_fixed_iterations(80);
        let one = BatchSolver::new(&system, RkSolver::new(9))
            .with_workers(1)
            .solve_many(&jobs, &opts)
            .unwrap();
        let many = BatchSolver::new(&system, RkSolver::new(9))
            .with_workers(4)
            .solve_many(&jobs, &opts)
            .unwrap();
        for (a, b) in one.iter().zip(&many) {
            for (u, v) in a.result.x.iter().zip(&b.result.x) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }
}
