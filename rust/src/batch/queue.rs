//! Many independent `(system, options)` jobs per dispatch ([`SolveQueue`]).

use super::{default_workers, fan_out, SolveReport};
use crate::data::LinearSystem;
use crate::error::{Error, Result};
use crate::parallel::pool::WorkerPool;
use crate::solvers::{SolveOptions, Solver};
use std::sync::Arc;

/// A queue of independent solve jobs multiplexed through one pool dispatch.
///
/// Where [`super::BatchSolver`] amortizes one system across many right-hand
/// sides, `SolveQueue` is the multi-tenant shape: every job carries its own
/// [`LinearSystem`] *and* its own [`SolveOptions`] (mixed consistent and
/// inconsistent systems, mixed stopping rules), and one [`WorkerPool::run`]
/// region drains them all with work stealing. Reports come back in push
/// order, one [`SolveReport`] per job, so a diverging or slow job never
/// hides the outcomes of its neighbours.
///
/// Because every job carries its own [`SolveOptions`], **per-job live
/// telemetry** comes free: push a job whose options hold a
/// [`ProgressSink`](crate::metrics::ProgressSink)
/// (`SolveOptions::with_progress`) and watch that job's residual stream on
/// the matching receiver while the queue drains — each job's samples land
/// on its own channel, demultiplexed by construction (see the
/// [module docs](crate::batch) and `tests/telemetry_streaming.rs`).
///
/// # Example
///
/// ```
/// use kaczmarz::batch::SolveQueue;
/// use kaczmarz::data::{DatasetBuilder, LinearSystem};
/// use kaczmarz::linalg::Matrix;
/// use kaczmarz::solvers::rk::RkSolver;
/// use kaczmarz::solvers::SolveOptions;
///
/// let mut queue = SolveQueue::new();
/// // Reproduction-style job: known x*, paper stopping rule.
/// queue.push(
///     DatasetBuilder::new(100, 6).seed(2).consistent(),
///     SolveOptions::default(),
/// );
/// // Timing-style job: fixed budget, nothing measured.
/// queue.push(
///     DatasetBuilder::new(80, 5).seed(3).inconsistent(),
///     SolveOptions::default().with_fixed_iterations(200),
/// );
/// // Serving-style job: no reference solution exists — stop on the
/// // residual, which needs none, and solve the system in place.
/// let a = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]).unwrap();
/// queue.push(
///     LinearSystem::new(a, vec![1.0, 2.0, 3.0], None, true),
///     SolveOptions::default().with_residual_stopping(1e-12, 16),
/// );
/// let reports = queue.run(&RkSolver::new(1)).unwrap();
/// assert_eq!(reports.len(), 3);
/// assert!(reports[0].result.converged);
/// assert!(!reports[1].result.converged); // budget spent, nothing measured
/// assert!(reports[1].residual_norm > 0.0); // inconsistent: residual floor
/// assert!(reports[2].result.converged); // certified: ‖Ax - b‖² < 1e-12
/// ```
pub struct SolveQueue {
    jobs: Vec<(LinearSystem, SolveOptions)>,
    workers: usize,
    pool: Option<Arc<WorkerPool>>,
}

impl SolveQueue {
    /// Empty queue with one lane per hardware thread.
    pub fn new() -> Self {
        SolveQueue { jobs: Vec::new(), workers: default_workers(), pool: None }
    }

    /// Cap the number of jobs in flight at once.
    pub fn with_workers(mut self, workers: usize) -> Self {
        assert!(workers >= 1, "need at least one lane");
        self.workers = workers;
        self
    }

    /// Dispatch on a dedicated pool instead of the process-global one.
    pub fn with_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Enqueue a job; returns its id (= its index in the report vector).
    pub fn push(&mut self, system: LinearSystem, opts: SolveOptions) -> usize {
        self.jobs.push((system, opts));
        self.jobs.len() - 1
    }

    /// Number of queued jobs.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    /// True when no jobs are queued.
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Run every queued job with `solver` on the pool; the queue itself is
    /// untouched, so it can be re-run (e.g. with a different solver).
    ///
    /// Fails fast on the calling thread if a job's options would consult a
    /// reference solution its system does not carry
    /// ([`SolveOptions::consults_reference`], the same contract as
    /// [`super::BatchSolver::solve_many`]): only reference-error *stopping*
    /// needs one. Every job — with or without a reference — is solved *in
    /// place*, zero clones: solvers evaluate their stopping metric lazily,
    /// so a reference-free job under residual stopping or a fixed budget
    /// simply never looks for one — and such a job may still request a
    /// (residual-channel) history via `history_step`.
    pub fn run<S: Solver + Sync>(&self, solver: &S) -> Result<Vec<SolveReport>> {
        for (j, (system, opts)) in self.jobs.iter().enumerate() {
            if system.reference_solution().is_none() && opts.consults_reference() {
                return Err(Error::InvalidArgument(format!(
                    "job {j}: its system has no reference solution, so \
                     reference-error stopping is unavailable (stop on the \
                     residual or use fixed_iterations; histories work either \
                     way — they degrade to the residual channel)"
                )));
            }
        }
        if self.jobs.is_empty() {
            return Ok(Vec::new());
        }
        let lane_count = self.workers.min(self.jobs.len()).max(1);
        let pool = self.pool.as_deref().unwrap_or_else(|| crate::parallel::pool::global());
        Ok(fan_out(pool, lane_count, self.jobs.len(), |_lane, j| {
            let (system, opts) = &self.jobs[j];
            let result = solver.solve(system, opts);
            let residual_norm = system.residual_norm(&result.x);
            // In-process queue: a lane claims the job inside the same pool
            // dispatch that runs it, so there is no measurable queue wait.
            let dropped_samples = opts.progress.as_ref().map_or(0, |s| s.dropped());
            SolveReport {
                job: j,
                solver: solver.name(),
                result,
                residual_norm,
                queue_wait: std::time::Duration::ZERO,
                dropped_samples,
            }
        }))
    }
}

impl Default for SolveQueue {
    fn default() -> Self {
        SolveQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetBuilder;
    use crate::solvers::rk::RkSolver;

    #[test]
    fn reports_come_back_in_push_order() {
        let mut queue = SolveQueue::new().with_workers(3);
        for seed in 0..6u32 {
            let id = queue.push(
                DatasetBuilder::new(120 + 10 * seed as usize, 6).seed(seed).consistent(),
                SolveOptions::default(),
            );
            assert_eq!(id, seed as usize);
        }
        assert_eq!(queue.len(), 6);
        let reports = queue.run(&RkSolver::new(5)).unwrap();
        for (j, r) in reports.iter().enumerate() {
            assert_eq!(r.job, j);
            assert_eq!(r.solver, "RK");
            assert!(r.result.converged, "job {j}");
        }
    }

    #[test]
    fn empty_queue_is_ok() {
        let queue = SolveQueue::new();
        assert!(queue.is_empty());
        assert!(queue.run(&RkSolver::new(1)).unwrap().is_empty());
    }

    #[test]
    fn rejects_referenceless_job_with_tolerance_stopping() {
        use crate::linalg::Matrix;
        let a = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        // No x_true / x_ls: nothing to measure the error against.
        let system = LinearSystem::new(a, vec![1.0, 2.0], None, true);
        let mut queue = SolveQueue::new();
        queue.push(system, SolveOptions::default());
        let err = queue.run(&RkSolver::new(1)).err().expect("must be rejected");
        assert!(matches!(err, Error::InvalidArgument(_)), "{err:?}");
    }

    #[test]
    fn referenceless_job_runs_under_fixed_budget() {
        // The path the rejection message advertises: no reference, but a
        // fixed iteration budget with history off. Must solve, not panic.
        use crate::linalg::Matrix;
        let a = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]).unwrap();
        let system = LinearSystem::new(a, vec![1.0, 2.0, 3.0], None, true);
        let mut queue = SolveQueue::new();
        queue.push(system, SolveOptions::default().with_fixed_iterations(200));
        let reports = queue.run(&RkSolver::new(4)).unwrap();
        assert_eq!(reports[0].result.iterations, 200);
        // x* = [1, 2] is reachable: the residual must be tiny.
        assert!(reports[0].residual_norm < 1e-8, "residual {}", reports[0].residual_norm);
    }

    #[test]
    fn rerun_is_bit_deterministic() {
        let mut queue = SolveQueue::new();
        for seed in 0..3u32 {
            queue.push(
                DatasetBuilder::new(100, 6).seed(seed).consistent(),
                SolveOptions::default().with_fixed_iterations(60),
            );
        }
        let first = queue.run(&RkSolver::new(2)).unwrap();
        let second = queue.run(&RkSolver::new(2)).unwrap();
        for (a, b) in first.iter().zip(&second) {
            for (u, v) in a.result.x.iter().zip(&b.result.x) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }
}
