//! Hand-rolled CLI argument parsing (no clap in the offline environment).
//!
//! Grammar: `kaczmarz <command> [positional...] [--flag value | --switch]`.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    /// First non-flag token (the subcommand).
    pub command: String,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
    /// `--key value` and `--switch` (value "true") flags.
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(key) = tok.strip_prefix("--") {
                let value = match iter.peek() {
                    Some(v) if !v.starts_with("--") => iter.next().unwrap(),
                    _ => "true".to_string(),
                };
                args.flags.insert(key.to_string(), value);
            } else if args.command.is_empty() {
                args.command = tok;
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// String flag with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Typed flag with default; panics with a clear message on parse errors.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        match self.flags.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key} {v}: cannot parse ({e:?})")),
        }
    }

    /// Boolean switch (present => true).
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_and_positionals() {
        let a = parse("experiment fig4 extra");
        assert_eq!(a.command, "experiment");
        assert_eq!(a.positional, vec!["fig4", "extra"]);
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = parse("solve --rows 100 --verbose --method rkab");
        assert_eq!(a.get("rows", "0"), "100");
        assert!(a.has("verbose"));
        assert_eq!(a.get("method", "rk"), "rkab");
        assert_eq!(a.get_parse::<usize>("rows", 0), 100);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("solve");
        assert_eq!(a.get_parse::<f64>("alpha", 1.0), 1.0);
        assert!(!a.has("verbose"));
    }

    #[test]
    #[should_panic]
    fn bad_typed_flag_panics() {
        let a = parse("solve --rows abc");
        let _ = a.get_parse::<usize>("rows", 0);
    }
}
