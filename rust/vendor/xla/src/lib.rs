//! Offline stand-in for the `xla` (PJRT) bindings.
//!
//! This container has no XLA toolchain, so the real bindings cannot link.
//! This stub exposes the exact API surface `kaczmarz::runtime` consumes and
//! fails at the *client-creation* boundary (`PjRtClient::cpu`) with a clear
//! message, so everything downstream compiles and the runtime integration
//! tests skip cleanly when artifacts are absent. Host-side pieces that need
//! no backend ([`Literal`] construction/reshape) are implemented for real so
//! shape validation keeps working.
//!
//! Swapping in the real crate is a one-line change in `rust/Cargo.toml`; no
//! `kaczmarz` source changes are required.

use std::fmt;

/// Error type mirroring `xla::Error` (message-only in the stub).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias mirroring the real bindings.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT backend not available in this build (offline `xla` stub); \
         install the real `xla` crate to execute AOT artifacts"
    ))
}

/// Scalar types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    /// Convert from the stub's f64 storage.
    fn from_f64(v: f64) -> Self;
}

impl NativeType for f64 {
    fn from_f64(v: f64) -> f64 {
        v
    }
}

/// Host-side tensor: flat f64 buffer plus dimensions.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f64>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a flat buffer.
    pub fn vec1(data: &[f64]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reshape; errors when the element count does not match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let expected: i64 = dims.iter().product();
        if expected != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape: literal of {} elements cannot have shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Current dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Unwrap a single-element tuple result (identity for flat literals).
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }

    /// Copy the contents out as `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f64(v)).collect())
    }
}

/// Device-side buffer handle (never constructible through the stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Copy back to host.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// PJRT client handle.
pub struct PjRtClient(());

impl PjRtClient {
    /// CPU client — always fails in the stub (no backend is linked).
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform string.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given inputs; `L` mirrors the real bindings' generic
    /// argument (`Literal` in all call sites here).
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO text file — requires the real parser, so the stub fails
    /// (after a readability check so missing files report as IO-shaped).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(Error(format!("hlo text file not found: {path}")));
        }
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper.
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert!(l.reshape(&[4, 2]).is_err());
        let back: Vec<f64> = r.to_vec().unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn client_creation_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(err.to_string().contains("offline"), "{err}");
    }
}
