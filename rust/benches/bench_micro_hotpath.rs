//! Micro-benchmarks of the L3 hot paths feeding the cost model and the
//! §Perf pass: dot/axpy (the per-iteration projection), row sampling
//! (alias vs CDF), gather-add, atomic CAS-add, memcpy, barrier crossings,
//! the batch-serving fan-out (batched vs looped single solves), stop-check
//! overhead, and telemetry-sink overhead. Prints ns/op and effective GB/s.
//!
//! **Perf-tracking CI lane:** this harness is also the `bench-smoke` CI
//! job's workload. `BENCH_SMOKE=1` shrinks every problem size/iteration
//! count (~1 min wall instead of many), and the run always writes a
//! machine-readable `BENCH_micro.json` (override the path with
//! `BENCH_JSON=...`): every table row (per-op ns/iter) plus the
//! bitwise-equivalence flags. The process **exits nonzero when any
//! equivalence check fails**, so fused-kernel or batching drift cannot
//! merge green; timing ratios are printed but never gate (CI runners are
//! too noisy to fail on perf numbers alone).

use kaczmarz::batch::{BatchJob, BatchSolver};
use kaczmarz::data::{DatasetBuilder, LinearSystem, SparseDatasetBuilder};
use kaczmarz::linalg::simd::{axpy_avx2, axpy_dot_avx2, dot_avx2};
use kaczmarz::linalg::vector::{axpy, axpy_dot_scalar, axpy_scalar, dot, dot_scalar};
use kaczmarz::linalg::{
    active_flavor, detected_flavor, gemv, gemv_block_into, gemv_panel, KernelFlavor, Matrix,
    Storage,
};
use kaczmarz::metrics::{ProgressSink, Stopwatch};
use kaczmarz::parallel::shared::{AtomicF64Vec, SpinBarrier};
use kaczmarz::parallel::WorkerPool;
use kaczmarz::report::{json_string, Table};
use kaczmarz::rng::{AliasTable, DiscreteDistribution, Mt19937};
use kaczmarz::serve::{FrontEndConfig, SolveFrontEnd, SubmitRequest, SystemRegistry};
use kaczmarz::solvers::rek::RekSolver;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rka::RkaSolver;
use kaczmarz::solvers::rkab::{block_sweep, RkabSolver};
use kaczmarz::solvers::{GreedySelector, RowSampler, SamplingScheme, SolveOptions, Solver};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn bench<F: FnMut()>(mut f: F, iters: usize) -> f64 {
    // Warmup.
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let sw = Stopwatch::start();
    for _ in 0..iters {
        f();
    }
    sw.seconds() / iters as f64
}

fn main() {
    // BENCH_SMOKE=1: the CI-sized run (reduced sizes, same coverage).
    let smoke = std::env::var("BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    // Iteration-count divisor for timing loops in smoke mode.
    let shrink = if smoke { 10 } else { 1 };
    if smoke {
        eprintln!("BENCH_SMOKE=1: reduced problem sizes (perf-tracking CI lane)");
    }
    // Which kernel flavor the *dispatched* rows below run under (recorded
    // at the top level of BENCH_micro.json so compare_bench.py never
    // mistakes a simd-vs-scalar timing delta for regression drift).
    let have_simd = detected_flavor() == KernelFlavor::Avx2Fma;
    eprintln!(
        "kernels: dispatch={} host={}",
        active_flavor().name(),
        detected_flavor().name()
    );
    if !have_simd {
        eprintln!("[kernels] host lacks AVX2+FMA: [simd] rows skipped, flavor gates pass trivially");
    }

    let mut t = Table::new(
        "L3 hot-path micro-benchmarks",
        &["operation", "n", "ns/op", "GB/s (eff)"],
    );
    // Equivalence gates: (name, pass). Any `false` fails the process at the
    // end — these are bit-exactness claims, not timing claims.
    let mut checks: Vec<(String, bool)> = Vec::new();

    let mut rng = Mt19937::new(1);
    for n in [50usize, 200, 1000, 4000, 10000] {
        let a: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mut y = vec![0.0f64; n];
        let iters = (50_000_000 / shrink / n).max(100);

        let td = bench(
            || {
                std::hint::black_box(dot(std::hint::black_box(&a), std::hint::black_box(&b)));
            },
            iters,
        );
        t.row(vec![
            "dot".into(),
            n.to_string(),
            format!("{:.1}", td * 1e9),
            format!("{:.1}", 16.0 * n as f64 / td / 1e9),
        ]);

        let ta = bench(
            || {
                axpy(1.0001, std::hint::black_box(&a), std::hint::black_box(&mut y));
            },
            iters,
        );
        t.row(vec![
            "axpy".into(),
            n.to_string(),
            format!("{:.1}", ta * 1e9),
            format!("{:.1}", 24.0 * n as f64 / ta / 1e9),
        ]);
    }

    // Explicit kernel-flavor rows: the scalar 8-lane reference vs the
    // AVX2+FMA kernels, timed side by side through the flavor-explicit
    // entry points (`*_scalar` vs `simd::*_avx2`, independent of the
    // process-wide dispatch). Cross-flavor agreement is a *relative
    // tolerance* gate — FMA legally contracts `a*b + c` into one rounding,
    // so bitwise comparison across flavors is meaningless; the bitwise
    // gates elsewhere in this harness keep gating the scalar path.
    {
        const KERNEL_REL_TOL: f64 = 1e-11;
        let rel_ok = |got: f64, reference: f64| {
            (got - reference).abs() / reference.abs().max(1e-30) < KERNEL_REL_TOL
        };
        let mut dot_ok = true;
        let mut axpy_ok = true;
        let mut fused_ok = true;
        let mut rngk = Mt19937::new(77);
        for n in [1000usize, 10000] {
            let a: Vec<f64> = (0..n).map(|_| rngk.next_f64() - 0.5).collect();
            let b: Vec<f64> = (0..n).map(|_| rngk.next_f64() - 0.5).collect();
            let z: Vec<f64> = (0..n).map(|_| rngk.next_f64() - 0.5).collect();
            let mut y = vec![0.0f64; n];
            let iters = (50_000_000 / shrink / n).max(100);

            let td_s = bench(
                || {
                    std::hint::black_box(dot_scalar(
                        std::hint::black_box(&a),
                        std::hint::black_box(&b),
                    ));
                },
                iters,
            );
            t.row(vec![
                "dot [scalar]".into(),
                n.to_string(),
                format!("{:.1}", td_s * 1e9),
                format!("{:.1}", 16.0 * n as f64 / td_s / 1e9),
            ]);
            let ta_s = bench(
                || {
                    axpy_scalar(1.0001, std::hint::black_box(&a), std::hint::black_box(&mut y));
                },
                iters,
            );
            t.row(vec![
                "axpy [scalar]".into(),
                n.to_string(),
                format!("{:.1}", ta_s * 1e9),
                format!("{:.1}", 24.0 * n as f64 / ta_s / 1e9),
            ]);
            // scale 0.0 keeps y bounded over millions of applications while
            // doing identical memory traffic and flops.
            let tf_s = bench(
                || {
                    std::hint::black_box(axpy_dot_scalar(
                        0.0,
                        std::hint::black_box(&a),
                        std::hint::black_box(&z),
                        std::hint::black_box(&mut y),
                    ));
                },
                iters,
            );
            t.row(vec![
                "axpy_dot [scalar]".into(),
                n.to_string(),
                format!("{:.1}", tf_s * 1e9),
                format!("{:.1}", 32.0 * n as f64 / tf_s / 1e9),
            ]);

            if have_simd {
                let td_v = bench(
                    || {
                        std::hint::black_box(
                            dot_avx2(std::hint::black_box(&a), std::hint::black_box(&b)).unwrap(),
                        );
                    },
                    iters,
                );
                t.row(vec![
                    "dot [simd]".into(),
                    n.to_string(),
                    format!("{:.1}", td_v * 1e9),
                    format!("{:.1}", 16.0 * n as f64 / td_v / 1e9),
                ]);
                let ta_v = bench(
                    || {
                        axpy_avx2(1.0001, std::hint::black_box(&a), std::hint::black_box(&mut y));
                    },
                    iters,
                );
                t.row(vec![
                    "axpy [simd]".into(),
                    n.to_string(),
                    format!("{:.1}", ta_v * 1e9),
                    format!("{:.1}", 24.0 * n as f64 / ta_v / 1e9),
                ]);
                let tf_v = bench(
                    || {
                        std::hint::black_box(
                            axpy_dot_avx2(
                                0.0,
                                std::hint::black_box(&a),
                                std::hint::black_box(&z),
                                std::hint::black_box(&mut y),
                            )
                            .unwrap(),
                        );
                    },
                    iters,
                );
                t.row(vec![
                    "axpy_dot [simd]".into(),
                    n.to_string(),
                    format!("{:.1}", tf_v * 1e9),
                    format!("{:.1}", 32.0 * n as f64 / tf_v / 1e9),
                ]);
                println!(
                    "[kernels n={n}] simd/scalar: dot = {:.3}, axpy = {:.3}, axpy_dot = {:.3} \
                     (< 1 means the simd kernel is faster)",
                    td_v / td_s,
                    ta_v / ta_s,
                    tf_v / tf_s
                );
            }
        }

        // The tolerance gates, across every remainder length n mod 8: the
        // two flavors must agree to KERNEL_REL_TOL on dot and the fused
        // kernel's returned dot, and element-wise on both axpy outputs.
        // On a host without AVX2+FMA the gates pass trivially (there is
        // only one flavor to run).
        if have_simd {
            for n in [64usize, 65, 66, 67, 68, 69, 70, 71, 1003] {
                let a: Vec<f64> = (0..n).map(|_| rngk.next_f64() - 0.5).collect();
                let b: Vec<f64> = (0..n).map(|_| rngk.next_f64() - 0.5).collect();
                let z: Vec<f64> = (0..n).map(|_| rngk.next_f64() - 0.5).collect();
                dot_ok &= rel_ok(dot_avx2(&a, &b).unwrap(), dot_scalar(&a, &b));
                let mut y_s = b.clone();
                axpy_scalar(0.73, &a, &mut y_s);
                let mut y_v = b.clone();
                axpy_avx2(0.73, &a, &mut y_v);
                axpy_ok &= y_s.iter().zip(&y_v).all(|(u, v)| rel_ok(*v, *u));
                let mut y_s = b.clone();
                let f_s = axpy_dot_scalar(0.41, &a, &z, &mut y_s);
                let mut y_v = b.clone();
                let f_v = axpy_dot_avx2(0.41, &a, &z, &mut y_v).unwrap();
                fused_ok &= rel_ok(f_v, f_s) && y_s.iter().zip(&y_v).all(|(u, v)| rel_ok(*v, *u));
            }
        }
        println!(
            "[kernels] simd-vs-scalar tolerance gates: dot = {dot_ok}, axpy = {axpy_ok}, \
             axpy_dot = {fused_ok} (must all be true)"
        );
        checks.push(("simd dot vs scalar (rel tol)".into(), dot_ok));
        checks.push(("simd axpy vs scalar (rel tol)".into(), axpy_ok));
        checks.push(("simd axpy_dot vs scalar (rel tol)".into(), fused_ok));
    }

    // Full projection on a real system (what CostModel::t_proj measures).
    let (proj_m, proj_n, proj_iters) =
        if smoke { (1200usize, 300usize, 4_000usize) } else { (4000, 1000, 20_000) };
    let sys = DatasetBuilder::new(proj_m, proj_n).seed(3).consistent();
    let r = RkSolver::new(1)
        .solve(&sys, &SolveOptions::default().with_fixed_iterations(proj_iters));
    t.row(vec![
        format!("RK projection ({proj_m}x{proj_n} system)"),
        proj_n.to_string(),
        format!("{:.1}", r.seconds / r.iterations as f64 * 1e9),
        format!("{:.1}", 16.0 * proj_n as f64 / (r.seconds / r.iterations as f64) / 1e9),
    ]);

    // REK's column-space step (col_dot + col_axpy over the m-vector z) and
    // the full REK iteration (one column + one row projection): the zoo's
    // per-iteration cost next to the plain RK projection above. The column
    // kernels stride down the dense row-major buffer, so their effective
    // bandwidth is the cache-unfriendly bound, not the streaming one.
    {
        let cnorms = sys.a.col_norms_sq();
        let mut z = sys.b.clone();
        let mut j = 0usize;
        let col_iters = (20_000_000 / shrink / proj_m).max(100);
        let tc = bench(
            || {
                let d = sys.a.col_dot(j, &z) / cnorms[j];
                sys.a.col_axpy(j, -d, &mut z);
                j = if j + 1 == proj_n { 0 } else { j + 1 };
                std::hint::black_box(&mut z);
            },
            col_iters,
        );
        t.row(vec![
            format!("REK column projection ({proj_m}x{proj_n})"),
            proj_m.to_string(),
            format!("{:.1}", tc * 1e9),
            format!("{:.1}", 32.0 * proj_m as f64 / tc / 1e9),
        ]);
        let r = RekSolver::new(1)
            .solve(&sys, &SolveOptions::default().with_fixed_iterations(proj_iters / 2));
        t.row(vec![
            format!("REK iteration ({proj_m}x{proj_n} system)"),
            proj_n.to_string(),
            format!("{:.1}", r.seconds / r.iterations as f64 * 1e9),
            "-".into(),
        ]);
    }

    // Greedy Motzkin selection: every pick scans the full residual (one
    // gemv_block_into pass + an m-length argmax) where the randomized
    // sampler pays one O(1) alias draw — this row is that price, per
    // selected row, for the README's "when is greedy worth it" paragraph.
    {
        let mut g = GreedySelector::new(&sys);
        let x = vec![0.0f64; sys.cols()];
        let scan_iters = (200_000_000 / shrink / (proj_m * proj_n)).max(10);
        let tg = bench(
            || {
                std::hint::black_box(g.select(&sys, &x, 1));
            },
            scan_iters,
        );
        t.row(vec![
            format!("greedy Motzkin scan ({proj_m}x{proj_n})"),
            proj_m.to_string(),
            format!("{:.0}", tg * 1e9),
            format!("{:.1}", 8.0 * (proj_m * proj_n) as f64 / tg / 1e9),
        ]);
    }

    // RKAB in-block sweep: the real fused kernel (solvers::rkab::block_sweep,
    // the exact function on the solver hot path) vs the seed's scalar
    // dot-then-axpy row loop, per block size. Both shapes draw bs fresh rows
    // per sweep from identically-seeded samplers, so sampling cost cancels;
    // the fused kernel touches v once per projection instead of twice, so it
    // must be no slower at every bs and clearly faster once the block stops
    // fitting in L1/L2.
    {
        let n = sys.cols();
        for bs in [1usize, 8, 32, 128, 512] {
            let sweeps = (2_000_000 / shrink / (bs * n).max(1)).max(10);
            let alpha = 1.0;

            // Row-loop baseline (the seed's formulation).
            let mut sampler = RowSampler::new(&sys, SamplingScheme::FullMatrix, 0, 1, 17);
            let mut idx: Vec<usize> = Vec::with_capacity(bs);
            let mut v = vec![0.0f64; n];
            let t_base = bench(
                || {
                    idx.clear();
                    for _ in 0..bs {
                        idx.push(sampler.sample());
                    }
                    for &i in &idx {
                        let row = sys.a.row(i);
                        let scale = alpha * (sys.b[i] - dot(row, &v)) / sys.row_norms_sq[i];
                        axpy(scale, row, &mut v);
                    }
                    std::hint::black_box(&mut v);
                },
                sweeps,
            );

            // The solver's fused kernel, measured directly.
            let mut sampler = RowSampler::new(&sys, SamplingScheme::FullMatrix, 0, 1, 17);
            let mut idx: Vec<usize> = Vec::with_capacity(bs);
            let mut v = vec![0.0f64; n];
            let t_fused = bench(
                || {
                    block_sweep(&sys, &mut sampler, bs, alpha, &mut v, &mut idx);
                    std::hint::black_box(&mut v);
                },
                sweeps,
            );

            let per_row_base = t_base / bs as f64;
            let per_row_fused = t_fused / bs as f64;
            t.row(vec![
                format!("rkab sweep row-loop (bs={bs})"),
                n.to_string(),
                format!("{:.1}", per_row_base * 1e9),
                format!("{:.1}", 32.0 * n as f64 / per_row_base / 1e9),
            ]);
            t.row(vec![
                format!("rkab sweep fused (bs={bs})"),
                n.to_string(),
                format!("{:.1}", per_row_fused * 1e9),
                format!("{:.1}", 32.0 * n as f64 / per_row_fused / 1e9),
            ]);
            println!(
                "[rkab-sweep bs={bs}] fused/base = {:.3} (must be <= ~1.0; < 1 means faster)",
                per_row_fused / per_row_base
            );
        }

        // Bitwise equivalence: the fused kernel must reproduce the exact
        // bits of the dot-then-axpy formulation (same sampled rows, same
        // FP operation order). Drift here is a silent numerics change in
        // the RKAB hot path — this is the check that gates the CI lane.
        {
            let bs = 32usize;
            let mut s_base = RowSampler::new(&sys, SamplingScheme::FullMatrix, 0, 1, 99);
            let mut s_fused = RowSampler::new(&sys, SamplingScheme::FullMatrix, 0, 1, 99);
            let mut idx_base: Vec<usize> = Vec::with_capacity(bs);
            let mut idx_fused: Vec<usize> = Vec::with_capacity(bs);
            let mut v_base = vec![0.0f64; n];
            let mut v_fused = vec![0.0f64; n];
            for _ in 0..50 {
                idx_base.clear();
                for _ in 0..bs {
                    idx_base.push(s_base.sample());
                }
                for &i in &idx_base {
                    let row = sys.a.row(i);
                    let scale = (sys.b[i] - dot(row, &v_base)) / sys.row_norms_sq[i];
                    axpy(scale, row, &mut v_base);
                }
                block_sweep(&sys, &mut s_fused, bs, 1.0, &mut v_fused, &mut idx_fused);
            }
            let bitwise = idx_base == idx_fused
                && v_base.iter().zip(&v_fused).all(|(a, b)| a.to_bits() == b.to_bits());
            println!("[rkab-sweep] fused bitwise-equal to row loop = {bitwise} (must be true)");
            checks.push(("rkab fused sweep bitwise vs row loop".into(), bitwise));
        }
    }

    // Storage-generic row kernels: the fused row_axpy_dot (projection j's
    // update + projection j+1's residual dot, the RKAB in-block hot op) on
    // CSR storage at 1%/10%/50% density vs the same matrix densified. The
    // sparse op touches only stored coordinates, so its ns/op should track
    // nnz per row rather than n — the rows below are where the density
    // break-even documented in the README is measured.
    {
        let (sm, sn) = if smoke { (400usize, 512usize) } else { (1000, 2048) };
        for density in [0.01f64, 0.1, 0.5] {
            let sparse = SparseDatasetBuilder::new(sm, sn, density).seed(61).consistent();
            let csr = sparse.a.as_csr().expect("sparse builder yields CSR").clone();
            let dense: Storage = csr.to_dense().into();
            let nnz_row = csr.nnz() / sm;
            let iters = (20_000_000 / shrink / sn).max(100);

            // scale = 0.0 keeps the iterate bounded across millions of
            // applications while performing the identical memory traffic
            // and flops per stored entry.
            let mut v = vec![0.5f64; sn];
            let mut i = 0usize;
            let t_sparse = bench(
                || {
                    let next = if i + 1 == sm { 0 } else { i + 1 };
                    std::hint::black_box(sparse.a.row_axpy_dot(i, 0.0, next, &mut v));
                    i = next;
                },
                iters,
            );
            let mut v = vec![0.5f64; sn];
            let mut i = 0usize;
            let t_dense = bench(
                || {
                    let next = if i + 1 == sm { 0 } else { i + 1 };
                    std::hint::black_box(dense.row_axpy_dot(i, 0.0, next, &mut v));
                    i = next;
                },
                iters,
            );
            let pct = (density * 100.0).round() as usize;
            t.row(vec![
                format!("axpy_dot csr {pct}% (nnz/row={nnz_row})"),
                sn.to_string(),
                format!("{:.1}", t_sparse * 1e9),
                "-".into(),
            ]);
            t.row(vec![
                format!("axpy_dot dense of {pct}% matrix"),
                sn.to_string(),
                format!("{:.1}", t_dense * 1e9),
                "-".into(),
            ]);
            println!(
                "[axpy_dot density={pct}%] csr/dense = {:.3} (should shrink with density)",
                t_sparse / t_dense
            );
        }

        // Dense Storage dispatch must reproduce the raw fused kernel bit for
        // bit — this identity is what lets every dense solver keep its seed
        // bits after the storage-generic refactor, so it gates the CI lane.
        {
            let d = DatasetBuilder::new(64, 96).seed(71).consistent();
            let dense_m = d.a.as_dense().expect("generated systems are dense").clone();
            let mut v1 = vec![0.25f64; 96];
            let mut v2 = v1.clone();
            let mut ok = true;
            for i in 0..63 {
                let f1 = d.a.row_axpy_dot(i, 0.37, i + 1, &mut v1);
                let f2 = kaczmarz::linalg::axpy_dot(
                    0.37,
                    dense_m.row(i),
                    dense_m.row(i + 1),
                    &mut v2,
                );
                ok &= f1.to_bits() == f2.to_bits();
            }
            ok &= v1.iter().zip(&v2).all(|(a, b)| a.to_bits() == b.to_bits());
            println!(
                "[storage] dense Storage row_axpy_dot bitwise vs raw kernel = {ok} (must be true)"
            );
            checks.push(("dense storage row_axpy_dot bitwise vs raw kernel".into(), ok));
        }
    }

    // Cache-blocked gemv on a wide matrix (x no longer fits L1): panel
    // kernel vs the straight row-dot loop.
    {
        let (m, n) = if smoke { (256usize, 2048usize) } else { (512, 8192) };
        let mut rngw = Mt19937::new(23);
        let data: Vec<f64> = (0..m * n).map(|_| rngw.next_f64() - 0.5).collect();
        let a = Matrix::from_vec(m, n, data).unwrap();
        let x: Vec<f64> = (0..n).map(|_| rngw.next_f64() - 0.5).collect();
        let mut y = vec![0.0f64; m];
        let iters = if smoke { 20 } else { 50 };
        let t_naive = bench(
            || {
                for (yi, row) in y.iter_mut().zip(a.rows_iter()) {
                    *yi = dot(row, &x);
                }
                std::hint::black_box(&mut y);
            },
            iters,
        );
        let t_blocked = bench(
            || {
                gemv_block_into(&a, &x, &mut y);
                std::hint::black_box(&mut y);
            },
            iters,
        );
        let bytes = (m * n + n + m) as f64 * 8.0;
        t.row(vec![
            format!("gemv row-dot ({m}x{n})"),
            n.to_string(),
            format!("{:.0}", t_naive * 1e9),
            format!("{:.1}", bytes / t_naive / 1e9),
        ]);
        t.row(vec![
            format!("gemv cache-blocked ({m}x{n})"),
            n.to_string(),
            format!("{:.0}", t_blocked * 1e9),
            format!("{:.1}", bytes / t_blocked / 1e9),
        ]);

        // Flavor-explicit blocked gemv (same panel walk, inner dot pinned
        // to one flavor) — the fourth kernel the tolerance gate covers.
        let mut y_s = vec![0.0f64; m];
        let t_gs = bench(
            || {
                gemv_flavored(&a, &x, &mut y_s, false);
                std::hint::black_box(&mut y_s);
            },
            iters,
        );
        t.row(vec![
            format!("gemv [scalar] ({m}x{n})"),
            n.to_string(),
            format!("{:.0}", t_gs * 1e9),
            format!("{:.1}", bytes / t_gs / 1e9),
        ]);
        if have_simd {
            let mut y_v = vec![0.0f64; m];
            let t_gv = bench(
                || {
                    gemv_flavored(&a, &x, &mut y_v, true);
                    std::hint::black_box(&mut y_v);
                },
                iters,
            );
            t.row(vec![
                format!("gemv [simd] ({m}x{n})"),
                n.to_string(),
                format!("{:.0}", t_gv * 1e9),
                format!("{:.1}", bytes / t_gv / 1e9),
            ]);
            println!(
                "[kernels] gemv simd/scalar = {:.3} (< 1 means the simd kernel is faster)",
                t_gv / t_gs
            );
            gemv_flavored(&a, &x, &mut y_s, false);
            gemv_flavored(&a, &x, &mut y_v, true);
            let ok = y_s.iter().zip(&y_v).all(|(u, v)| {
                (v - u).abs() / u.abs().max(1e-30) < 1e-11
            });
            println!("[kernels] simd gemv vs scalar tolerance gate = {ok} (must be true)");
            checks.push(("simd gemv vs scalar (rel tol)".into(), ok));
        } else {
            checks.push(("simd gemv vs scalar (rel tol)".into(), true));
        }
    }

    // Row sampling: alias vs CDF binary search.
    let weights = sys.sampling_weights();
    let alias = AliasTable::new(weights);
    let cdf = DiscreteDistribution::new(weights);
    let mut rng2 = Mt19937::new(9);
    let sample_iters = 2_000_000 / shrink;
    let ts = bench(
        || {
            std::hint::black_box(alias.sample(&mut rng2));
        },
        sample_iters,
    );
    t.row(vec![
        "sample (alias)".into(),
        format!("m={}", sys.rows()),
        format!("{:.1}", ts * 1e9),
        "-".into(),
    ]);
    let ts = bench(
        || {
            std::hint::black_box(cdf.sample(&mut rng2));
        },
        sample_iters,
    );
    t.row(vec![
        "sample (cdf bsearch)".into(),
        format!("m={}", sys.rows()),
        format!("{:.1}", ts * 1e9),
        "-".into(),
    ]);

    // Gather primitives at n = 1000.
    let n = 1000;
    let src: Vec<f64> = (0..n).map(|i| i as f64).collect();
    let mut dst = vec![0.0f64; n];
    let tg = bench(
        || {
            for i in 0..n {
                dst[i] += src[i];
            }
            std::hint::black_box(&mut dst);
        },
        50_000 / shrink,
    );
    t.row(vec![
        "gather add (critical body)".into(),
        n.to_string(),
        format!("{:.1}", tg * 1e9),
        format!("{:.1}", 24.0 * n as f64 / tg / 1e9),
    ]);
    let av = AtomicF64Vec::zeros(n);
    let tat = bench(
        || {
            for i in 0..n {
                av.add(i, 1.0);
            }
        },
        20_000 / shrink,
    );
    t.row(vec![
        "atomic CAS add".into(),
        n.to_string(),
        format!("{:.1}", tat * 1e9),
        format!("{:.1}", 24.0 * n as f64 / tat / 1e9),
    ]);
    let tc = bench(
        || {
            dst.copy_from_slice(&src);
            std::hint::black_box(&mut dst);
        },
        100_000 / shrink,
    );
    t.row(vec![
        "memcpy".into(),
        n.to_string(),
        format!("{:.1}", tc * 1e9),
        format!("{:.1}", 16.0 * n as f64 / tc / 1e9),
    ]);

    // Barrier crossing (measured; note: 1-core container oversubscribes).
    // Runs as a pool dispatch — the same engine the solvers use — with a
    // warm-up dispatch first so worker spawning stays off the clock.
    for q in [2usize, 4] {
        let barrier = SpinBarrier::new(q);
        let rounds = 20_000usize / shrink;
        let pool = WorkerPool::new();
        pool.run(q, |_| {});
        let sw = Stopwatch::start();
        pool.run(q, |_| {
            for _ in 0..rounds {
                barrier.wait();
            }
        });
        t.row(vec![
            format!("spin barrier crossing (q={q})"),
            "-".into(),
            format!("{:.1}", sw.seconds() / rounds as f64 * 1e9),
            "-".into(),
        ]);
    }

    // Batch serving: right-hand sides against one system, solved by a loop
    // of independent single solves (each paying system construction:
    // matrix copy + row-norm recompute) vs one BatchSolver dispatch (lane
    // state prepared once, jobs fanned across the pool). The batched path
    // must be at least as fast and bitwise-equal to the loop.
    {
        let (bm, bn, n_jobs, b_iters) =
            if smoke { (600usize, 120usize, 8usize, 800usize) } else { (1500, 250, 16, 2000) };
        let serve = DatasetBuilder::new(bm, bn).seed(41).consistent();
        let mut rngb = Mt19937::new(29);
        let jobs: Vec<BatchJob> = (0..n_jobs)
            .map(|_| {
                let x: Vec<f64> =
                    (0..serve.cols()).map(|_| rngb.next_f64() - 0.5).collect();
                BatchJob::new(gemv(&serve.a, &x).unwrap()).with_reference(x)
            })
            .collect();
        let opts = SolveOptions::default().with_fixed_iterations(b_iters);
        let seed = 7;

        // Looped baseline: build + solve each request independently.
        let sw = Stopwatch::start();
        let mut looped = Vec::with_capacity(n_jobs);
        for job in &jobs {
            let sys =
                LinearSystem::new(serve.a.clone(), job.rhs.clone(), job.x_ref.clone(), true);
            looped.push(RkSolver::new(seed).solve(&sys, &opts));
        }
        let t_loop = sw.seconds();

        // Batched: one dispatch over a warm pool. Warm with the full batch
        // so every lane's worker thread is spawned (and parked) before the
        // clock starts — a 1-job warm-up would collapse to the q == 1
        // no-dispatch shortcut and leave the pool cold.
        let batch = BatchSolver::new(&serve, RkSolver::new(seed));
        batch.solve_many(&jobs, &opts).unwrap();
        let sw = Stopwatch::start();
        let reports = batch.solve_many(&jobs, &opts).unwrap();
        let t_batch = sw.seconds();

        let bitwise = reports.iter().zip(&looped).all(|(r, l)| {
            r.result.x.iter().zip(&l.x).all(|(a, b)| a.to_bits() == b.to_bits())
        });
        t.row(vec![
            format!("batch serve looped ({n_jobs} rhs)"),
            serve.cols().to_string(),
            format!("{:.0}", t_loop / n_jobs as f64 * 1e9),
            "-".into(),
        ]);
        t.row(vec![
            format!("batch serve pooled ({n_jobs} rhs)"),
            serve.cols().to_string(),
            format!("{:.0}", t_batch / n_jobs as f64 * 1e9),
            "-".into(),
        ]);
        println!(
            "[batch-serve jobs={n_jobs}] batched/looped = {:.3} (must be <= ~1.0), \
             bitwise-equal = {bitwise} (must be true)",
            t_batch / t_loop
        );
        checks.push(("batch serve bitwise vs looped solves".into(), bitwise));
    }

    // Serve load test: the admission front end under a burst of small jobs
    // against resident systems — the wire server minus the sockets. N
    // fixed-budget jobs land at once on a handful of lanes; the rows are
    // end-to-end job throughput and the p50/p99 queue wait (submit →
    // lane pickup), i.e. the latency the bounded queue itself adds under
    // saturation. Timing never gates; the gate is conservation — every
    // job comes back `Done` having spent its exact fixed budget, and the
    // front-end counters balance. A lost, stuck, or double-counted job is
    // a serving-layer bug regardless of how fast the lanes drained.
    {
        let n_jobs = if smoke { 400usize } else { 4000 };
        let lanes = 4usize;
        let names = ["serve-a", "serve-b", "serve-c", "serve-d"];
        let registry = Arc::new(SystemRegistry::new(usize::MAX));
        for (i, name) in names.iter().enumerate() {
            registry
                .insert(*name, DatasetBuilder::new(240, 32).seed(80 + i as u32).consistent());
        }
        let front = SolveFrontEnd::new(
            Arc::clone(&registry),
            FrontEndConfig { lanes, max_pending: n_jobs },
        );
        let opts = SolveOptions::default().with_fixed_iterations(60);
        let sw = Stopwatch::start();
        let mut ids = Vec::with_capacity(n_jobs);
        for jx in 0..n_jobs {
            let req = SubmitRequest::new(names[jx % names.len()], Arc::new(RkSolver::new(jx as u32)))
                .with_opts(opts.clone());
            ids.push(front.submit(req).expect("queue is sized for the whole burst"));
        }
        let mut waits: Vec<f64> = Vec::with_capacity(n_jobs);
        let mut all_done = true;
        for id in &ids {
            match front.wait(*id, std::time::Duration::from_secs(600)) {
                Some(kaczmarz::serve::JobStatus::Done(report)) => {
                    all_done &= report.result.iterations == 60;
                    waits.push(report.queue_wait.as_secs_f64());
                }
                _ => all_done = false,
            }
        }
        let elapsed = sw.seconds();
        waits.sort_by(|a, b| a.total_cmp(b));
        let pct = |p: f64| -> f64 {
            if waits.is_empty() {
                return f64::NAN;
            }
            waits[((waits.len() as f64 * p) as usize).min(waits.len() - 1)]
        };
        let (p50, p99) = (pct(0.50), pct(0.99));
        t.row(vec![
            format!("serve burst end-to-end ({n_jobs} jobs, {lanes} lanes)"),
            n_jobs.to_string(),
            format!("{:.0}", elapsed / n_jobs as f64 * 1e9),
            "-".into(),
        ]);
        t.row(vec![
            format!("serve queue wait p50 ({n_jobs} jobs)"),
            n_jobs.to_string(),
            format!("{:.0}", p50 * 1e9),
            "-".into(),
        ]);
        t.row(vec![
            format!("serve queue wait p99 ({n_jobs} jobs)"),
            n_jobs.to_string(),
            format!("{:.0}", p99 * 1e9),
            "-".into(),
        ]);
        println!(
            "[serve-load jobs={n_jobs} lanes={lanes}] {:.0} jobs/s, queue wait p50 = {:.1} us, \
             p99 = {:.1} us (timing informational; conservation gates)",
            n_jobs as f64 / elapsed,
            p50 * 1e6,
            p99 * 1e6
        );
        let stats = front.stats();
        let conserved = all_done
            && waits.len() == n_jobs
            && stats.submitted == n_jobs as u64
            && stats.completed == n_jobs as u64
            && stats.rejected == 0
            && stats.cancelled == 0
            && stats.deadline_missed == 0
            && stats.failed_other == 0
            && stats.dropped_samples == 0;
        println!("[serve-load] conservation = {conserved} (must be true)");
        checks.push(("serve load conservation (all jobs done, counters balance)".into(), conserved));
    }

    // Solver-zoo equivalence gates: `Weights::Uniform` must not be a new
    // code path. Hand-roll the pre-zoo RKA / RKAB update loops (rows drawn
    // per worker, projections against x^(k), plain alpha/q and 1/q
    // averaging) and require today's solvers to reproduce them bit for bit
    // at a fixed budget — any drift is a silent numerics change in the
    // default paths every paper experiment runs on.
    {
        let zsys = DatasetBuilder::new(200, 24).seed(53).consistent();
        let (q, alpha, seed, iters) = (4usize, 1.0f64, 13u32, 150usize);

        let mut samplers: Vec<RowSampler> = (0..q)
            .map(|t| RowSampler::new(&zsys, SamplingScheme::FullMatrix, t, q, seed))
            .collect();
        let mut x = vec![0.0f64; zsys.cols()];
        let mut delta = vec![0.0f64; zsys.cols()];
        for _ in 0..iters {
            delta.fill(0.0);
            for sampler in samplers.iter_mut() {
                let i = sampler.sample();
                let scale = alpha * (zsys.b[i] - zsys.a.row_dot(i, &x))
                    / (q as f64 * zsys.row_norms_sq[i]);
                zsys.a.row_axpy(i, scale, &mut delta);
            }
            axpy(1.0, &delta, &mut x);
        }
        let r = RkaSolver::new(seed, q, alpha)
            .solve(&zsys, &SolveOptions::default().with_fixed_iterations(iters));
        let ok = r.x.iter().zip(&x).all(|(a, b)| a.to_bits() == b.to_bits());
        println!("[zoo] uniform-weight RKA bitwise vs pre-zoo loop = {ok} (must be true)");
        checks.push(("uniform-weight rka bitwise vs pre-zoo loop".into(), ok));

        let bs = 8usize;
        let mut samplers: Vec<RowSampler> = (0..q)
            .map(|t| RowSampler::new(&zsys, SamplingScheme::FullMatrix, t, q, seed))
            .collect();
        let mut x = vec![0.0f64; zsys.cols()];
        let mut v = vec![0.0f64; zsys.cols()];
        let mut acc = vec![0.0f64; zsys.cols()];
        let mut idx: Vec<usize> = Vec::with_capacity(bs);
        for _ in 0..iters {
            acc.fill(0.0);
            for sampler in samplers.iter_mut() {
                v.copy_from_slice(&x);
                block_sweep(&zsys, sampler, bs, alpha, &mut v, &mut idx);
                axpy(1.0, &v, &mut acc);
            }
            let inv = 1.0 / q as f64;
            for (xi, ai) in x.iter_mut().zip(&acc) {
                *xi = ai * inv;
            }
        }
        let r = RkabSolver::new(seed, q, bs, alpha)
            .solve(&zsys, &SolveOptions::default().with_fixed_iterations(iters));
        let ok = r.x.iter().zip(&x).all(|(a, b)| a.to_bits() == b.to_bits());
        println!("[zoo] uniform-weight RKAB bitwise vs pre-zoo loop = {ok} (must be true)");
        checks.push(("uniform-weight rkab bitwise vs pre-zoo loop".into(), ok));
    }

    // Stopping-test and telemetry-sink overhead on a serving-sized system.
    // The reference-error test is O(n) per iteration; the residual test is
    // a full O(m·n) gemv per *check*, so `check_every` is the amortization
    // lever; a progress sink piggybacks on those same checkpoints, so its
    // overhead must be noise ("zero new GEMVs" as a number, not a comment).
    // Every run executes exactly the same iterations (tolerance 0 is
    // unsatisfiable, the cap stops the run) with the stopping machinery
    // live; the fixed-budget row is the no-stopping floor.
    {
        let (m, n) = if smoke { (1024usize, 256usize) } else { (2048, 512) };
        let sys = DatasetBuilder::new(m, n).seed(47).consistent();
        let iters = 512usize;
        let run = |t: &mut Table, label: String, opts: SolveOptions| -> f64 {
            let r = RkSolver::new(5).solve(&sys, &opts);
            assert_eq!(r.iterations, iters, "{label}: must run the full cap");
            assert!(!r.converged, "{label}: tolerance 0 is unsatisfiable");
            let per_iter = r.seconds / iters as f64;
            t.row(vec![label, n.to_string(), format!("{:.0}", per_iter * 1e9), "-".into()]);
            per_iter
        };
        let t_off = run(
            &mut t,
            format!("stopping off, fixed budget ({m}x{n})"),
            SolveOptions::default().with_fixed_iterations(iters),
        );
        let t_ref = run(
            &mut t,
            format!("stop ref-error every iter ({m}x{n})"),
            SolveOptions::default().with_tolerance(0.0).with_max_iterations(iters),
        );
        for ce in [1usize, 32, 256] {
            let t_res = run(
                &mut t,
                format!("stop residual ce={ce} ({m}x{n})"),
                SolveOptions::default()
                    .with_residual_stopping(0.0, ce)
                    .with_max_iterations(iters),
            );
            println!(
                "[stop-check ce={ce}] residual/ref-error = {:.2}, residual/off = {:.2} \
                 (amortizes toward 1 as ce grows)",
                t_res / t_ref,
                t_res / t_off
            );
        }

        // Telemetry-sink overhead at the same checkpoints: no sink vs
        // callback vs bounded channel, residual stopping at ce ∈ {32, 256}.
        // Expected samples per run: iters/ce + 1 (k = 0 included).
        for ce in [32usize, 256] {
            let base = SolveOptions::default()
                .with_residual_stopping(0.0, ce)
                .with_max_iterations(iters);
            let t_none = run(&mut t, format!("sink none ce={ce} ({m}x{n})"), base.clone());

            let count = Arc::new(AtomicUsize::new(0));
            let counter = Arc::clone(&count);
            let cb = ProgressSink::callback(move |s| {
                std::hint::black_box(s.residual);
                counter.fetch_add(1, Ordering::Relaxed);
            });
            let t_cb = run(
                &mut t,
                format!("sink callback ce={ce} ({m}x{n})"),
                base.clone().with_progress(cb),
            );

            let (chan, rx) = ProgressSink::bounded(8);
            let t_ch = run(
                &mut t,
                format!("sink channel ce={ce} ({m}x{n})"),
                base.with_progress(chan),
            );

            println!(
                "[sink-overhead ce={ce}] callback/none = {:.3}, channel/none = {:.3} \
                 (both must be ~1.0: sinks reuse the checkpoint GEMV)",
                t_cb / t_none,
                t_ch / t_none
            );
            // The sample *count* is exact arithmetic, so it does gate: one
            // sample per checkpoint (k = 0, ce, ..., iters), and the
            // channel's queued + dropped tally must conserve every emission.
            let expected = iters / ce + 1;
            let cb_seen = count.load(Ordering::Relaxed);
            let ch_seen = rx.drain().len() + rx.dropped() as usize;
            checks.push((
                format!("sink callback sample count ce={ce}"),
                cb_seen == expected,
            ));
            checks.push((
                format!("sink channel sample count ce={ce} (queued + dropped)"),
                ch_seen == expected,
            ));
        }
    }

    println!("{}", t.to_markdown());
    println!("{}", t.to_text());

    // Machine-readable output for the perf-tracking CI lane: every table
    // row plus the equivalence flags, as one JSON document.
    let json_path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_micro.json".into());
    let mut j = String::from("{\n");
    j.push_str(&format!("\"bench\": {},\n", json_string("bench_micro_hotpath")));
    j.push_str(&format!("\"smoke\": {},\n", smoke));
    // Which flavor the dispatched (untagged) rows ran under; the
    // flavor-explicit rows carry their flavor in the operation name
    // ("dot [simd]" / "dot [scalar]").
    j.push_str(&format!("\"kernel\": {},\n", json_string(active_flavor().name())));
    j.push_str(&format!("\"rows\": {},\n", t.to_json()));
    j.push_str("\"checks\": [");
    for (i, (name, pass)) in checks.iter().enumerate() {
        if i > 0 {
            j.push(',');
        }
        j.push_str(&format!("\n  {{\"name\": {}, \"pass\": {}}}", json_string(name), pass));
    }
    j.push_str("\n]\n}\n");
    std::fs::write(&json_path, &j).expect("write bench JSON");
    eprintln!("wrote {json_path}");

    let failed: Vec<&str> =
        checks.iter().filter(|(_, ok)| !ok).map(|(name, _)| name.as_str()).collect();
    if !failed.is_empty() {
        eprintln!("EQUIVALENCE CHECK FAILURES: {failed:?}");
        std::process::exit(1);
    }
}

/// Blocked `y = A x` with the inner dot pinned to one kernel flavor
/// (`simd: true` requires a host with AVX2+FMA): the same panel-major
/// walk as `gemv_block_into`, used for the flavor-explicit gemv rows and
/// their tolerance gate.
fn gemv_flavored(a: &Matrix, x: &[f64], y: &mut [f64], simd: bool) {
    let panel = gemv_panel();
    let n = a.cols();
    y.iter_mut().for_each(|v| *v = 0.0);
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + panel).min(n);
        let xp = &x[lo..hi];
        for (k, yi) in y.iter_mut().enumerate() {
            let row = &a.row(k)[lo..hi];
            *yi += if simd {
                dot_avx2(row, xp).expect("host has AVX2+FMA")
            } else {
                dot_scalar(row, xp)
            };
        }
        lo = hi;
    }
}
