//! Micro-benchmarks of the L3 hot paths feeding the cost model and the
//! §Perf pass: dot/axpy (the per-iteration projection), row sampling
//! (alias vs CDF), gather-add, atomic CAS-add, memcpy, and barrier
//! crossings. Prints ns/op and effective GB/s.

use kaczmarz::data::DatasetBuilder;
use kaczmarz::linalg::vector::{axpy, dot};
use kaczmarz::linalg::{gemv_block_into, Matrix};
use kaczmarz::metrics::Stopwatch;
use kaczmarz::parallel::shared::{AtomicF64Vec, SpinBarrier};
use kaczmarz::report::Table;
use kaczmarz::rng::{AliasTable, DiscreteDistribution, Mt19937};
use kaczmarz::solvers::rkab::block_sweep;
use kaczmarz::solvers::{RowSampler, SamplingScheme, SolveOptions, Solver};
use std::sync::Arc;

fn bench<F: FnMut()>(mut f: F, iters: usize) -> f64 {
    // Warmup.
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let sw = Stopwatch::start();
    for _ in 0..iters {
        f();
    }
    sw.seconds() / iters as f64
}

fn main() {
    let mut t = Table::new(
        "L3 hot-path micro-benchmarks",
        &["operation", "n", "ns/op", "GB/s (eff)"],
    );

    let mut rng = Mt19937::new(1);
    for n in [50usize, 200, 1000, 4000, 10000] {
        let a: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mut y = vec![0.0f64; n];
        let iters = (50_000_000 / n).max(100);

        let td = bench(
            || {
                std::hint::black_box(dot(std::hint::black_box(&a), std::hint::black_box(&b)));
            },
            iters,
        );
        t.row(vec![
            "dot".into(),
            n.to_string(),
            format!("{:.1}", td * 1e9),
            format!("{:.1}", 16.0 * n as f64 / td / 1e9),
        ]);

        let ta = bench(
            || {
                axpy(1.0001, std::hint::black_box(&a), std::hint::black_box(&mut y));
            },
            iters,
        );
        t.row(vec![
            "axpy".into(),
            n.to_string(),
            format!("{:.1}", ta * 1e9),
            format!("{:.1}", 24.0 * n as f64 / ta / 1e9),
        ]);
    }

    // Full projection on a real system (what CostModel::t_proj measures).
    let sys = DatasetBuilder::new(4000, 1000).seed(3).consistent();
    let r = kaczmarz::solvers::rk::RkSolver::new(1)
        .solve(&sys, &SolveOptions::default().with_fixed_iterations(20_000));
    t.row(vec![
        "RK projection (4000x1000 system)".into(),
        "1000".into(),
        format!("{:.1}", r.seconds / r.iterations as f64 * 1e9),
        format!("{:.1}", 16_000.0 / (r.seconds / r.iterations as f64) / 1e9),
    ]);

    // RKAB in-block sweep: the real fused kernel (solvers::rkab::block_sweep,
    // the exact function on the solver hot path) vs the seed's scalar
    // dot-then-axpy row loop, per block size. Both shapes draw bs fresh rows
    // per sweep from identically-seeded samplers, so sampling cost cancels;
    // the fused kernel touches v once per projection instead of twice, so it
    // must be no slower at every bs and clearly faster once the block stops
    // fitting in L1/L2.
    {
        let n = sys.cols();
        for bs in [1usize, 8, 32, 128, 512] {
            let sweeps = (2_000_000 / (bs * n).max(1)).max(10);
            let alpha = 1.0;

            // Row-loop baseline (the seed's formulation).
            let mut sampler = RowSampler::new(&sys, SamplingScheme::FullMatrix, 0, 1, 17);
            let mut idx: Vec<usize> = Vec::with_capacity(bs);
            let mut v = vec![0.0f64; n];
            let t_base = bench(
                || {
                    idx.clear();
                    for _ in 0..bs {
                        idx.push(sampler.sample());
                    }
                    for &i in &idx {
                        let row = sys.a.row(i);
                        let scale = alpha * (sys.b[i] - dot(row, &v)) / sys.row_norms_sq[i];
                        axpy(scale, row, &mut v);
                    }
                    std::hint::black_box(&mut v);
                },
                sweeps,
            );

            // The solver's fused kernel, measured directly.
            let mut sampler = RowSampler::new(&sys, SamplingScheme::FullMatrix, 0, 1, 17);
            let mut idx: Vec<usize> = Vec::with_capacity(bs);
            let mut v = vec![0.0f64; n];
            let t_fused = bench(
                || {
                    block_sweep(&sys, &mut sampler, bs, alpha, &mut v, &mut idx);
                    std::hint::black_box(&mut v);
                },
                sweeps,
            );

            let per_row_base = t_base / bs as f64;
            let per_row_fused = t_fused / bs as f64;
            t.row(vec![
                format!("rkab sweep row-loop (bs={bs})"),
                n.to_string(),
                format!("{:.1}", per_row_base * 1e9),
                format!("{:.1}", 32.0 * n as f64 / per_row_base / 1e9),
            ]);
            t.row(vec![
                format!("rkab sweep fused (bs={bs})"),
                n.to_string(),
                format!("{:.1}", per_row_fused * 1e9),
                format!("{:.1}", 32.0 * n as f64 / per_row_fused / 1e9),
            ]);
            println!(
                "[rkab-sweep bs={bs}] fused/base = {:.3} (must be <= ~1.0; < 1 means faster)",
                per_row_fused / per_row_base
            );
        }
    }

    // Cache-blocked gemv on a wide matrix (x no longer fits L1): panel
    // kernel vs the straight row-dot loop.
    {
        let (m, n) = (512usize, 8192usize);
        let mut rngw = Mt19937::new(23);
        let data: Vec<f64> = (0..m * n).map(|_| rngw.next_f64() - 0.5).collect();
        let a = Matrix::from_vec(m, n, data).unwrap();
        let x: Vec<f64> = (0..n).map(|_| rngw.next_f64() - 0.5).collect();
        let mut y = vec![0.0f64; m];
        let iters = 50;
        let t_naive = bench(
            || {
                for (yi, row) in y.iter_mut().zip(a.rows_iter()) {
                    *yi = dot(row, &x);
                }
                std::hint::black_box(&mut y);
            },
            iters,
        );
        let t_blocked = bench(
            || {
                gemv_block_into(&a, &x, &mut y);
                std::hint::black_box(&mut y);
            },
            iters,
        );
        let bytes = (m * n + n + m) as f64 * 8.0;
        t.row(vec![
            format!("gemv row-dot ({m}x{n})"),
            n.to_string(),
            format!("{:.0}", t_naive * 1e9),
            format!("{:.1}", bytes / t_naive / 1e9),
        ]);
        t.row(vec![
            format!("gemv cache-blocked ({m}x{n})"),
            n.to_string(),
            format!("{:.0}", t_blocked * 1e9),
            format!("{:.1}", bytes / t_blocked / 1e9),
        ]);
    }

    // Row sampling: alias vs CDF binary search.
    let weights = sys.sampling_weights();
    let alias = AliasTable::new(weights);
    let cdf = DiscreteDistribution::new(weights);
    let mut rng2 = Mt19937::new(9);
    let ts = bench(|| {
        std::hint::black_box(alias.sample(&mut rng2));
    }, 2_000_000);
    t.row(vec!["sample (alias)".into(), "m=4000".into(), format!("{:.1}", ts * 1e9), "-".into()]);
    let ts = bench(|| {
        std::hint::black_box(cdf.sample(&mut rng2));
    }, 2_000_000);
    t.row(vec!["sample (cdf bsearch)".into(), "m=4000".into(), format!("{:.1}", ts * 1e9), "-".into()]);

    // Gather primitives at n = 1000.
    let n = 1000;
    let src: Vec<f64> = (0..n).map(|i| i as f64).collect();
    let mut dst = vec![0.0f64; n];
    let tg = bench(
        || {
            for i in 0..n {
                dst[i] += src[i];
            }
            std::hint::black_box(&mut dst);
        },
        50_000,
    );
    t.row(vec![
        "gather add (critical body)".into(),
        n.to_string(),
        format!("{:.1}", tg * 1e9),
        format!("{:.1}", 24.0 * n as f64 / tg / 1e9),
    ]);
    let av = AtomicF64Vec::zeros(n);
    let tat = bench(
        || {
            for i in 0..n {
                av.add(i, 1.0);
            }
        },
        20_000,
    );
    t.row(vec![
        "atomic CAS add".into(),
        n.to_string(),
        format!("{:.1}", tat * 1e9),
        format!("{:.1}", 24.0 * n as f64 / tat / 1e9),
    ]);
    let tc = bench(
        || {
            dst.copy_from_slice(&src);
            std::hint::black_box(&mut dst);
        },
        100_000,
    );
    t.row(vec![
        "memcpy".into(),
        n.to_string(),
        format!("{:.1}", tc * 1e9),
        format!("{:.1}", 16.0 * n as f64 / tc / 1e9),
    ]);

    // Barrier crossing (measured; note: 1-core container oversubscribes).
    for q in [2usize, 4] {
        let barrier = Arc::new(SpinBarrier::new(q));
        let rounds = 20_000usize;
        let sw = Stopwatch::start();
        std::thread::scope(|scope| {
            for _ in 0..q {
                let b = Arc::clone(&barrier);
                scope.spawn(move || {
                    for _ in 0..rounds {
                        b.wait();
                    }
                });
            }
        });
        t.row(vec![
            format!("spin barrier crossing (q={q})"),
            "-".into(),
            format!("{:.1}", sw.seconds() / rounds as f64 * 1e9),
            "-".into(),
        ]);
    }

    println!("{}", t.to_markdown());
    println!("{}", t.to_text());
}
