//! Bench: the ablation studies (averaging strategies, sampling
//! distribution, auto block-size tuner). See coordinator::experiments::ablations.

use kaczmarz::coordinator::{find, Scale};
use kaczmarz::metrics::Stopwatch;

fn main() {
    let factor: f64 = std::env::var("KACZMARZ_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let seeds: u32 = std::env::var("KACZMARZ_BENCH_SEEDS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    let scale = Scale { factor, seeds };
    for id in ["ablation-averaging", "ablation-sampling", "ablation-autotune"] {
        let exp = find(id).expect("registered experiment");
        let sw = Stopwatch::start();
        let report = exp.run(scale);
        println!("{}", report.to_markdown());
        let _ = report.write(std::path::Path::new("results"), id);
        eprintln!("[bench] {id} finished in {:.1} s", sw.seconds());
    }
}
