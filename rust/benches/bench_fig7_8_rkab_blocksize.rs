//! Bench: regenerates the paper's fig7 fig8 via the coordinator driver(s).
//! Scale with KACZMARZ_BENCH_SCALE (default 1.0) / KACZMARZ_BENCH_SEEDS (3).

use kaczmarz::coordinator::{find, Scale};
use kaczmarz::metrics::Stopwatch;

fn main() {
    let factor: f64 = std::env::var("KACZMARZ_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let seeds: u32 = std::env::var("KACZMARZ_BENCH_SEEDS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    let scale = Scale { factor, seeds };
    for id in ["fig7", "fig8", ] {
        let exp = find(id).expect("registered experiment");
        let sw = Stopwatch::start();
        let report = exp.run(scale);
        println!("{}", report.to_markdown());
        let out = std::path::PathBuf::from("results");
        let _ = report.write(&out, id);
        eprintln!("[bench] {id} finished in {:.1} s (scale {factor}, seeds {seeds})", sw.seconds());
    }
    // All RKAB solves above ran as dispatches on the persistent worker pool:
    // the resident count is the high-water q - 1, not (solves x q) spawns.
    eprintln!(
        "[bench] persistent pool residency: {} workers after all runs",
        kaczmarz::parallel::pool::global().worker_count()
    );
}
