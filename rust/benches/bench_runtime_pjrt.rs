//! Bench: PJRT execution overhead of the AOT Pallas kernels vs the native
//! Rust implementation of the same update — quantifies the L3<->RT boundary
//! cost (literal marshalling + PJRT dispatch + interpret-mode kernel).
//!
//! Requires `make artifacts`.

use kaczmarz::data::DatasetBuilder;
use kaczmarz::metrics::Stopwatch;
use kaczmarz::report::Table;
use kaczmarz::runtime::{ArtifactKind, PjrtEngine};
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SolveOptions, Solver};
use std::path::PathBuf;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP bench_runtime_pjrt: run `make artifacts` first");
        return;
    }
    let mut engine = PjrtEngine::new(&dir).expect("engine");
    println!("platform: {}", engine.platform());

    let mut t = Table::new(
        "PJRT rkab_round step vs native (per call)",
        &["q", "bs", "n", "pjrt/call", "native/call", "overhead"],
    );

    for (q, bs, n) in [(2usize, 64usize, 256usize), (4, 64, 256), (4, 256, 256)] {
        let entry = match engine.find(ArtifactKind::RkabRound, q, bs, n) {
            Ok(e) => e,
            Err(_) => continue,
        };
        let sys = DatasetBuilder::new(2000, n).seed(1).consistent();

        // Build inputs once.
        let mut a_blocks = vec![0.0; q * bs * n];
        let mut b_blocks = vec![0.0; q * bs];
        let mut inv_norms = vec![0.0; q * bs];
        for t_ in 0..q {
            for j in 0..bs {
                let i = (t_ * bs + j) % sys.rows();
                a_blocks[(t_ * bs + j) * n..(t_ * bs + j + 1) * n]
                    .copy_from_slice(sys.a.row(i));
                b_blocks[t_ * bs + j] = sys.b[i];
                inv_norms[t_ * bs + j] = 1.0 / sys.row_norms_sq[i];
            }
        }
        let x = vec![0.0f64; n];
        let mk_inputs = || {
            [
                PjrtEngine::literal(&a_blocks, &[q as i64, bs as i64, n as i64]).unwrap(),
                PjrtEngine::literal(&b_blocks, &[q as i64, bs as i64]).unwrap(),
                PjrtEngine::literal(&inv_norms, &[q as i64, bs as i64]).unwrap(),
                PjrtEngine::literal(&x, &[n as i64]).unwrap(),
                PjrtEngine::literal(&[1.0], &[1]).unwrap(),
            ]
        };
        engine.prepare(&entry.name).unwrap();
        // Warmup + measure.
        for _ in 0..3 {
            engine.run(&entry.name, &mk_inputs()).unwrap();
        }
        let calls = 20;
        let sw = Stopwatch::start();
        for _ in 0..calls {
            engine.run(&entry.name, &mk_inputs()).unwrap();
        }
        let pjrt_per_call = sw.seconds() / calls as f64;

        // Native equivalent: one RKAB iteration (q workers x bs rows).
        let native = RkabSolver::new(1, q, bs, 1.0)
            .solve(&sys, &SolveOptions::default().with_fixed_iterations(200));
        let native_per_call = native.seconds / native.iterations as f64;

        t.row(vec![
            q.to_string(),
            bs.to_string(),
            n.to_string(),
            format!("{:.2} ms", pjrt_per_call * 1e3),
            format!("{:.2} ms", native_per_call * 1e3),
            format!("{:.1}x", pjrt_per_call / native_per_call),
        ]);
    }
    println!("{}", t.to_markdown());
    println!("{}", t.to_text());
    println!(
        "note: the PJRT path runs the Pallas kernel under interpret=True on CPU \
         (DESIGN.md §Hardware-Adaptation) — the overhead column quantifies \
         marshalling + dispatch + interpret cost, not TPU performance."
    );
}
