//! Property tests for the reference-free observability contract:
//!
//! 1. a system constructed **without** a reference solution can run every
//!    solver layer with `history_step != 0` and residual stopping,
//!    producing a non-empty residual history and **zero** reference
//!    evaluations — pinned by the panicking-probe pattern of
//!    `tests/stopping_properties.rs` (`error_sq` panics on a reference-free
//!    system, so a clean pass proves the count is exactly zero);
//! 2. on referenced systems the history is dual-channel (both channels
//!    populated, sample-aligned), and the residual channel certifies the
//!    tolerance at the stopping sample;
//! 3. residual-stopped calibration agrees with reference-stopped
//!    calibration on a consistent system within seed noise, and an
//!    all-divergent configuration is a typed error, never a zero budget.

use kaczmarz::batch::SolveQueue;
use kaczmarz::coordinator::{calibrate_iterations, calibrate_iterations_residual};
use kaczmarz::data::{DatasetBuilder, LinearSystem};
use kaczmarz::distributed::{DistRka, DistRkab, Placement, SimCluster};
use kaczmarz::error::Error;
use kaczmarz::metrics::{Channel, History};
use kaczmarz::parallel::{AsyRkSolver, BlockSequentialRk, ParallelRka, ParallelRkab};
use kaczmarz::solvers::ck::CkSolver;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rka::RkaSolver;
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SolveOptions, Solver};

/// The same system, stripped of every reference solution: any call to
/// `error_sq` panics, so a run that completes proves zero consultations.
fn strip_reference(sys: &LinearSystem) -> LinearSystem {
    LinearSystem::new(sys.a.clone(), sys.b.clone(), None, true)
}

/// Every `Solver`-trait implementation in the crate, smallest viable
/// parallelism degrees (the pool tolerates oversubscription).
fn all_trait_solvers(seed: u32) -> Vec<(&'static str, Box<dyn Solver>)> {
    vec![
        ("CK", Box::new(CkSolver::new())),
        ("RK", Box::new(RkSolver::new(seed))),
        ("RKA", Box::new(RkaSolver::new(seed, 4, 1.0))),
        ("RKAB", Box::new(RkabSolver::new(seed, 4, 8, 1.0))),
        ("RKA-parallel", Box::new(ParallelRka::new(seed, 3, 1.0))),
        ("RKAB-parallel", Box::new(ParallelRkab::new(seed, 3, 8, 1.0))),
        ("RK-block-seq", Box::new(BlockSequentialRk::new(seed, 2))),
        ("AsyRK", Box::new(AsyRkSolver::new(seed, 2))),
    ]
}

fn assert_residual_only_history(name: &str, h: &History) {
    assert!(!h.is_empty(), "{name}: history requested but empty");
    assert_eq!(h.errors.len(), 0, "{name}: reference channel recorded without a reference");
    assert!(!h.has_reference_channel(), "{name}");
    assert_eq!(h.residuals.len(), h.iterations.len(), "{name}: channel misaligned");
    assert!(h.residuals.iter().all(|r| r.is_finite()), "{name}: non-finite residual sample");
    // min_error transparently reads the residual channel.
    assert_eq!(h.primary_channel(), Channel::Residual, "{name}");
    assert_eq!(h.min_error(), h.min_in(Channel::Residual), "{name}");
}

// ---------------------------------------------------------------------------
// Property 1: reference-free convergence curves, zero reference evaluations.
// ---------------------------------------------------------------------------

#[test]
fn reference_free_histories_record_residuals_for_every_trait_solver() {
    // The probe: no reference anywhere; `error_sq` panics if consulted.
    let sys = strip_reference(&DatasetBuilder::new(200, 10).seed(1).consistent());
    for (name, s) in all_trait_solvers(3) {
        // Residual stopping + history — the shape PR 3 could not express
        // (history used to force `consults_reference()` = true). AsyRK's
        // racy dense updates converge more slowly (the paper's point about
        // it), so it gets the same looser — still deep — target as in
        // tests/stopping_properties.rs.
        let opts = if name == "AsyRK" {
            SolveOptions::default().with_residual_stopping(1e-3, 1).with_history_step(8)
        } else {
            SolveOptions::default().with_residual_stopping(1e-6, 8).with_history_step(8)
        };
        let r = s.solve(&sys, &opts);
        assert!(r.converged, "{name}: residual run did not converge");
        assert_residual_only_history(name, &r.history);
        // The curve moved: for the synchronous solvers the first sample is
        // ‖b‖ at x^(0) = 0 and the stopping sample is inside the tolerance.
        // (AsyRK's monitor takes its first sample only after the racy
        // workers have already started, so only the weaker non-increase
        // holds there.)
        let first = r.history.residuals.first().unwrap();
        let last = r.history.residuals.last().unwrap();
        if name == "AsyRK" {
            assert!(last <= first, "{name}: residual curve increased");
        } else {
            assert!(last < first, "{name}: residual curve did not decrease");
        }
    }
}

#[test]
fn reference_free_histories_under_fixed_budgets_too() {
    let sys = strip_reference(&DatasetBuilder::new(150, 8).seed(5).consistent());
    let opts = SolveOptions::default().with_fixed_iterations(40).with_history_step(10);
    for (name, s) in all_trait_solvers(3) {
        let r = s.solve(&sys, &opts);
        assert!(!r.converged, "{name}: fixed-budget run claimed convergence");
        assert_residual_only_history(name, &r.history);
    }
}

#[test]
fn reference_free_histories_for_distributed_solvers() {
    let sys = strip_reference(&DatasetBuilder::new(240, 10).seed(2).consistent());
    let cluster = SimCluster::new(3, Placement::two_per_node());
    let opts = SolveOptions::default()
        .with_residual_stopping(1e-6, 8)
        .with_history_step(8)
        .with_max_iterations(2_000_000);

    let r = DistRka::new(3, 1.0).solve(&sys, &opts, &cluster);
    assert!(r.converged, "DistRka residual run did not converge");
    assert_residual_only_history("DistRka", &r.history);

    let r = DistRkab::new(3, 8, 1.0).solve(&sys, &opts, &cluster);
    assert!(r.converged, "DistRkab residual run did not converge");
    assert_residual_only_history("DistRkab", &r.history);
}

#[test]
fn reference_free_histories_for_the_pjrt_solver() {
    // Requires `make artifacts` (skipped with a clear message otherwise),
    // same guard as tests/runtime_integration.rs.
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return;
    }
    // (q, bs, n) = (4, 256, 256) is in the AOT catalogue (see
    // python/compile/aot.py RKAB_ROUND_SHAPES) and converges quickly on
    // this workload (same shape as pjrt_rkab_converges_to_solution).
    let (q, bs, n) = (4, 256, 256);
    let sys = strip_reference(&DatasetBuilder::new(4000, n).seed(7).consistent());
    let solver = kaczmarz::runtime::PjrtRkabSolver::new(&dir, 3, q, bs, n, 1.0)
        .expect("rkab_round artifact for q=4, bs=256, n=256");
    let opts = SolveOptions::default()
        .with_residual_stopping(1e-1, 4)
        .with_history_step(4)
        .with_max_iterations(2000);
    let r = solver.solve(&sys, &opts).expect("PJRT solve");
    assert!(r.converged, "PJRT residual run did not converge");
    assert_residual_only_history("RKAB-pjrt", &r.history);
}

#[test]
fn reference_free_queue_jobs_can_request_convergence_curves() {
    // The serving story end to end: a reference-free job asks for both a
    // residual-stopped solve AND its convergence curve — previously
    // rejected up front by the queue's consults_reference validation.
    let system = strip_reference(&DatasetBuilder::new(200, 8).seed(7).consistent());
    let mut queue = SolveQueue::new();
    queue.push(
        system,
        SolveOptions::default().with_residual_stopping(1e-6, 16).with_history_step(16),
    );
    let reports = queue.run(&RkSolver::new(3)).unwrap();
    assert!(reports[0].result.converged);
    let curve = reports[0].residual_history();
    assert!(!curve.is_empty(), "queue job produced no residual history");
    assert!(curve.last().unwrap() < curve.first().unwrap());
    assert!(!reports[0].result.history.has_reference_channel());
}

// ---------------------------------------------------------------------------
// Property 2: dual-channel histories on referenced systems.
// ---------------------------------------------------------------------------

#[test]
fn referenced_histories_carry_both_channels_aligned() {
    let sys = DatasetBuilder::new(200, 10).seed(9).consistent();
    let opts = SolveOptions::default().with_fixed_iterations(100).with_history_step(20);
    let r = RkSolver::new(4).solve(&sys, &opts);
    assert_eq!(r.history.iterations, vec![0, 20, 40, 60, 80, 100]);
    assert_eq!(r.history.errors.len(), 6);
    assert_eq!(r.history.residuals.len(), 6);
    assert!(r.history.has_reference_channel());
    assert_eq!(r.history.primary_channel(), Channel::ReferenceError);
    // Both channels shrink over a consistent-system solve.
    assert!(r.history.errors.last().unwrap() < r.history.errors.first().unwrap());
    assert!(r.history.residuals.last().unwrap() < r.history.residuals.first().unwrap());
}

#[test]
fn residual_history_certifies_tolerance_at_the_stopping_sample() {
    // With history_step == check_every the stopping iteration is also a
    // history sample, so the recorded curve ends inside the tolerance.
    let sys = DatasetBuilder::new(200, 10).seed(11).consistent();
    let tol = 1e-6;
    let opts = SolveOptions::default()
        .with_residual_stopping(tol, 8)
        .with_history_step(8)
        .with_max_iterations(2_000_000);
    let r = RkSolver::new(2).solve(&sys, &opts);
    assert!(r.converged);
    let last = *r.history.residuals.last().unwrap();
    assert!(last * last < tol, "stopping sample residual² {:.3e} >= tol", last * last);
    // The recorded sample describes the returned iterate (same x, the
    // record and the metric share the stopping checkpoint).
    let direct = sys.residual_norm(&r.x);
    assert!(
        (last - direct).abs() <= 1e-9 * direct.max(1.0),
        "recorded {last:.6e} vs recomputed {direct:.6e}"
    );
}

// ---------------------------------------------------------------------------
// Property 3: residual-stopped calibration.
// ---------------------------------------------------------------------------

#[test]
fn residual_calibration_agrees_with_reference_calibration_within_seed_noise() {
    let sys = DatasetBuilder::new(200, 10).seed(13).consistent();
    let opts = SolveOptions::default(); // reference-stopped, eps = 1e-8
    let by_ref = calibrate_iterations(RkSolver::new, &sys, &opts, 4).unwrap();

    // Self-calibrate the comparable residual tolerance: the residual² the
    // seed-0 reference-stopped run ends at. Both calibrations then chase
    // the same contraction depth along identical per-seed iterate paths,
    // so the means must agree closely (offline simulation: ratio ~1.007;
    // the 1.5x band is seed-noise slack, not an expected effect).
    let probe = RkSolver::new(0).solve(&sys, &opts);
    let r = sys.residual_norm(&probe.x);
    let tol = r * r;
    assert!(tol > 0.0);
    let by_res = calibrate_iterations_residual(RkSolver::new, &sys, &opts, tol, 1, 4).unwrap();

    assert_eq!(by_ref.converged_fraction, 1.0);
    assert_eq!(by_res.converged_fraction, 1.0);
    let ratio = by_res.mean_iterations / by_ref.mean_iterations;
    assert!(
        (0.67..1.5).contains(&ratio),
        "residual calibration drifted: {} vs {} (ratio {ratio:.3})",
        by_res.mean_iterations,
        by_ref.mean_iterations
    );
}

#[test]
fn residual_calibration_runs_on_reference_free_systems() {
    // The ROADMAP item: the §3.1 calibrate-then-time protocol on a system
    // with no known solution. The reference-stopped mode cannot run here at
    // all (error_sq panics); the residual mode calibrates a usable budget.
    let sys = strip_reference(&DatasetBuilder::new(200, 10).seed(15).consistent());
    let cal = calibrate_iterations_residual(
        RkSolver::new,
        &sys,
        &SolveOptions::default(),
        1e-6,
        8,
        3,
    )
    .expect("reference-free calibration");
    let budget = cal.iterations();
    assert!(budget > 0);
    // ...and the budget actually drives the timing protocol on the same
    // reference-free system.
    let timed = RkSolver::new(0)
        .solve(&sys, &SolveOptions::default().with_fixed_iterations(budget));
    assert_eq!(timed.iterations, budget);
}

#[test]
fn all_divergent_calibration_is_a_typed_error() {
    let sys = DatasetBuilder::new(200, 10).seed(2).consistent();
    let opts = SolveOptions {
        divergence_factor: 1e4,
        max_iterations: 50_000,
        ..Default::default()
    };
    // alpha = 3.9 with large blocks diverges for every seed (Fig. 10b).
    let err = calibrate_iterations(|s| RkabSolver::new(s, 4, 100, 3.9), &sys, &opts, 3)
        .err()
        .expect("all-divergent calibration must be an error, not a zero budget");
    assert!(matches!(err, Error::CalibrationFailed { diverged: 3, .. }), "{err:?}");
}
