//! Serving-front-end property tests: registry residency, admission
//! back-pressure, deadlines, cancellation, and telemetry conservation.
//!
//! These pin the *contracts* of `kaczmarz::serve` end to end through the
//! public API (the wire layer has its own socket tests in the module):
//!
//! 1. the registry evicts in LRU order and hands out `Arc`-shared systems;
//! 2. a full admission queue refuses with typed `Overloaded` — and the
//!    refusal carries the real queue numbers;
//! 3. a lapsed deadline fails typed without stalling sibling jobs;
//! 4. cancellation stops a running solve at a checkpoint (bounded time),
//!    not at its iteration cap;
//! 5. dropped + delivered telemetry samples conserve across sink
//!    capacities, and queue wait is measured (nonzero for a job that
//!    provably waited).

use kaczmarz::data::DatasetBuilder;
use kaczmarz::error::Error;
use kaczmarz::metrics::ProgressSink;
use kaczmarz::serve::{
    approx_system_bytes, FrontEndConfig, JobStatus, SolveFrontEnd, SubmitRequest, SystemRegistry,
};
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::{SolveOptions, Solver};
use std::sync::Arc;
use std::time::{Duration, Instant};

const WAIT: Duration = Duration::from_secs(120);

fn rk(seed: u32) -> Arc<dyn Solver + Send + Sync> {
    Arc::new(RkSolver::new(seed))
}

fn registry_with_demo() -> Arc<SystemRegistry> {
    let reg = Arc::new(SystemRegistry::new(usize::MAX));
    reg.insert("demo", DatasetBuilder::new(240, 16).seed(1).consistent());
    reg
}

/// Options that can never satisfy their tolerance: the job runs until
/// halted (cancel/deadline) or its huge iteration cap.
fn endless_opts() -> SolveOptions {
    SolveOptions::default()
        .with_residual_stopping(0.0, 8)
        .with_max_iterations(usize::MAX / 2)
}

/// Spin until job `id` is observed `Running` (it has provably left the
/// queue and occupies a lane).
fn wait_until_running(front: &SolveFrontEnd, id: u64) {
    let deadline = Instant::now() + WAIT;
    loop {
        match front.status(id).expect("known job") {
            JobStatus::Running => return,
            s if s.is_terminal() => panic!("job {id} finished before it could block: {s:?}"),
            _ => {
                assert!(Instant::now() < deadline, "job {id} never started running");
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

// ---------------------------------------------------------------- registry

#[test]
fn registry_evicts_lru_and_shares_arcs() {
    let sys = |seed: u32| DatasetBuilder::new(100, 10).seed(seed).consistent();
    let one = approx_system_bytes(&sys(0));
    // Room for two resident systems, not three.
    let reg = SystemRegistry::new(2 * one + one / 2);
    assert!(reg.insert("a", sys(1)).is_empty());
    assert!(reg.insert("b", sys(2)).is_empty());
    // Touch "a" so "b" becomes least-recently-used.
    assert!(reg.get("a").is_some());
    let evicted = reg.insert("c", sys(3));
    assert_eq!(evicted, vec!["b".to_string()], "LRU order must evict 'b'");
    assert!(reg.contains("a") && reg.contains("c") && !reg.contains("b"));

    // Residency is Arc-shared: two gets hand out the same allocation, and a
    // handle held across an eviction stays valid.
    let h1 = reg.get("a").unwrap();
    let h2 = reg.get("a").unwrap();
    assert!(Arc::ptr_eq(&h1, &h2), "gets must share one resident system");
    reg.remove("a");
    assert!(!reg.contains("a"));
    assert_eq!(h1.rows(), 100, "held handle must survive eviction");
}

// --------------------------------------------------------------- admission

#[test]
fn full_queue_refuses_with_typed_overloaded() {
    let front = SolveFrontEnd::new(
        registry_with_demo(),
        FrontEndConfig { lanes: 1, max_pending: 1 },
    );
    let blocker = front
        .submit(SubmitRequest::new("demo", rk(1)).with_opts(endless_opts()))
        .unwrap();
    wait_until_running(&front, blocker); // queue is now provably empty
    let queued = front
        .submit(SubmitRequest::new("demo", rk(2)).with_opts(endless_opts()))
        .unwrap();
    // Queue full: the third submission must be refused, with real numbers.
    let err = front
        .submit(SubmitRequest::new("demo", rk(3)).with_opts(endless_opts()))
        .unwrap_err();
    match err {
        Error::Overloaded { pending, capacity } => {
            assert_eq!(pending, 1);
            assert_eq!(capacity, 1);
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    // The refusal is bookkept, and never entered the queue.
    let stats = front.stats();
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.submitted, 2);
    // Cancelling the blocker unblocks the lane; the queued job then gets
    // its turn (and is cancelled too — this test only probes admission).
    assert!(front.cancel(blocker));
    assert!(front.cancel(queued));
    for id in [blocker, queued] {
        let status = front.wait(id, WAIT).unwrap();
        assert!(matches!(&status, JobStatus::Failed(e) if matches!(**e, Error::Cancelled)));
    }
}

// --------------------------------------------------------------- deadlines

#[test]
fn lapsed_deadline_fails_typed_without_stalling_siblings() {
    let front = SolveFrontEnd::new(
        registry_with_demo(),
        FrontEndConfig { lanes: 2, max_pending: 16 },
    );
    // An unsatisfiable job with a 1 ms budget: must fail DeadlineExceeded
    // at a checkpoint, long before its iteration cap.
    let doomed = front
        .submit(
            SubmitRequest::new("demo", rk(1))
                .with_opts(endless_opts())
                .with_deadline(Duration::from_millis(1)),
        )
        .unwrap();
    // Sibling jobs submitted around it must complete normally.
    let siblings: Vec<u64> = (0..4)
        .map(|s| {
            front
                .submit(SubmitRequest::new("demo", rk(10 + s)).with_opts(
                    SolveOptions::default().with_residual_stopping(1e-8, 16),
                ))
                .unwrap()
        })
        .collect();
    match front.wait(doomed, WAIT).unwrap() {
        JobStatus::Failed(e) => match *e {
            Error::DeadlineExceeded { budget_ms } => assert_eq!(budget_ms, 1),
            ref other => panic!("expected DeadlineExceeded, got {other:?}"),
        },
        other => panic!("expected Failed, got {other:?}"),
    }
    for id in siblings {
        let status = front.wait(id, WAIT).unwrap();
        assert!(
            matches!(&status, JobStatus::Done(r) if r.result.converged),
            "sibling {id} stalled by the doomed job: {status:?}"
        );
    }
    let stats = front.stats();
    assert_eq!(stats.deadline_missed, 1);
    assert_eq!(stats.completed, 4);
    // Conservation: every accepted job is accounted for exactly once.
    assert_eq!(
        stats.submitted,
        stats.completed + stats.cancelled + stats.deadline_missed + stats.failed_other
    );
}

#[test]
fn deadline_lapsed_while_queued_fails_without_a_lane() {
    let front = SolveFrontEnd::new(
        registry_with_demo(),
        FrontEndConfig { lanes: 1, max_pending: 8 },
    );
    let blocker = front
        .submit(SubmitRequest::new("demo", rk(1)).with_opts(endless_opts()))
        .unwrap();
    wait_until_running(&front, blocker);
    // Zero budget, stuck behind the blocker: its deadline lapses in the
    // queue, so it must fail at dequeue without consuming solve time.
    let doomed = front
        .submit(
            SubmitRequest::new("demo", rk(2))
                .with_opts(endless_opts())
                .with_deadline(Duration::ZERO),
        )
        .unwrap();
    assert!(front.cancel(blocker));
    let status = front.wait(doomed, WAIT).unwrap();
    assert!(
        matches!(&status, JobStatus::Failed(e) if matches!(**e, Error::DeadlineExceeded { .. })),
        "queued-past-deadline job must fail typed: {status:?}"
    );
}

// ------------------------------------------------------------ cancellation

#[test]
fn cancel_stops_a_running_solve_at_a_checkpoint() {
    let front = SolveFrontEnd::new(
        registry_with_demo(),
        FrontEndConfig { lanes: 1, max_pending: 4 },
    );
    let id = front
        .submit(SubmitRequest::new("demo", rk(1)).with_opts(endless_opts()))
        .unwrap();
    wait_until_running(&front, id);
    let cancelled_at = Instant::now();
    assert!(front.cancel(id));
    let status = front.wait(id, WAIT).unwrap();
    // Typed, and *fast*: the cap is ~usize::MAX/2 iterations (hours); a
    // checkpoint halt lands in far under the generous bound below.
    assert!(matches!(&status, JobStatus::Failed(e) if matches!(**e, Error::Cancelled)));
    assert!(
        cancelled_at.elapsed() < Duration::from_secs(30),
        "cancel took {:?} — not a checkpoint halt",
        cancelled_at.elapsed()
    );
    assert_eq!(front.stats().cancelled, 1);
}

// ------------------------------------------- telemetry + wait conservation

#[test]
fn dropped_plus_delivered_samples_conserve_across_sink_capacities() {
    // Same deterministic job twice: a roomy sink counts the emission total;
    // a capacity-1 sink must then satisfy delivered + dropped == total.
    let front = SolveFrontEnd::new(
        registry_with_demo(),
        FrontEndConfig { lanes: 1, max_pending: 4 },
    );
    // Fixed budget + history: emission checkpoints at k = 64, 128, …, 2048
    // — deterministic, so two identical runs emit identical totals.
    let job_opts =
        || SolveOptions::default().with_fixed_iterations(2048).with_history_step(64);

    let (roomy_sink, roomy_rx) = ProgressSink::bounded(4096);
    let id = front
        .submit(
            SubmitRequest::new("demo", rk(7))
                .with_opts(job_opts().with_progress(roomy_sink)),
        )
        .unwrap();
    let roomy = match front.wait(id, WAIT).unwrap() {
        JobStatus::Done(r) => r,
        other => panic!("expected Done, got {other:?}"),
    };
    let total = roomy_rx.drain().len() as u64;
    assert!(total > 0, "checkpointed job emitted no samples");
    assert_eq!(roomy.dropped_samples, 0, "roomy sink must not drop");

    let (tiny_sink, tiny_rx) = ProgressSink::bounded(1);
    let id = front
        .submit(
            SubmitRequest::new("demo", rk(7)).with_opts(job_opts().with_progress(tiny_sink)),
        )
        .unwrap();
    let tiny = match front.wait(id, WAIT).unwrap() {
        JobStatus::Done(r) => r,
        other => panic!("expected Done, got {other:?}"),
    };
    let delivered = tiny_rx.drain().len() as u64;
    assert_eq!(
        tiny.dropped_samples + delivered,
        total,
        "conservation: dropped ({}) + delivered ({delivered}) != emitted ({total})",
        tiny.dropped_samples
    );
    assert_eq!(tiny.dropped_samples, tiny_rx.dropped(), "report and receiver must agree");
    // The front end aggregates the same totals.
    assert_eq!(front.stats().dropped_samples, tiny.dropped_samples);
}

#[test]
fn queue_wait_is_measured_for_jobs_that_waited() {
    let front = SolveFrontEnd::new(
        registry_with_demo(),
        FrontEndConfig { lanes: 1, max_pending: 4 },
    );
    // A blocker that takes real time (fixed budget, no stopping checks).
    let blocker = front
        .submit(
            SubmitRequest::new("demo", rk(1))
                .with_opts(SolveOptions::default().with_fixed_iterations(400_000)),
        )
        .unwrap();
    wait_until_running(&front, blocker);
    let waiter = front
        .submit(
            SubmitRequest::new("demo", rk(2))
                .with_opts(SolveOptions::default().with_residual_stopping(1e-8, 16)),
        )
        .unwrap();
    let blocker_report = match front.wait(blocker, WAIT).unwrap() {
        JobStatus::Done(r) => r,
        other => panic!("blocker: {other:?}"),
    };
    let waiter_report = match front.wait(waiter, WAIT).unwrap() {
        JobStatus::Done(r) => r,
        other => panic!("waiter: {other:?}"),
    };
    // The waiter provably sat behind the blocker's solve on the only lane.
    assert!(
        waiter_report.queue_wait > Duration::ZERO,
        "waiter queue_wait must be nonzero"
    );
    // And queue wait is submit → dequeue, so the waiter's wait is at least
    // a slice of the blocker's remaining solve — sanity: bounded above by
    // total test patience, below by zero (strict) asserted above.
    assert!(blocker_report.result.iterations == 400_000);
}
