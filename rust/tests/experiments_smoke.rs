//! Smoke-runs every registered experiment at CI scale and sanity-checks the
//! emitted reports (every driver must produce its shape-check section and at
//! least one table).

use kaczmarz::coordinator::{find, registry, Scale};

#[test]
fn every_experiment_smokes() {
    // One pass over the whole registry at smoke scale. This is the paper's
    // full evaluation pipeline end to end, miniaturized.
    let scale = Scale::smoke();
    for exp in registry() {
        let md = exp.run(scale).to_markdown();
        assert!(
            md.contains("###"),
            "{} produced no table:\n{md}",
            exp.id()
        );
        assert!(
            md.contains("Shape check") || md.contains("horizon"),
            "{} missing its shape-check note",
            exp.id()
        );
    }
}

#[test]
fn reports_write_to_disk() {
    let exp = find("fig1").unwrap();
    let report = exp.run(Scale::smoke());
    let dir = std::env::temp_dir().join("kcz_experiments_smoke");
    let path = report.write(&dir, exp.id()).unwrap();
    let content = std::fs::read_to_string(&path).unwrap();
    assert!(content.contains("Fig 1"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn solver_zoo_head_to_head_smokes() {
    // The zoo panel is the solver-menagerie head-to-head (RK / RKA /
    // weighted RKA / REK at an equal row budget); its report must name the
    // REK column the assertions in solver_zoo_properties.rs lock down.
    let exp = find("zoo").expect("zoo experiment registered");
    let md = exp.run(Scale::smoke()).to_markdown();
    assert!(md.contains("REK"), "zoo report missing REK row:\n{md}");
    assert!(md.contains("Head-to-head"), "zoo report missing its table:\n{md}");
    assert!(md.contains("Shape check"), "zoo report missing shape-check note:\n{md}");
}

#[test]
fn registry_ids_unique() {
    let mut ids: Vec<&str> = registry().iter().map(|e| e.id()).collect();
    let before = ids.len();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), before);
}
