//! Least-squares property suite for the solver zoo: REK (Randomized
//! Extended Kaczmarz), greedy Motzkin sampling, and heterogeneous averaging
//! weights.
//!
//! The claims locked down here:
//!
//! 1. on an inconsistent system, RK and RKA stall at a convergence horizon
//!    (a positive error floor vs `x_LS`); REK, at the **same row budget**,
//!    lands orders of magnitude below that self-calibrated floor — and at an
//!    equal *iteration* budget it beats the best RKA configuration;
//! 2. greedy Motzkin selection keeps the error monotone non-increasing on
//!    consistent systems, collapses the scanned max distance, zeroes the
//!    selected row's residual at each step, and out-iterates randomized
//!    sampling where row norms are heavily skewed;
//! 3. uniform weights are not a new code path: `Weights::Uniform` RKA and
//!    RKAB are **bitwise identical** to a hand-rolled transcription of the
//!    pre-zoo update loops;
//! 4. every new path is reference-free: fixed-budget runs on a system with
//!    no reference solution (where any `error_sq` consult panics) complete
//!    cleanly, and the zoo serves through `BatchSolver` / `SolveQueue`.
//!
//! The dataset seed for the stall-floor and skewed-norm properties comes
//! from `KACZMARZ_ZOO_SEED` (default 71); CI runs the suite under a small
//! seed matrix. Margins below were validated offline for seeds 71 and 9
//! with a bit-exact MT19937 replication of the generator and solvers; the
//! observed REK-vs-floor separation exceeds 1e22, asserted at 1e6.

use kaczmarz::batch::{BatchJob, BatchSolver, SolveQueue};
use kaczmarz::data::{DatasetBuilder, LinearSystem};
use kaczmarz::linalg::{axpy, gemv};
use kaczmarz::solvers::cgls::attach_least_squares;
use kaczmarz::solvers::rek::RekSolver;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rka::{RkaSolver, Weights};
use kaczmarz::solvers::rkab::{block_sweep, RkabSolver};
use kaczmarz::solvers::{
    GreedySelector, RowSampler, SamplingScheme, SamplingStrategy, SolveOptions, Solver,
};

/// Dataset seed for the seed-matrixed properties (`KACZMARZ_ZOO_SEED`,
/// default 71 — the CI matrix runs {71, 9}, both validated offline).
fn zoo_seed() -> u32 {
    std::env::var("KACZMARZ_ZOO_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(71)
}

/// The same system, stripped of every reference solution: any call to
/// `error_sq` panics, so a run that completes proves zero consultations.
fn strip_reference(sys: &LinearSystem) -> LinearSystem {
    LinearSystem::new(sys.a.clone(), sys.b.clone(), None, true)
}

// ---------------------------------------------------------------------------
// Property 1: REK breaks the RK/RKA stall floor.
// ---------------------------------------------------------------------------

#[test]
fn rek_lands_below_the_self_calibrated_stall_floor() {
    let mut sys = DatasetBuilder::new(400, 8).seed(zoo_seed()).inconsistent();
    attach_least_squares(&mut sys, 1e-12, 50_000).expect("CGLS");

    // Self-calibration: where do RK and RKA actually plateau on THIS system
    // at a 40k-row budget? (Fixed runs evaluate no metric; the error is read
    // off the final iterate.) The floor is the best of the three.
    const ROWS: usize = 40_000;
    let rk_err = {
        let r = RkSolver::new(3).solve(&sys, &SolveOptions::default().with_fixed_iterations(ROWS));
        sys.error_sq(&r.x)
    };
    let rka_err = |q: usize| {
        let opts = SolveOptions::default().with_fixed_iterations(ROWS / q);
        sys.error_sq(&RkaSolver::new(3, q, 1.0).solve(&sys, &opts).x)
    };
    let floor = rk_err.min(rka_err(5)).min(rka_err(20));
    assert!(floor > 1e-8, "stall floor {floor:.3e} suspiciously low — not inconsistent?");

    // REK at the same row budget must land far below the floor (observed
    // separation > 1e22 for the matrix seeds; 1e6 asserted).
    let rek = RekSolver::new(3).solve(&sys, &SolveOptions::default().with_fixed_iterations(ROWS));
    let rek_err = sys.error_sq(&rek.x);
    assert!(
        rek_err < floor / 1e6,
        "REK {rek_err:.3e} not far enough below the RK/RKA floor {floor:.3e}"
    );
}

#[test]
fn rek_beats_best_rka_at_equal_iteration_budget() {
    // The acceptance head-to-head: equal ITERATION budget, where each RKA
    // iteration consumes q = 10 rows to REK's one row + one column.
    let mut sys = DatasetBuilder::new(400, 8).seed(zoo_seed()).inconsistent();
    attach_least_squares(&mut sys, 1e-12, 50_000).expect("CGLS");
    let opts = SolveOptions::default().with_fixed_iterations(4_000);
    let rka_err = sys.error_sq(&RkaSolver::new(3, 10, 1.0).solve(&sys, &opts).x);
    let rek_err = sys.error_sq(&RekSolver::new(3).solve(&sys, &opts).x);
    assert!(
        rek_err < rka_err / 100.0,
        "REK {rek_err:.3e} vs RKA(q=10) {rka_err:.3e} at 4000 iterations"
    );
}

// ---------------------------------------------------------------------------
// Property 2: greedy Motzkin selection.
// ---------------------------------------------------------------------------

#[test]
fn greedy_error_is_monotone_and_scan_distances_collapse() {
    let sys = DatasetBuilder::new(200, 8).seed(zoo_seed()).consistent();

    // Drive 400 greedy steps by hand through the public selector so the
    // per-step scan distances are observable.
    let mut selector = GreedySelector::new(&sys);
    let mut x = vec![0.0; sys.cols()];
    let mut distances = Vec::with_capacity(400);
    let mut errors = Vec::with_capacity(400);
    for _ in 0..400 {
        let i = selector.select(&sys, &x, 1)[0];
        distances.push(selector.last_distance_sq(&sys, i));
        let scale = (sys.b[i] - sys.a.row_dot(i, &x)) / sys.row_norms_sq[i];
        sys.a.row_axpy(i, scale, &mut x);
        // A unit projection satisfies the selected row's equation exactly.
        let resid = (sys.b[i] - sys.a.row_dot(i, &x)).abs();
        assert!(resid < 1e-9 * sys.row_norms_sq[i].sqrt().max(1.0), "row {i} residual {resid}");
        errors.push(sys.error_sq(&x));
    }

    // Unit projections never increase the distance to x* (exact-arithmetic
    // contraction); in floating point the comparison is only meaningful
    // above the machine floor — greedy hits ~1e-29 within ~60 steps here.
    for (k, w) in errors.windows(2).enumerate() {
        assert!(
            w[1] <= w[0] * (1.0 + 1e-12) || w[1] < 1e-20,
            "error rose at step {}: {:.3e} -> {:.3e}",
            k + 1,
            w[0],
            w[1]
        );
    }
    // The max-distance sequence is NOT pointwise monotone (obtuse-row
    // counterexamples exist), but it collapses: the early scan maxima dwarf
    // the late ones (observed ratio ~1e31; 1e6 asserted).
    let early = distances[..50].iter().cloned().fold(0.0, f64::max);
    let late = distances[350..].iter().cloned().fold(0.0, f64::max);
    assert!(
        late < early / 1e6,
        "greedy scan distances did not collapse: early {early:.3e}, late {late:.3e}"
    );
    // And the trajectory really converged.
    let err = errors.last().unwrap();
    assert!(*err < 1e-16, "greedy stalled at {err:.3e}");
}

#[test]
fn greedy_beats_randomized_sampling_on_skewed_row_norms() {
    // Row sigmas spread over [1, 60] ⇒ squared row norms spread by >1e3:
    // eq. 4 keeps revisiting heavy rows, the Motzkin scan goes straight for
    // the most violated constraint (observed 110-134 vs 12-13 iterations
    // for the matrix seeds; 2x margin asserted).
    let sys =
        DatasetBuilder::new(300, 6).seed(zoo_seed()).sigma_range(1.0, 60.0).consistent();
    let opts = SolveOptions::default().with_tolerance(1e-8).with_max_iterations(2_000_000);
    let rand = RkSolver::new(7).solve(&sys, &opts);
    let greedy = RkSolver::new(7).with_sampling(SamplingStrategy::Greedy).solve(&sys, &opts);
    assert!(rand.converged && greedy.converged);
    assert!(
        2 * greedy.iterations < rand.iterations,
        "greedy {} vs randomized {}",
        greedy.iterations,
        rand.iterations
    );
}

// ---------------------------------------------------------------------------
// Property 3: uniform weights are bitwise the pre-zoo solvers.
// ---------------------------------------------------------------------------

#[test]
fn uniform_weight_rka_is_bitwise_the_pre_zoo_update_loop() {
    let sys = DatasetBuilder::new(150, 8).seed(4).consistent();
    let (q, alpha, seed, iters) = (4usize, 1.0f64, 9u32, 300usize);

    // Hand-rolled transcription of the pre-zoo RKA iteration: sample one
    // row per worker, project against x^(k), average with alpha/q.
    let mut samplers: Vec<RowSampler> = (0..q)
        .map(|t| RowSampler::new(&sys, SamplingScheme::FullMatrix, t, q, seed))
        .collect();
    let mut x = vec![0.0; sys.cols()];
    let mut delta = vec![0.0; sys.cols()];
    for _ in 0..iters {
        delta.fill(0.0);
        for sampler in samplers.iter_mut() {
            let i = sampler.sample();
            let scale =
                alpha * (sys.b[i] - sys.a.row_dot(i, &x)) / (q as f64 * sys.row_norms_sq[i]);
            sys.a.row_axpy(i, scale, &mut delta);
        }
        axpy(1.0, &delta, &mut x);
    }

    let r = RkaSolver::new(seed, q, alpha)
        .solve(&sys, &SolveOptions::default().with_fixed_iterations(iters));
    for (j, (a, b)) in r.x.iter().zip(&x).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "coordinate {j}: {a} vs {b}");
    }
}

#[test]
fn uniform_weight_rkab_is_bitwise_the_pre_zoo_update_loop() {
    let sys = DatasetBuilder::new(150, 8).seed(4).consistent();
    let (q, bs, alpha, seed, iters) = (3usize, 6usize, 1.0f64, 9u32, 200usize);

    // Hand-rolled transcription of the pre-zoo RKAB iteration: each worker
    // sweeps its own sampled block from x^(k), results averaged by 1/q.
    let mut samplers: Vec<RowSampler> = (0..q)
        .map(|t| RowSampler::new(&sys, SamplingScheme::FullMatrix, t, q, seed))
        .collect();
    let mut x = vec![0.0; sys.cols()];
    let mut v = vec![0.0; sys.cols()];
    let mut acc = vec![0.0; sys.cols()];
    let mut idx = Vec::with_capacity(bs);
    for _ in 0..iters {
        acc.fill(0.0);
        for sampler in samplers.iter_mut() {
            v.copy_from_slice(&x);
            block_sweep(&sys, sampler, bs, alpha, &mut v, &mut idx);
            axpy(1.0, &v, &mut acc);
        }
        let inv = 1.0 / q as f64;
        for (xi, ai) in x.iter_mut().zip(&acc) {
            *xi = ai * inv;
        }
    }

    let r = RkabSolver::new(seed, q, bs, alpha)
        .solve(&sys, &SolveOptions::default().with_fixed_iterations(iters));
    for (j, (a, b)) in r.x.iter().zip(&x).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "coordinate {j}: {a} vs {b}");
    }
}

// ---------------------------------------------------------------------------
// Property 4: reference-free runs and batch serving.
// ---------------------------------------------------------------------------

#[test]
fn zoo_paths_run_reference_free_with_zero_reference_evaluations() {
    // The probe: no reference solution anywhere, so a single error_sq
    // consult panics. Fixed budgets must complete on every new path.
    let sys = strip_reference(&DatasetBuilder::new(150, 8).seed(5).consistent());
    let opts = SolveOptions::default().with_fixed_iterations(60);

    let r = RekSolver::new(3).solve(&sys, &opts);
    assert!(!r.converged && r.iterations == 60, "REK reference-free run");
    let r = RkSolver::new(3).with_sampling(SamplingStrategy::Greedy).solve(&sys, &opts);
    assert!(!r.converged && r.iterations == 60, "greedy RK reference-free run");
    let r = RkaSolver::new(3, 4, 1.0)
        .with_weights(Weights::InverseRowNorm(1.0))
        .with_sampling(SamplingStrategy::Greedy)
        .solve(&sys, &opts);
    assert!(!r.converged && r.iterations == 60, "greedy weighted RKA reference-free run");
    let r = RkabSolver::new(3, 4, 8, 1.0)
        .with_weights(Weights::InverseRowNorm(1.0))
        .with_sampling(SamplingStrategy::Greedy)
        .solve(&sys, &opts);
    assert!(!r.converged && r.iterations == 60, "greedy weighted RKAB reference-free run");
}

#[test]
fn batch_solver_serves_rek_jobs() {
    // Multiple right-hand sides over one matrix, solved by REK under
    // residual stopping (consistent rhs ⇒ the residual reaches any
    // tolerance; each job re-derives its own z = b stream).
    let sys = DatasetBuilder::new(200, 8).seed(9).consistent();
    let jobs: Vec<BatchJob> = (0..3)
        .map(|j| {
            let hidden: Vec<f64> = (0..sys.cols()).map(|i| (i + j) as f64 - 2.0).collect();
            BatchJob::new(gemv(&sys.a, &hidden).unwrap())
        })
        .collect();
    let opts = SolveOptions::default().with_residual_stopping(1e-6, 32);
    let reports = BatchSolver::new(&sys, RekSolver::new(3))
        .with_workers(2)
        .solve_many(&jobs, &opts)
        .unwrap();
    for r in &reports {
        assert!(r.result.converged, "REK batch job {}", r.job);
        assert!(r.residual_norm * r.residual_norm < 1e-6, "job {}", r.job);
    }
}

#[test]
fn solve_queue_serves_greedy_jobs() {
    let system = strip_reference(&DatasetBuilder::new(200, 8).seed(7).consistent());
    let mut queue = SolveQueue::new();
    queue.push(system, SolveOptions::default().with_residual_stopping(1e-6, 32));
    let solver = RkSolver::new(3).with_sampling(SamplingStrategy::Greedy);
    let reports = queue.run(&solver).unwrap();
    assert!(reports[0].result.converged, "greedy queue job must certify via residual");
    assert!(reports[0].residual_norm * reports[0].residual_norm < 1e-6);
}
