//! Property-based tests on the library's invariants.
//!
//! No proptest offline, so properties are driven by an MT19937-fed case
//! generator: every property runs against `CASES` randomized instances with
//! shrink-friendly, printed seeds (re-run a failure by fixing the seed).

use kaczmarz::data::{DatasetBuilder, LinearSystem};
use kaczmarz::linalg::{jacobi_singular_values, Matrix};
use kaczmarz::rng::{AliasTable, DiscreteDistribution, Mt19937};
use kaczmarz::solvers::alpha::{optimal_alpha, spectral_bounds};
use kaczmarz::solvers::cgls::solve_least_squares;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rka::RkaSolver;
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SolveOptions, Solver};

const CASES: u32 = 12;

/// Random small overdetermined system from a case seed.
fn random_system(seed: u32) -> LinearSystem {
    let mut rng = Mt19937::new(seed);
    let m = 40 + (rng.next_below(200)) as usize;
    let n = 2 + (rng.next_below(12)) as usize;
    DatasetBuilder::new(m, n).seed(seed).consistent()
}

#[test]
fn prop_projection_lands_on_hyperplane() {
    // One Kaczmarz projection with alpha=1 must satisfy the projected row's
    // equation exactly: <A^(i), x'> = b_i.
    for case in 0..CASES {
        let sys = random_system(1000 + case);
        let mut rng = Mt19937::new(case);
        let mut x: Vec<f64> = (0..sys.cols()).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
        let i = rng.next_below(sys.rows() as u32) as usize;
        let row = sys.a.row(i);
        let scale = (sys.b[i] - kaczmarz::linalg::dot(row, &x)) / sys.row_norms_sq[i];
        kaczmarz::linalg::axpy(scale, row, &mut x);
        let resid = (sys.b[i] - kaczmarz::linalg::dot(row, &x)).abs();
        let row_scale = sys.row_norms_sq[i].sqrt();
        assert!(resid < 1e-9 * row_scale.max(1.0), "case {case}: resid {resid}");
    }
}

#[test]
fn prop_error_monotone_nonincreasing_under_projection() {
    // Pure projections (alpha=1) never increase the distance to x* on a
    // consistent system — per-iteration contraction property.
    for case in 0..CASES {
        let sys = random_system(2000 + case);
        let opts = SolveOptions::default().with_fixed_iterations(200).with_history_step(10);
        let r = RkSolver::new(case).solve(&sys, &opts);
        for w in r.history.errors.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-12),
                "case {case}: error rose {} -> {}",
                w[0],
                w[1]
            );
        }
    }
}

#[test]
fn prop_rka_fixed_point_is_solution() {
    // If x = x*, any RKA/RKAB update leaves it unchanged.
    for case in 0..CASES {
        let sys = random_system(3000 + case);
        let x_true = sys.x_true.clone().unwrap();
        // Warm-start by running zero iterations from x*: emulate by checking
        // residuals of the sampled-row scale factors are ~0.
        for i in 0..sys.rows() {
            let row = sys.a.row(i);
            let r = (sys.b[i] - kaczmarz::linalg::dot(row, &x_true)).abs();
            let scale = sys.row_norms_sq[i].sqrt() * kaczmarz::linalg::norm2(&x_true);
            assert!(r < 1e-9 * scale.max(1.0), "case {case} row {i}: residual {r}");
        }
    }
}

#[test]
fn prop_rkab_rows_used_accounting() {
    for case in 0..CASES {
        let sys = random_system(4000 + case);
        let mut rng = Mt19937::new(case);
        let q = 1 + rng.next_below(6) as usize;
        let bs = 1 + rng.next_below(20) as usize;
        let iters = 1 + rng.next_below(30) as usize;
        let opts = SolveOptions::default().with_fixed_iterations(iters);
        let r = RkabSolver::new(case, q, bs, 1.0).solve(&sys, &opts);
        assert_eq!(r.rows_used, iters * q * bs, "case {case}");
    }
}

#[test]
fn prop_sampling_distributions_agree() {
    // Alias table and CDF sampler draw from the same distribution: compare
    // empirical frequencies on random weights.
    for case in 0..CASES {
        let mut rng = Mt19937::new(5000 + case);
        let k = 2 + rng.next_below(30) as usize;
        let weights: Vec<f64> = (0..k).map(|_| rng.next_f64() + 0.01).collect();
        let total: f64 = weights.iter().sum();
        let alias = AliasTable::new(&weights);
        let cdf = DiscreteDistribution::new(&weights);
        let draws = 40_000;
        let mut fa = vec![0.0; k];
        let mut fc = vec![0.0; k];
        for _ in 0..draws {
            fa[alias.sample(&mut rng)] += 1.0;
            fc[cdf.sample(&mut rng)] += 1.0;
        }
        for i in 0..k {
            let p = weights[i] / total;
            assert!((fa[i] / draws as f64 - p).abs() < 0.02, "case {case} alias cat {i}");
            assert!((fc[i] / draws as f64 - p).abs() < 0.02, "case {case} cdf cat {i}");
        }
    }
}

#[test]
fn prop_optimal_alpha_bounds() {
    // For any spectrum, eq. 6 yields alpha* in (1, q] (consistent systems).
    for case in 0..CASES {
        let sys = random_system(6000 + case);
        let b = spectral_bounds(&sys, 0, sys.rows()).unwrap();
        assert!(b.s_min > 0.0 && b.s_min <= b.s_max && b.s_max <= 1.0 + 1e-12, "case {case}");
        for q in [2usize, 4, 8, 16, 64] {
            let a = optimal_alpha(&b, q);
            assert!(a > 0.99 && a <= q as f64 + 1e-9, "case {case} q {q}: alpha {a}");
        }
    }
}

#[test]
fn prop_cgls_beats_any_random_probe() {
    // x_LS minimizes the residual: no random probe may do better.
    for case in 0..CASES {
        let mut rng = Mt19937::new(7000 + case);
        let m = 30 + rng.next_below(100) as usize;
        let n = 2 + rng.next_below(8) as usize;
        let sys = DatasetBuilder::new(m, n).seed(7000 + case).inconsistent();
        let x_ls = solve_least_squares(&sys, 1e-12, 5_000).unwrap();
        let r_ls = sys.residual_norm(&x_ls);
        for _ in 0..5 {
            let probe: Vec<f64> =
                x_ls.iter().map(|v| v + rng.next_f64() * 0.2 - 0.1).collect();
            assert!(sys.residual_norm(&probe) >= r_ls - 1e-9, "case {case}");
        }
    }
}

#[test]
fn prop_singular_values_bound_matrix_action() {
    // For any x: sigma_min ||x|| <= ||Ax|| <= sigma_max ||x||.
    for case in 0..CASES {
        let mut rng = Mt19937::new(8000 + case);
        let m = 10 + rng.next_below(20) as usize;
        let n = 2 + rng.next_below(5) as usize;
        let data: Vec<f64> = (0..m * n).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
        let a = Matrix::from_vec(m, n, data).unwrap();
        let sv = jacobi_singular_values(&a, 1e-12, 200).unwrap();
        let (smax, smin) = (sv[0], sv[n - 1]);
        for _ in 0..5 {
            let x: Vec<f64> = (0..n).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
            let ax = kaczmarz::linalg::gemv(&a, &x).unwrap();
            let nx = kaczmarz::linalg::norm2(&x);
            let nax = kaczmarz::linalg::norm2(&ax);
            assert!(nax <= smax * nx * (1.0 + 1e-9), "case {case}");
            assert!(nax >= smin * nx * (1.0 - 1e-9), "case {case}");
        }
    }
}

#[test]
fn prop_rka_q1_is_rk_for_any_seed() {
    for case in 0..CASES {
        let sys = random_system(9000 + case);
        let opts = SolveOptions::default().with_fixed_iterations(100);
        let rka = RkaSolver::new(case, 1, 1.0).solve(&sys, &opts);
        let rk = RkSolver::new(kaczmarz::rng::derive_seed(case, 0)).solve(&sys, &opts);
        for (a, b) in rka.x.iter().zip(&rk.x) {
            assert!((a - b).abs() < 1e-12, "case {case}");
        }
    }
}
