//! Integration of the batch-solve serving layer: batched results must be
//! bitwise-equal to independent single solves, the queue must report per-job
//! outcomes for heterogeneous workloads, and both must ride the persistent
//! pool without spawning per-solve threads.

use kaczmarz::batch::{BatchJob, BatchSolver, SolveQueue};
use kaczmarz::data::{DatasetBuilder, LinearSystem};
use kaczmarz::linalg::{gemv, Storage};
use kaczmarz::metrics::History;
use kaczmarz::parallel::WorkerPool;
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SolveOptions, SolveResult, Solver};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// `count` right-hand sides `b_j = A x_j` with known solutions.
fn make_jobs(system: &LinearSystem, count: usize, seed: u32) -> Vec<BatchJob> {
    use kaczmarz::rng::Mt19937;
    let mut rng = Mt19937::new(seed);
    (0..count)
        .map(|_| {
            let x: Vec<f64> =
                (0..system.cols()).map(|_| rng.next_f64() - 0.5).collect();
            BatchJob::new(gemv(&system.a, &x).unwrap()).with_reference(x)
        })
        .collect()
}

#[test]
fn batched_16_rhs_equals_16_independent_solves_bitwise() {
    // The acceptance bar of the serving layer: fanning 16 rhs across pool
    // workers changes *when* each job runs, never *what* it computes.
    let system = DatasetBuilder::new(300, 12).seed(1).consistent();
    let jobs = make_jobs(&system, 16, 17);
    let opts = SolveOptions::default().with_fixed_iterations(120);

    let reports = BatchSolver::new(&system, RkSolver::new(7))
        .with_workers(4)
        .solve_many(&jobs, &opts)
        .unwrap();
    assert_eq!(reports.len(), 16);

    for (j, (report, job)) in reports.iter().zip(&jobs).enumerate() {
        let independent = LinearSystem::new(
            system.a.clone(),
            job.rhs.clone(),
            job.x_ref.clone(),
            true,
        );
        let solo = RkSolver::new(7).solve(&independent, &opts);
        assert_eq!(report.job, j);
        assert_eq!(report.result.iterations, solo.iterations, "job {j}");
        for (a, b) in report.result.x.iter().zip(&solo.x) {
            assert_eq!(a.to_bits(), b.to_bits(), "job {j}: batched {a} vs solo {b}");
        }
    }
}

#[test]
fn batched_rkab_matches_independent_solves_bitwise_too() {
    // Same guarantee through a block solver (the paper's RKAB), whose
    // in-block float association is the delicate part.
    let system = DatasetBuilder::new(240, 10).seed(2).consistent();
    let jobs = make_jobs(&system, 6, 23);
    let opts = SolveOptions::default().with_fixed_iterations(40);

    let reports = BatchSolver::new(&system, RkabSolver::new(5, 4, 8, 1.0))
        .with_workers(3)
        .solve_many(&jobs, &opts)
        .unwrap();
    for (report, job) in reports.iter().zip(&jobs) {
        let independent = LinearSystem::new(
            system.a.clone(),
            job.rhs.clone(),
            job.x_ref.clone(),
            true,
        );
        let solo = RkabSolver::new(5, 4, 8, 1.0).solve(&independent, &opts);
        for (a, b) in report.result.x.iter().zip(&solo.x) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

#[test]
fn queue_mixed_consistent_inconsistent_jobs_report_individually() {
    // Multi-tenant shape: different systems, different stopping rules, one
    // dispatch. Consistent jobs must converge to tolerance; inconsistent
    // jobs run their fixed budget and report the residual floor honestly.
    let mut queue = SolveQueue::new().with_workers(3);
    let consistent_ids: Vec<usize> = (0..3u32)
        .map(|s| {
            queue.push(
                DatasetBuilder::new(200 + 20 * s as usize, 8).seed(s).consistent(),
                SolveOptions::default(),
            )
        })
        .collect();
    let inconsistent_ids: Vec<usize> = (0..2u32)
        .map(|s| {
            queue.push(
                DatasetBuilder::new(150, 6).seed(40 + s).inconsistent(),
                SolveOptions::default().with_fixed_iterations(300),
            )
        })
        .collect();

    let reports = queue.run(&RkSolver::new(3)).unwrap();
    assert_eq!(reports.len(), 5);
    for &id in &consistent_ids {
        assert_eq!(reports[id].job, id);
        assert!(reports[id].result.converged, "job {id}");
        // err² < 1e-8 at stop with σ_max ~ 1e2 row scales => residual ~ 1e-2.
        assert!(reports[id].residual_norm < 1e-1, "job {id}");
    }
    for &id in &inconsistent_ids {
        assert_eq!(reports[id].job, id);
        assert_eq!(reports[id].result.iterations, 300, "job {id}");
        // Inconsistent by construction: no iterate zeroes the residual.
        assert!(reports[id].residual_norm > 1e-4, "job {id}");
    }
}

#[test]
fn batch_layer_reuses_pool_workers_across_calls() {
    // The serving property: request N+1 spawns no threads. A dedicated pool
    // (immune to other tests growing the global one) must hold exactly
    // lanes-1 workers after warm-up, across both batch primitives.
    let pool = Arc::new(WorkerPool::new());
    let system = DatasetBuilder::new(150, 8).seed(5).consistent();
    let jobs = make_jobs(&system, 8, 31);
    let opts = SolveOptions::default().with_fixed_iterations(30);

    let batch = BatchSolver::new(&system, RkSolver::new(1))
        .with_workers(4)
        .with_pool(Arc::clone(&pool));
    batch.solve_many(&jobs, &opts).unwrap();
    assert_eq!(pool.worker_count(), 3, "first call spawns the lanes");
    for _ in 0..5 {
        batch.solve_many(&jobs, &opts).unwrap();
    }
    assert_eq!(pool.worker_count(), 3, "later calls reuse parked workers");

    let mut queue = SolveQueue::new().with_workers(4).with_pool(Arc::clone(&pool));
    for s in 0..6u32 {
        queue.push(
            DatasetBuilder::new(100, 6).seed(s).consistent(),
            SolveOptions::default().with_fixed_iterations(30),
        );
    }
    queue.run(&RkSolver::new(1)).unwrap();
    assert_eq!(pool.worker_count(), 3, "queue shares the same parked workers");
}

/// A `Solver` that counts how many of the systems handed to it hold
/// pointer-identical matrix storage with a designated original
/// (`Storage::shares_storage`, i.e. `Arc::ptr_eq` on the backing buffer of
/// whichever backend the system uses).
struct StorageProbe {
    original: Storage,
    shared: Arc<AtomicUsize>,
    solves: Arc<AtomicUsize>,
}

impl Solver for StorageProbe {
    fn name(&self) -> &'static str {
        "storage-probe"
    }
    fn solve(&self, system: &LinearSystem, _opts: &SolveOptions) -> SolveResult {
        self.solves.fetch_add(1, Ordering::Relaxed);
        if system.a.shares_storage(&self.original) {
            self.shared.fetch_add(1, Ordering::Relaxed);
        }
        SolveResult {
            x: vec![0.0; system.cols()],
            iterations: 0,
            converged: false,
            diverged: false,
            seconds: 0.0,
            rows_used: 0,
            history: History::default(),
        }
    }
}

#[test]
fn sixteen_lanes_share_one_resident_matrix() {
    // The memory bar of the serving layer: a 16-lane batch over a resident
    // system holds ONE matrix buffer, not sixteen. Every lane's
    // `LinearSystem` clone must observe pointer-equal row storage with the
    // resident original — lanes only duplicate the O(m) rhs/row-norm
    // vectors, so resident-matrix memory is O(m·n), independent of lanes.
    let system = DatasetBuilder::new(200, 10).seed(11).consistent();
    assert!(
        system.clone().a.shares_storage(&system.a),
        "cloning a system must not duplicate matrix storage"
    );

    let shared = Arc::new(AtomicUsize::new(0));
    let solves = Arc::new(AtomicUsize::new(0));
    let probe = StorageProbe {
        original: system.a.clone(), // Arc bump, same buffer
        shared: Arc::clone(&shared),
        solves: Arc::clone(&solves),
    };
    let jobs = make_jobs(&system, 16, 43);
    let opts = SolveOptions::default().with_fixed_iterations(1);
    BatchSolver::new(&system, probe)
        .with_workers(16)
        .solve_many(&jobs, &opts)
        .unwrap();
    assert_eq!(solves.load(Ordering::Relaxed), 16);
    assert_eq!(
        shared.load(Ordering::Relaxed),
        16,
        "every lane must read the one resident matrix"
    );
}

#[test]
fn reference_free_jobs_run_the_fixed_budget() {
    // Serving an unknown rhs: no reference exists, so the job runs the
    // fixed-iteration protocol and the report's residual is the quality
    // signal. (b = A·x for hidden x, so the residual must shrink.)
    let system = DatasetBuilder::new(200, 8).seed(9).consistent();
    let hidden: Vec<f64> = (0..system.cols()).map(|i| 1.0 + i as f64).collect();
    let jobs = [BatchJob::new(gemv(&system.a, &hidden).unwrap())];
    let opts = SolveOptions::default().with_fixed_iterations(4000);
    let reports = BatchSolver::new(&system, RkSolver::new(3))
        .solve_many(&jobs, &opts)
        .unwrap();
    let b_norm = kaczmarz::linalg::norm2(&jobs[0].rhs);
    assert!(
        reports[0].residual_norm < 1e-3 * b_norm,
        "residual {} vs ‖b‖ {b_norm}",
        reports[0].residual_norm
    );
}
