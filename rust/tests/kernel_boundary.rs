//! The checked kernel boundary must hold in **release** builds.
//!
//! The hot-path kernels (`dot`/`axpy`/`axpy_dot`, the `row_*` trait
//! methods) guard shape mismatches only with `debug_assert_eq!` — in a
//! release build a mismatched caller silently computes over the common
//! prefix. The `Storage::try_*` entry points are the supported boundary
//! for external callers: they validate shapes with real branches and
//! return a typed [`Error::InvalidArgument`]. Integration tests compile
//! the library crate *without* `cfg(test)` and CI runs this suite in the
//! `test-release` lane, so these assertions exercise exactly the
//! configuration the `debug_assert`s vanish from.

use kaczmarz::data::DatasetBuilder;
use kaczmarz::error::Error;
use kaczmarz::linalg::{CsrMatrix, RowStorage, Storage};

fn backends() -> Vec<Storage> {
    let sys = DatasetBuilder::new(6, 4).seed(11).consistent();
    let dense = sys.a.as_dense().expect("generated systems are dense").clone();
    let sparse = CsrMatrix::from_dense(&dense);
    vec![Storage::from(dense), Storage::from(sparse)]
}

#[test]
fn boundary_rejects_short_x_in_release() {
    for st in backends() {
        let x_short = vec![1.0; 3]; // cols is 4
        let err = st.try_row_dot(0, &x_short).unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err:?}");

        let mut y_short = vec![0.0; 3];
        let err = st.try_row_axpy(0, 2.0, &mut y_short).unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err:?}");

        let err = st.try_row_axpy_dot(0, 2.0, 1, &mut y_short).unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err:?}");

        let mut y_rows = vec![0.0; 6];
        let err = st.try_gemv_into(&x_short, &mut y_rows).unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err:?}");
    }
}

#[test]
fn boundary_rejects_out_of_range_rows_in_release() {
    for st in backends() {
        let x = vec![1.0; 4];
        assert!(matches!(st.try_row_dot(6, &x), Err(Error::InvalidArgument(_))));
        let mut y = vec![0.0; 4];
        assert!(matches!(st.try_row_axpy(17, 1.0, &mut y), Err(Error::InvalidArgument(_))));
        // The fused kernel validates the prefetched *next* index too.
        assert!(matches!(
            st.try_row_axpy_dot(0, 1.0, 6, &mut y),
            Err(Error::InvalidArgument(_))
        ));
    }
}

#[test]
fn boundary_accepts_and_matches_unchecked_kernels() {
    for st in backends() {
        let x: Vec<f64> = (0..4).map(|i| (i as f64 * 0.6).sin()).collect();
        let checked = st.try_row_dot(2, &x).unwrap();
        assert_eq!(checked.to_bits(), st.row_dot(2, &x).to_bits());

        let mut y = vec![0.0; 6];
        st.try_gemv_into(&x, &mut y).unwrap();
        let mut reference = vec![0.0; 6];
        RowStorage::gemv_block_into(&st, &x, &mut reference);
        for (u, v) in y.iter().zip(&reference) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }
}
