//! Integration properties of the streaming-telemetry subsystem
//! (`ProgressSink` / `StopCheck` emission), across every solver layer:
//!
//! 1. **Liveness** — a bounded-channel sink observes samples *while the
//!    solve is still running* (≥ 2 before the solve call returns) on every
//!    layer class: sequential, shared-memory, AsyRK, distributed, and the
//!    serving queue;
//! 2. **Non-interference** — a deliberately slow callback sink and a
//!    deliberately full (capacity-1, never-drained) channel sink change
//!    neither the iteration count nor a single bit of the solved `x`
//!    compared to a sink-free run (the sink reads already-computed metrics;
//!    it cannot perturb the iterate or the RNG stream);
//! 3. **Demultiplexing** — queue/batch jobs with per-job sinks each receive
//!    exactly their own curve, even with lanes stealing jobs concurrently;
//! 4. **Reference-free autotune** — the residual-scored tuner runs on a
//!    system with no reference solution, and on consistent systems its
//!    choice agrees with the reference-scored tuner within the test band
//!    (same probe protocol, metrics that decay together).

use kaczmarz::batch::SolveQueue;
use kaczmarz::coordinator::{
    autotune_block_size, autotune_block_size_residual, AutotuneConfig, CostModel,
};
use kaczmarz::data::{DatasetBuilder, LinearSystem};
use kaczmarz::distributed::{DistRka, DistRkab, Placement, SimCluster};
use kaczmarz::metrics::{ProgressReceiver, ProgressSink, Sample};
use kaczmarz::parallel::{AsyRkSolver, ParallelRka, ParallelRkab};
use kaczmarz::solvers::rk::RkSolver;
use kaczmarz::solvers::rkab::RkabSolver;
use kaczmarz::solvers::{SolveOptions, SolveResult, Solver};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Drive `solve` on the current thread while a spawned probe thread drains
/// `rx`; returns `(samples popped while the solve was still running, all
/// samples)`. The probe marks a sample "mid-solve" only if the done flag is
/// still clear when it pops it, so the first count is a *lower* bound on
/// live deliveries.
fn observe_mid_solve<F: FnOnce()>(rx: ProgressReceiver, solve: F) -> (usize, Vec<Sample>) {
    let done = Arc::new(AtomicBool::new(false));
    let done_probe = Arc::clone(&done);
    let probe = std::thread::spawn(move || {
        let mut before = 0usize;
        let mut all = Vec::new();
        loop {
            match rx.recv_timeout(Duration::from_millis(20)) {
                Some(s) => {
                    if !done_probe.load(Ordering::Acquire) {
                        before += 1;
                    }
                    all.push(s);
                }
                None => {
                    if done_probe.load(Ordering::Acquire) {
                        all.extend(rx.drain());
                        break;
                    }
                }
            }
        }
        (before, all)
    });
    solve();
    done.store(true, Ordering::Release);
    probe.join().unwrap()
}

fn assert_live_stream(layer: &str, before: usize, all: &[Sample]) {
    assert!(before >= 2, "{layer}: only {before} samples arrived before the solve returned");
    assert!(all.len() >= before);
    // Samples are ordered and the elapsed clock is monotone.
    assert!(all.windows(2).all(|w| w[0].k <= w[1].k), "{layer}: k went backwards");
    assert!(
        all.windows(2).all(|w| w[0].elapsed <= w[1].elapsed),
        "{layer}: elapsed went backwards"
    );
    assert!(all.iter().all(|s| s.residual.is_finite()), "{layer}: non-finite residual");
}

// ---------------------------------------------------------------------------
// Property 1: mid-solve liveness, one test per layer class.
// ---------------------------------------------------------------------------

#[test]
fn channel_sink_is_live_mid_solve_sequential() {
    let sys = DatasetBuilder::new(500, 40).seed(1).consistent();
    let (sink, rx) = ProgressSink::bounded(1 << 14);
    let opts = SolveOptions::default()
        .with_fixed_iterations(400_000)
        .with_history_step(32)
        .with_progress(sink);
    let (before, all) = observe_mid_solve(rx, || {
        RkSolver::new(3).solve(&sys, &opts);
    });
    assert_live_stream("RK", before, &all);
}

#[test]
fn channel_sink_is_live_mid_solve_shared_memory() {
    let sys = DatasetBuilder::new(300, 24).seed(2).consistent();
    let (sink, rx) = ProgressSink::bounded(1 << 12);
    let opts = SolveOptions::default()
        .with_fixed_iterations(30_000)
        .with_history_step(16)
        .with_progress(sink);
    let (before, all) = observe_mid_solve(rx, || {
        ParallelRka::new(5, 2, 1.0).solve(&sys, &opts);
    });
    assert_live_stream("RKA-parallel", before, &all);
}

#[test]
fn channel_sink_is_live_mid_solve_asyrk() {
    // AsyRK's monitor records on its own polling cadence over the racy
    // global update count; the stream length is nondeterministic, but its
    // liveness is not.
    let sys = DatasetBuilder::new(200, 16).seed(3).consistent();
    let (sink, rx) = ProgressSink::bounded(1 << 12);
    let opts = SolveOptions::default()
        .with_fixed_iterations(300_000)
        .with_history_step(128)
        .with_progress(sink);
    let (before, all) = observe_mid_solve(rx, || {
        AsyRkSolver::new(3, 2).solve(&sys, &opts);
    });
    assert_live_stream("AsyRK", before, &all);
}

#[test]
fn channel_sink_is_live_mid_solve_distributed() {
    let sys = DatasetBuilder::new(240, 20).seed(4).consistent();
    let cluster = SimCluster::new(3, Placement::two_per_node());
    let (sink, rx) = ProgressSink::bounded(1 << 12);
    let opts = SolveOptions::default()
        .with_fixed_iterations(20_000)
        .with_history_step(8)
        .with_progress(sink);
    let (before, all) = observe_mid_solve(rx, || {
        DistRka::new(3, 1.0).solve(&sys, &opts, &cluster);
    });
    assert_live_stream("DistRka", before, &all);
}

#[test]
fn channel_sink_is_live_mid_solve_queue() {
    // Serving shape: a reference-free job in the queue, watched live
    // through the sink its own options carry.
    let src = DatasetBuilder::new(400, 30).seed(5).consistent();
    let system = LinearSystem::new(src.a.clone(), src.b.clone(), None, true);
    let (sink, rx) = ProgressSink::bounded(1 << 14);
    let mut queue = SolveQueue::new();
    queue.push(
        system,
        SolveOptions::default()
            .with_fixed_iterations(300_000)
            .with_history_step(64)
            .with_progress(sink),
    );
    let (before, all) = observe_mid_solve(rx, || {
        queue.run(&RkSolver::new(7)).unwrap();
    });
    assert_live_stream("SolveQueue", before, &all);
    // Reference-free system: the reference channel must stay empty.
    assert!(all.iter().all(|s| s.reference_err.is_none()));
}

// ---------------------------------------------------------------------------
// Property 2: slow/full sinks never perturb the solve (bitwise).
// ---------------------------------------------------------------------------

/// Run `make_solve` three times — sink-free, with a deliberately slow
/// callback, with a deliberately full capacity-1 channel — and require
/// identical iteration counts and bit-identical `x`.
fn assert_sink_noninterference<S: Solver>(layer: &str, solver: S, sys: &LinearSystem) {
    let base = SolveOptions::default().with_fixed_iterations(6_000).with_history_step(1_500);
    let plain = solver.solve(sys, &base);

    // Slow consumer: ~2ms per sample (5 samples: k = 0, 1500, ..., 6000).
    let slow_sink = ProgressSink::callback(|_s| std::thread::sleep(Duration::from_millis(2)));
    let slow = solver.solve(sys, &base.clone().with_progress(slow_sink));

    // Full channel: capacity 1, never drained — every emission after the
    // first hits the drop-oldest path.
    let (full_sink, rx) = ProgressSink::bounded(1);
    let full = solver.solve(sys, &base.clone().with_progress(full_sink));
    assert_eq!(rx.len(), 1, "{layer}: capacity-1 channel must hold exactly one sample");
    assert_eq!(rx.dropped() as usize + 1, plain.history.len(), "{layer}: drops unaccounted");

    for (name, watched) in [("slow callback", &slow), ("full channel", &full)] {
        assert_eq!(plain.iterations, watched.iterations, "{layer}/{name}: iteration drift");
        assert_eq!(plain.x.len(), watched.x.len(), "{layer}/{name}");
        for (i, (a, b)) in plain.x.iter().zip(&watched.x).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{layer}/{name}: x[{i}] differs ({a} vs {b})"
            );
        }
        // The recorded history is identical too: the sink taps the same
        // checkpoint values, it does not alter them.
        assert_eq!(plain.history.iterations, watched.history.iterations, "{layer}/{name}");
        for (a, b) in plain.history.residuals.iter().zip(&watched.history.residuals) {
            assert_eq!(a.to_bits(), b.to_bits(), "{layer}/{name}: residual sample drift");
        }
    }
}

#[test]
fn slow_and_full_sinks_do_not_perturb_sequential_solvers() {
    let sys = DatasetBuilder::new(200, 12).seed(11).consistent();
    assert_sink_noninterference("RK", RkSolver::new(9), &sys);
    assert_sink_noninterference("RKAB", RkabSolver::new(9, 4, 8, 1.0), &sys);
}

#[test]
fn slow_and_full_sinks_do_not_perturb_shared_memory_rkab() {
    // rkab_shared's gather is deterministic (bit-identical to the
    // sequential reference), so the bitwise claim holds for the parallel
    // engine too.
    let sys = DatasetBuilder::new(200, 12).seed(12).consistent();
    assert_sink_noninterference("RKAB-parallel", ParallelRkab::new(9, 2, 8, 1.0), &sys);
}

#[test]
fn slow_and_full_sinks_do_not_perturb_distributed_rkab() {
    let sys = DatasetBuilder::new(240, 16).seed(13).consistent();
    let cluster = SimCluster::new(2, Placement::two_per_node());
    let base = SolveOptions::default().with_fixed_iterations(3_000).with_history_step(750);
    let plain = DistRkab::new(5, 8, 1.0).solve(&sys, &base, &cluster);
    let (full_sink, _rx) = ProgressSink::bounded(1);
    let watched =
        DistRkab::new(5, 8, 1.0).solve(&sys, &base.clone().with_progress(full_sink), &cluster);
    assert_eq!(plain.iterations, watched.iterations);
    for (a, b) in plain.x.iter().zip(&watched.x) {
        assert_eq!(a.to_bits(), b.to_bits(), "DistRkab: x drift under full sink");
    }
}

#[test]
fn sinks_do_not_change_asyrk_outcomes() {
    // AsyRK is inherently racy (its iterate depends on thread interleaving
    // with or without a sink), so the bitwise claim does not apply; what
    // must hold is that a watched run still converges to the same quality.
    let sys = DatasetBuilder::new(200, 10).seed(14).consistent();
    let opts = SolveOptions::default().with_tolerance(1e-6).with_max_iterations(2_000_000);
    let (sink, rx) = ProgressSink::bounded(64);
    // Residual target mirrors tests/observability_properties.rs: AsyRK's
    // racy dense updates converge slowly, so it gets the looser bound.
    let watched_opts = opts
        .clone()
        .with_residual_stopping(1e-3, 1)
        .with_history_step(64)
        .with_progress(sink);
    let plain = AsyRkSolver::new(3, 2).solve(&sys, &opts);
    let watched = AsyRkSolver::new(3, 2).solve(&sys, &watched_opts);
    assert!(plain.converged);
    assert!(watched.converged, "watched AsyRK run failed to converge");
    assert!(!rx.is_empty() || rx.dropped() > 0, "watched AsyRK run emitted nothing");
}

// ---------------------------------------------------------------------------
// Property 3: per-job demultiplexing through the queue.
// ---------------------------------------------------------------------------

#[test]
fn queue_jobs_receive_their_own_streams() {
    // Three jobs with distinct systems and budgets, each watched on its own
    // channel, drained by two stealing lanes: every channel must carry
    // exactly its job's curve (same k, same residual bits as the history
    // that job reported).
    let mut queue = SolveQueue::new().with_workers(2);
    let mut rxs = Vec::new();
    for (j, (m, n)) in [(300usize, 20usize), (250, 16), (350, 24)].iter().enumerate() {
        let sys = DatasetBuilder::new(*m, *n).seed(20 + j as u32).consistent();
        let (sink, rx) = ProgressSink::bounded(128);
        rxs.push(rx);
        queue.push(
            sys,
            SolveOptions::default()
                .with_fixed_iterations(6_000 + 1_000 * j)
                .with_history_step(100)
                .with_progress(sink),
        );
    }
    let reports = queue.run(&RkSolver::new(2)).unwrap();
    assert_eq!(reports.len(), 3);
    for (j, rx) in rxs.iter().enumerate() {
        let samples = rx.drain();
        let h = &reports[j].result.history;
        assert_eq!(rx.dropped(), 0, "job {j}: capacity was sized for the full stream");
        assert_eq!(samples.len(), h.len(), "job {j}: stream/history length mismatch");
        for (s, (k, r)) in samples.iter().zip(h.iterations.iter().zip(&h.residuals)) {
            assert_eq!(s.k, *k, "job {j}: wrong iteration in stream");
            assert_eq!(s.residual.to_bits(), r.to_bits(), "job {j}: foreign sample in stream");
        }
    }
}

// ---------------------------------------------------------------------------
// Property 4: reference-free autotune.
// ---------------------------------------------------------------------------

#[test]
fn residual_autotune_agrees_with_reference_autotune_on_consistent_systems() {
    let sys = DatasetBuilder::new(1500, 80).seed(21).consistent();
    let model = CostModel::calibrate(&sys);
    let cfg = AutotuneConfig::new(4);
    let (best_ref, probes_ref) = autotune_block_size(&sys, &model, &cfg).unwrap();
    let (best_res, probes_res) = autotune_block_size_residual(&sys, &model, &cfg).unwrap();

    // Same protocol: identical candidate sets and probe budgets (the two
    // scorers run the same probe trajectories with the same seed).
    let sizes = |p: &[kaczmarz::coordinator::autotune::ProbeResult]| {
        p.iter().map(|r| (r.block_size, r.iterations)).collect::<Vec<_>>()
    };
    assert_eq!(sizes(&probes_ref), sizes(&probes_res));

    // Agreement within the test band. The two scorers run identical probe
    // trajectories, so per candidate they divide the same modeled time into
    // decays of two metrics that shrink together on a consistent system —
    // offline simulation of these exact probes (bit-exact MT19937 port)
    // puts the residual/reference decay ratio at 1.017–1.019 for every
    // candidate. Argmax *positions* are NOT compared: with a fixed row
    // budget the probes land near-tied, so the argmax legitimately swings
    // with the machine's calibrated cost constants. The robust claim is
    // score-level: per-candidate scores agree within 25%, and each tuner's
    // winner is within 2x of the other tuner's winner under the *other*
    // scorer's metric.
    let score_of = |probes: &[kaczmarz::coordinator::autotune::ProbeResult], bs: usize| {
        probes
            .iter()
            .find(|r| r.block_size == bs)
            .expect("winner is a probed candidate")
            .score
    };
    for (r_ref, r_res) in probes_ref.iter().zip(&probes_res) {
        assert!(r_ref.score > 0.0, "reference probe bs={} saw no decay", r_ref.block_size);
        assert!(r_res.score > 0.0, "residual probe bs={} saw no decay", r_res.block_size);
        let ratio = r_res.score / r_ref.score;
        assert!(
            (0.75..=1.25).contains(&ratio),
            "bs={}: residual score {} vs reference score {} (ratio {ratio})",
            r_ref.block_size,
            r_res.score,
            r_ref.score
        );
    }
    assert!(
        score_of(&probes_ref, best_res) >= 0.5 * score_of(&probes_ref, best_ref),
        "residual pick bs={best_res} scores poorly under the reference metric: {:?}",
        probes_ref.iter().map(|r| (r.block_size, r.score)).collect::<Vec<_>>(),
    );
    assert!(
        score_of(&probes_res, best_ref) >= 0.5 * score_of(&probes_res, best_res),
        "reference pick bs={best_ref} scores poorly under the residual metric: {:?}",
        probes_res.iter().map(|r| (r.block_size, r.score)).collect::<Vec<_>>(),
    );
}

#[test]
fn residual_autotune_runs_on_reference_free_systems() {
    // The production shape: nobody knows x*. error_sq panics on this
    // system, so completing at all proves the scorer is reference-free.
    let src = DatasetBuilder::new(600, 40).seed(22).consistent();
    let sys = LinearSystem::new(src.a.clone(), src.b.clone(), None, true);
    let model = CostModel::calibrate(&src);
    let (best, probes) =
        autotune_block_size_residual(&sys, &model, &AutotuneConfig::new(2)).unwrap();
    assert!(best >= 1);
    assert!(probes.iter().all(|r| r.metric_sq.is_finite()));
}

// ---------------------------------------------------------------------------
// Sample/SolveResult coherence: the stream is the history, live.
// ---------------------------------------------------------------------------

#[test]
fn streamed_samples_match_the_recorded_history_bit_for_bit() {
    let sys = DatasetBuilder::new(300, 20).seed(30).consistent();
    let (sink, rx) = ProgressSink::bounded(256);
    let opts = SolveOptions::default()
        .with_fixed_iterations(4_000)
        .with_history_step(250)
        .with_progress(sink);
    let r: SolveResult = RkabSolver::new(6, 2, 8, 1.0).solve(&sys, &opts);
    let samples = rx.drain();
    assert_eq!(samples.len(), r.history.len());
    for (i, s) in samples.iter().enumerate() {
        assert_eq!(s.k, r.history.iterations[i]);
        assert_eq!(s.residual.to_bits(), r.history.residuals[i].to_bits());
        // Referenced system: the stream carries the error channel too.
        assert_eq!(s.reference_err.map(f64::to_bits), Some(r.history.errors[i].to_bits()));
    }
}
